"""L2 JAX compute graphs for the G-REST update step (paper Alg. 2).

Three build-time-lowered functions make up one G-REST time step; the Rust
coordinator (L3) interleaves them with sparse Delta products and the small
dense eigendecomposition:

  1. ``build_basis(xbar, panel)``   -> (q, valid)
         Orthonormal augmentation panel Q spanning
         (I - XbarXbar^T) panel  (paper Eq. 11), via the Pallas
         project-out kernel (L1) applied twice (BCGS2) followed by
         CholeskyQR2.  ``valid`` flags columns that survived rank
         screening; deflated columns are exactly zero.

  2. ``form_t(xbar, q, lam, dxk, dq)`` -> t
         The projected Rayleigh-Ritz matrix of Eq. (13) with
         Z = [Xbar, Q].  Because Q is constructed orthogonal to Xbar and
         Xbar is orthonormal, Z^T Abar Z = diag(lam) on the leading K x K
         block and zero elsewhere; the Delta term uses the precomputed
         sparse products dxk = Delta Xbar and dq = Delta Q supplied by L3.

  3. ``rotate(xbar, q, f1, f2)``    -> x_new
         Ritz rotation X_new = Xbar F1 + Q F2 after L3 eigendecomposes t
         (small, (K+M) x (K+M), done natively in Rust).

Everything is *custom-call-free*: the PJRT runtime bundled with the
``xla`` crate (xla_extension 0.5.1) predates jax's current LAPACK FFI
custom calls, so QR/Cholesky/triangular-inverse are implemented here in
pure lax ops (masked ``fori_loop`` factorizations).  All shapes are
static per artifact tier; the L3 runtime zero-pads N rows and M columns,
which these kernels preserve exactly (zero rows stay zero through
project-out and CholQR; zero columns are deflated by rank screening).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import projection

# Ridge used to keep the masked Cholesky positive definite in the
# presence of padded (exactly-zero) or rank-deficient panel columns.
_RIDGE = 1e-10
# Columns whose norm after CholQR2 falls below this are treated as rank
# deficient and deflated to zero.  Valid columns exit CholQR2 with norm
# ≈ 1 and rank-guarded dependent columns with norm ≈ 0, so 0.5 separates
# the two populations with maximal margin.
_DEFLATE_TOL = 0.5


def cholesky_masked(g: jax.Array, pivot_tol: float = 1e-6) -> jax.Array:
    """Rank-guarded lower Cholesky factor of an (m, m) PSD matrix in pure
    lax ops.

    Left-looking column algorithm with mask-based "dynamic" triangular
    indexing so the loop body is shape-static (lowered as an XLA while
    loop, no LAPACK custom call).

    Rank guard: when the Schur-complement diagonal of column j collapses
    below ``pivot_tol * max_diag(G)`` — i.e. panel column j is (numerically)
    dependent on earlier columns — the column is replaced by eₗ.  Then
    R = Lᵀ has R_jj = 1 and zero fill in that column's trailing part, so
    P·R⁻¹ maps the dependent column to its (tiny) residual instead of
    amplifying noise by 1/√ridge; the norm screen in ``build_basis``
    deflates it exactly.  Without this guard, rank-deficient update
    panels (common: pure-expansion Δ has rank ≤ 2S) produced
    non-orthonormal junk directions that silently corrupted the
    Rayleigh-Ritz matrix.
    """
    m = g.shape[0]
    idx = jnp.arange(m)
    scale = jnp.maximum(jnp.max(jnp.diag(g)), _RIDGE)

    def body(j, l):
        below = (idx < j).astype(g.dtype)  # strictly-earlier columns
        lj_row = l[j, :] * below
        c = g[:, j] - l @ lj_row
        keep = c[j] > pivot_tol * scale
        d = jnp.where(keep, jnp.sqrt(jnp.maximum(c[j], _RIDGE)), jnp.ones_like(c[j]))
        col = jnp.where(keep, c / d, (idx == j).astype(g.dtype))
        col = jnp.where(idx >= j, col, jnp.zeros_like(col))
        return l.at[:, j].set(col)

    return lax.fori_loop(0, m, body, jnp.zeros_like(g))


def tri_inv_upper(r: jax.Array) -> jax.Array:
    """Inverse of an (m, m) upper-triangular matrix via back substitution.

    Row-oriented: processes rows bottom-up, each step a masked (m,) @
    (m, m) contraction, so the whole solve is O(m^2) work per iteration
    inside an XLA while loop.
    """
    m = r.shape[0]
    idx = jnp.arange(m)

    def body(step, x):
        i = m - 1 - step
        above = (idx > i).astype(r.dtype)
        ri = r[i, :] * above
        e_i = (idx == i).astype(r.dtype)
        row = (e_i - ri @ x) / r[i, i]
        return x.at[i, :].set(row)

    return lax.fori_loop(0, m, body, jnp.zeros_like(r))


def _cholqr(p: jax.Array, *, interpret: bool) -> jax.Array:
    """One CholeskyQR pass: P -> P R^{-1} with R = chol(P^T P + ridge)^T."""
    g = projection.gram(p, p, interpret=interpret)
    scale = jnp.maximum(jnp.max(jnp.abs(jnp.diag(g))), 1.0)
    g = g + (_RIDGE * scale) * jnp.eye(g.shape[0], dtype=g.dtype)
    l = cholesky_masked(g)
    rinv = tri_inv_upper(l.T)
    return p @ rinv


@functools.partial(jax.jit, static_argnames=("interpret",))
def build_basis(xbar: jax.Array, panel: jax.Array, *, interpret: bool = True):
    """Phase 1: orthonormal basis of (I - XbarXbar^T) panel.

    Args:
      xbar: (N, K) orthonormal tracked eigenvectors (zero-padded rows ok).
      panel: (N, M) update panel [Delta Xbar_K, Delta_2-or-sketch]
        (zero-padded columns ok).

    Returns:
      q: (N, M) with orthonormal valid columns, zero deflated columns,
        and Q^T xbar = 0.
      valid: (M,) float mask of surviving columns.
    """
    # BCGS2: project out the tracked subspace twice for orthogonality to
    # working precision, interleaved with CholQR passes for intra-panel
    # orthonormality (CholeskyQR2).
    p = projection.project_out(xbar, panel, interpret=interpret)
    p = _cholqr(p, interpret=interpret)
    p = projection.project_out(xbar, p, interpret=interpret)
    p = _cholqr(p, interpret=interpret)
    norms = jnp.sqrt(jnp.sum(p * p, axis=0))
    valid = (norms > _DEFLATE_TOL).astype(p.dtype)
    safe = jnp.where(norms > _DEFLATE_TOL, norms, jnp.ones_like(norms))
    q = p * (valid / safe)[None, :]
    return q, valid


@functools.partial(jax.jit, static_argnames=("interpret",))
def form_t(
    xbar: jax.Array,
    q: jax.Array,
    lam: jax.Array,
    dxk: jax.Array,
    dq: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Phase 2a: projected matrix T = Z^T Abar Z + Z^T Delta Z (Eq. 13).

    Args:
      xbar: (N, K) tracked eigenvectors.
      q: (N, M) augmentation basis from :func:`build_basis`.
      lam: (K,) tracked eigenvalues.
      dxk: (N, K) sparse product Delta Xbar (computed by L3).
      dq: (N, M) sparse product Delta Q (computed by L3).

    Returns:
      (K+M, K+M) symmetric projected matrix.
    """
    k = xbar.shape[1]
    m = q.shape[1]
    t11 = jnp.diag(lam) + projection.gram(xbar, dxk, interpret=interpret)
    t12 = projection.gram(xbar, dq, interpret=interpret)
    t22 = projection.gram(q, dq, interpret=interpret)
    top = jnp.concatenate([t11, t12], axis=1)
    bot = jnp.concatenate([t12.T, t22], axis=1)
    t = jnp.concatenate([top, bot], axis=0)
    return 0.5 * (t + t.T)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rotate(
    xbar: jax.Array,
    q: jax.Array,
    f1: jax.Array,
    f2: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Phase 2b: Ritz rotation X_new = Xbar F1 + Q F2.

    F = [F1; F2] holds the top-K eigenvectors of T (columns), computed
    natively by L3's dense eigensolver between phases 2a and 2b.
    """
    del interpret
    return xbar @ f1 + q @ f2


# ---------------------------------------------------------------------------
# Reference single-call composition (testing only; artifacts ship the three
# functions separately because the small eigh runs in Rust).
# ---------------------------------------------------------------------------


def grest_step_reference(xbar, lam, panel, delta_matvec, k_out=None):
    """Full G-REST step in numpy-ish jax, for python-side validation.

    ``delta_matvec`` maps an (N, j) block to Delta @ block (dense oracle
    in tests).  Uses jnp.linalg.eigh (NOT artifact-safe) — test-only.
    """
    k = xbar.shape[1]
    k_out = k_out or k
    q, _ = build_basis(xbar, panel)
    dxk = delta_matvec(xbar)
    dq = delta_matvec(q)
    t = form_t(xbar, q, lam, dxk, dq)
    theta, f = jnp.linalg.eigh(t)
    order = jnp.argsort(-jnp.abs(theta))[:k_out]
    theta_k = theta[order]
    f_k = f[:, order]
    x_new = rotate(xbar, q, f_k[:k, :], f_k[k:, :])
    return theta_k, x_new
