"""Pure-jnp oracles for the Pallas kernels in :mod:`projection`.

Used by pytest/hypothesis to validate the tiled kernels over shape and
dtype sweeps; never lowered into artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_ref(x, b):
    """C = X^T B."""
    dtype = jnp.promote_types(x.dtype, b.dtype)
    return jnp.dot(x.astype(dtype).T, b.astype(dtype))


def apply_proj_ref(b, x, c):
    """P = B - X C."""
    dtype = jnp.promote_types(jnp.promote_types(b.dtype, x.dtype), c.dtype)
    return b.astype(dtype) - jnp.dot(x.astype(dtype), c.astype(dtype))


def project_out_ref(x, b):
    """P = (I - X X^T) B."""
    return apply_proj_ref(b, x, gram_ref(x, b))
