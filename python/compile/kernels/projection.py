"""L1 Pallas kernels for G-REST's dense hot path.

The per-step hot spot of G-REST (paper Sec. 3.3/4) is the tall-skinny
"project-out" chain

    P = B - X (X^T B),        X: (N, K) orthonormal,  B: (N, M) panel,

which removes the tracked eigenspace Ran(X) from the update panel before
orthonormalization (Table 1, row 4).  Both Gram accumulation and the
correction are expressed as tiled Pallas kernels:

  * ``gram``        C = X^T B          — one-pass reduction over N tiles,
                                          (K, M) accumulator resident in VMEM.
  * ``apply_proj``  P = B - X C        — streaming pass over N tiles.
  * ``project_out`` composition of the two.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks the N
dimension in ``TILE_N`` rows; each grid step holds an (TILE_N, K) slab of X,
an (TILE_N, M) slab of B and the (K, M) accumulator in VMEM
(256*64 + 256*192 + 64*192 floats ~ 0.3 MB at the large tier), and the
contraction ``x.T @ b`` is MXU-shaped.  ``interpret=True`` everywhere:
this repository executes on the CPU PJRT plugin; a real-TPU build would
drop the flag and lower to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile of the streaming dimension.  256 keeps the VMEM working set
# small while giving the MXU full 128-lane panels; it also divides every
# artifact tier's N_cap (all tiers are multiples of 256).
TILE_N = 256


def _gram_kernel(x_ref, b_ref, o_ref):
    """Accumulate one (TILE_N, K)^T @ (TILE_N, M) contribution of X^T B."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    b = b_ref[...]
    o_ref[...] += jnp.dot(x.T, b, preferred_element_type=o_ref.dtype)


def _apply_kernel(b_ref, x_ref, c_ref, o_ref):
    """One (TILE_N, M) tile of P = B - X C."""
    o_ref[...] = b_ref[...] - jnp.dot(
        x_ref[...], c_ref[...], preferred_element_type=o_ref.dtype
    )


def _pad_rows(a: jax.Array, tile: int) -> jax.Array:
    n = a.shape[0]
    rem = (-n) % tile
    if rem:
        a = jnp.pad(a, ((0, rem),) + ((0, 0),) * (a.ndim - 1))
    return a


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram(x: jax.Array, b: jax.Array, *, interpret: bool = True) -> jax.Array:
    """C = X^T B via a tiled Pallas reduction.

    Args:
      x: (N, K) left factor.
      b: (N, M) right factor.
    Returns:
      (K, M) Gram product, in the promoted dtype of the inputs.
    """
    n, k = x.shape
    _, m = b.shape
    dtype = jnp.promote_types(x.dtype, b.dtype)
    # Accumulate across N-tiles in f32 regardless of input dtype (matches
    # the MXU's native f32 accumulation and keeps bf16 inputs accurate).
    acc = jnp.float32 if dtype != jnp.float64 else dtype
    xp = _pad_rows(x.astype(dtype), TILE_N)
    bp = _pad_rows(b.astype(dtype), TILE_N)
    steps = xp.shape[0] // TILE_N
    out = pl.pallas_call(
        _gram_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((TILE_N, k), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, m), acc),
        interpret=interpret,
    )(xp, bp)
    return out.astype(dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_proj(
    b: jax.Array, x: jax.Array, c: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """P = B - X C via a tiled streaming Pallas pass."""
    n, m = b.shape
    _, k = x.shape
    dtype = jnp.promote_types(jnp.promote_types(b.dtype, x.dtype), c.dtype)
    bp = _pad_rows(b.astype(dtype), TILE_N)
    xp = _pad_rows(x.astype(dtype), TILE_N)
    steps = bp.shape[0] // TILE_N
    out = pl.pallas_call(
        _apply_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((TILE_N, m), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N, k), lambda i: (i, 0)),
            pl.BlockSpec((k, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp.shape[0], m), dtype),
        interpret=interpret,
    )(bp, xp, c.astype(dtype))
    return out[:n]


def project_out(x: jax.Array, b: jax.Array, *, interpret: bool = True) -> jax.Array:
    """P = (I - X X^T) B — the fused projection used by G-REST (Eq. 11)."""
    c = gram(x, b, interpret=interpret)
    return apply_proj(b, x, c, interpret=interpret)
