"""AOT lowering of the G-REST L2 graphs to HLO text artifacts.

Emits, for every size tier, three artifacts consumed by the Rust runtime
(``rust/src/runtime``):

    artifacts/build_basis_<tier>.hlo.txt     (xbar, panel)        -> (q, valid)
    artifacts/form_t_<tier>.hlo.txt          (xbar, q, lam, dxk, dq) -> (t,)
    artifacts/rotate_<tier>.hlo.txt          (xbar, q, f1, f2)    -> (x_new,)

plus ``artifacts/manifest.json`` describing shapes.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Size tiers.  K = 64 matches the paper's tracked-eigenpair count; the
# panel width M covers K columns of Delta*Xbar plus the node-expansion
# block (Delta_2 or its RSVD sketch).  t256 is a miniature tier used by
# tests and the quickstart.  All N are multiples of the Pallas TILE_N.
TIERS = [
    {"name": "t256", "n": 256, "k": 16, "m": 32},
    {"name": "t1024", "n": 1024, "k": 64, "m": 128},
    {"name": "t4096", "n": 4096, "k": 64, "m": 128},
    {"name": "t16384", "n": 16384, "k": 64, "m": 192},
]

DTYPE = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def lower_tier(tier: dict) -> list[dict]:
    n, k, m = tier["n"], tier["k"], tier["m"]
    entries = []

    fns = {
        "build_basis": (
            model.build_basis,
            (_spec(n, k), _spec(n, m)),
            [["q", [n, m]], ["valid", [m]]],
        ),
        "form_t": (
            model.form_t,
            (_spec(n, k), _spec(n, m), _spec(k), _spec(n, k), _spec(n, m)),
            [["t", [k + m, k + m]]],
        ),
        "rotate": (
            model.rotate,
            (_spec(n, k), _spec(n, m), _spec(k, k), _spec(m, k)),
            [["x_new", [n, k]]],
        ),
    }
    for fname, (fn, args, outputs) in fns.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname_out = f"{fname}_{tier['name']}.hlo.txt"
        entries.append(
            {
                "fn": fname,
                "tier": tier["name"],
                "file": fname_out,
                "n": n,
                "k": k,
                "m": m,
                "inputs": [list(a.shape) for a in args],
                "outputs": outputs,
                "text": text,
            }
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--tiers",
        default="all",
        help="comma-separated tier names (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    selected = TIERS
    if args.tiers != "all":
        names = set(args.tiers.split(","))
        selected = [t for t in TIERS if t["name"] in names]

    manifest = {"dtype": "f32", "tile_n": 256, "artifacts": []}
    for tier in selected:
        for entry in lower_tier(tier):
            text = entry.pop("text")
            path = os.path.join(args.out_dir, entry["file"])
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(entry)
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Whitespace-delimited twin of the manifest for the dependency-free
    # Rust parser: "fn tier file n k m" per line.
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        for e in manifest["artifacts"]:
            f.write(
                f"{e['fn']} {e['tier']} {e['file']} {e['n']} {e['k']} {e['m']}\n"
            )
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
