"""AOT path: lowered HLO artifacts are custom-call-free and well-formed."""


import pytest

pytest.importorskip("jax", reason="jax not installed; compile-pipeline suite skipped")

import json
import os

import jax

from compile import aot

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def t256_entries():
    tier = next(t for t in aot.TIERS if t["name"] == "t256")
    return aot.lower_tier(tier)


def test_t256_lowering_produces_three_artifacts(t256_entries):
    assert {e["fn"] for e in t256_entries} == {"build_basis", "form_t", "rotate"}


def test_no_custom_calls(t256_entries):
    """xla_extension 0.5.1 cannot execute jax's LAPACK custom calls; the
    whole model must lower to native HLO ops."""
    for e in t256_entries:
        assert "custom-call" not in e["text"], f"{e['fn']} contains a custom call"


def test_entry_layouts_match_manifest(t256_entries):
    for e in t256_entries:
        head = e["text"].splitlines()[0]
        assert "entry_computation_layout" in head
        for shape in e["inputs"]:
            token = "f32[" + ",".join(str(s) for s in shape) + "]"
            assert token in head, f"{e['fn']}: input {token} missing from layout"


def test_artifacts_dir_if_built_matches_manifest():
    """If `make artifacts` has been run, every manifest entry exists on disk."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    with open(manifest) as f:
        m = json.load(f)
    for e in m["artifacts"]:
        path = os.path.join(art, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule"), e["file"]
        assert "custom-call" not in text
