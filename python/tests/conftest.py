"""Guard the JAX/Pallas test suite behind its optional dependencies.

The offline image may lack `jax` and/or `hypothesis`; the test modules
here import them at collection time, which would turn
`pytest python/tests/` into hard collection errors.  Each module is
dropped from collection when a dependency *it actually uses* is
missing (the modules also self-guard with module-level
`pytest.importorskip`, which covers directly-named files), and
`test_environment.py` reports the situation as one visible skip so the
run exits green.

Note: `pytest.importorskip` must NOT be called at conftest scope — it
raises during pytest's config stage and aborts the whole run.
"""
import importlib.util


def _missing(*mods):
    return any(importlib.util.find_spec(m) is None for m in mods)


collect_ignore = []
if _missing("jax"):
    collect_ignore.append("test_aot.py")
if _missing("jax", "hypothesis"):
    collect_ignore.extend(["test_kernels.py", "test_model.py"])
