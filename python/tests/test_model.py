"""L2 correctness: model phases vs numpy references and spectral invariants."""


import pytest

pytest.importorskip("jax", reason="jax not installed; compile-pipeline suite skipped")
pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; compile-pipeline suite skipped"
)

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_enable_x64", False)


def _sym(rng, n):
    a = rng.standard_normal((n, n))
    return (a + a.T) / 2


def _leading(a, k):
    w, v = np.linalg.eigh(a)
    order = np.argsort(-np.abs(w))[:k]
    return w[order], v[:, order]


# ---------------------------------------------------------------------------
# Pure-lax factorization building blocks
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
def test_cholesky_masked_matches_numpy(m, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, m + 3))
    g = (a @ a.T + m * np.eye(m)).astype(np.float32)
    l = np.asarray(model.cholesky_masked(jnp.asarray(g)))
    np.testing.assert_allclose(l @ l.T, g, rtol=1e-3, atol=1e-3)
    assert np.allclose(np.triu(l, 1), 0.0)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_tri_inv_upper(m, seed):
    rng = np.random.default_rng(seed)
    r = np.triu(rng.standard_normal((m, m))).astype(np.float32)
    r[np.arange(m), np.arange(m)] = np.sign(r.diagonal()) * (
        np.abs(r.diagonal()) + 1.0
    )
    rinv = np.asarray(model.tri_inv_upper(jnp.asarray(r)))
    np.testing.assert_allclose(r @ rinv, np.eye(m), atol=2e-4)
    assert np.allclose(np.tril(rinv, -1), 0.0)


# ---------------------------------------------------------------------------
# build_basis invariants
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(40, 500),
    k=st.integers(1, 16),
    m=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_build_basis_orthonormal(n, k, m, seed):
    rng = np.random.default_rng(seed)
    x, _ = np.linalg.qr(rng.standard_normal((n, k)))
    panel = rng.standard_normal((n, m))
    q, valid = model.build_basis(
        jnp.asarray(x, jnp.float32), jnp.asarray(panel, jnp.float32)
    )
    q, valid = np.asarray(q), np.asarray(valid)
    nv = int(valid.sum())
    assert nv >= 1  # generic random panel is full rank
    qv = q[:, valid > 0.5]
    np.testing.assert_allclose(qv.T @ qv, np.eye(nv), atol=2e-3)
    np.testing.assert_allclose(qv.T @ x, 0.0, atol=2e-3)
    # deflated columns are exactly zero
    assert np.all(q[:, valid < 0.5] == 0.0)


def test_build_basis_deflates_dependent_and_zero_columns():
    rng = np.random.default_rng(3)
    n, k = 200, 6
    x, _ = np.linalg.qr(rng.standard_normal((n, k)))
    good = rng.standard_normal((n, 4))
    panel = np.concatenate(
        [good, good[:, :1] * 2.0, np.zeros((n, 3)), x[:, :2]], axis=1
    )  # 4 good + 1 dependent + 3 zero + 2 in Ran(X)
    q, valid = model.build_basis(
        jnp.asarray(x, jnp.float32), jnp.asarray(panel, jnp.float32)
    )
    valid = np.asarray(valid)
    assert valid.sum() <= 5  # at most the 4 independent + slack 1
    qv = np.asarray(q)[:, valid > 0.5]
    np.testing.assert_allclose(qv.T @ qv, np.eye(qv.shape[1]), atol=5e-3)


def test_build_basis_zero_padded_rows_stay_zero():
    rng = np.random.default_rng(4)
    n, pad, k, m = 150, 106, 5, 8
    x = np.zeros((n + pad, k), np.float32)
    x[:n], _ = np.linalg.qr(rng.standard_normal((n, k)))
    panel = np.zeros((n + pad, m), np.float32)
    panel[:n] = rng.standard_normal((n, m))
    q, valid = model.build_basis(jnp.asarray(x), jnp.asarray(panel))
    q = np.asarray(q)
    np.testing.assert_allclose(q[n:], 0.0, atol=1e-6)


def test_build_basis_padding_equivalence():
    """Padded (rows+cols) call reproduces the unpadded basis span."""
    rng = np.random.default_rng(5)
    n, k, m = 120, 4, 6
    x, _ = np.linalg.qr(rng.standard_normal((n, k)))
    panel = rng.standard_normal((n, m)).astype(np.float32)
    q0, _ = model.build_basis(jnp.asarray(x, jnp.float32), jnp.asarray(panel))
    xp = np.zeros((256, k), np.float32)
    xp[:n] = x
    pp = np.zeros((256, m + 5), np.float32)
    pp[:n, :m] = panel
    qp, validp = model.build_basis(jnp.asarray(xp), jnp.asarray(pp))
    qp, validp = np.asarray(qp), np.asarray(validp)
    assert int(validp.sum()) == m
    # spans agree: projector difference is tiny
    p0 = np.asarray(q0) @ np.asarray(q0).T
    pv = qp[:n][:, validp > 0.5]
    np.testing.assert_allclose(pv @ pv.T, p0, atol=5e-3)


# ---------------------------------------------------------------------------
# form_t / rotate / full-step spectral accuracy
# ---------------------------------------------------------------------------


def test_form_t_matches_dense_projection():
    rng = np.random.default_rng(11)
    n, k, m = 90, 5, 7
    a = _sym(rng, n)
    lam, x = _leading(a, k)
    d = np.zeros((n, n))
    ii = rng.integers(0, n, size=(30, 2))
    for i, j in ii:
        if i != j:
            d[i, j] = d[j, i] = 0.1
    panel = (d @ x).astype(np.float32)[:, :m]
    xf = jnp.asarray(x, jnp.float32)
    q, _ = model.build_basis(xf, jnp.asarray(panel))
    dxk = jnp.asarray(d, jnp.float32) @ xf
    dq = jnp.asarray(d, jnp.float32) @ q
    t = np.asarray(model.form_t(xf, q, jnp.asarray(lam, jnp.float32), dxk, dq))
    z = np.concatenate([x, np.asarray(q)], axis=1)
    abar_lowrank = x @ np.diag(lam) @ x.T
    t_ref = z.T @ (abar_lowrank + d) @ z
    np.testing.assert_allclose(t, t_ref, atol=2e-3)
    np.testing.assert_allclose(t, t.T, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_grest_step_tracks_perturbed_spectrum(seed):
    """After one step, Ritz pairs approximate the exact leading eigenpairs
    of A + Delta far better than the stale eigenvectors do."""
    rng = np.random.default_rng(seed)
    n, k = 120, 6
    a = _sym(rng, n)
    lam, x = _leading(a, k)
    d = np.zeros((n, n))
    for _ in range(25):
        i, j = rng.integers(0, n, 2)
        if i != j:
            d[i, j] = d[j, i] = 0.2 * rng.standard_normal()
    panel = (d @ x).astype(np.float32)
    theta, xn = model.grest_step_reference(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(lam, jnp.float32),
        jnp.asarray(panel),
        lambda b: jnp.asarray(d, jnp.float32) @ b,
    )
    wh, vh = _leading(a + d, k)
    theta, xn = np.asarray(theta), np.asarray(xn)
    order = np.argsort(-np.abs(theta))
    # residual of the top Ritz pair against the exact operator
    top = xn[:, order[0]]
    res_new = np.linalg.norm((a + d) @ top - theta[order[0]] * top)
    res_old = np.linalg.norm((a + d) @ x[:, 0] - lam[0] * x[:, 0])
    assert res_new < res_old * 0.9 or res_new < 1e-3


def test_grest_step_exact_when_delta_zero():
    rng = np.random.default_rng(21)
    n, k = 80, 4
    a = _sym(rng, n)
    lam, x = _leading(a, k)
    panel = np.zeros((n, 5), np.float32)
    theta, xn = model.grest_step_reference(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(lam, jnp.float32),
        jnp.asarray(panel),
        lambda b: jnp.zeros_like(b),
    )
    theta = np.sort(np.asarray(theta))
    np.testing.assert_allclose(theta, np.sort(lam), atol=1e-4)


def test_rotate_is_plain_matmul():
    rng = np.random.default_rng(22)
    xbar = rng.standard_normal((60, 4)).astype(np.float32)
    q = rng.standard_normal((60, 7)).astype(np.float32)
    f1 = rng.standard_normal((4, 4)).astype(np.float32)
    f2 = rng.standard_normal((7, 4)).astype(np.float32)
    got = np.asarray(model.rotate(*map(jnp.asarray, (xbar, q, f1, f2))))
    np.testing.assert_allclose(got, xbar @ f1 + q @ f2, rtol=1e-5, atol=1e-5)
