"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including non-multiples of TILE_N, which
exercise the padding path) and dtypes, asserting allclose against ref.
"""


import pytest

pytest.importorskip("jax", reason="jax not installed; compile-pipeline suite skipped")
pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; compile-pipeline suite skipped"
)

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import projection, ref

jax.config.update("jax_enable_x64", False)


def _rand(rng, shape, dtype):
    a = rng.standard_normal(shape)
    return jnp.asarray(a, dtype=dtype)


def _tols(dtype):
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=2e-4, atol=2e-4)


shapes = st.tuples(
    st.integers(min_value=1, max_value=700),  # N (crosses TILE_N boundaries)
    st.integers(min_value=1, max_value=48),  # K
    st.integers(min_value=1, max_value=48),  # M
)
dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


@settings(max_examples=25, deadline=None)
@given(shape=shapes, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_gram_matches_ref(shape, dtype, seed):
    n, k, m = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, k), dtype)
    b = _rand(rng, (n, m), dtype)
    got = projection.gram(x, b)
    want = ref.gram_ref(x, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        **_tols(dtype),
    )


@settings(max_examples=25, deadline=None)
@given(shape=shapes, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_apply_proj_matches_ref(shape, dtype, seed):
    n, k, m = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, k), dtype)
    b = _rand(rng, (n, m), dtype)
    c = _rand(rng, (k, m), dtype)
    got = projection.apply_proj(b, x, c)
    want = ref.apply_proj_ref(b, x, c)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        **_tols(dtype),
    )


@settings(max_examples=20, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31 - 1))
def test_project_out_matches_ref_f32(shape, seed):
    n, k, m = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, k), jnp.float32)
    b = _rand(rng, (n, m), jnp.float32)
    got = projection.project_out(x, b)
    want = ref.project_out_ref(x, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_project_out_annihilates_range():
    """(I - XX^T)(X c) == 0 for orthonormal X."""
    rng = np.random.default_rng(7)
    a = rng.standard_normal((300, 12))
    x, _ = np.linalg.qr(a)
    c = rng.standard_normal((12, 5))
    b = jnp.asarray(x @ c, jnp.float32)
    p = projection.project_out(jnp.asarray(x, jnp.float32), b)
    np.testing.assert_allclose(np.asarray(p), 0.0, atol=1e-4)


def test_project_out_idempotent():
    rng = np.random.default_rng(8)
    x, _ = np.linalg.qr(rng.standard_normal((257, 9)))
    x = jnp.asarray(x, jnp.float32)
    b = jnp.asarray(rng.standard_normal((257, 6)), jnp.float32)
    p1 = projection.project_out(x, b)
    p2 = projection.project_out(x, p1)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-3, atol=1e-4)


def test_gram_zero_padding_rows_invariant():
    """Zero rows contribute nothing: gram(pad(x), pad(b)) == gram(x, b)."""
    rng = np.random.default_rng(9)
    x = rng.standard_normal((100, 7)).astype(np.float32)
    b = rng.standard_normal((100, 11)).astype(np.float32)
    xp = np.zeros((512, 7), np.float32)
    bp = np.zeros((512, 11), np.float32)
    xp[:100], bp[:100] = x, b
    np.testing.assert_allclose(
        np.asarray(projection.gram(jnp.asarray(xp), jnp.asarray(bp))),
        np.asarray(projection.gram(jnp.asarray(x), jnp.asarray(b))),
        rtol=1e-4,
        atol=1e-5,
    )


@pytest.mark.parametrize("n", [255, 256, 257, 512, 513])
def test_tile_boundaries(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(projection.project_out(x, b)),
        np.asarray(ref.project_out_ref(x, b)),
        rtol=1e-3,
        atol=1e-4,
    )
