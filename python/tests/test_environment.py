"""Environment gate: reports the compile-pipeline suite as skipped when
its optional dependencies are absent (see conftest.py)."""
import importlib.util

import pytest


def test_compile_pipeline_deps_importable():
    for mod in ("jax", "hypothesis"):
        if importlib.util.find_spec(mod) is None:
            pytest.skip(f"{mod} not installed; compile-pipeline suite skipped")
    import jax  # noqa: F401
    import hypothesis  # noqa: F401
