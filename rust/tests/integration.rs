//! Cross-module integration tests: scenario → trackers → metrics, the
//! coordinator service under streams, and Laplacian-tracking paths.

use grest::eval::angle::mean_angle;
use grest::graph::datasets;
use grest::graph::generators;
use grest::graph::scenario::scenario1_from_static;
use grest::linalg::rng::Rng;
use grest::tracking::traits::apply_delta;
use grest::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};

#[test]
fn dataset_scenario_tracking_pipeline() {
    // registry dataset → scenario → track → accuracy within sane bounds
    let spec = {
        let mut s = datasets::by_name("CM-Collab").unwrap();
        s.nodes = 400;
        s.edges = 1600;
        s
    };
    let mut rng = Rng::new(1);
    let sc = datasets::scenario_for(&spec, Some(5), &mut rng);
    let k = 16;
    let init = init_eigenpairs(&sc.initial, k, 2);
    let mut tracker = GRest::new(init, SubspaceMode::Full);
    for (t, step) in sc.steps.iter().enumerate() {
        tracker.update(&step.delta).unwrap();
        let reference = init_eigenpairs(&step.adjacency, k, 50 + t as u64);
        let psi = mean_angle(tracker.current(), &reference, 3);
        assert!(psi < 0.6, "step {t}: psi {psi}");
    }
}

#[test]
fn accuracy_ordering_matches_paper() {
    // G-REST3 ≤ G-REST2 ≤ TRIP in mean ψ on an expansion-heavy scenario
    // (averaged over seeds to avoid single-draw flukes)
    let mut sums = [0.0f64; 3];
    for seed in 0..3u64 {
        let mut rng = Rng::new(100 + seed);
        let w = generators::power_law_weights(300, 2.3, 1200);
        let g = generators::chung_lu(&w, &mut rng);
        let sc = scenario1_from_static("t", &g, 4);
        let k = 12;
        let reference = grest::eval::harness::reference_run(&sc, k, 5 + seed);
        let roster =
            grest::eval::harness::paper_trackers(false, 8, grest::linalg::threads::Threads::AUTO);
        let results = grest::eval::harness::run_trackers(&sc, &reference, k, 4, &roster, 5 + seed)
            .unwrap();
        let get = |n: &str| {
            results
                .iter()
                .find(|r| r.name == n)
                .unwrap()
                .grand_mean_angle(4)
        };
        sums[0] += get("TRIP");
        sums[1] += get("G-REST2");
        sums[2] += get("G-REST3");
    }
    assert!(sums[2] <= sums[1] + 1e-9, "G-REST3 {} vs G-REST2 {}", sums[2], sums[1]);
    assert!(sums[2] <= sums[0] + 1e-9, "G-REST3 {} vs TRIP {}", sums[2], sums[0]);
}

#[test]
fn randomized_stream_delta_consistency() {
    // property: for random event sequences, the builder's emitted deltas
    // always reconstruct the adjacency exactly (Â = Ā + Δ at every batch)
    use grest::graph::stream::{DeltaBuilder, GraphEvent};
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let mut builder = DeltaBuilder::new();
        let mut adjacency = grest::sparse::csr::Csr::empty(0, 0);
        for _batch in 0..6 {
            let n_ev = 1 + rng.below(12);
            for _ in 0..n_ev {
                let a = rng.below(30) as u64;
                let b = rng.below(40) as u64;
                if rng.flip(0.75) {
                    builder.push(GraphEvent::AddEdge(a, b));
                } else {
                    builder.push(GraphEvent::RemoveEdge(a, b));
                }
            }
            if let Some(delta) = builder.emit() {
                // incremental row-merge vs the COO-based padding oracle
                let adj = adjacency.apply_delta(&delta);
                let rebuilt = apply_delta(&adjacency, &delta);
                let mut diff = rebuilt.to_dense();
                diff.axpy(-1.0, &adj.to_dense());
                assert!(diff.max_abs() < 1e-12, "seed {seed}");
                // and vs the from-scratch graph rebuild (exact equality)
                let want = builder.graph().adjacency();
                assert_eq!(adj.indptr, want.indptr, "seed {seed}");
                assert_eq!(adj.indices, want.indices, "seed {seed}");
                assert_eq!(adj.data, want.data, "seed {seed}");
                assert!(adj.is_symmetric(0.0));
                adjacency = adj;
            }
        }
    }
}

#[test]
fn event_sourced_delta_equals_from_diff_oracle_at_scale() {
    // tentpole property: mixed add/remove/expansion batches prepared in
    // O(|batch|) from the event list must equal the full
    // rebuild-and-diff oracle exactly, and the apply_delta chain must
    // track the from-scratch adjacency
    use grest::graph::stream::{DeltaBuilder, GraphEvent};
    use grest::sparse::delta::Delta;
    let mut rng = Rng::new(99);
    let g = generators::erdos_renyi(150, 0.04, &mut rng);
    let mut builder = DeltaBuilder::from_graph(g);
    let mut committed = builder.graph().adjacency();
    for batch in 0..12 {
        for _ in 0..(5 + rng.below(40)) {
            let a = rng.below(200) as u64; // ids ≥ 150 are expansions
            let b = rng.below(200) as u64;
            if rng.flip(0.65) {
                builder.push(GraphEvent::AddEdge(a, b));
            } else {
                builder.push(GraphEvent::RemoveEdge(a, b));
            }
        }
        let oracle = Delta::from_diff(&committed, &builder.graph().adjacency());
        match builder.prepare() {
            None => assert!(oracle.nnz() == 0 && oracle.s_new == 0, "batch {batch}"),
            Some(d) => {
                assert_eq!(d.n_old, oracle.n_old, "batch {batch}");
                assert_eq!(d.s_new, oracle.s_new, "batch {batch}");
                assert_eq!(d.full.indptr, oracle.full.indptr, "batch {batch}");
                assert_eq!(d.full.indices, oracle.full.indices, "batch {batch}");
                assert_eq!(d.full.data, oracle.full.data, "batch {batch}");
                committed = committed.apply_delta(&d);
                let want = builder.graph().adjacency();
                assert_eq!(committed.indptr, want.indptr, "batch {batch}");
                assert_eq!(committed.indices, want.indices, "batch {batch}");
                assert_eq!(committed.data, want.data, "batch {batch}");
            }
        }
        builder.commit();
    }
}

#[test]
fn randomized_tracker_invariants() {
    // property: over random update sequences, G-REST keeps orthonormal
    // eigenvectors and its Ritz values within the spectral bounds of Â
    use grest::sparse::coo::Coo;
    use grest::sparse::delta::Delta;
    for seed in 0..5u64 {
        let mut rng = Rng::new(40 + seed);
        let w = generators::power_law_weights(120, 2.4, 500);
        let g = generators::chung_lu(&w, &mut rng);
        let mut a = g.adjacency();
        let k = 8;
        let init = init_eigenpairs(&a, k, seed);
        let mut tracker = GRest::new(init, SubspaceMode::Rsvd { l: 6, p: 4 });
        for step in 0..4 {
            let n = a.n_rows;
            let s = rng.below(4);
            let mut kb = Coo::new(n, n);
            for _ in 0..10 {
                let (u, v) = (rng.below(n), rng.below(n));
                if u != v && kb.entries.iter().all(|&(a0, b0, _)| (a0, b0) != (u, v)) {
                    let sign = if a.get(u, v) > 0.0 { -1.0 } else { 1.0 };
                    kb.push_sym(u, v, sign);
                }
            }
            let mut gb = Coo::new(n, s);
            for j in 0..s {
                gb.push(rng.below(n), j, 1.0);
            }
            let d = Delta::from_blocks(n, s, &kb, &gb, &Coo::new(s, s));
            tracker.update(&d).unwrap();
            a = apply_delta(&a, &d);
            // orthonormality
            let v = &tracker.current().vectors;
            let gm = v.t_matmul(v);
            let mut eye = grest::Mat::eye(k);
            eye.axpy(-1.0, &gm);
            assert!(eye.max_abs() < 1e-7, "seed {seed} step {step}");
            // Ritz values within ‖Â‖₁ bound
            let bound = (0..a.n_rows)
                .map(|i| a.row(i).1.iter().map(|x| x.abs()).sum::<f64>())
                .fold(0.0f64, f64::max)
                + 1e-9;
            for &th in &tracker.current().values {
                assert!(th.abs() <= bound, "Ritz {th} beyond bound {bound}");
            }
        }
    }
}

#[test]
fn laplacian_clustering_end_to_end() {
    let mut rng = Rng::new(7);
    let sc = grest::graph::scenario::sbm_expansion(300, 3, 0.1, 0.005, 260, 10, 4, &mut rng);
    let (t0, steps) = grest::tracking::laplacian::shifted_scenario(
        &sc,
        grest::tracking::laplacian::Shift::Normalized,
    );
    let init = init_eigenpairs(&t0, 3, 8);
    let mut tracker = GRest::new(init, SubspaceMode::Full);
    let labels = sc.labels_per_step.as_ref().unwrap();
    for (t, (delta, _)) in steps.iter().enumerate() {
        tracker.update(delta).unwrap();
        let est =
            grest::tasks::clustering::spectral_cluster(&tracker.current().vectors, 3, 1);
        let ari = grest::tasks::ari::adjusted_rand_index(&est, &labels[t + 1]);
        assert!(ari > 0.8, "step {t}: ARI {ari}");
    }
}

#[test]
fn coordinator_survives_burst_and_preserves_order() {
    use grest::coordinator::{BatchPolicy, ServiceConfig, TrackingService};
    use grest::graph::stream::GraphEvent;
    let mut rng = Rng::new(3);
    let g = generators::erdos_renyi(100, 0.08, &mut rng);
    let svc = TrackingService::spawn(ServiceConfig {
        initial: g,
        k: 6,
        policy: BatchPolicy::ByCount(16),
        seed: 2,
        tracker: grest::tracking::TrackerSpec::parse("grest3").unwrap(),
        threads: grest::linalg::threads::Threads::SINGLE,
        serve_precision: grest::linalg::ServePrecision::F64,
        durability: None,
    })
    .unwrap();
    // burst: add then remove the same edge repeatedly; final state must
    // reflect the LAST event (ordering preserved)
    for _ in 0..7 {
        svc.handle
            .ingest(vec![GraphEvent::AddEdge(0, 1), GraphEvent::RemoveEdge(0, 1)])
            .unwrap();
    }
    svc.handle.ingest(vec![GraphEvent::AddEdge(0, 99)]).unwrap();
    svc.handle.flush().unwrap();
    let snap = svc.handle.snapshot();
    assert!(snap.version >= 1);
    assert_eq!(snap.n_nodes, 100);
    svc.join();
}

#[test]
fn coordinator_isolated_new_nodes_then_removal_heavy_batches() {
    // Satellite coverage: (a) batches that only add *isolated* new nodes
    // (s_new > 0, nnz == 0 — an edge to an unseen id added then removed
    // within the batch interns the id but nets out the edge; self-loop
    // events are dropped before interning and must NOT inflate s_new)
    // and (b) RemoveEdge-heavy batches, streamed through the service;
    // snapshot n_nodes/version must track the builder's committed state
    // at every flush.
    use grest::coordinator::{BatchPolicy, ServiceConfig, TrackingService};
    use grest::graph::stream::GraphEvent;
    let mut rng = Rng::new(13);
    let g = generators::erdos_renyi(50, 0.15, &mut rng);
    let initial_edges: Vec<(usize, usize)> = g.edges();
    let svc = TrackingService::spawn(ServiceConfig {
        initial: g,
        k: 5,
        policy: BatchPolicy::ByCount(1_000_000),
        seed: 4,
        tracker: grest::tracking::TrackerSpec::parse("grest3").unwrap(),
        threads: grest::linalg::threads::Threads::SINGLE,
        serve_precision: grest::linalg::ServePrecision::F64,
        durability: None,
    })
    .unwrap();
    let h = &svc.handle;

    // (a) isolated-new-node batch: add-then-remove edges to unseen ids
    // (id interned, edge netted out) plus a self loop that must vanish
    h.ingest(vec![
        GraphEvent::AddEdge(900, 0),
        GraphEvent::RemoveEdge(900, 0),
        GraphEvent::AddEdge(901, 1),
        GraphEvent::RemoveEdge(901, 1),
        GraphEvent::AddEdge(902, 2),
        GraphEvent::RemoveEdge(902, 2),
        GraphEvent::AddEdge(903, 903), // self loop: dropped, never interned
    ])
    .unwrap();
    let v = h.flush().unwrap();
    assert_eq!(v, 1, "pure-expansion batch must publish");
    let snap = h.snapshot();
    assert_eq!(snap.n_nodes, 53, "three isolated nodes; self-loop id not interned");
    assert_eq!(snap.pairs.k(), 5);
    assert_eq!(snap.pairs.n(), 53, "eigenvectors padded to the new space");

    // (b) RemoveEdge-heavy batch: delete a third of the original edges
    let removals: Vec<GraphEvent> = initial_edges
        .iter()
        .take(initial_edges.len() / 3)
        .map(|&(u, v)| GraphEvent::RemoveEdge(u as u64, v as u64))
        .collect();
    assert!(removals.len() > 10, "need a genuinely removal-heavy batch");
    h.ingest(removals).unwrap();
    let v = h.flush().unwrap();
    assert_eq!(v, 2);
    let snap = h.snapshot();
    assert_eq!(snap.n_nodes, 53, "removals never change the node count");

    // (c) a no-op batch (remove unknown edges) must not bump the version
    h.ingest(vec![GraphEvent::RemoveEdge(7000, 7001)]).unwrap();
    let v = h.flush().unwrap();
    assert_eq!(v, 2, "no-op batch must not publish a new version");

    let m = h.metrics();
    assert_eq!(m.batches_applied.get(), 2);
    assert_eq!(m.update_failures.get(), 0);
    assert_eq!(m.nodes_added.get(), 3);
    svc.join();
}

#[test]
fn read_storm_soak_queries_never_touch_the_worker() {
    // Satellite coverage for the lock-free read path: reader threads
    // hammering cached queries mid-ingest must not slow flushes (the
    // `Command` enum no longer even has query variants, so a query
    // *cannot* reach the worker — the latency comparison below guards
    // the weaker property that reader CPU load doesn't serialize the
    // write path), snapshot versions must stay monotone per reader, and
    // queries pinned to one version must agree bitwise across threads.
    use grest::coordinator::{BatchPolicy, ServiceConfig, TrackingService};
    use grest::graph::stream::GraphEvent;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let mut rng = Rng::new(17);
    let g = generators::erdos_renyi(120, 0.06, &mut rng);
    let svc = TrackingService::spawn(ServiceConfig {
        initial: g,
        k: 5,
        policy: BatchPolicy::ByCount(1_000_000),
        seed: 9,
        tracker: grest::tracking::TrackerSpec::parse("grest3").unwrap(),
        threads: grest::linalg::threads::Threads::SINGLE,
        serve_precision: grest::linalg::ServePrecision::F64,
        durability: None,
    })
    .unwrap();
    let h = svc.handle.clone();

    // distinct edges per batch index so both phases do real tracker work
    let run_phase = |offset: usize, batches: usize| -> Vec<std::time::Duration> {
        let mut lat = Vec::with_capacity(batches);
        for b in offset..offset + batches {
            let ev: Vec<GraphEvent> = (0..10)
                .map(|i| {
                    let a = ((b * 10 + i) * 7 % 140) as u64; // ids 120.. arrive over time
                    let c = ((b * 10 + i) * 13 + 1) as u64 % 140;
                    GraphEvent::AddEdge(a, c)
                })
                .collect();
            h.ingest(ev).unwrap();
            let t0 = std::time::Instant::now();
            h.flush().unwrap();
            lat.push(t0.elapsed());
        }
        lat.sort();
        lat
    };

    // phase A: quiet ingest, no readers
    let quiet = run_phase(0, 10);

    // phase B: 8 readers hammering derived queries + snapshot polls
    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = vec![];
    for r in 0..8u64 {
        let h = h.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || {
            let mut last = 0u64;
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let snap = h.snapshot();
                assert!(snap.version >= last, "reader saw version go backwards");
                last = snap.version;
                let _ = h.central_nodes(5 + (r as usize % 3));
                let _ = h.clusters(2 + (r as usize % 2));
                let _ = h.similar_to(r % 120, 5);
                reads += 3;
            }
            reads
        }));
    }
    let storm = run_phase(10, 10);
    stop.store(true, Ordering::Relaxed);
    let total_reads: u64 = readers.into_iter().map(|t| t.join().unwrap()).sum();
    assert!(total_reads > 0);

    // generous bound: structurally queries can't block the worker, so a
    // storm may only cost scheduler noise, never queue-serialization
    // (pre-refactor, every reader query sat in the worker's mpsc queue
    // ahead of the flush and this ratio blew up with reader count)
    let median = |l: &[std::time::Duration]| l[l.len() / 2];
    assert!(
        median(&storm) < 30 * median(&quiet) + std::time::Duration::from_millis(100),
        "flush under read storm {:?} vs quiet {:?}",
        median(&storm),
        median(&quiet)
    );

    // pinned-version cache coherence: many threads querying one
    // snapshot get identical results (and the memo cache served them)
    let snap = h.snapshot();
    let mut pinned = vec![];
    for _ in 0..6 {
        let h = h.clone();
        let snap = snap.clone();
        pinned.push(std::thread::spawn(move || {
            let central = h.query_engine().central_nodes(&snap, 10);
            let clusters = h.query_engine().clusters(&snap, 3);
            ((*central).clone(), (*clusters).clone())
        }));
    }
    let results: Vec<_> = pinned.into_iter().map(|t| t.join().unwrap()).collect();
    for r in &results[1..] {
        assert_eq!(r.0, results[0].0, "pinned central-nodes must agree across threads");
        assert_eq!(r.1, results[0].1, "pinned clusters must agree across threads");
    }
    assert_eq!(results[0].1.version, snap.version);

    let m = h.metrics();
    assert!(
        m.queries_cached.get() > 0,
        "read storm must hit the memo cache"
    );
    assert!(m.queries_computed.get() > 0);
    svc.join();
}

#[test]
fn xla_and_native_agree_on_dataset_run() {
    if !cfg!(feature = "xla") {
        eprintln!("built without the `xla` feature (stub backend); skipping");
        return;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let manifest = grest::runtime::ArtifactManifest::load(&dir).unwrap();
    let mut rng = Rng::new(11);
    let w = generators::power_law_weights(220, 2.3, 900);
    let g = generators::chung_lu(&w, &mut rng);
    let sc = scenario1_from_static("x", &g, 8); // small S per step so the t256 tier (m=32) fits
    let k = 16;
    let max_s = sc.steps.iter().map(|s| s.delta.s_new).max().unwrap();
    let phases =
        grest::runtime::XlaPhases::for_problem(manifest, sc.max_nodes(), k, k + max_s).unwrap();
    let init = init_eigenpairs(&sc.initial, k, 3);
    let mut xla = GRest::with_phases(init.clone(), SubspaceMode::Full, phases, 5);
    let mut native = GRest::new(init, SubspaceMode::Full);
    for step in &sc.steps {
        xla.update(&step.delta).unwrap();
        native.update(&step.delta).unwrap();
    }
    for j in 0..k {
        assert!(
            (xla.current().values[j] - native.current().values[j]).abs() < 2e-3,
            "λ{j} drifted between backends"
        );
    }
}
