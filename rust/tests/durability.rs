//! Durability-tier integration tests: the crash/fault-injection harness.
//!
//! The contract under test (ISSUE 10): a tenant killed at **any** batch
//! boundary — or at **any** storage syscall boundary, including torn
//! writes and silent bit flips — recovers to a state that is *bitwise
//! identical* to some prefix of the uninterrupted run, and re-ingesting
//! the remaining events converges bitwise to the uninterrupted final
//! state.  Corruption is detected loudly (`DurabilityError::Corrupt`),
//! never silently replayed.
//!
//! The recovery recipe in `spawn_tenant` deliberately mirrors the
//! private `build_state` flow in `coordinator/service.rs` (load →
//! restore checkpoint → replay WAL tail → attach durability), driven
//! here over `Memory`/`FaultyBackend` storage so every fault point is
//! reachable without real I/O.

use grest::coordinator::durability::backend::{
    FaultHandle, FaultMode, FaultyBackend, Memory, StorageBackend,
};
use grest::coordinator::durability::recover::{self, Recovered};
use grest::coordinator::durability::wal::{decode_events, encode_events};
use grest::coordinator::durability::{DurabilityConfig, DurabilityError, TenantDurability};
use grest::coordinator::metrics::Metrics;
use grest::coordinator::snapshot::{EmbeddingSnapshot, PublishStamp, SnapshotStore};
use grest::coordinator::tenant::{TenantBudget, TenantCmd, TenantState};
use grest::coordinator::{BatchPolicy, ConfigError, ServiceConfig, TrackingService};
use grest::graph::graph::Graph;
use grest::graph::stream::{DeltaBuilder, GraphEvent, IdMap};
use grest::linalg::f32mat::ServePrecision;
use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::tracking::spec::TrackerSpec;
use grest::tracking::traits::init_eigenpairs;
use std::sync::Arc;

const SEED: u64 = 5;
const K: usize = 3;
const CKPT_EVERY: usize = 3;

fn seed_graph() -> Graph {
    let mut rng = Rng::new(SEED);
    grest::graph::generators::erdos_renyi(30, 0.1, &mut rng)
}

/// Deterministic mixed event stream: every batch interns at least one
/// brand-new external id (so every flush advances the version by
/// exactly 1 — version == batches applied), plus random adds/removes
/// and a self-loop (logged but dropped pre-intern, exercising the
/// replay-the-raw-stream counting contract).
fn batches() -> Vec<Vec<GraphEvent>> {
    let mut rng = Rng::new(77);
    (0..8u64)
        .map(|b| {
            let mut evs = vec![GraphEvent::AddEdge(rng.below(30) as u64, 1000 + b)];
            for _ in 0..(1 + rng.below(4)) {
                let u = rng.below(40) as u64;
                let v = rng.below(40) as u64;
                evs.push(if rng.flip(0.75) {
                    GraphEvent::AddEdge(u, v)
                } else {
                    GraphEvent::RemoveEdge(u, v)
                });
            }
            evs.push(GraphEvent::AddEdge(b + 50, b + 50)); // self-loop
            evs
        })
        .collect()
}

/// Bitwise view of the latest published snapshot: version, node count,
/// eigenvalue bits, eigenvector bits, external id order.
type Fingerprint = (u64, usize, Vec<u64>, Vec<u64>, Vec<u64>);

fn snap_fingerprint(s: &EmbeddingSnapshot) -> Fingerprint {
    (
        s.version,
        s.n_nodes,
        s.pairs.values.iter().map(|v| v.to_bits()).collect(),
        s.pairs.vectors.as_slice().iter().map(|v| v.to_bits()).collect(),
        s.ids.externals().to_vec(),
    )
}

fn fingerprint(store: &SnapshotStore) -> Fingerprint {
    snap_fingerprint(&store.latest())
}

/// Build (or recover) a tenant over the given storage, mirroring the
/// service spawn path: load checkpoint + WAL, restore, replay the tail
/// through the normal flush machinery, then attach the WAL for live
/// logging.
fn spawn_tenant_with_policy(
    wal: Box<dyn StorageBackend>,
    ckpt: Box<dyn StorageBackend>,
    policy: BatchPolicy,
) -> Result<(TenantState, SnapshotStore, Arc<Metrics>), DurabilityError> {
    let g = seed_graph();
    let a0 = g.adjacency();
    let init = init_eigenpairs(&a0, K, SEED);
    let mut tracker =
        TrackerSpec::default().build_seeded_send(&a0, &init, SEED).expect("tracker builds");
    let store = SnapshotStore::new(EmbeddingSnapshot {
        version: 0,
        n_nodes: a0.n_rows,
        pairs: init.clone(),
        ids: Arc::new(IdMap::identity(a0.n_rows)),
        published_at: PublishStamp::now(),
    });
    let metrics = Metrics::new();
    let Recovered { checkpoint, tail, truncated_bytes, wal, ckpt_backend } =
        recover::load(wal, ckpt)?;
    metrics.wal_truncated_bytes.add(truncated_bytes);
    let recovered_something = checkpoint.is_some() || !tail.is_empty();
    let mut state = match checkpoint {
        Some(c) => {
            tracker
                .restore_state(c.tracker)
                .map_err(|e| DurabilityError::Unsupported(e.to_string()))?;
            let builder = DeltaBuilder::from_committed(&c.adjacency, c.ids.clone());
            let mut st = TenantState::new(
                tracker,
                builder,
                c.adjacency.clone(),
                policy,
                store.clone(),
                metrics.clone(),
                TenantBudget::default(),
            );
            st.restore_version(c.version);
            if c.version > 0 {
                store.publish(EmbeddingSnapshot {
                    version: c.version,
                    n_nodes: c.adjacency.n_rows,
                    pairs: c.pairs,
                    ids: Arc::new(IdMap::from_externals(c.ids)),
                    published_at: PublishStamp::restored(c.wall_us),
                });
            }
            st
        }
        None => TenantState::new(
            tracker,
            DeltaBuilder::from_graph(g),
            a0,
            policy,
            store.clone(),
            metrics.clone(),
            TenantBudget::default(),
        ),
    };
    state.replay(&tail)?;
    if recovered_something {
        metrics.recoveries.incr();
    }
    state.attach_durability(TenantDurability::new(wal, ckpt_backend, CKPT_EVERY));
    Ok((state, store, metrics))
}

fn spawn_tenant(
    wal: Box<dyn StorageBackend>,
    ckpt: Box<dyn StorageBackend>,
) -> Result<(TenantState, SnapshotStore, Arc<Metrics>), DurabilityError> {
    // ByCount(1): one Events command closes one batch — one flush, one
    // version — so "crash after batch b" is exactly "apply b commands"
    spawn_tenant_with_policy(wal, ckpt, BatchPolicy::ByCount(1))
}

fn feed(state: &mut TenantState, batches: &[Vec<GraphEvent>]) {
    for b in batches {
        let _ = state.apply(TenantCmd::Events(b.clone()));
    }
}

// ---------------------------------------------------------------------
// crash at every batch boundary

#[test]
fn crash_at_every_batch_boundary_recovers_bitwise_identical() {
    let bs = batches();
    let (mut reference, ref_store, _) =
        spawn_tenant(Box::new(Memory::new()), Box::new(Memory::new())).unwrap();
    feed(&mut reference, &bs);
    let want = fingerprint(&ref_store);
    assert_eq!(want.0, bs.len() as u64, "every batch advances the version");

    for b in 0..=bs.len() {
        let wal_mem = Memory::new();
        let ckpt_mem = Memory::new();
        {
            let (mut live, _, _) =
                spawn_tenant(Box::new(wal_mem.clone()), Box::new(ckpt_mem.clone())).unwrap();
            feed(&mut live, &bs[..b]);
        } // drop without ceremony: `TenantDurability` does no Drop I/O
        wal_mem.crash(); // power cut: unsynced page-cache bytes are gone
        let (mut rec, rec_store, metrics) =
            spawn_tenant(Box::new(wal_mem.clone()), Box::new(ckpt_mem.clone()))
                .unwrap_or_else(|e| panic!("recovery after batch {b} failed: {e}"));
        assert_eq!(rec.version(), b as u64, "recovered version after batch {b}");
        assert_eq!(metrics.recoveries.get(), u64::from(b > 0));
        feed(&mut rec, &bs[b..]);
        assert_eq!(fingerprint(&rec_store), want, "crash after batch {b} diverged");
    }
}

#[test]
fn unsynced_events_die_with_the_process_and_reingest_converges() {
    // Events ingested but never flushed sit in the WAL's in-process
    // buffer — a crash loses them, exactly like a real page cache.  The
    // producer re-sends (at-least-once ingest) and the result converges.
    let bs = batches();
    let policy = BatchPolicy::ByCount(1_000_000);
    let (mut reference, ref_store, _) = spawn_tenant_with_policy(
        Box::new(Memory::new()),
        Box::new(Memory::new()),
        policy,
    )
    .unwrap();
    for b in &bs {
        let _ = reference.apply(TenantCmd::Events(b.clone()));
        reference.flush();
    }
    let want = fingerprint(&ref_store);

    let wal_mem = Memory::new();
    let ckpt_mem = Memory::new();
    {
        let (mut live, _, _) = spawn_tenant_with_policy(
            Box::new(wal_mem.clone()),
            Box::new(ckpt_mem.clone()),
            policy,
        )
        .unwrap();
        for b in &bs[..4] {
            let _ = live.apply(TenantCmd::Events(b.clone()));
            live.flush();
        }
        let _ = live.apply(TenantCmd::Events(bs[4].clone())); // never flushed
        assert_eq!(live.version(), 4);
    }
    wal_mem.crash();
    let (mut rec, rec_store, _) = spawn_tenant_with_policy(
        Box::new(wal_mem.clone()),
        Box::new(ckpt_mem.clone()),
        policy,
    )
    .unwrap();
    assert_eq!(rec.version(), 4, "the unflushed batch is gone, prefix intact");
    for b in &bs[4..] {
        let _ = rec.apply(TenantCmd::Events(b.clone()));
        rec.flush();
    }
    assert_eq!(fingerprint(&rec_store), want);
}

// ---------------------------------------------------------------------
// fault matrix: kill / torn write at every WAL syscall boundary

/// Run the reference stream once over a fault-counted WAL, returning
/// the per-version fingerprints, the final fingerprint, and the number
/// of WAL syscalls (the fault-point space).
fn wal_reference() -> (Vec<Fingerprint>, Fingerprint, usize) {
    let bs = batches();
    let handle = FaultHandle::new();
    let (mut reference, ref_store, _) = spawn_tenant(
        Box::new(FaultyBackend::new(Memory::new(), handle.clone())),
        Box::new(Memory::new()),
    )
    .unwrap();
    let mut fps = vec![fingerprint(&ref_store)];
    for b in &bs {
        let _ = reference.apply(TenantCmd::Events(b.clone()));
        fps.push(fingerprint(&ref_store));
    }
    let last = fps.last().cloned().expect("nonempty");
    (fps, last, handle.ops())
}

/// After recovery: close any replayed-but-uncommitted batch, re-feed
/// the batches the durable state had not absorbed, and check bitwise
/// convergence with the uninterrupted final state.
fn assert_converges(
    mut rec: TenantState,
    rec_store: &SnapshotStore,
    bs: &[Vec<GraphEvent>],
    want_final: &Fingerprint,
    label: &str,
) {
    rec.flush(); // applies a fully-replayed pending batch, if any
    let v = rec.version() as usize;
    assert!(v <= bs.len(), "{label}: recovered past the stream end");
    feed(&mut rec, &bs[v..]);
    assert_eq!(&fingerprint(rec_store), want_final, "{label}: diverged after re-ingest");
}

#[test]
fn kill_and_torn_faults_at_every_wal_syscall_recover_prefix_exact() {
    let bs = batches();
    let (ref_fps, want_final, wal_ops) = wal_reference();
    assert!(wal_ops > 12, "fault-point space unexpectedly small: {wal_ops}");

    for fail_at in 0..wal_ops {
        for mode in [FaultMode::Kill, FaultMode::TornWrite] {
            let label = format!("{mode:?} at wal syscall {fail_at}");
            let wal_mem = Memory::new();
            let ckpt_mem = Memory::new();
            let handle = FaultHandle::new();
            handle.arm(fail_at, mode);
            {
                // the "process": runs until the fault kills its storage,
                // then keeps limping (flushes abort, counted) — or dies
                // at spawn if the fault hits the recovery read
                let spawned = spawn_tenant(
                    Box::new(FaultyBackend::new(wal_mem.clone(), handle.clone())),
                    Box::new(ckpt_mem.clone()),
                );
                if let Ok((mut live, _, _)) = spawned {
                    feed(&mut live, &bs);
                }
            }
            wal_mem.crash();
            let (rec, rec_store, _) =
                spawn_tenant(Box::new(wal_mem.clone()), Box::new(ckpt_mem.clone()))
                    .unwrap_or_else(|e| panic!("{label}: recovery failed: {e}"));
            let v = rec.version() as usize;
            assert_eq!(
                fingerprint(&rec_store),
                ref_fps[v],
                "{label}: recovered state is not the reference prefix at version {v}"
            );
            assert_converges(rec, &rec_store, &bs, &want_final, &label);
        }
    }
}

#[test]
fn bit_flips_are_detected_never_silently_replayed() {
    let bs = batches();
    let (ref_fps, want_final, wal_ops) = wal_reference();

    for fail_at in 0..wal_ops {
        let label = format!("BitFlip at wal syscall {fail_at}");
        let wal_mem = Memory::new();
        let ckpt_mem = Memory::new();
        let handle = FaultHandle::new();
        handle.arm(fail_at, FaultMode::BitFlip);
        {
            let spawned = spawn_tenant(
                Box::new(FaultyBackend::new(wal_mem.clone(), handle.clone())),
                Box::new(ckpt_mem.clone()),
            );
            if let Ok((mut live, _, _)) = spawned {
                feed(&mut live, &bs);
            }
        }
        wal_mem.crash();
        match spawn_tenant(Box::new(wal_mem.clone()), Box::new(ckpt_mem.clone())) {
            // interior damage: refusing to replay is the contract
            Err(DurabilityError::Corrupt { .. }) => {}
            Err(e) => panic!("{label}: unexpected recovery error: {e}"),
            Ok((rec, rec_store, metrics)) => {
                // tail damage: recovery truncates, REPORTS the loss, and
                // resumes prefix-exact — any lost progress must show up
                // in wal_truncated_bytes, never vanish silently
                let v = rec.version() as usize;
                assert_eq!(
                    fingerprint(&rec_store),
                    ref_fps[v],
                    "{label}: silent divergence at version {v}"
                );
                if v < bs.len() {
                    assert!(
                        metrics.wal_truncated_bytes.get() > 0,
                        "{label}: lost progress (v={v}) without reporting truncation"
                    );
                }
                assert_converges(rec, &rec_store, &bs, &want_final, &label);
            }
        }
    }
}

#[test]
fn faults_in_checkpoint_storage_never_lose_state() {
    let bs = batches();
    let (_, want_final, _) = wal_reference();
    // count checkpoint-backend syscalls on a clean run
    let ckpt_handle = FaultHandle::new();
    {
        let (mut clean, _, _) = spawn_tenant(
            Box::new(Memory::new()),
            Box::new(FaultyBackend::new(Memory::new(), ckpt_handle.clone())),
        )
        .unwrap();
        feed(&mut clean, &bs);
    }
    let ckpt_ops = ckpt_handle.ops();
    assert!(ckpt_ops >= 2, "expected a load read plus checkpoint stores, got {ckpt_ops}");

    for fail_at in 0..ckpt_ops {
        for mode in [FaultMode::Kill, FaultMode::TornWrite, FaultMode::BitFlip] {
            let label = format!("{mode:?} at ckpt syscall {fail_at}");
            let wal_mem = Memory::new();
            let ckpt_mem = Memory::new();
            let handle = FaultHandle::new();
            handle.arm(fail_at, mode);
            {
                let spawned = spawn_tenant(
                    Box::new(wal_mem.clone()),
                    Box::new(FaultyBackend::new(ckpt_mem.clone(), handle.clone())),
                );
                if let Ok((mut live, _, _)) = spawned {
                    feed(&mut live, &bs);
                }
            }
            wal_mem.crash();
            match spawn_tenant(Box::new(wal_mem.clone()), Box::new(ckpt_mem.clone())) {
                Ok((rec, rec_store, _)) => {
                    assert_converges(rec, &rec_store, &bs, &want_final, &label);
                }
                // a silently flipped checkpoint image (with the WAL
                // prefix it covered already truncated) must refuse to
                // load — loud corruption beats silent divergence
                Err(DurabilityError::Corrupt { .. }) if mode == FaultMode::BitFlip => {}
                Err(e) => panic!("{label}: recovery failed: {e}"),
            }
        }
    }
}

// ---------------------------------------------------------------------
// event-frame codec round-trip (satellite: property test)

#[test]
fn event_frame_roundtrip_is_identity() {
    // empty batch
    assert_eq!(decode_events(&encode_events(&[])).unwrap(), Vec::<GraphEvent>::new());
    // extremes: max/zero ids, self-loops
    let edge_cases = [
        GraphEvent::AddEdge(u64::MAX, 0),
        GraphEvent::RemoveEdge(u64::MAX, u64::MAX),
        GraphEvent::AddEdge(7, 7),
        GraphEvent::RemoveEdge(0, 0),
    ];
    assert_eq!(decode_events(&encode_events(&edge_cases)).unwrap(), edge_cases);
    // randomized streams over both event kinds and the full id width
    let mut rng = Rng::new(123);
    for _ in 0..200 {
        let n = rng.below(40);
        let events: Vec<GraphEvent> = (0..n)
            .map(|_| {
                let u = ((rng.below(1 << 30) as u64) << 34) ^ rng.below(1 << 30) as u64;
                let v = ((rng.below(1 << 30) as u64) << 34) ^ rng.below(1 << 30) as u64;
                if rng.flip(0.5) {
                    GraphEvent::AddEdge(u, v)
                } else {
                    GraphEvent::RemoveEdge(u, v)
                }
            })
            .collect();
        assert_eq!(decode_events(&encode_events(&events)).unwrap(), events);
    }
}

// ---------------------------------------------------------------------
// config validation (satellite)

fn service_config(durability: Option<DurabilityConfig>) -> ServiceConfig {
    ServiceConfig {
        initial: seed_graph(),
        k: K,
        policy: BatchPolicy::ByCount(1_000_000),
        seed: SEED,
        tracker: TrackerSpec::default(),
        threads: Threads::SINGLE,
        serve_precision: ServePrecision::F64,
        durability,
    }
}

#[test]
fn config_validation_catches_bad_durability() {
    // no durability: nothing to validate
    service_config(None).validate().unwrap();

    // checkpoint_every == 0 is meaningless
    let mut d = DurabilityConfig::new(std::env::temp_dir().join("grest-durability-unused"));
    d.checkpoint_every = 0;
    match service_config(Some(d)).validate() {
        Err(ConfigError::ZeroCheckpointInterval) => {}
        other => panic!("zero interval must be rejected, got {other:?}"),
    }

    // a durability dir nested under a regular file can never be created
    let file = std::env::temp_dir().join(format!("grest-durability-flat-{}", std::process::id()));
    std::fs::write(&file, b"not a directory").unwrap();
    let d = DurabilityConfig::new(file.join("sub"));
    match service_config(Some(d.clone())).validate() {
        Err(ConfigError::DirUnwritable { path, .. }) => assert_eq!(path, file.join("sub")),
        other => panic!("unwritable dir must be rejected, got {other:?}"),
    }
    // and the spawn path surfaces the same error instead of limping on
    let err = match TrackingService::spawn(service_config(Some(d))) {
        Err(e) => e,
        Ok(_) => panic!("spawn over an unwritable durability dir must fail"),
    };
    assert!(err.to_string().contains("not writable"), "{err}");
    let _ = std::fs::remove_file(file);
}

// ---------------------------------------------------------------------
// end-to-end: the real service over real files

#[test]
fn service_recovers_from_disk_across_respawn() {
    let dir = std::env::temp_dir().join(format!("grest-durability-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut d = DurabilityConfig::new(&dir);
    d.checkpoint_every = 2;
    let bs = batches();

    // run 1: half the stream, one flush per batch, then an abrupt stop
    let fp_mid;
    {
        let svc = TrackingService::spawn(service_config(Some(d.clone()))).unwrap();
        let h = &svc.handle;
        for b in &bs[..4] {
            h.ingest(b.clone()).unwrap();
            h.flush().unwrap();
        }
        assert_eq!(h.snapshot().version, 4);
        fp_mid = snap_fingerprint(&h.snapshot());
        let m = h.metrics();
        assert_eq!(m.wal_appends.get(), 4);
        assert!(m.wal_bytes.get() > 0);
        assert!(m.checkpoints_written.get() >= 1, "checkpoint_every=2 over 4 flushes");
        assert_eq!(m.wal_failures.get(), 0);
        svc.join();
    }

    // run 2: respawn on the same dir — resumes bitwise, versions continue
    let fp_final;
    {
        let svc = TrackingService::spawn(service_config(Some(d.clone()))).unwrap();
        let h = &svc.handle;
        assert_eq!(h.metrics().recoveries.get(), 1, "respawn must count a recovery");
        assert_eq!(h.snapshot().version, 4);
        assert_eq!(
            snap_fingerprint(&h.snapshot()),
            fp_mid,
            "recovered snapshot must be bitwise the pre-stop one"
        );
        for b in &bs[4..] {
            h.ingest(b.clone()).unwrap();
            h.flush().unwrap();
        }
        assert_eq!(h.snapshot().version, bs.len() as u64);
        fp_final = snap_fingerprint(&h.snapshot());
        svc.join();
    }

    // the crash-interrupted run equals an uninterrupted in-memory run
    {
        let svc = TrackingService::spawn(service_config(None)).unwrap();
        let h = &svc.handle;
        for b in &bs {
            h.ingest(b.clone()).unwrap();
            h.flush().unwrap();
        }
        assert_eq!(
            snap_fingerprint(&h.snapshot()),
            fp_final,
            "recovered run must match the uninterrupted run bitwise"
        );
        svc.join();
    }
    let _ = std::fs::remove_dir_all(dir);
}
