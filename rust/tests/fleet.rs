//! Fleet integration tests: the multi-tenant coordinator on a shared
//! worker pool.
//!
//! The acceptance contract of the pool refactor: 16 native-backend
//! tenants multiplexed onto 4 workers must behave exactly like 16
//! dedicated threads — every tenant publishes monotone snapshot
//! versions and bitwise-identical results to a pinned run of the same
//! seeds/specs.  Plus the isolation soak: a tenant whose tracker fails
//! every batch must not disturb its neighbours.

use grest::coordinator::{
    BatchPolicy, Fleet, FleetConfig, ServiceConfig, ServiceHandle, TenantBudget, TenantId,
    TrackingService,
};
use grest::graph::stream::GraphEvent;
use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::linalg::ServePrecision;
use grest::sparse::delta::Delta;
use grest::tracking::traits::{EigTracker, EigenPairs};
use grest::tracking::TrackerSpec;

/// One registry spec per tenant, cycled — the pool must schedule
/// heterogeneous tenants, not just 16 copies of one tracker.
const SPECS: &[&str] =
    &["grest3", "grest2", "grest-rsvd:l=6,p=4", "trip", "iasc", "timers", "trip-basic"];

fn tenant_config(t: u64) -> ServiceConfig {
    let mut rng = Rng::new(100 + t);
    ServiceConfig {
        initial: grest::graph::generators::erdos_renyi(60, 0.15, &mut rng),
        k: 4,
        policy: BatchPolicy::ByCount(4),
        seed: 100 + t,
        tracker: TrackerSpec::parse(SPECS[t as usize % SPECS.len()]).unwrap(),
        threads: Threads::SINGLE,
        serve_precision: ServePrecision::F64,
        durability: None,
    }
}

/// Deterministic tenant-salted event stream (shared by the pooled and
/// pinned runs).
fn event(t: u64, i: u64) -> GraphEvent {
    let a = (i * 7919 + t * 13) % 60;
    if i % 9 == 8 {
        GraphEvent::RemoveEdge(a, (i * 104_729 + t) % 60)
    } else {
        GraphEvent::AddEdge(a, (i * 104_729 + t + 1) % 70)
    }
}

/// Ingest the per-tenant streams with interleaved flushes; returns, per
/// tenant, the flush-version sequence plus the final snapshot
/// (version, eigenvalues, eigenvector data) for bitwise comparison.
fn drive(handles: &[ServiceHandle]) -> Vec<(Vec<u64>, u64, Vec<f64>, Vec<f64>)> {
    let mut flush_versions: Vec<Vec<u64>> = vec![Vec::new(); handles.len()];
    for i in 0..48u64 {
        for (t, h) in handles.iter().enumerate() {
            h.ingest(vec![event(t as u64, i)]).unwrap();
        }
        if (i + 1) % 16 == 0 {
            for (t, h) in handles.iter().enumerate() {
                flush_versions[t].push(h.flush().unwrap());
            }
        }
    }
    handles
        .iter()
        .zip(flush_versions)
        .map(|(h, fv)| {
            let s = h.snapshot();
            (fv, s.version, s.pairs.values.clone(), s.pairs.vectors.as_slice().to_vec())
        })
        .collect()
}

/// The acceptance test of the worker-pool refactor: 16 native tenants
/// on 4 workers, versions monotone, results bitwise-identical to
/// thread-per-tenant.
#[test]
fn sixteen_tenants_on_four_workers_match_dedicated_threads_bitwise() {
    const TENANTS: u64 = 16;

    // pooled run: one Fleet, 4 shared workers
    let fleet = Fleet::new(FleetConfig { workers: 4 });
    assert_eq!(fleet.workers(), 4);
    for t in 0..TENANTS {
        fleet.spawn(TenantId(t), tenant_config(t)).unwrap();
    }
    let pooled: Vec<ServiceHandle> =
        (0..TENANTS).map(|t| fleet.get(TenantId(t)).unwrap()).collect();
    let pool_results = drive(&pooled);
    drop(pooled);
    fleet.join();

    // pinned run: same seeds/specs/streams, one dedicated thread each
    let pinned_svcs: Vec<TrackingService> =
        (0..TENANTS).map(|t| TrackingService::spawn_pinned(tenant_config(t)).unwrap()).collect();
    let pinned: Vec<ServiceHandle> = pinned_svcs.iter().map(|s| s.handle.clone()).collect();
    let pin_results = drive(&pinned);
    drop(pinned);
    for s in pinned_svcs {
        s.join();
    }

    for (t, (pool_r, pin_r)) in pool_results.iter().zip(&pin_results).enumerate() {
        // every tenant made progress and its flush versions are
        // strictly monotone, ending at the snapshot version
        let (flush_versions, version, values, vectors) = pool_r;
        assert!(*version >= 1, "tenant {t} never published");
        assert!(
            flush_versions.windows(2).all(|w| w[0] <= w[1]),
            "tenant {t} flush versions not monotone: {flush_versions:?}"
        );
        assert_eq!(*version, *flush_versions.last().unwrap(), "tenant {t}");
        // bitwise-identical to the dedicated-thread run
        assert_eq!(flush_versions, &pin_r.0, "tenant {t} version sequences diverged");
        assert_eq!(*version, pin_r.1, "tenant {t} final versions diverged");
        assert_eq!(values, &pin_r.2, "tenant {t} eigenvalues diverged");
        assert_eq!(vectors, &pin_r.3, "tenant {t} eigenvectors diverged");
    }
}

/// Retirement-vs-submit stress: every tenant is removed, one at a time,
/// while three hammer threads keep firing ingests/flushes/queries at
/// all of them through cloned handles.  The scheduler's retirement
/// latch must hold under fire: no deadlock (every hammer joins), no
/// post-stop execution (a removed tenant answers with a clean `Err`,
/// and `remove` is immediately sticky), no panic from a raced reply
/// channel.
#[test]
fn removing_tenants_under_fire_stays_clean() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    const TENANTS: u64 = 6;
    let fleet = Fleet::new(FleetConfig { workers: 2 });
    let handles: Vec<ServiceHandle> =
        (0..TENANTS).map(|t| fleet.spawn(TenantId(t), tenant_config(t)).unwrap()).collect();

    let stop = Arc::new(AtomicBool::new(false));
    let mut hammers = Vec::new();
    for w in 0..3u64 {
        let handles = handles.clone();
        let stop = stop.clone();
        hammers.push(std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                for (t, h) in handles.iter().enumerate() {
                    // live tenants answer Ok; removed tenants must
                    // answer a clean Err — never hang, never panic
                    let _ = h.ingest(vec![event(t as u64, w * 1000 + i)]);
                    if i % 7 == w {
                        let _ = h.flush();
                    }
                    let _ = h.snapshot().version;
                }
                i += 1;
            }
        }));
    }

    for t in 0..TENANTS {
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(fleet.remove(TenantId(t)), "tenant {t} was already gone");
        // retirement is immediately sticky from every handle's view
        assert!(handles[t as usize].ingest(vec![event(t, 0)]).is_err());
        assert!(handles[t as usize].flush().is_err());
        assert!(fleet.get(TenantId(t)).is_none());
    }

    stop.store(true, Ordering::Relaxed);
    for h in hammers {
        h.join().expect("hammer thread must exit cleanly (no deadlock, no post-stop panic)");
    }
    fleet.join();
}

/// A tracker that rejects every update — the fault injector for the
/// isolation soak.
struct FailingTracker {
    pairs: EigenPairs,
}

impl EigTracker for FailingTracker {
    fn descriptor(&self) -> TrackerSpec {
        TrackerSpec::custom("always-fails")
    }

    fn update(&mut self, _delta: &Delta) -> anyhow::Result<()> {
        anyhow::bail!("injected tracker fault")
    }

    fn current(&self) -> &EigenPairs {
        &self.pairs
    }
}

/// Isolation soak: one tenant errors on every batch; its neighbours'
/// snapshot versions advance normally, their flushes stay responsive,
/// and `update_failures` stays scoped to the faulty tenant.
#[test]
fn flaky_tenant_does_not_disturb_healthy_tenants() {
    const HEALTHY: u64 = 3;
    const ROUNDS: u64 = 30;
    let fleet = Fleet::new(FleetConfig { workers: 2 });

    let flaky_id = TenantId(99);
    let flaky = fleet
        .spawn_with_factory(
            flaky_id,
            tenant_config(99),
            TenantBudget::default(),
            Box::new(|_a0, init| Ok(Box::new(FailingTracker { pairs: init.clone() }))),
        )
        .unwrap();
    let healthy: Vec<ServiceHandle> =
        (0..HEALTHY).map(|t| fleet.spawn(TenantId(t), tenant_config(t)).unwrap()).collect();

    let mut flush_lat = Vec::new();
    for i in 0..ROUNDS {
        // the flaky tenant gets the same traffic as everyone else; every
        // one of its flushes fails inside the pool worker
        flaky.ingest(vec![event(99, i)]).unwrap();
        for (t, h) in healthy.iter().enumerate() {
            h.ingest(vec![event(t as u64, i)]).unwrap();
        }
        if (i + 1) % 5 == 0 {
            let _ = flaky.flush().unwrap();
            for h in &healthy {
                let t0 = std::time::Instant::now();
                h.flush().unwrap();
                flush_lat.push(t0.elapsed());
            }
        }
    }

    // healthy tenants: versions advanced, zero failures
    for (t, h) in healthy.iter().enumerate() {
        let m = h.metrics();
        assert_eq!(m.update_failures.get(), 0, "healthy tenant {t} saw failures");
        assert!(h.snapshot().version >= ROUNDS / 5, "healthy tenant {t} starved");
    }
    // flushes stayed responsive while sharing workers with the faulty
    // tenant (generous bound: this guards against starvation/deadlock,
    // not micro-latency)
    flush_lat.sort();
    let p95 = flush_lat[(flush_lat.len() * 95 / 100).min(flush_lat.len() - 1)];
    assert!(p95 < std::time::Duration::from_secs(5), "healthy p95 flush {p95:?}");

    // the faulty tenant: every flush failed, nothing ever published,
    // and the damage is scoped to its own metrics
    let fm = fleet.metrics(flaky_id).unwrap();
    assert!(fm.update_failures.get() >= ROUNDS / 5);
    assert_eq!(fm.batches_applied.get(), 0);
    assert_eq!(flaky.snapshot().version, 0);
    // ...and the fleet still removes it cleanly
    assert!(fleet.remove(flaky_id));
    assert!(flaky.ingest(vec![event(99, 0)]).is_err());
    fleet.join();
}
