//! Evaluation: the ψ angle metric (Eq. 15), the experiment harness that
//! drives every tracker over a scenario, and table/CSV reporters.

pub mod angle;
pub mod experiments;
pub mod harness;
pub mod table;
