//! Experiment harness: runs a set of trackers over a dynamic-graph
//! scenario, recording per-step eigenvector angles against a shared
//! Lanczos reference, per-step wall-clock, and per-step reported flops —
//! the raw material of every figure and table in the paper's Sec. 5.
//!
//! Trackers are described declaratively: the roster helpers return
//! [`TrackerSpec`] lists and [`run_trackers`] instantiates each through
//! [`TrackerSpec::build_seeded`], so a new tracker (or parameter sweep)
//! is one more spec in a `Vec`, not another constructor closure.

use crate::graph::scenario::DynamicScenario;
use crate::linalg::threads::Threads;
use crate::tracking::reference::Reference;
use crate::tracking::spec::{Algo, TrackerSpec};
use crate::tracking::traits::{init_eigenpairs, EigTracker, EigenPairs};
use std::time::{Duration, Instant};

/// The paper's evaluation roster minus TIMERS (add [`timers_spec`]):
/// TRIP, RM, IASC, G-REST₂, G-REST₃, G-REST_RSVD.  `rsvd_lp` scales with
/// graph expansion (paper: 100 for the SNAP runs, 20 for the SBM runs).
/// `threads` is the dense-kernel worker budget for the G-REST family.
pub fn paper_trackers(
    include_trip_basic: bool,
    rsvd_lp: usize,
    threads: Threads,
) -> Vec<TrackerSpec> {
    let mut v = vec![
        TrackerSpec::new(Algo::Trip),
        TrackerSpec::new(Algo::Rm { mu: 0.0 }),
        TrackerSpec::new(Algo::Iasc),
        TrackerSpec::new(Algo::Grest2).with_threads(threads),
        TrackerSpec::new(Algo::Grest3).with_threads(threads),
        TrackerSpec::new(Algo::GrestRsvd { l: rsvd_lp, p: rsvd_lp }).with_threads(threads),
    ];
    if include_trip_basic {
        v.insert(0, TrackerSpec::new(Algo::TripBasic));
    }
    v
}

/// TIMERS with the paper's default θ and restart gap.
pub fn timers_spec() -> TrackerSpec {
    TrackerSpec::new(Algo::Timers {
        theta: crate::tracking::spec::DEFAULT_TIMERS_THETA,
        min_gap: crate::tracking::spec::DEFAULT_TIMERS_GAP,
    })
}

/// Result of one tracker over one scenario.
pub struct RunResult {
    /// Spec-derived display name (one source of truth for tables/CSV).
    pub name: String,
    /// Canonical spec string (disambiguates sweeps whose display names
    /// coincide, e.g. seed or thread sweeps).
    pub spec: String,
    /// per-step ψ_i for i < angles_k, vs the Lanczos reference
    pub per_step_angles: Vec<Vec<f64>>,
    /// per-step tracker update time
    pub per_step_time: Vec<Duration>,
    /// per-step reported flop counts (0 when a tracker doesn't report)
    pub per_step_flops: Vec<u64>,
    pub total_time: Duration,
}

impl RunResult {
    /// Time-average of ψ_i for one eigenindex i (Fig. 2a/3a bars).
    pub fn avg_angle_for_index(&self, i: usize) -> f64 {
        let vals: Vec<f64> = self
            .per_step_angles
            .iter()
            .filter_map(|a| a.get(i).copied())
            .collect();
        vals.iter().sum::<f64>() / vals.len().max(1) as f64
    }

    /// Per-step mean over the first `k` indices (Fig. 2b/3b series).
    pub fn mean_angle_series(&self, k: usize) -> Vec<f64> {
        self.per_step_angles
            .iter()
            .map(|a| {
                let kk = k.min(a.len()).max(1);
                a[..kk].iter().sum::<f64>() / kk as f64
            })
            .collect()
    }

    /// Grand mean over time and indices (Fig. 5 scalar).
    pub fn grand_mean_angle(&self, k: usize) -> f64 {
        let s = self.mean_angle_series(k);
        s.iter().sum::<f64>() / s.len().max(1) as f64
    }

    /// Mean reported flops per update step (the complexity column).
    pub fn mean_flops_per_step(&self) -> f64 {
        self.per_step_flops.iter().map(|&f| f as f64).sum::<f64>()
            / self.per_step_flops.len().max(1) as f64
    }
}

/// Per-step reference eigenpairs (shared across trackers) plus the time
/// the reference computation took (the `eigs` baseline of Fig. 4).
pub struct ReferenceRun {
    pub per_step: Vec<EigenPairs>,
    pub per_step_time: Vec<Duration>,
    pub total_time: Duration,
}

/// Compute the Lanczos reference for every step of a scenario.
pub fn reference_run(sc: &DynamicScenario, k: usize, seed: u64) -> ReferenceRun {
    let mut per_step = Vec::with_capacity(sc.steps.len());
    let mut per_step_time = Vec::with_capacity(sc.steps.len());
    let t0 = Instant::now();
    for (t, step) in sc.steps.iter().enumerate() {
        let s0 = Instant::now();
        per_step.push(Reference::compute(&step.adjacency, k, seed.wrapping_add(t as u64)));
        per_step_time.push(s0.elapsed());
    }
    ReferenceRun { per_step, per_step_time, total_time: t0.elapsed() }
}

/// Run every spec over the scenario against a precomputed reference.
///
/// `angles_k` — how many leading eigenvector angles to record per step.
/// `seed` is the shared initialization seed and the fallback tracker
/// seed (an explicit `seed=` in a spec wins).  A spec that fails to
/// build (e.g. `@xla` without artifacts) is a clean error; a tracker
/// failing mid-run still panics (the run is unsalvageable).
pub fn run_trackers(
    sc: &DynamicScenario,
    reference: &ReferenceRun,
    k: usize,
    angles_k: usize,
    trackers: &[TrackerSpec],
    seed: u64,
) -> anyhow::Result<Vec<RunResult>> {
    let init = init_eigenpairs(&sc.initial, k, seed);
    trackers
        .iter()
        .map(|spec| {
            let mut tracker = spec
                .build_seeded(&sc.initial, &init, seed)
                .map_err(|e| anyhow::anyhow!("cannot build tracker `{spec}`: {e}"))?;
            let name = tracker.name();
            let spec_text = spec.to_string();
            let mut per_step_angles = Vec::with_capacity(sc.steps.len());
            let mut per_step_time = Vec::with_capacity(sc.steps.len());
            let mut per_step_flops = Vec::with_capacity(sc.steps.len());
            let t0 = Instant::now();
            for (t, step) in sc.steps.iter().enumerate() {
                let s0 = Instant::now();
                tracker
                    .update(&step.delta)
                    .unwrap_or_else(|e| panic!("{name} failed at step {t}: {e}"));
                per_step_time.push(s0.elapsed());
                per_step_flops.push(tracker.last_step_flops());
                per_step_angles.push(crate::eval::angle::angles(
                    tracker.current(),
                    &reference.per_step[t],
                    angles_k,
                ));
            }
            Ok(RunResult {
                name,
                spec: spec_text,
                per_step_angles,
                per_step_time,
                per_step_flops,
                total_time: t0.elapsed(),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::rng::Rng;

    fn small_scenario(seed: u64) -> DynamicScenario {
        let mut rng = Rng::new(seed);
        let w = generators::power_law_weights(120, 2.3, 360);
        let g = generators::chung_lu(&w, &mut rng);
        crate::graph::scenario::scenario1_from_static("test", &g, 4)
    }

    #[test]
    fn harness_runs_full_roster() {
        let sc = small_scenario(1);
        let k = 8;
        let reference = reference_run(&sc, k, 7);
        let mut roster = paper_trackers(false, 8, Threads::AUTO);
        roster.push(timers_spec());
        let results = run_trackers(&sc, &reference, k, 3, &roster, 7).unwrap();
        assert_eq!(results.len(), 7);
        for r in &results {
            assert_eq!(r.per_step_angles.len(), 4);
            assert!(r.grand_mean_angle(3).is_finite());
        }
    }

    #[test]
    fn baseline_trackers_report_flops() {
        // TRIP / RM / IASC / TIMERS must all report nonzero per-step
        // flops, not just the G-REST family (complexity columns)
        let sc = small_scenario(3);
        let k = 6;
        let reference = reference_run(&sc, k, 5);
        let mut roster = paper_trackers(true, 6, Threads::AUTO);
        roster.push(timers_spec());
        let results = run_trackers(&sc, &reference, k, 3, &roster, 5).unwrap();
        for r in &results {
            assert!(
                r.mean_flops_per_step() > 0.0,
                "{} reports zero flops",
                r.name
            );
        }
    }

    #[test]
    fn grest3_at_least_as_accurate_as_trip_on_expansion() {
        // paper's core qualitative claim, at harness level
        let sc = small_scenario(2);
        let k = 8;
        let reference = reference_run(&sc, k, 11);
        let roster = paper_trackers(false, 8, Threads::AUTO);
        let results = run_trackers(&sc, &reference, k, 3, &roster, 11).unwrap();
        let get = |n: &str| {
            results
                .iter()
                .find(|r| r.name == n)
                .unwrap()
                .grand_mean_angle(3)
        };
        let trip = get("TRIP");
        let g3 = get("G-REST3");
        assert!(
            g3 <= trip + 1e-9,
            "G-REST3 mean ψ {g3} should beat TRIP {trip}"
        );
    }
}
