//! The eigenvector-approximation metric ψ of paper Eq. (15):
//! ψ_i = arccos(|x_iᵀ x̃_i|) with both vectors unit-normalized.

use crate::linalg::blas;
use crate::tracking::traits::EigenPairs;

/// ψ between two vectors (radians in [0, π/2] after |·|).
pub fn angle(a: &[f64], b: &[f64]) -> f64 {
    let na = blas::nrm2(a).max(1e-300);
    let nb = blas::nrm2(b).max(1e-300);
    let c = (blas::dot(a, b).abs() / (na * nb)).min(1.0);
    c.acos()
}

/// Per-index angles ψ_i between estimate and reference, i = 0..k.
/// The estimate may live in a larger space (padded rows are compared
/// against implicit zeros in the reference — both sides are padded to the
/// longer length).
pub fn angles(estimate: &EigenPairs, reference: &EigenPairs, k: usize) -> Vec<f64> {
    let k = k.min(estimate.k()).min(reference.k());
    let n = estimate.n().max(reference.n());
    let mut out = Vec::with_capacity(k);
    let pad = |v: &[f64]| {
        let mut p = v.to_vec();
        p.resize(n, 0.0);
        p
    };
    for i in 0..k {
        let a = pad(estimate.vectors.col(i));
        let b = pad(reference.vectors.col(i));
        out.push(angle(&a, &b));
    }
    out
}

/// Mean of the first `k` angles — the paper's Fig. 2(b)/3(b) series.
pub fn mean_angle(estimate: &EigenPairs, reference: &EigenPairs, k: usize) -> f64 {
    let a = angles(estimate, reference, k);
    if a.is_empty() {
        return 0.0;
    }
    a.iter().sum::<f64>() / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;

    fn pairs(cols: Vec<Vec<f64>>) -> EigenPairs {
        let n = cols[0].len();
        let k = cols.len();
        let mut m = Mat::zeros(n, k);
        for (j, c) in cols.iter().enumerate() {
            m.set_col(j, c);
        }
        EigenPairs { values: vec![0.0; k], vectors: m }
    }

    #[test]
    fn identical_vectors_zero_angle() {
        let p = pairs(vec![vec![1.0, 0.0, 0.0]]);
        assert!(mean_angle(&p, &p, 1) < 1e-12);
    }

    #[test]
    fn sign_flip_is_zero_angle() {
        let a = pairs(vec![vec![0.6, 0.8]]);
        let b = pairs(vec![vec![-0.6, -0.8]]);
        assert!(mean_angle(&a, &b, 1) < 1e-12);
    }

    #[test]
    fn orthogonal_vectors_right_angle() {
        let a = pairs(vec![vec![1.0, 0.0]]);
        let b = pairs(vec![vec![0.0, 1.0]]);
        assert!((mean_angle(&a, &b, 1) - std::f64::consts::FRAC_PI_2).abs() < 1e-12);
    }

    #[test]
    fn padding_to_longer_space() {
        let a = pairs(vec![vec![1.0, 0.0, 0.0, 0.0]]); // estimate in R⁴
        let b = pairs(vec![vec![1.0, 0.0]]); // reference in R²
        assert!(mean_angle(&a, &b, 1) < 1e-12);
    }
}
