//! Aligned ASCII tables and CSV writers for the experiment outputs.

use std::fmt::Write as _;

/// A simple column-aligned table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Render with per-column width alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (c, cell) in r.iter().enumerate() {
                widths[c] = widths[c].max(cell.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for c in 0..ncol {
                let _ = write!(out, "{:<w$}  ", cells[c], w = widths[c]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    /// CSV rendering.  Fields containing a comma, quote, or newline are
    /// quoted RFC-4180 style (tracker names like `G-REST-RSVD(L=32,P=32)`
    /// carry commas).
    pub fn to_csv(&self) -> String {
        fn field(cell: &str) -> String {
            if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
                format!("\"{}\"", cell.replace('"', "\"\""))
            } else {
                cell.to_string()
            }
        }
        let mut out = String::new();
        let mut line = |cells: &[String]| {
            let quoted: Vec<String> = cells.iter().map(|c| field(c)).collect();
            out.push_str(&quoted.join(","));
            out.push('\n');
        };
        line(&self.headers);
        for r in &self.rows {
            line(r);
        }
        out
    }

    /// Write CSV next to the bench outputs (results/ by default).
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("GREST_RESULTS").unwrap_or_else(|_| "results".into());
        std::fs::create_dir_all(&dir)?;
        let path = std::path::Path::new(&dir).join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format seconds human-readably.
pub fn fmt_secs(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("name"));
        assert!(r.lines().count() == 4);
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[2].find('1'), lines[3].find('2'));
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn csv_quotes_fields_with_commas() {
        let mut t = Table::new(&["Tracker", "psi"]);
        t.row(vec!["G-REST-RSVD(L=32,P=32)".into(), "0.1".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "Tracker,psi\n\"G-REST-RSVD(L=32,P=32)\",0.1\n");
        // still one comma-separated record per row
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn fmt_secs_ranges() {
        use std::time::Duration;
        assert!(fmt_secs(Duration::from_micros(50)).ends_with("us"));
        assert!(fmt_secs(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_secs(Duration::from_secs(2)).ends_with('s'));
    }
}
