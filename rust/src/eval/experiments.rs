//! One driver per paper table/figure (see DESIGN.md experiment index).
//! Shared by the CLI (`grest experiment <id>`) and the bench targets.

use crate::eval::harness::{
    paper_trackers, reference_run, run_trackers, timers_spec, RunResult,
};
use crate::eval::table::{fmt_secs, Table};
use crate::graph::datasets::{self, DatasetSpec, Kind};
use crate::graph::scenario::sbm_expansion;
use crate::linalg::rng::Rng;
use crate::linalg::threads::Threads;
use crate::tasks::{ari::adjusted_rand_index, centrality, clustering};
use crate::tracking::laplacian::{shifted_scenario, Shift};
use crate::tracking::spec::{Algo, TrackerSpec};
use crate::tracking::traits::init_eigenpairs;
use crate::tracking::EigTracker;
use std::time::{Duration, Instant};

/// Scaled-down knobs for smoke runs (CI / quick bench).
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    /// tracked eigenpairs (paper: 64)
    pub k: usize,
    /// eigenvector angles recorded (paper: 32)
    pub angles_k: usize,
    /// RSVD L=P (paper: 100 for SNAP runs)
    pub rsvd_lp: usize,
    /// Monte-Carlo repetitions (paper: 10)
    pub mc: usize,
    /// time-step override (None = dataset default)
    pub t_override: Option<usize>,
    /// dataset size divisor on top of the registry scaling
    pub extra_scale: usize,
    /// dense-kernel worker budget for the G-REST trackers
    pub threads: Threads,
}

impl ExpConfig {
    /// Paper-faithful (at registry scale) configuration.
    pub fn paper() -> ExpConfig {
        let mc = std::env::var("GREST_MC")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2);
        ExpConfig {
            k: 64,
            angles_k: 32,
            rsvd_lp: 32,
            mc,
            t_override: None,
            extra_scale: 1,
            threads: Threads::AUTO,
        }
    }

    /// Fast smoke configuration (~seconds per figure).
    pub fn quick() -> ExpConfig {
        ExpConfig {
            k: 16,
            angles_k: 8,
            rsvd_lp: 8,
            mc: 1,
            t_override: Some(4),
            extra_scale: 4,
            threads: Threads::AUTO,
        }
    }
}

/// Scale a dataset spec down by an extra divisor (quick/smoke runs).
pub fn scale_spec(spec: &DatasetSpec, extra: usize) -> DatasetSpec {
    let mut s = spec.clone();
    if extra > 1 {
        s.nodes = (s.nodes / extra).max(64);
        s.edges = (s.edges / extra).max(4 * s.nodes);
        s.scale *= extra;
    }
    s
}

/// Aggregated result of one dataset (MC-averaged).
pub struct DatasetResult {
    pub dataset: String,
    /// tracker name → time-averaged ψ_i for i = 0,1,2 (Fig. 2a/3a)
    pub top3: Vec<(String, [f64; 3])>,
    /// tracker name → per-step mean-ψ over angles_k (Fig. 2b/3b)
    pub series: Vec<(String, Vec<f64>)>,
    /// tracker name → total tracking time (Fig. 4)
    pub times: Vec<(String, Duration)>,
    /// tracker name → mean reported flops per step (complexity column)
    pub flops: Vec<(String, f64)>,
    /// reference (`eigs`) total time
    pub eigs_time: Duration,
}

/// Run the full roster on one dataset spec, MC-averaged.
pub fn run_dataset(spec: &DatasetSpec, cfg: &ExpConfig) -> DatasetResult {
    let spec = scale_spec(spec, cfg.extra_scale);
    let mut agg: Option<DatasetResult> = None;
    for mc in 0..cfg.mc {
        let mut rng = Rng::new(1000 + mc as u64);
        let sc = datasets::scenario_for(&spec, cfg.t_override, &mut rng);
        let reference = reference_run(&sc, cfg.k, 7 + mc as u64);
        let mut roster = paper_trackers(false, cfg.rsvd_lp, cfg.threads);
        roster.push(timers_spec());
        let results = run_trackers(&sc, &reference, cfg.k, cfg.angles_k, &roster, 7 + mc as u64)
            .expect("paper roster must build");
        let cur = summarize(&spec.name, &results, reference.total_time, cfg.angles_k);
        agg = Some(match agg {
            None => cur,
            Some(mut prev) => {
                merge_into(&mut prev, &cur, mc + 1);
                prev
            }
        });
    }
    agg.unwrap()
}

fn summarize(
    name: &str,
    results: &[RunResult],
    eigs_time: Duration,
    angles_k: usize,
) -> DatasetResult {
    DatasetResult {
        dataset: name.to_string(),
        top3: results
            .iter()
            .map(|r| {
                (
                    r.name.clone(),
                    [
                        r.avg_angle_for_index(0),
                        r.avg_angle_for_index(1),
                        r.avg_angle_for_index(2),
                    ],
                )
            })
            .collect(),
        series: results
            .iter()
            .map(|r| (r.name.clone(), r.mean_angle_series(angles_k)))
            .collect(),
        times: results.iter().map(|r| (r.name.clone(), r.total_time)).collect(),
        flops: results
            .iter()
            .map(|r| (r.name.clone(), r.mean_flops_per_step()))
            .collect(),
        eigs_time,
    }
}

fn merge_into(prev: &mut DatasetResult, cur: &DatasetResult, runs_so_far: usize) {
    // running mean with weight 1/runs
    let w = 1.0 / runs_so_far as f64;
    for (p, c) in prev.top3.iter_mut().zip(cur.top3.iter()) {
        for i in 0..3 {
            p.1[i] += (c.1[i] - p.1[i]) * w;
        }
    }
    for (p, c) in prev.series.iter_mut().zip(cur.series.iter()) {
        for (a, b) in p.1.iter_mut().zip(c.1.iter()) {
            *a += (b - *a) * w;
        }
    }
    for (p, c) in prev.times.iter_mut().zip(cur.times.iter()) {
        p.1 = p.1.mul_f64(1.0 - w) + c.1.mul_f64(w);
    }
    for (p, c) in prev.flops.iter_mut().zip(cur.flops.iter()) {
        p.1 += (c.1 - p.1) * w;
    }
    prev.eigs_time = prev.eigs_time.mul_f64(1.0 - w) + cur.eigs_time.mul_f64(w);
}

/// Table 2: the dataset registry (paper vs build sizes).
pub fn table2() -> Table {
    let mut t = Table::new(&[
        "Dataset", "Type", "|V| paper", "|E| paper", "|V| built", "|E| target", "scale", "T",
    ]);
    for d in datasets::registry() {
        t.row(vec![
            d.name.into(),
            match d.kind {
                Kind::Static => "S".into(),
                Kind::Dynamic => "D".into(),
            },
            d.paper_nodes.to_string(),
            d.paper_edges.to_string(),
            d.nodes.to_string(),
            d.edges.to_string(),
            format!("1/{}", d.scale),
            d.t_steps.to_string(),
        ]);
    }
    t
}

/// Fig. 2 / Fig. 3 (accuracy) + Fig. 4 (runtime) for a dataset kind.
pub fn figure_accuracy_runtime(kind: Kind, cfg: &ExpConfig) -> (Vec<DatasetResult>, Table, Table, Table) {
    let specs: Vec<DatasetSpec> = datasets::registry()
        .into_iter()
        .filter(|d| d.kind == kind)
        .collect();
    let results: Vec<DatasetResult> = specs.iter().map(|s| run_dataset(s, cfg)).collect();

    // (a): time-averaged ψ for the first three eigenvectors
    let mut ta = Table::new(&["Dataset", "Tracker", "psi_1", "psi_2", "psi_3"]);
    for r in &results {
        for (name, t3) in &r.top3 {
            ta.row(vec![
                r.dataset.clone(),
                name.clone(),
                format!("{:.4}", t3[0]),
                format!("{:.4}", t3[1]),
                format!("{:.4}", t3[2]),
            ]);
        }
    }
    // (b): mean-ψ over the leading angles_k as a function of t
    let mut tb = Table::new(&["Dataset", "Tracker", "t", "mean_psi"]);
    for r in &results {
        for (name, series) in &r.series {
            for (t, v) in series.iter().enumerate() {
                tb.row(vec![
                    r.dataset.clone(),
                    name.clone(),
                    (t + 1).to_string(),
                    format!("{v:.5}"),
                ]);
            }
        }
    }
    // Fig. 4: total runtimes incl. eigs, plus the complexity column
    let mut tt = Table::new(&["Dataset", "Tracker", "total_time", "seconds", "Mflop_per_step"]);
    for r in &results {
        for ((name, d), (_, fl)) in r.times.iter().zip(r.flops.iter()) {
            tt.row(vec![
                r.dataset.clone(),
                name.clone(),
                fmt_secs(*d),
                format!("{:.4}", d.as_secs_f64()),
                format!("{:.2}", fl / 1e6),
            ]);
        }
        tt.row(vec![
            r.dataset.clone(),
            "eigs".into(),
            fmt_secs(r.eigs_time),
            format!("{:.4}", r.eigs_time.as_secs_f64()),
            "-".into(),
        ]);
    }
    (results, ta, tb, tt)
}

/// Fig. 5: RSVD (L, P) accuracy/runtime trade-off on CM-Collab.
pub fn fig5_rsvd_tradeoff(cfg: &ExpConfig, grid: &[usize]) -> Table {
    let spec = scale_spec(&datasets::by_name("CM-Collab").unwrap(), cfg.extra_scale);
    let mut rng = Rng::new(42);
    let sc = datasets::scenario_for(&spec, cfg.t_override, &mut rng);
    let reference = reference_run(&sc, cfg.k, 9);

    // G-REST3 baseline
    let threads = cfg.threads;
    let roster3 = vec![TrackerSpec::new(Algo::Grest3).with_threads(threads)];
    let base_runs =
        run_trackers(&sc, &reference, cfg.k, cfg.angles_k, &roster3, 9).expect("grest3 builds");
    let base = &base_runs[0];
    let base_psi = base.grand_mean_angle(cfg.angles_k);
    let base_time = base.total_time;

    let mut t = Table::new(&["L", "P", "mean_psi", "delta_vs_grest3", "speedup_x"]);
    t.row(vec![
        "full".into(),
        "full".into(),
        format!("{base_psi:.5}"),
        "0".into(),
        "1.00".into(),
    ]);
    for &l in grid {
        for &p in grid {
            let roster = vec![TrackerSpec::new(Algo::GrestRsvd { l, p }).with_threads(threads)];
            let runs = run_trackers(&sc, &reference, cfg.k, cfg.angles_k, &roster, 9)
                .expect("rsvd roster builds");
            let r = &runs[0];
            let psi = r.grand_mean_angle(cfg.angles_k);
            t.row(vec![
                l.to_string(),
                p.to_string(),
                format!("{psi:.5}"),
                format!("{:+.5}", psi - base_psi),
                format!("{:.2}", base_time.as_secs_f64() / r.total_time.as_secs_f64()),
            ]);
        }
    }
    t
}

/// Table 3: central-node identification accuracy on the static datasets.
pub fn table3_centrality(cfg: &ExpConfig, js: &[usize]) -> Table {
    let specs: Vec<DatasetSpec> = datasets::registry()
        .into_iter()
        .filter(|d| d.kind == Kind::Static)
        .collect();
    let mut t = Table::new(&["Method", "J", "Dataset", "overlap_%"]);
    for spec in &specs {
        let spec = scale_spec(spec, cfg.extra_scale);
        let mut rng = Rng::new(77);
        let sc = datasets::scenario_for(&spec, cfg.t_override, &mut rng);
        let reference = reference_run(&sc, cfg.k, 3);
        let mut roster = paper_trackers(false, cfg.rsvd_lp, cfg.threads);
        roster.push(timers_spec());
        // rerun trackers capturing eigenpairs per step for centrality
        let init = init_eigenpairs(&sc.initial, cfg.k, 3);
        for specr in &roster {
            let mut tracker = specr
                .build_seeded(&sc.initial, &init, 3)
                .unwrap_or_else(|e| panic!("cannot build tracker `{specr}`: {e}"));
            let mut overlaps: Vec<Vec<f64>> = vec![vec![]; js.len()];
            for (step_idx, step) in sc.steps.iter().enumerate() {
                tracker.update(&step.delta).unwrap();
                // use the leading 32 (angles_k) pairs as in the paper
                let kk = cfg.angles_k.min(cfg.k);
                let trunc = |p: &crate::tracking::EigenPairs| crate::tracking::EigenPairs {
                    values: p.values[..kk.min(p.k())].to_vec(),
                    vectors: p.vectors.select_cols(&(0..kk.min(p.k())).collect::<Vec<_>>()),
                };
                let est = trunc(tracker.current());
                let refp = trunc(&reference.per_step[step_idx]);
                for (ji, &j) in js.iter().enumerate() {
                    let j = j.min(step.adjacency.n_rows);
                    let got = centrality::central_nodes(&est, j);
                    let want = centrality::central_nodes(&refp, j);
                    overlaps[ji].push(centrality::overlap(&want, &got));
                }
            }
            for (ji, &j) in js.iter().enumerate() {
                let mean = overlaps[ji].iter().sum::<f64>() / overlaps[ji].len().max(1) as f64;
                t.row(vec![
                    specr.display_name(),
                    j.to_string(),
                    spec.name.into(),
                    format!("{:.1}", 100.0 * mean),
                ]);
            }
        }
    }
    t
}

/// Fig. 6: clustering ARI ratio vs p_out (a) and #clusters (b) on SBM
/// expansions, via shifted normalized-Laplacian tracking.
pub fn fig6_clustering(cfg: &ExpConfig, n: usize, p_outs: &[f64], ks: &[usize]) -> Table {
    let mut t = Table::new(&["sweep", "value", "Tracker", "ARI_ratio"]);
    // (a) vary p_out at fixed k=5; (b) vary k at fixed p_out = middle
    let mid_pout = p_outs[p_outs.len() / 2];
    let mut jobs: Vec<(String, f64, usize)> = p_outs.iter().map(|&p| ("p_out".to_string(), p, 5usize)).collect();
    jobs.extend(ks.iter().map(|&k| ("clusters".to_string(), mid_pout, k)));
    for (sweep, p_out, k_clusters) in jobs {
        let value = if sweep == "p_out" { format!("{p_out}") } else { format!("{k_clusters}") };
        let mut per_tracker: Vec<(String, Vec<f64>)> = Vec::new();
        for mc in 0..cfg.mc {
            let mut rng = Rng::new(500 + mc as u64);
            let n0 = n - n / 20;
            let s_per = (n - n0) / 5;
            let sc = sbm_expansion(n, k_clusters, 0.05, p_out, n0, s_per, 5, &mut rng);
            let labels = sc.labels_per_step.clone().unwrap();
            // shifted normalized Laplacian stream
            let (t0, steps) = shifted_scenario(&sc, Shift::Normalized);
            let init = init_eigenpairs(&t0, k_clusters, 21 + mc as u64);
            let lp = cfg.rsvd_lp.min(20).max(4);
            let specs = {
                let mut v = paper_trackers(false, lp, cfg.threads);
                v.push(timers_spec());
                v
            };
            let mut trackers: Vec<Box<dyn EigTracker>> = specs
                .iter()
                .map(|s| {
                    s.build_seeded(&t0, &init, 33)
                        .unwrap_or_else(|e| panic!("cannot build tracker `{s}`: {e}"))
                })
                .collect();
            let mut ratios: Vec<(String, Vec<f64>)> =
                trackers.iter().map(|t| (t.name(), vec![])).collect();
            for (step_idx, (delta, t_now)) in steps.iter().enumerate() {
                let truth = &labels[step_idx + 1];
                // reference clustering from exact trailing eigenvectors
                let refp = init_eigenpairs(t_now, k_clusters, 99 + step_idx as u64);
                let ref_labels = clustering::spectral_cluster(&refp.vectors, k_clusters, 1);
                let ref_ari = adjusted_rand_index(&ref_labels, truth).max(1e-6);
                for (ti, tracker) in trackers.iter_mut().enumerate() {
                    tracker.update(delta).unwrap();
                    let est_labels =
                        clustering::spectral_cluster(&tracker.current().vectors, k_clusters, 1);
                    let ari = adjusted_rand_index(&est_labels, truth);
                    ratios[ti].1.push(ari / ref_ari);
                }
            }
            if per_tracker.is_empty() {
                per_tracker = ratios;
            } else {
                for (p, c) in per_tracker.iter_mut().zip(ratios.iter()) {
                    p.1.extend(c.1.iter().copied());
                }
            }
        }
        for (name, rs) in per_tracker {
            let mean = rs.iter().sum::<f64>() / rs.len().max(1) as f64;
            t.row(vec![sweep.clone(), value.clone(), name, format!("{mean:.3}")]);
        }
    }
    t
}

/// End-to-end wall-clock of one full experiment id (for logs).
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    eprintln!("[experiment] {label} finished in {}", fmt_secs(t0.elapsed()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_all_rows() {
        let t = table2();
        let r = t.render();
        assert!(r.contains("Crocodile") && r.contains("AskUbuntu"));
    }

    #[test]
    fn quick_fig5_grid_runs() {
        let mut cfg = ExpConfig::quick();
        cfg.t_override = Some(2);
        cfg.extra_scale = 8;
        let t = fig5_rsvd_tradeoff(&cfg, &[4]);
        let csv = t.to_csv();
        assert!(csv.lines().count() >= 3); // header + full + one grid point
    }

    #[test]
    fn quick_fig6_runs_and_orders_sanely() {
        let cfg = ExpConfig { mc: 1, ..ExpConfig::quick() };
        let t = fig6_clustering(&cfg, 300, &[0.005], &[3]);
        let csv = t.to_csv();
        // 7 trackers × 2 sweeps (p_out row + clusters row)
        assert_eq!(csv.lines().count(), 1 + 14, "{csv}");
    }
}
