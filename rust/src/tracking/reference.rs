//! Full-recompute reference tracker: Lanczos (`eigs` stand-in) from
//! scratch at every step.  Provides the ψ-metric ground truth and the
//! runtime baseline of Fig. 4.

use crate::sparse::csr::Csr;
use crate::sparse::delta::Delta;
use crate::tracking::spec::{Algo, TrackerSpec};
use crate::tracking::traits::{apply_delta, init_eigenpairs, EigTracker, EigenPairs};

pub struct Reference {
    adjacency: Csr,
    k: usize,
    /// per-step Lanczos seed; advances on every update
    seed: u64,
    /// construction-time seed (reported by `descriptor`)
    initial_seed: u64,
    state: EigenPairs,
    flops: u64,
}

impl Reference {
    pub fn new(a0: &Csr, k: usize, seed: u64) -> Reference {
        let state = init_eigenpairs(a0, k, seed);
        Reference { adjacency: a0.clone(), k, seed, initial_seed: seed, state, flops: 0 }
    }

    /// Compute reference eigenpairs directly for a given matrix (used by
    /// the harness when the post-step adjacency is already known).
    pub fn compute(a: &Csr, k: usize, seed: u64) -> EigenPairs {
        init_eigenpairs(a, k, seed)
    }
}

impl EigTracker for Reference {
    fn descriptor(&self) -> TrackerSpec {
        TrackerSpec::new(Algo::Eigs).with_seed(self.initial_seed)
    }

    fn update(&mut self, delta: &Delta) -> anyhow::Result<()> {
        self.adjacency = apply_delta(&self.adjacency, delta);
        self.seed = self.seed.wrapping_add(1);
        self.state = init_eigenpairs(&self.adjacency, self.k, self.seed);
        let n = self.adjacency.n_rows as u64;
        let nnz = self.adjacency.nnz() as u64;
        let m = (4 * self.k + 40) as u64;
        self.flops = 2 * nnz * m + 2 * n * m * m;
        Ok(())
    }

    fn current(&self) -> &EigenPairs {
        &self.state
    }

    fn last_step_flops(&self) -> u64 {
        self.flops
    }

    /// aux_u layout: `[seed, flops]`; adjacency: the retained explicit
    /// copy.  The per-step seed must round-trip so restarted Lanczos
    /// runs draw the same start vectors as the uninterrupted run.
    fn save_state(&self) -> anyhow::Result<crate::tracking::traits::TrackerState> {
        Ok(crate::tracking::traits::TrackerState {
            pairs: self.state.clone(),
            aux_u: vec![self.seed, self.flops],
            aux_f: vec![],
            adjacency: Some(self.adjacency.clone()),
        })
    }

    fn restore_state(
        &mut self,
        st: crate::tracking::traits::TrackerState,
    ) -> anyhow::Result<()> {
        if st.aux_u.len() != 2 {
            anyhow::bail!("reference-tracker state layout mismatch");
        }
        let adjacency = match st.adjacency {
            Some(a) => a,
            None => anyhow::bail!("reference-tracker state missing its adjacency"),
        };
        self.seed = st.aux_u[0];
        self.flops = st.aux_u[1];
        self.adjacency = adjacency;
        self.state = st.pairs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::sparse::coo::Coo;

    #[test]
    fn reference_is_always_exact() {
        let mut rng = Rng::new(1);
        let g = crate::graph::generators::erdos_renyi(50, 0.1, &mut rng);
        let a0 = g.adjacency();
        let mut r = Reference::new(&a0, 4, 2);
        let mut kb = Coo::new(50, 50);
        kb.push_sym(0, 30, 1.0);
        kb.push_sym(5, 45, 1.0);
        let d = Delta::from_blocks(50, 0, &kb, &Coo::new(50, 0), &Coo::new(0, 0));
        r.update(&d).unwrap();
        let a1 = apply_delta(&a0, &d);
        assert!(r.current().max_residual(&a1) < 1e-7);
    }
}
