//! G-REST — Graph Rayleigh-Ritz Eigenspace Tracking (paper Alg. 2).
//!
//! One update step (time t → t+1):
//!
//! 1. Receive Δ; view X_K with S structural zero rows → X̄_K.
//! 2. Assemble the update panel
//!      * G-REST₂:     [ΔX̄_K]                      (Residual-Modes span)
//!      * G-REST₃:     [ΔX̄_K, Δ₂]                  (proposed, Eq. 11)
//!      * G-REST_RSVD: [ΔX̄_K, R] with R the L-rank randomized basis of
//!        (I−X̄X̄ᵀ)Δ₂                               (Sec. 3.5)
//! 3. `build_basis`: Q = orth((I − X̄X̄ᵀ)·panel).
//! 4. Sparse product ΔQ (here, in Rust — the only nnz(Δ)-cost step).
//! 5. `form_t`: T = Zᵀ(X̄ΛX̄ᵀ)Z + ZᵀΔZ over Z = [X̄, Q]  (Eq. 13).
//! 6. Small dense eigh of T; keep the K leading Ritz pairs by |θ|.
//! 7. `rotate`: X_new = X̄F₁ + QF₂,  Λ_new = Θ.
//!
//! Steps 3/5/7 are the dense phases behind the [`DensePhases`] trait:
//! [`NativePhases`] runs them with the in-crate kernels; the `runtime`
//! module provides an implementation that executes the AOT-compiled
//! JAX/Pallas artifacts on PJRT instead (same contract, tested equal).
//!
//! Two structural properties make the step cheap in steady state:
//!
//! * **Padding-aware phases.** X̄_K = [X_K; 0] is passed as a borrowed
//!   [`Padded`] view — the S structurally-zero rows are never copied
//!   (the old per-step `pad_rows` heap clone is gone) and never
//!   multiplied (every X̄-touching GEMM sheds the S/n fraction of its
//!   flops).  Zero contributions are exact in IEEE arithmetic and the
//!   kernels keep their reduction orders, so results are bitwise
//!   identical to the materialized-pad oracle (property-tested below).
//! * **Zero-allocation updates.** Every per-step temporary (the panel,
//!   assembled in place instead of via `hcat`; Q; ΔQ; T; F₁/F₂; the
//!   BCGS2 round buffers; the small-eigh scratch; and the
//!   double-buffered state vectors, swapped after `rotate`) lives in a
//!   grow-only [`StepWorkspace`] — a warmed tracker performs zero heap
//!   allocations per sequential update, asserted with a counting global
//!   allocator in `benches/microbench_grest.rs`.

use crate::linalg::eigh::{eigh_into, order_by_magnitude_into};
use crate::linalg::mat::{Mat, Padded};
use crate::linalg::qr::orthonormalize_against_into;
use crate::linalg::rng::Rng;
use crate::linalg::rsvd::rsvd_basis;
use crate::linalg::threads::Threads;
use crate::linalg::workspace::StepWorkspace;
use crate::sparse::delta::Delta;
use crate::tracking::spec::{Algo, Backend, TrackerSpec};
use crate::tracking::traits::{EigTracker, EigenPairs};

/// Projection-subspace construction (Table 1 of the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SubspaceMode {
    /// G-REST₂ — the Residual Modes subspace, optimal coefficients.
    Rm,
    /// G-REST₃ — proposed subspace with the explicit Δ₂ block (Eq. 11).
    Full,
    /// G-REST_RSVD — Δ₂ compressed by the randomized range finder.
    Rsvd { l: usize, p: usize },
}

impl SubspaceMode {
    pub fn label(&self) -> String {
        match self {
            SubspaceMode::Rm => "G-REST2".into(),
            SubspaceMode::Full => "G-REST3".into(),
            SubspaceMode::Rsvd { .. } => "G-REST-RSVD".into(),
        }
    }
}

/// The three dense phases of one G-REST step.  Implemented natively here
/// and by `runtime::grest_xla::XlaPhases` over the PJRT artifacts.
///
/// Contract (since the padding-aware refactor): X̄ arrives as a borrowed
/// [`Padded`] view; the panel transfers *ownership* into `build_basis`
/// (the native backend orthonormalizes it in place and returns the same
/// buffer as Q); every returned matrix may be backed by — and is given
/// back to — the caller's [`StepWorkspace`].  Backends that cannot work
/// in place (the PJRT wrapper) materialize what they need and return
/// fresh matrices; the workspace absorbs them.
pub trait DensePhases {
    /// Orthonormal basis of (I − X̄X̄ᵀ)·panel, rank-deficient columns
    /// deflated.  Consumes the panel buffer.
    fn build_basis(&self, xbar: Padded<'_>, panel: Mat, ws: &mut StepWorkspace) -> Mat;

    /// The projected matrix of Eq. (13) for Z = [X̄, Q].
    fn form_t(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        lam: &[f64],
        dxk: &Mat,
        dq: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat;

    /// Ritz rotation X_new = X̄ F₁ + Q F₂.
    fn rotate(&self, xbar: Padded<'_>, q: &Mat, f1: &Mat, f2: &Mat, ws: &mut StepWorkspace) -> Mat;

    fn label(&self) -> &'static str {
        "native"
    }

    /// Backend this implementation represents (for tracker descriptors).
    fn backend(&self) -> Backend {
        Backend::Native
    }

    /// Worker-thread budget used by the dense kernels, when meaningful.
    fn threads(&self) -> Threads {
        Threads::AUTO
    }

    /// XLA tier capacities (rows, panel cols) backing this
    /// implementation; `(0, 0)` for backends without fixed tiers.
    fn tier_caps(&self) -> (usize, usize) {
        (0, 0)
    }
}

/// Shared-ownership backends (lets many tracker instances reuse one
/// compiled-artifact cache within a thread).
impl<P: DensePhases + ?Sized> DensePhases for std::rc::Rc<P> {
    fn build_basis(&self, xbar: Padded<'_>, panel: Mat, ws: &mut StepWorkspace) -> Mat {
        (**self).build_basis(xbar, panel, ws)
    }
    fn form_t(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        lam: &[f64],
        dxk: &Mat,
        dq: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat {
        (**self).form_t(xbar, q, lam, dxk, dq, ws)
    }
    fn rotate(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        f1: &Mat,
        f2: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat {
        (**self).rotate(xbar, q, f1, f2, ws)
    }
    fn label(&self) -> &'static str {
        (**self).label()
    }
    fn backend(&self) -> Backend {
        (**self).backend()
    }
    fn threads(&self) -> Threads {
        (**self).threads()
    }
    fn tier_caps(&self) -> (usize, usize) {
        (**self).tier_caps()
    }
}

/// Pure-Rust dense phases (mirrors python/compile/model.py), carrying the
/// worker-thread budget for the blocked kernel layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativePhases {
    pub threads: Threads,
}

impl NativePhases {
    pub fn new(threads: Threads) -> NativePhases {
        NativePhases { threads }
    }
}

impl DensePhases for NativePhases {
    fn build_basis(&self, xbar: Padded<'_>, mut panel: Mat, ws: &mut StepWorkspace) -> Mat {
        let mut kept = std::mem::take(&mut ws.kept);
        orthonormalize_against_into(xbar, &mut panel, 1e-8, self.threads, ws, &mut kept);
        ws.kept = kept;
        panel
    }

    fn threads(&self) -> Threads {
        self.threads
    }

    fn form_t(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        lam: &[f64],
        dxk: &Mat,
        dq: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat {
        let k = xbar.cols();
        let m = q.cols();
        let dim = k + m;
        let mut t = ws.take_mat(dim, dim);
        // T11 = Λ + X̄ᵀ(ΔX̄).  X̄ᵀΔX̄ is analytically symmetric (Δᵀ = Δ),
        // so only the upper triangle is computed — half the flops of the
        // full K×K product the unspecialized pipeline paid; the padded
        // view drops the S zero rows from every dot.
        let mut t11 = ws.take_mat(0, 0);
        crate::linalg::blas::syrk_tn_into(&mut t11, xbar, dxk, self.threads);
        for i in 0..k {
            for j in 0..k {
                let lamij = if i == j { lam[i] } else { 0.0 };
                t.set(i, j, lamij + t11.get(i, j));
            }
        }
        ws.give_mat(t11);
        // T12 = X̄ᵀ(ΔQ) — genuinely rectangular, full product.
        let mut t12 = ws.take_mat(0, 0);
        crate::linalg::blas::gemm_tn_into(&mut t12, xbar, dq, self.threads);
        for i in 0..k {
            for j in 0..m {
                t.set(i, k + j, t12.get(i, j));
                t.set(k + j, i, t12.get(i, j));
            }
        }
        ws.give_mat(t12);
        // T22 = Qᵀ(ΔQ) — symmetric for the same reason as T11.
        let mut t22 = ws.take_mat(0, 0);
        crate::linalg::blas::syrk_tn_into(&mut t22, q, dq, self.threads);
        for i in 0..m {
            for j in 0..m {
                t.set(k + i, k + j, t22.get(i, j));
            }
        }
        ws.give_mat(t22);
        t
    }

    fn rotate(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        f1: &Mat,
        f2: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat {
        let mut out = ws.take_mat(0, 0);
        crate::linalg::blas::gemm_into(&mut out, xbar, f1, self.threads);
        crate::linalg::blas::gemm_acc_with(&mut out, q, f2, 1.0, self.threads);
        out
    }
}

/// The materialized-pad oracle backend: runs the same native phases on
/// `xbar.materialize()` (a `pad_rows` copy) instead of the borrowed
/// view.  This is the pipeline the padding-aware refactor replaced; it
/// is kept — together with `Mat::pad_rows` itself — exactly as the
/// property-test and bench oracle that the [`Padded`] pipeline must
/// match bitwise.
pub struct MaterializedPhases(pub NativePhases);

impl DensePhases for MaterializedPhases {
    fn build_basis(&self, xbar: Padded<'_>, panel: Mat, ws: &mut StepWorkspace) -> Mat {
        let xm = xbar.materialize();
        self.0.build_basis(Padded::from(&xm), panel, ws)
    }
    fn form_t(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        lam: &[f64],
        dxk: &Mat,
        dq: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat {
        let xm = xbar.materialize();
        self.0.form_t(Padded::from(&xm), q, lam, dxk, dq, ws)
    }
    fn rotate(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        f1: &Mat,
        f2: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat {
        let xm = xbar.materialize();
        self.0.rotate(Padded::from(&xm), q, f1, f2, ws)
    }
    fn label(&self) -> &'static str {
        "materialized-oracle"
    }
    fn threads(&self) -> Threads {
        self.0.threads
    }
}

/// The G-REST tracker (Alg. 2).
pub struct GRest<P: DensePhases = NativePhases> {
    state: EigenPairs,
    pub mode: SubspaceMode,
    phases: P,
    rng: Rng,
    seed: u64,
    flops: u64,
    ws: StepWorkspace,
    /// dimension of the last augmentation basis (diagnostics)
    pub last_basis_cols: usize,
}

impl GRest<NativePhases> {
    /// Native-backend tracker (auto thread budget).
    pub fn new(initial: EigenPairs, mode: SubspaceMode) -> Self {
        GRest::with_threads(initial, mode, Threads::AUTO)
    }

    /// Native-backend tracker with an explicit worker-thread budget for
    /// the dense phases.
    pub fn with_threads(initial: EigenPairs, mode: SubspaceMode, threads: Threads) -> Self {
        GRest::with_phases(initial, mode, NativePhases::new(threads), 0x9E57)
    }
}

impl<P: DensePhases> GRest<P> {
    pub fn with_phases(initial: EigenPairs, mode: SubspaceMode, phases: P, seed: u64) -> Self {
        GRest {
            state: initial,
            mode,
            phases,
            rng: Rng::new(seed),
            seed,
            flops: 0,
            ws: StepWorkspace::new(),
            last_basis_cols: 0,
        }
    }

    /// Reset the tracker to `initial` **in place**, keeping the warmed
    /// workspace: the state buffers are reused (no allocation once
    /// their capacity fits), the RNG rewinds to the construction seed
    /// (so an RSVD tracker replays the exact same sketches), and the
    /// per-step diagnostics clear — a reset tracker reproduces its
    /// original trajectory.  The per-step bench uses this to time
    /// warmed updates from a fixed state.
    pub fn reset_state(&mut self, initial: &EigenPairs) {
        self.state.values.clear();
        self.state.values.extend_from_slice(&initial.values);
        self.state.vectors.copy_from(&initial.vectors);
        self.rng = Rng::new(self.seed);
        self.flops = 0;
        self.last_basis_cols = 0;
    }
}

impl<P: DensePhases> EigTracker for GRest<P> {
    fn descriptor(&self) -> TrackerSpec {
        let algo = match self.mode {
            SubspaceMode::Rm => Algo::Grest2,
            SubspaceMode::Full => Algo::Grest3,
            SubspaceMode::Rsvd { l, p } => Algo::GrestRsvd { l, p },
        };
        let mut spec = TrackerSpec::new(algo)
            .with_backend(self.phases.backend())
            .with_threads(self.phases.threads())
            .with_seed(self.seed);
        (spec.n_cap, spec.panel_cap) = self.phases.tier_caps();
        spec
    }

    fn update(&mut self, delta: &Delta) -> anyhow::Result<()> {
        let GRest { state, mode, phases, rng, ws, flops, last_basis_cols, .. } = self;
        let k = state.k();
        let threads = phases.threads();
        let s = delta.s_new;
        let n_old = state.n();
        let n = n_old + s;
        let xbar = Padded::new(&state.vectors, s); // X̄_K, never materialized

        // sparse: ΔX̄_K into workspace storage
        let mut dxk = ws.take_mat(0, 0);
        delta.mul_padded_into(&state.vectors, &mut dxk, ws, threads);

        // RSVD tail basis, if configured (the only allocating subspace
        // mode — the randomized sketch is scratch-heavy by nature)
        let rsvd_r = match *mode {
            SubspaceMode::Rsvd { l, p } if s > 0 => {
                let r = rsvd_basis(
                    s,
                    &|om| delta.d2_mult_with(om, threads),
                    &|m, extra| delta.d2_t_mult_with(Padded::new(m, extra), threads),
                    Some(xbar),
                    l,
                    p,
                    rng,
                );
                if r.cols() > 0 {
                    Some(r)
                } else {
                    None
                }
            }
            _ => None,
        };
        let tail_cols = match *mode {
            SubspaceMode::Full if s > 0 => s,
            SubspaceMode::Rsvd { .. } => rsvd_r.as_ref().map_or(0, Mat::cols),
            _ => 0,
        };

        // assemble the update panel in place (no hcat copy chain)
        let m = k + tail_cols;
        let mut panel = ws.take_mat(n, m);
        for j in 0..k {
            panel.col_mut(j).copy_from_slice(dxk.col(j));
        }
        if let Some(r) = &rsvd_r {
            for j in 0..r.cols() {
                panel.col_mut(k + j).copy_from_slice(r.col(j));
            }
        } else if tail_cols > 0 {
            // Δ₂ block written straight off the sparse rows — the dense
            // (N+S)×S `d2_dense` materialization is gone
            for i in 0..n {
                let (cols, vals) = delta.full.row(i);
                for (&c, &v) in cols.iter().zip(vals.iter()) {
                    if c >= delta.n_old {
                        panel.set(i, k + (c - delta.n_old), v);
                    }
                }
            }
        }

        // dense phase 1: orthonormal augmentation basis (in place)
        let q = phases.build_basis(xbar, panel, ws);
        *last_basis_cols = q.cols();
        let qc = q.cols();

        // sparse interlude: ΔQ — row-partitioned under the same budget
        let mut dq = ws.take_mat(0, 0);
        delta.matmul_dense_into(&q, &mut dq, ws, threads);

        // dense phase 2a: projected matrix (Eq. 13)
        let t = phases.form_t(xbar, &q, &state.values, &dxk, &dq, ws);

        // small dense eigendecomposition (Alg. 2 line 9), in workspace
        eigh_into(&t, &mut ws.eig);
        ws.give_mat(t);
        let mut order = std::mem::take(&mut ws.order);
        order_by_magnitude_into(&ws.eig.d, k, &mut order);
        let mut f1 = ws.take_mat(k, order.len());
        let mut f2 = ws.take_mat(qc, order.len());
        let mut new_vals = ws.take_buf();
        for (c, &idx) in order.iter().enumerate() {
            new_vals.push(ws.eig.d[idx]);
            for i in 0..k {
                f1.set(i, c, ws.eig.v.get(i, idx));
            }
            for i in 0..qc {
                f2.set(i, c, ws.eig.v.get(k + i, idx));
            }
        }
        ws.order = order;

        // dense phase 2b: Ritz rotation
        let new_vecs = phases.rotate(xbar, &q, &f1, &f2, ws);

        // padding-aware flop model: X̄-touching products run at the
        // filled height n_old, not the padded n — this is the real cost
        // the Mflop tables report
        *flops = (2 * n_old * k * m          // BCGS2 projection gram X̄ᵀP
            + 2 * n * m * m                   // panel gram + CholQR update
            + n_old * k * k                   // T11 = sym(X̄ᵀΔX̄), half
            + 2 * n_old * k * qc              // T12 = X̄ᵀΔQ
            + n * qc * qc                     // T22 = sym(QᵀΔQ), half
            + (k + qc) * (k + qc) * (k + qc)  // eigh
            + 2 * n_old * k * k               // rotate: X̄F₁
            + 2 * n * qc * k) as u64 // rotate: QF₂
            + 2 * delta.nnz() as u64 * (k + qc) as u64;

        // recycle the step temporaries and swap the double-buffered state
        ws.give_mat(f1);
        ws.give_mat(f2);
        ws.give_mat(dq);
        ws.give_mat(dxk);
        ws.give_mat(q);
        let old_vecs = std::mem::replace(&mut state.vectors, new_vecs);
        ws.give_mat(old_vecs);
        let old_vals = std::mem::replace(&mut state.values, new_vals);
        ws.give_buf(old_vals);
        Ok(())
    }

    fn current(&self) -> &EigenPairs {
        &self.state
    }

    fn last_step_flops(&self) -> u64 {
        self.flops
    }

    /// aux_u layout: `[s0, s1, s2, s3, spare_flag, flops,
    /// last_basis_cols]` (xoshiro words first); aux_f: `[spare]` (0.0
    /// when absent — the flag disambiguates).  The RNG state makes a
    /// restored RSVD tracker replay the exact same sketches.
    fn save_state(&self) -> anyhow::Result<crate::tracking::traits::TrackerState> {
        let (s, spare) = self.rng.state_words();
        Ok(crate::tracking::traits::TrackerState {
            pairs: self.state.clone(),
            aux_u: vec![
                s[0],
                s[1],
                s[2],
                s[3],
                spare.is_some() as u64,
                self.flops,
                self.last_basis_cols as u64,
            ],
            aux_f: vec![spare.unwrap_or(0.0)],
            adjacency: None,
        })
    }

    fn restore_state(
        &mut self,
        st: crate::tracking::traits::TrackerState,
    ) -> anyhow::Result<()> {
        let (au, af) = (&st.aux_u, &st.aux_f);
        if au.len() != 7 || af.len() != 1 {
            anyhow::bail!("G-REST state layout mismatch ({} u64, {} f64)", au.len(), af.len());
        }
        let spare = if au[4] != 0 { Some(af[0]) } else { None };
        self.rng = Rng::from_state([au[0], au[1], au[2], au[3]], spare);
        self.flops = au[5];
        self.last_basis_cols = au[6] as usize;
        self.state = st.pairs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;
    use crate::tracking::traits::{apply_delta, init_eigenpairs};

    /// Heavy-tailed random graph: distinct, well-separated top
    /// eigenvalues (ring graphs have degenerate ± pairs that make
    /// per-vector angle tests ill-posed).
    fn ring_plus_chords(n: usize) -> Csr {
        let mut rng = Rng::new(n as u64);
        let w = crate::graph::generators::power_law_weights(n, 2.2, 3 * n);
        crate::graph::generators::chung_lu(&w, &mut rng).adjacency()
    }

    fn expansion_delta(n: usize, s: usize, seed: u64) -> Delta {
        let mut rng = Rng::new(seed);
        let mut kb = Coo::new(n, n);
        for _ in 0..n / 4 {
            let (u, v) = (rng.below(n), rng.below(n));
            if u != v {
                kb.push_sym(u, v, 1.0);
            }
        }
        let mut g = Coo::new(n, s);
        for j in 0..s {
            for _ in 0..3 {
                g.push(rng.below(n), j, 1.0);
            }
        }
        let mut c = Coo::new(s, s);
        if s >= 2 {
            c.push_sym(0, 1, 1.0);
        }
        // dedupe duplicates via csr round trip values>1 -> clamp to 1
        Delta::from_blocks(n, s, &kb.to_csr().to_coo_clamped(), &g.to_csr_clamped(), &c)
    }

    /// Pure-expansion delta: no topological (K-block) entries at all —
    /// every edge touches a new node.
    fn all_new_node_delta(n: usize, s: usize, seed: u64) -> Delta {
        let mut rng = Rng::new(seed);
        let kb = Coo::new(n, n);
        let mut g = Coo::new(n, s);
        for j in 0..s {
            for _ in 0..4 {
                g.push(rng.below(n), j, 1.0);
            }
        }
        let mut c = Coo::new(s, s);
        if s >= 2 {
            c.push_sym(0, 1, 1.0);
        }
        Delta::from_blocks(n, s, &kb, &g.to_csr_clamped(), &c)
    }

    // small helpers for the test above
    impl Csr {
        fn to_coo_clamped(&self) -> Coo {
            let mut coo = Coo::new(self.n_rows, self.n_cols);
            for i in 0..self.n_rows {
                let (cols, vals) = self.row(i);
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    coo.push(i, j, v.clamp(-1.0, 1.0));
                }
            }
            coo
        }
    }
    impl Coo {
        fn to_csr_clamped(&self) -> Coo {
            let csr = self.to_csr();
            let mut coo = Coo::new(self.rows, self.cols);
            for i in 0..csr.n_rows {
                let (cols, vals) = csr.row(i);
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    coo.push(i, j, v.clamp(-1.0, 1.0));
                }
            }
            coo
        }
    }

    fn angle(a: &[f64], b: &[f64]) -> f64 {
        let d = blas::dot(a, b).abs()
            / (blas::nrm2(a) * blas::nrm2(b)).max(1e-300);
        d.min(1.0).acos()
    }

    #[test]
    fn zero_delta_is_exact_fixed_point() {
        let a = ring_plus_chords(16);
        let init = init_eigenpairs(&a, 4, 1);
        let vals0 = init.values.clone();
        for mode in [SubspaceMode::Rm, SubspaceMode::Full, SubspaceMode::Rsvd { l: 4, p: 2 }] {
            let mut t = GRest::new(init.clone(), mode);
            let d = Delta::from_blocks(16, 0, &Coo::new(16, 16), &Coo::new(16, 0), &Coo::new(0, 0));
            t.update(&d).unwrap();
            for (a, b) in t.current().values.iter().zip(vals0.iter()) {
                assert!((a - b).abs() < 1e-8, "{mode:?}");
            }
        }
    }

    #[test]
    fn grest3_beats_grest2_on_expansion() {
        // paper headline: the Δ₂ block matters when nodes are added
        let a = ring_plus_chords(40);
        let init = init_eigenpairs(&a, 5, 2);
        let d = expansion_delta(40, 6, 3);
        let exact = crate::linalg::eigh::eigh(&apply_delta(&a, &d).to_dense());
        let order = exact.leading_by_magnitude(5);
        let mut t2 = GRest::new(init.clone(), SubspaceMode::Rm);
        let mut t3 = GRest::new(init, SubspaceMode::Full);
        t2.update(&d).unwrap();
        t3.update(&d).unwrap();
        let mut sum2 = 0.0;
        let mut sum3 = 0.0;
        for j in 0..5 {
            sum2 += angle(t2.current().vectors.col(j), exact.vectors.col(order[j]));
            sum3 += angle(t3.current().vectors.col(j), exact.vectors.col(order[j]));
        }
        assert!(
            sum3 <= sum2 + 1e-9,
            "G-REST3 total angle {sum3} vs G-REST2 {sum2}"
        );
    }

    #[test]
    fn grest3_single_step_high_accuracy() {
        let a = ring_plus_chords(30);
        let init = init_eigenpairs(&a, 6, 4);
        let d = expansion_delta(30, 4, 5);
        let exact = crate::linalg::eigh::eigh(&apply_delta(&a, &d).to_dense());
        let order = exact.leading_by_magnitude(3);
        let mut t3 = GRest::new(init, SubspaceMode::Full);
        t3.update(&d).unwrap();
        for j in 0..3 {
            let psi = angle(t3.current().vectors.col(j), exact.vectors.col(order[j]));
            assert!(psi < 0.2, "ψ_{j} = {psi}");
        }
    }

    #[test]
    fn rsvd_close_to_full_when_rank_covered() {
        // rank(Δ₂) small ⇒ RSVD with L+P ≥ rank reproduces G-REST3
        let a = ring_plus_chords(30);
        let init = init_eigenpairs(&a, 4, 6);
        let d = expansion_delta(30, 3, 7); // Δ₂ has ≤ 3+3 nonzero cols
        let mut t3 = GRest::new(init.clone(), SubspaceMode::Full);
        let mut tr = GRest::new(init, SubspaceMode::Rsvd { l: 8, p: 4 });
        t3.update(&d).unwrap();
        tr.update(&d).unwrap();
        for j in 0..4 {
            assert!(
                (t3.current().values[j] - tr.current().values[j]).abs() < 1e-6,
                "λ{j}: {} vs {}",
                t3.current().values[j],
                tr.current().values[j]
            );
        }
    }

    #[test]
    fn output_orthonormal() {
        let a = ring_plus_chords(24);
        let init = init_eigenpairs(&a, 4, 8);
        let mut t = GRest::new(init, SubspaceMode::Full);
        let d = expansion_delta(24, 3, 9);
        t.update(&d).unwrap();
        let v = &t.current().vectors;
        let g = v.t_matmul(v);
        let mut eye = Mat::eye(4);
        eye.axpy(-1.0, &g);
        assert!(eye.max_abs() < 1e-8);
    }

    #[test]
    fn results_bitwise_stable_across_thread_counts() {
        // the determinism contract of --threads: column-partitioned
        // parallelism never changes any reduction order, so single- and
        // multi-threaded runs agree to the last bit.  Sized so the dense
        // kernels actually cross the parallel threshold.
        let a = ring_plus_chords(2000);
        let init = init_eigenpairs(&a, 32, 11);
        let d = expansion_delta(2000, 8, 12);
        let mut t1 = GRest::with_threads(init.clone(), SubspaceMode::Full, Threads(1));
        let mut tn = GRest::with_threads(init, SubspaceMode::Full, Threads(4));
        t1.update(&d).unwrap();
        tn.update(&d).unwrap();
        assert_eq!(t1.current().values, tn.current().values);
        assert_eq!(
            t1.current().vectors.as_slice(),
            tn.current().vectors.as_slice(),
            "eigenvectors drifted across thread counts"
        );
    }

    #[test]
    fn rsvd_results_bitwise_stable_across_thread_counts() {
        // same contract for the randomized pipeline: the sketch is
        // seeded identically and every kernel it touches (sparse Δ₂
        // products, project-out, CholQR, the small SVD) keeps its
        // reduction orders under any worker count.
        let a = ring_plus_chords(2000);
        let init = init_eigenpairs(&a, 32, 11);
        let d = expansion_delta(2000, 8, 12);
        let mode = SubspaceMode::Rsvd { l: 6, p: 4 };
        let mut t1 = GRest::with_threads(init.clone(), mode, Threads(1));
        let mut tn = GRest::with_threads(init, mode, Threads(4));
        t1.update(&d).unwrap();
        tn.update(&d).unwrap();
        assert_eq!(t1.current().values, tn.current().values);
        assert_eq!(
            t1.current().vectors.as_slice(),
            tn.current().vectors.as_slice(),
            "RSVD eigenvectors drifted across thread counts"
        );
    }

    #[test]
    fn padded_pipeline_bitwise_matches_materialized_oracle() {
        // the tentpole contract end-to-end: the Padded-view pipeline
        // equals the pad_rows oracle to the last bit — over expansion,
        // pure-expansion (no K block), and edge-only (extra_rows == 0)
        // deltas, across thread counts, and across consecutive steps
        // (exercising warmed-workspace buffer reuse).
        let a = ring_plus_chords(40);
        let init = init_eigenpairs(&a, 5, 31);
        let deltas = [
            expansion_delta(40, 6, 32),
            all_new_node_delta(46, 5, 33),
            expansion_delta(51, 0, 34), // edge-only: extra_rows == 0
        ];
        for &workers in &[1usize, 4] {
            let mut tp = GRest::with_threads(init.clone(), SubspaceMode::Full, Threads(workers));
            let mut tm = GRest::with_phases(
                init.clone(),
                SubspaceMode::Full,
                MaterializedPhases(NativePhases::new(Threads(workers))),
                0x9E57,
            );
            for (step, d) in deltas.iter().enumerate() {
                tp.update(d).unwrap();
                tm.update(d).unwrap();
                assert_eq!(
                    tp.current().values,
                    tm.current().values,
                    "values drifted at step {step} (threads {workers})"
                );
                assert_eq!(
                    tp.current().vectors.as_slice(),
                    tm.current().vectors.as_slice(),
                    "vectors drifted at step {step} (threads {workers})"
                );
            }
        }
    }

    #[test]
    fn reset_state_restores_initial_in_place() {
        let a = ring_plus_chords(20);
        let init = init_eigenpairs(&a, 3, 41);
        let mut t = GRest::new(init.clone(), SubspaceMode::Full);
        let d = expansion_delta(20, 3, 42);
        t.update(&d).unwrap();
        assert_eq!(t.current().n(), 23);
        t.reset_state(&init);
        assert_eq!(t.current().values, init.values);
        assert_eq!(t.current().vectors.as_slice(), init.vectors.as_slice());
        // the tracker still updates correctly from the restored state
        t.update(&d).unwrap();
        assert_eq!(t.current().n(), 23);
    }

    #[test]
    fn reset_state_replays_rsvd_trajectory_bitwise() {
        // reset must also rewind the RNG: a reset RSVD tracker replays
        // the exact same randomized sketch and trajectory
        let a = ring_plus_chords(20);
        let init = init_eigenpairs(&a, 3, 43);
        let d = expansion_delta(20, 3, 44);
        let mut t = GRest::new(init.clone(), SubspaceMode::Rsvd { l: 3, p: 2 });
        t.update(&d).unwrap();
        let first_vals = t.current().values.clone();
        let first_vecs = t.current().vectors.clone();
        t.reset_state(&init);
        t.update(&d).unwrap();
        assert_eq!(t.current().values, first_vals);
        assert_eq!(t.current().vectors.as_slice(), first_vecs.as_slice());
    }

    #[test]
    fn flop_counter_charges_padded_products_at_filled_rows() {
        // satellite: the Mflop columns must reflect the padding-aware
        // cost — X̄-touching products run at n_old rows, not padded n
        let a = ring_plus_chords(60);
        let init = init_eigenpairs(&a, 6, 21);
        let (n_old, s, k) = (60usize, 20usize, 6usize);
        let d = expansion_delta(n_old, s, 22); // expansion-heavy: S = n/3
        let mut t = GRest::new(init, SubspaceMode::Full);
        t.update(&d).unwrap();
        let n = n_old + s;
        let m = k + s;
        let qc = t.last_basis_cols;
        assert!(qc > 0);
        let sparse = 2 * d.nnz() as u64 * (k + qc) as u64;
        // the pre-fix counter charged every X̄ product at padded height n
        let padded_model = (2 * n * k * m
            + 2 * n * m * m
            + n * k * k
            + 2 * n * k * qc
            + n * qc * qc
            + (k + qc).pow(3)
            + 2 * n * k * k
            + 2 * n * qc * k) as u64
            + sparse;
        let aware_model = (2 * n_old * k * m
            + 2 * n * m * m
            + n_old * k * k
            + 2 * n_old * k * qc
            + n * qc * qc
            + (k + qc).pow(3)
            + 2 * n_old * k * k
            + 2 * n * qc * k) as u64
            + sparse;
        assert_eq!(t.last_step_flops(), aware_model);
        assert!(
            t.last_step_flops() < padded_model,
            "{} !< {}",
            t.last_step_flops(),
            padded_model
        );
    }

    #[test]
    fn multi_step_stays_accurate() {
        // track K=6 so the subspace has slack; judge the top pair only
        // (deeper pairs legitimately drift under heavy cumulative churn).
        let mut a = ring_plus_chords(30);
        let init = init_eigenpairs(&a, 6, 10);
        let mut t = GRest::new(init, SubspaceMode::Full);
        for step in 0..5 {
            let d = expansion_delta(a.n_rows, 2, 100 + step);
            t.update(&d).unwrap();
            a = apply_delta(&a, &d);
        }
        let exact = crate::linalg::eigh::eigh(&a.to_dense());
        let order = exact.leading_by_magnitude(1);
        let psi = angle(t.current().vectors.col(0), exact.vectors.col(order[0]));
        assert!(psi < 0.3, "after 5 steps ψ_0 = {psi}");
    }
}
