//! G-REST — Graph Rayleigh-Ritz Eigenspace Tracking (paper Alg. 2).
//!
//! One update step (time t → t+1):
//!
//! 1. Receive Δ; pad X_K with S zero rows → X̄_K.
//! 2. Assemble the update panel
//!      * G-REST₂:     [ΔX̄_K]                      (Residual-Modes span)
//!      * G-REST₃:     [ΔX̄_K, Δ₂]                  (proposed, Eq. 11)
//!      * G-REST_RSVD: [ΔX̄_K, R] with R the L-rank randomized basis of
//!        (I−X̄X̄ᵀ)Δ₂                               (Sec. 3.5)
//! 3. `build_basis`: Q = orth((I − X̄X̄ᵀ)·panel).
//! 4. Sparse product ΔQ (here, in Rust — the only nnz(Δ)-cost step).
//! 5. `form_t`: T = Zᵀ(X̄ΛX̄ᵀ)Z + ZᵀΔZ over Z = [X̄, Q]  (Eq. 13).
//! 6. Small dense eigh of T; keep the K leading Ritz pairs by |θ|.
//! 7. `rotate`: X_new = X̄F₁ + QF₂,  Λ_new = Θ.
//!
//! Steps 3/5/7 are the dense phases behind the [`DensePhases`] trait:
//! [`NativePhases`] runs them with the in-crate kernels; the `runtime`
//! module provides an implementation that executes the AOT-compiled
//! JAX/Pallas artifacts on PJRT instead (same contract, tested equal).

use crate::linalg::blas;
use crate::linalg::eigh::eigh;
use crate::linalg::mat::Mat;
use crate::linalg::qr::orthonormalize_against_with;
use crate::linalg::rng::Rng;
use crate::linalg::threads::Threads;
use crate::linalg::rsvd::rsvd_basis;
use crate::sparse::delta::Delta;
use crate::tracking::spec::{Algo, Backend, TrackerSpec};
use crate::tracking::traits::{EigTracker, EigenPairs};

/// Projection-subspace construction (Table 1 of the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SubspaceMode {
    /// G-REST₂ — the Residual Modes subspace, optimal coefficients.
    Rm,
    /// G-REST₃ — proposed subspace with the explicit Δ₂ block (Eq. 11).
    Full,
    /// G-REST_RSVD — Δ₂ compressed by the randomized range finder.
    Rsvd { l: usize, p: usize },
}

impl SubspaceMode {
    pub fn label(&self) -> String {
        match self {
            SubspaceMode::Rm => "G-REST2".into(),
            SubspaceMode::Full => "G-REST3".into(),
            SubspaceMode::Rsvd { .. } => "G-REST-RSVD".into(),
        }
    }
}

/// The three dense phases of one G-REST step.  Implemented natively here
/// and by `runtime::grest_xla::XlaPhases` over the PJRT artifacts.
pub trait DensePhases {
    /// Orthonormal basis of (I − X̄X̄ᵀ)·panel, rank-deficient columns
    /// deflated.
    fn build_basis(&self, xbar: &Mat, panel: &Mat) -> Mat;

    /// The projected matrix of Eq. (13) for Z = [X̄, Q].
    fn form_t(&self, xbar: &Mat, q: &Mat, lam: &[f64], dxk: &Mat, dq: &Mat) -> Mat;

    /// Ritz rotation X_new = X̄ F₁ + Q F₂.
    fn rotate(&self, xbar: &Mat, q: &Mat, f1: &Mat, f2: &Mat) -> Mat;

    fn label(&self) -> &'static str {
        "native"
    }

    /// Backend this implementation represents (for tracker descriptors).
    fn backend(&self) -> Backend {
        Backend::Native
    }

    /// Worker-thread budget used by the dense kernels, when meaningful.
    fn threads(&self) -> Threads {
        Threads::AUTO
    }

    /// XLA tier capacities (rows, panel cols) backing this
    /// implementation; `(0, 0)` for backends without fixed tiers.
    fn tier_caps(&self) -> (usize, usize) {
        (0, 0)
    }
}

/// Shared-ownership backends (lets many tracker instances reuse one
/// compiled-artifact cache within a thread).
impl<P: DensePhases + ?Sized> DensePhases for std::rc::Rc<P> {
    fn build_basis(&self, xbar: &Mat, panel: &Mat) -> Mat {
        (**self).build_basis(xbar, panel)
    }
    fn form_t(&self, xbar: &Mat, q: &Mat, lam: &[f64], dxk: &Mat, dq: &Mat) -> Mat {
        (**self).form_t(xbar, q, lam, dxk, dq)
    }
    fn rotate(&self, xbar: &Mat, q: &Mat, f1: &Mat, f2: &Mat) -> Mat {
        (**self).rotate(xbar, q, f1, f2)
    }
    fn label(&self) -> &'static str {
        (**self).label()
    }
    fn backend(&self) -> Backend {
        (**self).backend()
    }
    fn threads(&self) -> Threads {
        (**self).threads()
    }
    fn tier_caps(&self) -> (usize, usize) {
        (**self).tier_caps()
    }
}

/// Pure-Rust dense phases (mirrors python/compile/model.py), carrying the
/// worker-thread budget for the blocked kernel layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativePhases {
    pub threads: Threads,
}

impl NativePhases {
    pub fn new(threads: Threads) -> NativePhases {
        NativePhases { threads }
    }
}

impl DensePhases for NativePhases {
    fn build_basis(&self, xbar: &Mat, panel: &Mat) -> Mat {
        let (q, _) = orthonormalize_against_with(xbar, panel, 1e-8, self.threads);
        q
    }

    fn threads(&self) -> Threads {
        self.threads
    }

    fn form_t(&self, xbar: &Mat, q: &Mat, lam: &[f64], dxk: &Mat, dq: &Mat) -> Mat {
        let k = xbar.cols();
        let m = q.cols();
        let dim = k + m;
        let mut t = Mat::zeros(dim, dim);
        // T11 = Λ + X̄ᵀ(ΔX̄).  X̄ᵀΔX̄ is analytically symmetric (Δᵀ = Δ),
        // so only the upper triangle is computed — half the flops of the
        // full K×K product the unspecialized pipeline paid.
        let t11 = xbar.sym_t_matmul_with(dxk, self.threads);
        for i in 0..k {
            for j in 0..k {
                let lamij = if i == j { lam[i] } else { 0.0 };
                t.set(i, j, lamij + t11.get(i, j));
            }
        }
        // T12 = X̄ᵀ(ΔQ) — genuinely rectangular, full product.
        let t12 = xbar.t_matmul_with(dq, self.threads);
        for i in 0..k {
            for j in 0..m {
                t.set(i, k + j, t12.get(i, j));
                t.set(k + j, i, t12.get(i, j));
            }
        }
        // T22 = Qᵀ(ΔQ) — symmetric for the same reason as T11.
        let t22 = q.sym_t_matmul_with(dq, self.threads);
        for i in 0..m {
            for j in 0..m {
                t.set(k + i, k + j, t22.get(i, j));
            }
        }
        t
    }

    fn rotate(&self, xbar: &Mat, q: &Mat, f1: &Mat, f2: &Mat) -> Mat {
        let mut out = xbar.matmul_with(f1, self.threads);
        blas::gemm_acc_with(&mut out, q, f2, 1.0, self.threads);
        out
    }
}

/// The G-REST tracker (Alg. 2).
pub struct GRest<P: DensePhases = NativePhases> {
    state: EigenPairs,
    pub mode: SubspaceMode,
    phases: P,
    rng: Rng,
    seed: u64,
    flops: u64,
    /// dimension of the last augmentation basis (diagnostics)
    pub last_basis_cols: usize,
}

impl GRest<NativePhases> {
    /// Native-backend tracker (auto thread budget).
    pub fn new(initial: EigenPairs, mode: SubspaceMode) -> Self {
        GRest::with_threads(initial, mode, Threads::AUTO)
    }

    /// Native-backend tracker with an explicit worker-thread budget for
    /// the dense phases.
    pub fn with_threads(initial: EigenPairs, mode: SubspaceMode, threads: Threads) -> Self {
        GRest::with_phases(initial, mode, NativePhases::new(threads), 0x9E57)
    }
}

impl<P: DensePhases> GRest<P> {
    pub fn with_phases(initial: EigenPairs, mode: SubspaceMode, phases: P, seed: u64) -> Self {
        GRest {
            state: initial,
            mode,
            phases,
            rng: Rng::new(seed),
            seed,
            flops: 0,
            last_basis_cols: 0,
        }
    }

    /// Assemble the update panel for the configured subspace mode.
    fn panel(&mut self, delta: &Delta, dxk: &Mat) -> Mat {
        let threads = self.phases.threads();
        match self.mode {
            SubspaceMode::Rm => dxk.clone(),
            SubspaceMode::Full => {
                if delta.s_new == 0 {
                    dxk.clone()
                } else {
                    dxk.hcat(&delta.d2_dense())
                }
            }
            SubspaceMode::Rsvd { l, p } => {
                if delta.s_new == 0 {
                    dxk.clone()
                } else {
                    let xbar = self.state.vectors.pad_rows(delta.s_new);
                    let r = rsvd_basis(
                        delta.s_new,
                        &|om| delta.d2_mult_with(om, threads),
                        &|m| delta.d2_t_mult_with(m, threads),
                        Some(&xbar),
                        l,
                        p,
                        &mut self.rng,
                    );
                    if r.cols() == 0 {
                        dxk.clone()
                    } else {
                        dxk.hcat(&r)
                    }
                }
            }
        }
    }
}

impl<P: DensePhases> EigTracker for GRest<P> {
    fn descriptor(&self) -> TrackerSpec {
        let algo = match self.mode {
            SubspaceMode::Rm => Algo::Grest2,
            SubspaceMode::Full => Algo::Grest3,
            SubspaceMode::Rsvd { l, p } => Algo::GrestRsvd { l, p },
        };
        let mut spec = TrackerSpec::new(algo)
            .with_backend(self.phases.backend())
            .with_threads(self.phases.threads())
            .with_seed(self.seed);
        (spec.n_cap, spec.panel_cap) = self.phases.tier_caps();
        spec
    }

    fn update(&mut self, delta: &Delta) -> anyhow::Result<()> {
        let k = self.state.k();
        let threads = self.phases.threads();
        let xbar = self.state.vectors.pad_rows(delta.s_new); // X̄_K
        let dxk = delta.mul_padded_with(&self.state.vectors, threads); // ΔX̄_K
        let panel = self.panel(delta, &dxk);
        let n = xbar.rows();

        // dense phase 1: orthonormal augmentation basis
        let q = self.phases.build_basis(&xbar, &panel);
        self.last_basis_cols = q.cols();

        // sparse interlude: ΔQ — row-partitioned under the same budget
        let dq = delta.matmul_dense_with(&q, threads);

        // dense phase 2a: projected matrix (Eq. 13)
        let t = self.phases.form_t(&xbar, &q, &self.state.values, &dxk, &dq);

        // small dense eigendecomposition (Alg. 2 line 9)
        let e = eigh(&t);
        let order = e.leading_by_magnitude(k);
        let mut f1 = Mat::zeros(k, order.len());
        let mut f2 = Mat::zeros(q.cols(), order.len());
        let mut new_vals = Vec::with_capacity(order.len());
        for (c, &idx) in order.iter().enumerate() {
            new_vals.push(e.values[idx]);
            for i in 0..k {
                f1.set(i, c, e.vectors.get(i, idx));
            }
            for i in 0..q.cols() {
                f2.set(i, c, e.vectors.get(k + i, idx));
            }
        }

        // dense phase 2b: Ritz rotation
        let new_vecs = self.phases.rotate(&xbar, &q, &f1, &f2);

        let m = panel.cols();
        self.flops = (2 * n * k * m          // project-out gram
            + 2 * n * m * m                   // orthonormalization
            + n * (k + m) * (k + m)           // form_t grams (symmetric: half)
            + (k + m) * (k + m) * (k + m)     // eigh
            + 2 * n * (k + m) * k) as u64 // rotate
            + 2 * delta.nnz() as u64 * (k + m) as u64;
        self.state = EigenPairs { values: new_vals, vectors: new_vecs };
        Ok(())
    }

    fn current(&self) -> &EigenPairs {
        &self.state
    }

    fn last_step_flops(&self) -> u64 {
        self.flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;
    use crate::tracking::traits::{apply_delta, init_eigenpairs};

    /// Heavy-tailed random graph: distinct, well-separated top
    /// eigenvalues (ring graphs have degenerate ± pairs that make
    /// per-vector angle tests ill-posed).
    fn ring_plus_chords(n: usize) -> Csr {
        let mut rng = Rng::new(n as u64);
        let w = crate::graph::generators::power_law_weights(n, 2.2, 3 * n);
        crate::graph::generators::chung_lu(&w, &mut rng).adjacency()
    }

    fn expansion_delta(n: usize, s: usize, seed: u64) -> Delta {
        let mut rng = Rng::new(seed);
        let mut kb = Coo::new(n, n);
        for _ in 0..n / 4 {
            let (u, v) = (rng.below(n), rng.below(n));
            if u != v {
                kb.push_sym(u, v, 1.0);
            }
        }
        let mut g = Coo::new(n, s);
        for j in 0..s {
            for _ in 0..3 {
                g.push(rng.below(n), j, 1.0);
            }
        }
        let mut c = Coo::new(s, s);
        if s >= 2 {
            c.push_sym(0, 1, 1.0);
        }
        // dedupe duplicates via csr round trip values>1 -> clamp to 1
        Delta::from_blocks(n, s, &kb.to_csr().to_coo_clamped(), &g.to_csr_clamped(), &c)
    }

    // small helpers for the test above
    impl Csr {
        fn to_coo_clamped(&self) -> Coo {
            let mut coo = Coo::new(self.n_rows, self.n_cols);
            for i in 0..self.n_rows {
                let (cols, vals) = self.row(i);
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    coo.push(i, j, v.clamp(-1.0, 1.0));
                }
            }
            coo
        }
    }
    impl Coo {
        fn to_csr_clamped(&self) -> Coo {
            let csr = self.to_csr();
            let mut coo = Coo::new(self.rows, self.cols);
            for i in 0..csr.n_rows {
                let (cols, vals) = csr.row(i);
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    coo.push(i, j, v.clamp(-1.0, 1.0));
                }
            }
            coo
        }
    }

    fn angle(a: &[f64], b: &[f64]) -> f64 {
        let d = blas::dot(a, b).abs()
            / (blas::nrm2(a) * blas::nrm2(b)).max(1e-300);
        d.min(1.0).acos()
    }

    #[test]
    fn zero_delta_is_exact_fixed_point() {
        let a = ring_plus_chords(16);
        let init = init_eigenpairs(&a, 4, 1);
        let vals0 = init.values.clone();
        for mode in [SubspaceMode::Rm, SubspaceMode::Full, SubspaceMode::Rsvd { l: 4, p: 2 }] {
            let mut t = GRest::new(init.clone(), mode);
            let d = Delta::from_blocks(16, 0, &Coo::new(16, 16), &Coo::new(16, 0), &Coo::new(0, 0));
            t.update(&d).unwrap();
            for (a, b) in t.current().values.iter().zip(vals0.iter()) {
                assert!((a - b).abs() < 1e-8, "{mode:?}");
            }
        }
    }

    #[test]
    fn grest3_beats_grest2_on_expansion() {
        // paper headline: the Δ₂ block matters when nodes are added
        let a = ring_plus_chords(40);
        let init = init_eigenpairs(&a, 5, 2);
        let d = expansion_delta(40, 6, 3);
        let exact = crate::linalg::eigh::eigh(&apply_delta(&a, &d).to_dense());
        let order = exact.leading_by_magnitude(5);
        let mut t2 = GRest::new(init.clone(), SubspaceMode::Rm);
        let mut t3 = GRest::new(init, SubspaceMode::Full);
        t2.update(&d).unwrap();
        t3.update(&d).unwrap();
        let mut sum2 = 0.0;
        let mut sum3 = 0.0;
        for j in 0..5 {
            sum2 += angle(t2.current().vectors.col(j), exact.vectors.col(order[j]));
            sum3 += angle(t3.current().vectors.col(j), exact.vectors.col(order[j]));
        }
        assert!(
            sum3 <= sum2 + 1e-9,
            "G-REST3 total angle {sum3} vs G-REST2 {sum2}"
        );
    }

    #[test]
    fn grest3_single_step_high_accuracy() {
        let a = ring_plus_chords(30);
        let init = init_eigenpairs(&a, 6, 4);
        let d = expansion_delta(30, 4, 5);
        let exact = crate::linalg::eigh::eigh(&apply_delta(&a, &d).to_dense());
        let order = exact.leading_by_magnitude(3);
        let mut t3 = GRest::new(init, SubspaceMode::Full);
        t3.update(&d).unwrap();
        for j in 0..3 {
            let psi = angle(t3.current().vectors.col(j), exact.vectors.col(order[j]));
            assert!(psi < 0.2, "ψ_{j} = {psi}");
        }
    }

    #[test]
    fn rsvd_close_to_full_when_rank_covered() {
        // rank(Δ₂) small ⇒ RSVD with L+P ≥ rank reproduces G-REST3
        let a = ring_plus_chords(30);
        let init = init_eigenpairs(&a, 4, 6);
        let d = expansion_delta(30, 3, 7); // Δ₂ has ≤ 3+3 nonzero cols
        let mut t3 = GRest::new(init.clone(), SubspaceMode::Full);
        let mut tr = GRest::new(init, SubspaceMode::Rsvd { l: 8, p: 4 });
        t3.update(&d).unwrap();
        tr.update(&d).unwrap();
        for j in 0..4 {
            assert!(
                (t3.current().values[j] - tr.current().values[j]).abs() < 1e-6,
                "λ{j}: {} vs {}",
                t3.current().values[j],
                tr.current().values[j]
            );
        }
    }

    #[test]
    fn output_orthonormal() {
        let a = ring_plus_chords(24);
        let init = init_eigenpairs(&a, 4, 8);
        let mut t = GRest::new(init, SubspaceMode::Full);
        let d = expansion_delta(24, 3, 9);
        t.update(&d).unwrap();
        let v = &t.current().vectors;
        let g = v.t_matmul(v);
        let mut eye = Mat::eye(4);
        eye.axpy(-1.0, &g);
        assert!(eye.max_abs() < 1e-8);
    }

    #[test]
    fn results_bitwise_stable_across_thread_counts() {
        // the determinism contract of --threads: column-partitioned
        // parallelism never changes any reduction order, so single- and
        // multi-threaded runs agree to the last bit.  Sized so the dense
        // kernels actually cross the parallel threshold.
        let a = ring_plus_chords(2000);
        let init = init_eigenpairs(&a, 32, 11);
        let d = expansion_delta(2000, 8, 12);
        let mut t1 = GRest::with_threads(init.clone(), SubspaceMode::Full, Threads(1));
        let mut tn = GRest::with_threads(init, SubspaceMode::Full, Threads(4));
        t1.update(&d).unwrap();
        tn.update(&d).unwrap();
        assert_eq!(t1.current().values, tn.current().values);
        assert_eq!(
            t1.current().vectors.as_slice(),
            tn.current().vectors.as_slice(),
            "eigenvectors drifted across thread counts"
        );
    }

    #[test]
    fn multi_step_stays_accurate() {
        // track K=6 so the subspace has slack; judge the top pair only
        // (deeper pairs legitimately drift under heavy cumulative churn).
        let mut a = ring_plus_chords(30);
        let init = init_eigenpairs(&a, 6, 10);
        let mut t = GRest::new(init, SubspaceMode::Full);
        for step in 0..5 {
            let d = expansion_delta(a.n_rows, 2, 100 + step);
            t.update(&d).unwrap();
            a = apply_delta(&a, &d);
        }
        let exact = crate::linalg::eigh::eigh(&a.to_dense());
        let order = exact.leading_by_magnitude(1);
        let psi = angle(t.current().vectors.col(0), exact.vectors.col(order[0]));
        assert!(psi < 0.3, "after 5 steps ψ_0 = {psi}");
    }
}
