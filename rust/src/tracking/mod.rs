//! Eigenpair tracking algorithms.
//!
//! Baselines from the literature (Sec. 2.3 of the paper): TRIP-Basic,
//! TRIP, Residual Modes, IASC, TIMERS; the proposed Rayleigh-Ritz family
//! G-REST₂ / G-REST₃ / G-REST_RSVD (Alg. 2); a full-recompute reference
//! (`eigs` stand-in); and the Laplacian / matrix-function extensions of
//! Sec. 4.

pub mod grest;
pub mod iasc;
pub mod laplacian;
pub mod matfun;
pub mod reference;
pub mod residual_modes;
pub mod spec;
pub mod timers;
pub mod traits;
pub mod trip;
pub mod trip_basic;

pub use grest::{GRest, SubspaceMode};
pub use spec::{Algo, Backend, TrackerSpec};
pub use traits::{init_eigenpairs, EigTracker, EigenPairs};
