//! Declarative tracker specification — the single description of every
//! tracker the crate knows how to build.
//!
//! A [`TrackerSpec`] names an algorithm (with its per-algorithm knobs),
//! an execution [`Backend`] for the dense phases, a worker-thread
//! budget, and an optional seed.  It serializes to and from a compact
//! string grammar:
//!
//! ```text
//! spec     := name [":" params] ["@" backend]
//! params   := key "=" value ("," key "=" value)*
//! backend  := "native" | "xla"
//! ```
//!
//! Examples: `grest3`, `grest-rsvd:l=32,p=16`, `timers:theta=0.01`,
//! `grest3@xla`, `grest3:threads=4,seed=9`.
//!
//! Every construction site in the crate — the CLI, the experiment
//! harness, the coordinator service, the per-figure drivers — goes
//! through [`TrackerSpec::build`], and every tracker reports its own
//! spec back via [`crate::tracking::traits::EigTracker::descriptor`],
//! so table rows, CSV keys, and service metrics all derive names from
//! one source.  The [`registry`] enumerates the known algorithms with
//! their aliases (including every legacy `--tracker` name).

use crate::linalg::threads::Threads;
use crate::sparse::csr::Csr;
use crate::tracking::grest::{GRest, NativePhases, SubspaceMode};
use crate::tracking::iasc::Iasc;
use crate::tracking::reference::Reference;
use crate::tracking::residual_modes::ResidualModes;
use crate::tracking::timers::Timers;
use crate::tracking::traits::{EigTracker, EigenPairs};
use crate::tracking::trip::Trip;
use crate::tracking::trip_basic::TripBasic;
use anyhow::{anyhow, bail, Result};
use std::fmt;

/// Seed used when neither the spec nor the caller supplies one (the
/// historical default of the direct `GRest` constructors).
pub const DEFAULT_SEED: u64 = 0x9E57;

/// Dense-phase execution backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// In-crate blocked/threaded kernels.
    Native,
    /// AOT-compiled JAX/Pallas artifacts on PJRT (G-REST family only;
    /// requires the `xla` cargo feature and built artifacts).
    Xla,
}

impl Backend {
    pub fn label(self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Xla => "xla",
        }
    }
}

/// Algorithm plus its per-algorithm parameters (paper Sec. 2.3 / Alg. 2).
#[derive(Clone, Debug, PartialEq)]
pub enum Algo {
    /// First-order perturbation, Eqs. (5)-(6) (Chen & Tong 2015).
    TripBasic,
    /// TRIP: coefficients from the K×K system of Eq. (7).
    Trip,
    /// Residual Modes with untracked-spectrum stand-in `mu`.
    Rm { mu: f64 },
    /// IASC: Rayleigh-Ritz over [X̄, identity on new nodes].
    Iasc,
    /// TIMERS: IASC with error-bounded restarts.
    Timers { theta: f64, min_gap: usize },
    /// G-REST₂ — Residual-Modes subspace.
    Grest2,
    /// G-REST₃ — proposed subspace with the explicit Δ₂ block (Eq. 11).
    Grest3,
    /// G-REST_RSVD — Δ₂ compressed by the randomized range finder.
    GrestRsvd { l: usize, p: usize },
    /// Full Lanczos recompute at every step (the `eigs` baseline).
    Eigs,
    /// Escape hatch for ad-hoc trackers built outside the registry
    /// (closure factories, test doubles).  Carries only a display name;
    /// neither parseable nor buildable.
    Custom(String),
}

impl Algo {
    /// True for the G-REST family (the algorithms with dense phases, the
    /// only consumers of the `threads` budget and the XLA backend).
    pub fn is_grest(&self) -> bool {
        matches!(self, Algo::Grest2 | Algo::Grest3 | Algo::GrestRsvd { .. })
    }

    /// Canonical grammar name (lower-case, parseable).
    pub fn canonical_name(&self) -> &str {
        match self {
            Algo::TripBasic => "trip-basic",
            Algo::Trip => "trip",
            Algo::Rm { .. } => "rm",
            Algo::Iasc => "iasc",
            Algo::Timers { .. } => "timers",
            Algo::Grest2 => "grest2",
            Algo::Grest3 => "grest3",
            Algo::GrestRsvd { .. } => "grest-rsvd",
            Algo::Eigs => "eigs",
            Algo::Custom(name) => name,
        }
    }
}

/// Declarative, serializable description of one tracker instance.
#[derive(Clone, Debug, PartialEq)]
pub struct TrackerSpec {
    pub algo: Algo,
    pub backend: Backend,
    /// Dense-kernel worker budget (G-REST family; ignored elsewhere).
    pub threads: Threads,
    /// Tracker seed; `None` defers to the build-site fallback.
    pub seed: Option<u64>,
    /// XLA tier row capacity (0 = size from the initial adjacency).
    pub n_cap: usize,
    /// XLA tier panel-column capacity (0 = K + 128).
    pub panel_cap: usize,
    /// XLA artifact directory override (builder-only — paths don't fit
    /// the string grammar; `None` resolves `$GREST_ARTIFACTS` /
    /// `./artifacts` via `ArtifactManifest::load_default`).
    pub artifacts_dir: Option<std::path::PathBuf>,
}

impl TrackerSpec {
    pub fn new(algo: Algo) -> TrackerSpec {
        TrackerSpec {
            algo,
            backend: Backend::Native,
            threads: Threads::AUTO,
            seed: None,
            n_cap: 0,
            panel_cap: 0,
            artifacts_dir: None,
        }
    }

    /// Spec for an ad-hoc tracker: display name only, not buildable.
    pub fn custom(name: &str) -> TrackerSpec {
        TrackerSpec::new(Algo::Custom(name.to_string()))
    }

    pub fn with_threads(mut self, threads: Threads) -> TrackerSpec {
        self.threads = threads;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> TrackerSpec {
        self.seed = Some(seed);
        self
    }

    pub fn with_backend(mut self, backend: Backend) -> TrackerSpec {
        self.backend = backend;
        self
    }

    /// Display name used by harness tables, CSV keys, and metrics.
    /// Algorithm-distinguishing parameters appear when they differ from
    /// the paper defaults, so parameter sweeps stay distinguishable
    /// (`TIMERS(theta=0.05)` vs `TIMERS`); the paper labels themselves
    /// are unchanged at the defaults.
    pub fn display_name(&self) -> String {
        let base = match &self.algo {
            Algo::TripBasic => "TRIP-Basic".to_string(),
            Algo::Trip => "TRIP".to_string(),
            Algo::Rm { mu } => {
                if *mu != 0.0 {
                    format!("RM(mu={mu})")
                } else {
                    "RM".to_string()
                }
            }
            Algo::Iasc => "IASC".to_string(),
            Algo::Timers { theta, min_gap } => {
                let mut ps: Vec<String> = Vec::new();
                if *theta != DEFAULT_TIMERS_THETA {
                    ps.push(format!("theta={theta}"));
                }
                if *min_gap != DEFAULT_TIMERS_GAP {
                    ps.push(format!("gap={min_gap}"));
                }
                if ps.is_empty() {
                    "TIMERS".to_string()
                } else {
                    format!("TIMERS({})", ps.join(","))
                }
            }
            Algo::Grest2 => "G-REST2".to_string(),
            Algo::Grest3 => "G-REST3".to_string(),
            Algo::GrestRsvd { l, p } => format!("G-REST-RSVD(L={l},P={p})"),
            Algo::Eigs => "eigs".to_string(),
            Algo::Custom(name) => name.clone(),
        };
        match self.backend {
            Backend::Native => base,
            Backend::Xla => format!("{base}@xla"),
        }
    }

    /// Parse the spec grammar (see the module docs).  Accepts every
    /// legacy `--tracker` name as an alias, case-insensitively.
    pub fn parse(text: &str) -> Result<TrackerSpec> {
        let trimmed = text.trim();
        if trimmed.is_empty() {
            bail!("empty tracker spec; expected name[:key=value,...][@backend]");
        }
        let (body, backend) = match trimmed.rsplit_once('@') {
            None => (trimmed, Backend::Native),
            Some((body, b)) => match b.to_ascii_lowercase().as_str() {
                "native" => (body, Backend::Native),
                "xla" => (body, Backend::Xla),
                other => bail!(
                    "unknown backend `{other}` in tracker spec `{trimmed}`; \
                     expected `native` or `xla`"
                ),
            },
        };
        let (name, params) = match body.split_once(':') {
            None => (body, None),
            Some((name, params)) => (name, Some(params)),
        };
        let mut spec = TrackerSpec::new(resolve_algo(name)?).with_backend(backend);
        if let Some(params) = params {
            for part in params.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let Some((key, value)) = part.split_once('=') else {
                    bail!(
                        "malformed parameter `{part}` in tracker spec `{trimmed}`: \
                         expected key=value"
                    );
                };
                apply_param(&mut spec, key.trim(), value.trim())
                    .map_err(|e| anyhow!("in tracker spec `{trimmed}`: {e}"))?;
            }
        }
        Ok(spec)
    }

    /// Check that [`build`](Self::build) can succeed in principle
    /// (cheap; does not touch artifacts or the graph).  Catches specs
    /// that can never work — custom specs, `@xla` outside the G-REST
    /// family, `@xla` in a binary built without the `xla` feature — so
    /// callers that defer building to another thread (the coordinator
    /// worker) fail fast instead of panicking there.
    pub fn validate_buildable(&self) -> Result<()> {
        match (&self.algo, self.backend) {
            (Algo::Custom(name), _) => bail!(
                "custom tracker `{name}` has no registered constructor; \
                 build it directly and use the closure escape hatch"
            ),
            (Algo::Grest2 | Algo::Grest3 | Algo::GrestRsvd { .. }, Backend::Xla) => {
                if cfg!(feature = "xla") {
                    Ok(())
                } else {
                    bail!(
                        "spec `{self}` requests the @xla backend, but this binary was \
                         built without the `xla` cargo feature; rebuild with \
                         `--features xla` or drop `@xla` for the native backend"
                    )
                }
            }
            (Algo::Grest2 | Algo::Grest3 | Algo::GrestRsvd { .. }, Backend::Native) => Ok(()),
            (_, Backend::Xla) => bail!(
                "the @xla backend only serves the G-REST family, not `{self}`"
            ),
            _ => Ok(()),
        }
    }

    /// Build the tracker for an initial adjacency and its precomputed
    /// leading eigenpairs, seeding from the spec or [`DEFAULT_SEED`].
    pub fn build(&self, a0: &Csr, init: &EigenPairs) -> Result<Box<dyn EigTracker>> {
        self.build_seeded(a0, init, DEFAULT_SEED)
    }

    /// [`build`](Self::build) with a caller-supplied fallback seed (an
    /// explicit `seed=` in the spec still wins).
    pub fn build_seeded(
        &self,
        a0: &Csr,
        init: &EigenPairs,
        fallback_seed: u64,
    ) -> Result<Box<dyn EigTracker>> {
        if self.backend == Backend::Xla {
            self.validate_buildable()?;
            let seed = self.seed.unwrap_or(fallback_seed);
            let mode = match &self.algo {
                Algo::Grest2 => SubspaceMode::Rm,
                Algo::Grest3 => SubspaceMode::Full,
                Algo::GrestRsvd { l, p } => SubspaceMode::Rsvd { l: *l, p: *p },
                // validate_buildable rejects @xla outside the G-REST family
                _ => unreachable!(),
            };
            let manifest = match &self.artifacts_dir {
                Some(dir) => crate::runtime::ArtifactManifest::load(dir)?,
                None => crate::runtime::ArtifactManifest::load_default()?,
            };
            let k = init.k();
            let n = if self.n_cap > 0 { self.n_cap } else { a0.n_rows };
            let m = if self.panel_cap > 0 { self.panel_cap } else { k + 128 };
            let phases = crate::runtime::XlaPhases::for_problem(manifest, n, k, m)?;
            return Ok(Box::new(GRest::with_phases(init.clone(), mode, phases, seed)));
        }
        let tracker: Box<dyn EigTracker> = self.build_seeded_send(a0, init, fallback_seed)?;
        Ok(tracker)
    }

    /// [`build_seeded`](Self::build_seeded) for the native backend only,
    /// returning a `Send` tracker that may hop between worker-pool
    /// threads.  `@xla` specs are rejected here: PJRT executable state
    /// is thread-bound, so XLA tenants must stay on a dedicated pinned
    /// thread (use `build_seeded` from that thread instead).
    pub fn build_seeded_send(
        &self,
        a0: &Csr,
        init: &EigenPairs,
        fallback_seed: u64,
    ) -> Result<Box<dyn EigTracker + Send>> {
        self.validate_buildable()?;
        if self.backend == Backend::Xla {
            bail!(
                "spec `{self}` requests the @xla backend, whose PJRT state is \
                 thread-bound; @xla tenants need a pinned thread, not the shared \
                 worker pool"
            );
        }
        let seed = self.seed.unwrap_or(fallback_seed);
        let grest_mode = match &self.algo {
            Algo::Grest2 => Some(SubspaceMode::Rm),
            Algo::Grest3 => Some(SubspaceMode::Full),
            Algo::GrestRsvd { l, p } => Some(SubspaceMode::Rsvd { l: *l, p: *p }),
            _ => None,
        };
        if let Some(mode) = grest_mode {
            return Ok(Box::new(GRest::with_phases(
                init.clone(),
                mode,
                NativePhases::new(self.threads),
                seed,
            )));
        }
        Ok(match &self.algo {
            Algo::TripBasic => Box::new(TripBasic::new(init.clone())),
            Algo::Trip => Box::new(Trip::new(init.clone())),
            Algo::Rm { mu } => Box::new(ResidualModes::with_mu(init.clone(), *mu)),
            Algo::Iasc => Box::new(Iasc::new(init.clone())),
            Algo::Timers { theta, min_gap } => Box::new(
                Timers::with_initial(a0, init.clone(), seed)
                    .with_theta(*theta)
                    .with_min_gap(*min_gap),
            ),
            Algo::Eigs => Box::new(Reference::new(a0, init.k(), seed)),
            // both handled above
            Algo::Custom(_) | Algo::Grest2 | Algo::Grest3 | Algo::GrestRsvd { .. } => {
                unreachable!()
            }
        })
    }
}

impl Default for TrackerSpec {
    /// The paper's flagship: G-REST₃ on the native backend.
    fn default() -> TrackerSpec {
        TrackerSpec::new(Algo::Grest3)
    }
}

impl fmt::Display for TrackerSpec {
    /// Canonical grammar form; `parse(format(s)) == s` for every
    /// non-custom spec (property-tested below).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.algo.canonical_name())?;
        let mut params: Vec<String> = Vec::new();
        match &self.algo {
            Algo::GrestRsvd { l, p } => {
                params.push(format!("l={l}"));
                params.push(format!("p={p}"));
            }
            Algo::Timers { theta, min_gap } => {
                if *theta != DEFAULT_TIMERS_THETA {
                    params.push(format!("theta={theta}"));
                }
                if *min_gap != DEFAULT_TIMERS_GAP {
                    params.push(format!("gap={min_gap}"));
                }
            }
            Algo::Rm { mu } => {
                if *mu != 0.0 {
                    params.push(format!("mu={mu}"));
                }
            }
            _ => {}
        }
        // emit only what parse() accepts back for this algo/backend, so
        // Display stays a strict inverse of the grammar
        if self.backend == Backend::Xla {
            if self.n_cap != 0 {
                params.push(format!("n={}", self.n_cap));
            }
            if self.panel_cap != 0 {
                params.push(format!("m={}", self.panel_cap));
            }
        }
        if self.algo.is_grest()
            && self.backend == Backend::Native
            && self.threads != Threads::AUTO
        {
            params.push(format!("threads={}", self.threads.0));
        }
        if self.algo.is_grest() || matches!(self.algo, Algo::Timers { .. } | Algo::Eigs) {
            if let Some(seed) = self.seed {
                params.push(format!("seed={seed}"));
            }
        }
        if !params.is_empty() {
            write!(f, ":{}", params.join(","))?;
        }
        if self.backend == Backend::Xla {
            write!(f, "@xla")?;
        }
        Ok(())
    }
}

/// TIMERS restart threshold θ (paper: 0.01).
pub const DEFAULT_TIMERS_THETA: f64 = 0.01;
/// TIMERS minimum steps between restarts (paper modification: 5).
pub const DEFAULT_TIMERS_GAP: usize = 5;
/// RSVD default sketch size L = P (matches the old `--tracker grest-rsvd`).
pub const DEFAULT_RSVD_LP: usize = 32;

/// One registry row: canonical name, aliases (legacy `--tracker` names
/// and paper labels), accepted parameters, and the default spec.
pub struct RegistryEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub params: &'static str,
    pub description: &'static str,
    pub algo: Algo,
}

/// Every algorithm the factory can build, with its aliases.
pub fn registry() -> Vec<RegistryEntry> {
    vec![
        RegistryEntry {
            name: "trip-basic",
            aliases: &["tripbasic"],
            params: "",
            description: "first-order perturbation, Eqs. (5)-(6) (Chen & Tong 2015)",
            algo: Algo::TripBasic,
        },
        RegistryEntry {
            name: "trip",
            aliases: &[],
            params: "",
            description: "TRIP: coefficients from the K x K system of Eq. (7)",
            algo: Algo::Trip,
        },
        RegistryEntry {
            name: "rm",
            aliases: &["residual-modes"],
            params: "mu=<f64>",
            description: "Residual Modes with untracked-spectrum stand-in mu",
            algo: Algo::Rm { mu: 0.0 },
        },
        RegistryEntry {
            name: "iasc",
            aliases: &[],
            params: "",
            description: "IASC: Rayleigh-Ritz over [X, identity on new nodes]",
            algo: Algo::Iasc,
        },
        RegistryEntry {
            name: "timers",
            aliases: &[],
            params: "theta=<f64>,gap=<usize>",
            description: "TIMERS: IASC with error-bounded full restarts",
            algo: Algo::Timers { theta: DEFAULT_TIMERS_THETA, min_gap: DEFAULT_TIMERS_GAP },
        },
        RegistryEntry {
            name: "grest2",
            aliases: &["g-rest2"],
            params: "threads=<usize>",
            description: "G-REST2: Rayleigh-Ritz over the Residual-Modes subspace",
            algo: Algo::Grest2,
        },
        RegistryEntry {
            name: "grest3",
            aliases: &["g-rest3"],
            params: "threads=<usize>",
            description: "G-REST3: proposed subspace with the explicit Delta_2 block (Eq. 11)",
            algo: Algo::Grest3,
        },
        RegistryEntry {
            name: "grest-rsvd",
            aliases: &["rsvd", "grestrsvd", "g-rest-rsvd"],
            params: "l=<usize>,p=<usize>,threads=<usize>",
            description: "G-REST_RSVD: Delta_2 compressed by the randomized range finder",
            algo: Algo::GrestRsvd { l: DEFAULT_RSVD_LP, p: DEFAULT_RSVD_LP },
        },
        RegistryEntry {
            name: "eigs",
            aliases: &["reference", "exact"],
            params: "",
            description: "full Lanczos recompute every step (accuracy/runtime baseline)",
            algo: Algo::Eigs,
        },
    ]
}

/// Resolve an algorithm name (canonical, alias, or paper display label
/// such as `TRIP-Basic` / `G-REST3`), case-insensitively.
fn resolve_algo(name: &str) -> Result<Algo> {
    let lower = name.trim().to_ascii_lowercase();
    for entry in registry() {
        if entry.name == lower || entry.aliases.contains(&lower.as_str()) {
            return Ok(entry.algo);
        }
    }
    let known: Vec<&str> = registry().iter().map(|e| e.name).collect();
    bail!(
        "unknown tracker `{name}`; known trackers: {} \
         (run `grest track --tracker list` for the full registry)",
        known.join(", ")
    )
}

fn parse_num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T> {
    value
        .parse()
        .map_err(|_| anyhow!("parameter `{key}` has invalid value `{value}`"))
}

fn apply_param(spec: &mut TrackerSpec, key: &str, value: &str) -> Result<()> {
    let algo_name = spec.algo.canonical_name().to_string();
    // cross-algorithm knobs, rejected where they could not take effect
    // (a silently ignored sweep knob is worse than an error)
    match key {
        "threads" => {
            if !spec.algo.is_grest() {
                bail!(
                    "parameter `threads` only applies to the G-REST family \
                     (`{algo_name}` has no dense-kernel phases)"
                );
            }
            if spec.backend == Backend::Xla {
                bail!(
                    "parameter `threads` drives the native dense kernels; \
                     the @xla backend schedules internally"
                );
            }
            spec.threads = Threads(parse_num(key, value)?);
            return Ok(());
        }
        "seed" => {
            if !(spec.algo.is_grest()
                || matches!(spec.algo, Algo::Timers { .. } | Algo::Eigs))
            {
                bail!(
                    "parameter `seed` only applies to trackers with randomized \
                     or restart state (G-REST family, timers, eigs), not `{algo_name}`"
                );
            }
            spec.seed = Some(parse_num(key, value)?);
            return Ok(());
        }
        "n" | "m" => {
            if spec.backend != Backend::Xla {
                bail!(
                    "parameter `{key}` sizes the XLA artifact tier and only \
                     applies with the `@xla` backend"
                );
            }
            if key == "n" {
                spec.n_cap = parse_num(key, value)?;
            } else {
                spec.panel_cap = parse_num(key, value)?;
            }
            return Ok(());
        }
        _ => {}
    }
    match &mut spec.algo {
        Algo::GrestRsvd { l, p } => match key {
            "l" => *l = parse_num(key, value)?,
            "p" => *p = parse_num(key, value)?,
            _ => bail!("tracker `{algo_name}` has no parameter `{key}` (accepted: l, p)"),
        },
        Algo::Timers { theta, min_gap } => match key {
            "theta" => *theta = parse_num(key, value)?,
            "gap" => *min_gap = parse_num(key, value)?,
            _ => bail!("tracker `{algo_name}` has no parameter `{key}` (accepted: theta, gap)"),
        },
        Algo::Rm { mu } => match key {
            "mu" => *mu = parse_num(key, value)?,
            _ => bail!("tracker `{algo_name}` has no parameter `{key}` (accepted: mu)"),
        },
        _ => bail!(
            "tracker `{algo_name}` has no parameter `{key}` \
             (common parameters: threads, seed, n, m)"
        ),
    }
    Ok(())
}

/// Human-readable registry listing (`grest track --tracker list`).
pub fn list_help() -> String {
    let mut out = String::new();
    out.push_str("Tracker spec grammar: name[:key=value,...][@backend]\n");
    out.push_str("  backends: native (default), xla (G-REST family; needs artifacts)\n");
    out.push_str(
        "  cross-algorithm params: threads=<usize> (G-REST family), \
         seed=<u64> (G-REST/timers/eigs),\n  n=<rows>, m=<panel cols> \
         (@xla tier capacities)\n\n",
    );
    out.push_str(&format!(
        "{:<12} {:<24} {:<28} {}\n",
        "SPEC", "ALIASES", "PARAMS", "DESCRIPTION"
    ));
    for e in registry() {
        out.push_str(&format!(
            "{:<12} {:<24} {:<28} {}\n",
            e.name,
            e.aliases.join(", "),
            e.params,
            e.description
        ));
    }
    out.push_str("\nExamples: grest3   grest-rsvd:l=32,p=16   timers:theta=0.01   grest3@xla\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::sparse::coo::Coo;
    use crate::tracking::traits::init_eigenpairs;

    fn small_problem() -> (Csr, EigenPairs) {
        let mut coo = Coo::new(12, 12);
        for i in 0..12 {
            coo.push(i, i, (12 - i) as f64 * 2.0);
        }
        for i in 0..11 {
            coo.push_sym(i, i + 1, 0.4);
        }
        let a = coo.to_csr();
        let init = init_eigenpairs(&a, 3, 1);
        (a, init)
    }

    #[test]
    fn parses_issue_examples() {
        let s = TrackerSpec::parse("grest-rsvd:l=32,p=16").unwrap();
        assert_eq!(s.algo, Algo::GrestRsvd { l: 32, p: 16 });
        assert_eq!(s.backend, Backend::Native);

        let s = TrackerSpec::parse("timers:theta=0.01").unwrap();
        assert_eq!(s.algo, Algo::Timers { theta: 0.01, min_gap: DEFAULT_TIMERS_GAP });

        let s = TrackerSpec::parse("grest3@xla").unwrap();
        assert_eq!(s.algo, Algo::Grest3);
        assert_eq!(s.backend, Backend::Xla);
        assert_eq!(s.display_name(), "G-REST3@xla");
    }

    #[test]
    fn every_legacy_tracker_name_still_resolves() {
        // the old `--tracker` vocabulary of cmd_track plus the paper
        // display labels used by tables — all must keep working
        let legacy = [
            ("trip-basic", "TRIP-Basic"),
            ("trip", "TRIP"),
            ("rm", "RM"),
            ("iasc", "IASC"),
            ("timers", "TIMERS"),
            ("grest2", "G-REST2"),
            ("grest3", "G-REST3"),
            ("grest-rsvd", "G-REST-RSVD(L=32,P=32)"),
            ("TRIP-Basic", "TRIP-Basic"),
            ("TRIP", "TRIP"),
            ("RM", "RM"),
            ("IASC", "IASC"),
            ("TIMERS", "TIMERS"),
            ("G-REST2", "G-REST2"),
            ("G-REST3", "G-REST3"),
            ("G-REST-RSVD", "G-REST-RSVD(L=32,P=32)"),
            ("eigs", "eigs"),
            ("reference", "eigs"),
        ];
        for (name, display) in legacy {
            let spec = TrackerSpec::parse(name)
                .unwrap_or_else(|e| panic!("legacy name `{name}` must parse: {e}"));
            assert_eq!(spec.display_name(), display, "for `{name}`");
        }
    }

    #[test]
    fn roundtrip_parse_format_parse_across_registry() {
        // property test: for every registry algorithm and randomized
        // knobs, parse(format(spec)) == spec and format is a fixpoint
        let mut rng = Rng::new(42);
        for entry in registry() {
            for _ in 0..40 {
                let mut spec = TrackerSpec::new(entry.algo.clone());
                // respect the grammar's applicability matrix: threads is
                // G-REST-native-only, seed needs randomized/restart
                // state, and n/m tier caps need the @xla backend
                if rng.flip(0.3) {
                    spec.backend = Backend::Xla;
                }
                if spec.algo.is_grest() && spec.backend == Backend::Native && rng.flip(0.5) {
                    spec.threads = Threads(rng.below(8));
                }
                let seed_ok = spec.algo.is_grest()
                    || matches!(spec.algo, Algo::Timers { .. } | Algo::Eigs);
                if seed_ok && rng.flip(0.5) {
                    spec.seed = Some(rng.below(100_000) as u64);
                }
                if spec.backend == Backend::Xla {
                    if rng.flip(0.3) {
                        spec.n_cap = 1 + rng.below(4096);
                    }
                    if rng.flip(0.3) {
                        spec.panel_cap = 1 + rng.below(512);
                    }
                }
                match &mut spec.algo {
                    Algo::GrestRsvd { l, p } => {
                        *l = 1 + rng.below(200);
                        *p = rng.below(200);
                    }
                    Algo::Timers { theta, min_gap } => {
                        *theta = (1 + rng.below(500)) as f64 / 1000.0;
                        *min_gap = 1 + rng.below(12);
                    }
                    Algo::Rm { mu } => {
                        *mu = (rng.below(200) as f64 - 100.0) / 8.0;
                    }
                    _ => {}
                }
                let text = spec.to_string();
                let parsed = TrackerSpec::parse(&text)
                    .unwrap_or_else(|e| panic!("`{text}` must re-parse: {e}"));
                assert_eq!(parsed, spec, "round-trip mismatch for `{text}`");
                assert_eq!(parsed.to_string(), text, "format not a fixpoint for `{text}`");
            }
        }
    }

    #[test]
    fn display_names_distinguish_param_sweeps() {
        let cases = [
            ("timers", "TIMERS"),
            ("timers:theta=0.05", "TIMERS(theta=0.05)"),
            ("timers:theta=0.05,gap=3", "TIMERS(theta=0.05,gap=3)"),
            ("rm", "RM"),
            ("rm:mu=0.5", "RM(mu=0.5)"),
            ("grest-rsvd:l=16,p=8", "G-REST-RSVD(L=16,P=8)"),
        ];
        for (text, display) in cases {
            assert_eq!(
                TrackerSpec::parse(text).unwrap().display_name(),
                display,
                "for `{text}`"
            );
        }
    }

    #[test]
    fn malformed_specs_error_clearly() {
        let cases = [
            ("", "empty tracker spec"),
            ("   ", "empty tracker spec"),
            ("warp-drive", "unknown tracker"),
            ("grest3@gpu", "unknown backend"),
            ("trip:bogus=1", "no parameter `bogus`"),
            ("grest-rsvd:l", "expected key=value"),
            ("grest-rsvd:l=abc", "invalid value"),
            ("timers:theta=fast", "invalid value"),
            ("trip:l=4", "no parameter `l`"),
            // silently-ignored knobs are rejected, not accepted
            ("trip:threads=8", "only applies to the G-REST family"),
            ("grest3:threads=8@xla", "schedules internally"),
            ("iasc:seed=5", "only applies to trackers"),
            ("grest3:n=5000", "@xla"),
        ];
        for (text, needle) in cases {
            let err = TrackerSpec::parse(text)
                .expect_err(&format!("`{text}` must fail to parse"))
                .to_string();
            assert!(
                err.contains(needle),
                "error for `{text}` should mention `{needle}`, got: {err}"
            );
        }
    }

    #[test]
    fn xla_backend_restricted_to_grest_family() {
        let spec = TrackerSpec::parse("trip@xla").unwrap();
        let err = spec.validate_buildable().unwrap_err().to_string();
        assert!(err.contains("G-REST"), "{err}");
        let err = TrackerSpec::custom("whatever").validate_buildable().unwrap_err();
        assert!(err.to_string().contains("escape hatch"), "{err}");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_spec_rejected_upfront_without_feature() {
        // spawn-style callers validate before handing the spec to a
        // worker thread; without the feature this must fail fast, not
        // panic later inside the worker
        let err = TrackerSpec::parse("grest3@xla")
            .unwrap()
            .validate_buildable()
            .unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }

    #[test]
    fn registry_defaults_build_and_names_match() {
        let (a, init) = small_problem();
        for entry in registry() {
            let spec = TrackerSpec::new(entry.algo.clone());
            let tracker = spec
                .build_seeded(&a, &init, 3)
                .unwrap_or_else(|e| panic!("`{}` must build: {e}", entry.name));
            assert_eq!(
                tracker.name(),
                spec.display_name(),
                "tracker name must derive from the spec for `{}`",
                entry.name
            );
            assert_eq!(
                tracker.descriptor().algo,
                spec.algo,
                "descriptor algo drifted for `{}`",
                entry.name
            );
        }
    }

    #[test]
    fn built_trackers_track_a_small_update() {
        // one real update through every registry default, via the factory
        let (a, init) = small_problem();
        let mut k = Coo::new(12, 12);
        k.push_sym(0, 4, 0.2);
        k.push_sym(2, 6, -0.1);
        let d = crate::sparse::delta::Delta::from_blocks(
            12,
            0,
            &k,
            &Coo::new(12, 0),
            &Coo::new(0, 0),
        );
        for entry in registry() {
            let spec = TrackerSpec::new(entry.algo.clone());
            let mut tracker = spec.build_seeded(&a, &init, 3).unwrap();
            tracker.update(&d).unwrap();
            assert_eq!(tracker.current().k(), 3, "`{}` lost eigenpairs", entry.name);
            assert!(
                tracker.current().values.iter().all(|v| v.is_finite()),
                "`{}` produced non-finite eigenvalues",
                entry.name
            );
        }
    }

    #[test]
    fn explicit_seed_wins_over_fallback() {
        let (a, init) = small_problem();
        let spec = TrackerSpec::parse("eigs:seed=9").unwrap();
        let t = spec.build_seeded(&a, &init, 1234).unwrap();
        assert_eq!(t.descriptor().seed, Some(9));
        // same contract for TIMERS (restart Lanczos seed)
        let spec = TrackerSpec::parse("timers:seed=9").unwrap();
        let t = spec.build_seeded(&a, &init, 1234).unwrap();
        assert_eq!(t.descriptor().seed, Some(9));
    }

    #[test]
    fn list_help_mentions_every_registry_entry() {
        let help = list_help();
        for e in registry() {
            assert!(help.contains(e.name), "list is missing `{}`", e.name);
        }
    }
}
