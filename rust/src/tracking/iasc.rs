//! IASC (Dhanjal, Gaudel & Clémençon 2014): Rayleigh–Ritz over the
//! subspace Z = [X̄_K, 0; 0, I_S] — padded old eigenvectors plus identity
//! columns on the new nodes.  A strong baseline when updates are pure
//! expansion, but blind to topological (K-block) updates outside Ran(X̄).

use crate::linalg::eigh::eigh;
use crate::linalg::mat::{Mat, Padded};
use crate::sparse::delta::Delta;
use crate::tracking::spec::{Algo, TrackerSpec};
use crate::tracking::traits::{interaction_matrix, EigTracker, EigenPairs};

pub struct Iasc {
    state: EigenPairs,
    flops: u64,
}

impl Iasc {
    pub fn new(initial: EigenPairs) -> Iasc {
        Iasc { state: initial, flops: 0 }
    }
}

impl EigTracker for Iasc {
    fn descriptor(&self) -> TrackerSpec {
        TrackerSpec::new(Algo::Iasc)
    }

    fn update(&mut self, delta: &Delta) -> anyhow::Result<()> {
        let k = self.state.k();
        let n_old = self.state.n();
        let s = delta.s_new;
        let x = &self.state.vectors;
        let dxk = delta.mul_padded(x); // (N+S)×K
        let b = interaction_matrix(x, &dxk); // K×K  = X̄ᵀΔX̄
        self.flops = (2 * n_old * k * k) as u64
            + 2 * delta.nnz() as u64 * (k + s) as u64
            + ((k + s) * (k + s) * (k + s)) as u64;

        // T = Zᵀ (X̄ΛX̄ᵀ + Δ) Z over Z = [X̄ E_S]:
        //   T11 = Λ + X̄ᵀΔX̄
        //   T12 = X̄ᵀΔE_S  = top-K part of Δ₂ᵀX̄, transposed
        //   T22 = E_SᵀΔE_S = C block (bottom-right of Δ)
        let dim = k + s;
        let mut t = Mat::zeros(dim, dim);
        for i in 0..k {
            for j in 0..k {
                let lam = if i == j { self.state.values[i] } else { 0.0 };
                t.set(i, j, lam + b.get(i, j));
            }
        }
        if s > 0 {
            // Δ₂ᵀX̄ off the Padded view: the zero rows of X̄ are skipped
            // inside the sparse kernel instead of being materialized.
            let d2t_x = delta.d2_t_mult(Padded::new(x, s)); // S×K = Δ₂ᵀX̄
            for i in 0..k {
                for j in 0..s {
                    t.set(i, k + j, d2t_x.get(j, i));
                    t.set(k + j, i, d2t_x.get(j, i));
                }
            }
            // C block
            for r in 0..s {
                let row = delta.n_old + r;
                let (cols, vals) = delta.full.row(row);
                for (&cidx, &v) in cols.iter().zip(vals.iter()) {
                    if cidx >= delta.n_old {
                        t.set(k + r, k + (cidx - delta.n_old), v);
                    }
                }
            }
        }

        let e = eigh(&t);
        let order = e.leading_by_magnitude(k);
        let n_new = delta.n_new();
        let mut new_vecs = Mat::zeros(n_new, k);
        let mut new_vals = Vec::with_capacity(k);
        for (c, &idx) in order.iter().enumerate() {
            new_vals.push(e.values[idx]);
            let f = e.vectors.col(idx);
            // X_new[:, c] = X̄ f[0..k] + E_S f[k..]
            let col = new_vecs.col_mut(c);
            for i in 0..k {
                let fi = f[i];
                if fi != 0.0 {
                    for (r, &v) in x.col(i).iter().enumerate() {
                        col[r] += fi * v;
                    }
                }
            }
            for j in 0..s {
                col[n_old + j] = f[k + j];
            }
        }
        self.state = EigenPairs { values: new_vals, vectors: new_vecs };
        Ok(())
    }

    fn current(&self) -> &EigenPairs {
        &self.state
    }

    fn last_step_flops(&self) -> u64 {
        self.flops
    }

    /// aux_u layout: `[flops]`.  IASC is stateless beyond its pairs.
    fn save_state(&self) -> anyhow::Result<crate::tracking::traits::TrackerState> {
        Ok(crate::tracking::traits::TrackerState {
            pairs: self.state.clone(),
            aux_u: vec![self.flops],
            aux_f: vec![],
            adjacency: None,
        })
    }

    fn restore_state(
        &mut self,
        st: crate::tracking::traits::TrackerState,
    ) -> anyhow::Result<()> {
        if st.aux_u.len() != 1 {
            anyhow::bail!("IASC state layout mismatch");
        }
        self.flops = st.aux_u[0];
        self.state = st.pairs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::tracking::traits::{apply_delta, init_eigenpairs};

    #[test]
    fn pure_expansion_is_captured_exactly_for_rank_k_matrix() {
        // A⁰ is exactly rank-K; Z spans [X̄, E_S] which contains the exact
        // invariant subspace of Â = X̄ΛX̄ᵀ + Δ when Δ only touches new
        // nodes — so IASC must be near-exact.
        let mut coo = Coo::new(6, 6);
        coo.push_sym(0, 1, 2.0);
        coo.push_sym(2, 3, 1.0);
        let a = coo.to_csr();
        let init = init_eigenpairs(&a, 4, 1);
        let mut t = Iasc::new(init);
        let kb = Coo::new(6, 6);
        let mut g = Coo::new(6, 2);
        g.push(0, 0, 1.0);
        g.push(3, 1, 1.0);
        let mut c = Coo::new(2, 2);
        c.push_sym(0, 1, 1.0);
        let d = Delta::from_blocks(6, 2, &kb, &g, &c);
        t.update(&d).unwrap();
        let exact = crate::linalg::eigh::eigh(&apply_delta(&a, &d).to_dense());
        let order = exact.leading_by_magnitude(4);
        // the test graph is bipartite (± eigenvalue pairs), so compare
        // magnitudes: ordering within an exactly-tied pair is fp noise.
        for j in 0..4 {
            assert!(
                (t.current().values[j].abs() - exact.values[order[j]].abs()).abs() < 1e-6,
                "|λ{j}|: {} vs {}",
                t.current().values[j],
                exact.values[order[j]]
            );
        }
    }

    #[test]
    fn orthonormal_output() {
        let mut coo = Coo::new(10, 10);
        for i in 0..9 {
            coo.push_sym(i, i + 1, 1.0);
        }
        let a = coo.to_csr();
        let init = init_eigenpairs(&a, 3, 2);
        let mut t = Iasc::new(init);
        let kb = Coo::new(10, 10);
        let mut g = Coo::new(10, 3);
        g.push(0, 0, 1.0);
        g.push(4, 1, 1.0);
        g.push(9, 2, 1.0);
        let c = Coo::new(3, 3);
        let d = Delta::from_blocks(10, 3, &kb, &g, &c);
        t.update(&d).unwrap();
        let v = &t.current().vectors;
        let gm = v.t_matmul(v);
        let mut eye = Mat::eye(3);
        eye.axpy(-1.0, &gm);
        assert!(eye.max_abs() < 1e-8);
    }

    #[test]
    fn captures_eigenvalue_growth_from_new_hub() {
        // attach a hub to many nodes: top eigenvalue must grow, and IASC
        // (unlike TRIP) must see it.
        let mut coo = Coo::new(8, 8);
        for i in 0..7 {
            coo.push_sym(i, i + 1, 1.0);
        }
        let a = coo.to_csr();
        let init = init_eigenpairs(&a, 2, 3);
        let lam0 = init.values[0];
        let mut t = Iasc::new(init);
        let kb = Coo::new(8, 8);
        let mut g = Coo::new(8, 1);
        for i in 0..8 {
            g.push(i, 0, 1.0);
        }
        let c = Coo::new(1, 1);
        let d = Delta::from_blocks(8, 1, &kb, &g, &c);
        t.update(&d).unwrap();
        assert!(t.current().values[0] > lam0 + 0.5, "hub must raise λ₁");
    }
}
