//! TIMERS (Zhang et al. 2017): error-bounded restart.  Runs a cheap
//! tracker (IASC, as in the paper's experiments) between full truncated
//! eigendecompositions, restarting when an accumulated-error proxy
//! exceeds the threshold θ.
//!
//! Proxy: Σ‖Δ⁽ᵗ⁾‖_F since the last restart, relative to ‖Â⁽ᵗ⁾‖_F — a
//! computable surrogate for TIMERS' loss lower bound.  Following the
//! paper's modification, at least `min_gap` (=5) steps must pass between
//! restarts.  TIMERS retains the explicit adjacency (its higher memory
//! footprint, as the paper notes).

use crate::sparse::csr::Csr;
use crate::sparse::delta::Delta;
use crate::tracking::iasc::Iasc;
use crate::tracking::spec::{Algo, TrackerSpec};
use crate::tracking::traits::{apply_delta, init_eigenpairs, EigTracker, EigenPairs};

pub struct Timers {
    inner: Iasc,
    adjacency: Csr,
    k: usize,
    /// restart threshold θ (paper: 0.01)
    pub theta: f64,
    /// minimum steps between restarts (paper modification: 5)
    pub min_gap: usize,
    accumulated_fro: f64,
    steps_since_restart: usize,
    /// restart Lanczos seed; advances on every restart
    seed: u64,
    /// construction-time seed (reported by `descriptor`)
    initial_seed: u64,
    pub restarts: usize,
    flops: u64,
}

impl Timers {
    pub fn new(a0: &Csr, k: usize, seed: u64) -> Timers {
        Timers::with_initial(a0, init_eigenpairs(a0, k, seed), seed)
    }

    /// Construct from precomputed initial eigenpairs (skips the internal
    /// Lanczos; used by [`crate::tracking::spec::TrackerSpec::build`]).
    pub fn with_initial(a0: &Csr, initial: EigenPairs, seed: u64) -> Timers {
        let k = initial.k();
        Timers {
            inner: Iasc::new(initial),
            adjacency: a0.clone(),
            k,
            theta: 0.01,
            min_gap: 5,
            accumulated_fro: 0.0,
            steps_since_restart: 0,
            seed,
            initial_seed: seed,
            restarts: 0,
            flops: 0,
        }
    }

    pub fn with_theta(mut self, theta: f64) -> Timers {
        self.theta = theta;
        self
    }

    pub fn with_min_gap(mut self, min_gap: usize) -> Timers {
        self.min_gap = min_gap;
        self
    }
}

impl EigTracker for Timers {
    fn descriptor(&self) -> TrackerSpec {
        TrackerSpec::new(Algo::Timers { theta: self.theta, min_gap: self.min_gap })
            .with_seed(self.initial_seed)
    }

    fn update(&mut self, delta: &Delta) -> anyhow::Result<()> {
        self.adjacency = apply_delta(&self.adjacency, delta);
        self.accumulated_fro += delta.full.fro_norm();
        self.steps_since_restart += 1;

        let a_norm = self.adjacency.fro_norm().max(1e-300);
        let proxy = self.accumulated_fro / a_norm;
        if proxy > self.theta && self.steps_since_restart >= self.min_gap {
            // full truncated eigendecomposition restart
            self.seed = self.seed.wrapping_add(1);
            let fresh = init_eigenpairs(&self.adjacency, self.k, self.seed);
            self.inner = Iasc::new(fresh);
            self.accumulated_fro = 0.0;
            self.steps_since_restart = 0;
            self.restarts += 1;
            // restart cost dominates
            let n = self.adjacency.n_rows as u64;
            let nnz = self.adjacency.nnz() as u64;
            let m = (4 * self.k + 40) as u64;
            self.flops = 2 * nnz * m + 2 * n * m * m;
        } else {
            self.inner.update(delta)?;
            self.flops = self.inner.last_step_flops();
        }
        Ok(())
    }

    fn current(&self) -> &EigenPairs {
        self.inner.current()
    }

    fn last_step_flops(&self) -> u64 {
        self.flops
    }

    /// aux_u layout: `[steps_since_restart, seed, restarts, flops]`;
    /// aux_f: `[accumulated_fro]`; adjacency: TIMERS' private explicit
    /// copy.  θ/min_gap/initial_seed travel in the descriptor.
    fn save_state(&self) -> anyhow::Result<crate::tracking::traits::TrackerState> {
        Ok(crate::tracking::traits::TrackerState {
            pairs: self.inner.current().clone(),
            aux_u: vec![
                self.steps_since_restart as u64,
                self.seed,
                self.restarts as u64,
                self.flops,
            ],
            aux_f: vec![self.accumulated_fro],
            adjacency: Some(self.adjacency.clone()),
        })
    }

    fn restore_state(
        &mut self,
        st: crate::tracking::traits::TrackerState,
    ) -> anyhow::Result<()> {
        if st.aux_u.len() != 4 || st.aux_f.len() != 1 {
            anyhow::bail!("TIMERS state layout mismatch");
        }
        let adjacency = match st.adjacency {
            Some(a) => a,
            None => anyhow::bail!("TIMERS state missing its adjacency"),
        };
        self.steps_since_restart = st.aux_u[0] as usize;
        self.seed = st.aux_u[1];
        self.restarts = st.aux_u[2] as usize;
        self.flops = st.aux_u[3];
        self.accumulated_fro = st.aux_f[0];
        self.adjacency = adjacency;
        self.inner = Iasc::new(st.pairs);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::sparse::coo::Coo;

    fn er_adjacency(n: usize, p: f64, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        crate::graph::generators::erdos_renyi(n, p, &mut rng).adjacency()
    }

    fn random_topo_delta(n: usize, edges: usize, seed: u64) -> Delta {
        let mut rng = Rng::new(seed);
        let mut kb = Coo::new(n, n);
        for _ in 0..edges {
            let (u, v) = (rng.below(n), rng.below(n));
            if u != v {
                kb.push_sym(u, v, 1.0);
            }
        }
        Delta::from_blocks(n, 0, &kb, &Coo::new(n, 0), &Coo::new(0, 0))
    }

    #[test]
    fn restarts_fire_after_enough_drift() {
        let a0 = er_adjacency(60, 0.1, 1);
        let mut t = Timers::new(&a0, 4, 2).with_theta(0.01);
        for s in 0..12 {
            let d = random_topo_delta(60, 20, 100 + s);
            t.update(&d).unwrap();
        }
        assert!(t.restarts >= 1, "expected at least one restart");
    }

    #[test]
    fn min_gap_respected() {
        let a0 = er_adjacency(50, 0.1, 3);
        let mut t = Timers::new(&a0, 3, 4).with_theta(1e-9); // restart-eager
        for s in 0..10 {
            let d = random_topo_delta(50, 10, 200 + s);
            t.update(&d).unwrap();
        }
        // with min_gap 5 and 10 steps, at most 2 restarts possible
        assert!(t.restarts <= 2, "restarts={}", t.restarts);
    }

    #[test]
    fn restart_recovers_accuracy() {
        let a0 = er_adjacency(60, 0.08, 5);
        let mut t = Timers::new(&a0, 3, 6).with_theta(1e-9);
        let mut a = a0;
        for s in 0..6 {
            let d = random_topo_delta(60, 25, 300 + s);
            a = apply_delta(&a, &d);
            t.update(&d).unwrap();
        }
        // After a restart step, residual must be at Lanczos accuracy.
        // Force a final restart-eligible step:
        let d = random_topo_delta(60, 25, 999);
        a = apply_delta(&a, &d);
        t.update(&d).unwrap();
        if t.restarts > 0 && t.steps_since_restart == 0 {
            assert!(t.current().max_residual(&a) < 1e-6);
        }
    }
}
