//! TRIP (Chen & Tong 2015; paper Sec. 2.3.2): like TRIP-Basic but the
//! eigenvector coefficients solve the K×K system of Eq. (7), delaying the
//! eigenvector computation until the updated eigenvalue λ̃_j is available.

use crate::linalg::lu;
use crate::linalg::mat::Mat;
use crate::sparse::delta::Delta;
use crate::tracking::spec::{Algo, TrackerSpec};
use crate::tracking::traits::{interaction_matrix, EigTracker, EigenPairs};

pub struct Trip {
    state: EigenPairs,
    flops: u64,
}

impl Trip {
    pub fn new(initial: EigenPairs) -> Trip {
        Trip { state: initial, flops: 0 }
    }
}

impl EigTracker for Trip {
    fn descriptor(&self) -> TrackerSpec {
        TrackerSpec::new(Algo::Trip)
    }

    fn update(&mut self, delta: &Delta) -> anyhow::Result<()> {
        let k = self.state.k();
        let x = &self.state.vectors;
        let dxk = delta.mul_padded(x);
        let b = interaction_matrix(x, &dxk); // X̄ᵀΔX̄
        self.flops = (2 * x.rows() * k * k + k * k * k) as u64 + 2 * delta.nnz() as u64 * k as u64;

        let mut new_vals = Vec::with_capacity(k);
        for j in 0..k {
            new_vals.push(self.state.values[j] + b.get(j, j));
        }
        let n_new = delta.n_new();
        let mut new_vecs = Mat::zeros(n_new, k);
        for j in 0..k {
            // (W_j − B) b_j = B[:, j]  with W_j = diag(λ̃_j − λ_i)   (Eq. 7)
            let mut lhs = Mat::zeros(k, k);
            for i in 0..k {
                for p in 0..k {
                    let w = if i == p { new_vals[j] - self.state.values[i] } else { 0.0 };
                    lhs.set(i, p, w - b.get(i, p));
                }
            }
            let rhs: Vec<f64> = (0..k).map(|i| b.get(i, j)).collect();
            let coeffs = match lu::solve(&lhs, &rhs) {
                Some(c) => c,
                // singular system (e.g. Δ=0): fall back to b_j = 0, i.e.
                // keep the old eigenvector x̃_j = X̄ e_j.
                None => vec![0.0; k],
            };
            // x̃_j = X̄ (e_j + b_j)   (Eq. 7): seed with x̄_j, then add every
            // solved coefficient — including b_j's own j-th component,
            // which shifts x̃_j along x̄_j and is NOT a pure scaling once
            // the other components are present.
            {
                let col = new_vecs.col_mut(j);
                col[..x.rows()].copy_from_slice(x.col(j));
            }
            for (i, &c) in coeffs.iter().enumerate() {
                if c != 0.0 {
                    let col = new_vecs.col_mut(j);
                    crate::linalg::blas::axpy(c, x.col(i), &mut col[..x.rows()]);
                }
            }
            let nrm = crate::linalg::blas::nrm2(new_vecs.col(j)).max(1e-300);
            for v in new_vecs.col_mut(j) {
                *v /= nrm;
            }
        }
        self.state = EigenPairs { values: new_vals, vectors: new_vecs };
        Ok(())
    }

    fn current(&self) -> &EigenPairs {
        &self.state
    }

    fn last_step_flops(&self) -> u64 {
        self.flops
    }

    /// aux_u layout: `[flops]`.  TRIP is stateless beyond its pairs.
    fn save_state(&self) -> anyhow::Result<crate::tracking::traits::TrackerState> {
        Ok(crate::tracking::traits::TrackerState {
            pairs: self.state.clone(),
            aux_u: vec![self.flops],
            aux_f: vec![],
            adjacency: None,
        })
    }

    fn restore_state(
        &mut self,
        st: crate::tracking::traits::TrackerState,
    ) -> anyhow::Result<()> {
        if st.aux_u.len() != 1 {
            anyhow::bail!("TRIP state layout mismatch");
        }
        self.flops = st.aux_u[0];
        self.state = st.pairs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::tracking::traits::{apply_delta, init_eigenpairs};

    fn diag_dominant(n: usize) -> crate::sparse::csr::Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, (n - i) as f64 * 3.0);
        }
        for i in 0..n - 1 {
            coo.push_sym(i, i + 1, 0.3);
        }
        coo.to_csr()
    }

    #[test]
    fn zero_delta_keeps_state() {
        let a = diag_dominant(9);
        let init = init_eigenpairs(&a, 3, 1);
        let v0 = init.vectors.clone();
        let mut t = Trip::new(init);
        let d = Delta::from_blocks(9, 0, &Coo::new(9, 9), &Coo::new(9, 0), &Coo::new(0, 0));
        t.update(&d).unwrap();
        let mut diff = t.current().vectors.clone();
        diff.axpy(-1.0, &v0);
        assert!(diff.max_abs() < 1e-10);
    }

    #[test]
    fn tracks_small_topological_update() {
        let a = diag_dominant(10);
        let init = init_eigenpairs(&a, 4, 2);
        let mut t = Trip::new(init);
        let mut k = Coo::new(10, 10);
        k.push_sym(0, 2, 0.05);
        k.push_sym(1, 3, -0.02);
        let d = Delta::from_blocks(10, 0, &k, &Coo::new(10, 0), &Coo::new(0, 0));
        t.update(&d).unwrap();
        let exact = crate::linalg::eigh::eigh(&apply_delta(&a, &d).to_dense());
        let order = exact.leading_by_magnitude(4);
        for j in 0..4 {
            assert!(
                (t.current().values[j] - exact.values[order[j]]).abs() < 5e-3,
                "λ{j}: {} vs {}",
                t.current().values[j],
                exact.values[order[j]]
            );
            let overlap = crate::linalg::blas::dot(
                t.current().vectors.col(j),
                exact.vectors.col(order[j]),
            )
            .abs();
            assert!(overlap > 0.995, "vector {j} overlap {overlap}");
        }
    }

    #[test]
    fn eq7_reconstruction_matches_dense_solve() {
        // regression for the dropped-coefficient bug: one TRIP step must
        // equal the dense solve of the K×K system of Eq. (7) followed by
        // x̃_j = X̄(e_j + b_j), including b_j's own j-th component.
        let a = diag_dominant(10);
        let k = 4;
        let init = init_eigenpairs(&a, k, 5);
        let x0 = init.vectors.clone();
        let vals0 = init.values.clone();
        let mut t = Trip::new(init);
        let mut kcoo = Coo::new(10, 10);
        kcoo.push_sym(0, 1, 0.2);
        kcoo.push_sym(2, 5, -0.15);
        kcoo.push_sym(3, 4, 0.1);
        let d = Delta::from_blocks(10, 0, &kcoo, &Coo::new(10, 0), &Coo::new(0, 0));
        t.update(&d).unwrap();

        let dxk = d.mul_padded(&x0);
        let b = interaction_matrix(&x0, &dxk);
        for j in 0..k {
            let lam_new = vals0[j] + b.get(j, j);
            let mut lhs = Mat::zeros(k, k);
            for i in 0..k {
                for p in 0..k {
                    let w = if i == p { lam_new - vals0[i] } else { 0.0 };
                    lhs.set(i, p, w - b.get(i, p));
                }
            }
            let rhs: Vec<f64> = (0..k).map(|i| b.get(i, j)).collect();
            let coeffs = lu::solve(&lhs, &rhs).expect("Eq. 7 system solvable");
            assert!(
                coeffs[j].abs() > 1e-12,
                "test delta must exercise a nonzero j-th coefficient"
            );
            // dense reconstruction, normalized
            let mut want: Vec<f64> = x0.col(j).to_vec();
            for (i, &c) in coeffs.iter().enumerate() {
                for (r, w) in want.iter_mut().enumerate() {
                    *w += c * x0.get(r, i);
                }
            }
            let nrm = crate::linalg::blas::nrm2(&want);
            let got = t.current().vectors.col(j);
            let sign = crate::linalg::blas::dot(got, &want).signum();
            for (r, w) in want.iter().enumerate() {
                assert!(
                    (got[r] - sign * w / nrm).abs() < 1e-12,
                    "x̃_{j}[{r}]: {} vs {}",
                    got[r],
                    sign * w / nrm
                );
            }
        }
    }

    #[test]
    fn trip_at_least_as_good_as_trip_basic_on_vectors() {
        use crate::tracking::trip_basic::TripBasic;
        let a = diag_dominant(12);
        let init = init_eigenpairs(&a, 3, 3);
        let mut t1 = Trip::new(init.clone());
        let mut t0 = TripBasic::new(init);
        let mut k = Coo::new(12, 12);
        k.push_sym(0, 5, 0.4);
        k.push_sym(2, 7, 0.3);
        k.push_sym(1, 4, -0.2);
        let d = Delta::from_blocks(12, 0, &k, &Coo::new(12, 0), &Coo::new(0, 0));
        t1.update(&d).unwrap();
        t0.update(&d).unwrap();
        let exact = crate::linalg::eigh::eigh(&apply_delta(&a, &d).to_dense());
        let order = exact.leading_by_magnitude(1);
        let ov1 = crate::linalg::blas::dot(t1.current().vectors.col(0), exact.vectors.col(order[0])).abs();
        let ov0 = crate::linalg::blas::dot(t0.current().vectors.col(0), exact.vectors.col(order[0])).abs();
        assert!(ov1 >= ov0 - 5e-3, "TRIP {ov1} vs TRIP-Basic {ov0}");
    }
}
