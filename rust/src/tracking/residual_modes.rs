//! Residual Modes (Mitz, Sharon & Shkolnisky 2019; paper Sec. 2.3.3):
//! TRIP-Basic plus a rank-one correction per eigenvector from the
//! untracked spectrum, with the unknown eigenvalues replaced by a scalar
//! μ (default 0, matching the paper's experiments).

use crate::linalg::blas;
use crate::linalg::mat::{Mat, Padded};
use crate::sparse::delta::Delta;
use crate::tracking::spec::{Algo, TrackerSpec};
use crate::tracking::traits::{interaction_matrix, EigTracker, EigenPairs};

const GAP_EPS: f64 = 1e-10;

pub struct ResidualModes {
    state: EigenPairs,
    /// μ — stand-in for the untracked eigenvalues λ_{K+1..N}.
    pub mu: f64,
    flops: u64,
}

impl ResidualModes {
    pub fn new(initial: EigenPairs) -> ResidualModes {
        ResidualModes { state: initial, mu: 0.0, flops: 0 }
    }

    pub fn with_mu(initial: EigenPairs, mu: f64) -> ResidualModes {
        ResidualModes { state: initial, mu, flops: 0 }
    }
}

impl EigTracker for ResidualModes {
    fn descriptor(&self) -> TrackerSpec {
        TrackerSpec::new(Algo::Rm { mu: self.mu })
    }

    fn update(&mut self, delta: &Delta) -> anyhow::Result<()> {
        let k = self.state.k();
        let n_old = self.state.n();
        let x = &self.state.vectors;
        let dxk = delta.mul_padded(x); // (N+S)×K = ΔX̄
        let b = interaction_matrix(x, &dxk);
        self.flops =
            (4 * n_old * k * k) as u64 + 2 * delta.nnz() as u64 * k as u64;

        let mut new_vals = Vec::with_capacity(k);
        for j in 0..k {
            new_vals.push(self.state.values[j] + b.get(j, j));
        }

        // Residual block: R = (I − X̄X̄ᵀ) Δ X̄  — note the bottom S rows of
        // ΔX̄ (the Gᵀx_j part) pass through untouched (Prop. 1 proof).
        // X̄ is the borrowed Padded view: no n×k materialization, and the
        // projection Gram skips the structurally-zero rows.
        let resid = blas::project_out(Padded::new(x, delta.s_new), &dxk); // (N+S)×K

        let n_new = delta.n_new();
        let mut new_vecs = Mat::zeros(n_new, k);
        for j in 0..k {
            {
                let col = new_vecs.col_mut(j);
                col[..n_old].copy_from_slice(x.col(j));
            }
            // tracked-spectrum corrections (same as TRIP-Basic)
            for i in 0..k {
                if i == j {
                    continue;
                }
                let gap = self.state.values[j] - self.state.values[i];
                if gap.abs() < GAP_EPS {
                    continue;
                }
                let coeff = b.get(i, j) / gap;
                let xi = x.col(i).to_vec();
                let col = new_vecs.col_mut(j);
                for (r, &v) in xi.iter().enumerate() {
                    col[r] += coeff * v;
                }
            }
            // residual-mode correction: + (λ_j − μ)^{-1} R[:, j]
            let gap = self.state.values[j] - self.mu;
            if gap.abs() > GAP_EPS {
                let coeff = 1.0 / gap;
                let rj = resid.col(j).to_vec();
                let col = new_vecs.col_mut(j);
                for (r, &v) in rj.iter().enumerate() {
                    col[r] += coeff * v;
                }
            }
            let nrm = blas::nrm2(new_vecs.col(j)).max(1e-300);
            for v in new_vecs.col_mut(j) {
                *v /= nrm;
            }
        }
        self.state = EigenPairs { values: new_vals, vectors: new_vecs };
        Ok(())
    }

    fn current(&self) -> &EigenPairs {
        &self.state
    }

    fn last_step_flops(&self) -> u64 {
        self.flops
    }

    /// aux_u layout: `[flops]`; μ travels in the descriptor, so the
    /// rebuilt tracker already carries it.
    fn save_state(&self) -> anyhow::Result<crate::tracking::traits::TrackerState> {
        Ok(crate::tracking::traits::TrackerState {
            pairs: self.state.clone(),
            aux_u: vec![self.flops],
            aux_f: vec![],
            adjacency: None,
        })
    }

    fn restore_state(
        &mut self,
        st: crate::tracking::traits::TrackerState,
    ) -> anyhow::Result<()> {
        if st.aux_u.len() != 1 {
            anyhow::bail!("Residual-Modes state layout mismatch");
        }
        self.flops = st.aux_u[0];
        self.state = st.pairs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::tracking::traits::{apply_delta, init_eigenpairs};

    fn banded(n: usize) -> crate::sparse::csr::Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, (n - i) as f64);
        }
        for i in 0..n - 1 {
            coo.push_sym(i, i + 1, 0.5);
        }
        coo.to_csr()
    }

    #[test]
    fn residual_correction_improves_on_trip_basic() {
        use crate::tracking::trip_basic::TripBasic;
        let a = banded(20);
        let init = init_eigenpairs(&a, 3, 1);
        let mut rm = ResidualModes::new(init.clone());
        let mut tb = TripBasic::new(init);
        // perturbation coupling tracked and untracked directions
        let mut k = Coo::new(20, 20);
        k.push_sym(0, 15, 0.8);
        k.push_sym(1, 18, 0.6);
        let d = Delta::from_blocks(20, 0, &k, &Coo::new(20, 0), &Coo::new(0, 0));
        rm.update(&d).unwrap();
        tb.update(&d).unwrap();
        let exact = crate::linalg::eigh::eigh(&apply_delta(&a, &d).to_dense());
        let order = exact.leading_by_magnitude(3);
        let mut rm_better = 0;
        for j in 0..3 {
            let ov_rm = blas::dot(rm.current().vectors.col(j), exact.vectors.col(order[j])).abs();
            let ov_tb = blas::dot(tb.current().vectors.col(j), exact.vectors.col(order[j])).abs();
            if ov_rm >= ov_tb - 1e-12 {
                rm_better += 1;
            }
        }
        assert!(rm_better >= 2, "RM better on {rm_better}/3");
    }

    #[test]
    fn expansion_gives_nonzero_new_rows() {
        // unlike TRIP, RM's residual term sees Gᵀx_j (Prop. 1 proof)
        let a = banded(10);
        let init = init_eigenpairs(&a, 2, 2);
        let mut rm = ResidualModes::new(init);
        let kb = Coo::new(10, 10);
        let mut g = Coo::new(10, 1);
        g.push(0, 0, 1.0);
        let c = Coo::new(1, 1);
        let d = Delta::from_blocks(10, 1, &kb, &g, &c);
        rm.update(&d).unwrap();
        assert!(
            rm.current().vectors.get(10, 0).abs() > 1e-8,
            "new-node row should receive residual mass"
        );
    }

    #[test]
    fn mu_zero_matches_paper_default() {
        let a = banded(8);
        let init = init_eigenpairs(&a, 2, 3);
        let rm = ResidualModes::new(init);
        assert_eq!(rm.mu, 0.0);
    }
}
