//! Laplacian eigenpair tracking (paper Sec. 4.2).
//!
//! The trailing eigenpairs of L (or Lₙ) are the leading eigenpairs of the
//! shifted operator T = αI − L (resp. Tₙ = 2I − Lₙ = I + D^{-1/2}AD^{-1/2}),
//! so any adjacency tracker runs unchanged on the shifted matrices.  This
//! module converts adjacency snapshots to shifted (normalized) Laplacians
//! and their per-step Δ_T updates, and maps tracked (μ, φ) back to
//! Laplacian eigenpairs ν = α − μ.

use crate::graph::scenario::DynamicScenario;
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::sparse::delta::Delta;
use std::collections::HashMap;

/// T = αI − (D − A) for an adjacency matrix.
pub fn shifted_laplacian(adj: &Csr, alpha: f64) -> Csr {
    let n = adj.n_rows;
    let deg = adj.row_sums();
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, alpha - deg[i]);
        let (cols, vals) = adj.row(i);
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            if j != i {
                coo.push(i, j, v);
            }
        }
    }
    coo.to_csr()
}

/// Tₙ = 2I − Lₙ = I + D^{-1/2} A D^{-1/2}.
pub fn shifted_normalized_laplacian(adj: &Csr, _unused: f64) -> Csr {
    let n = adj.n_rows;
    let deg = adj.row_sums();
    let dinv: Vec<f64> = deg
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect();
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 1.0);
        let (cols, vals) = adj.row(i);
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            if j != i {
                coo.push(i, j, v * dinv[i] * dinv[j]);
            }
        }
    }
    coo.to_csr()
}

/// A picked shift α for a whole scenario: 2·d_max over the horizon (the
/// Gershgorin bound of Sec. 4.2), so the shift never needs to change
/// mid-run (a changing α would shift old eigenvalues inconsistently).
pub fn pick_alpha(sc: &DynamicScenario) -> f64 {
    let final_adj = sc
        .steps
        .last()
        .map(|s| &s.adjacency)
        .unwrap_or(&sc.initial);
    let dmax = final_adj
        .row_sums()
        .into_iter()
        .fold(0.0f64, f64::max);
    2.0 * dmax
}

/// Which shifted operator a scenario is converted to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Shift {
    /// T = αI − L (combinatorial Laplacian under the Gershgorin shift).
    Combinatorial { alpha: f64 },
    /// Tₙ = 2I − Lₙ = I + D^{-1/2} A D^{-1/2}.
    Normalized,
}

/// Δ_T for T = αI − L, assembled directly from the adjacency update in
/// O(nnz(Δ)): off-diagonal entries are the adjacency delta itself and
/// the diagonal absorbs the incremental degree changes — −Δdᵢ for
/// existing nodes, α − dᵢ for new ones (their whole adjacency row is in
/// Δ, so Δ's row sum *is* their degree).
pub fn shifted_laplacian_delta(adj_delta: &Delta, alpha: f64) -> Delta {
    let n_old = adj_delta.n_old;
    let n = adj_delta.n_new();
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        let (cols, vals) = adj_delta.full.row(i);
        let mut rs = 0.0;
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            coo.push(i, j, v);
            rs += v;
        }
        let diag = if i < n_old { -rs } else { alpha - rs };
        coo.push(i, i, diag);
    }
    Delta { n_old, s_new: adj_delta.s_new, full: coo.to_csr() }
}

/// Δ_Tₙ for the shifted normalized Laplacian, assembled incrementally:
/// a degree change at node i rescales *all* of i's incident entries, so
/// only the rows of nodes incident to the update are recomputed — each
/// as a sorted merge of its old and new adjacency rows under the old
/// and new D^{-1/2} — for O(Σ_{i touched} deg(i)) total instead of a
/// full rebuild.  Untouched neighbors receive the mirrored entry.
///
/// Caveat for operators maintained with `Csr::apply_delta`: entry
/// values drift from the freshly computed products by ≲ a few ulp per
/// rescale, so an edge *removal* after earlier rescales can leave a
/// ~1e-16 structural residue instead of an exact zero.  Numerically
/// harmless (values match the full rebuild to ~1e-15 per step), but
/// under heavy removal churn the maintained operator's nnz can carry
/// such ghost entries; the in-repo streams are add/expansion-only.
pub fn shifted_normalized_delta(a_old: &Csr, a_new: &Csr, adj_delta: &Delta) -> Delta {
    let n_old = adj_delta.n_old;
    let n = adj_delta.n_new();
    assert_eq!(a_old.n_rows, n_old);
    assert_eq!(a_new.n_rows, n);
    let dptr = &adj_delta.full.indptr;
    let touched: Vec<bool> = (0..n).map(|i| dptr[i + 1] > dptr[i]).collect();
    // memoized D^{-1/2} per node (old and new), computed from the
    // incident adjacency rows only when first needed
    let mut dinv_new: HashMap<usize, f64> = HashMap::new();
    let mut dinv_old: HashMap<usize, f64> = HashMap::new();
    let dinv_of = |a: &Csr, i: usize| -> f64 {
        if i >= a.n_rows {
            return 0.0;
        }
        let d: f64 = a.row(i).1.iter().sum();
        if d > 0.0 {
            1.0 / d.sqrt()
        } else {
            0.0
        }
    };
    let mut coo = Coo::new(n, n);
    let empty_c: &[usize] = &[];
    let empty_v: &[f64] = &[];
    for i in 0..n {
        if i >= n_old {
            // every node carries a unit diagonal; for new nodes it is
            // itself part of Δ_Tₙ
            coo.push(i, i, 1.0);
        }
        if !touched[i] {
            continue;
        }
        let di_new = *dinv_new.entry(i).or_insert_with(|| dinv_of(a_new, i));
        let di_old = *dinv_old.entry(i).or_insert_with(|| dinv_of(a_old, i));
        let (oc, ov) = if i < n_old { a_old.row(i) } else { (empty_c, empty_v) };
        let (nc, nv) = a_new.row(i);
        let (mut p, mut q) = (0usize, 0usize);
        while p < oc.len() || q < nc.len() {
            let (j, vo, vn) = if q >= nc.len() || (p < oc.len() && oc[p] < nc[q]) {
                let r = (oc[p], ov[p], 0.0);
                p += 1;
                r
            } else if p >= oc.len() || nc[q] < oc[p] {
                let r = (nc[q], 0.0, nv[q]);
                q += 1;
                r
            } else {
                let r = (oc[p], ov[p], nv[q]);
                p += 1;
                q += 1;
                r
            };
            let dj_new = *dinv_new.entry(j).or_insert_with(|| dinv_of(a_new, j));
            let dj_old = *dinv_old.entry(j).or_insert_with(|| dinv_of(a_old, j));
            let dv = vn * di_new * dj_new - vo * di_old * dj_old;
            if dv != 0.0 {
                coo.push(i, j, dv);
                if !touched[j] {
                    // j's own row is never visited: mirror the change
                    coo.push(j, i, dv);
                }
            }
        }
    }
    Delta { n_old, s_new: adj_delta.s_new, full: coo.to_csr() }
}

/// Convert an adjacency scenario into a shifted-operator scenario:
/// returns (T⁽⁰⁾, per-step (Δ_T, T⁽ᵗ⁾)).  The per-step Δ_T is assembled
/// incrementally from the adjacency delta ([`shifted_laplacian_delta`]
/// / [`shifted_normalized_delta`]) and T⁽ᵗ⁾ is maintained with the
/// `Csr::apply_delta` row-merge — the full operator is built from
/// scratch only once, at t = 0.  [`shifted_laplacian`] and
/// [`shifted_normalized_laplacian`] remain the full-rebuild test
/// oracles.
pub fn shifted_scenario(sc: &DynamicScenario, shift: Shift) -> (Csr, Vec<(Delta, Csr)>) {
    let t0 = match shift {
        Shift::Combinatorial { alpha } => shifted_laplacian(&sc.initial, alpha),
        Shift::Normalized => shifted_normalized_laplacian(&sc.initial, 0.0),
    };
    let mut prev_t = t0.clone();
    let mut prev_adj = &sc.initial;
    let mut steps = Vec::with_capacity(sc.steps.len());
    for s in &sc.steps {
        let dt = match shift {
            Shift::Combinatorial { alpha } => shifted_laplacian_delta(&s.delta, alpha),
            Shift::Normalized => shifted_normalized_delta(prev_adj, &s.adjacency, &s.delta),
        };
        let t = prev_t.apply_delta(&dt);
        prev_t = t.clone();
        prev_adj = &s.adjacency;
        steps.push((dt, t));
    }
    (t0, steps)
}

/// Map tracked shifted eigenvalues μ back to Laplacian eigenvalues
/// ν = α − μ (use α = 2 for the normalized variant).
pub fn unshift_values(mu: &[f64], alpha: f64) -> Vec<f64> {
    mu.iter().map(|m| alpha - m).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh::eigh;
    use crate::linalg::rng::Rng;

    #[test]
    fn shifted_laplacian_spectrum_relation() {
        let mut rng = Rng::new(1);
        let g = crate::graph::generators::erdos_renyi(30, 0.15, &mut rng);
        let adj = g.adjacency();
        let alpha = 2.0 * adj.row_sums().into_iter().fold(0.0f64, f64::max);
        let t = shifted_laplacian(&adj, alpha);
        // eig(T) = alpha - eig(L), eigenvectors shared
        let l = g.laplacian();
        let et = eigh(&t.to_dense());
        let el = eigh(&l.to_dense());
        for i in 0..30 {
            let vt = et.values[i];
            let vl = el.values[29 - i];
            assert!((vt - (alpha - vl)).abs() < 1e-8);
        }
        // leading eigenvalue of T corresponds to the trailing of L (=0)
        let top_t = et.values[29];
        assert!((top_t - alpha).abs() < 1e-8);
    }

    #[test]
    fn shifted_normalized_in_range() {
        let mut rng = Rng::new(2);
        let g = crate::graph::generators::erdos_renyi(25, 0.2, &mut rng);
        let tn = shifted_normalized_laplacian(&g.adjacency(), 0.0);
        let e = eigh(&tn.to_dense());
        for v in &e.values {
            assert!(*v > -1e-9 && *v < 2.0 + 1e-9, "eig {v}");
        }
        // top eigenvalue is 2 - λmin(Ln) = 2 for each connected component
        assert!((e.values[24] - 2.0).abs() < 1e-8);
    }

    #[test]
    fn shifted_scenario_consistency() {
        let mut rng = Rng::new(3);
        let g = crate::graph::generators::erdos_renyi(40, 0.15, &mut rng);
        let sc = crate::graph::scenario::scenario1_from_static("er", &g, 3);
        let alpha = pick_alpha(&sc);
        let (t0, steps) = shifted_scenario(&sc, Shift::Combinatorial { alpha });
        assert_eq!(t0.n_rows, sc.initial.n_rows);
        let mut prev = t0;
        for (d, t) in &steps {
            let rebuilt = crate::tracking::traits::apply_delta(&prev, d);
            let mut diff = rebuilt.to_dense();
            diff.axpy(-1.0, &t.to_dense());
            assert!(diff.max_abs() < 1e-10);
            prev = t.clone();
        }
    }

    #[test]
    fn incremental_shifted_deltas_match_full_rebuild_oracle() {
        // Scenario-2-style stream (K-block churn + expansion): the
        // incremental Δ_T and maintained T⁽ᵗ⁾ must match the
        // shift-everything-and-diff oracle for both operators
        let mut rng = Rng::new(6);
        let (_, stream) = crate::graph::generators::ba_with_arrivals(60, 2, &mut rng);
        let sc = crate::graph::scenario::scenario2_from_stream("ba", &stream, 4);
        let alpha = pick_alpha(&sc);
        for shift in [Shift::Combinatorial { alpha }, Shift::Normalized] {
            let full = |adj: &Csr| match shift {
                Shift::Combinatorial { alpha } => shifted_laplacian(adj, alpha),
                Shift::Normalized => shifted_normalized_laplacian(adj, 0.0),
            };
            let (t0, steps) = shifted_scenario(&sc, shift);
            let mut prev_oracle = full(&sc.initial);
            {
                let mut d0 = t0.to_dense();
                d0.axpy(-1.0, &prev_oracle.to_dense());
                assert!(d0.max_abs() == 0.0, "t0 must be the full shift");
            }
            for (step, (dt, t)) in sc.steps.iter().zip(steps.iter()) {
                let t_oracle = full(&step.adjacency);
                let d_oracle = Delta::from_diff(&prev_oracle, &t_oracle);
                assert_eq!(dt.n_old, d_oracle.n_old);
                assert_eq!(dt.s_new, d_oracle.s_new);
                let mut dd = dt.full.to_dense();
                dd.axpy(-1.0, &d_oracle.full.to_dense());
                assert!(dd.max_abs() < 1e-12, "{shift:?}: Δ_T mismatch {}", dd.max_abs());
                let mut td = t.to_dense();
                td.axpy(-1.0, &t_oracle.to_dense());
                assert!(td.max_abs() < 1e-12, "{shift:?}: T mismatch {}", td.max_abs());
                prev_oracle = t_oracle;
            }
        }
    }

    #[test]
    fn shifted_laplacian_delta_handles_isolated_new_nodes() {
        // an expansion delta with an edgeless new node: its diagonal
        // must still carry α (combinatorial) / 1 (normalized)
        use crate::sparse::coo::Coo;
        let mut a_old = Coo::new(3, 3);
        a_old.push_sym(0, 1, 1.0);
        a_old.push_sym(1, 2, 1.0);
        let a_old = a_old.to_csr();
        // new node 3 connects to 0; new node 4 is isolated
        let mut g = Coo::new(3, 2);
        g.push(0, 0, 1.0);
        let d = Delta::from_blocks(3, 2, &Coo::new(3, 3), &g, &Coo::new(2, 2));
        let a_new = a_old.apply_delta(&d);
        let alpha = 6.0;
        let dt = shifted_laplacian_delta(&d, alpha);
        let want = Delta::from_diff(
            &shifted_laplacian(&a_old, alpha),
            &shifted_laplacian(&a_new, alpha),
        );
        let mut diff = dt.full.to_dense();
        diff.axpy(-1.0, &want.full.to_dense());
        assert!(diff.max_abs() < 1e-12);
        assert_eq!(dt.full.get(4, 4), alpha);

        let dtn = shifted_normalized_delta(&a_old, &a_new, &d);
        let wantn = Delta::from_diff(
            &shifted_normalized_laplacian(&a_old, 0.0),
            &shifted_normalized_laplacian(&a_new, 0.0),
        );
        let mut diffn = dtn.full.to_dense();
        diffn.axpy(-1.0, &wantn.full.to_dense());
        assert!(diffn.max_abs() < 1e-12);
        assert_eq!(dtn.full.get(4, 4), 1.0);
    }

    #[test]
    fn tracking_smallest_laplacian_eigenpairs_via_grest() {
        // end-to-end: track trailing eigenpairs of L via T = αI − L
        use crate::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};
        let mut rng = Rng::new(4);
        let g = crate::graph::generators::erdos_renyi(60, 0.12, &mut rng);
        let sc = crate::graph::scenario::scenario1_from_static("er", &g, 3);
        let alpha = pick_alpha(&sc);
        let (t0, steps) = shifted_scenario(&sc, Shift::Combinatorial { alpha });
        let init = init_eigenpairs(&t0, 4, 5);
        let mut tracker = GRest::new(init, SubspaceMode::Full);
        for (d, _) in &steps {
            tracker.update(d).unwrap();
        }
        let final_t = &steps.last().unwrap().1;
        let exact = eigh(&final_t.to_dense());
        // the top tracked eigenvalue of T must match 2dmax - 0 = alpha
        // only for connected graphs; instead compare against exact top
        let top_exact = exact.values[final_t.n_rows - 1];
        assert!(
            (tracker.current().values[0] - top_exact).abs() < 0.05 * top_exact.abs().max(1.0),
            "{} vs {}",
            tracker.current().values[0],
            top_exact
        );
        let nu = unshift_values(&tracker.current().values, alpha);
        assert!(nu[0] < 1.0, "smallest Laplacian eigenvalue ~0, got {}", nu[0]);
    }
}
