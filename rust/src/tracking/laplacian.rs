//! Laplacian eigenpair tracking (paper Sec. 4.2).
//!
//! The trailing eigenpairs of L (or Lₙ) are the leading eigenpairs of the
//! shifted operator T = αI − L (resp. Tₙ = 2I − Lₙ = I + D^{-1/2}AD^{-1/2}),
//! so any adjacency tracker runs unchanged on the shifted matrices.  This
//! module converts adjacency snapshots to shifted (normalized) Laplacians
//! and their per-step Δ_T updates, and maps tracked (μ, φ) back to
//! Laplacian eigenpairs ν = α − μ.

use crate::graph::scenario::DynamicScenario;
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::sparse::delta::Delta;

/// T = αI − (D − A) for an adjacency matrix.
pub fn shifted_laplacian(adj: &Csr, alpha: f64) -> Csr {
    let n = adj.n_rows;
    let deg = adj.row_sums();
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, alpha - deg[i]);
        let (cols, vals) = adj.row(i);
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            if j != i {
                coo.push(i, j, v);
            }
        }
    }
    coo.to_csr()
}

/// Tₙ = 2I − Lₙ = I + D^{-1/2} A D^{-1/2}.
pub fn shifted_normalized_laplacian(adj: &Csr, _unused: f64) -> Csr {
    let n = adj.n_rows;
    let deg = adj.row_sums();
    let dinv: Vec<f64> = deg
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect();
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 1.0);
        let (cols, vals) = adj.row(i);
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            if j != i {
                coo.push(i, j, v * dinv[i] * dinv[j]);
            }
        }
    }
    coo.to_csr()
}

/// A picked shift α for a whole scenario: 2·d_max over the horizon (the
/// Gershgorin bound of Sec. 4.2), so the shift never needs to change
/// mid-run (a changing α would shift old eigenvalues inconsistently).
pub fn pick_alpha(sc: &DynamicScenario) -> f64 {
    let final_adj = sc
        .steps
        .last()
        .map(|s| &s.adjacency)
        .unwrap_or(&sc.initial);
    let dmax = final_adj
        .row_sums()
        .into_iter()
        .fold(0.0f64, f64::max);
    2.0 * dmax
}

/// Convert an adjacency scenario into a shifted-operator scenario:
/// returns (T⁽⁰⁾, per-step (Δ_T, T⁽ᵗ⁾)).  `shift` is either
/// [`shifted_laplacian`] (with `alpha`) or
/// [`shifted_normalized_laplacian`] (alpha ignored).
pub fn shifted_scenario(
    sc: &DynamicScenario,
    shift: fn(&Csr, f64) -> Csr,
    alpha: f64,
) -> (Csr, Vec<(Delta, Csr)>) {
    let t0 = shift(&sc.initial, alpha);
    let mut prev = t0.clone();
    let mut steps = Vec::with_capacity(sc.steps.len());
    for s in &sc.steps {
        let t = shift(&s.adjacency, alpha);
        let d = Delta::from_diff(&prev, &t);
        prev = t.clone();
        steps.push((d, t));
    }
    (t0, steps)
}

/// Map tracked shifted eigenvalues μ back to Laplacian eigenvalues
/// ν = α − μ (use α = 2 for the normalized variant).
pub fn unshift_values(mu: &[f64], alpha: f64) -> Vec<f64> {
    mu.iter().map(|m| alpha - m).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh::eigh;
    use crate::linalg::rng::Rng;

    #[test]
    fn shifted_laplacian_spectrum_relation() {
        let mut rng = Rng::new(1);
        let g = crate::graph::generators::erdos_renyi(30, 0.15, &mut rng);
        let adj = g.adjacency();
        let alpha = 2.0 * adj.row_sums().into_iter().fold(0.0f64, f64::max);
        let t = shifted_laplacian(&adj, alpha);
        // eig(T) = alpha - eig(L), eigenvectors shared
        let l = g.laplacian();
        let et = eigh(&t.to_dense());
        let el = eigh(&l.to_dense());
        for i in 0..30 {
            let vt = et.values[i];
            let vl = el.values[29 - i];
            assert!((vt - (alpha - vl)).abs() < 1e-8);
        }
        // leading eigenvalue of T corresponds to the trailing of L (=0)
        let top_t = et.values[29];
        assert!((top_t - alpha).abs() < 1e-8);
    }

    #[test]
    fn shifted_normalized_in_range() {
        let mut rng = Rng::new(2);
        let g = crate::graph::generators::erdos_renyi(25, 0.2, &mut rng);
        let tn = shifted_normalized_laplacian(&g.adjacency(), 0.0);
        let e = eigh(&tn.to_dense());
        for v in &e.values {
            assert!(*v > -1e-9 && *v < 2.0 + 1e-9, "eig {v}");
        }
        // top eigenvalue is 2 - λmin(Ln) = 2 for each connected component
        assert!((e.values[24] - 2.0).abs() < 1e-8);
    }

    #[test]
    fn shifted_scenario_consistency() {
        let mut rng = Rng::new(3);
        let g = crate::graph::generators::erdos_renyi(40, 0.15, &mut rng);
        let sc = crate::graph::scenario::scenario1_from_static("er", &g, 3);
        let alpha = pick_alpha(&sc);
        let (t0, steps) = shifted_scenario(&sc, shifted_laplacian, alpha);
        assert_eq!(t0.n_rows, sc.initial.n_rows);
        let mut prev = t0;
        for (d, t) in &steps {
            let rebuilt = crate::tracking::traits::apply_delta(&prev, d);
            let mut diff = rebuilt.to_dense();
            diff.axpy(-1.0, &t.to_dense());
            assert!(diff.max_abs() < 1e-10);
            prev = t.clone();
        }
    }

    #[test]
    fn tracking_smallest_laplacian_eigenpairs_via_grest() {
        // end-to-end: track trailing eigenpairs of L via T = αI − L
        use crate::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};
        let mut rng = Rng::new(4);
        let g = crate::graph::generators::erdos_renyi(60, 0.12, &mut rng);
        let sc = crate::graph::scenario::scenario1_from_static("er", &g, 3);
        let alpha = pick_alpha(&sc);
        let (t0, steps) = shifted_scenario(&sc, shifted_laplacian, alpha);
        let init = init_eigenpairs(&t0, 4, 5);
        let mut tracker = GRest::new(init, SubspaceMode::Full);
        for (d, _) in &steps {
            tracker.update(d).unwrap();
        }
        let final_t = &steps.last().unwrap().1;
        let exact = eigh(&final_t.to_dense());
        // the top tracked eigenvalue of T must match 2dmax - 0 = alpha
        // only for connected graphs; instead compare against exact top
        let top_exact = exact.values[final_t.n_rows - 1];
        assert!(
            (tracker.current().values[0] - top_exact).abs() < 0.05 * top_exact.abs().max(1.0),
            "{} vs {}",
            tracker.current().values[0],
            top_exact
        );
        let nu = unshift_values(&tracker.current().values, alpha);
        assert!(nu[0] < 1.0, "smallest Laplacian eigenvalue ~0, got {}", nu[0]);
    }
}
