//! Matrix-function tracking (paper Sec. 4.1): h(A) ≈ X_K h(Λ_K) X_Kᵀ
//! from the tracked truncated eigendecomposition.  Used for subgraph
//! centrality (h = exp) and provided generically for polynomials, powers
//! and logs.

use crate::linalg::mat::Mat;
use crate::tracking::traits::EigenPairs;

/// h(A)·v ≈ X h(Λ) (Xᵀ v).
pub fn matfun_apply(pairs: &EigenPairs, h: impl Fn(f64) -> f64, v: &[f64]) -> Vec<f64> {
    let xt_v = crate::linalg::blas::gemv_t(&pairs.vectors, v);
    let scaled: Vec<f64> = xt_v
        .iter()
        .zip(pairs.values.iter())
        .map(|(c, &l)| c * h(l))
        .collect();
    crate::linalg::blas::gemv(&pairs.vectors, &scaled)
}

/// Dense h(A) ≈ X h(Λ) Xᵀ (small graphs / tests).
pub fn matfun_dense(pairs: &EigenPairs, h: impl Fn(f64) -> f64) -> Mat {
    let k = pairs.k();
    let mut xh = pairs.vectors.clone();
    for j in 0..k {
        let s = h(pairs.values[j]);
        for v in xh.col_mut(j) {
            *v *= s;
        }
    }
    xh.matmul(&pairs.vectors.t())
}

/// exp(A)·1 — the subgraph-centrality vector (Sec. 5.4).  Scaling by
/// e^{-λ₁} is applied for numerical stability; rankings are unaffected.
pub fn subgraph_centrality_scores(pairs: &EigenPairs) -> Vec<f64> {
    let lam_max = pairs
        .values
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    let ones = vec![1.0; pairs.n()];
    matfun_apply(pairs, |l| (l - lam_max).exp(), &ones)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh::eigh;
    use crate::linalg::rng::Rng;

    fn full_pairs(a: &Mat) -> EigenPairs {
        let e = eigh(a);
        let order = e.leading_by_magnitude(a.rows());
        let values: Vec<f64> = order.iter().map(|&i| e.values[i]).collect();
        EigenPairs { values, vectors: e.vectors.select_cols(&order) }
    }

    #[test]
    fn identity_function_reconstructs_matrix() {
        let mut rng = Rng::new(1);
        let raw = Mat::randn(12, 12, &mut rng);
        let mut a = raw.clone();
        a.axpy(1.0, &raw.t());
        a.scale(0.5);
        let pairs = full_pairs(&a);
        let rec = matfun_dense(&pairs, |l| l);
        let mut diff = rec;
        diff.axpy(-1.0, &a);
        assert!(diff.max_abs() < 1e-8);
    }

    #[test]
    fn square_function_matches_a_squared() {
        let mut rng = Rng::new(2);
        let raw = Mat::randn(10, 10, &mut rng);
        let mut a = raw.clone();
        a.axpy(1.0, &raw.t());
        a.scale(0.5);
        let pairs = full_pairs(&a);
        let sq = matfun_dense(&pairs, |l| l * l);
        let want = a.matmul(&a);
        let mut diff = sq;
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-7);
    }

    #[test]
    fn exp_via_taylor_agreement() {
        // small-norm symmetric matrix: exp(A)·1 vs 12-term Taylor
        let mut rng = Rng::new(3);
        let raw = Mat::randn(8, 8, &mut rng);
        let mut a = raw.clone();
        a.axpy(1.0, &raw.t());
        a.scale(0.05);
        let pairs = full_pairs(&a);
        let got = matfun_apply(&pairs, f64::exp, &vec![1.0; 8]);
        // Taylor
        let mut term = vec![1.0; 8];
        let mut sum = vec![1.0; 8];
        for k in 1..13 {
            term = crate::linalg::blas::gemv(&a, &term);
            for t in term.iter_mut() {
                *t /= k as f64;
            }
            for (s, t) in sum.iter_mut().zip(term.iter()) {
                *s += t;
            }
        }
        for i in 0..8 {
            assert!((got[i] - sum[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn centrality_ranks_hub_highest() {
        // star graph: center has the largest subgraph centrality
        let mut coo = crate::sparse::coo::Coo::new(7, 7);
        for i in 1..7 {
            coo.push_sym(0, i, 1.0);
        }
        let a = coo.to_csr().to_dense();
        let pairs = full_pairs(&a);
        let scores = subgraph_centrality_scores(&pairs);
        let max_idx = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_idx, 0);
    }
}
