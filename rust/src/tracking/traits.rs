//! The tracker interface shared by every algorithm, plus initialization
//! helpers.

use crate::linalg::lanczos::{lanczos_topk, LinOp};
use crate::linalg::mat::Mat;
use crate::linalg::rng::Rng;
use crate::sparse::csr::Csr;
use crate::sparse::delta::Delta;
use crate::tracking::spec::TrackerSpec;

/// K tracked eigenpairs, ordered by |λ| descending (paper convention).
#[derive(Clone)]
pub struct EigenPairs {
    pub values: Vec<f64>,
    /// N×K matrix, column j is the eigenvector of `values[j]`.
    pub vectors: Mat,
}

impl EigenPairs {
    pub fn k(&self) -> usize {
        self.values.len()
    }

    pub fn n(&self) -> usize {
        self.vectors.rows()
    }

    /// Rank-K reconstruction error ‖A − XΛXᵀ‖ restricted to the residual
    /// of each tracked pair: max_j ‖A x_j − λ_j x_j‖.
    pub fn max_residual(&self, a: &Csr) -> f64 {
        let ax = a.matmul_dense(&self.vectors);
        let mut worst = 0.0f64;
        for j in 0..self.k() {
            let mut r = 0.0;
            for i in 0..self.n() {
                let d = ax.get(i, j) - self.values[j] * self.vectors.get(i, j);
                r += d * d;
            }
            worst = worst.max(r.sqrt());
        }
        worst
    }
}

/// A tracker's complete internal state in a tracker-agnostic container,
/// for checkpointing (the durability tier).  Every f64 travels by bit
/// pattern end to end, so save → checkpoint → restore is *bitwise*
/// lossless.  Each tracker documents its own `aux_u`/`aux_f` layout;
/// the container stays schema-free so the checkpoint format never
/// changes when a tracker adds a field.
#[derive(Clone)]
pub struct TrackerState {
    /// The tracked eigenpair estimate.
    pub pairs: EigenPairs,
    /// Tracker-specific integer state (RNG words, counters, flops).
    pub aux_u: Vec<u64>,
    /// Tracker-specific float state (e.g. accumulated ‖Δ‖_F).
    pub aux_f: Vec<f64>,
    /// For trackers that retain the explicit adjacency (TIMERS, the
    /// reference): their private copy.
    pub adjacency: Option<Csr>,
}

/// A tracker consumes a stream of structured updates Δ⁽ᵗ⁾ and maintains
/// an estimate of the K leading eigenpairs.
pub trait EigTracker {
    /// Declarative identity of this tracker: the [`TrackerSpec`] that
    /// describes (and could rebuild) it.  The single source for display
    /// names, harness table rows, CSV keys, and service metrics.
    /// Ad-hoc trackers return [`TrackerSpec::custom`].
    fn descriptor(&self) -> TrackerSpec;

    /// Display name (used by the experiment harness / tables); derived
    /// from [`Self::descriptor`].
    fn name(&self) -> String {
        self.descriptor().display_name()
    }

    /// Apply one graph update.
    fn update(&mut self, delta: &Delta) -> anyhow::Result<()>;

    /// Current eigenpair estimate.
    fn current(&self) -> &EigenPairs;

    /// Approximate per-step FLOP count for complexity reporting
    /// (optional; 0 when not tracked).
    fn last_step_flops(&self) -> u64 {
        0
    }

    /// Serialize the full internal state for checkpointing.  Trackers
    /// that don't opt in (ad-hoc test trackers) inherit this default
    /// and simply can't be run with `ServiceConfig::durability`.
    fn save_state(&self) -> anyhow::Result<TrackerState> {
        anyhow::bail!("tracker '{}' does not support checkpointing", self.name())
    }

    /// Restore state captured by [`Self::save_state`] on a tracker
    /// built from the same descriptor.  Must be bitwise-exact: after
    /// restore, identical update streams produce identical floats.
    fn restore_state(&mut self, _state: TrackerState) -> anyhow::Result<()> {
        anyhow::bail!("tracker '{}' does not support checkpointing", self.name())
    }
}

/// Compute the initial K leading eigenpairs of A⁽⁰⁾ with Lanczos
/// (the paper's line 3 of Alg. 2; "any direct eigendecomposition").
pub fn init_eigenpairs(a0: &Csr, k: usize, seed: u64) -> EigenPairs {
    let mut rng = Rng::new(seed);
    let max_basis = (4 * k + 40).min(a0.n_rows);
    let (values, vectors) = lanczos_topk(a0, k, 1e-10, max_basis, &mut rng);
    EigenPairs { values, vectors }
}

/// Same, for an arbitrary symmetric operator.
pub fn init_eigenpairs_op(op: &dyn LinOp, k: usize, seed: u64) -> EigenPairs {
    let mut rng = Rng::new(seed);
    let max_basis = (4 * k + 40).min(op.dim());
    let (values, vectors) = lanczos_topk(op, k, 1e-10, max_basis, &mut rng);
    EigenPairs { values, vectors }
}

/// Shared helper: X̄ᵀ Δ X̄ = Xᵀ (ΔX̄)[0..N] — the K×K interaction matrix
/// every perturbation method needs (only sees the K block, Prop. 1).
pub fn interaction_matrix(x: &Mat, dxk: &Mat) -> Mat {
    let n = x.rows();
    let k = x.cols();
    let mut b = Mat::zeros(k, k);
    for j in 0..k {
        let dj = dxk.col(j);
        for i in 0..k {
            b.set(i, j, crate::linalg::blas::dot(x.col(i), &dj[..n]));
        }
    }
    b
}

/// Pad an adjacency with `delta`, producing Â = Ā + Δ (used by trackers
/// that must retain the explicit matrix: TIMERS, the reference).
pub fn apply_delta(a: &Csr, delta: &Delta) -> Csr {
    let n = delta.n_new();
    assert_eq!(a.n_rows, delta.n_old);
    let mut coo = crate::sparse::coo::Coo::new(n, n);
    for i in 0..a.n_rows {
        let (cols, vals) = a.row(i);
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            coo.push(i, j, v);
        }
    }
    for i in 0..n {
        let (cols, vals) = delta.full.row(i);
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            coo.push(i, j, v);
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    #[test]
    fn init_matches_dense() {
        let mut coo = Coo::new(10, 10);
        for i in 0..9 {
            coo.push_sym(i, i + 1, 1.0);
        }
        coo.push_sym(0, 9, 1.0);
        let a = coo.to_csr();
        let pairs = init_eigenpairs(&a, 3, 1);
        let dense = crate::linalg::eigh::eigh(&a.to_dense());
        let order = dense.leading_by_magnitude(3);
        for j in 0..3 {
            assert!((pairs.values[j].abs() - dense.values[order[j]].abs()).abs() < 1e-8);
        }
        assert!(pairs.max_residual(&a) < 1e-7);
    }

    #[test]
    fn apply_delta_reconstructs() {
        let mut a = Coo::new(3, 3);
        a.push_sym(0, 1, 1.0);
        let a = a.to_csr();
        let mut k = Coo::new(3, 3);
        k.push_sym(0, 1, -1.0);
        k.push_sym(1, 2, 1.0);
        let g = Coo::new(3, 1);
        let mut c = Coo::new(1, 1);
        let _ = &mut c;
        let d = Delta::from_blocks(3, 1, &k, &g, &c);
        let ahat = apply_delta(&a, &d);
        assert_eq!(ahat.n_rows, 4);
        assert_eq!(ahat.get(0, 1), 0.0);
        assert_eq!(ahat.get(1, 2), 1.0);
    }

    #[test]
    fn interaction_matrix_matches_dense() {
        use crate::linalg::rng::Rng;
        let mut rng = Rng::new(2);
        let x = Mat::randn(6, 3, &mut rng);
        let mut k = Coo::new(6, 6);
        k.push_sym(0, 3, 1.0);
        k.push_sym(2, 4, -1.0);
        let g = Coo::new(6, 2);
        let c = Coo::new(2, 2);
        let d = Delta::from_blocks(6, 2, &k, &g, &c);
        let dxk = d.mul_padded(&x);
        let b = interaction_matrix(&x, &dxk);
        // dense check
        let xbar = x.pad_rows(2);
        let want = xbar.t_matmul(&d.full.to_dense().matmul(&xbar));
        let mut diff = b.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-12);
    }
}
