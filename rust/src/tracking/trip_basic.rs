//! TRIP-Basic (Chen & Tong 2015; paper Sec. 2.3.1): first-order
//! perturbation update restricted to the K tracked eigenpairs,
//! Eqs. (5)–(6).

use crate::linalg::mat::Mat;
use crate::sparse::delta::Delta;
use crate::tracking::spec::{Algo, TrackerSpec};
use crate::tracking::traits::{interaction_matrix, EigTracker, EigenPairs};

/// Minimum eigenvalue gap before a correction term is skipped (the
/// first-order formula assumes simple eigenvalues).
const GAP_EPS: f64 = 1e-10;

pub struct TripBasic {
    state: EigenPairs,
    flops: u64,
}

impl TripBasic {
    pub fn new(initial: EigenPairs) -> TripBasic {
        TripBasic { state: initial, flops: 0 }
    }
}

impl EigTracker for TripBasic {
    fn descriptor(&self) -> TrackerSpec {
        TrackerSpec::new(Algo::TripBasic)
    }

    fn update(&mut self, delta: &Delta) -> anyhow::Result<()> {
        let k = self.state.k();
        let x = &self.state.vectors; // N×K (old dimension)
        let dxk = delta.mul_padded(x); // (N+S)×K
        let b = interaction_matrix(x, &dxk); // K×K, = X̄ᵀΔX̄
        self.flops = (2 * x.rows() * k * k) as u64 + 2 * delta.nnz() as u64 * k as u64;

        // eigenvalues: λ̃_j = λ_j + B_jj           (Eq. 5)
        let mut new_vals = Vec::with_capacity(k);
        for j in 0..k {
            new_vals.push(self.state.values[j] + b.get(j, j));
        }
        // eigenvectors: x̃_j = x̄_j + Σ_{i≠j} B_ij/(λ_j−λ_i) x̄_i   (Eq. 6)
        // (lives in the padded space; new-node rows stay zero — Prop. 1)
        let n_new = delta.n_new();
        let mut new_vecs = Mat::zeros(n_new, k);
        for j in 0..k {
            {
                let col = new_vecs.col_mut(j);
                col[..x.rows()].copy_from_slice(x.col(j));
            }
            for i in 0..k {
                if i == j {
                    continue;
                }
                let gap = self.state.values[j] - self.state.values[i];
                if gap.abs() < GAP_EPS {
                    continue;
                }
                let coeff = b.get(i, j) / gap;
                let (src_start, _) = (0usize, 0usize);
                let _ = src_start;
                let xi = x.col(i).to_vec();
                let col = new_vecs.col_mut(j);
                for (r, &v) in xi.iter().enumerate() {
                    col[r] += coeff * v;
                }
            }
            // normalize
            let nrm = crate::linalg::blas::nrm2(new_vecs.col(j)).max(1e-300);
            for v in new_vecs.col_mut(j) {
                *v /= nrm;
            }
        }
        self.state = EigenPairs { values: new_vals, vectors: new_vecs };
        Ok(())
    }

    fn current(&self) -> &EigenPairs {
        &self.state
    }

    fn last_step_flops(&self) -> u64 {
        self.flops
    }

    /// aux_u layout: `[flops]`.  TRIP-Basic is stateless beyond pairs.
    fn save_state(&self) -> anyhow::Result<crate::tracking::traits::TrackerState> {
        Ok(crate::tracking::traits::TrackerState {
            pairs: self.state.clone(),
            aux_u: vec![self.flops],
            aux_f: vec![],
            adjacency: None,
        })
    }

    fn restore_state(
        &mut self,
        st: crate::tracking::traits::TrackerState,
    ) -> anyhow::Result<()> {
        if st.aux_u.len() != 1 {
            anyhow::bail!("TRIP-Basic state layout mismatch");
        }
        self.flops = st.aux_u[0];
        self.state = st.pairs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::tracking::traits::init_eigenpairs;

    /// ring graph adjacency
    fn ring(n: usize) -> crate::sparse::csr::Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            coo.push_sym(i, (i + 1) % n, 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn zero_delta_is_fixed_point() {
        let a = ring(12);
        let init = init_eigenpairs(&a, 3, 1);
        let vals0 = init.values.clone();
        let mut t = TripBasic::new(init);
        let d = Delta::from_blocks(12, 0, &Coo::new(12, 12), &Coo::new(12, 0), &Coo::new(0, 0));
        t.update(&d).unwrap();
        for (a, b) in t.current().values.iter().zip(vals0.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn corollary2_pure_expansion_leaves_eigenvalues() {
        // K = 0 block ⇒ λ̃ = λ exactly (paper Corollary 2)
        let a = ring(10);
        let init = init_eigenpairs(&a, 3, 2);
        let vals0 = init.values.clone();
        let mut t = TripBasic::new(init);
        let k = Coo::new(10, 10);
        let mut g = Coo::new(10, 2);
        g.push(0, 0, 1.0);
        g.push(5, 1, 1.0);
        let mut c = Coo::new(2, 2);
        c.push_sym(0, 1, 1.0);
        let d = Delta::from_blocks(10, 2, &k, &g, &c);
        t.update(&d).unwrap();
        assert_eq!(t.current().n(), 12);
        for (a, b) in t.current().values.iter().zip(vals0.iter()) {
            assert!((a - b).abs() < 1e-12, "Corollary 2 violated");
        }
        // new-node rows of the eigenvectors are zero (Prop. 1)
        for j in 0..3 {
            assert_eq!(t.current().vectors.get(10, j), 0.0);
            assert_eq!(t.current().vectors.get(11, j), 0.0);
        }
    }

    #[test]
    fn small_perturbation_tracks_first_order() {
        // weighted perturbation of a diagonal-ish matrix with clear gaps
        let mut coo = Coo::new(8, 8);
        for i in 0..8 {
            coo.push(i, i, (8 - i) as f64 * 2.0);
        }
        let a = coo.to_csr();
        let init = init_eigenpairs(&a, 3, 3);
        let mut t = TripBasic::new(init);
        let mut k = Coo::new(8, 8);
        k.push_sym(0, 1, 0.01);
        let d = Delta::from_blocks(8, 0, &k, &Coo::new(8, 0), &Coo::new(0, 0));
        t.update(&d).unwrap();
        // exact: eigh of A+Δ
        let ahat = crate::tracking::traits::apply_delta(&a, &d);
        let exact = crate::linalg::eigh::eigh(&ahat.to_dense());
        let order = exact.leading_by_magnitude(3);
        for j in 0..3 {
            assert!(
                (t.current().values[j] - exact.values[order[j]]).abs() < 1e-3,
                "λ{j}"
            );
        }
    }
}
