//! detlint — the zero-dependency concurrency/determinism lint for
//! `rust/src`.
//!
//! Run from the repo root (CI gates on it):
//!
//! ```text
//! cargo run --bin detlint            # lint the tree; exit 1 on findings
//! cargo run --bin detlint -- --self-test   # prove every rule fires
//! ```
//!
//! Line-oriented by design: no parser, no dependencies, fast enough to
//! run on every commit.  The rules encode this repo's concurrency and
//! determinism contracts:
//!
//! | rule                      | contract                                                      |
//! |---------------------------|---------------------------------------------------------------|
//! | `raw-std-sync`            | all sync primitives come from the `crate::sync` facade, so    |
//! |                           | the loom harness model-checks the exact shipped protocol      |
//! | `hash-iter`               | deterministic modules (`linalg/`, `tracking/`, `tasks/`,      |
//! |                           | `sparse/`) never iterate a `HashMap`/`HashSet` (random order) |
//! | `into-alloc`              | `_into` kernels are allocation-free (`Vec::new`, `vec!`,      |
//! |                           | `.to_vec()`, `.clone()`, `hcat` banned in their bodies)       |
//! | `relaxed-outside-metrics` | `Ordering::Relaxed` only in `coordinator/metrics.rs`          |
//! | `ordering-comment`        | every `Acquire`/`Release`/`AcqRel` carries an `// ordering:`  |
//! |                           | justification within the preceding lines                      |
//! | `coordinator-unwrap`      | no `.unwrap()`/`.expect(` in non-test coordinator code        |
//! |                           | (poison policy is centralized in `sync.rs`)                   |
//! | `thread-spawn`            | no `std::thread::scope`/`spawn` outside `linalg/threads.rs`   |
//! |                           | and `sync.rs` — kernels dispatch on the persistent pool       |
//! | `raw-intrinsics`          | no `std::arch`/`core::arch` outside `linalg/gemm_simd.rs` —   |
//! |                           | one audited home for SIMD `unsafe`, scalar code everywhere else |
//! | `raw-fs`                  | no `std::fs`/`File::create` outside the durability tier's     |
//! |                           | `StorageBackend` impls and the audited plain-file I/O homes   |
//! |                           | (`graph/io.rs`, `eval/table.rs`, `runtime/artifact.rs`,       |
//! |                           | `main.rs`) — durable writes must be fault-injectable          |
//!
//! Audited exceptions live in `rust/detlint.allow`, one per line as
//! `rule:path-suffix:needle`; a finding is suppressed when all three
//! match.  Heuristic limits: `hash-iter` tracks `let`-bound hash
//! collections per file, and the `#[cfg(test)] mod tests` tail (this
//! repo's convention puts tests last) is skipped for the `hash-iter`,
//! `coordinator-unwrap`, `thread-spawn`, and `raw-fs` rules — test
//! code may unwrap, spawn helper threads, and touch temp files.  The `relaxed-outside-metrics`
//! rule is deliberately strict: tests inside `rust/src` hold to it
//! too.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Rule {
    RawStdSync,
    HashIter,
    IntoAlloc,
    RelaxedOutsideMetrics,
    OrderingComment,
    CoordinatorUnwrap,
    ThreadSpawn,
    RawIntrinsics,
    RawFs,
}

impl Rule {
    fn name(self) -> &'static str {
        match self {
            Rule::RawStdSync => "raw-std-sync",
            Rule::HashIter => "hash-iter",
            Rule::IntoAlloc => "into-alloc",
            Rule::RelaxedOutsideMetrics => "relaxed-outside-metrics",
            Rule::OrderingComment => "ordering-comment",
            Rule::CoordinatorUnwrap => "coordinator-unwrap",
            Rule::ThreadSpawn => "thread-spawn",
            Rule::RawIntrinsics => "raw-intrinsics",
            Rule::RawFs => "raw-fs",
        }
    }
}

struct Finding {
    rule: Rule,
    path: String,
    line: usize,
    text: String,
}

/// Strip comments and blank out string/char literal contents, carrying
/// block-comment state across lines, so rule needles never match inside
/// comments or message strings.
fn strip_code(src: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_block_comment = false;
    for line in src.lines() {
        let mut code = String::with_capacity(line.len());
        let bytes: Vec<char> = line.chars().collect();
        let mut i = 0;
        while i < bytes.len() {
            if in_block_comment {
                if bytes[i] == '*' && bytes.get(i + 1) == Some(&'/') {
                    in_block_comment = false;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            match bytes[i] {
                '/' if bytes.get(i + 1) == Some(&'/') => break, // line comment
                '/' if bytes.get(i + 1) == Some(&'*') => {
                    in_block_comment = true;
                    i += 2;
                }
                '"' => {
                    // blank the string body, keep the quotes
                    code.push('"');
                    i += 1;
                    while i < bytes.len() {
                        if bytes[i] == '\\' {
                            i += 2;
                        } else if bytes[i] == '"' {
                            code.push('"');
                            i += 1;
                            break;
                        } else {
                            i += 1;
                        }
                    }
                }
                '\'' => {
                    // char literal ('x' / '\n') vs lifetime ('a)
                    let is_char = bytes.get(i + 1) == Some(&'\\')
                        || (bytes.get(i + 2) == Some(&'\'') && bytes.get(i + 1) != Some(&'\''));
                    if is_char {
                        code.push_str("' '");
                        i += 1;
                        while i < bytes.len() {
                            if bytes[i] == '\\' {
                                i += 2;
                            } else if bytes[i] == '\'' {
                                i += 1;
                                break;
                            } else {
                                i += 1;
                            }
                        }
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                }
                c => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        out.push(code);
    }
    out
}

/// Index of the `#[cfg(test)] mod tests` tail (this repo keeps unit
/// tests at the end of each file), or `usize::MAX` when absent.
fn test_tail_start(raw: &[&str]) -> usize {
    for (i, l) in raw.iter().enumerate() {
        if l.trim() == "#[cfg(test)]" {
            if let Some(next) = raw.get(i + 1) {
                if next.trim_start().starts_with("mod ") {
                    return i;
                }
            }
        }
    }
    usize::MAX
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Binding name from a `let [mut] name[: ty] = ...HashMap/HashSet...`
/// line, if any.
fn hash_binding_name(code: &str) -> Option<String> {
    let rest = code.trim_start().strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let name: String = rest.chars().take_while(|&c| is_ident(c)).collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Does this line iterate the hash-collection binding `name`?
fn iterates(code: &str, name: &str) -> bool {
    const METHODS: &[&str] = &[
        ".iter()",
        ".iter_mut()",
        ".into_iter()",
        ".keys()",
        ".values()",
        ".values_mut()",
        ".drain(",
        ".retain(",
    ];
    let mut start = 0;
    while let Some(pos) = code[start..].find(name) {
        let at = start + pos;
        let bounded_before = at == 0 || !is_ident(code[..at].chars().next_back().unwrap_or(' '));
        let after = &code[at + name.len()..];
        let bounded_after = !after.chars().next().map(is_ident).unwrap_or(false);
        if bounded_before && bounded_after {
            if METHODS.iter().any(|m| after.starts_with(m)) {
                return true;
            }
            let before = &code[..at];
            if before.ends_with("in ") || before.ends_with("in &") || before.ends_with("in &mut ")
            {
                return true;
            }
        }
        start = at + name.len();
    }
    false
}

/// Function name declared on this line (`fn name(` / `fn name<`), if any.
fn fn_decl_name(code: &str) -> Option<String> {
    let mut search = 0;
    while let Some(pos) = code[search..].find("fn ") {
        let at = search + pos;
        let bounded = at == 0 || !is_ident(code[..at].chars().next_back().unwrap_or(' '));
        if bounded {
            let name: String =
                code[at + 3..].trim_start().chars().take_while(|&c| is_ident(c)).collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        search = at + 3;
    }
    None
}

fn lint_file(rel: &str, src: &str) -> Vec<Finding> {
    // the lint's own source holds every rule needle as a literal
    if rel == "bin/detlint.rs" {
        return Vec::new();
    }
    let raw: Vec<&str> = src.lines().collect();
    let code = strip_code(src);
    let tail = test_tail_start(&raw);
    let mut out = Vec::new();
    let mut push = |rule: Rule, line: usize| {
        out.push(Finding {
            rule,
            path: rel.to_string(),
            line: line + 1,
            text: raw[line].trim().to_string(),
        });
    };

    // raw-std-sync: the facade itself is the one place std::sync appears
    if rel != "sync.rs" {
        for (i, c) in code.iter().enumerate() {
            if c.contains("std::sync") {
                push(Rule::RawStdSync, i);
            }
        }
    }

    // hash-iter: deterministic modules must not iterate hash collections
    let deterministic = ["linalg/", "tracking/", "tasks/", "sparse/"]
        .iter()
        .any(|p| rel.starts_with(p));
    if deterministic {
        let mut names: Vec<String> = Vec::new();
        for (i, c) in code.iter().enumerate() {
            if i >= tail {
                break;
            }
            if (c.contains("HashMap") || c.contains("HashSet")) && c.contains("let ") {
                if let Some(name) = hash_binding_name(c) {
                    names.push(name);
                }
            }
            if names.iter().any(|n| iterates(c, n)) {
                push(Rule::HashIter, i);
            }
        }
    }

    // into-alloc: allocation tokens banned inside `_into` kernel bodies
    const ALLOC_TOKENS: &[&str] = &["Vec::new", "vec!", ".to_vec()", ".clone()", "hcat"];
    let mut i = 0;
    while i < code.len() {
        let is_into = fn_decl_name(&code[i]).is_some_and(|n| n.ends_with("_into"));
        if !is_into {
            i += 1;
            continue;
        }
        // walk the body by brace depth, starting at the signature line
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        while j < code.len() {
            for ch in code[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if ALLOC_TOKENS.iter().any(|t| code[j].contains(t)) {
                push(Rule::IntoAlloc, j);
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }

    // relaxed-outside-metrics: strict — the Counter/Histogram newtypes in
    // metrics.rs are the only place unordered atomics are acceptable
    if rel != "coordinator/metrics.rs" {
        for (i, c) in code.iter().enumerate() {
            if c.contains("Ordering::Relaxed") {
                push(Rule::RelaxedOutsideMetrics, i);
            }
        }
    }

    // ordering-comment: Acquire/Release/AcqRel must carry a nearby
    // `// ordering:` justification (same line or the 12 lines above,
    // which tolerates multi-line statements under a comment block)
    for (i, c) in code.iter().enumerate() {
        let annotated_site = ["Ordering::Acquire", "Ordering::Release", "Ordering::AcqRel"]
            .iter()
            .any(|t| c.contains(t));
        if annotated_site {
            let lo = i.saturating_sub(12);
            let justified = raw[lo..=i].iter().any(|l| l.contains("ordering:"));
            if !justified {
                push(Rule::OrderingComment, i);
            }
        }
    }

    // coordinator-unwrap: non-test coordinator code never panics on a
    // Result/Option shortcut (sync.rs centralizes the poison policy)
    if rel.starts_with("coordinator/") {
        for (i, c) in code.iter().enumerate() {
            if i >= tail {
                break;
            }
            if c.contains(".unwrap()") || c.contains(".expect(") {
                push(Rule::CoordinatorUnwrap, i);
            }
        }
    }

    // thread-spawn: raw thread creation lives in exactly two places —
    // the kernel pool (linalg/threads.rs, incl. the bench-only scoped
    // baseline) and the sync facade's spawn_named.  Everything else
    // dispatches on the persistent pool, so there are no per-call
    // spawns to measure or model-check around.  Test tails may spawn
    // helper threads.
    if rel != "sync.rs" && rel != "linalg/threads.rs" {
        for (i, c) in code.iter().enumerate() {
            if i >= tail {
                break;
            }
            if c.contains("std::thread::scope") || c.contains("std::thread::spawn") {
                push(Rule::ThreadSpawn, i);
            }
        }
    }

    // raw-intrinsics: architecture intrinsics (and the `unsafe` they
    // drag in) live in exactly one audited file — the SIMD micro-kernel
    // rungs.  Everywhere else stays scalar so the bitwise oracles don't
    // grow silent platform-specific forks.  Strict: test code holds to
    // it too (a test that needs a SIMD path goes through the gemm_simd
    // entry points, never raw intrinsics).
    if rel != "linalg/gemm_simd.rs" {
        for (i, c) in code.iter().enumerate() {
            if c.contains("std::arch") || c.contains("core::arch") {
                push(Rule::RawIntrinsics, i);
            }
        }
    }

    // raw-fs: every durable write goes through the `StorageBackend`
    // trait in `coordinator/durability/` so the fault-injection harness
    // can kill it at any syscall boundary.  The audited plain-file
    // homes — edge-list/snapshot I/O, eval tables, artifact loading,
    // and the CLI — predate the tier and stay exempt; test tails may
    // touch temp files directly.
    let fs_exempt = rel.starts_with("coordinator/durability/")
        || rel == "graph/io.rs"
        || rel == "eval/table.rs"
        || rel == "runtime/artifact.rs"
        || rel == "main.rs";
    if !fs_exempt {
        for (i, c) in code.iter().enumerate() {
            if i >= tail {
                break;
            }
            if c.contains("std::fs") || c.contains("File::create") {
                push(Rule::RawFs, i);
            }
        }
    }

    out
}

// ---------------------------------------------------------------------
// allowlist

struct AllowEntry {
    rule: String,
    path_suffix: String,
    needle: String,
    used: bool,
}

fn parse_allowlist(text: &str) -> Vec<AllowEntry> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.splitn(3, ':');
        let fields = (parts.next(), parts.next(), parts.next());
        if let (Some(rule), Some(path), Some(needle)) = fields {
            out.push(AllowEntry {
                rule: rule.to_string(),
                path_suffix: path.to_string(),
                needle: needle.to_string(),
                used: false,
            });
        } else {
            eprintln!("detlint: malformed allowlist line (want rule:path:needle): {line}");
        }
    }
    out
}

fn allowed(f: &Finding, allow: &mut [AllowEntry]) -> bool {
    for e in allow.iter_mut() {
        let hit = e.rule == f.rule.name()
            && f.path.ends_with(&e.path_suffix)
            && f.text.contains(&e.needle);
        if hit {
            e.used = true;
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------
// tree walking

fn first_existing(candidates: &[PathBuf]) -> Option<PathBuf> {
    candidates.iter().find(|p| p.exists()).cloned()
}

fn src_root() -> Option<PathBuf> {
    first_existing(&[
        PathBuf::from("rust/src"),
        PathBuf::from("src"),
        Path::new(env!("CARGO_MANIFEST_DIR")).join("src"),
    ])
}

fn allowlist_path() -> Option<PathBuf> {
    first_existing(&[
        PathBuf::from("rust/detlint.allow"),
        PathBuf::from("detlint.allow"),
        Path::new(env!("CARGO_MANIFEST_DIR")).join("detlint.allow"),
    ])
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut entries: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

// ---------------------------------------------------------------------
// self-test fixtures: every rule must fire on its seeded bad snippet

const FIXTURES: &[(&str, &str, &str)] = &[
    ("coordinator/fixture.rs", "use std::sync::Mutex;\n", "raw-std-sync"),
    (
        "linalg/fixture.rs",
        "fn f() {\n    let mut m = std::collections::HashMap::new();\n    m.insert(1, 2);\n    for (k, v) in &m {\n        let _ = (k, v);\n    }\n}\n",
        "hash-iter",
    ),
    (
        "sparse/fixture.rs",
        "fn axpy_into(dst: &mut [f64]) {\n    let tmp: Vec<f64> = Vec::new();\n    dst[0] = tmp.len() as f64;\n}\n",
        "into-alloc",
    ),
    (
        "tracking/fixture.rs",
        "fn f(x: &AtomicU64) {\n    x.store(1, Ordering::Relaxed);\n}\n",
        "relaxed-outside-metrics",
    ),
    (
        "coordinator/fixture2.rs",
        "fn f(x: &AtomicBool) {\n    x.store(true, Ordering::Release);\n}\n",
        "ordering-comment",
    ),
    (
        "coordinator/fixture3.rs",
        "fn f(m: &std::collections::HashMap<u32, u32>) {\n    let _ = m.get(&1).unwrap();\n}\n",
        "coordinator-unwrap",
    ),
    (
        "tasks/fixture2.rs",
        "fn f() {\n    std::thread::spawn(|| {}).join().ok();\n    std::thread::scope(|s| {\n        s.spawn(|| {});\n    });\n}\n",
        "thread-spawn",
    ),
    (
        "linalg/fixture2.rs",
        "use core::arch::x86_64::_mm256_add_pd;\n\nfn f() {\n    use std::arch::is_x86_feature_detected;\n}\n",
        "raw-intrinsics",
    ),
    (
        "coordinator/fixture4.rs",
        "fn f() -> std::io::Result<()> {\n    let data = std::fs::read(\"state.bin\")?;\n    let _ = std::fs::File::create(\"state.bin\")?;\n    drop(data);\n    Ok(())\n}\n",
        "raw-fs",
    ),
];

const CLEAN_FIXTURE: (&str, &str) = (
    "coordinator/clean.rs",
    "use crate::sync::{Arc, Mutex};\n\nfn f(m: &Mutex<u32>) -> u32 {\n    *m.lock()\n}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        super::f(&crate::sync::Mutex::new(1));\n        Some(1).unwrap();\n    }\n}\n",
);

fn run_self_test() -> ExitCode {
    let mut failures = 0;
    for (rel, src, expect) in FIXTURES {
        let fired: Vec<&str> = lint_file(rel, src).iter().map(|f| f.rule.name()).collect();
        if fired.contains(expect) {
            println!("self-test: {expect:<24} fires on {rel}");
        } else {
            eprintln!("self-test FAILED: {expect} did not fire on {rel} (fired: {fired:?})");
            failures += 1;
        }
    }
    let (rel, src) = CLEAN_FIXTURE;
    let clean = lint_file(rel, src);
    if clean.is_empty() {
        println!("self-test: clean fixture passes ({rel})");
    } else {
        for f in &clean {
            eprintln!("self-test FAILED: false positive [{}] {}:{}", f.rule.name(), f.path, f.line);
        }
        failures += 1;
    }
    // the allowlist machinery must suppress a matching finding
    let mut allow = parse_allowlist("into-alloc:sparse/fixture.rs:Vec::new()\n");
    let findings = lint_file(FIXTURES[2].0, FIXTURES[2].1);
    let suppressed = findings.iter().filter(|f| allowed(f, &mut allow)).count();
    if suppressed == 1 && allow[0].used {
        println!("self-test: allowlist suppression works");
    } else {
        eprintln!("self-test FAILED: allowlist did not suppress the seeded finding");
        failures += 1;
    }
    if failures == 0 {
        println!("detlint self-test: all rules verified");
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn main() -> ExitCode {
    if std::env::args().any(|a| a == "--self-test") {
        return run_self_test();
    }
    let Some(root) = src_root() else {
        eprintln!("detlint: cannot locate rust/src (run from the repo root)");
        return ExitCode::FAILURE;
    };
    let mut allow = match allowlist_path() {
        Some(p) => match std::fs::read_to_string(&p) {
            Ok(text) => parse_allowlist(&text),
            Err(e) => {
                eprintln!("detlint: cannot read {}: {e}", p.display());
                return ExitCode::FAILURE;
            }
        },
        None => Vec::new(),
    };

    let mut files = Vec::new();
    collect_rs_files(&root, &mut files);
    let mut reported = 0usize;
    for path in &files {
        let Ok(src) = std::fs::read_to_string(path) else {
            eprintln!("detlint: unreadable file {}", path.display());
            reported += 1;
            continue;
        };
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        for f in lint_file(&rel, &src) {
            if allowed(&f, &mut allow) {
                continue;
            }
            println!("{}/{}:{}: [{}] {}", root.display(), f.path, f.line, f.rule.name(), f.text);
            reported += 1;
        }
    }
    for e in allow.iter().filter(|e| !e.used) {
        println!(
            "detlint: warning: unused allowlist entry {}:{}:{}",
            e.rule, e.path_suffix, e.needle
        );
    }
    if reported == 0 {
        println!("detlint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("detlint: {reported} finding(s)");
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_fixture_fires_its_rule() {
        for (rel, src, expect) in FIXTURES {
            let fired: Vec<&str> = lint_file(rel, src).iter().map(|f| f.rule.name()).collect();
            assert!(fired.contains(expect), "{expect} did not fire on {rel}: {fired:?}");
        }
    }

    #[test]
    fn clean_fixture_has_no_findings() {
        let (rel, src) = CLEAN_FIXTURE;
        let findings = lint_file(rel, src);
        assert!(findings.is_empty(), "false positives: {:?}", findings[0].text);
    }

    #[test]
    fn comments_and_strings_do_not_trigger() {
        let src = "// std::sync is banned\nfn f() {\n    let msg = \"call .unwrap() on std::sync types\";\n    drop(msg);\n}\n";
        assert!(lint_file("coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_tail_may_unwrap() {
        let src = "fn f() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1).unwrap();\n    }\n}\n";
        assert!(lint_file("coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn ordering_comment_window_accepts_block_above() {
        let src = "fn f(x: &AtomicBool) {\n    // ordering: Release pairs with the Acquire load in g\n    x.store(true, Ordering::Release);\n}\n";
        assert!(lint_file("coordinator/x.rs", src).is_empty());
        let far = format!(
            "fn f(x: &AtomicBool) {{\n    // ordering: too far away\n{}    x.store(true, Ordering::Release);\n}}\n",
            "    let _ = 1;\n".repeat(13)
        );
        let findings = lint_file("coordinator/x.rs", &far);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule.name(), "ordering-comment");
    }

    #[test]
    fn into_alloc_scopes_to_the_kernel_body() {
        let src = "fn scale(v: &mut [f64]) -> Vec<f64> {\n    v.to_vec()\n}\n\nfn scale_into(dst: &mut [f64]) {\n    let t = dst.to_vec();\n    dst[0] = t[0];\n}\n";
        let findings = lint_file("sparse/x.rs", src);
        assert_eq!(findings.len(), 1, "only the _into body is restricted");
        assert_eq!(findings[0].line, 6);
    }

    #[test]
    fn hash_iter_tracks_bindings() {
        let ok = "fn f() {\n    let mut seen = std::collections::HashSet::new();\n    seen.insert(1);\n    let _ = seen.contains(&1);\n}\n";
        assert!(lint_file("linalg/x.rs", ok).is_empty());
        let bad = "fn f() -> usize {\n    let mut seen = std::collections::HashSet::new();\n    seen.insert(1);\n    seen.iter().count()\n}\n";
        let findings = lint_file("linalg/x.rs", bad);
        assert!(findings.iter().any(|f| f.rule.name() == "hash-iter"));
    }

    #[test]
    fn thread_spawn_exempts_the_pool_file_and_test_tails() {
        let bad = "fn f() {\n    std::thread::scope(|s| { s.spawn(|| {}); });\n}\n";
        let findings = lint_file("tasks/x.rs", bad);
        assert!(findings.iter().any(|f| f.rule.name() == "thread-spawn"));
        // the kernel pool's home (and the facade's spawn_named) may spawn
        assert!(lint_file("linalg/threads.rs", bad).is_empty());
        // test tails may spawn helper threads
        let tail_only = "fn f() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        std::thread::spawn(|| {}).join().ok();\n    }\n}\n";
        assert!(lint_file("tasks/x.rs", tail_only).is_empty());
    }

    #[test]
    fn raw_intrinsics_exempts_only_the_simd_kernel_home() {
        let bad = "fn f() {\n    let v = unsafe { std::arch::x86_64::_mm256_setzero_pd() };\n    drop(v);\n}\n";
        let findings = lint_file("linalg/blas.rs", bad);
        assert!(findings.iter().any(|f| f.rule.name() == "raw-intrinsics"));
        // the one audited home of architecture intrinsics
        assert!(lint_file("linalg/gemm_simd.rs", bad).is_empty());
        // strict: unlike thread-spawn, test tails hold to it too
        let tail = "fn f() {}\n\n#[cfg(test)]\nmod tests {\n    use core::arch::x86_64::__m256d;\n}\n";
        let findings = lint_file("tasks/x.rs", tail);
        assert!(findings.iter().any(|f| f.rule.name() == "raw-intrinsics"));
    }

    #[test]
    fn raw_fs_exempts_durability_and_audited_io_homes() {
        let bad = "fn f() {\n    let _ = std::fs::remove_file(\"wal.log\");\n}\n";
        let findings = lint_file("coordinator/tenant.rs", bad);
        assert!(findings.iter().any(|f| f.rule.name() == "raw-fs"));
        // the StorageBackend homes and the audited plain-file users pass
        assert!(lint_file("coordinator/durability/backend.rs", bad).is_empty());
        assert!(lint_file("coordinator/durability/recover.rs", bad).is_empty());
        assert!(lint_file("graph/io.rs", bad).is_empty());
        assert!(lint_file("eval/table.rs", bad).is_empty());
        assert!(lint_file("runtime/artifact.rs", bad).is_empty());
        assert!(lint_file("main.rs", bad).is_empty());
        // `File::create` via a `use std::fs::File` import is caught too
        let aliased = "use std::io::Write;\nfn f(p: &str) {\n    let _ = File::create(p);\n}\n";
        let findings = lint_file("tracking/x.rs", aliased);
        assert!(findings.iter().any(|f| f.rule.name() == "raw-fs"));
        // test tails may touch temp files directly
        let tail_only = "fn f() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let _ = std::fs::remove_file(\"tmp\");\n    }\n}\n";
        assert!(lint_file("coordinator/x.rs", tail_only).is_empty());
    }

    #[test]
    fn allowlist_matches_on_all_three_fields() {
        let mut allow =
            parse_allowlist("# comment\n\ninto-alloc:sparse/x.rs:dst.to_vec()\nbad-line\n");
        assert_eq!(allow.len(), 1);
        let src = "fn scale_into(dst: &mut [f64]) {\n    let t = dst.to_vec();\n    dst[0] = t[0];\n}\n";
        let findings = lint_file("sparse/x.rs", src);
        assert_eq!(findings.len(), 1);
        assert!(allowed(&findings[0], &mut allow));
        // wrong rule/path → no suppression
        let mut other = parse_allowlist("hash-iter:sparse/x.rs:dst.to_vec()\n");
        assert!(!allowed(&findings[0], &mut other));
    }
}
