//! Graph substrate: dynamic graphs, synthetic generators, the evaluation
//! scenarios of paper Sec. 5, and the (substituted) dataset registry.

pub mod datasets;
pub mod generators;
#[allow(clippy::module_inception)]
pub mod graph;
pub mod io;
pub mod scenario;
pub mod stream;
