//! Random graph generators: Erdős–Rényi, stochastic block model,
//! Chung–Lu (expected-degree power law), Barabási–Albert preferential
//! attachment, and a timestamped preferential-attachment stream for the
//! temporal (Type-D) datasets.

use crate::graph::graph::Graph;
use crate::linalg::rng::Rng;

/// Erdős–Rényi G(n, p).
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> Graph {
    let mut g = Graph::with_nodes(n);
    // geometric skipping for sparse p
    if p <= 0.0 {
        return g;
    }
    if p >= 1.0 {
        for u in 0..n {
            for v in u + 1..n {
                g.add_edge(u, v);
            }
        }
        return g;
    }
    let lq = (1.0 - p).ln();
    let (mut u, mut v) = (1usize, 0usize);
    while u < n {
        let r = 1.0 - rng.uniform();
        let skip = (r.ln() / lq).floor() as usize + 1;
        v += skip;
        while v >= u && u < n {
            v -= u;
            u += 1;
        }
        if u < n {
            g.add_edge(u, v);
        }
    }
    g
}

/// Stochastic block model with `k` equal-probability clusters.
/// Returns (graph, cluster labels).
pub fn sbm(n: usize, k: usize, p_in: f64, p_out: f64, rng: &mut Rng) -> (Graph, Vec<usize>) {
    let labels: Vec<usize> = (0..n).map(|_| rng.below(k)).collect();
    let mut g = Graph::with_nodes(n);
    for u in 0..n {
        for v in u + 1..n {
            let p = if labels[u] == labels[v] { p_in } else { p_out };
            if rng.flip(p) {
                g.add_edge(u, v);
            }
        }
    }
    (g, labels)
}

/// Power-law expected degree sequence: w_i ∝ (i + i0)^{-1/(γ-1)}, scaled
/// so the expected edge count is ~`target_edges`.
pub fn power_law_weights(n: usize, gamma: f64, target_edges: usize) -> Vec<f64> {
    let alpha = 1.0 / (gamma - 1.0);
    let i0 = 1.0;
    let mut w: Vec<f64> = (0..n).map(|i| (i as f64 + i0).powf(-alpha)).collect();
    let sum: f64 = w.iter().sum();
    // expected edges of Chung-Lu = (Σw)²/(2Σw) scaled... after normalizing
    // Σw = 2E the expected degree of node i is w_i.
    let scale = (2.0 * target_edges as f64) / sum;
    for x in w.iter_mut() {
        *x *= scale;
    }
    // cap weights for well-posed Chung-Lu: w_i w_j / Σw ≤ 1
    let total: f64 = w.iter().sum();
    let cap = total.sqrt();
    for x in w.iter_mut() {
        if *x > cap {
            *x = cap;
        }
    }
    w
}

/// Chung–Lu model: P(i~j) = min(1, w_i w_j / Σw).  Heavy-tailed degree
/// profile matching real SNAP graphs (the dataset substitution of
/// DESIGN.md).  Uses the efficient weight-sorted skipping sampler.
pub fn chung_lu(weights: &[f64], rng: &mut Rng) -> Graph {
    let n = weights.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| weights[b].partial_cmp(&weights[a]).unwrap());
    let w: Vec<f64> = order.iter().map(|&i| weights[i]).collect();
    let total: f64 = w.iter().sum();
    let mut g = Graph::with_nodes(n);
    for i in 0..n {
        let mut j = i + 1;
        while j < n {
            let p = (w[i] * w[j] / total).min(1.0);
            if p <= 0.0 {
                break;
            }
            if p < 1.0 {
                // skip ahead geometrically using the current p as an upper
                // bound for subsequent (sorted, decreasing) weights
                let r = 1.0 - rng.uniform();
                let skip = (r.ln() / (1.0 - p).ln()).floor() as usize;
                j += skip;
                if j >= n {
                    break;
                }
                let q = (w[i] * w[j] / total).min(1.0);
                if rng.uniform() < q / p {
                    g.add_edge(order[i], order[j]);
                }
                j += 1;
            } else {
                g.add_edge(order[i], order[j]);
                j += 1;
            }
        }
    }
    g
}

/// Barabási–Albert: each new node attaches to `m` existing nodes chosen
/// by preferential attachment.  Returns the graph.
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Rng) -> Graph {
    let g = ba_with_arrivals(n, m, rng).0;
    g
}

/// Barabási–Albert that also returns the arrival-ordered edge list
/// (u, v) with u the newly arrived node — the temporal stream used to
/// synthesize the Type-D datasets.
pub fn ba_with_arrivals(n: usize, m: usize, rng: &mut Rng) -> (Graph, Vec<(usize, usize)>) {
    assert!(m >= 1 && n > m);
    let mut g = Graph::with_nodes(n);
    let mut stream = Vec::with_capacity(n * m);
    // repeated-node list for preferential sampling
    let mut targets: Vec<usize> = Vec::with_capacity(2 * n * m);
    // seed clique on m+1 nodes
    for u in 0..=m {
        for v in u + 1..=m {
            g.add_edge(u, v);
            stream.push((v, u));
            targets.push(u);
            targets.push(v);
        }
    }
    for u in m + 1..n {
        let mut chosen = std::collections::HashSet::new();
        while chosen.len() < m {
            let t = targets[rng.below(targets.len())];
            if t != u {
                chosen.insert(t);
            }
        }
        for &v in chosen.iter() {
            g.add_edge(u, v);
            stream.push((u, v));
            targets.push(u);
            targets.push(v);
        }
    }
    (g, stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_edge_count_close_to_expectation() {
        let mut rng = Rng::new(1);
        let n = 400;
        let p = 0.02;
        let g = erdos_renyi(n, p, &mut rng);
        let expected = p * (n * (n - 1) / 2) as f64;
        let got = g.n_edges() as f64;
        assert!(
            (got - expected).abs() < 4.0 * expected.sqrt() + 10.0,
            "got {got}, expected {expected}"
        );
    }

    #[test]
    fn er_extremes() {
        let mut rng = Rng::new(2);
        assert_eq!(erdos_renyi(10, 0.0, &mut rng).n_edges(), 0);
        assert_eq!(erdos_renyi(10, 1.0, &mut rng).n_edges(), 45);
    }

    #[test]
    fn sbm_denser_within_clusters() {
        let mut rng = Rng::new(3);
        let (g, labels) = sbm(300, 3, 0.15, 0.01, &mut rng);
        let (mut win, mut wout, mut pin_pairs, mut pout_pairs) = (0usize, 0usize, 0usize, 0usize);
        for u in 0..300 {
            for v in u + 1..300 {
                let same = labels[u] == labels[v];
                if same {
                    pin_pairs += 1;
                } else {
                    pout_pairs += 1;
                }
                if g.has_edge(u, v) {
                    if same {
                        win += 1;
                    } else {
                        wout += 1;
                    }
                }
            }
        }
        let din = win as f64 / pin_pairs as f64;
        let dout = wout as f64 / pout_pairs as f64;
        assert!(din > 5.0 * dout, "din={din} dout={dout}");
    }

    #[test]
    fn chung_lu_matches_target_edges() {
        let mut rng = Rng::new(4);
        let w = power_law_weights(1000, 2.3, 5000);
        let g = chung_lu(&w, &mut rng);
        let e = g.n_edges() as f64;
        assert!(
            e > 2500.0 && e < 7500.0,
            "edges {e} far from target 5000"
        );
        // heavy tail: max degree well above mean
        let mean_deg = 2.0 * e / 1000.0;
        assert!(g.max_degree() as f64 > 4.0 * mean_deg);
    }

    #[test]
    fn ba_properties() {
        let mut rng = Rng::new(5);
        let (g, stream) = ba_with_arrivals(500, 3, &mut rng);
        assert_eq!(g.n_edges(), stream.len());
        // every non-seed arrival contributes exactly m edges
        assert_eq!(stream.len(), 3 * (500 - 4) + 6);
        // hubs exist
        assert!(g.max_degree() > 20);
    }
}
