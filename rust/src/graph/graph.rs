//! A mutable undirected graph with adjacency-set storage, convertible to
//! the CSR adjacency / Laplacian matrices the trackers consume.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use std::collections::BTreeSet;

/// Undirected simple graph (no self loops, unweighted).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    adj: Vec<BTreeSet<usize>>,
    n_edges: usize,
}

impl Graph {
    /// Empty graph with `n` isolated nodes.
    pub fn with_nodes(n: usize) -> Graph {
        Graph { adj: vec![BTreeSet::new(); n], n_edges: 0 }
    }

    pub fn n_nodes(&self) -> usize {
        self.adj.len()
    }

    pub fn n_edges(&self) -> usize {
        self.n_edges
    }

    /// Append `count` isolated nodes; returns the first new index.
    pub fn add_nodes(&mut self, count: usize) -> usize {
        let first = self.adj.len();
        self.adj.extend((0..count).map(|_| BTreeSet::new()));
        first
    }

    /// Add edge (u,v); returns true if it was new.  Self loops ignored.
    pub fn add_edge(&mut self, u: usize, v: usize) -> bool {
        if u == v || u >= self.adj.len() || v >= self.adj.len() {
            return false;
        }
        let added = self.adj[u].insert(v);
        if added {
            self.adj[v].insert(u);
            self.n_edges += 1;
        }
        added
    }

    /// Remove edge (u,v); returns true if it existed.
    pub fn remove_edge(&mut self, u: usize, v: usize) -> bool {
        if u >= self.adj.len() || v >= self.adj.len() {
            return false;
        }
        let removed = self.adj[u].remove(&v);
        if removed {
            self.adj[v].remove(&u);
            self.n_edges -= 1;
        }
        removed
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        u < self.adj.len() && self.adj[u].contains(&v)
    }

    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    pub fn neighbors(&self, u: usize) -> impl Iterator<Item = usize> + '_ {
        self.adj[u].iter().copied()
    }

    /// All edges (u < v).
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.n_edges);
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &v in nbrs.iter() {
                if u < v {
                    out.push((u, v));
                }
            }
        }
        out
    }

    /// Adjacency matrix as symmetric CSR.
    pub fn adjacency(&self) -> Csr {
        let n = self.n_nodes();
        let mut indptr = vec![0usize; n + 1];
        for (u, nbrs) in self.adj.iter().enumerate() {
            indptr[u + 1] = indptr[u] + nbrs.len();
        }
        let mut indices = Vec::with_capacity(2 * self.n_edges);
        for nbrs in self.adj.iter() {
            indices.extend(nbrs.iter().copied());
        }
        let data = vec![1.0; indices.len()];
        // hand-assembled (BTreeSet iteration is sorted): assert the CSR
        // invariants in debug builds like every other constructor
        Csr { n_rows: n, n_cols: n, indptr, indices, data }.debug_validate()
    }

    /// Combinatorial Laplacian L = D − A as CSR.
    pub fn laplacian(&self) -> Csr {
        let n = self.n_nodes();
        let mut coo = Coo::new(n, n);
        for (u, nbrs) in self.adj.iter().enumerate() {
            coo.push(u, u, nbrs.len() as f64);
            for &v in nbrs.iter() {
                coo.push(u, v, -1.0);
            }
        }
        coo.to_csr()
    }

    /// Normalized adjacency D^{-1/2} A D^{-1/2} (isolated nodes get zero
    /// rows), so that Lₙ = I − normalized_adjacency().
    pub fn normalized_adjacency(&self) -> Csr {
        let n = self.n_nodes();
        let dinv: Vec<f64> = self
            .adj
            .iter()
            .map(|nb| {
                if nb.is_empty() {
                    0.0
                } else {
                    1.0 / (nb.len() as f64).sqrt()
                }
            })
            .collect();
        let mut coo = Coo::new(n, n);
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &v in nbrs.iter() {
                coo.push(u, v, dinv[u] * dinv[v]);
            }
        }
        coo.to_csr()
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|s| s.len()).max().unwrap_or(0)
    }

    /// Subgraph induced by `nodes` (relabelled 0..nodes.len() in order).
    pub fn induced_subgraph(&self, nodes: &[usize]) -> Graph {
        let mut index = vec![usize::MAX; self.n_nodes()];
        for (new, &old) in nodes.iter().enumerate() {
            index[old] = new;
        }
        let mut g = Graph::with_nodes(nodes.len());
        for (new_u, &old_u) in nodes.iter().enumerate() {
            for &old_v in self.adj[old_u].iter() {
                let new_v = index[old_v];
                if new_v != usize::MAX && new_u < new_v {
                    g.add_edge(new_u, new_v);
                }
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        let mut g = Graph::with_nodes(3);
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g
    }

    #[test]
    fn add_remove_edges() {
        let mut g = path3();
        assert_eq!(g.n_edges(), 2);
        assert!(g.has_edge(1, 0));
        assert!(!g.add_edge(0, 1)); // duplicate
        assert!(!g.add_edge(2, 2)); // self loop
        assert!(g.remove_edge(0, 1));
        assert!(!g.remove_edge(0, 1));
        assert_eq!(g.n_edges(), 1);
    }

    #[test]
    fn adjacency_symmetric_and_binary() {
        let g = path3();
        let a = g.adjacency();
        assert!(a.is_symmetric(0.0));
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.get(0, 1), 1.0);
        assert_eq!(a.get(0, 2), 0.0);
    }

    #[test]
    fn laplacian_row_sums_zero() {
        let g = path3();
        let l = g.laplacian();
        for s in l.row_sums() {
            assert!(s.abs() < 1e-12);
        }
        assert_eq!(l.get(1, 1), 2.0);
        assert_eq!(l.get(0, 1), -1.0);
    }

    #[test]
    fn normalized_adjacency_spectrum_bounded() {
        // eigenvalues of D^{-1/2}AD^{-1/2} lie in [-1, 1]
        let mut g = Graph::with_nodes(6);
        for (u, v) in [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0), (0, 3)] {
            g.add_edge(u, v);
        }
        let na = g.normalized_adjacency();
        let e = crate::linalg::eigh::eigh(&na.to_dense());
        for v in e.values {
            assert!(v > -1.0 - 1e-9 && v < 1.0 + 1e-9);
        }
    }

    #[test]
    fn induced_subgraph_relabels() {
        let mut g = Graph::with_nodes(5);
        g.add_edge(0, 2);
        g.add_edge(2, 4);
        g.add_edge(1, 3);
        let s = g.induced_subgraph(&[0, 2, 4]);
        assert_eq!(s.n_nodes(), 3);
        assert_eq!(s.n_edges(), 2);
        assert!(s.has_edge(0, 1)); // old (0,2)
        assert!(s.has_edge(1, 2)); // old (2,4)
    }

    #[test]
    fn add_nodes_grows() {
        let mut g = path3();
        let first = g.add_nodes(2);
        assert_eq!(first, 3);
        assert_eq!(g.n_nodes(), 5);
        assert!(g.add_edge(3, 4));
    }
}
