//! Edge-list I/O: load/save graphs and timestamped streams as plain text
//! (`u v` or `u v t` per line, `#` comments), the SNAP interchange format.

use crate::graph::graph::Graph;
use anyhow::{Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Parse an edge list (ignores comments/blank lines, tolerates an extra
/// timestamp column).  Node ids are arbitrary u64; they are compacted to
/// 0..n by first appearance.
pub fn parse_edge_list(text: &str) -> Result<Vec<(u64, u64)>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let u: u64 = it
            .next()
            .context("missing source")?
            .parse()
            .with_context(|| format!("line {}", lineno + 1))?;
        let v: u64 = it
            .next()
            .context("missing target")?
            .parse()
            .with_context(|| format!("line {}", lineno + 1))?;
        out.push((u, v));
    }
    Ok(out)
}

/// Compact arbitrary node ids to dense indices by first appearance.
pub fn compact_ids(edges: &[(u64, u64)]) -> (Vec<(usize, usize)>, usize) {
    let mut map = std::collections::HashMap::new();
    let mut next = 0usize;
    let intern = |x: u64, map: &mut std::collections::HashMap<u64, usize>, next: &mut usize| {
        *map.entry(x).or_insert_with(|| {
            let i = *next;
            *next += 1;
            i
        })
    };
    let out: Vec<(usize, usize)> = edges
        .iter()
        .map(|&(u, v)| (intern(u, &mut map, &mut next), intern(v, &mut map, &mut next)))
        .collect();
    (out, next)
}

/// Load a file into a [`Graph`].
pub fn load_graph(path: &Path) -> Result<Graph> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let raw = parse_edge_list(&text)?;
    let (edges, n) = compact_ids(&raw);
    let mut g = Graph::with_nodes(n);
    for (u, v) in edges {
        g.add_edge(u, v);
    }
    Ok(g)
}

/// Load a timestamped stream (edges kept in file order).
pub fn load_stream(path: &Path) -> Result<Vec<(usize, usize)>> {
    let text = std::fs::read_to_string(path)?;
    let raw = parse_edge_list(&text)?;
    Ok(compact_ids(&raw).0)
}

/// Save a graph as an edge list.
pub fn save_graph(g: &Graph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# nodes {} edges {}", g.n_nodes(), g.n_edges())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

/// Stream a large edge list without loading the whole file (returns an
/// iterator of parsed (u, v) pairs).
pub fn stream_edge_file(path: &Path) -> Result<impl Iterator<Item = Result<(u64, u64)>>> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    Ok(reader.lines().filter_map(|line| match line {
        Err(e) => Some(Err(e.into())),
        Ok(l) => {
            let l = l.trim().to_string();
            if l.is_empty() || l.starts_with('#') || l.starts_with('%') {
                return None;
            }
            let mut it = l.split_whitespace();
            let u = it.next()?.parse::<u64>().ok()?;
            let v = it.next()?.parse::<u64>().ok()?;
            Some(Ok((u, v)))
        }
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tolerates_comments_and_timestamps() {
        let text = "# comment\n1 2\n2 3 100\n\n% other\n3 1";
        let e = parse_edge_list(text).unwrap();
        assert_eq!(e, vec![(1, 2), (2, 3), (3, 1)]);
    }

    #[test]
    fn compact_ids_first_appearance() {
        let (e, n) = compact_ids(&[(100, 5), (5, 7), (7, 100)]);
        assert_eq!(n, 3);
        assert_eq!(e, vec![(0, 1), (1, 2), (2, 0)]);
    }

    #[test]
    fn graph_roundtrip_through_file() {
        let dir = std::env::temp_dir().join("grest_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        let mut g = Graph::with_nodes(4);
        g.add_edge(0, 1);
        g.add_edge(1, 3);
        save_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g2.n_edges(), 2);
        // compaction may relabel, but edge count and degree multiset survive
        let mut d1: Vec<usize> = (0..g.n_nodes()).map(|i| g.degree(i)).collect();
        let mut d2: Vec<usize> = (0..g2.n_nodes()).map(|i| g2.degree(i)).collect();
        d1.retain(|&d| d > 0);
        d2.retain(|&d| d > 0);
        d1.sort_unstable();
        d2.sort_unstable();
        assert_eq!(d1, d2);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_edge_list("a b").is_err());
        assert!(parse_edge_list("1").is_err());
    }
}
