//! The paper's two evaluation protocols (Sec. 5.1) as reusable scenario
//! builders, plus the SBM-expansion protocol of the clustering test
//! (Sec. 5.5).
//!
//! A scenario is the initial adjacency A⁽⁰⁾ plus a sequence of per-step
//! updates Δ⁽ᵗ⁾, with the post-step adjacency kept for reference
//! (`eigs`) computations and downstream-task ground truth.

use crate::graph::graph::Graph;
use crate::graph::stream::{DeltaBuilder, GraphEvent};
use crate::linalg::rng::Rng;
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::sparse::delta::Delta;

/// One time-step of graph evolution.
pub struct TimeStep {
    /// Update matrix Δ⁽ᵗ⁺¹⁾ relative to the previous adjacency.
    pub delta: Delta,
    /// Adjacency after applying the update.
    pub adjacency: Csr,
}

/// A dynamic graph: initial adjacency plus T update steps.
pub struct DynamicScenario {
    pub name: String,
    pub initial: Csr,
    pub steps: Vec<TimeStep>,
    /// Node labels (cluster ground truth) per step, when known (SBM):
    /// `labels_per_step[t]` matches `steps[t].adjacency` rows; index 0 of
    /// the vec corresponds to the *initial* graph.
    pub labels_per_step: Option<Vec<Vec<usize>>>,
}

impl DynamicScenario {
    pub fn t_steps(&self) -> usize {
        self.steps.len()
    }

    /// Largest node count reached.
    pub fn max_nodes(&self) -> usize {
        self.steps
            .last()
            .map(|s| s.adjacency.n_rows)
            .unwrap_or(self.initial.n_rows)
    }

    /// Total update nnz across steps (cost driver for all trackers).
    pub fn total_delta_nnz(&self) -> usize {
        self.steps.iter().map(|s| s.delta.nnz()).sum()
    }
}

/// Expansion-only Δ for revealing `added` nodes of the full graph `g`
/// into a scenario whose current node set has `n_old` members: G-block
/// edges to already-present nodes and C-block edges among the
/// newcomers, assembled in O(Σ deg(added)) — no induced-subgraph
/// rebuild, no full-matrix diff.  `pos` maps original node ids to
/// scenario indices (`usize::MAX` = not yet revealed) and is updated
/// with the newcomers.
fn expansion_delta(g: &Graph, pos: &mut [usize], n_old: usize, added: &[usize]) -> Delta {
    let s_new = added.len();
    for (off, &v) in added.iter().enumerate() {
        pos[v] = n_old + off;
    }
    let mut gb = Coo::new(n_old, s_new);
    let mut cb = Coo::new(s_new, s_new);
    for (off, &v) in added.iter().enumerate() {
        for u in g.neighbors(v) {
            let pu = pos[u];
            if pu == usize::MAX {
                continue;
            }
            if pu < n_old {
                gb.push(pu, off, 1.0);
            } else {
                let ou = pu - n_old;
                if ou < off {
                    cb.push_sym(ou, off, 1.0);
                }
            }
        }
    }
    Delta::from_blocks(n_old, s_new, &Coo::new(n_old, n_old), &gb, &cb)
}

/// Scenario 1 (Sec. 5.1): a static graph is revealed by degree order.
/// V⁽⁰⁾ = the ⌊N/2⌋ highest-degree nodes; each of the T steps adds the
/// next ⌊(N−N⁽⁰⁾)/T⌋ highest-degree nodes (the last step takes the
/// remainder, so every node is revealed even when `(n − n0) % t_steps
/// != 0`), inducing subgraphs.  Updates consist purely of graph
/// expansion (S > 0, K = 0 up to the induced edges among previously
/// present nodes... which by construction do not change), built
/// incrementally per step and applied with `Csr::apply_delta`.
pub fn scenario1_from_static(name: &str, g: &Graph, t_steps: usize) -> DynamicScenario {
    let n = g.n_nodes();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| g.degree(b).cmp(&g.degree(a)).then(a.cmp(&b)));
    let n0 = n / 2;
    let s_per = (n - n0) / t_steps;
    assert!(s_per > 0, "too many steps for graph size");
    let initial = g.induced_subgraph(&order[..n0]).adjacency();
    let mut pos = vec![usize::MAX; n];
    for (p, &v) in order[..n0].iter().enumerate() {
        pos[v] = p;
    }
    let mut prev_adj = initial.clone();
    let mut steps = Vec::with_capacity(t_steps);
    for t in 0..t_steps {
        let lo = n0 + t * s_per;
        let hi = if t + 1 == t_steps { n } else { n0 + (t + 1) * s_per };
        let delta = expansion_delta(g, &mut pos, lo, &order[lo..hi]);
        let adj = prev_adj.apply_delta(&delta);
        prev_adj = adj.clone();
        steps.push(TimeStep { delta, adjacency: adj });
    }
    DynamicScenario { name: name.to_string(), initial, steps, labels_per_step: None }
}

/// Scenario 2 (Sec. 5.1): timestamped edge stream.  E⁽⁰⁾ = the first
/// ⌊M/2⌋ edges; each step appends the next ⌊(M−M⁽⁰⁾)/T⌋ edges (the last
/// step takes the remainder).  Nodes are indexed by first appearance —
/// exactly [`DeltaBuilder`]'s interning order, so the stream is fed
/// straight through the event-sourced ingestion path: each step's Δ is
/// assembled in O(edges of the step) and the adjacency is maintained
/// with `Csr::apply_delta` instead of per-step rebuilds.
pub fn scenario2_from_stream(
    name: &str,
    stream: &[(usize, usize)],
    t_steps: usize,
) -> DynamicScenario {
    let m = stream.len();
    let m0 = m / 2;
    let m_per = (m - m0) / t_steps;
    assert!(m_per > 0, "too many steps for stream length");
    let mut b = DeltaBuilder::new();
    for &(u, v) in &stream[..m0] {
        b.push(GraphEvent::AddEdge(u as u64, v as u64));
    }
    let initial = match b.emit() {
        Some(d) => Csr::empty(0, 0).apply_delta(&d),
        None => Csr::empty(0, 0),
    };
    let mut prev = initial.clone();
    let mut steps = Vec::with_capacity(t_steps);
    let mut done = m0;
    for t in 0..t_steps {
        let hi = if t + 1 == t_steps { m } else { m0 + (t + 1) * m_per };
        for &(u, v) in &stream[done..hi] {
            b.push(GraphEvent::AddEdge(u as u64, v as u64));
        }
        done = hi;
        let delta = b.emit().unwrap_or_else(|| Delta {
            n_old: prev.n_rows,
            s_new: 0,
            full: Csr::empty(prev.n_rows, prev.n_rows),
        });
        let adj = prev.apply_delta(&delta);
        prev = adj.clone();
        steps.push(TimeStep { delta, adjacency: adj });
    }
    DynamicScenario { name: name.to_string(), initial, steps, labels_per_step: None }
}

/// SBM expansion protocol of Sec. 5.5: generate a full SBM graph, start
/// from a random N⁽⁰⁾-subset, add `s_per` random remaining nodes per step.
/// Ground-truth labels per step are returned for ARI evaluation.
pub fn sbm_expansion(
    n: usize,
    k_clusters: usize,
    p_in: f64,
    p_out: f64,
    n0: usize,
    s_per: usize,
    t_steps: usize,
    rng: &mut Rng,
) -> DynamicScenario {
    assert!(n0 + s_per * t_steps <= n);
    let (g, labels) = crate::graph::generators::sbm(n, k_clusters, p_in, p_out, rng);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut current: Vec<usize> = order[..n0].to_vec();
    let lab_of = |nodes: &[usize]| nodes.iter().map(|&i| labels[i]).collect::<Vec<_>>();
    let initial = g.induced_subgraph(&current).adjacency();
    let mut pos = vec![usize::MAX; n];
    for (p, &v) in current.iter().enumerate() {
        pos[v] = p;
    }
    let mut labels_per_step = vec![lab_of(&current)];
    let mut prev = initial.clone();
    let mut steps = Vec::with_capacity(t_steps);
    for t in 0..t_steps {
        let lo = n0 + t * s_per;
        let delta = expansion_delta(&g, &mut pos, current.len(), &order[lo..lo + s_per]);
        current.extend_from_slice(&order[lo..lo + s_per]);
        let adj = prev.apply_delta(&delta);
        prev = adj.clone();
        labels_per_step.push(lab_of(&current));
        steps.push(TimeStep { delta, adjacency: adj });
    }
    DynamicScenario {
        name: format!("sbm_n{n}_k{k_clusters}"),
        initial,
        steps,
        labels_per_step: Some(labels_per_step),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn scenario1_consistency() {
        // 203 nodes over 5 steps: (203 − 101) % 5 != 0, so the last step
        // must take the remainder (regression for the dead-branch bug)
        let mut rng = Rng::new(1);
        let g = generators::erdos_renyi(203, 0.05, &mut rng);
        let sc = scenario1_from_static("er", &g, 5);
        assert_eq!(sc.t_steps(), 5);
        assert_eq!(sc.initial.n_rows, 101);
        // each step: Ā + Δ == Â  (checked via from_diff reconstruction)
        let mut prev = sc.initial.clone();
        for step in &sc.steps {
            assert_eq!(step.delta.n_old, prev.n_rows);
            assert_eq!(step.delta.n_new(), step.adjacency.n_rows);
            // reconstruct: padded prev + delta == adjacency
            let n = step.adjacency.n_rows;
            let mut dense = prev.to_dense().pad_rows(n - prev.n_rows);
            // pad cols too
            let mut full = crate::linalg::mat::Mat::zeros(n, n);
            for i in 0..prev.n_rows {
                for j in 0..prev.n_cols {
                    full.set(i, j, dense.get(i, j));
                }
            }
            let _ = &mut dense;
            full.axpy(1.0, &step.delta.full.to_dense());
            let mut diff = full;
            diff.axpy(-1.0, &step.adjacency.to_dense());
            assert!(diff.max_abs() < 1e-12);
            prev = step.adjacency.clone();
        }
        // final graph has ALL nodes, including the remainder
        assert_eq!(sc.max_nodes(), 203);
    }

    #[test]
    fn scenario1_reveals_remainder_nodes() {
        // regression: with (n − n0) % t_steps != 0 the old code's two
        // identical branches silently dropped the trailing nodes, so
        // every Scenario-1 figure ran on a truncated graph
        let mut rng = Rng::new(7);
        let g = generators::erdos_renyi(107, 0.1, &mut rng);
        let sc = scenario1_from_static("er", &g, 4);
        // n0 = 53, s_per = 13: steps reveal 13+13+13+15 nodes
        assert_eq!(sc.initial.n_rows, 53);
        assert_eq!(sc.max_nodes(), 107, "remainder nodes must be revealed");
        assert_eq!(sc.steps[3].delta.s_new, 15);
        for t in 0..3 {
            assert_eq!(sc.steps[t].delta.s_new, 13);
        }
    }

    #[test]
    fn scenario1_matches_induced_subgraph_rebuild() {
        // oracle: the incrementally maintained adjacency equals the
        // induced-subgraph rebuild of the degree-order prefix
        let mut rng = Rng::new(9);
        let g = generators::erdos_renyi(83, 0.1, &mut rng);
        let sc = scenario1_from_static("er", &g, 3);
        let n = g.n_nodes();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| g.degree(b).cmp(&g.degree(a)).then(a.cmp(&b)));
        for step in &sc.steps {
            let upto = step.adjacency.n_rows;
            let want = g.induced_subgraph(&order[..upto]).adjacency();
            assert_eq!(step.adjacency.indptr, want.indptr);
            assert_eq!(step.adjacency.indices, want.indices);
            assert_eq!(step.adjacency.data, want.data);
        }
        assert_eq!(sc.max_nodes(), 83);
    }

    #[test]
    fn scenario1_pure_expansion_has_no_k_block() {
        // degree-ordered reveal never changes edges among existing nodes
        // (non-divisible size: 100 − 50 = 50 over 4 steps)
        let mut rng = Rng::new(2);
        let g = generators::erdos_renyi(100, 0.08, &mut rng);
        let sc = scenario1_from_static("er", &g, 4);
        for step in &sc.steps {
            let kb = step.delta.k_block_dense();
            assert!(kb.max_abs() == 0.0, "K block must be empty in Scenario 1");
        }
        assert_eq!(sc.max_nodes(), 100, "remainder revealed");
    }

    #[test]
    fn scenario2_matches_rebuild_oracle() {
        // oracle: the event-sourced stream path equals the from-scratch
        // prefix rebuild at every step (nodes labelled by first
        // appearance either way)
        let mut rng = Rng::new(11);
        let (_, stream) = generators::ba_with_arrivals(90, 2, &mut rng);
        let sc = scenario2_from_stream("ba", &stream, 5);
        let m = stream.len();
        let m0 = m / 2;
        let m_per = (m - m0) / 5;
        let mut label = std::collections::HashMap::new();
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for &(u, v) in &stream {
            let next = label.len();
            let lu = *label.entry(u).or_insert(next);
            let next = label.len();
            let lv = *label.entry(v).or_insert(next);
            edges.push((lu, lv));
        }
        let build = |upto: usize| -> Csr {
            let n_nodes = edges[..upto].iter().map(|&(u, v)| u.max(v) + 1).max().unwrap_or(0);
            let mut g = Graph::with_nodes(n_nodes);
            for &(u, v) in &edges[..upto] {
                g.add_edge(u, v);
            }
            g.adjacency()
        };
        let want0 = build(m0);
        assert_eq!(sc.initial.indptr, want0.indptr);
        assert_eq!(sc.initial.indices, want0.indices);
        for (t, step) in sc.steps.iter().enumerate() {
            let hi = if t + 1 == 5 { m } else { m0 + (t + 1) * m_per };
            let want = build(hi);
            assert_eq!(step.adjacency.indptr, want.indptr, "step {t}");
            assert_eq!(step.adjacency.indices, want.indices, "step {t}");
            assert_eq!(step.adjacency.data, want.data, "step {t}");
        }
    }

    #[test]
    fn scenario2_node_growth_and_symmetry() {
        let mut rng = Rng::new(3);
        let (_, stream) = generators::ba_with_arrivals(150, 2, &mut rng);
        let sc = scenario2_from_stream("ba", &stream, 6);
        let mut prev_n = sc.initial.n_rows;
        for step in &sc.steps {
            assert!(step.adjacency.n_rows >= prev_n);
            assert!(step.adjacency.is_symmetric(0.0));
            assert!(step.delta.full.is_symmetric(0.0));
            prev_n = step.adjacency.n_rows;
        }
        assert_eq!(sc.max_nodes(), 150);
    }

    #[test]
    fn sbm_expansion_labels_track_nodes() {
        let mut rng = Rng::new(4);
        let sc = sbm_expansion(120, 3, 0.2, 0.02, 80, 10, 4, &mut rng);
        let labels = sc.labels_per_step.as_ref().unwrap();
        assert_eq!(labels.len(), 5);
        assert_eq!(labels[0].len(), 80);
        assert_eq!(labels[4].len(), 120);
        assert_eq!(sc.steps[3].adjacency.n_rows, 120);
    }
}
