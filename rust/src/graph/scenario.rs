//! The paper's two evaluation protocols (Sec. 5.1) as reusable scenario
//! builders, plus the SBM-expansion protocol of the clustering test
//! (Sec. 5.5).
//!
//! A scenario is the initial adjacency A⁽⁰⁾ plus a sequence of per-step
//! updates Δ⁽ᵗ⁾, with the post-step adjacency kept for reference
//! (`eigs`) computations and downstream-task ground truth.

use crate::graph::graph::Graph;
use crate::linalg::rng::Rng;
use crate::sparse::csr::Csr;
use crate::sparse::delta::Delta;

/// One time-step of graph evolution.
pub struct TimeStep {
    /// Update matrix Δ⁽ᵗ⁺¹⁾ relative to the previous adjacency.
    pub delta: Delta,
    /// Adjacency after applying the update.
    pub adjacency: Csr,
}

/// A dynamic graph: initial adjacency plus T update steps.
pub struct DynamicScenario {
    pub name: String,
    pub initial: Csr,
    pub steps: Vec<TimeStep>,
    /// Node labels (cluster ground truth) per step, when known (SBM):
    /// `labels_per_step[t]` matches `steps[t].adjacency` rows; index 0 of
    /// the vec corresponds to the *initial* graph.
    pub labels_per_step: Option<Vec<Vec<usize>>>,
}

impl DynamicScenario {
    pub fn t_steps(&self) -> usize {
        self.steps.len()
    }

    /// Largest node count reached.
    pub fn max_nodes(&self) -> usize {
        self.steps
            .last()
            .map(|s| s.adjacency.n_rows)
            .unwrap_or(self.initial.n_rows)
    }

    /// Total update nnz across steps (cost driver for all trackers).
    pub fn total_delta_nnz(&self) -> usize {
        self.steps.iter().map(|s| s.delta.nnz()).sum()
    }
}

/// Scenario 1 (Sec. 5.1): a static graph is revealed by degree order.
/// V⁽⁰⁾ = the ⌊N/2⌋ highest-degree nodes; each of the T steps adds the
/// next ⌊(N−N⁽⁰⁾)/T⌋ highest-degree nodes, inducing subgraphs.
/// Updates consist purely of graph expansion (S > 0, K = 0 up to the
/// induced edges among previously present nodes... which by construction
/// do not change).
pub fn scenario1_from_static(name: &str, g: &Graph, t_steps: usize) -> DynamicScenario {
    let n = g.n_nodes();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| g.degree(b).cmp(&g.degree(a)).then(a.cmp(&b)));
    let n0 = n / 2;
    let s_per = (n - n0) / t_steps;
    assert!(s_per > 0, "too many steps for graph size");
    let mut current: Vec<usize> = order[..n0].to_vec();
    let initial = g.induced_subgraph(&current).adjacency();
    let mut prev_adj = initial.clone();
    let mut steps = Vec::with_capacity(t_steps);
    for t in 0..t_steps {
        let lo = n0 + t * s_per;
        let hi = if t + 1 == t_steps { n0 + (t + 1) * s_per } else { n0 + (t + 1) * s_per };
        let hi = hi.min(n);
        current.extend_from_slice(&order[lo..hi]);
        let adj = g.induced_subgraph(&current).adjacency();
        let delta = Delta::from_diff(&prev_adj, &adj);
        prev_adj = adj.clone();
        steps.push(TimeStep { delta, adjacency: adj });
    }
    DynamicScenario { name: name.to_string(), initial, steps, labels_per_step: None }
}

/// Scenario 2 (Sec. 5.1): timestamped edge stream.  E⁽⁰⁾ = the first
/// ⌊M/2⌋ edges; each step appends the next ⌊(M−M⁽⁰⁾)/T⌋ edges.  Nodes are
/// indexed by first appearance, so updates mix topological changes
/// (K block) and expansion (G/C blocks).
pub fn scenario2_from_stream(
    name: &str,
    stream: &[(usize, usize)],
    t_steps: usize,
) -> DynamicScenario {
    let m = stream.len();
    let m0 = m / 2;
    let m_per = (m - m0) / t_steps;
    assert!(m_per > 0, "too many steps for stream length");
    // Relabel nodes by first appearance.
    let mut label = std::collections::HashMap::new();
    let relabel = |x: usize, label: &mut std::collections::HashMap<usize, usize>| {
        let next = label.len();
        *label.entry(x).or_insert(next)
    };
    let edges: Vec<(usize, usize)> = stream
        .iter()
        .map(|&(u, v)| (relabel(u, &mut label), relabel(v, &mut label)))
        .collect();
    let build = |upto: usize| -> Csr {
        let n_nodes = edges[..upto]
            .iter()
            .map(|&(u, v)| u.max(v) + 1)
            .max()
            .unwrap_or(0);
        let mut g = Graph::with_nodes(n_nodes);
        for &(u, v) in &edges[..upto] {
            g.add_edge(u, v);
        }
        g.adjacency()
    };
    let initial = build(m0);
    let mut prev = initial.clone();
    let mut steps = Vec::with_capacity(t_steps);
    for t in 0..t_steps {
        let hi = if t + 1 == t_steps { m } else { m0 + (t + 1) * m_per };
        let adj = build(hi);
        let delta = Delta::from_diff(&prev, &adj);
        prev = adj.clone();
        steps.push(TimeStep { delta, adjacency: adj });
    }
    DynamicScenario { name: name.to_string(), initial, steps, labels_per_step: None }
}

/// SBM expansion protocol of Sec. 5.5: generate a full SBM graph, start
/// from a random N⁽⁰⁾-subset, add `s_per` random remaining nodes per step.
/// Ground-truth labels per step are returned for ARI evaluation.
pub fn sbm_expansion(
    n: usize,
    k_clusters: usize,
    p_in: f64,
    p_out: f64,
    n0: usize,
    s_per: usize,
    t_steps: usize,
    rng: &mut Rng,
) -> DynamicScenario {
    assert!(n0 + s_per * t_steps <= n);
    let (g, labels) = crate::graph::generators::sbm(n, k_clusters, p_in, p_out, rng);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut current: Vec<usize> = order[..n0].to_vec();
    let lab_of = |nodes: &[usize]| nodes.iter().map(|&i| labels[i]).collect::<Vec<_>>();
    let initial = g.induced_subgraph(&current).adjacency();
    let mut labels_per_step = vec![lab_of(&current)];
    let mut prev = initial.clone();
    let mut steps = Vec::with_capacity(t_steps);
    for t in 0..t_steps {
        let lo = n0 + t * s_per;
        current.extend_from_slice(&order[lo..lo + s_per]);
        let adj = g.induced_subgraph(&current).adjacency();
        let delta = Delta::from_diff(&prev, &adj);
        prev = adj.clone();
        labels_per_step.push(lab_of(&current));
        steps.push(TimeStep { delta, adjacency: adj });
    }
    DynamicScenario {
        name: format!("sbm_n{n}_k{k_clusters}"),
        initial,
        steps,
        labels_per_step: Some(labels_per_step),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn scenario1_consistency() {
        let mut rng = Rng::new(1);
        let g = generators::erdos_renyi(200, 0.05, &mut rng);
        let sc = scenario1_from_static("er", &g, 5);
        assert_eq!(sc.t_steps(), 5);
        assert_eq!(sc.initial.n_rows, 100);
        // each step: Ā + Δ == Â  (checked via from_diff reconstruction)
        let mut prev = sc.initial.clone();
        for step in &sc.steps {
            assert_eq!(step.delta.n_old, prev.n_rows);
            assert_eq!(step.delta.n_new(), step.adjacency.n_rows);
            // reconstruct: padded prev + delta == adjacency
            let n = step.adjacency.n_rows;
            let mut dense = prev.to_dense().pad_rows(n - prev.n_rows);
            // pad cols too
            let mut full = crate::linalg::mat::Mat::zeros(n, n);
            for i in 0..prev.n_rows {
                for j in 0..prev.n_cols {
                    full.set(i, j, dense.get(i, j));
                }
            }
            let _ = &mut dense;
            full.axpy(1.0, &step.delta.full.to_dense());
            let mut diff = full;
            diff.axpy(-1.0, &step.adjacency.to_dense());
            assert!(diff.max_abs() < 1e-12);
            prev = step.adjacency.clone();
        }
        // final graph has all nodes
        assert_eq!(sc.max_nodes(), 200);
    }

    #[test]
    fn scenario1_pure_expansion_has_no_k_block() {
        // degree-ordered reveal never changes edges among existing nodes
        let mut rng = Rng::new(2);
        let g = generators::erdos_renyi(100, 0.08, &mut rng);
        let sc = scenario1_from_static("er", &g, 4);
        for step in &sc.steps {
            let kb = step.delta.k_block_dense();
            assert!(kb.max_abs() == 0.0, "K block must be empty in Scenario 1");
        }
    }

    #[test]
    fn scenario2_node_growth_and_symmetry() {
        let mut rng = Rng::new(3);
        let (_, stream) = generators::ba_with_arrivals(150, 2, &mut rng);
        let sc = scenario2_from_stream("ba", &stream, 6);
        let mut prev_n = sc.initial.n_rows;
        for step in &sc.steps {
            assert!(step.adjacency.n_rows >= prev_n);
            assert!(step.adjacency.is_symmetric(0.0));
            assert!(step.delta.full.is_symmetric(0.0));
            prev_n = step.adjacency.n_rows;
        }
        assert_eq!(sc.max_nodes(), 150);
    }

    #[test]
    fn sbm_expansion_labels_track_nodes() {
        let mut rng = Rng::new(4);
        let sc = sbm_expansion(120, 3, 0.2, 0.02, 80, 10, 4, &mut rng);
        let labels = sc.labels_per_step.as_ref().unwrap();
        assert_eq!(labels.len(), 5);
        assert_eq!(labels[0].len(), 80);
        assert_eq!(labels[4].len(), 120);
        assert_eq!(sc.steps[3].adjacency.n_rows, 120);
    }
}
