//! Edge-event streams: the ingestion format of the L3 coordinator.
//!
//! Events arrive one at a time (edge add/remove, possibly referencing
//! never-seen node ids); [`DeltaBuilder`] accumulates them against the
//! current graph state and emits the structured update matrix Δ when the
//! coordinator decides to close a batch (paper's "time step").

use crate::graph::graph::Graph;
use crate::sparse::delta::Delta;
use std::collections::HashMap;

/// A single graph mutation event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphEvent {
    /// Add an undirected edge between external node ids.
    AddEdge(u64, u64),
    /// Remove an undirected edge.
    RemoveEdge(u64, u64),
}

/// Accumulates events into a pending batch on top of a committed graph,
/// mapping external ids to dense internal indices (new ids allocate the
/// next index, i.e. the expansion block of Eq. 2).
pub struct DeltaBuilder {
    graph: Graph,
    ids: HashMap<u64, usize>,
    /// committed node count (N in Eq. 2) at the last emit
    committed_nodes: usize,
    pending: Vec<GraphEvent>,
}

impl Default for DeltaBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl DeltaBuilder {
    pub fn new() -> DeltaBuilder {
        DeltaBuilder {
            graph: Graph::with_nodes(0),
            ids: HashMap::new(),
            committed_nodes: 0,
            pending: Vec::new(),
        }
    }

    /// Seed from an existing graph whose nodes use ids 0..n.
    pub fn from_graph(g: Graph) -> DeltaBuilder {
        let n = g.n_nodes();
        let ids = (0..n as u64).map(|i| (i, i as usize)).collect();
        DeltaBuilder { graph: g, ids, committed_nodes: n, pending: Vec::new() }
    }

    pub fn committed_nodes(&self) -> usize {
        self.committed_nodes
    }

    pub fn pending_events(&self) -> usize {
        self.pending.len()
    }

    /// Number of not-yet-committed new nodes referenced by pending events.
    pub fn pending_new_nodes(&self) -> usize {
        self.graph.n_nodes() - self.committed_nodes
    }

    fn intern(&mut self, id: u64) -> usize {
        if let Some(&idx) = self.ids.get(&id) {
            idx
        } else {
            let idx = self.graph.add_nodes(1);
            self.ids.insert(id, idx);
            idx
        }
    }

    /// Apply an event to the working graph and remember it in the batch.
    pub fn push(&mut self, ev: GraphEvent) {
        match ev {
            GraphEvent::AddEdge(a, b) => {
                let (u, v) = (self.intern(a), self.intern(b));
                self.graph.add_edge(u, v);
            }
            GraphEvent::RemoveEdge(a, b) => {
                if let (Some(&u), Some(&v)) = (self.ids.get(&a), self.ids.get(&b)) {
                    self.graph.remove_edge(u, v);
                }
            }
        }
        self.pending.push(ev);
    }

    /// Build (Δ, new adjacency) for the pending batch relative to the
    /// last committed state, WITHOUT committing.  Returns `None` when the
    /// batch is empty or nets out to no change.
    ///
    /// Callers that can fail while applying the batch (the coordinator's
    /// `tracker.update`) must call [`DeltaBuilder::commit`] only after
    /// success; until then the batch stays pending and a later `prepare`
    /// re-emits the accumulated delta against the same committed state.
    pub fn prepare(
        &self,
        prev_adjacency: &crate::sparse::csr::Csr,
    ) -> Option<(Delta, crate::sparse::csr::Csr)> {
        if self.pending.is_empty() && self.graph.n_nodes() == self.committed_nodes {
            return None;
        }
        let adj = self.graph.adjacency();
        let delta = Delta::from_diff(prev_adjacency, &adj);
        if delta.nnz() == 0 && delta.s_new == 0 {
            return None;
        }
        Some((delta, adj))
    }

    /// Mark the pending batch committed (the prepared delta was applied
    /// downstream, or netted out to nothing).
    pub fn commit(&mut self) {
        self.committed_nodes = self.graph.n_nodes();
        self.pending.clear();
    }

    /// Close the batch: [`DeltaBuilder::prepare`] + [`DeltaBuilder::commit`]
    /// in one step, for callers with no fallible work in between.
    pub fn emit(
        &mut self,
        prev_adjacency: &crate::sparse::csr::Csr,
    ) -> Option<(Delta, crate::sparse::csr::Csr)> {
        let out = self.prepare(prev_adjacency);
        self.commit();
        out
    }

    /// Current (uncommitted) graph view.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_accumulate_into_delta() {
        let mut b = DeltaBuilder::new();
        b.push(GraphEvent::AddEdge(10, 20));
        b.push(GraphEvent::AddEdge(20, 30));
        let empty = crate::sparse::csr::Csr::empty(0, 0);
        let (d, adj) = b.emit(&empty).unwrap();
        assert_eq!(d.n_old, 0);
        assert_eq!(d.s_new, 3);
        assert_eq!(adj.n_rows, 3);
        assert_eq!(adj.get(0, 1), 1.0);

        // second batch: remove one edge, add a node
        b.push(GraphEvent::RemoveEdge(10, 20));
        b.push(GraphEvent::AddEdge(30, 40));
        let (d2, adj2) = b.emit(&adj).unwrap();
        assert_eq!(d2.n_old, 3);
        assert_eq!(d2.s_new, 1);
        assert_eq!(d2.full.get(0, 1), -1.0); // removal in K block
        assert_eq!(adj2.get(2, 3), 1.0);
    }

    #[test]
    fn emit_none_when_no_change() {
        let mut b = DeltaBuilder::new();
        let empty = crate::sparse::csr::Csr::empty(0, 0);
        assert!(b.emit(&empty).is_none());
        b.push(GraphEvent::AddEdge(1, 2));
        let (_, adj) = b.emit(&empty).unwrap();
        // add+remove cancels, but the events still touched the graph:
        b.push(GraphEvent::AddEdge(1, 2)); // already exists -> no-op
        b.push(GraphEvent::RemoveEdge(5, 6)); // unknown ids -> no-op
        assert!(b.emit(&adj).is_none());
    }

    #[test]
    fn remove_unknown_edge_is_noop() {
        let mut b = DeltaBuilder::new();
        b.push(GraphEvent::RemoveEdge(1, 2));
        let empty = crate::sparse::csr::Csr::empty(0, 0);
        assert!(b.emit(&empty).is_none());
    }

    #[test]
    fn event_multiplicity_preserved_within_batch() {
        // add then remove within one batch -> net zero delta for that pair
        let mut b = DeltaBuilder::new();
        b.push(GraphEvent::AddEdge(1, 2));
        b.push(GraphEvent::AddEdge(2, 3));
        b.push(GraphEvent::RemoveEdge(1, 2));
        let empty = crate::sparse::csr::Csr::empty(0, 0);
        let (d, adj) = b.emit(&empty).unwrap();
        assert_eq!(adj.get(0, 1), 0.0);
        assert_eq!(adj.get(1, 2), 1.0);
        assert_eq!(d.s_new, 3);
    }
}
