//! Edge-event streams: the ingestion format of the L3 coordinator.
//!
//! Events arrive one at a time (edge add/remove, possibly referencing
//! never-seen node ids); [`DeltaBuilder`] accumulates them against the
//! current graph state and emits the structured update matrix Δ when the
//! coordinator decides to close a batch (paper's "time step").
//!
//! Δ assembly is *event-sourced*: alongside the working graph, the
//! builder keeps the net weight change per edge relative to the last
//! committed state, so [`DeltaBuilder::prepare`] writes the K/G/C blocks
//! straight from that map in O(|batch|) — it never walks the full
//! adjacency.  `Delta::from_diff` over a from-scratch rebuild remains
//! the test oracle for this path, and callers maintain their committed
//! CSR with [`crate::sparse::csr::Csr::apply_delta`].

use crate::graph::graph::Graph;
use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::sparse::delta::Delta;
use std::collections::HashMap;
use crate::sync::Arc;

/// Frozen bidirectional mapping between the dense internal indices the
/// trackers operate on (rows of the eigenvector matrix) and the external
/// node ids the caller ingested.  Published inside every
/// [`crate::coordinator::EmbeddingSnapshot`] so downstream queries can
/// answer in the caller's id space without touching the worker.
#[derive(Clone, Debug, Default)]
pub struct IdMap {
    /// `to_external[i]` is the external id of internal index `i`.
    to_external: Vec<u64>,
    to_internal: HashMap<u64, usize>,
}

impl IdMap {
    /// The identity mapping `i -> i` over `0..n` (the contract of
    /// [`DeltaBuilder::from_graph`] for seed graphs).
    pub fn identity(n: usize) -> IdMap {
        IdMap::from_externals((0..n as u64).collect())
    }

    /// Build from the internal-order list of external ids (must be
    /// distinct — the interner guarantees this).
    pub fn from_externals(to_external: Vec<u64>) -> IdMap {
        let to_internal =
            to_external.iter().enumerate().map(|(i, &e)| (e, i)).collect();
        IdMap { to_external, to_internal }
    }

    /// Number of mapped nodes.
    pub fn len(&self) -> usize {
        self.to_external.len()
    }

    pub fn is_empty(&self) -> bool {
        self.to_external.is_empty()
    }

    /// External id of internal index `i`.
    pub fn external(&self, i: usize) -> Option<u64> {
        self.to_external.get(i).copied()
    }

    /// Internal index of external id `e`.
    pub fn internal(&self, e: u64) -> Option<usize> {
        self.to_internal.get(&e).copied()
    }

    /// All external ids in internal-index order.
    pub fn externals(&self) -> &[u64] {
        &self.to_external
    }
}

/// A single graph mutation event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphEvent {
    /// Add an undirected edge between external node ids.
    AddEdge(u64, u64),
    /// Remove an undirected edge.
    RemoveEdge(u64, u64),
}

/// Accumulates events into a pending batch on top of a committed graph,
/// mapping external ids to dense internal indices (new ids allocate the
/// next index, i.e. the expansion block of Eq. 2).
///
/// Self-loop events (`AddEdge(a, a)` / `RemoveEdge(a, a)`) are dropped
/// before interning: the graph model is simple (`Graph::add_edge`
/// rejects self loops), and interning the id would allocate a phantom
/// isolated node that silently inflates S.
pub struct DeltaBuilder {
    graph: Graph,
    ids: HashMap<u64, usize>,
    /// external id of each interned internal index, in intern order
    externals: Vec<u64>,
    /// frozen map over `externals[..committed_nodes]`, rebuilt
    /// copy-on-write only at commits that added nodes, so
    /// [`DeltaBuilder::committed_ids`] is an O(1) Arc clone
    committed_map: Arc<IdMap>,
    /// committed node count (N in Eq. 2) at the last emit
    committed_nodes: usize,
    /// count of pending (non-self-loop) events, for the batch policy;
    /// Δ assembly itself reads only `net`, so events are not retained
    pending_events: usize,
    /// Net weight change per undirected edge (canonical `u < v` keys)
    /// of the working graph relative to the committed state; entries
    /// netting to zero are removed, so at prepare time this *is* the
    /// K/G/C content of Δ.
    net: HashMap<(usize, usize), f64>,
}

impl Default for DeltaBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl DeltaBuilder {
    pub fn new() -> DeltaBuilder {
        DeltaBuilder {
            graph: Graph::with_nodes(0),
            ids: HashMap::new(),
            externals: Vec::new(),
            committed_map: Arc::new(IdMap::default()),
            committed_nodes: 0,
            pending_events: 0,
            net: HashMap::new(),
        }
    }

    /// Seed from an existing graph whose nodes use ids 0..n.
    pub fn from_graph(g: Graph) -> DeltaBuilder {
        let n = g.n_nodes();
        let ids = (0..n as u64).map(|i| (i, i as usize)).collect();
        DeltaBuilder {
            graph: g,
            ids,
            externals: (0..n as u64).collect(),
            committed_map: Arc::new(IdMap::identity(n)),
            committed_nodes: n,
            pending_events: 0,
            net: HashMap::new(),
        }
    }

    /// Rebuild a builder whose committed state is an existing adjacency
    /// with its intern-order external-id list — the checkpoint-restore
    /// path.  The working graph is reconstructed edge-by-edge from the
    /// CSR's upper triangle, so a builder restored from a checkpoint is
    /// indistinguishable from one that ingested the original stream and
    /// committed at the same point.
    pub fn from_committed(adjacency: &Csr, externals: Vec<u64>) -> DeltaBuilder {
        let n = externals.len();
        debug_assert_eq!(adjacency.n_rows, n, "id list must cover the adjacency");
        let mut graph = Graph::with_nodes(n);
        for u in 0..adjacency.n_rows.min(n) {
            for p in adjacency.indptr[u]..adjacency.indptr[u + 1] {
                let v = adjacency.indices[p];
                if u < v && v < n && adjacency.data[p] != 0.0 {
                    graph.add_edge(u, v);
                }
            }
        }
        let ids = externals.iter().enumerate().map(|(i, &e)| (e, i)).collect();
        DeltaBuilder {
            graph,
            ids,
            externals: externals.clone(),
            committed_map: Arc::new(IdMap::from_externals(externals)),
            committed_nodes: n,
            pending_events: 0,
            net: HashMap::new(),
        }
    }

    pub fn committed_nodes(&self) -> usize {
        self.committed_nodes
    }

    pub fn pending_events(&self) -> usize {
        self.pending_events
    }

    /// Number of not-yet-committed new nodes referenced by pending events.
    pub fn pending_new_nodes(&self) -> usize {
        self.graph.n_nodes() - self.committed_nodes
    }

    fn intern(&mut self, id: u64) -> usize {
        if let Some(&idx) = self.ids.get(&id) {
            idx
        } else {
            let idx = self.graph.add_nodes(1);
            self.ids.insert(id, idx);
            self.externals.push(id);
            idx
        }
    }

    /// Id mapping of the *committed* node space (the first
    /// `committed_nodes` interned ids).  This is what the coordinator
    /// publishes alongside each snapshot: pending, not-yet-committed
    /// arrivals are excluded, so the map always covers exactly the rows
    /// of the published eigenvector matrix.  O(1): the map is rebuilt
    /// copy-on-write at [`DeltaBuilder::commit`] only when the batch
    /// added nodes; edge-only batches re-share the previous Arc.
    pub fn committed_ids(&self) -> Arc<IdMap> {
        self.committed_map.clone()
    }

    /// Record a net edge-weight change relative to the committed state.
    fn record(&mut self, u: usize, v: usize, w: f64) {
        let key = (u.min(v), u.max(v));
        let e = self.net.entry(key).or_insert(0.0);
        *e += w;
        if *e == 0.0 {
            self.net.remove(&key);
        }
    }

    /// Apply an event to the working graph and remember it in the batch.
    pub fn push(&mut self, ev: GraphEvent) {
        match ev {
            GraphEvent::AddEdge(a, b) => {
                if a == b {
                    return; // self loop: no-op, never interned
                }
                let (u, v) = (self.intern(a), self.intern(b));
                if self.graph.add_edge(u, v) {
                    self.record(u, v, 1.0);
                }
            }
            GraphEvent::RemoveEdge(a, b) => {
                if a == b {
                    return;
                }
                let uv = match (self.ids.get(&a).copied(), self.ids.get(&b).copied()) {
                    (Some(u), Some(v)) => Some((u, v)),
                    _ => None,
                };
                if let Some((u, v)) = uv {
                    if self.graph.remove_edge(u, v) {
                        self.record(u, v, -1.0);
                    }
                }
            }
        }
        self.pending_events += 1;
    }

    /// Build Δ for the pending batch relative to the last committed
    /// state, WITHOUT committing — O(|batch|): the K/G/C blocks are
    /// written directly from the net edge-change map; the full
    /// adjacency is never touched.  Returns `None` when the batch is
    /// empty or nets out to no change (and no nodes arrived).
    ///
    /// Callers that can fail while applying the batch (the coordinator's
    /// `tracker.update`) must call [`DeltaBuilder::commit`] only after
    /// success; until then the batch stays pending and a later `prepare`
    /// re-emits the accumulated delta against the same committed state.
    pub fn prepare(&self) -> Option<Delta> {
        let n_old = self.committed_nodes;
        let s_new = self.graph.n_nodes() - n_old;
        if self.net.is_empty() && s_new == 0 {
            return None;
        }
        let mut k = Coo::new(n_old, n_old);
        let mut g = Coo::new(n_old, s_new);
        let mut c = Coo::new(s_new, s_new);
        for (&(u, v), &w) in &self.net {
            // keys are canonical (u < v), so v < n_old means both old
            if v < n_old {
                k.push_sym(u, v, w);
            } else if u < n_old {
                g.push(u, v - n_old, w);
            } else {
                c.push_sym(u - n_old, v - n_old, w);
            }
        }
        Some(Delta::from_blocks(n_old, s_new, &k, &g, &c))
    }

    /// Mark the pending batch committed (the prepared delta was applied
    /// downstream, or netted out to nothing).
    pub fn commit(&mut self) {
        if self.graph.n_nodes() != self.committed_nodes {
            // nodes arrived: refresh the shared committed-id map
            self.committed_map = Arc::new(IdMap::from_externals(self.externals.clone()));
        }
        self.committed_nodes = self.graph.n_nodes();
        self.pending_events = 0;
        self.net.clear();
    }

    /// Close the batch: [`DeltaBuilder::prepare`] + [`DeltaBuilder::commit`]
    /// in one step, for callers with no fallible work in between.
    pub fn emit(&mut self) -> Option<Delta> {
        let out = self.prepare();
        self.commit();
        out
    }

    /// Current (uncommitted) graph view.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::Csr;

    #[test]
    fn events_accumulate_into_delta() {
        let mut b = DeltaBuilder::new();
        b.push(GraphEvent::AddEdge(10, 20));
        b.push(GraphEvent::AddEdge(20, 30));
        let d = b.emit().unwrap();
        assert_eq!(d.n_old, 0);
        assert_eq!(d.s_new, 3);
        let adj = Csr::empty(0, 0).apply_delta(&d);
        assert_eq!(adj.n_rows, 3);
        assert_eq!(adj.get(0, 1), 1.0);

        // second batch: remove one edge, add a node
        b.push(GraphEvent::RemoveEdge(10, 20));
        b.push(GraphEvent::AddEdge(30, 40));
        let d2 = b.emit().unwrap();
        assert_eq!(d2.n_old, 3);
        assert_eq!(d2.s_new, 1);
        assert_eq!(d2.full.get(0, 1), -1.0); // removal in K block
        let adj2 = adj.apply_delta(&d2);
        assert_eq!(adj2.get(2, 3), 1.0);
        assert_eq!(adj2.get(0, 1), 0.0);
    }

    #[test]
    fn emit_none_when_no_change() {
        let mut b = DeltaBuilder::new();
        assert!(b.emit().is_none());
        b.push(GraphEvent::AddEdge(1, 2));
        assert!(b.emit().is_some());
        // add-existing and remove-unknown are both graph no-ops
        b.push(GraphEvent::AddEdge(1, 2)); // already exists -> no-op
        b.push(GraphEvent::RemoveEdge(5, 6)); // unknown ids -> no-op
        assert!(b.emit().is_none());
    }

    #[test]
    fn remove_unknown_edge_is_noop() {
        let mut b = DeltaBuilder::new();
        b.push(GraphEvent::RemoveEdge(1, 2));
        assert!(b.emit().is_none());
    }

    #[test]
    fn self_loop_events_are_noops_and_never_intern() {
        // regression: AddEdge(a, a) used to intern `a` and allocate a
        // phantom isolated node, inflating s_new
        let mut b = DeltaBuilder::new();
        b.push(GraphEvent::AddEdge(7, 7));
        b.push(GraphEvent::RemoveEdge(7, 7));
        assert_eq!(b.pending_events(), 0);
        assert_eq!(b.pending_new_nodes(), 0);
        assert!(b.emit().is_none());
        // a real edge afterwards sees only its own two nodes
        b.push(GraphEvent::AddEdge(7, 8));
        let d = b.emit().unwrap();
        assert_eq!(d.s_new, 2);
    }

    #[test]
    fn event_multiplicity_preserved_within_batch() {
        // add then remove within one batch -> net zero delta for that pair
        let mut b = DeltaBuilder::new();
        b.push(GraphEvent::AddEdge(1, 2));
        b.push(GraphEvent::AddEdge(2, 3));
        b.push(GraphEvent::RemoveEdge(1, 2));
        let d = b.emit().unwrap();
        let adj = Csr::empty(0, 0).apply_delta(&d);
        assert_eq!(adj.get(0, 1), 0.0);
        assert_eq!(adj.get(1, 2), 1.0);
        assert_eq!(d.s_new, 3);
    }

    #[test]
    fn committed_ids_track_intern_order_and_exclude_pending() {
        let mut b = DeltaBuilder::from_graph(Graph::with_nodes(3));
        // seed graph: identity map over 0..3
        let ids = b.committed_ids();
        assert_eq!(ids.externals(), &[0, 1, 2]);
        assert_eq!(ids.internal(2), Some(2));
        // pending arrivals are NOT in the committed map until commit
        b.push(GraphEvent::AddEdge(0, 500));
        b.push(GraphEvent::AddEdge(500, 42));
        let ids = b.committed_ids();
        assert_eq!(ids.len(), 3);
        assert_eq!(ids.internal(500), None);
        b.commit();
        let ids = b.committed_ids();
        assert_eq!(ids.externals(), &[0, 1, 2, 500, 42]);
        assert_eq!(ids.internal(500), Some(3));
        assert_eq!(ids.internal(42), Some(4));
        assert_eq!(ids.external(4), Some(42));
        assert_eq!(ids.external(9), None);
        assert_eq!(ids.internal(7777), None);
        // round trip over the whole map
        for i in 0..ids.len() {
            assert_eq!(ids.internal(ids.external(i).unwrap()), Some(i));
        }
        // edge-only batches re-share the same Arc (O(1) publish)
        let before = b.committed_ids();
        b.push(GraphEvent::AddEdge(0, 1));
        b.commit();
        assert!(Arc::ptr_eq(&before, &b.committed_ids()), "no new nodes: map Arc reused");
        // a node-adding batch swaps in a fresh, extended map
        b.push(GraphEvent::AddEdge(0, 600));
        b.commit();
        assert!(!Arc::ptr_eq(&before, &b.committed_ids()));
        assert_eq!(b.committed_ids().internal(600), Some(5));
    }

    #[test]
    fn from_committed_reconstructs_builder_exactly() {
        // build a committed state the streaming way...
        let mut b = DeltaBuilder::new();
        b.push(GraphEvent::AddEdge(10, 20));
        b.push(GraphEvent::AddEdge(20, 30));
        b.push(GraphEvent::AddEdge(30, 77));
        b.push(GraphEvent::RemoveEdge(10, 20));
        b.commit();
        let committed = b.graph().adjacency();
        // ...then restore from (adjacency, externals) as recovery does
        let mut r = DeltaBuilder::from_committed(
            &committed,
            b.committed_ids().externals().to_vec(),
        );
        assert_eq!(r.committed_nodes(), b.committed_nodes());
        assert_eq!(r.committed_ids().externals(), b.committed_ids().externals());
        let ra = r.graph().adjacency();
        assert_eq!(ra.indptr, committed.indptr);
        assert_eq!(ra.indices, committed.indices);
        assert_eq!(ra.data, committed.data);
        // identical follow-up batches yield identical deltas
        for x in [&mut b, &mut r] {
            x.push(GraphEvent::AddEdge(20, 30)); // existing edge: no-op
            x.push(GraphEvent::AddEdge(77, 99)); // new node
            x.push(GraphEvent::RemoveEdge(20, 30));
        }
        let (db, dr) = (b.emit().unwrap(), r.emit().unwrap());
        assert_eq!(db.full.indptr, dr.full.indptr);
        assert_eq!(db.full.indices, dr.full.indices);
        assert_eq!(db.full.data, dr.full.data);
        assert_eq!(b.committed_ids().externals(), r.committed_ids().externals());
    }

    #[test]
    fn event_sourced_prepare_matches_from_diff_oracle() {
        // property: over random add/remove/expansion streams, the
        // O(|batch|) event-sourced Δ equals the from-scratch
        // rebuild-and-diff oracle, and apply_delta tracks the rebuild
        use crate::linalg::rng::Rng;
        for seed in 0..15u64 {
            let mut rng = Rng::new(1000 + seed);
            let mut b = DeltaBuilder::new();
            let mut committed = Csr::empty(0, 0);
            for _batch in 0..8 {
                for _ in 0..(1 + rng.below(15)) {
                    let x = rng.below(25) as u64;
                    let y = rng.below(35) as u64; // ids ≥ 25 arrive over time
                    if rng.flip(0.7) {
                        b.push(GraphEvent::AddEdge(x, y));
                    } else {
                        b.push(GraphEvent::RemoveEdge(x, y));
                    }
                }
                let oracle = Delta::from_diff(&committed, &b.graph().adjacency());
                match b.prepare() {
                    None => {
                        assert_eq!(oracle.nnz(), 0, "seed {seed}");
                        assert_eq!(oracle.s_new, 0, "seed {seed}");
                        b.commit();
                    }
                    Some(d) => {
                        assert_eq!(d.n_old, oracle.n_old, "seed {seed}");
                        assert_eq!(d.s_new, oracle.s_new, "seed {seed}");
                        assert_eq!(d.full.indptr, oracle.full.indptr, "seed {seed}");
                        assert_eq!(d.full.indices, oracle.full.indices, "seed {seed}");
                        assert_eq!(d.full.data, oracle.full.data, "seed {seed}");
                        b.commit();
                        committed = committed.apply_delta(&d);
                        let rebuild = b.graph().adjacency();
                        assert_eq!(committed.indptr, rebuild.indptr, "seed {seed}");
                        assert_eq!(committed.indices, rebuild.indices, "seed {seed}");
                        assert_eq!(committed.data, rebuild.data, "seed {seed}");
                    }
                }
            }
        }
    }
}
