//! Synthetic dataset registry substituting the paper's SNAP/NDR datasets
//! (Table 2).
//!
//! The sandbox has no network access and cannot download SNAP, so each
//! dataset is replaced by a synthetic graph matched to its (scaled)
//! node/edge counts and heavy-tailed degree profile:
//!
//! * Type **S** (static)  → Chung–Lu with power-law expected degrees.
//! * Type **D** (dynamic) → a preferential-attachment edge stream mixing
//!   node arrivals with edges among existing nodes (matching Scenario 2's
//!   "topological updates + expansion" character).
//!
//! Sizes are scaled down (÷8–÷32, column `scale`) because every benchmark
//! recomputes reference eigenpairs with Lanczos at each step; the
//! algorithmic comparison (who wins, by what factor) is scale-free.  See
//! DESIGN.md §Substitutions.

use crate::graph::generators;
use crate::graph::graph::Graph;
use crate::graph::scenario::{scenario1_from_static, scenario2_from_stream, DynamicScenario};
use crate::linalg::rng::Rng;

/// Whether the paper treats the dataset as static (Scenario 1) or
/// timestamped-dynamic (Scenario 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Static,
    Dynamic,
}

/// One row of Table 2, with paper-scale and build-scale sizes.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub kind: Kind,
    /// Paper's |V| and |E| (for the Table 2 printout).
    pub paper_nodes: usize,
    pub paper_edges: usize,
    /// Our synthetic build sizes.
    pub nodes: usize,
    pub edges: usize,
    /// Down-scale factor applied (documentation).
    pub scale: usize,
    /// Default number of time steps T for this dataset's scenario.
    pub t_steps: usize,
    /// Power-law exponent of the degree profile.
    pub gamma: f64,
}

/// The eight datasets of Table 2 (scaled).
pub fn registry() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec { name: "Crocodile", kind: Kind::Static, paper_nodes: 11_631, paper_edges: 170_773, nodes: 1454, edges: 21_347, scale: 8, t_steps: 10, gamma: 2.2 },
        DatasetSpec { name: "CM-Collab", kind: Kind::Static, paper_nodes: 23_133, paper_edges: 93_439, nodes: 2892, edges: 11_680, scale: 8, t_steps: 10, gamma: 2.5 },
        DatasetSpec { name: "Epinions", kind: Kind::Static, paper_nodes: 75_879, paper_edges: 405_740, nodes: 4742, edges: 25_359, scale: 16, t_steps: 10, gamma: 2.1 },
        DatasetSpec { name: "Twitch", kind: Kind::Static, paper_nodes: 168_114, paper_edges: 6_797_557, nodes: 5254, edges: 212_424, scale: 32, t_steps: 8, gamma: 2.1 },
        DatasetSpec { name: "MathOverflow", kind: Kind::Dynamic, paper_nodes: 24_818, paper_edges: 187_986, nodes: 1551, edges: 11_749, scale: 16, t_steps: 20, gamma: 2.3 },
        DatasetSpec { name: "Tech", kind: Kind::Dynamic, paper_nodes: 34_761, paper_edges: 107_720, nodes: 2172, edges: 6732, scale: 16, t_steps: 20, gamma: 2.4 },
        DatasetSpec { name: "Enron", kind: Kind::Dynamic, paper_nodes: 87_273, paper_edges: 297_456, nodes: 2727, edges: 9295, scale: 32, t_steps: 25, gamma: 2.2 },
        DatasetSpec { name: "AskUbuntu", kind: Kind::Dynamic, paper_nodes: 159_316, paper_edges: 455_691, nodes: 4978, edges: 14_240, scale: 32, t_steps: 25, gamma: 2.2 },
    ]
}

/// Look up a dataset by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<DatasetSpec> {
    registry()
        .into_iter()
        .find(|d| d.name.eq_ignore_ascii_case(name))
}

/// Build the static graph for a Type-S spec.
pub fn build_static(spec: &DatasetSpec, rng: &mut Rng) -> Graph {
    assert_eq!(spec.kind, Kind::Static);
    let w = generators::power_law_weights(spec.nodes, spec.gamma, spec.edges);
    generators::chung_lu(&w, rng)
}

/// Build the timestamped edge stream for a Type-D spec: preferential
/// attachment arrivals interleaved (30%) with preferential edges among
/// existing nodes.
pub fn build_stream(spec: &DatasetSpec, rng: &mut Rng) -> Vec<(usize, usize)> {
    assert_eq!(spec.kind, Kind::Dynamic);
    let n = spec.nodes;
    let target_e = spec.edges;
    // arrivals contribute ~m edges each; densification edges the rest
    let dens_frac = 0.3;
    let m = (((1.0 - dens_frac) * target_e as f64) / n as f64).round().max(1.0) as usize;
    let mut stream = Vec::with_capacity(target_e);
    let mut targets: Vec<usize> = Vec::with_capacity(4 * target_e);
    let mut edge_set = std::collections::HashSet::new();
    let push_edge =
        |u: usize,
         v: usize,
         stream: &mut Vec<(usize, usize)>,
         targets: &mut Vec<usize>,
         edge_set: &mut std::collections::HashSet<(usize, usize)>| {
            let key = (u.min(v), u.max(v));
            if u != v && edge_set.insert(key) {
                stream.push((u, v));
                targets.push(u);
                targets.push(v);
                true
            } else {
                false
            }
        };
    // seed triangle
    for (u, v) in [(0, 1), (1, 2), (0, 2)] {
        push_edge(u, v, &mut stream, &mut targets, &mut edge_set);
    }
    let mut present = 3;
    while stream.len() < target_e {
        if present < n && (present == 3 || !rng.flip(dens_frac)) {
            // node arrival with m preferential edges
            let u = present;
            present += 1;
            let mut added = 0;
            let mut attempts = 0;
            while added < m && attempts < 20 * m {
                attempts += 1;
                let v = targets[rng.below(targets.len())];
                if push_edge(u, v, &mut stream, &mut targets, &mut edge_set) {
                    added += 1;
                }
            }
        } else {
            // densification edge among existing nodes (preferential ends)
            let u = targets[rng.below(targets.len())];
            let v = targets[rng.below(targets.len())];
            push_edge(u, v, &mut stream, &mut targets, &mut edge_set);
        }
        if present >= n && stream.len() >= target_e {
            break;
        }
    }
    stream
}

/// Build the full evaluation scenario for a dataset (Scenario 1 for
/// Type-S, Scenario 2 for Type-D), with `t_override` steps if given.
pub fn scenario_for(spec: &DatasetSpec, t_override: Option<usize>, rng: &mut Rng) -> DynamicScenario {
    let t = t_override.unwrap_or(spec.t_steps);
    match spec.kind {
        Kind::Static => {
            let g = build_static(spec, rng);
            scenario1_from_static(spec.name, &g, t)
        }
        Kind::Dynamic => {
            let stream = build_stream(spec, rng);
            scenario2_from_stream(spec.name, &stream, t)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_table2_rows() {
        let r = registry();
        assert_eq!(r.len(), 8);
        assert_eq!(r.iter().filter(|d| d.kind == Kind::Static).count(), 4);
        assert!(by_name("crocodile").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn static_build_near_target_size() {
        let mut rng = Rng::new(1);
        let spec = by_name("CM-Collab").unwrap();
        let g = build_static(&spec, &mut rng);
        assert_eq!(g.n_nodes(), spec.nodes);
        let e = g.n_edges() as f64;
        let target = spec.edges as f64;
        assert!(e > 0.5 * target && e < 1.6 * target, "edges {e} vs {target}");
    }

    #[test]
    fn stream_build_properties() {
        let mut rng = Rng::new(2);
        let spec = by_name("Tech").unwrap();
        let stream = build_stream(&spec, &mut rng);
        assert!(stream.len() >= spec.edges);
        // nodes appear in order
        let max_node = stream.iter().map(|&(u, v)| u.max(v)).max().unwrap();
        assert!(max_node < spec.nodes);
        // no duplicate undirected edges
        let set: std::collections::HashSet<(usize, usize)> = stream
            .iter()
            .map(|&(u, v)| (u.min(v), u.max(v)))
            .collect();
        assert_eq!(set.len(), stream.len());
    }

    #[test]
    fn scenario_for_both_kinds() {
        let mut rng = Rng::new(3);
        let s1 = scenario_for(&by_name("CM-Collab").unwrap(), Some(4), &mut rng);
        assert_eq!(s1.t_steps(), 4);
        let s2 = scenario_for(&by_name("Tech").unwrap(), Some(4), &mut rng);
        assert_eq!(s2.t_steps(), 4);
        assert!(s2.max_nodes() > s2.initial.n_rows);
    }
}
