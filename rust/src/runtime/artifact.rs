//! Artifact manifest: which HLO files exist, at which size tiers.
//!
//! Parses `artifacts/manifest.txt` (whitespace format emitted by
//! `python/compile/aot.py` next to the JSON manifest, so no JSON
//! dependency is needed here):
//!
//! ```text
//! fn tier file n k m
//! ```

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One size tier of compiled artifacts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tier {
    pub name: String,
    /// Row capacity (padded N).
    pub n: usize,
    /// Tracked eigenpairs.
    pub k: usize,
    /// Panel width capacity (padded M).
    pub m: usize,
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub fn_name: String,
    pub tier: String,
    pub file: PathBuf,
    pub n: usize,
    pub k: usize,
    pub m: usize,
}

/// Parsed manifest plus base directory.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl ArtifactManifest {
    /// Load from a directory containing `manifest.txt`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Default location: `$GREST_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<ArtifactManifest> {
        let dir = std::env::var("GREST_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    pub fn parse(dir: &Path, text: &str) -> Result<ArtifactManifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 6 {
                bail!("manifest line {}: expected 6 fields", lineno + 1);
            }
            entries.push(ArtifactEntry {
                fn_name: parts[0].to_string(),
                tier: parts[1].to_string(),
                file: dir.join(parts[2]),
                n: parts[3].parse()?,
                k: parts[4].parse()?,
                m: parts[5].parse()?,
            });
        }
        Ok(ArtifactManifest { dir: dir.to_path_buf(), entries })
    }

    /// Distinct tiers, sorted by capacity.
    pub fn tiers(&self) -> Vec<Tier> {
        let mut tiers: Vec<Tier> = Vec::new();
        for e in &self.entries {
            if !tiers.iter().any(|t| t.name == e.tier) {
                tiers.push(Tier { name: e.tier.clone(), n: e.n, k: e.k, m: e.m });
            }
        }
        tiers.sort_by_key(|t| (t.n, t.m));
        tiers
    }

    /// Smallest tier able to hold (n, k, m); k must match exactly (the
    /// tracked eigencount is baked into the artifact shapes).
    pub fn pick_tier(&self, n: usize, k: usize, m: usize) -> Option<Tier> {
        self.tiers()
            .into_iter()
            .find(|t| t.n >= n && t.k == k && t.m >= m)
    }

    /// Path for (fn, tier).
    pub fn path_for(&self, fn_name: &str, tier: &str) -> Option<PathBuf> {
        self.entries
            .iter()
            .find(|e| e.fn_name == fn_name && e.tier == tier)
            .map(|e| e.file.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
build_basis t256 build_basis_t256.hlo.txt 256 16 32
form_t t256 form_t_t256.hlo.txt 256 16 32
rotate t256 rotate_t256.hlo.txt 256 16 32
build_basis t1024 build_basis_t1024.hlo.txt 1024 64 128
";

    #[test]
    fn parse_and_pick() {
        let m = ArtifactManifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 4);
        let tiers = m.tiers();
        assert_eq!(tiers.len(), 2);
        assert_eq!(m.pick_tier(200, 16, 30).unwrap().name, "t256");
        assert_eq!(m.pick_tier(200, 64, 30).unwrap().name, "t1024");
        assert!(m.pick_tier(5000, 16, 30).is_none());
        assert!(m
            .path_for("form_t", "t256")
            .unwrap()
            .ends_with("form_t_t256.hlo.txt"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse(Path::new("/tmp"), "one two").is_err());
    }

    #[test]
    fn loads_real_manifest_when_built() {
        // integration sanity: if the repo's artifacts are built, the
        // manifest must parse and include the t256 tier.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            assert!(m.pick_tier(256, 16, 32).is_some());
        }
    }
}
