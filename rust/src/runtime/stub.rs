//! Stub [`XlaPhases`] for builds without the `xla` feature (the offline
//! default: the external `xla` crate that wraps PJRT is unavailable).
//!
//! The API mirrors `grest_xla::XlaPhases` exactly so callers (the CLI's
//! `--xla` path, benches, examples) compile unchanged; construction via
//! [`XlaPhases::for_problem`] always fails with an explanatory error and
//! callers fall back to the native backend.

use crate::linalg::mat::{Mat, Padded};
use crate::linalg::workspace::StepWorkspace;
use crate::runtime::artifact::{ArtifactManifest, Tier};
use crate::tracking::grest::DensePhases;
use crate::tracking::spec::Backend;
use anyhow::{bail, Result};

/// Placeholder for the PJRT-backed dense phases.  Never constructed in
/// this build; see [`XlaPhases::for_problem`].
pub struct XlaPhases {
    tier: Tier,
    _private: (),
}

impl XlaPhases {
    /// Always fails in a build without the `xla` feature.
    pub fn for_problem(
        _manifest: ArtifactManifest,
        n: usize,
        k: usize,
        m: usize,
    ) -> Result<XlaPhases> {
        bail!(
            "XLA backend unavailable: grest was built without the `xla` feature \
             (requested tier n={n} k={k} m={m}); use the native backend instead"
        )
    }

    pub fn tier(&self) -> &Tier {
        &self.tier
    }
}

impl DensePhases for XlaPhases {
    fn build_basis(&self, _xbar: Padded<'_>, _panel: Mat, _ws: &mut StepWorkspace) -> Mat {
        unreachable!("stub XlaPhases cannot be constructed")
    }

    fn form_t(
        &self,
        _xbar: Padded<'_>,
        _q: &Mat,
        _lam: &[f64],
        _dxk: &Mat,
        _dq: &Mat,
        _ws: &mut StepWorkspace,
    ) -> Mat {
        unreachable!("stub XlaPhases cannot be constructed")
    }

    fn rotate(
        &self,
        _xbar: Padded<'_>,
        _q: &Mat,
        _f1: &Mat,
        _f2: &Mat,
        _ws: &mut StepWorkspace,
    ) -> Mat {
        unreachable!("stub XlaPhases cannot be constructed")
    }

    fn label(&self) -> &'static str {
        "xla-stub"
    }

    fn backend(&self) -> Backend {
        Backend::Xla
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn construction_fails_with_clear_error() {
        let manifest = ArtifactManifest::parse(
            Path::new("/tmp"),
            "build_basis t256 build_basis_t256.hlo.txt 256 16 32\n",
        )
        .unwrap();
        let err = XlaPhases::for_problem(manifest, 200, 16, 20).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
