//! [`XlaPhases`] — the G-REST dense phases executed by the AOT-compiled
//! JAX/Pallas artifacts on PJRT, implementing the same [`DensePhases`]
//! contract as the native Rust pipeline (and unit-tested equal to it).
//!
//! The artifacts are compiled at fixed tier shapes (N_cap, K, M_cap);
//! this wrapper zero-pads inputs to the tier, runs the three phases, and
//! crops the results.  Zero padding is exact, not approximate: padded
//! rows stay zero through project-out/CholQR and padded panel columns are
//! deflated by `build_basis`'s rank screening (invariants tested both in
//! pytest and here).

use crate::linalg::mat::{Mat, Padded};
use crate::linalg::workspace::StepWorkspace;
use crate::runtime::artifact::{ArtifactManifest, Tier};
use crate::runtime::exec::{self, ExecCache};
use crate::tracking::grest::DensePhases;
use crate::tracking::spec::Backend;
use anyhow::{anyhow, Result};

/// PJRT-backed dense phases pinned to one artifact tier.
pub struct XlaPhases {
    manifest: ArtifactManifest,
    tier: Tier,
    cache: ExecCache,
}

impl XlaPhases {
    /// Pick the smallest tier that fits (n, k, m) from the manifest.
    pub fn for_problem(manifest: ArtifactManifest, n: usize, k: usize, m: usize) -> Result<XlaPhases> {
        let tier = manifest
            .pick_tier(n, k, m)
            .ok_or_else(|| anyhow!("no artifact tier fits n={n} k={k} m={m}"))?;
        Ok(XlaPhases { manifest, tier, cache: ExecCache::new() })
    }

    pub fn tier(&self) -> &Tier {
        &self.tier
    }

    fn exe(&self, fn_name: &str) -> Result<&'static xla::PjRtLoadedExecutable> {
        let path = self
            .manifest
            .path_for(fn_name, &self.tier.name)
            .ok_or_else(|| anyhow!("artifact {fn_name}/{} missing", self.tier.name))?;
        self.cache.get(&path)
    }

    fn check_fits(&self, n: usize, k: usize, m: usize) {
        assert!(
            n <= self.tier.n && k == self.tier.k && m <= self.tier.m,
            "problem (n={n},k={k},m={m}) exceeds tier {:?}",
            self.tier
        );
    }

    fn run_build_basis(&self, xbar: &Mat, panel: &Mat) -> Result<Mat> {
        let (n, k) = (xbar.rows(), xbar.cols());
        let m = panel.cols();
        self.check_fits(n, k, m);
        let t = &self.tier;
        let exe = self.exe("build_basis")?;
        let lits = exec::run_tuple(
            exe,
            &[
                exec::mat_to_literal(xbar, t.n, t.k)?,
                exec::mat_to_literal(panel, t.n, t.m)?,
            ],
        )?;
        // outputs: q (n×m), valid (m)
        let q = exec::literal_to_mat(&lits[0], t.n, t.m, n, t.m)?;
        let valid = exec::literal_to_vec(&lits[1], t.m)?;
        // keep only valid columns (they are exactly zero otherwise)
        let kept: Vec<usize> = (0..t.m).filter(|&j| valid[j] > 0.5).collect();
        Ok(q.select_cols(&kept))
    }

    fn run_form_t(&self, xbar: &Mat, q: &Mat, lam: &[f64], dxk: &Mat, dq: &Mat) -> Result<Mat> {
        let (n, k) = (xbar.rows(), xbar.cols());
        let m = q.cols();
        self.check_fits(n, k, m);
        let t = &self.tier;
        let exe = self.exe("form_t")?;
        let lits = exec::run_tuple(
            exe,
            &[
                exec::mat_to_literal(xbar, t.n, t.k)?,
                exec::mat_to_literal(q, t.n, t.m)?,
                exec::vec_to_literal(lam, t.k)?,
                exec::mat_to_literal(dxk, t.n, t.k)?,
                exec::mat_to_literal(dq, t.n, t.m)?,
            ],
        )?;
        let dim = t.k + t.m;
        // crop to the logical (k+m)×(k+m): rows/cols [0..k] ∪ [k..k+m]
        let full = exec::literal_to_mat(&lits[0], dim, dim, dim, dim)?;
        let mut out = Mat::zeros(k + m, k + m);
        let map = |i: usize| if i < k { i } else { t.k + (i - k) };
        for i in 0..k + m {
            for j in 0..k + m {
                out.set(i, j, full.get(map(i), map(j)));
            }
        }
        Ok(out)
    }

    fn run_rotate(&self, xbar: &Mat, q: &Mat, f1: &Mat, f2: &Mat) -> Result<Mat> {
        let (n, k) = (xbar.rows(), xbar.cols());
        let m = q.cols();
        self.check_fits(n, k, m);
        let t = &self.tier;
        let exe = self.exe("rotate")?;
        let lits = exec::run_tuple(
            exe,
            &[
                exec::mat_to_literal(xbar, t.n, t.k)?,
                exec::mat_to_literal(q, t.n, t.m)?,
                exec::mat_to_literal(f1, t.k, t.k)?,
                exec::mat_to_literal(f2, t.m, t.k)?,
            ],
        )?;
        exec::literal_to_mat(&lits[0], t.n, t.k, n, k)
    }
}

impl DensePhases for XlaPhases {
    // PJRT marshalling zero-pads every operand to the tier shape anyway,
    // so this backend materializes the Padded X̄ view before the copy-in;
    // its returned matrices are absorbed by the caller's workspace.
    fn build_basis(&self, xbar: Padded<'_>, panel: Mat, ws: &mut StepWorkspace) -> Mat {
        let xb = xbar.materialize();
        let q = self
            .run_build_basis(&xb, &panel)
            .expect("XLA build_basis failed");
        ws.give_mat(panel);
        q
    }

    fn form_t(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        lam: &[f64],
        dxk: &Mat,
        dq: &Mat,
        _ws: &mut StepWorkspace,
    ) -> Mat {
        let xb = xbar.materialize();
        self.run_form_t(&xb, q, lam, dxk, dq)
            .expect("XLA form_t failed")
    }

    fn rotate(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        f1: &Mat,
        f2: &Mat,
        _ws: &mut StepWorkspace,
    ) -> Mat {
        let xb = xbar.materialize();
        self.run_rotate(&xb, q, f1, f2).expect("XLA rotate failed")
    }

    fn label(&self) -> &'static str {
        "xla"
    }

    fn backend(&self) -> Backend {
        Backend::Xla
    }

    fn tier_caps(&self) -> (usize, usize) {
        (self.tier.n, self.tier.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::thin_qr;
    use crate::linalg::rng::Rng;
    use crate::tracking::grest::NativePhases;

    fn phases() -> Option<XlaPhases> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping XLA tests: artifacts not built");
            return None;
        }
        let manifest = ArtifactManifest::load(&dir).unwrap();
        Some(XlaPhases::for_problem(manifest, 200, 16, 20).unwrap())
    }

    #[test]
    fn xla_matches_native_build_basis() {
        let Some(xp) = phases() else { return };
        let mut rng = Rng::new(1);
        let mut ws = StepWorkspace::new();
        let (x, _) = thin_qr(&Mat::randn(200, 16, &mut rng));
        let panel = Mat::randn(200, 20, &mut rng);
        let q_xla = xp.build_basis(Padded::from(&x), panel.clone(), &mut ws);
        let q_nat = NativePhases::default().build_basis(Padded::from(&x), panel.clone(), &mut ws);
        assert_eq!(q_xla.cols(), q_nat.cols());
        // bases may differ by rotation; compare projectors P = QQᵀ on a
        // probe block
        let probe = Mat::randn(200, 5, &mut rng);
        let p_xla = q_xla.matmul(&q_xla.t_matmul(&probe));
        let p_nat = q_nat.matmul(&q_nat.t_matmul(&probe));
        let mut diff = p_xla.clone();
        diff.axpy(-1.0, &p_nat);
        assert!(diff.max_abs() < 1e-3, "projector mismatch {}", diff.max_abs());
        // orthonormality & orthogonality to x (f32 tolerance)
        let g = q_xla.t_matmul(&q_xla);
        let mut eye = Mat::eye(q_xla.cols());
        eye.axpy(-1.0, &g);
        assert!(eye.max_abs() < 1e-4);
        assert!(x.t_matmul(&q_xla).max_abs() < 1e-4);
    }

    #[test]
    fn xla_matches_native_form_t_and_rotate() {
        let Some(xp) = phases() else { return };
        let mut rng = Rng::new(2);
        let mut ws = StepWorkspace::new();
        let (x, _) = thin_qr(&Mat::randn(150, 16, &mut rng));
        let (qfull, _) = thin_qr(&Mat::randn(150, 36, &mut rng));
        // q must be orthogonal to x for the contract; project and renorm
        let q =
            NativePhases::default().build_basis(Padded::from(&x), qfull.top_left(150, 12), &mut ws);
        let lam: Vec<f64> = (0..16).map(|i| 8.0 - i as f64).collect();
        let dxk = Mat::randn(150, 16, &mut rng);
        let dq = Mat::randn(150, q.cols(), &mut rng);
        let t_xla = xp.form_t(Padded::from(&x), &q, &lam, &dxk, &dq, &mut ws);
        let t_nat = NativePhases::default().form_t(Padded::from(&x), &q, &lam, &dxk, &dq, &mut ws);
        let mut diff = t_xla.clone();
        diff.axpy(-1.0, &t_nat);
        assert!(diff.max_abs() < 1e-3, "form_t mismatch {}", diff.max_abs());

        let f1 = Mat::randn(16, 16, &mut rng);
        let f2 = Mat::randn(q.cols(), 16, &mut rng);
        let r_xla = xp.rotate(Padded::from(&x), &q, &f1, &f2, &mut ws);
        let r_nat = NativePhases::default().rotate(Padded::from(&x), &q, &f1, &f2, &mut ws);
        let mut rdiff = r_xla.clone();
        rdiff.axpy(-1.0, &r_nat);
        assert!(rdiff.max_abs() < 1e-3, "rotate mismatch {}", rdiff.max_abs());
    }

    #[test]
    fn xla_grest_end_to_end_matches_native() {
        // build the XLA tracker the way every other construction site
        // does: through the declarative TrackerSpec factory
        let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !artifacts.join("manifest.txt").exists() {
            eprintln!("skipping XLA tests: artifacts not built");
            return;
        }
        use crate::sparse::coo::Coo;
        use crate::sparse::delta::Delta;
        use crate::tracking::spec::TrackerSpec;
        use crate::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};
        let mut rng = Rng::new(3);
        let w = crate::graph::generators::power_law_weights(120, 2.2, 400);
        let a = crate::graph::generators::chung_lu(&w, &mut rng).adjacency();
        let init = init_eigenpairs(&a, 16, 4);
        // a rich Δ (rank > panel width) so the native and XLA pipelines
        // face a full-rank panel and deflation plays no role — deflation
        // thresholds differ by design (f32 vs f64) and rank-deficient
        // panels legitimately yield different (equally valid) subspaces.
        let mut kb = Coo::new(120, 120);
        let mut krng = Rng::new(99);
        for _ in 0..60 {
            let (u, v) = (krng.below(120), krng.below(120));
            if u != v {
                kb.push(u, v, 1.0);
                kb.push(v, u, 1.0);
            }
        }
        let kb = {
            // clamp duplicate pushes back to ±1
            let csr = kb.to_csr();
            let mut c2 = Coo::new(120, 120);
            for i in 0..120 {
                let (cols, vals) = csr.row(i);
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    c2.push(i, j, v.clamp(-1.0, 1.0));
                }
            }
            c2
        };
        let mut g = Coo::new(120, 2);
        g.push(0, 0, 1.0);
        g.push(5, 1, 1.0);
        g.push(17, 0, 1.0);
        g.push(44, 1, 1.0);
        let mut c = Coo::new(2, 2);
        c.push_sym(0, 1, 1.0);
        let d = Delta::from_blocks(120, 2, &kb, &g, &c);

        let mut spec = TrackerSpec::parse("grest3:n=200,m=20,seed=7@xla").unwrap();
        // explicit dir instead of $GREST_ARTIFACTS: no process-global
        // env mutation in a multithreaded test binary
        spec.artifacts_dir = Some(artifacts);
        let mut t_xla = spec.build(&a, &init).expect("spec-built XLA tracker");
        assert_eq!(t_xla.name(), "G-REST3@xla");
        let mut t_nat = GRest::new(init, SubspaceMode::Full);
        t_xla.update(&d).unwrap();
        t_nat.update(&d).unwrap();
        for j in 0..16 {
            assert!(
                (t_xla.current().values[j] - t_nat.current().values[j]).abs() < 1e-3,
                "λ{j}: xla {} vs native {}",
                t_xla.current().values[j],
                t_nat.current().values[j]
            );
        }
        // top eigenvector agreement
        let ov = crate::linalg::blas::dot(
            t_xla.current().vectors.col(0),
            t_nat.current().vectors.col(0),
        )
        .abs();
        assert!(ov > 1.0 - 1e-4, "top vector overlap {ov}");
    }
}
