//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes the G-REST dense phases on the
//! XLA CPU client.  Python never runs here — artifacts are produced once
//! by `make artifacts` and this module is pure Rust + PJRT.

pub mod artifact;
pub mod client;
pub mod exec;
pub mod grest_xla;

pub use artifact::{ArtifactManifest, Tier};
pub use grest_xla::XlaPhases;
