//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes the G-REST dense phases on the
//! XLA CPU client.  Python never runs here — artifacts are produced once
//! by `make artifacts` and this module is pure Rust + PJRT.
//!
//! The PJRT pieces need the external `xla` crate, which is not available
//! in the offline build; they are gated behind the `xla` cargo feature.
//! The default build ships [`stub::XlaPhases`] — same API, but
//! construction always fails with a clear error, so every caller keeps
//! compiling and degrades to the native backend at runtime.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod exec;
#[cfg(feature = "xla")]
pub mod grest_xla;
#[cfg(not(feature = "xla"))]
pub mod stub;

pub use artifact::{ArtifactManifest, Tier};
#[cfg(feature = "xla")]
pub use grest_xla::XlaPhases;
#[cfg(not(feature = "xla"))]
pub use stub::XlaPhases;
