//! Literal marshalling and compiled-executable cache.
//!
//! Our dense matrices are f64 column-major; PJRT literals here are f32
//! row-major (the artifacts are compiled at f32 — see DESIGN.md).  All
//! padding/unpadding to the artifact tier shapes happens in this module
//! so the callers deal only in logical shapes.

use crate::linalg::mat::Mat;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// f64 column-major (rows×cols) → f32 row-major literal padded to
/// (pad_rows×pad_cols).
pub fn mat_to_literal(m: &Mat, pad_rows: usize, pad_cols: usize) -> Result<xla::Literal> {
    assert!(m.rows() <= pad_rows && m.cols() <= pad_cols, "mat {}x{} exceeds pad {}x{}", m.rows(), m.cols(), pad_rows, pad_cols);
    let mut buf = vec![0f32; pad_rows * pad_cols];
    for j in 0..m.cols() {
        let col = m.col(j);
        for (i, &v) in col.iter().enumerate() {
            buf[i * pad_cols + j] = v as f32;
        }
    }
    Ok(xla::Literal::vec1(&buf).reshape(&[pad_rows as i64, pad_cols as i64])?)
}

/// f64 slice → f32 rank-1 literal padded to `pad_len`.
pub fn vec_to_literal(v: &[f64], pad_len: usize) -> Result<xla::Literal> {
    assert!(v.len() <= pad_len);
    let mut buf = vec![0f32; pad_len];
    for (b, &x) in buf.iter_mut().zip(v.iter()) {
        *b = x as f32;
    }
    Ok(xla::Literal::vec1(&buf).reshape(&[pad_len as i64])?)
}

/// f32 row-major literal (pad_rows×pad_cols) → f64 column-major Mat
/// cropped to (rows×cols).
pub fn literal_to_mat(
    lit: &xla::Literal,
    pad_rows: usize,
    pad_cols: usize,
    rows: usize,
    cols: usize,
) -> Result<Mat> {
    let data: Vec<f32> = lit.to_vec()?;
    if data.len() != pad_rows * pad_cols {
        return Err(anyhow!(
            "literal size {} != padded {}x{}",
            data.len(),
            pad_rows,
            pad_cols
        ));
    }
    let mut out = Mat::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            out.set(i, j, data[i * pad_cols + j] as f64);
        }
    }
    Ok(out)
}

/// Rank-1 literal → f64 vec cropped to `len`.
pub fn literal_to_vec(lit: &xla::Literal, len: usize) -> Result<Vec<f64>> {
    let data: Vec<f32> = lit.to_vec()?;
    Ok(data.iter().take(len).map(|&x| x as f64).collect())
}

/// Cache of compiled executables, keyed by artifact path.  Compilation of
/// a large tier takes O(seconds); each artifact compiles exactly once per
/// process.
pub struct ExecCache {
    compiled: RefCell<HashMap<PathBuf, &'static xla::PjRtLoadedExecutable>>,
}

impl Default for ExecCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecCache {
    pub fn new() -> ExecCache {
        ExecCache { compiled: RefCell::new(HashMap::new()) }
    }

    /// Get (or compile) the executable for an HLO text file.  Like the
    /// client, executables are thread-bound (`Rc` internals), so the
    /// cache is a `RefCell` and `ExecCache` is deliberately `!Send`.
    pub fn get(&self, path: &Path) -> Result<&'static xla::PjRtLoadedExecutable> {
        if let Some(exe) = self.compiled.borrow().get(path) {
            return Ok(exe);
        }
        let client = crate::runtime::client::cpu_client()?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        // Executables live for the process lifetime (compiled once, shared
        // within the thread); leaking avoids self-referential lifetimes.
        let exe: &'static xla::PjRtLoadedExecutable = Box::leak(Box::new(exe));
        self.compiled.borrow_mut().insert(path.to_path_buf(), exe);
        Ok(exe)
    }
}

/// Run an executable whose output is a tuple of `n_outputs` literals.
pub fn run_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(inputs)?;
    let lit = result[0][0].to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_literal_roundtrip_with_padding() {
        let m = Mat::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let lit = mat_to_literal(&m, 4, 5).unwrap();
        let back = literal_to_mat(&lit, 4, 5, 2, 3).unwrap();
        let mut diff = back.clone();
        diff.axpy(-1.0, &m);
        assert!(diff.max_abs() < 1e-6);
        // padded area is zero
        let full: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(full[3], 0.0); // row 0, col 3
        assert_eq!(full[3 * 5], 0.0); // row 3, col 0
    }

    #[test]
    fn vec_literal_roundtrip() {
        let v = [1.5, -2.5, 3.25];
        let lit = vec_to_literal(&v, 6).unwrap();
        let back = literal_to_vec(&lit, 3).unwrap();
        for (a, b) in back.iter().zip(v.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
