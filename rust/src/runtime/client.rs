//! Per-thread PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-backed (neither `Send` nor
//! `Sync`), so the client is cached per thread; creating one takes
//! ~100 ms, so anything that executes artifacts should stay on one
//! thread (the coordinator runs the tracker on a dedicated worker
//! thread for exactly this reason).

use anyhow::{anyhow, Result};
use std::cell::OnceCell;

thread_local! {
    static CLIENT: OnceCell<std::result::Result<&'static xla::PjRtClient, String>> =
        const { OnceCell::new() };
}

/// The calling thread's CPU PJRT client (created and leaked on first use).
pub fn cpu_client() -> Result<&'static xla::PjRtClient> {
    CLIENT.with(|cell| {
        cell.get_or_init(|| {
            xla::PjRtClient::cpu()
                .map(|c| &*Box::leak(Box::new(c)))
                .map_err(|e| e.to_string())
        })
        .clone()
        .map_err(|e| anyhow!("PJRT client init failed: {e}"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_boots_and_is_cached() {
        let a = cpu_client().unwrap();
        assert!(a.device_count() >= 1);
        let b = cpu_client().unwrap();
        assert!(std::ptr::eq(a, b));
    }
}
