//! Adjusted Rand Index (Hubert & Arabie 1985) — the clustering-agreement
//! metric of paper Sec. 5.5.

/// ARI between two labelings of the same points.  1 = identical
/// partitions (up to relabeling), ~0 = chance agreement.
pub fn adjusted_rand_index(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n <= 1 {
        return 1.0;
    }
    let ka = a.iter().max().map(|&m| m + 1).unwrap_or(0);
    let kb = b.iter().max().map(|&m| m + 1).unwrap_or(0);
    // contingency table
    let mut table = vec![0u64; ka * kb];
    let mut ra = vec![0u64; ka];
    let mut rb = vec![0u64; kb];
    for i in 0..n {
        table[a[i] * kb + b[i]] += 1;
        ra[a[i]] += 1;
        rb[b[i]] += 1;
    }
    let c2 = |x: u64| (x * x.saturating_sub(1)) as f64 / 2.0;
    let sum_ij: f64 = table.iter().map(|&x| c2(x)).sum();
    let sum_a: f64 = ra.iter().map(|&x| c2(x)).sum();
    let sum_b: f64 = rb.iter().map(|&x| c2(x)).sum();
    let total = c2(n as u64);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0;
    }
    (sum_ij - expected) / (max_index - expected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    #[test]
    fn identical_partitions() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn relabeled_partitions_are_identical() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![2, 2, 0, 0, 1, 1];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_partitions_near_zero() {
        let mut rng = Rng::new(1);
        let n = 5000;
        let a: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        let b: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari.abs() < 0.03, "ARI {ari}");
    }

    #[test]
    fn partial_agreement_between_zero_and_one() {
        let a = vec![0, 0, 0, 0, 1, 1, 1, 1];
        let b = vec![0, 0, 0, 1, 1, 1, 1, 0];
        let ari = adjusted_rand_index(&a, &b);
        assert!(ari > 0.0 && ari < 1.0, "ARI {ari}");
    }

    #[test]
    fn known_value_example() {
        // classic example: ARI is symmetric
        let a = vec![0, 0, 1, 1];
        let b = vec![0, 1, 0, 1];
        let x = adjusted_rand_index(&a, &b);
        let y = adjusted_rand_index(&b, &a);
        assert!((x - y).abs() < 1e-12);
        assert!(x < 0.01);
    }
}
