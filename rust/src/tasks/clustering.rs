//! Spectral clustering (paper Sec. 5.5): k-means++ on the rows of the
//! tracked eigenvector matrix of the (shifted) normalized Laplacian.
//!
//! The per-point work (seeding distance updates and the Lloyd assign
//! step) is row-partitioned across a [`Threads`] budget; every point's
//! label/distance is produced by exactly one thread with a fixed
//! reduction order, so results are **bitwise identical across thread
//! counts** — the same determinism contract as the dense kernels.
//!
//! The distance phases optionally run on the f32-storage /
//! f64-accumulate serving tier ([`ServePrecision::F32`]): the points
//! are demoted once to a row-major [`F32Mat`] and each scan loads f32
//! rows while accumulating distances in f64.  Center *updates* (the
//! mean step) and the empty-cluster re-seed stay f64 — only the
//! bandwidth-bound scans change.  The f32 path keeps the same
//! bitwise-across-thread-counts guarantee (same chunk-ordered
//! partition, same per-point arithmetic); it differs from the f64
//! *oracle* path by the documented f32 storage rounding.

use crate::graph::stream::IdMap;
use crate::linalg::f32mat::{self, F32Mat, ServePrecision};
use crate::linalg::mat::Mat;
use crate::linalg::rng::Rng;
use crate::linalg::threads::{kernel_pool, Threads};
use crate::tracking::traits::EigenPairs;

/// Cluster assignment computed from one published embedding, keyed by
/// external node ids (re-exported as `coordinator::ClusterAssignment`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterAssignment {
    /// Snapshot version the labels were computed at.
    pub version: u64,
    /// External node ids, in internal row order.
    pub nodes: Vec<u64>,
    /// `labels[i]` is the cluster of `nodes[i]`.
    pub labels: Vec<usize>,
}

impl ClusterAssignment {
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Cluster of one external node id (linear scan — iterate
    /// `nodes`/`labels` directly for bulk access).
    pub fn label_of(&self, external: u64) -> Option<usize> {
        self.nodes.iter().position(|&e| e == external).map(|i| self.labels[i])
    }
}

/// K-means result.
pub struct KMeansResult {
    pub labels: Vec<usize>,
    pub centers: Mat,
    pub inertia: f64,
}

/// K-means++ with `n_init` restarts on the *rows* of `x` (n points of
/// dimension d = x.cols()); returns the best run by inertia.
pub fn kmeans(x: &Mat, k: usize, n_init: usize, max_iter: usize, rng: &mut Rng) -> KMeansResult {
    kmeans_with(x, k, n_init, max_iter, rng, Threads::SINGLE)
}

/// [`kmeans`] with an explicit worker budget for the per-point phases.
pub fn kmeans_with(
    x: &Mat,
    k: usize,
    n_init: usize,
    max_iter: usize,
    rng: &mut Rng,
    threads: Threads,
) -> KMeansResult {
    kmeans_with_precision(x, k, n_init, max_iter, rng, threads, ServePrecision::F64)
}

/// [`kmeans_with`] with an explicit distance-phase precision.  `F64` is
/// the oracle; `F32` demotes the points once and runs the seeding and
/// assign scans on the serving tier (see module docs).
#[allow(clippy::too_many_arguments)]
pub fn kmeans_with_precision(
    x: &Mat,
    k: usize,
    n_init: usize,
    max_iter: usize,
    rng: &mut Rng,
    threads: Threads,
    precision: ServePrecision,
) -> KMeansResult {
    assert!(k >= 1);
    let n = x.rows();
    // one demotion for every restart and every distance phase
    let xf = match precision {
        ServePrecision::F64 => None,
        ServePrecision::F32 => Some(F32Mat::from_mat(x)),
    };
    let mut best: Option<KMeansResult> = None;
    for _ in 0..n_init.max(1) {
        let r = kmeans_single(x, xf.as_ref(), k, max_iter, rng, threads);
        if best.as_ref().map(|b| r.inertia < b.inertia).unwrap_or(true) {
            best = Some(r);
        }
    }
    let mut out = best.unwrap();
    if out.labels.len() != n {
        out.labels.resize(n, 0);
    }
    out
}

fn row_dist2(x: &Mat, i: usize, center: &[f64]) -> f64 {
    let d = x.cols();
    let mut s = 0.0;
    for c in 0..d {
        let diff = x.get(i, c) - center[c];
        s += diff * diff;
    }
    s
}

/// Map `f` over row indices `0..n`, partitioned into contiguous chunks
/// dispatched on the persistent kernel pool.  Each output element is
/// produced by exactly one executor and results are concatenated in
/// chunk order, so the output is identical to the sequential
/// `(0..n).map(f)` for any worker count.
fn par_map_rows<T: Send>(
    n: usize,
    workers: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    // one pre-sized slot per chunk; the pool fills them in place
    let mut slots: Vec<Vec<T>> = Vec::with_capacity(workers);
    slots.resize_with(workers, Vec::new);
    {
        let fr = &f;
        let mut parts = Vec::with_capacity(workers);
        for (w, slot) in slots.iter_mut().enumerate() {
            let lo = (w * chunk).min(n);
            let hi = ((w + 1) * chunk).min(n);
            parts.push((lo, hi, slot));
        }
        kernel_pool().run(parts, move |(lo, hi, slot): (usize, usize, &mut Vec<T>)| {
            slot.reserve_exact(hi - lo);
            slot.extend((lo..hi).map(fr));
        });
    }
    let mut out = Vec::with_capacity(n);
    for slot in &mut slots {
        out.append(slot);
    }
    out
}

fn kmeans_single(
    x: &Mat,
    xf: Option<&F32Mat>,
    k: usize,
    max_iter: usize,
    rng: &mut Rng,
    threads: Threads,
) -> KMeansResult {
    let n = x.rows();
    let d = x.cols();
    let k = k.min(n.max(1));
    // worker budgets gated per phase: the assign step does ~3nkd flops,
    // each k-means++ seeding scan only ~3nd (k-fold less — it must not
    // inherit the assign step's fan-out decision)
    let workers = threads.for_flops(3 * n * k * d.max(1));
    let seed_workers = threads.for_flops(3 * n * d.max(1));
    // f32 center scratch of the serving-tier distance phases, demoted
    // fresh before each scan (centers move; the points were demoted
    // once in kmeans_with_precision)
    let mut c32: Vec<f32> = Vec::new();
    // k-means++ seeding
    let mut centers = Mat::zeros(d, k); // column c = center c
    let first = rng.below(n.max(1));
    for c in 0..d {
        centers.set(c, 0, x.get(first, c));
    }
    let mut min_d2: Vec<f64> = match xf {
        None => par_map_rows(n, seed_workers, |i| row_dist2(x, i, centers.col(0))),
        Some(xf) => {
            f32mat::demote_into(centers.col(0), &mut c32);
            let c0: &[f32] = &c32;
            par_map_rows(n, seed_workers, |i| f32mat::row_dist2_f32(xf, i, c0))
        }
    };
    for cidx in 1..k {
        let total: f64 = min_d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut r = rng.uniform() * total;
            let mut chosen = n - 1;
            for (i, &w) in min_d2.iter().enumerate() {
                if r < w {
                    chosen = i;
                    break;
                }
                r -= w;
            }
            chosen
        };
        for c in 0..d {
            centers.set(c, cidx, x.get(pick, c));
        }
        min_d2 = match xf {
            None => par_map_rows(n, seed_workers, |i| {
                let nd = row_dist2(x, i, centers.col(cidx));
                if nd < min_d2[i] {
                    nd
                } else {
                    min_d2[i]
                }
            }),
            Some(xf) => {
                f32mat::demote_into(centers.col(cidx), &mut c32);
                let cc: &[f32] = &c32;
                par_map_rows(n, seed_workers, |i| {
                    let nd = f32mat::row_dist2_f32(xf, i, cc);
                    if nd < min_d2[i] {
                        nd
                    } else {
                        min_d2[i]
                    }
                })
            }
        };
    }
    // Lloyd iterations
    let mut labels = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    for _ in 0..max_iter {
        // assign: per-point nearest center, row-partitioned; the inertia
        // reduction stays sequential over per-point values so the sum
        // order (and hence the restart selection) is thread-independent
        let assign: Vec<(usize, f64)> = match xf {
            None => par_map_rows(n, workers, |i| {
                let mut bestc = 0;
                let mut bestd = f64::INFINITY;
                for c in 0..k {
                    let dd = row_dist2(x, i, centers.col(c));
                    if dd < bestd {
                        bestd = dd;
                        bestc = c;
                    }
                }
                (bestc, bestd)
            }),
            Some(xf) => {
                // demote all k centers once per iteration; the d×k
                // column-major buffer keeps center c contiguous at
                // c·d..(c+1)·d
                f32mat::demote_into(centers.as_slice(), &mut c32);
                let cs: &[f32] = &c32;
                par_map_rows(n, workers, |i| {
                    let mut bestc = 0;
                    let mut bestd = f64::INFINITY;
                    for c in 0..k {
                        let dd = f32mat::row_dist2_f32(xf, i, &cs[c * d..(c + 1) * d]);
                        if dd < bestd {
                            bestd = dd;
                            bestc = c;
                        }
                    }
                    (bestc, bestd)
                })
            }
        };
        let mut changed = false;
        let mut new_inertia = 0.0;
        for (i, &(bestc, bestd)) in assign.iter().enumerate() {
            if labels[i] != bestc {
                labels[i] = bestc;
                changed = true;
            }
            new_inertia += bestd;
        }
        inertia = new_inertia;
        if !changed {
            break;
        }
        // update
        let mut counts = vec![0usize; k];
        let mut sums = Mat::zeros(d, k);
        for i in 0..n {
            counts[labels[i]] += 1;
            for c in 0..d {
                sums.add_at(c, labels[i], x.get(i, c));
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster at the farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        row_dist2(x, a, centers.col(labels[a]))
                            .total_cmp(&row_dist2(x, b, centers.col(labels[b])))
                    })
                    .unwrap_or(0);
                for cc in 0..d {
                    centers.set(cc, c, x.get(far, cc));
                }
            } else {
                for cc in 0..d {
                    centers.set(cc, c, sums.get(cc, c) / counts[c] as f64);
                }
            }
        }
    }
    KMeansResult { labels, centers, inertia }
}

/// Row-normalize an eigenvector block before k-means (standard spectral
/// clustering post-processing; zero rows left untouched).
pub fn normalize_rows(x: &Mat) -> Mat {
    let mut out = x.clone();
    for i in 0..x.rows() {
        let mut s = 0.0;
        for j in 0..x.cols() {
            s += x.get(i, j) * x.get(i, j);
        }
        let nrm = s.sqrt();
        if nrm > 1e-12 {
            for j in 0..x.cols() {
                out.set(i, j, x.get(i, j) / nrm);
            }
        }
    }
    out
}

/// Full spectral-clustering step from tracked eigenvectors.
pub fn spectral_cluster(eigvecs: &Mat, k: usize, seed: u64) -> Vec<usize> {
    spectral_cluster_with(eigvecs, k, seed, Threads::SINGLE)
}

/// [`spectral_cluster`] with an explicit worker budget; bitwise
/// identical to the sequential path for every thread count.
pub fn spectral_cluster_with(eigvecs: &Mat, k: usize, seed: u64, threads: Threads) -> Vec<usize> {
    spectral_cluster_precision(eigvecs, k, seed, threads, ServePrecision::F64)
}

/// [`spectral_cluster_with`] with an explicit distance-phase precision
/// (row normalization stays f64; only the k-means scans change tier).
pub fn spectral_cluster_precision(
    eigvecs: &Mat,
    k: usize,
    seed: u64,
    threads: Threads,
    precision: ServePrecision,
) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let xn = normalize_rows(eigvecs);
    kmeans_with_precision(&xn, k, 5, 100, &mut rng, threads, precision).labels
}

/// Pure snapshot-facing entry point: cluster a published embedding
/// (the eigenpairs + id map of one snapshot `version`), reporting
/// assignments keyed by **external** node ids.  Deterministic in
/// `(version, k, seed)` regardless of `threads`.
pub fn cluster_assignment(
    pairs: &EigenPairs,
    ids: &IdMap,
    version: u64,
    k: usize,
    seed: u64,
    threads: Threads,
) -> ClusterAssignment {
    cluster_assignment_precision(pairs, ids, version, k, seed, threads, ServePrecision::F64)
}

/// [`cluster_assignment`] with an explicit distance-phase precision —
/// the entry point the `QueryEngine` routes its `ServiceConfig` knob
/// through.  Deterministic in `(version, k, seed, precision)`
/// regardless of `threads`.
#[allow(clippy::too_many_arguments)]
pub fn cluster_assignment_precision(
    pairs: &EigenPairs,
    ids: &IdMap,
    version: u64,
    k: usize,
    seed: u64,
    threads: Threads,
    precision: ServePrecision,
) -> ClusterAssignment {
    let labels = spectral_cluster_precision(&pairs.vectors, k, seed, threads, precision);
    ClusterAssignment { version, nodes: ids.externals().to_vec(), labels }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kmeans_separates_blobs() {
        let mut rng = Rng::new(1);
        let n = 90;
        let mut x = Mat::zeros(n, 2);
        for i in 0..n {
            let c = i / 30;
            x.set(i, 0, c as f64 * 10.0 + 0.3 * rng.normal());
            x.set(i, 1, (c as f64 - 1.0) * 8.0 + 0.3 * rng.normal());
        }
        let r = kmeans(&x, 3, 4, 100, &mut rng);
        // all points in one true blob share a label
        for blob in 0..3 {
            let l0 = r.labels[blob * 30];
            for i in 0..30 {
                assert_eq!(r.labels[blob * 30 + i], l0, "blob {blob}");
            }
        }
    }

    #[test]
    fn kmeans_k1_and_k_equals_n() {
        let mut rng = Rng::new(2);
        let x = Mat::randn(10, 3, &mut rng);
        let r1 = kmeans(&x, 1, 1, 50, &mut rng);
        assert!(r1.labels.iter().all(|&l| l == 0));
        let rn = kmeans(&x, 10, 1, 50, &mut rng);
        let distinct: std::collections::HashSet<_> = rn.labels.iter().collect();
        assert!(distinct.len() >= 8); // nearly one point per cluster
    }

    #[test]
    fn kmeans_bitwise_stable_across_thread_counts() {
        // the determinism contract behind the reader-side Threads budget:
        // same seed -> identical labels, centers, and inertia for any
        // worker count (par_map_rows is a chunk-ordered identity)
        // large enough that 3nkd crosses PAR_MIN_FLOPS and the assign
        // step genuinely fans out under Threads(4)
        let mut rng = Rng::new(9);
        let x = Mat::randn(30_000, 8, &mut rng);
        let k = 6;
        assert!(3 * x.rows() * k * x.cols() >= crate::linalg::threads::PAR_MIN_FLOPS);
        let mut r1 = Rng::new(42);
        let mut r4 = Rng::new(42);
        let seq = kmeans_with(&x, k, 2, 25, &mut r1, Threads::SINGLE);
        let par = kmeans_with(&x, k, 2, 25, &mut r4, Threads(4));
        assert_eq!(seq.labels, par.labels);
        assert_eq!(seq.centers.as_slice(), par.centers.as_slice());
        assert!(seq.inertia == par.inertia);
        // and the raw mapper really is a chunk-ordered identity
        let vals = par_map_rows(1003, 5, |i| (i * 31) % 17);
        let want: Vec<usize> = (0..1003).map(|i| (i * 31) % 17).collect();
        assert_eq!(vals, want);
    }

    #[test]
    fn f32_distance_phases_recover_the_same_blobs() {
        // well-separated blobs: the serving tier's ~2⁻²⁴ storage
        // rounding cannot flip any assignment
        let mut rng = Rng::new(21);
        let n = 90;
        let mut x = Mat::zeros(n, 2);
        for i in 0..n {
            let c = i / 30;
            x.set(i, 0, c as f64 * 10.0 + 0.3 * rng.normal());
            x.set(i, 1, (c as f64 - 1.0) * 8.0 + 0.3 * rng.normal());
        }
        let mut r64 = Rng::new(5);
        let mut r32 = Rng::new(5);
        let f64run =
            kmeans_with_precision(&x, 3, 4, 100, &mut r64, Threads::SINGLE, ServePrecision::F64);
        let f32run =
            kmeans_with_precision(&x, 3, 4, 100, &mut r32, Threads::SINGLE, ServePrecision::F32);
        // compare partitions, not raw label ids (seeding picks may
        // permute cluster indices between tiers)
        let ari = crate::tasks::ari::adjusted_rand_index(&f64run.labels, &f32run.labels);
        assert!(ari > 0.999, "tiers disagree on the partition: ARI {ari}");
        for blob in 0..3 {
            let l0 = f32run.labels[blob * 30];
            assert!(f32run.labels[blob * 30..(blob + 1) * 30].iter().all(|&l| l == l0));
        }
        // inertias agree to f32 storage rounding on these magnitudes
        assert!((f64run.inertia - f32run.inertia).abs() < 1e-4 * (1.0 + f64run.inertia));
    }

    #[test]
    fn f32_tier_is_bitwise_stable_across_thread_counts() {
        // the serving tier keeps the chunk-ordered determinism contract:
        // same seed -> identical labels/centers/inertia for any worker
        // count, exactly like the f64 path
        let mut rng = Rng::new(22);
        let x = Mat::randn(30_000, 8, &mut rng);
        let k = 6;
        assert!(3 * x.rows() * k * x.cols() >= crate::linalg::threads::PAR_MIN_FLOPS);
        let mut r1 = Rng::new(42);
        let mut r4 = Rng::new(42);
        let seq =
            kmeans_with_precision(&x, k, 2, 25, &mut r1, Threads::SINGLE, ServePrecision::F32);
        let par = kmeans_with_precision(&x, k, 2, 25, &mut r4, Threads(4), ServePrecision::F32);
        assert_eq!(seq.labels, par.labels);
        assert_eq!(seq.centers.as_slice(), par.centers.as_slice());
        assert!(seq.inertia == par.inertia);
    }

    #[test]
    fn cluster_assignment_precision_f64_is_the_plain_entry_point() {
        let mut rng = Rng::new(23);
        let x = Mat::randn(120, 3, &mut rng);
        let pairs = EigenPairs { values: vec![3.0, 2.0, 1.0], vectors: x };
        let ids = IdMap::from_externals((0..120u64).map(|i| 900 + i).collect());
        let a = cluster_assignment(&pairs, &ids, 9, 3, 7, Threads::SINGLE);
        let b = cluster_assignment_precision(
            &pairs,
            &ids,
            9,
            3,
            7,
            Threads::SINGLE,
            ServePrecision::F64,
        );
        assert_eq!(a, b, "F64 precision is the default path");
    }

    #[test]
    fn spectral_cluster_with_matches_sequential_entry_point() {
        let mut rng = Rng::new(11);
        let x = Mat::randn(200, 4, &mut rng);
        let a = spectral_cluster(&x, 3, 5);
        let b = spectral_cluster_with(&x, 3, 5, Threads(8));
        assert_eq!(a, b);
    }

    #[test]
    fn spectral_clustering_recovers_sbm_blocks() {
        let mut rng = Rng::new(3);
        let (g, truth) = crate::graph::generators::sbm(150, 3, 0.25, 0.01, &mut rng);
        let tn = crate::tracking::laplacian::shifted_normalized_laplacian(&g.adjacency(), 0.0);
        let pairs = crate::tracking::traits::init_eigenpairs(&tn, 3, 4);
        let labels = spectral_cluster(&pairs.vectors, 3, 5);
        let ari = crate::tasks::ari::adjusted_rand_index(&labels, &truth);
        assert!(ari > 0.9, "ARI {ari}");
    }
}
