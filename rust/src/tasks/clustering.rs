//! Spectral clustering (paper Sec. 5.5): k-means++ on the rows of the
//! tracked eigenvector matrix of the (shifted) normalized Laplacian.

use crate::linalg::mat::Mat;
use crate::linalg::rng::Rng;

/// K-means result.
pub struct KMeansResult {
    pub labels: Vec<usize>,
    pub centers: Mat,
    pub inertia: f64,
}

/// K-means++ with `n_init` restarts on the *rows* of `x` (n points of
/// dimension d = x.cols()); returns the best run by inertia.
pub fn kmeans(x: &Mat, k: usize, n_init: usize, max_iter: usize, rng: &mut Rng) -> KMeansResult {
    assert!(k >= 1);
    let n = x.rows();
    let mut best: Option<KMeansResult> = None;
    for _ in 0..n_init.max(1) {
        let r = kmeans_single(x, k, max_iter, rng);
        if best.as_ref().map(|b| r.inertia < b.inertia).unwrap_or(true) {
            best = Some(r);
        }
    }
    let mut out = best.unwrap();
    if out.labels.len() != n {
        out.labels.resize(n, 0);
    }
    out
}

fn row_dist2(x: &Mat, i: usize, center: &[f64]) -> f64 {
    let d = x.cols();
    let mut s = 0.0;
    for c in 0..d {
        let diff = x.get(i, c) - center[c];
        s += diff * diff;
    }
    s
}

fn kmeans_single(x: &Mat, k: usize, max_iter: usize, rng: &mut Rng) -> KMeansResult {
    let n = x.rows();
    let d = x.cols();
    let k = k.min(n.max(1));
    // k-means++ seeding
    let mut centers = Mat::zeros(d, k); // column c = center c
    let first = rng.below(n.max(1));
    for c in 0..d {
        centers.set(c, 0, x.get(first, c));
    }
    let mut min_d2: Vec<f64> = (0..n).map(|i| row_dist2(x, i, centers.col(0))).collect();
    for cidx in 1..k {
        let total: f64 = min_d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut r = rng.uniform() * total;
            let mut chosen = n - 1;
            for (i, &w) in min_d2.iter().enumerate() {
                if r < w {
                    chosen = i;
                    break;
                }
                r -= w;
            }
            chosen
        };
        for c in 0..d {
            centers.set(c, cidx, x.get(pick, c));
        }
        for i in 0..n {
            let nd = row_dist2(x, i, centers.col(cidx));
            if nd < min_d2[i] {
                min_d2[i] = nd;
            }
        }
    }
    // Lloyd iterations
    let mut labels = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    for _ in 0..max_iter {
        // assign
        let mut changed = false;
        let mut new_inertia = 0.0;
        for i in 0..n {
            let mut bestc = 0;
            let mut bestd = f64::INFINITY;
            for c in 0..k {
                let dd = row_dist2(x, i, centers.col(c));
                if dd < bestd {
                    bestd = dd;
                    bestc = c;
                }
            }
            if labels[i] != bestc {
                labels[i] = bestc;
                changed = true;
            }
            new_inertia += bestd;
        }
        inertia = new_inertia;
        if !changed {
            break;
        }
        // update
        let mut counts = vec![0usize; k];
        let mut sums = Mat::zeros(d, k);
        for i in 0..n {
            counts[labels[i]] += 1;
            for c in 0..d {
                sums.add_at(c, labels[i], x.get(i, c));
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster at the farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        row_dist2(x, a, centers.col(labels[a]))
                            .partial_cmp(&row_dist2(x, b, centers.col(labels[b])))
                            .unwrap()
                    })
                    .unwrap_or(0);
                for cc in 0..d {
                    centers.set(cc, c, x.get(far, cc));
                }
            } else {
                for cc in 0..d {
                    centers.set(cc, c, sums.get(cc, c) / counts[c] as f64);
                }
            }
        }
    }
    KMeansResult { labels, centers, inertia }
}

/// Row-normalize an eigenvector block before k-means (standard spectral
/// clustering post-processing; zero rows left untouched).
pub fn normalize_rows(x: &Mat) -> Mat {
    let mut out = x.clone();
    for i in 0..x.rows() {
        let mut s = 0.0;
        for j in 0..x.cols() {
            s += x.get(i, j) * x.get(i, j);
        }
        let nrm = s.sqrt();
        if nrm > 1e-12 {
            for j in 0..x.cols() {
                out.set(i, j, x.get(i, j) / nrm);
            }
        }
    }
    out
}

/// Full spectral-clustering step from tracked eigenvectors.
pub fn spectral_cluster(eigvecs: &Mat, k: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let xn = normalize_rows(eigvecs);
    kmeans(&xn, k, 5, 100, &mut rng).labels
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kmeans_separates_blobs() {
        let mut rng = Rng::new(1);
        let n = 90;
        let mut x = Mat::zeros(n, 2);
        for i in 0..n {
            let c = i / 30;
            x.set(i, 0, c as f64 * 10.0 + 0.3 * rng.normal());
            x.set(i, 1, (c as f64 - 1.0) * 8.0 + 0.3 * rng.normal());
        }
        let r = kmeans(&x, 3, 4, 100, &mut rng);
        // all points in one true blob share a label
        for blob in 0..3 {
            let l0 = r.labels[blob * 30];
            for i in 0..30 {
                assert_eq!(r.labels[blob * 30 + i], l0, "blob {blob}");
            }
        }
    }

    #[test]
    fn kmeans_k1_and_k_equals_n() {
        let mut rng = Rng::new(2);
        let x = Mat::randn(10, 3, &mut rng);
        let r1 = kmeans(&x, 1, 1, 50, &mut rng);
        assert!(r1.labels.iter().all(|&l| l == 0));
        let rn = kmeans(&x, 10, 1, 50, &mut rng);
        let distinct: std::collections::HashSet<_> = rn.labels.iter().collect();
        assert!(distinct.len() >= 8); // nearly one point per cluster
    }

    #[test]
    fn spectral_clustering_recovers_sbm_blocks() {
        let mut rng = Rng::new(3);
        let (g, truth) = crate::graph::generators::sbm(150, 3, 0.25, 0.01, &mut rng);
        let tn = crate::tracking::laplacian::shifted_normalized_laplacian(&g.adjacency(), 0.0);
        let pairs = crate::tracking::traits::init_eigenpairs(&tn, 3, 4);
        let labels = spectral_cluster(&pairs.vectors, 3, 5);
        let ari = crate::tasks::ari::adjusted_rand_index(&labels, &truth);
        assert!(ari > 0.9, "ARI {ari}");
    }
}
