//! Downstream learning tasks driven by the tracked eigenembeddings:
//! central-node identification (Sec. 5.4) and spectral clustering
//! (Sec. 5.5).

pub mod ari;
pub mod centrality;
pub mod clustering;
