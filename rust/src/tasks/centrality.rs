//! Central-node identification via subgraph centrality (paper Sec. 5.4):
//! scores = exp(A)·1 ≈ X_K exp(Λ_K) X_Kᵀ 1; performance is the overlap
//! |Ĩ ∩ I| / J between the top-J sets under estimated vs reference
//! eigenpairs.

use crate::graph::stream::IdMap;
use crate::tracking::matfun::subgraph_centrality_scores;
use crate::tracking::traits::EigenPairs;

/// Indices of the J largest entries of `scores` (ties by index).
/// NaN scores (degenerate eigenpairs can produce them) rank below every
/// real score instead of panicking the comparator.
pub fn top_j(scores: &[f64], j: usize) -> Vec<usize> {
    let key = |s: f64| if s.is_nan() { f64::NEG_INFINITY } else { s };
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| key(scores[b]).total_cmp(&key(scores[a])).then(a.cmp(&b)));
    idx.truncate(j);
    idx
}

/// Top-J central nodes from tracked eigenpairs, as *internal* row
/// indices (the harness/evaluation entry point, where internal and
/// external ids coincide).
pub fn central_nodes(pairs: &EigenPairs, j: usize) -> Vec<usize> {
    let scores = subgraph_centrality_scores(pairs);
    top_j(&scores, j)
}

/// Pure snapshot-facing entry point: top-J central nodes of a published
/// embedding (eigenpairs + the id map frozen with them), reported as
/// **external** node ids.
pub fn central_nodes_external(pairs: &EigenPairs, ids: &IdMap, j: usize) -> Vec<u64> {
    central_nodes(pairs, j)
        .into_iter()
        .map(|i| ids.external(i).expect("snapshot ids cover every row"))
        .collect()
}

/// |a ∩ b| / |a| — the overlap accuracy of Table 3.
pub fn overlap(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() {
        return 1.0;
    }
    let sb: std::collections::HashSet<usize> = b.iter().copied().collect();
    let inter = a.iter().filter(|x| sb.contains(x)).count();
    inter as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracking::traits::init_eigenpairs;

    #[test]
    fn top_j_basics() {
        let s = [0.1, 5.0, 3.0, 4.0];
        assert_eq!(top_j(&s, 2), vec![1, 3]);
        assert_eq!(top_j(&s, 10), vec![1, 3, 2, 0]);
    }

    #[test]
    fn top_j_nan_robust() {
        // regression: partial_cmp().unwrap() used to panic here; NaN
        // scores must rank last and never unseat real scores
        let s = [1.0, f64::NAN, 2.0, f64::NAN, 0.5];
        assert_eq!(top_j(&s, 2), vec![2, 0]);
        assert_eq!(top_j(&s, 5), vec![2, 0, 4, 1, 3]);
        let all_nan = [f64::NAN, f64::NAN];
        assert_eq!(top_j(&all_nan, 1), vec![0], "ties among NaN break by index");
        assert_eq!(top_j(&[f64::NEG_INFINITY, f64::NAN], 2), vec![0, 1]);
    }

    #[test]
    fn central_nodes_external_maps_to_ingested_ids() {
        // star + path as below, but published under shuffled external ids
        let mut coo = crate::sparse::coo::Coo::new(12, 12);
        for i in 1..9 {
            coo.push_sym(0, i, 1.0);
        }
        coo.push_sym(9, 10, 1.0);
        coo.push_sym(10, 11, 1.0);
        let a = coo.to_csr();
        let pairs = init_eigenpairs(&a, 4, 1);
        let externals: Vec<u64> = (0..12u64).map(|i| 1000 + 7 * i).collect();
        let ids = IdMap::from_externals(externals.clone());
        let top = central_nodes_external(&pairs, &ids, 3);
        assert_eq!(top[0], 1000, "hub (internal 0) must surface as its external id");
        for t in &top {
            assert!(externals.contains(t), "external id {t} unknown");
        }
    }

    #[test]
    fn overlap_metric() {
        assert_eq!(overlap(&[1, 2, 3], &[3, 2, 9]), 2.0 / 3.0);
        assert_eq!(overlap(&[], &[1]), 1.0);
        assert_eq!(overlap(&[5], &[5]), 1.0);
    }

    #[test]
    fn hub_is_most_central_from_tracked_pairs() {
        // star + path: node 0 is the hub
        let mut coo = crate::sparse::coo::Coo::new(12, 12);
        for i in 1..9 {
            coo.push_sym(0, i, 1.0);
        }
        coo.push_sym(9, 10, 1.0);
        coo.push_sym(10, 11, 1.0);
        let a = coo.to_csr();
        let pairs = init_eigenpairs(&a, 4, 1);
        let top = central_nodes(&pairs, 3);
        assert_eq!(top[0], 0, "hub must rank first, got {top:?}");
    }

    #[test]
    fn tracked_vs_reference_overlap_high_for_good_tracker() {
        use crate::linalg::rng::Rng;
        let mut rng = Rng::new(2);
        let w = crate::graph::generators::power_law_weights(150, 2.3, 500);
        let g = crate::graph::generators::chung_lu(&w, &mut rng);
        let a = g.adjacency();
        let exact = init_eigenpairs(&a, 16, 3);
        let rough = init_eigenpairs(&a, 16, 4); // different seed, same answer
        let o = overlap(&central_nodes(&rough, 20), &central_nodes(&exact, 20));
        assert!(o > 0.95, "overlap {o}");
    }
}
