//! # G-REST — Graph Rayleigh-Ritz Eigenspace Tracking
//!
//! A production-oriented reproduction of *"Subspace Projection Methods for
//! Fast Spectral Embeddings of Evolving Graphs"* (Eini, Karaaslanli,
//! Kalantzis, Traganitis; 2026).
//!
//! The crate tracks the K leading eigenpairs of the adjacency (or shifted
//! Laplacian) matrix of an evolving graph under edge updates and node
//! additions, using Rayleigh–Ritz projections onto the subspace
//!
//! ```text
//! Z = Ran([ X̄_K , (I − X̄_K X̄_Kᵀ)[ Δ X̄_K , Δ₂ ] ])      (paper Eq. 11)
//! ```
//!
//! ## Layout
//!
//! * [`sparse`]   — CSR/COO matrices and the structured update matrix Δ.
//! * [`linalg`]   — dense kernels (QR, symmetric eigh, Jacobi SVD, Lanczos,
//!   randomized SVD) built from scratch; no external BLAS/LAPACK.
//! * [`graph`]    — dynamic graphs, synthetic generators, the paper's two
//!   evaluation scenarios, and the (substituted) dataset registry.
//! * [`tracking`] — the trackers: TRIP-Basic, TRIP, Residual Modes, IASC,
//!   TIMERS, and the proposed G-REST₂ / G-REST₃ / G-REST_RSVD (Alg. 2),
//!   plus Laplacian and matrix-function tracking (paper Sec. 4).  Every
//!   tracker is addressed declaratively through
//!   [`tracking::spec::TrackerSpec`] (`grest-rsvd:l=32,p=16`,
//!   `grest3@xla`, …) and built by its registry-backed factory.
//! * [`runtime`]  — PJRT execution of the AOT-compiled JAX/Pallas dense
//!   pipeline (`artifacts/*.hlo.txt`); Python is never on the request path.
//! * [`coordinator`] — the L3 streaming service: event ingestion, update
//!   batching, snapshot store, metrics.
//! * [`tasks`]    — downstream tasks: subgraph centrality, spectral
//!   clustering (k-means + ARI).
//! * [`eval`]     — experiment harness reproducing every table/figure.

pub mod coordinator;
pub mod eval;
pub mod graph;
pub mod linalg;
pub mod runtime;
pub mod sparse;
pub mod sync;
pub mod tasks;
pub mod tracking;

pub use linalg::mat::Mat;
pub use sparse::csr::Csr;
pub use sparse::delta::Delta;
pub use tracking::TrackerSpec;
