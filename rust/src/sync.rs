//! The crate-wide synchronization facade.
//!
//! Every module imports its concurrency primitives from here instead of
//! `std::sync` (enforced by `detlint` rule `raw-std-sync`).  In the
//! default build these are thin wrappers over — or straight re-exports
//! of — the std types.  The payoff is model-checkability: the
//! `rust/loom-model` crate compiles the scheduler protocol
//! (`coordinator/pool_core.rs`), the memo-cache core
//! (`coordinator/memo_core.rs`), and the kernel-pool dispatch protocol
//! (`linalg/kernel_core.rs`) against a `loom`-backed twin of this
//! facade under `--cfg loom`, exploring every interleaving of the
//! lock/CAS/condvar protocol — without `loom` ever appearing in this
//! crate's dependency graph (the offline tier-1 build stays
//! dependency-free).
//!
//! The wrappers also centralize poison handling: a poisoned lock means
//! another thread panicked while holding it, and this crate's policy is
//! to propagate that panic at the next acquisition (same behavior the
//! scattered `.lock().unwrap()` calls had, now in one audited place —
//! `detlint` bans `unwrap`/`expect` in coordinator code).

pub use std::sync::atomic;
pub use std::sync::mpsc;
pub use std::sync::{Arc, MutexGuard, OnceLock, RwLockReadGuard, RwLockWriteGuard, Weak};

/// [`std::sync::Mutex`] that panics on poison at acquisition instead of
/// returning a `Result` (callers never see a `LockResult`).
#[derive(Debug, Default)]
pub struct Mutex<T: ?Sized>(std::sync::Mutex<T>);

impl<T> Mutex<T> {
    pub fn new(value: T) -> Mutex<T> {
        Mutex(std::sync::Mutex::new(value))
    }
}

impl<T: ?Sized> Mutex<T> {
    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.0.lock().expect("mutex poisoned: a thread panicked while holding this lock")
    }
}

/// [`std::sync::RwLock`] with the same poison-panics-here policy.
#[derive(Debug, Default)]
pub struct RwLock<T: ?Sized>(std::sync::RwLock<T>);

impl<T> RwLock<T> {
    pub fn new(value: T) -> RwLock<T> {
        RwLock(std::sync::RwLock::new(value))
    }
}

impl<T: ?Sized> RwLock<T> {
    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        self.0.read().expect("rwlock poisoned: a thread panicked while holding this lock")
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        self.0.write().expect("rwlock poisoned: a thread panicked while holding this lock")
    }
}

/// [`std::sync::Condvar`] whose wait methods take and return plain
/// guards (poison panics here, and `wait_timeout` reports the timeout
/// as a bare `bool`).
#[derive(Debug, Default)]
pub struct Condvar(std::sync::Condvar);

impl Condvar {
    pub fn new() -> Condvar {
        Condvar(std::sync::Condvar::new())
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.0.wait(guard).expect("mutex poisoned during condvar wait")
    }

    /// Wait with a timeout; returns the reacquired guard and whether
    /// the wait timed out (vs. was notified).
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let (guard, res) =
            self.0.wait_timeout(guard, dur).expect("mutex poisoned during condvar wait");
        (guard, res.timed_out())
    }

    pub fn notify_one(&self) {
        self.0.notify_one();
    }

    pub fn notify_all(&self) {
        self.0.notify_all();
    }
}

/// A write-once cell for `Clone` values: concurrent readers of an
/// unfilled slot share exactly one in-flight `get_or_init` computation.
///
/// This is the memo-cache primitive.  It deliberately exposes a
/// *clone-based* API (values out, never references) so the loom twin
/// can implement it with a `Mutex<Option<T>>` — `loom` has no
/// `OnceLock` — while the std flavor rides the real
/// [`std::sync::OnceLock`] blocking-initializer guarantee.
#[derive(Debug, Default)]
pub struct OnceSlot<T>(std::sync::OnceLock<T>);

impl<T: Clone> OnceSlot<T> {
    /// `const` so a slot can live in a `static` (e.g. the cached
    /// machine-parallelism lookup in `linalg/threads.rs`).  The loom
    /// twin's `new` is non-`const` (loom mutexes allocate lazily);
    /// nothing compiled under `--cfg loom` uses a `static` slot.
    pub const fn new() -> OnceSlot<T> {
        OnceSlot(std::sync::OnceLock::new())
    }

    /// The value, if some caller already initialized the slot.
    pub fn try_get(&self) -> Option<T> {
        self.0.get().cloned()
    }

    /// The value, initializing the slot with `f` if empty.  At most one
    /// caller ever runs `f`; racing callers block on that computation.
    pub fn get_or_init(&self, f: impl FnOnce() -> T) -> T {
        self.0.get_or_init(f).clone()
    }
}

pub mod thread {
    //! Thread spawning for pool workers.  The loom twin maps
    //! `spawn_named` onto `loom::thread::spawn` (names are a
    //! diagnostics nicety the model checker doesn't have).

    pub use std::thread::JoinHandle;

    /// Spawn an OS thread with a descriptive name (visible in
    /// debuggers, panics, and `/proc`).
    pub fn spawn_named(name: &str, f: impl FnOnce() + Send + 'static) -> JoinHandle<()> {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("the OS refused to spawn a thread")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_and_condvar_roundtrip() {
        let m = Arc::new(Mutex::new(0u32));
        let cv = Arc::new(Condvar::new());
        let (m2, cv2) = (m.clone(), cv.clone());
        let t = std::thread::spawn(move || {
            let mut g = m2.lock();
            *g = 7;
            cv2.notify_one();
        });
        let mut g = m.lock();
        while *g == 0 {
            g = cv.wait(g);
        }
        assert_eq!(*g, 7);
        drop(g);
        t.join().expect("helper thread");
    }

    #[test]
    fn wait_timeout_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = m.lock();
        let (_g, timed_out) = cv.wait_timeout(g, std::time::Duration::from_millis(1));
        assert!(timed_out);
    }

    #[test]
    fn once_slot_initializes_exactly_once() {
        let slot = OnceSlot::new();
        assert_eq!(slot.try_get(), None);
        assert_eq!(slot.get_or_init(|| 41), 41);
        assert_eq!(slot.get_or_init(|| 99), 41, "second init must be ignored");
        assert_eq!(slot.try_get(), Some(41));
    }

    #[test]
    fn rwlock_read_write() {
        let l = RwLock::new(5u64);
        assert_eq!(*l.read(), 5);
        *l.write() = 6;
        assert_eq!(*l.read(), 6);
    }
}
