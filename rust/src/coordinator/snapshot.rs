//! Versioned embedding snapshots with lock-cheap concurrent reads:
//! the worker publishes `Arc<EmbeddingSnapshot>` swaps; readers clone the
//! Arc under a short read lock and never block the tracker.
//!
//! A snapshot is *self-sufficient*: eigenpairs, version, and the frozen
//! internal→external node-id mapping travel together, so every
//! downstream query (centrality, clustering, per-node embedding lookup,
//! similarity) can be answered from the snapshot alone — in the
//! caller's id space — without ever sending a worker command.

use crate::graph::stream::IdMap;
use crate::sync::{Arc, RwLock};
use crate::tracking::traits::EigenPairs;
use std::time::{Duration, Instant, SystemTime};

/// When a snapshot was published, on two clocks at once.
///
/// `snapshot_age` must come from a *monotonic* clock (wall clocks jump
/// under NTP skew), but a monotonic anchor alone cannot round-trip
/// through a checkpoint — `Instant` means nothing across processes.  So
/// a stamp carries both: a monotonic anchor for age arithmetic in this
/// process, and wall-clock micros for the checkpoint.  After restore,
/// `base` pre-loads the age with the wall-clock elapsed time (clamped
/// at zero, so backwards skew can never yield a negative age) and the
/// anchor restarts monotone from there.
#[derive(Clone, Copy, Debug)]
pub struct PublishStamp {
    anchor: Instant,
    base: Duration,
    wall_us: u64,
}

impl PublishStamp {
    /// Stamp for a snapshot published right now.
    pub fn now() -> PublishStamp {
        let wall_us = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map_or(0, |d| d.as_micros() as u64);
        PublishStamp { anchor: Instant::now(), base: Duration::ZERO, wall_us }
    }

    /// Stamp for a snapshot restored from a checkpoint that recorded
    /// `wall_us`.  The reported age starts at the wall-clock elapsed
    /// time since the original publish — or zero if the clock moved
    /// backwards meanwhile — and grows monotonically from there.
    pub fn restored(wall_us: u64) -> PublishStamp {
        let now_us = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map_or(0, |d| d.as_micros() as u64);
        let base = Duration::from_micros(now_us.saturating_sub(wall_us));
        PublishStamp { anchor: Instant::now(), base, wall_us }
    }

    /// Monotone age: never decreases, never negative, regardless of
    /// wall-clock skew.
    pub fn age(&self) -> Duration {
        self.base + self.anchor.elapsed()
    }

    /// Wall-clock micros since the Unix epoch at the original publish
    /// (what checkpoints persist).
    pub fn wall_us(&self) -> u64 {
        self.wall_us
    }
}

/// An immutable published embedding state.
pub struct EmbeddingSnapshot {
    /// Monotone version, one per applied batch.
    pub version: u64,
    /// Nodes covered by this snapshot.
    pub n_nodes: usize,
    /// The tracked eigenpairs.
    pub pairs: EigenPairs,
    /// Internal-index ↔ external-id mapping frozen at the batch commit;
    /// covers exactly the rows of `pairs.vectors`.
    pub ids: Arc<IdMap>,
    /// When this snapshot was published (checkpoint-aware monotone
    /// clock).
    pub published_at: PublishStamp,
}

impl EmbeddingSnapshot {
    /// The K-dimensional embedding row of an external node id, or `None`
    /// when the id was never part of this snapshot's committed space.
    pub fn embedding(&self, external: u64) -> Option<Vec<f64>> {
        let i = self.ids.internal(external)?;
        if i >= self.pairs.n() {
            return None;
        }
        Some((0..self.pairs.k()).map(|j| self.pairs.vectors.get(i, j)).collect())
    }

    /// Age of this snapshot (time since publication) on the monotone
    /// clock — safe against wall-clock skew, checkpoint-aware.
    pub fn age(&self) -> Duration {
        self.published_at.age()
    }
}

/// Single-writer multi-reader snapshot cell.
#[derive(Clone)]
pub struct SnapshotStore {
    inner: Arc<RwLock<Arc<EmbeddingSnapshot>>>,
}

impl SnapshotStore {
    pub fn new(initial: EmbeddingSnapshot) -> SnapshotStore {
        SnapshotStore { inner: Arc::new(RwLock::new(Arc::new(initial))) }
    }

    /// Latest snapshot (cheap: clones an Arc).
    pub fn latest(&self) -> Arc<EmbeddingSnapshot> {
        self.inner.read().clone()
    }

    /// Publish a new snapshot; enforces monotone versions and the
    /// ids-cover-all-rows invariant.
    pub fn publish(&self, snap: EmbeddingSnapshot) {
        debug_assert_eq!(
            snap.ids.len(),
            snap.n_nodes,
            "snapshot id map must cover every node"
        );
        let mut w = self.inner.write();
        assert!(
            snap.version > w.version,
            "snapshot versions must be monotone ({} -> {})",
            w.version,
            snap.version
        );
        *w = Arc::new(snap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;

    fn snap(version: u64, n: usize) -> EmbeddingSnapshot {
        EmbeddingSnapshot {
            version,
            n_nodes: n,
            pairs: EigenPairs { values: vec![1.0], vectors: Mat::zeros(n, 1) },
            ids: Arc::new(IdMap::identity(n)),
            published_at: PublishStamp::now(),
        }
    }

    #[test]
    fn publish_and_read() {
        let store = SnapshotStore::new(snap(0, 3));
        assert_eq!(store.latest().version, 0);
        store.publish(snap(1, 4));
        assert_eq!(store.latest().version, 1);
        assert_eq!(store.latest().n_nodes, 4);
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn non_monotone_rejected() {
        let store = SnapshotStore::new(snap(5, 3));
        store.publish(snap(5, 3));
    }

    #[test]
    fn embedding_lookup_by_external_id() {
        let mut vectors = Mat::zeros(3, 2);
        for i in 0..3 {
            for j in 0..2 {
                vectors.set(i, j, (10 * i + j) as f64);
            }
        }
        let s = EmbeddingSnapshot {
            version: 1,
            n_nodes: 3,
            pairs: EigenPairs { values: vec![2.0, 1.0], vectors },
            ids: Arc::new(IdMap::from_externals(vec![5, 900, 7])),
            published_at: PublishStamp::now(),
        };
        assert_eq!(s.embedding(900), Some(vec![10.0, 11.0]));
        assert_eq!(s.embedding(7), Some(vec![20.0, 21.0]));
        assert_eq!(s.embedding(1234), None);
    }

    #[test]
    fn publish_stamp_age_is_monotone_and_never_negative() {
        // regression: `published_at` was an Instant that couldn't
        // round-trip a checkpoint; a wall-clock-based replacement would
        // go negative under backwards NTP skew.  The stamp must (a)
        // report non-decreasing ages and (b) clamp at zero when the
        // recorded wall time is in the "future" (clock skew).
        let live = PublishStamp::now();
        let a0 = live.age();
        let a1 = live.age();
        assert!(a1 >= a0, "age must be monotone");

        // restore from a checkpoint written 5 simulated seconds ago:
        // age starts around 5s, not zero
        let old = PublishStamp::now().wall_us().saturating_sub(5_000_000);
        let restored = PublishStamp::restored(old);
        assert!(restored.age() >= Duration::from_secs(4), "age carries across restore");
        assert_eq!(restored.wall_us(), old, "wall anchor survives for the next checkpoint");

        // wall clock moved BACKWARDS between publish and restore: the
        // stamp clamps to zero instead of underflowing
        let future = PublishStamp::now().wall_us() + 3_600_000_000;
        let skewed = PublishStamp::restored(future);
        assert!(skewed.age() < Duration::from_secs(3600), "skew must not inflate age");
        let b0 = skewed.age();
        let b1 = skewed.age();
        assert!(b1 >= b0, "still monotone under skew");
    }

    #[test]
    fn concurrent_readers_see_consistent_versions() {
        let store = SnapshotStore::new(snap(0, 1));
        let mut readers = vec![];
        for _ in 0..4 {
            let s = store.clone();
            readers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..2000 {
                    let v = s.latest().version;
                    assert!(v >= last, "version went backwards");
                    last = v;
                }
            }));
        }
        for v in 1..200 {
            store.publish(snap(v, 1));
        }
        for r in readers {
            r.join().unwrap();
        }
    }
}
