//! Versioned embedding snapshots with lock-cheap concurrent reads:
//! the worker publishes `Arc<EmbeddingSnapshot>` swaps; readers clone the
//! Arc under a short read lock and never block the tracker.

use crate::tracking::traits::EigenPairs;
use std::sync::{Arc, RwLock};

/// An immutable published embedding state.
pub struct EmbeddingSnapshot {
    /// Monotone version, one per applied batch.
    pub version: u64,
    /// Nodes covered by this snapshot.
    pub n_nodes: usize,
    /// The tracked eigenpairs.
    pub pairs: EigenPairs,
    /// Wall time of publication.
    pub published_at: std::time::Instant,
}

/// Single-writer multi-reader snapshot cell.
#[derive(Clone)]
pub struct SnapshotStore {
    inner: Arc<RwLock<Arc<EmbeddingSnapshot>>>,
}

impl SnapshotStore {
    pub fn new(initial: EmbeddingSnapshot) -> SnapshotStore {
        SnapshotStore { inner: Arc::new(RwLock::new(Arc::new(initial))) }
    }

    /// Latest snapshot (cheap: clones an Arc).
    pub fn latest(&self) -> Arc<EmbeddingSnapshot> {
        self.inner.read().unwrap().clone()
    }

    /// Publish a new snapshot; enforces monotone versions.
    pub fn publish(&self, snap: EmbeddingSnapshot) {
        let mut w = self.inner.write().unwrap();
        assert!(
            snap.version > w.version,
            "snapshot versions must be monotone ({} -> {})",
            w.version,
            snap.version
        );
        *w = Arc::new(snap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;

    fn snap(version: u64, n: usize) -> EmbeddingSnapshot {
        EmbeddingSnapshot {
            version,
            n_nodes: n,
            pairs: EigenPairs { values: vec![1.0], vectors: Mat::zeros(n, 1) },
            published_at: std::time::Instant::now(),
        }
    }

    #[test]
    fn publish_and_read() {
        let store = SnapshotStore::new(snap(0, 3));
        assert_eq!(store.latest().version, 0);
        store.publish(snap(1, 4));
        assert_eq!(store.latest().version, 1);
        assert_eq!(store.latest().n_nodes, 4);
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn non_monotone_rejected() {
        let store = SnapshotStore::new(snap(5, 3));
        store.publish(snap(5, 3));
    }

    #[test]
    fn concurrent_readers_see_consistent_versions() {
        let store = SnapshotStore::new(snap(0, 1));
        let mut readers = vec![];
        for _ in 0..4 {
            let s = store.clone();
            readers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..2000 {
                    let v = s.latest().version;
                    assert!(v >= last, "version went backwards");
                    last = v;
                }
            }));
        }
        for v in 1..200 {
            store.publish(snap(v, 1));
        }
        for r in readers {
            r.join().unwrap();
        }
    }
}
