//! Versioned embedding snapshots with lock-cheap concurrent reads:
//! the worker publishes `Arc<EmbeddingSnapshot>` swaps; readers clone the
//! Arc under a short read lock and never block the tracker.
//!
//! A snapshot is *self-sufficient*: eigenpairs, version, and the frozen
//! internal→external node-id mapping travel together, so every
//! downstream query (centrality, clustering, per-node embedding lookup,
//! similarity) can be answered from the snapshot alone — in the
//! caller's id space — without ever sending a worker command.

use crate::graph::stream::IdMap;
use crate::sync::{Arc, RwLock};
use crate::tracking::traits::EigenPairs;

/// An immutable published embedding state.
pub struct EmbeddingSnapshot {
    /// Monotone version, one per applied batch.
    pub version: u64,
    /// Nodes covered by this snapshot.
    pub n_nodes: usize,
    /// The tracked eigenpairs.
    pub pairs: EigenPairs,
    /// Internal-index ↔ external-id mapping frozen at the batch commit;
    /// covers exactly the rows of `pairs.vectors`.
    pub ids: Arc<IdMap>,
    /// Wall time of publication.
    pub published_at: std::time::Instant,
}

impl EmbeddingSnapshot {
    /// The K-dimensional embedding row of an external node id, or `None`
    /// when the id was never part of this snapshot's committed space.
    pub fn embedding(&self, external: u64) -> Option<Vec<f64>> {
        let i = self.ids.internal(external)?;
        if i >= self.pairs.n() {
            return None;
        }
        Some((0..self.pairs.k()).map(|j| self.pairs.vectors.get(i, j)).collect())
    }

    /// Wall-clock age of this snapshot (time since publication).
    pub fn age(&self) -> std::time::Duration {
        self.published_at.elapsed()
    }
}

/// Single-writer multi-reader snapshot cell.
#[derive(Clone)]
pub struct SnapshotStore {
    inner: Arc<RwLock<Arc<EmbeddingSnapshot>>>,
}

impl SnapshotStore {
    pub fn new(initial: EmbeddingSnapshot) -> SnapshotStore {
        SnapshotStore { inner: Arc::new(RwLock::new(Arc::new(initial))) }
    }

    /// Latest snapshot (cheap: clones an Arc).
    pub fn latest(&self) -> Arc<EmbeddingSnapshot> {
        self.inner.read().clone()
    }

    /// Publish a new snapshot; enforces monotone versions and the
    /// ids-cover-all-rows invariant.
    pub fn publish(&self, snap: EmbeddingSnapshot) {
        debug_assert_eq!(
            snap.ids.len(),
            snap.n_nodes,
            "snapshot id map must cover every node"
        );
        let mut w = self.inner.write();
        assert!(
            snap.version > w.version,
            "snapshot versions must be monotone ({} -> {})",
            w.version,
            snap.version
        );
        *w = Arc::new(snap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;

    fn snap(version: u64, n: usize) -> EmbeddingSnapshot {
        EmbeddingSnapshot {
            version,
            n_nodes: n,
            pairs: EigenPairs { values: vec![1.0], vectors: Mat::zeros(n, 1) },
            ids: Arc::new(IdMap::identity(n)),
            published_at: std::time::Instant::now(),
        }
    }

    #[test]
    fn publish_and_read() {
        let store = SnapshotStore::new(snap(0, 3));
        assert_eq!(store.latest().version, 0);
        store.publish(snap(1, 4));
        assert_eq!(store.latest().version, 1);
        assert_eq!(store.latest().n_nodes, 4);
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn non_monotone_rejected() {
        let store = SnapshotStore::new(snap(5, 3));
        store.publish(snap(5, 3));
    }

    #[test]
    fn embedding_lookup_by_external_id() {
        let mut vectors = Mat::zeros(3, 2);
        for i in 0..3 {
            for j in 0..2 {
                vectors.set(i, j, (10 * i + j) as f64);
            }
        }
        let s = EmbeddingSnapshot {
            version: 1,
            n_nodes: 3,
            pairs: EigenPairs { values: vec![2.0, 1.0], vectors },
            ids: Arc::new(IdMap::from_externals(vec![5, 900, 7])),
            published_at: std::time::Instant::now(),
        };
        assert_eq!(s.embedding(900), Some(vec![10.0, 11.0]));
        assert_eq!(s.embedding(7), Some(vec![20.0, 21.0]));
        assert_eq!(s.embedding(1234), None);
    }

    #[test]
    fn concurrent_readers_see_consistent_versions() {
        let store = SnapshotStore::new(snap(0, 1));
        let mut readers = vec![];
        for _ in 0..4 {
            let s = store.clone();
            readers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..2000 {
                    let v = s.latest().version;
                    assert!(v >= last, "version went backwards");
                    last = v;
                }
            }));
        }
        for v in 1..200 {
            store.publish(snap(v, 1));
        }
        for r in readers {
            r.join().unwrap();
        }
    }
}
