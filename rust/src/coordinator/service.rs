//! The tracking service: a single-tenant facade over the shared
//! [`WorkerPool`].
//!
//! Native-backend services no longer own an OS thread: `spawn` builds a
//! [`TenantState`] and registers it on the process-wide pool
//! ([`WorkerPool::global`]), where a fixed set of workers steps any
//! number of tenants.  Multi-tenant callers use
//! [`Fleet`](crate::coordinator::fleet::Fleet) directly; this facade
//! keeps every single-tenant call site (`grest track --serve`, the
//! `embedding_server` example, the soak tests) compiling unchanged.
//!
//! The one exception is `@xla`: the PJRT client and compiled
//! executables are thread-bound (`Rc` internals), so XLA-backed
//! trackers are constructed *and* driven on one dedicated pinned
//! thread ([`TrackingService::spawn_pinned`]) — driving the same state
//! machine, so pooled and pinned runs are bitwise identical for equal
//! command sequences.
//!
//! The worker's only job is ingest: apply batches, publish snapshots.
//! Every read — raw snapshots and all derived queries (central nodes,
//! clusters, embeddings, similarity) — is served off-worker from the
//! lock-cheap [`SnapshotStore`] through the [`QueryEngine`], so query
//! traffic never serializes behind pending batch updates.

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::durability::recover::{self, Recovered};
use crate::coordinator::durability::{backend, DurabilityConfig, TenantDurability};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::{Tenant, WorkerPool};
use crate::coordinator::query::{ClusterAssignment, QueryEngine};
use crate::coordinator::snapshot::{EmbeddingSnapshot, PublishStamp, SnapshotStore};
use crate::coordinator::tenant::{Applied, TenantBudget, TenantCmd, TenantState};
use crate::graph::graph::Graph;
use crate::graph::stream::{DeltaBuilder, GraphEvent, IdMap};
use crate::linalg::f32mat::ServePrecision;
use crate::linalg::threads::Threads;
use crate::sparse::csr::Csr;
use crate::tracking::spec::{Backend, TrackerSpec};
use crate::tracking::traits::{EigTracker, EigenPairs};
use anyhow::{anyhow, Result};
use crate::sync::mpsc::{self, Receiver, Sender};
use crate::sync::Arc;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Builds the tracker inside the pinned worker thread (lets callers
/// choose the XLA backend without `Send` bounds on the tracker).  A
/// build error is reported back through [`TrackingService::spawn`] /
/// [`TrackingService::spawn_with_factory`], which then fail instead of
/// leaving a dead worker behind.
pub type TrackerFactory =
    Box<dyn FnOnce(&Csr, &EigenPairs) -> Result<Box<dyn EigTracker>> + Send>;

/// [`TrackerFactory`] for pool-resident tenants: the tracker hops
/// between worker threads, so it must be `Send` (every native-backend
/// registry tracker is; `@xla` is not — see
/// [`TrackerSpec::build_seeded_send`]).
pub type SendTrackerFactory =
    Box<dyn FnOnce(&Csr, &EigenPairs) -> Result<Box<dyn EigTracker + Send>> + Send>;

/// Service configuration.
pub struct ServiceConfig {
    /// Initial graph (defines A⁽⁰⁾ and the id space 0..n).
    pub initial: Graph,
    /// Tracked eigenpairs.
    pub k: usize,
    /// Batch-closing policy.
    pub policy: BatchPolicy,
    /// Lanczos seed for initialization, the tracker fallback seed, and
    /// the reader-side clustering seed (two services with different
    /// seeds never share k-means randomness).
    pub seed: u64,
    /// Declarative tracker to serve.
    pub tracker: TrackerSpec,
    /// Worker budget for reader-side query kernels (k-means assignment);
    /// results are bitwise identical for every thread count.
    pub threads: Threads,
    /// Read-side serving precision.  `ServePrecision::F64` (the
    /// default everywhere in this crate) answers queries from the f64
    /// snapshot bit-for-bit; `ServePrecision::F32` opts the cosine and
    /// k-means distance scans into the f32-storage/f64-accumulate tier
    /// (see `linalg::f32mat` for the documented tolerance).  The update
    /// step is unaffected either way.
    pub serve_precision: ServePrecision,
    /// Durability: when set, the tenant logs every ingested event to a
    /// WAL under this directory, checkpoints its full state every
    /// `checkpoint_every` flushes, and recovers from both at spawn.
    /// `None` (the default everywhere pre-existing) runs purely in
    /// memory.
    ///
    /// Recovery contract: re-spawning with the *same* `initial` graph
    /// and durability dir resumes bitwise-exactly where the durable
    /// state left off.
    pub durability: Option<DurabilityConfig>,
}

/// A [`ServiceConfig`] that cannot work, caught at spawn instead of
/// surfacing as a confusing runtime failure.
#[derive(Debug)]
pub enum ConfigError {
    /// `DurabilityConfig::checkpoint_every` is zero — the cadence
    /// "checkpoint every 0 flushes" has no meaning.
    ZeroCheckpointInterval,
    /// The durability directory cannot be created or written.
    DirUnwritable { path: PathBuf, detail: String },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroCheckpointInterval => {
                write!(f, "durability.checkpoint_every must be >= 1")
            }
            ConfigError::DirUnwritable { path, detail } => {
                write!(f, "durability dir {} is not writable: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl ServiceConfig {
    /// Validate cross-field invariants (currently: the durability
    /// block).  Every spawn path calls this first.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if let Some(d) = &self.durability {
            if d.checkpoint_every == 0 {
                return Err(ConfigError::ZeroCheckpointInterval);
            }
            if let Err(detail) = backend::probe_dir(&d.dir) {
                return Err(ConfigError::DirUnwritable { path: d.dir.clone(), detail });
            }
        }
        Ok(())
    }
}

/// Where the tenant lives: on a shared pool, or on its own pinned
/// thread (`@xla`).
#[derive(Clone)]
enum TenantRef {
    Pooled { pool: WorkerPool, tenant: Arc<Tenant> },
    Pinned { tx: Sender<TenantCmd> },
}

/// Cloneable, Send handle to the service.
#[derive(Clone)]
pub struct ServiceHandle {
    tenant: TenantRef,
    snapshots: SnapshotStore,
    metrics: Arc<Metrics>,
    query: Arc<QueryEngine>,
}

impl ServiceHandle {
    fn submit(&self, cmd: TenantCmd) -> Result<()> {
        match &self.tenant {
            TenantRef::Pooled { pool, tenant } => pool.submit(tenant, cmd),
            TenantRef::Pinned { tx } => {
                tx.send(cmd).map_err(|_| anyhow!("tracker worker is shut down"))
            }
        }
    }

    /// Ingest a batch of events (non-blocking; the worker applies the
    /// policy).  `events_ingested` counts only successful enqueues — a
    /// send to a shut-down worker must not inflate it.
    pub fn ingest(&self, events: Vec<GraphEvent>) -> Result<()> {
        let n = events.len() as u64;
        self.submit(TenantCmd::Events(events))?;
        self.metrics.events_ingested.add(n);
        Ok(())
    }

    /// Force a flush; returns the published snapshot version.
    pub fn flush(&self) -> Result<u64> {
        let (rtx, rrx) = mpsc::channel();
        self.submit(TenantCmd::Flush(rtx))?;
        rrx.recv().map_err(|_| anyhow!("tracker worker is shut down"))
    }

    /// Latest embedding snapshot (never blocks the worker).
    pub fn snapshot(&self) -> Arc<EmbeddingSnapshot> {
        self.snapshots.latest()
    }

    /// The committed adjacency (a clone of the worker's incrementally
    /// maintained CSR) — for debugging dumps and the soak tests that
    /// cross-check it against a from-scratch rebuild.
    pub fn adjacency(&self) -> Result<Csr> {
        let (rtx, rrx) = mpsc::channel();
        self.submit(TenantCmd::Adjacency(rtx))?;
        rrx.recv().map_err(|_| anyhow!("tracker worker is shut down"))
    }

    /// Top-J central nodes by subgraph centrality on the latest
    /// snapshot, as **external** node ids.  Never touches the worker;
    /// memoized per snapshot version.
    pub fn central_nodes(&self, j: usize) -> Arc<Vec<u64>> {
        self.query.central_nodes(&self.snapshot(), j)
    }

    /// Cluster assignment of the latest snapshot, keyed by **external**
    /// node ids and seeded from [`ServiceConfig::seed`].  Never touches
    /// the worker; memoized per snapshot version.
    pub fn clusters(&self, k: usize) -> Arc<ClusterAssignment> {
        self.query.clusters(&self.snapshot(), k)
    }

    /// Embedding row of one external node id in the latest snapshot.
    pub fn embedding(&self, external: u64) -> Option<Vec<f64>> {
        self.query.embedding(&self.snapshot(), external)
    }

    /// Top-`top` most embedding-cosine-similar nodes to `external` in
    /// the latest snapshot, `(external id, similarity)` descending.
    pub fn similar_to(&self, external: u64, top: usize) -> Option<Arc<Vec<(u64, f64)>>> {
        self.query.similar_to(&self.snapshot(), external, top)
    }

    /// Wall-clock age of the latest published snapshot — how stale the
    /// read path currently is.
    pub fn snapshot_age(&self) -> Duration {
        self.snapshot().age()
    }

    /// The snapshot-only query engine, for pinned-version queries
    /// (`h.query_engine().central_nodes(&snap, j)` answers at `snap`
    /// even after newer versions publish).
    pub fn query_engine(&self) -> &QueryEngine {
        &self.query
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Stop the tenant and wait until no worker will touch it again
    /// (outstanding queued commands are dropped; their reply channels
    /// error out).  Idempotent across handle clones.
    pub fn shutdown(&self) {
        let (ack_tx, ack_rx) = mpsc::channel();
        if self.submit(TenantCmd::Shutdown(ack_tx)).is_ok() {
            // Err here means the worker exited with the ack sender —
            // either way the tenant is retired once recv returns
            let _ = ack_rx.recv();
        }
    }
}

/// The running service: a public handle, plus a join handle only for
/// pinned (`@xla`) tenants — pool-resident tenants own no thread.
pub struct TrackingService {
    pub handle: ServiceHandle,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl TrackingService {
    /// Spawn the service described by `config.tracker` (the declarative
    /// path every production caller uses).  Native-backend trackers run
    /// on the process-wide shared [`WorkerPool::global`]; `@xla` falls
    /// back to a dedicated pinned thread (PJRT state is thread-bound).
    pub fn spawn(config: ServiceConfig) -> Result<TrackingService> {
        config.tracker.validate_buildable()?;
        if config.tracker.backend == Backend::Xla {
            return Self::spawn_pinned(config);
        }
        Self::spawn_on(WorkerPool::global(), config, TenantBudget::default())
    }

    /// Spawn as a tenant of a specific pool, with a resource budget
    /// (the [`Fleet`](crate::coordinator::fleet::Fleet) entry point).
    /// Rejects `@xla` specs — those need [`spawn_pinned`]
    /// (Self::spawn_pinned).
    pub fn spawn_on(
        pool: &WorkerPool,
        config: ServiceConfig,
        budget: TenantBudget,
    ) -> Result<TrackingService> {
        config.tracker.validate_buildable()?;
        let spec = config.tracker.clone();
        let seed = config.seed;
        Self::spawn_on_with_factory(
            pool,
            config,
            budget,
            Box::new(move |a0, init| spec.build_seeded_send(a0, init, seed)),
        )
    }

    /// Pool-tenant escape hatch: a hand-written `Send` tracker factory
    /// (ad-hoc or experimental trackers the registry doesn't know).
    /// `config.tracker` is ignored.
    pub fn spawn_on_with_factory(
        pool: &WorkerPool,
        config: ServiceConfig,
        budget: TenantBudget,
        factory: SendTrackerFactory,
    ) -> Result<TrackingService> {
        config.validate()?;
        let a0 = config.initial.adjacency();
        let init = crate::tracking::traits::init_eigenpairs(&a0, config.k, config.seed);
        // built synchronously on the caller's thread: a broken factory
        // (or a @xla spec routed here) fails the spawn directly
        let tracker = factory(&a0, &init)?;
        let (store, metrics, query) = read_side(&a0, &init, &config);
        let state = build_state(
            tracker,
            config.initial,
            a0,
            config.policy,
            config.durability,
            budget,
            &store,
            &metrics,
        )?;
        let tenant = pool.register(state);
        let handle = ServiceHandle {
            tenant: TenantRef::Pooled { pool: pool.clone(), tenant },
            snapshots: store,
            metrics,
            query,
        };
        Ok(TrackingService { handle, worker: None })
    }

    /// Spawn on a dedicated pinned thread — required for `@xla`,
    /// available to anyone wanting thread-per-tenant isolation (the
    /// fleet bench uses it as the comparison baseline).
    pub fn spawn_pinned(config: ServiceConfig) -> Result<TrackingService> {
        Self::spawn_pinned_budgeted(config, TenantBudget::default())
    }

    /// [`spawn_pinned`](Self::spawn_pinned) with a resource budget.
    pub fn spawn_pinned_budgeted(
        config: ServiceConfig,
        budget: TenantBudget,
    ) -> Result<TrackingService> {
        config.tracker.validate_buildable()?;
        let spec = config.tracker.clone();
        let seed = config.seed;
        Self::spawn_with_factory_budgeted(
            config,
            budget,
            Box::new(move |a0, init| spec.build_seeded(a0, init, seed)),
        )
    }

    /// Pinned-thread escape hatch: spawn with a hand-written factory.
    /// `config.tracker` is ignored; the factory runs on the worker
    /// thread with the initial adjacency and the Lanczos-computed
    /// initial pairs (this is the only spawn path whose tracker may be
    /// `!Send`).
    pub fn spawn_with_factory(
        config: ServiceConfig,
        factory: TrackerFactory,
    ) -> Result<TrackingService> {
        Self::spawn_with_factory_budgeted(config, TenantBudget::default(), factory)
    }

    /// [`spawn_with_factory`](Self::spawn_with_factory) with a budget.
    pub fn spawn_with_factory_budgeted(
        config: ServiceConfig,
        budget: TenantBudget,
        factory: TrackerFactory,
    ) -> Result<TrackingService> {
        config.validate()?;
        let a0 = config.initial.adjacency();
        let init = crate::tracking::traits::init_eigenpairs(&a0, config.k, config.seed);
        let (store, metrics, query) = read_side(&a0, &init, &config);
        let (tx, rx) = mpsc::channel();
        let handle = ServiceHandle {
            tenant: TenantRef::Pinned { tx },
            snapshots: store.clone(),
            metrics: metrics.clone(),
            query,
        };
        let cfg_policy = config.policy;
        let durability = config.durability;
        let initial_graph = config.initial;
        // the worker reports whether the factory succeeded, so a broken
        // tracker spec (e.g. missing XLA artifacts) surfaces here as an
        // error instead of a dead worker behind a healthy-looking handle
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("grest-tracker".into())
            .spawn(move || {
                pinned_loop(
                    rx,
                    initial_graph,
                    a0,
                    init,
                    factory,
                    cfg_policy,
                    durability,
                    store,
                    metrics,
                    budget,
                    ready_tx,
                )
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(TrackingService { handle, worker: Some(worker) }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                let _ = worker.join();
                Err(anyhow!("tracker worker died during startup"))
            }
        }
    }

    /// Shut down and join.
    pub fn join(mut self) {
        self.shutdown_and_wait();
    }

    fn shutdown_and_wait(&mut self) {
        self.handle.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for TrackingService {
    fn drop(&mut self) {
        self.shutdown_and_wait();
    }
}

/// The read side shared by both spawn paths: version-0 snapshot store,
/// metrics, and the snapshot-only query engine.
fn read_side(
    a0: &Csr,
    init: &EigenPairs,
    config: &ServiceConfig,
) -> (SnapshotStore, Arc<Metrics>, Arc<QueryEngine>) {
    let store = SnapshotStore::new(EmbeddingSnapshot {
        version: 0,
        n_nodes: a0.n_rows,
        pairs: init.clone(),
        // the seed graph's external ids are 0..n by the
        // DeltaBuilder::from_graph contract
        ids: Arc::new(IdMap::identity(a0.n_rows)),
        published_at: PublishStamp::now(),
    });
    let metrics = Metrics::new();
    let query = Arc::new(QueryEngine::with_precision(
        config.seed,
        config.threads,
        metrics.clone(),
        config.serve_precision,
    ));
    (store, metrics, query)
}

/// Build the tenant state machine shared by the pooled and pinned
/// spawn paths.  Without durability this is just `TenantState::new`
/// over the initial graph.  With durability it is the recovery flow:
/// load the latest checkpoint (restore builder + adjacency + tracker +
/// version + published snapshot), replay the WAL tail through the
/// normal flush path, then attach the WAL for live logging.
#[allow(clippy::too_many_arguments)]
fn build_state<T: ?Sized + EigTracker>(
    tracker: Box<T>,
    initial: Graph,
    a0: Csr,
    policy: BatchPolicy,
    durability: Option<DurabilityConfig>,
    budget: TenantBudget,
    store: &SnapshotStore,
    metrics: &Arc<Metrics>,
) -> Result<TenantState<T>> {
    let Some(dcfg) = durability else {
        return Ok(TenantState::new(
            tracker,
            DeltaBuilder::from_graph(initial),
            a0,
            policy,
            store.clone(),
            metrics.clone(),
            budget,
        ));
    };
    let Recovered { checkpoint, tail, truncated_bytes, wal, ckpt_backend } =
        recover::load_dir(&dcfg)?;
    metrics.wal_truncated_bytes.add(truncated_bytes);
    let recovered_something = checkpoint.is_some() || !tail.is_empty();
    let mut tracker = tracker;
    let mut state = match checkpoint {
        Some(ckpt) => {
            tracker.restore_state(ckpt.tracker)?;
            let builder = DeltaBuilder::from_committed(&ckpt.adjacency, ckpt.ids.clone());
            let mut st = TenantState::new(
                tracker,
                builder,
                ckpt.adjacency.clone(),
                policy,
                store.clone(),
                metrics.clone(),
                budget,
            );
            st.restore_version(ckpt.version);
            // checkpoints are only taken after a successful flush, so
            // version >= 1 always holds here; the guard keeps a
            // hand-built version-0 checkpoint from tripping the
            // store's monotonicity assert
            if ckpt.version > 0 {
                store.publish(EmbeddingSnapshot {
                    version: ckpt.version,
                    n_nodes: ckpt.adjacency.n_rows,
                    pairs: ckpt.pairs,
                    ids: Arc::new(IdMap::from_externals(ckpt.ids)),
                    published_at: PublishStamp::restored(ckpt.wall_us),
                });
            }
            st
        }
        // no checkpoint yet: the WAL replays on top of the configured
        // initial graph (the caller must re-spawn with the same one)
        None => TenantState::new(
            tracker,
            DeltaBuilder::from_graph(initial),
            a0,
            policy,
            store.clone(),
            metrics.clone(),
            budget,
        ),
    };
    state.replay(&tail)?;
    if recovered_something {
        metrics.recoveries.incr();
    }
    state.attach_durability(TenantDurability::new(wal, ckpt_backend, dcfg.checkpoint_every));
    Ok(state)
}

/// Dedicated-thread driver: the same [`TenantState`] machine the pool
/// steps, fed from an mpsc channel, with `recv_timeout` standing in for
/// the pool's timer heap on `max_age` deadlines.
#[allow(clippy::too_many_arguments)]
fn pinned_loop(
    rx: Receiver<TenantCmd>,
    initial_graph: Graph,
    a0: Csr,
    init: EigenPairs,
    factory: TrackerFactory,
    policy: BatchPolicy,
    durability: Option<DurabilityConfig>,
    store: SnapshotStore,
    metrics: Arc<Metrics>,
    budget: TenantBudget,
    ready: Sender<Result<()>>,
) {
    let built = factory(&a0, &init).and_then(|tracker| {
        build_state(
            tracker,
            initial_graph,
            a0,
            policy,
            durability,
            budget,
            &store,
            &metrics,
        )
    });
    let mut state: TenantState<dyn EigTracker> = match built {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    loop {
        let cmd = match state.next_deadline() {
            None => match rx.recv() {
                Ok(cmd) => cmd,
                // every handle dropped without shutdown: retire
                Err(_) => return,
            },
            Some(at) => {
                let now = Instant::now();
                if at <= now {
                    state.poll_deadline(now);
                    continue;
                }
                match rx.recv_timeout(at - now) {
                    Ok(cmd) => cmd,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        state.poll_deadline(Instant::now());
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            }
        };
        if let Applied::Stopped(ack) = state.apply(cmd) {
            let _ = ack.send(());
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::tracking::{GRest, SubspaceMode};

    fn base_graph(n: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        crate::graph::generators::erdos_renyi(n, 0.08, &mut rng)
    }

    #[test]
    fn service_tracks_streamed_updates() {
        let g = base_graph(60, 1);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 4,
            policy: BatchPolicy::ByCount(8),
            seed: 2,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
            serve_precision: ServePrecision::F64,
            durability: None,
        })
        .unwrap();
        let h = &svc.handle;
        assert_eq!(h.snapshot().version, 0);
        // stream 40 events referencing new node ids 1000+
        let mut events = vec![];
        for i in 0..40u64 {
            events.push(GraphEvent::AddEdge(i % 60, 1000 + (i % 7)));
        }
        h.ingest(events).unwrap();
        let v = h.flush().unwrap();
        assert!(v >= 1, "at least one batch applied");
        let snap = h.snapshot();
        assert!(snap.n_nodes > 60, "new nodes tracked");
        assert_eq!(snap.pairs.k(), 4);
        let central = h.central_nodes(5);
        assert_eq!(central.len(), 5);
        // results are *external* ids: every id is one the stream ingested
        for &id in central.iter() {
            assert!(
                id < 60 || (1000..1007).contains(&id),
                "central node {id} is not an ingested external id"
            );
        }
        let m = h.metrics();
        assert!(m.batches_applied.get() >= 1);
        svc.join();
    }

    #[test]
    fn snapshot_ids_and_query_cache_serve_external_id_space() {
        let g = base_graph(40, 2);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 4,
            policy: BatchPolicy::ByCount(1_000_000),
            seed: 5,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
            serve_precision: ServePrecision::F64,
            durability: None,
        })
        .unwrap();
        let h = &svc.handle;
        h.ingest(vec![
            GraphEvent::AddEdge(0, 9000),
            GraphEvent::AddEdge(9000, 9001),
            GraphEvent::AddEdge(1, 9001),
        ])
        .unwrap();
        h.flush().unwrap();
        let snap = h.snapshot();
        assert_eq!(snap.n_nodes, 42);
        assert_eq!(snap.ids.internal(9000), Some(40));
        assert_eq!(snap.ids.internal(9001), Some(41));
        // embedding lookup by external id == the raw row at the
        // interned internal index
        let emb = h.embedding(9001).unwrap();
        assert_eq!(emb.len(), 4);
        for (j, &e) in emb.iter().enumerate() {
            assert_eq!(e, snap.pairs.vectors.get(41, j));
        }
        assert!(h.embedding(123_456).is_none());
        // similarity answers in external ids and excludes the query node
        let sim = h.similar_to(9000, 5).unwrap();
        assert_eq!(sim.len(), 5);
        assert!(sim.iter().all(|&(e, _)| e != 9000));
        assert!(sim.iter().all(|&(e, _)| e < 40 || e == 9001));
        // repeated queries at one version hit the memo cache
        let m = h.metrics();
        let a = h.central_nodes(6);
        let computed = m.queries_computed.get();
        let b = h.central_nodes(6);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(m.queries_computed.get(), computed);
        assert!(m.queries_cached.get() >= 1);
        svc.join();
    }

    #[test]
    fn cluster_seed_derives_from_service_config() {
        // regression: the old worker command hard-coded
        // spectral_cluster(..., 42); two services with different seeds
        // silently shared clustering randomness.  Each service must
        // cluster with ITS OWN seed.
        let run = |seed: u64| {
            let svc = TrackingService::spawn(ServiceConfig {
                initial: base_graph(50, 4),
                k: 4,
                policy: BatchPolicy::ByCount(1_000_000),
                seed,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
                serve_precision: ServePrecision::F64,
                durability: None,
            })
            .unwrap();
            let got = svc.handle.clusters(3);
            let snap = svc.handle.snapshot();
            let want = crate::tasks::clustering::spectral_cluster_with(
                &snap.pairs.vectors,
                3,
                seed,
                Threads::SINGLE,
            );
            svc.join();
            (got.labels.clone(), want)
        };
        let (got_a, want_a) = run(3);
        let (got_b, want_b) = run(1234);
        assert_eq!(got_a, want_a, "service must cluster with its own seed");
        assert_eq!(got_b, want_b, "service must cluster with its own seed");
    }

    #[test]
    fn failed_update_keeps_batch_pending_and_retries() {
        // regression: a failed tracker update must not drop the batch or
        // advance the committed adjacency — the next flush retries the
        // accumulated delta and the final state reflects every event.
        struct Flaky {
            inner: GRest,
            failures_left: usize,
        }
        impl crate::tracking::traits::EigTracker for Flaky {
            fn descriptor(&self) -> TrackerSpec {
                TrackerSpec::custom("flaky")
            }
            fn update(&mut self, delta: &crate::sparse::delta::Delta) -> anyhow::Result<()> {
                if self.failures_left > 0 {
                    self.failures_left -= 1;
                    anyhow::bail!("injected failure");
                }
                self.inner.update(delta)
            }
            fn current(&self) -> &crate::tracking::traits::EigenPairs {
                self.inner.current()
            }
        }

        let g = base_graph(30, 7);
        // closure escape hatch: an ad-hoc tracker the registry can't build
        let svc = TrackingService::spawn_with_factory(
            ServiceConfig {
                initial: g,
                k: 3,
                policy: BatchPolicy::ByCount(1000),
                seed: 8,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
                serve_precision: ServePrecision::F64,
                durability: None,
            },
            Box::new(|_a0, init| {
                Ok(Box::new(Flaky {
                    inner: GRest::new(init.clone(), SubspaceMode::Full),
                    failures_left: 1,
                }))
            }),
        )
        .unwrap();
        let h = &svc.handle;
        h.ingest(vec![GraphEvent::AddEdge(0, 700), GraphEvent::AddEdge(1, 701)]).unwrap();
        // first flush: tracker fails — no snapshot, batch stays pending
        let v = h.flush().unwrap();
        assert_eq!(v, 0, "failed update must not publish");
        assert_eq!(h.metrics().update_failures.get(), 1);
        assert_eq!(h.snapshot().n_nodes, 30);
        // second flush: retry succeeds with the SAME accumulated batch
        let v = h.flush().unwrap();
        assert_eq!(v, 1);
        let snap = h.snapshot();
        assert_eq!(snap.n_nodes, 32, "retried batch must include both new nodes");
        assert_eq!(h.metrics().batches_applied.get(), 1);
        svc.join();
    }

    #[test]
    fn soak_incremental_adjacency_matches_rebuild() {
        // long mixed add/remove/expansion stream: at every flush the
        // worker's incrementally maintained CSR (apply_delta chain) must
        // equal a from-scratch Graph::adjacency() rebuild, and snapshot
        // versions must stay monotone
        let g = base_graph(50, 21);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g.clone(),
            k: 4,
            policy: BatchPolicy::ByCount(1_000_000),
            seed: 3,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
            serve_precision: ServePrecision::F64,
            durability: None,
        })
        .unwrap();
        let h = &svc.handle;
        let mut mirror = DeltaBuilder::from_graph(g);
        let mut rng = Rng::new(77);
        let mut last_version = 0u64;
        for batch in 0..25 {
            let mut events = Vec::new();
            for _ in 0..(1 + rng.below(12)) {
                let a = rng.below(70) as u64; // ids 50.. arrive over time
                let b = rng.below(70) as u64;
                let ev = if rng.flip(0.7) {
                    GraphEvent::AddEdge(a, b)
                } else {
                    GraphEvent::RemoveEdge(a, b)
                };
                events.push(ev);
            }
            for &ev in &events {
                mirror.push(ev);
            }
            mirror.commit();
            h.ingest(events).unwrap();
            let v = h.flush().unwrap();
            assert!(v >= last_version, "versions must be monotone");
            last_version = v;
            let inc = h.adjacency().unwrap();
            let want = mirror.graph().adjacency(); // from-scratch rebuild
            assert_eq!(inc.n_rows, want.n_rows, "batch {batch}");
            assert_eq!(inc.indptr, want.indptr, "batch {batch}");
            assert_eq!(inc.indices, want.indices, "batch {batch}");
            assert_eq!(inc.data, want.data, "batch {batch}");
        }
        assert!(h.metrics().batches_applied.get() >= 1);
        svc.join();
    }

    #[test]
    fn snapshot_versions_monotone_under_stream() {
        let g = base_graph(40, 3);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 3,
            policy: BatchPolicy::ByCount(4),
            seed: 4,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
            serve_precision: ServePrecision::F64,
            durability: None,
        })
        .unwrap();
        let h = svc.handle.clone();
        let reader = {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut last = 0;
                for _ in 0..500 {
                    let v = h.snapshot().version;
                    assert!(v >= last);
                    last = v;
                }
            })
        };
        for b in 0..10u64 {
            let ev: Vec<GraphEvent> =
                (0..4).map(|i| GraphEvent::AddEdge(b * 4 + i, (b * 4 + i + 1) % 40)).collect();
            h.ingest(ev).unwrap();
        }
        h.flush().unwrap();
        reader.join().unwrap();
        svc.join();
    }

    #[test]
    fn queries_work_mid_stream() {
        let g = base_graph(50, 5);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 4,
            policy: BatchPolicy::ByNewNodes(3),
            seed: 6,
            tracker: TrackerSpec::parse("grest2").unwrap(),
            threads: Threads::SINGLE,
            serve_precision: ServePrecision::F64,
            durability: None,
        })
        .unwrap();
        let h = &svc.handle;
        h.ingest(vec![
            GraphEvent::AddEdge(0, 900),
            GraphEvent::AddEdge(1, 901),
            GraphEvent::AddEdge(2, 902),
        ])
        .unwrap();
        let clusters = h.clusters(2);
        assert!(!clusters.is_empty());
        assert_eq!(clusters.nodes.len(), clusters.labels.len());
        let snap = h.snapshot();
        assert!(snap.pairs.k() > 0);
        svc.join();
    }

    #[test]
    fn spawn_surfaces_factory_build_errors() {
        // a factory that fails at runtime (e.g. missing XLA artifacts)
        // must fail spawn itself, not leave a dead worker behind
        let g = base_graph(20, 11);
        let res = TrackingService::spawn_with_factory(
            ServiceConfig {
                initial: g,
                k: 3,
                policy: BatchPolicy::ByCount(4),
                seed: 1,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
                serve_precision: ServePrecision::F64,
                durability: None,
            },
            Box::new(|_a0, _init| anyhow::bail!("artifacts missing")),
        );
        match res {
            Ok(_) => panic!("spawn must propagate the factory error"),
            Err(e) => assert!(e.to_string().contains("artifacts missing"), "{e}"),
        }
        // same contract on the pooled path
        let g = base_graph(20, 11);
        let res = TrackingService::spawn_on_with_factory(
            WorkerPool::global(),
            ServiceConfig {
                initial: g,
                k: 3,
                policy: BatchPolicy::ByCount(4),
                seed: 1,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
                serve_precision: ServePrecision::F64,
                durability: None,
            },
            TenantBudget::default(),
            Box::new(|_a0, _init| anyhow::bail!("artifacts missing")),
        );
        match res {
            Ok(_) => panic!("spawn_on must propagate the factory error"),
            Err(e) => assert!(e.to_string().contains("artifacts missing"), "{e}"),
        }
    }

    #[test]
    fn spawn_rejects_unbuildable_spec() {
        let g = base_graph(20, 9);
        let res = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 3,
            policy: BatchPolicy::ByCount(4),
            seed: 1,
            tracker: TrackerSpec::parse("trip@xla").unwrap(),
            threads: Threads::SINGLE,
            serve_precision: ServePrecision::F64,
            durability: None,
        });
        match res {
            Ok(_) => panic!("trip@xla must be rejected before the worker spawns"),
            Err(e) => assert!(e.to_string().contains("G-REST"), "{e}"),
        }
    }

    #[test]
    fn ingest_counts_only_on_successful_enqueue() {
        // regression: ingest() bumped events_ingested *before* the send,
        // so ingesting into a joined service inflated the counter
        for pinned in [false, true] {
            let config = || ServiceConfig {
                initial: base_graph(25, 13),
                k: 3,
                policy: BatchPolicy::ByCount(1_000_000),
                seed: 13,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
                serve_precision: ServePrecision::F64,
                durability: None,
            };
            let svc = if pinned {
                TrackingService::spawn_pinned(config()).unwrap()
            } else {
                TrackingService::spawn(config()).unwrap()
            };
            let h = svc.handle.clone();
            h.ingest(vec![GraphEvent::AddEdge(0, 800), GraphEvent::AddEdge(1, 801)]).unwrap();
            assert_eq!(h.metrics().events_ingested.get(), 2);
            svc.join();
            let err = h.ingest(vec![GraphEvent::AddEdge(2, 802)]);
            assert!(err.is_err(), "ingest into a joined service must fail (pinned={pinned})");
            assert_eq!(
                h.metrics().events_ingested.get(),
                2,
                "failed enqueue must not count (pinned={pinned})"
            );
        }
    }

    #[test]
    fn pooled_service_flushes_on_max_age_without_manual_flush() {
        // deadline trigger end-to-end on the shared pool: ingest below
        // every count bound, then wait for the scheduler's timer wakeup
        for pinned in [false, true] {
            let config = ServiceConfig {
                initial: base_graph(25, 17),
                k: 3,
                policy: BatchPolicy::MaxAge(Duration::from_millis(40)),
                seed: 17,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
                serve_precision: ServePrecision::F64,
                durability: None,
            };
            let svc = if pinned {
                TrackingService::spawn_pinned(config).unwrap()
            } else {
                TrackingService::spawn(config).unwrap()
            };
            let h = &svc.handle;
            h.ingest(vec![GraphEvent::AddEdge(0, 850), GraphEvent::AddEdge(1, 851)]).unwrap();
            let deadline = Instant::now() + Duration::from_secs(10);
            while h.snapshot().version == 0 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(5));
            }
            assert_eq!(
                h.snapshot().version,
                1,
                "max_age must flush with no manual flush (pinned={pinned})"
            );
            assert!(h.snapshot().n_nodes > 25);
            svc.join();
        }
    }
}
