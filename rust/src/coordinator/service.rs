//! The tracking service: a worker thread that owns the graph state and
//! the tracker, fed by an mpsc command channel.
//!
//! Why a dedicated thread: the PJRT client and compiled executables are
//! thread-bound (`Rc` internals), so the XLA-backed tracker must be
//! constructed *and* driven on one thread.  The handle is `Clone + Send`.
//!
//! The worker's only job is ingest: apply batches, publish snapshots.
//! Every read — raw snapshots and all derived queries (central nodes,
//! clusters, embeddings, similarity) — is served off-worker from the
//! lock-cheap [`SnapshotStore`] through the [`QueryEngine`], so query
//! traffic never serializes behind pending batch updates.

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::query::{ClusterAssignment, QueryEngine};
use crate::coordinator::snapshot::{EmbeddingSnapshot, SnapshotStore};
use crate::graph::graph::Graph;
use crate::graph::stream::{DeltaBuilder, GraphEvent, IdMap};
use crate::linalg::threads::Threads;
use crate::sparse::csr::Csr;
use crate::tracking::spec::TrackerSpec;
use crate::tracking::traits::{EigTracker, EigenPairs};
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Builds the tracker inside the worker thread (lets callers choose the
/// native or XLA backend without `Send` bounds on the tracker itself).
/// A build error is reported back through [`TrackingService::spawn`] /
/// [`TrackingService::spawn_with_factory`], which then fail instead of
/// leaving a dead worker behind.  Derived from [`ServiceConfig::tracker`]
/// by [`TrackingService::spawn`]; hand-written closures remain available
/// through [`TrackingService::spawn_with_factory`].
pub type TrackerFactory =
    Box<dyn FnOnce(&Csr, &EigenPairs) -> Result<Box<dyn EigTracker>> + Send>;

/// Service configuration.
pub struct ServiceConfig {
    /// Initial graph (defines A⁽⁰⁾ and the id space 0..n).
    pub initial: Graph,
    /// Tracked eigenpairs.
    pub k: usize,
    /// Batch-closing policy.
    pub policy: BatchPolicy,
    /// Lanczos seed for initialization, the tracker fallback seed, and
    /// the reader-side clustering seed (two services with different
    /// seeds never share k-means randomness).
    pub seed: u64,
    /// Declarative tracker to serve (built on the worker thread).
    pub tracker: TrackerSpec,
    /// Worker budget for reader-side query kernels (k-means assignment);
    /// results are bitwise identical for every thread count.
    pub threads: Threads,
}

enum Command {
    Events(Vec<GraphEvent>),
    Flush(Sender<u64>),
    Adjacency(Sender<Csr>),
    Shutdown,
}

/// Cloneable, Send handle to the service.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Command>,
    snapshots: SnapshotStore,
    metrics: Arc<Metrics>,
    query: Arc<QueryEngine>,
}

impl ServiceHandle {
    /// Ingest a batch of events (non-blocking; worker applies policy).
    pub fn ingest(&self, events: Vec<GraphEvent>) -> Result<()> {
        self.metrics
            .events_ingested
            .fetch_add(events.len() as u64, Ordering::Relaxed);
        self.tx.send(Command::Events(events))?;
        Ok(())
    }

    /// Force a flush; returns the published snapshot version.
    pub fn flush(&self) -> Result<u64> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Command::Flush(rtx))?;
        Ok(rrx.recv()?)
    }

    /// Latest embedding snapshot (never blocks the worker).
    pub fn snapshot(&self) -> Arc<EmbeddingSnapshot> {
        self.snapshots.latest()
    }

    /// The committed adjacency (a clone of the worker's incrementally
    /// maintained CSR) — for debugging dumps and the soak tests that
    /// cross-check it against a from-scratch rebuild.
    pub fn adjacency(&self) -> Result<Csr> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Command::Adjacency(rtx))?;
        Ok(rrx.recv()?)
    }

    /// Top-J central nodes by subgraph centrality on the latest
    /// snapshot, as **external** node ids.  Never touches the worker;
    /// memoized per snapshot version.
    pub fn central_nodes(&self, j: usize) -> Arc<Vec<u64>> {
        self.query.central_nodes(&self.snapshot(), j)
    }

    /// Cluster assignment of the latest snapshot, keyed by **external**
    /// node ids and seeded from [`ServiceConfig::seed`].  Never touches
    /// the worker; memoized per snapshot version.
    pub fn clusters(&self, k: usize) -> Arc<ClusterAssignment> {
        self.query.clusters(&self.snapshot(), k)
    }

    /// Embedding row of one external node id in the latest snapshot.
    pub fn embedding(&self, external: u64) -> Option<Vec<f64>> {
        self.query.embedding(&self.snapshot(), external)
    }

    /// Top-`top` most embedding-cosine-similar nodes to `external` in
    /// the latest snapshot, `(external id, similarity)` descending.
    pub fn similar_to(&self, external: u64, top: usize) -> Option<Arc<Vec<(u64, f64)>>> {
        self.query.similar_to(&self.snapshot(), external, top)
    }

    /// Wall-clock age of the latest published snapshot — how stale the
    /// read path currently is.
    pub fn snapshot_age(&self) -> Duration {
        self.snapshot().age()
    }

    /// The snapshot-only query engine, for pinned-version queries
    /// (`h.query_engine().central_nodes(&snap, j)` answers at `snap`
    /// even after newer versions publish).
    pub fn query_engine(&self) -> &QueryEngine {
        &self.query
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Stop the worker (drains outstanding commands first).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Command::Shutdown);
    }
}

/// The running service (join handle + public handle).
pub struct TrackingService {
    pub handle: ServiceHandle,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl TrackingService {
    /// Spawn the worker serving the tracker described by
    /// `config.tracker` (the declarative path every production caller
    /// uses).  The tracker itself is built on the worker thread — the
    /// XLA backend's PJRT state is thread-bound.
    pub fn spawn(config: ServiceConfig) -> Result<TrackingService> {
        config.tracker.validate_buildable()?;
        let spec = config.tracker.clone();
        let seed = config.seed;
        Self::spawn_with_factory(
            config,
            Box::new(move |a0, init| spec.build_seeded(a0, init, seed)),
        )
    }

    /// Escape hatch: spawn with a hand-written factory (ad-hoc or
    /// experimental trackers the registry doesn't know).
    /// `config.tracker` is ignored; the factory runs on the worker
    /// thread with the initial adjacency and the Lanczos-computed
    /// initial pairs.
    pub fn spawn_with_factory(
        config: ServiceConfig,
        factory: TrackerFactory,
    ) -> Result<TrackingService> {
        let a0 = config.initial.adjacency();
        let init = crate::tracking::traits::init_eigenpairs(&a0, config.k, config.seed);
        let store = SnapshotStore::new(EmbeddingSnapshot {
            version: 0,
            n_nodes: a0.n_rows,
            pairs: init.clone(),
            // the seed graph's external ids are 0..n by the
            // DeltaBuilder::from_graph contract
            ids: Arc::new(IdMap::identity(a0.n_rows)),
            published_at: Instant::now(),
        });
        let metrics = Metrics::new();
        let query = Arc::new(QueryEngine::new(config.seed, config.threads, metrics.clone()));
        let (tx, rx) = mpsc::channel();
        let handle =
            ServiceHandle { tx, snapshots: store.clone(), metrics: metrics.clone(), query };
        let cfg_policy = config.policy;
        let initial_graph = config.initial;
        // the worker reports whether the factory succeeded, so a broken
        // tracker spec (e.g. missing XLA artifacts) surfaces here as an
        // error instead of a dead worker behind a healthy-looking handle
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("grest-tracker".into())
            .spawn(move || {
                worker_loop(
                    rx,
                    initial_graph,
                    a0,
                    init,
                    factory,
                    cfg_policy,
                    store,
                    metrics,
                    ready_tx,
                )
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(TrackingService { handle: handle.clone(), worker: Some(worker) }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                let _ = worker.join();
                Err(anyhow::anyhow!("tracker worker died during startup"))
            }
        }
    }

    /// Shut down and join.
    pub fn join(mut self) {
        self.handle.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for TrackingService {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rx: Receiver<Command>,
    initial_graph: Graph,
    a0: Csr,
    init: EigenPairs,
    factory: TrackerFactory,
    policy: BatchPolicy,
    store: SnapshotStore,
    metrics: Arc<Metrics>,
    ready: Sender<Result<()>>,
) {
    let mut tracker = match factory(&a0, &init) {
        Ok(t) => {
            let _ = ready.send(Ok(()));
            t
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let mut builder = DeltaBuilder::from_graph(initial_graph);
    let mut adjacency = a0;
    let mut version = 0u64;

    let flush =
        |builder: &mut DeltaBuilder, adjacency: &mut Csr, tracker: &mut Box<dyn EigTracker>, version: &mut u64| {
            match builder.prepare() {
                // batch netted out to no change: drop the pending events,
                // committed state is already consistent
                None => builder.commit(),
                Some(delta) => {
                    let t0 = Instant::now();
                    match tracker.update(&delta) {
                        Ok(()) => {
                            // commit builder + adjacency only after the
                            // tracker accepted the batch, so a failure
                            // never leaves them diverged from the tracker
                            builder.commit();
                            metrics.nodes_added.fetch_add(delta.s_new as u64, Ordering::Relaxed);
                            metrics.update_latency.observe(t0.elapsed());
                            metrics.batches_applied.fetch_add(1, Ordering::Relaxed);
                            // incremental row-merge: only rows touched by
                            // Δ are rewritten, never a full rebuild
                            *adjacency = adjacency.apply_delta(&delta);
                            *version += 1;
                            store.publish(EmbeddingSnapshot {
                                version: *version,
                                n_nodes: adjacency.n_rows,
                                pairs: tracker.current().clone(),
                                // O(1): Arc clone, copy-on-write at commit
                                ids: builder.committed_ids(),
                                published_at: Instant::now(),
                            });
                        }
                        Err(_) => {
                            // batch stays pending; the next flush retries
                            // the accumulated delta against the same
                            // committed state
                            metrics.update_failures.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        };

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Events(events) => {
                for ev in events {
                    builder.push(ev);
                }
                if policy.should_flush(builder.pending_events(), builder.pending_new_nodes()) {
                    flush(&mut builder, &mut adjacency, &mut tracker, &mut version);
                }
            }
            Command::Flush(reply) => {
                flush(&mut builder, &mut adjacency, &mut tracker, &mut version);
                let _ = reply.send(version);
            }
            Command::Adjacency(reply) => {
                let _ = reply.send(adjacency.clone());
            }
            Command::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::tracking::{GRest, SubspaceMode};

    fn base_graph(n: usize, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        crate::graph::generators::erdos_renyi(n, 0.08, &mut rng)
    }

    #[test]
    fn service_tracks_streamed_updates() {
        let g = base_graph(60, 1);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 4,
            policy: BatchPolicy::ByCount(8),
            seed: 2,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
        })
        .unwrap();
        let h = &svc.handle;
        assert_eq!(h.snapshot().version, 0);
        // stream 40 events referencing new node ids 1000+
        let mut events = vec![];
        for i in 0..40u64 {
            events.push(GraphEvent::AddEdge(i % 60, 1000 + (i % 7)));
        }
        h.ingest(events).unwrap();
        let v = h.flush().unwrap();
        assert!(v >= 1, "at least one batch applied");
        let snap = h.snapshot();
        assert!(snap.n_nodes > 60, "new nodes tracked");
        assert_eq!(snap.pairs.k(), 4);
        let central = h.central_nodes(5);
        assert_eq!(central.len(), 5);
        // results are *external* ids: every id is one the stream ingested
        for &id in central.iter() {
            assert!(
                id < 60 || (1000..1007).contains(&id),
                "central node {id} is not an ingested external id"
            );
        }
        let m = h.metrics();
        assert!(m.batches_applied.load(Ordering::Relaxed) >= 1);
        svc.join();
    }

    #[test]
    fn snapshot_ids_and_query_cache_serve_external_id_space() {
        let g = base_graph(40, 2);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 4,
            policy: BatchPolicy::ByCount(1_000_000),
            seed: 5,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
        })
        .unwrap();
        let h = &svc.handle;
        h.ingest(vec![
            GraphEvent::AddEdge(0, 9000),
            GraphEvent::AddEdge(9000, 9001),
            GraphEvent::AddEdge(1, 9001),
        ])
        .unwrap();
        h.flush().unwrap();
        let snap = h.snapshot();
        assert_eq!(snap.n_nodes, 42);
        assert_eq!(snap.ids.internal(9000), Some(40));
        assert_eq!(snap.ids.internal(9001), Some(41));
        // embedding lookup by external id == the raw row at the
        // interned internal index
        let emb = h.embedding(9001).unwrap();
        assert_eq!(emb.len(), 4);
        for (j, &e) in emb.iter().enumerate() {
            assert_eq!(e, snap.pairs.vectors.get(41, j));
        }
        assert!(h.embedding(123_456).is_none());
        // similarity answers in external ids and excludes the query node
        let sim = h.similar_to(9000, 5).unwrap();
        assert_eq!(sim.len(), 5);
        assert!(sim.iter().all(|&(e, _)| e != 9000));
        assert!(sim.iter().all(|&(e, _)| e < 40 || e == 9001));
        // repeated queries at one version hit the memo cache
        let m = h.metrics();
        let a = h.central_nodes(6);
        let computed = m.queries_computed.load(Ordering::Relaxed);
        let b = h.central_nodes(6);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(m.queries_computed.load(Ordering::Relaxed), computed);
        assert!(m.queries_cached.load(Ordering::Relaxed) >= 1);
        svc.join();
    }

    #[test]
    fn cluster_seed_derives_from_service_config() {
        // regression: the old worker command hard-coded
        // spectral_cluster(..., 42); two services with different seeds
        // silently shared clustering randomness.  Each service must
        // cluster with ITS OWN seed.
        let run = |seed: u64| {
            let svc = TrackingService::spawn(ServiceConfig {
                initial: base_graph(50, 4),
                k: 4,
                policy: BatchPolicy::ByCount(1_000_000),
                seed,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
            })
            .unwrap();
            let got = svc.handle.clusters(3);
            let snap = svc.handle.snapshot();
            let want = crate::tasks::clustering::spectral_cluster_with(
                &snap.pairs.vectors,
                3,
                seed,
                Threads::SINGLE,
            );
            svc.join();
            (got.labels.clone(), want)
        };
        let (got_a, want_a) = run(3);
        let (got_b, want_b) = run(1234);
        assert_eq!(got_a, want_a, "service must cluster with its own seed");
        assert_eq!(got_b, want_b, "service must cluster with its own seed");
    }

    #[test]
    fn failed_update_keeps_batch_pending_and_retries() {
        // regression: a failed tracker update must not drop the batch or
        // advance the committed adjacency — the next flush retries the
        // accumulated delta and the final state reflects every event.
        struct Flaky {
            inner: GRest,
            failures_left: usize,
        }
        impl crate::tracking::traits::EigTracker for Flaky {
            fn descriptor(&self) -> TrackerSpec {
                TrackerSpec::custom("flaky")
            }
            fn update(&mut self, delta: &crate::sparse::delta::Delta) -> anyhow::Result<()> {
                if self.failures_left > 0 {
                    self.failures_left -= 1;
                    anyhow::bail!("injected failure");
                }
                self.inner.update(delta)
            }
            fn current(&self) -> &crate::tracking::traits::EigenPairs {
                self.inner.current()
            }
        }

        let g = base_graph(30, 7);
        // closure escape hatch: an ad-hoc tracker the registry can't build
        let svc = TrackingService::spawn_with_factory(
            ServiceConfig {
                initial: g,
                k: 3,
                policy: BatchPolicy::ByCount(1000),
                seed: 8,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
            },
            Box::new(|_a0, init| {
                Ok(Box::new(Flaky {
                    inner: GRest::new(init.clone(), SubspaceMode::Full),
                    failures_left: 1,
                }))
            }),
        )
        .unwrap();
        let h = &svc.handle;
        h.ingest(vec![GraphEvent::AddEdge(0, 700), GraphEvent::AddEdge(1, 701)]).unwrap();
        // first flush: tracker fails — no snapshot, batch stays pending
        let v = h.flush().unwrap();
        assert_eq!(v, 0, "failed update must not publish");
        assert_eq!(h.metrics().update_failures.load(Ordering::Relaxed), 1);
        assert_eq!(h.snapshot().n_nodes, 30);
        // second flush: retry succeeds with the SAME accumulated batch
        let v = h.flush().unwrap();
        assert_eq!(v, 1);
        let snap = h.snapshot();
        assert_eq!(snap.n_nodes, 32, "retried batch must include both new nodes");
        assert_eq!(h.metrics().batches_applied.load(Ordering::Relaxed), 1);
        svc.join();
    }

    #[test]
    fn soak_incremental_adjacency_matches_rebuild() {
        // long mixed add/remove/expansion stream: at every flush the
        // worker's incrementally maintained CSR (apply_delta chain) must
        // equal a from-scratch Graph::adjacency() rebuild, and snapshot
        // versions must stay monotone
        let g = base_graph(50, 21);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g.clone(),
            k: 4,
            policy: BatchPolicy::ByCount(1_000_000),
            seed: 3,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
        })
        .unwrap();
        let h = &svc.handle;
        let mut mirror = DeltaBuilder::from_graph(g);
        let mut rng = Rng::new(77);
        let mut last_version = 0u64;
        for batch in 0..25 {
            let mut events = Vec::new();
            for _ in 0..(1 + rng.below(12)) {
                let a = rng.below(70) as u64; // ids 50.. arrive over time
                let b = rng.below(70) as u64;
                let ev = if rng.flip(0.7) {
                    GraphEvent::AddEdge(a, b)
                } else {
                    GraphEvent::RemoveEdge(a, b)
                };
                events.push(ev);
            }
            for &ev in &events {
                mirror.push(ev);
            }
            mirror.commit();
            h.ingest(events).unwrap();
            let v = h.flush().unwrap();
            assert!(v >= last_version, "versions must be monotone");
            last_version = v;
            let inc = h.adjacency().unwrap();
            let want = mirror.graph().adjacency(); // from-scratch rebuild
            assert_eq!(inc.n_rows, want.n_rows, "batch {batch}");
            assert_eq!(inc.indptr, want.indptr, "batch {batch}");
            assert_eq!(inc.indices, want.indices, "batch {batch}");
            assert_eq!(inc.data, want.data, "batch {batch}");
        }
        assert!(h.metrics().batches_applied.load(Ordering::Relaxed) >= 1);
        svc.join();
    }

    #[test]
    fn snapshot_versions_monotone_under_stream() {
        let g = base_graph(40, 3);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 3,
            policy: BatchPolicy::ByCount(4),
            seed: 4,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
        })
        .unwrap();
        let h = svc.handle.clone();
        let reader = {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut last = 0;
                for _ in 0..500 {
                    let v = h.snapshot().version;
                    assert!(v >= last);
                    last = v;
                }
            })
        };
        for b in 0..10u64 {
            let ev: Vec<GraphEvent> =
                (0..4).map(|i| GraphEvent::AddEdge(b * 4 + i, (b * 4 + i + 1) % 40)).collect();
            h.ingest(ev).unwrap();
        }
        h.flush().unwrap();
        reader.join().unwrap();
        svc.join();
    }

    #[test]
    fn queries_work_mid_stream() {
        let g = base_graph(50, 5);
        let svc = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 4,
            policy: BatchPolicy::ByNewNodes(3),
            seed: 6,
            tracker: TrackerSpec::parse("grest2").unwrap(),
            threads: Threads::SINGLE,
        })
        .unwrap();
        let h = &svc.handle;
        h.ingest(vec![
            GraphEvent::AddEdge(0, 900),
            GraphEvent::AddEdge(1, 901),
            GraphEvent::AddEdge(2, 902),
        ])
        .unwrap();
        let clusters = h.clusters(2);
        assert!(!clusters.is_empty());
        assert_eq!(clusters.nodes.len(), clusters.labels.len());
        let snap = h.snapshot();
        assert!(snap.pairs.k() > 0);
        svc.join();
    }

    #[test]
    fn spawn_surfaces_factory_build_errors() {
        // a factory that fails at runtime (e.g. missing XLA artifacts)
        // must fail spawn itself, not leave a dead worker behind
        let g = base_graph(20, 11);
        let res = TrackingService::spawn_with_factory(
            ServiceConfig {
                initial: g,
                k: 3,
                policy: BatchPolicy::ByCount(4),
                seed: 1,
                tracker: TrackerSpec::default(),
                threads: Threads::SINGLE,
            },
            Box::new(|_a0, _init| anyhow::bail!("artifacts missing")),
        );
        match res {
            Ok(_) => panic!("spawn must propagate the factory error"),
            Err(e) => assert!(e.to_string().contains("artifacts missing"), "{e}"),
        }
    }

    #[test]
    fn spawn_rejects_unbuildable_spec() {
        let g = base_graph(20, 9);
        let res = TrackingService::spawn(ServiceConfig {
            initial: g,
            k: 3,
            policy: BatchPolicy::ByCount(4),
            seed: 1,
            tracker: TrackerSpec::parse("trip@xla").unwrap(),
            threads: Threads::SINGLE,
        });
        match res {
            Ok(_) => panic!("trip@xla must be rejected before the worker spawns"),
            Err(e) => assert!(e.to_string().contains("G-REST"), "{e}"),
        }
    }
}
