//! Durability tier: WAL + checkpoint/restore + crash recovery.
//!
//! Three layers, bottom-up:
//!
//! - [`backend`]: the byte-log [`StorageBackend`] abstraction (memory /
//!   file / fault-injected), the only code in the crate that touches
//!   `std::fs` (enforced by detlint's `raw-fs` rule).
//! - [`wal`]: CRC32-framed append-only event log with torn-tail
//!   detection; [`checkpoint`]: atomic full-state images with bitwise
//!   f64 round-tripping.
//! - [`recover`]: open both, validate their seq relationship, and hand
//!   the coordinator what it needs to resume exactly where the durable
//!   state left off.
//!
//! The ordering invariant the whole tier rests on (*log before flush*):
//! a tenant fsyncs the events frames of a batch **before** the tracker
//! consumes the batch, and publishes a snapshot only for state that is
//! re-derivable from the durable log.  See docs/CONCURRENCY.md.

pub mod backend;
pub mod checkpoint;
pub mod recover;
pub mod wal;

use crate::graph::stream::GraphEvent;
use backend::{StorageBackend, StorageError};
use checkpoint::Checkpoint;
use std::path::PathBuf;
use wal::Wal;

/// Durability knobs on [`crate::coordinator::ServiceConfig`].
#[derive(Clone, Debug)]
pub struct DurabilityConfig {
    /// Directory holding this tenant's `wal.log` + `checkpoint.bin`
    /// (fleets append a per-tenant subdirectory keyed by `TenantId`).
    pub dir: PathBuf,
    /// Take a checkpoint every this many flushes (must be non-zero;
    /// enforced by `ServiceConfig::validate`).
    pub checkpoint_every: usize,
}

impl DurabilityConfig {
    pub const DEFAULT_CHECKPOINT_EVERY: usize = 16;

    pub fn new(dir: impl Into<PathBuf>) -> DurabilityConfig {
        DurabilityConfig { dir: dir.into(), checkpoint_every: Self::DEFAULT_CHECKPOINT_EVERY }
    }

    pub fn wal_path(&self) -> PathBuf {
        self.dir.join("wal.log")
    }

    pub fn checkpoint_path(&self) -> PathBuf {
        self.dir.join("checkpoint.bin")
    }
}

/// Everything that can go wrong in the durability tier.  `Corrupt` and
/// `ReplayMismatch` are the loud-failure half of the contract: recovery
/// either resumes bitwise-exact or reports one of these — it never
/// silently diverges.
#[derive(Debug)]
pub enum DurabilityError {
    /// The storage layer failed (I/O error or injected fault).
    Storage(StorageError),
    /// Durable bytes fail validation (CRC, framing, seq continuity).
    Corrupt { context: &'static str, offset: u64, detail: String },
    /// Replay reached a commit frame whose version disagrees with the
    /// recomputed state — the recovered run diverged from the original.
    ReplayMismatch { seq: u64, expected: u64, got: u64 },
    /// The configured tracker cannot save/restore its state.
    Unsupported(String),
}

impl From<StorageError> for DurabilityError {
    fn from(e: StorageError) -> Self {
        DurabilityError::Storage(e)
    }
}

impl std::fmt::Display for DurabilityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DurabilityError::Storage(e) => write!(f, "{e}"),
            DurabilityError::Corrupt { context, offset, detail } => {
                write!(f, "corrupt {context} at byte {offset}: {detail}")
            }
            DurabilityError::ReplayMismatch { seq, expected, got } => write!(
                f,
                "replay diverged at wal seq {seq}: commit frame says version {expected}, \
                 recovered state is at {got}"
            ),
            DurabilityError::Unsupported(what) => write!(f, "durability unsupported: {what}"),
        }
    }
}

impl std::error::Error for DurabilityError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DurabilityError::Storage(e) => Some(e),
            _ => None,
        }
    }
}

/// Per-tenant durability state owned by the `TenantState` machine: the
/// live WAL plus the checkpoint backend and cadence counter.  All
/// writes happen on the worker thread inside `apply`/`flush`; `Drop`
/// performs no I/O (a dropped tenant looks exactly like a crash, which
/// is what the recovery path is tested against).
pub struct TenantDurability {
    wal: Wal,
    ckpt_backend: Box<dyn StorageBackend>,
    checkpoint_every: usize,
    flushes_since_ckpt: usize,
}

impl TenantDurability {
    pub fn new(
        wal: Wal,
        ckpt_backend: Box<dyn StorageBackend>,
        checkpoint_every: usize,
    ) -> TenantDurability {
        TenantDurability { wal, ckpt_backend, checkpoint_every, flushes_since_ckpt: 0 }
    }

    /// Buffer an events frame (durable at the next flush's group
    /// fsync).  Returns the framed byte count, for metrics.
    pub fn log_events(&mut self, events: &[GraphEvent]) -> u64 {
        let before = self.wal.buffered_len();
        self.wal.append_events(events);
        (self.wal.buffered_len() - before) as u64
    }

    /// Whether any frames are buffered awaiting a group fsync.
    pub fn has_buffered(&self) -> bool {
        self.wal.has_buffered()
    }

    /// Group-fsync everything buffered so far.  Called at the *start*
    /// of a flush: the batch's events must be durable before the
    /// tracker consumes them (log-before-flush).
    pub fn sync_events(&mut self) -> Result<(), DurabilityError> {
        self.wal.sync()
    }

    /// Log + fsync the flush boundary.  On failure the commit frame
    /// stays buffered for the next sync; the caller publishes anyway
    /// (the published state is re-derivable from the already-durable
    /// events frames) and counts the failure.  Returns the framed byte
    /// count, for metrics.
    pub fn log_commit(&mut self, version: u64) -> Result<u64, DurabilityError> {
        let before = self.wal.buffered_len();
        self.wal.append_commit(version);
        let bytes = (self.wal.buffered_len() - before) as u64;
        self.wal.sync()?;
        Ok(bytes)
    }

    /// Cadence: returns true when this flush should checkpoint.  Never
    /// true while the WAL has unsynced frames — truncation would race
    /// the buffered retry.
    pub fn due_for_checkpoint(&mut self) -> bool {
        self.flushes_since_ckpt += 1;
        self.flushes_since_ckpt >= self.checkpoint_every && !self.wal.has_buffered()
    }

    /// First WAL seq not yet assigned (what a checkpoint records as
    /// [`Checkpoint::next_seq`]).
    pub fn wal_next_seq(&self) -> u64 {
        self.wal.next_seq()
    }

    /// Atomically store a checkpoint, then truncate the WAL prefix it
    /// covers.  Resets the cadence counter even on failure (retrying
    /// every flush would turn one bad disk into a checkpoint storm).
    pub fn record_checkpoint(&mut self, ckpt: &Checkpoint) -> Result<(), DurabilityError> {
        self.flushes_since_ckpt = 0;
        ckpt.store(self.ckpt_backend.as_mut())?;
        if ckpt.next_seq > 0 {
            self.wal.truncate_through(ckpt.next_seq - 1)?;
        }
        Ok(())
    }
}
