//! Append-only write-ahead log of edge events.
//!
//! Frame format (all integers little-endian):
//!
//! ```text
//! +----------+----------+----------+--------+------------------+
//! | len: u32 | crc: u32 | seq: u64 | kind:u8|  payload bytes   |
//! +----------+----------+----------+--------+------------------+
//!             <-------- crc covers seq|kind|payload ---------->
//! ```
//!
//! `len` counts everything after the crc field (9 + payload bytes);
//! `seq` is a monotone +1 sequence number.  Two frame kinds exist:
//!
//! - **Events** (`kind=1`): a batch of [`GraphEvent`]s as ingested,
//!   payload `count:u32` then `(tag:u8, u:u64, v:u64)` per event.  The
//!   tag space is reserved for future event kinds (weighted edges).
//! - **Commit** (`kind=2`): a flush boundary, payload the snapshot
//!   `version:u64` after the flush.  Every flush logs one — including
//!   no-op flushes — so replay reproduces the exact batch boundaries.
//!
//! Appends are buffered in memory and hit the backend on [`Wal::sync`]
//! (group commit: one write + one fsync per flush boundary, not per
//! event).  [`Wal::open`] parses the whole log, verifies CRCs and seq
//! continuity, and distinguishes a *torn tail* (invalid bytes at the
//! very end with no valid frame after them — the normal result of a
//! crash mid-append, silently truncated and reported) from *corruption*
//! (an invalid frame followed by a valid one, or a CRC/seq violation in
//! the interior — always a loud [`DurabilityError::Corrupt`]).

use super::backend::StorageBackend;
use super::DurabilityError;
use crate::graph::stream::GraphEvent;

/// CRC32 (IEEE reflected, poly 0xEDB88320) — dependency-free, table
/// built at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

const FRAME_EVENTS: u8 = 1;
const FRAME_COMMIT: u8 = 2;

const EVENT_ADD: u8 = 1;
const EVENT_REMOVE: u8 = 2;

/// Fixed bytes before the payload: len(4) + crc(4) + seq(8) + kind(1).
const HEADER: usize = 17;

/// Decoded content of one WAL frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FramePayload {
    /// A batch of ingested events.
    Events(Vec<GraphEvent>),
    /// A flush boundary; `version` is the snapshot version after it.
    Commit { version: u64 },
}

/// One parsed WAL frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub seq: u64,
    pub payload: FramePayload,
}

/// Encode a batch of events as a frame payload (public so the
/// round-trip property test can drive it directly).
pub fn encode_events(events: &[GraphEvent]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + events.len() * 17);
    out.extend_from_slice(&(events.len() as u32).to_le_bytes());
    for ev in events {
        let (tag, u, v) = match *ev {
            GraphEvent::AddEdge(u, v) => (EVENT_ADD, u, v),
            GraphEvent::RemoveEdge(u, v) => (EVENT_REMOVE, u, v),
        };
        out.push(tag);
        out.extend_from_slice(&u.to_le_bytes());
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn read_u32(bytes: &[u8], at: usize) -> Option<u32> {
    let b: [u8; 4] = bytes.get(at..at + 4)?.try_into().ok()?;
    Some(u32::from_le_bytes(b))
}

fn read_u64(bytes: &[u8], at: usize) -> Option<u64> {
    let b: [u8; 8] = bytes.get(at..at + 8)?.try_into().ok()?;
    Some(u64::from_le_bytes(b))
}

/// Decode an events payload.  Errors on truncation, trailing garbage,
/// or an unknown tag (reserved tag space: readers must reject, not
/// skip, what they don't understand).
pub fn decode_events(payload: &[u8]) -> Result<Vec<GraphEvent>, DurabilityError> {
    let corrupt = |detail: &str| DurabilityError::Corrupt {
        context: "events payload",
        offset: 0,
        detail: detail.to_string(),
    };
    let count = read_u32(payload, 0).ok_or_else(|| corrupt("missing count"))? as usize;
    let mut at = 4;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let tag = *payload.get(at).ok_or_else(|| corrupt("truncated event"))?;
        let u = read_u64(payload, at + 1).ok_or_else(|| corrupt("truncated event"))?;
        let v = read_u64(payload, at + 9).ok_or_else(|| corrupt("truncated event"))?;
        at += 17;
        out.push(match tag {
            EVENT_ADD => GraphEvent::AddEdge(u, v),
            EVENT_REMOVE => GraphEvent::RemoveEdge(u, v),
            other => return Err(corrupt(&format!("unknown event tag {other}"))),
        });
    }
    if at != payload.len() {
        return Err(corrupt("trailing bytes after events"));
    }
    Ok(out)
}

/// Try to parse one frame at `at`.  `Ok(None)` means the bytes at `at`
/// do not form a valid frame (short, bad CRC, bad kind, undecodable
/// payload) — the caller decides whether that is a torn tail or
/// corruption.  `Ok(Some((frame, next_offset)))` on success.
fn parse_frame(data: &[u8], at: usize) -> Option<(Frame, usize)> {
    let len = read_u32(data, at)? as usize;
    if len < 9 || at + 8 + len > data.len() {
        return None;
    }
    let crc = read_u32(data, at + 4)?;
    let body = &data[at + 8..at + 8 + len];
    if crc32(body) != crc {
        return None;
    }
    let seq = read_u64(body, 0)?;
    let kind = body[8];
    let payload = &body[9..];
    let payload = match kind {
        FRAME_EVENTS => FramePayload::Events(decode_events(payload).ok()?),
        FRAME_COMMIT => FramePayload::Commit { version: read_u64(payload, 0)? },
        _ => return None,
    };
    Some((Frame { seq, payload }, at + 8 + len))
}

/// Result of scanning a log at open: the valid frames, plus how many
/// trailing bytes were discarded as a torn tail (0 on a clean log).
pub struct WalScan {
    pub frames: Vec<Frame>,
    pub truncated_bytes: u64,
}

/// The write-ahead log: buffered frame appends over a
/// [`StorageBackend`], group-fsynced at flush boundaries.
pub struct Wal {
    backend: Box<dyn StorageBackend>,
    buf: Vec<u8>,
    next_seq: u64,
}

impl Wal {
    /// Open (and validate) a log.  Torn tails are truncated in storage
    /// and reported via [`WalScan::truncated_bytes`]; interior
    /// corruption is a loud error.  `fallback_next_seq` seeds the
    /// sequence counter when the log is empty (it continues from the
    /// checkpointed seq, so a checkpoint + empty log stays monotone).
    pub fn open(
        mut backend: Box<dyn StorageBackend>,
        fallback_next_seq: u64,
    ) -> Result<(Wal, WalScan), DurabilityError> {
        let data = backend.read_all()?;
        let mut frames = Vec::new();
        let mut at = 0usize;
        let mut truncated_bytes = 0u64;
        while at < data.len() {
            match parse_frame(&data, at) {
                Some((frame, next)) => {
                    if let Some(last) = frames.last() {
                        let last: &Frame = last;
                        if frame.seq != last.seq + 1 {
                            return Err(DurabilityError::Corrupt {
                                context: "wal",
                                offset: at as u64,
                                detail: format!(
                                    "sequence gap: frame {} follows {}",
                                    frame.seq, last.seq
                                ),
                            });
                        }
                    }
                    frames.push(frame);
                    at = next;
                }
                None => {
                    // Invalid bytes at `at`.  A torn tail is expected
                    // after a crash mid-append; a valid frame anywhere
                    // AFTER this point means interior damage instead.
                    for probe in at + 1..data.len() {
                        if parse_frame(&data, probe).is_some() {
                            return Err(DurabilityError::Corrupt {
                                context: "wal",
                                offset: at as u64,
                                detail: format!(
                                    "invalid frame at byte {at} followed by a valid frame at \
                                     byte {probe}: interior corruption, refusing to replay"
                                ),
                            });
                        }
                    }
                    truncated_bytes = (data.len() - at) as u64;
                    backend.replace(&data[..at])?;
                    break;
                }
            }
        }
        let next_seq = match frames.last() {
            Some(f) => f.seq + 1,
            None => fallback_next_seq,
        };
        Ok((Wal { backend, buf: Vec::new(), next_seq }, WalScan { frames, truncated_bytes }))
    }

    fn push_frame(&mut self, kind: u8, payload: &[u8]) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let len = (9 + payload.len()) as u32;
        let mut body = Vec::with_capacity(9 + payload.len());
        body.extend_from_slice(&seq.to_le_bytes());
        body.push(kind);
        body.extend_from_slice(payload);
        self.buf.extend_from_slice(&len.to_le_bytes());
        self.buf.extend_from_slice(&crc32(&body).to_le_bytes());
        self.buf.extend_from_slice(&body);
        seq
    }

    /// Buffer an events frame; durable only after [`Wal::sync`].
    pub fn append_events(&mut self, events: &[GraphEvent]) -> u64 {
        let payload = encode_events(events);
        self.push_frame(FRAME_EVENTS, &payload)
    }

    /// Buffer a commit (flush-boundary) frame.
    pub fn append_commit(&mut self, version: u64) -> u64 {
        self.push_frame(FRAME_COMMIT, &version.to_le_bytes())
    }

    /// Write buffered frames and fsync (group commit).  On failure the
    /// buffer is retained, so a later sync retries the same bytes.
    pub fn sync(&mut self) -> Result<(), DurabilityError> {
        if !self.buf.is_empty() {
            self.backend.append(&self.buf)?;
            self.buf.clear();
        }
        self.backend.sync()?;
        Ok(())
    }

    /// Are there appended-but-unsynced frames?  Checkpoints must not
    /// run while true: a truncation would race the buffered retry.
    pub fn has_buffered(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Bytes buffered but not yet handed to the backend (metrics).
    pub fn buffered_len(&self) -> usize {
        self.buf.len()
    }

    /// Next sequence number to be assigned.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Drop every durable frame with seq <= `through` (checkpoint
    /// advanced past them).  Caller must ensure no buffered frames
    /// ([`Wal::has_buffered`] is false).
    pub fn truncate_through(&mut self, through: u64) -> Result<(), DurabilityError> {
        debug_assert!(self.buf.is_empty(), "truncate with buffered frames");
        let data = self.backend.read_all()?;
        let mut at = 0usize;
        while at < data.len() {
            match parse_frame(&data, at) {
                Some((frame, next)) => {
                    if frame.seq > through {
                        break;
                    }
                    at = next;
                }
                None => break, // torn tail past the cut point: keep it for open() to judge
            }
        }
        if at > 0 {
            self.backend.replace(&data[at..])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::Memory;
    use super::*;

    fn events(n: u64) -> Vec<GraphEvent> {
        (0..n).map(|i| GraphEvent::AddEdge(i, i + 1)).collect()
    }

    #[test]
    fn append_sync_reopen_roundtrip() {
        let mem = Memory::new();
        let (mut wal, scan) = Wal::open(Box::new(mem.clone()), 0).unwrap();
        assert!(scan.frames.is_empty());
        let s0 = wal.append_events(&events(3));
        let s1 = wal.append_commit(1);
        assert_eq!((s0, s1), (0, 1));
        wal.sync().unwrap();
        let (wal2, scan) = Wal::open(Box::new(mem), 0).unwrap();
        assert_eq!(scan.truncated_bytes, 0);
        assert_eq!(scan.frames.len(), 2);
        assert_eq!(scan.frames[0].payload, FramePayload::Events(events(3)));
        assert_eq!(scan.frames[1].payload, FramePayload::Commit { version: 1 });
        assert_eq!(wal2.next_seq(), 2);
    }

    #[test]
    fn unsynced_frames_die_with_the_process() {
        let mem = Memory::new();
        let (mut wal, _) = Wal::open(Box::new(mem.clone()), 0).unwrap();
        wal.append_events(&events(2));
        wal.append_commit(1);
        wal.sync().unwrap();
        wal.append_events(&events(5)); // never synced
        mem.crash();
        let (_, scan) = Wal::open(Box::new(mem), 0).unwrap();
        assert_eq!(scan.frames.len(), 2, "only synced frames survive");
    }

    #[test]
    fn torn_tail_is_truncated_and_reported() {
        let mem = Memory::new();
        let (mut wal, _) = Wal::open(Box::new(mem.clone()), 0).unwrap();
        wal.append_events(&events(2));
        wal.sync().unwrap();
        // simulate a torn append: half a frame of garbage at the end
        {
            use super::super::backend::StorageBackend;
            let mut m = mem.clone();
            m.append(&[0x55; 11]).unwrap();
            m.sync().unwrap();
        }
        let (wal2, scan) = Wal::open(Box::new(mem.clone()), 0).unwrap();
        assert_eq!(scan.frames.len(), 1);
        assert_eq!(scan.truncated_bytes, 11);
        assert_eq!(wal2.next_seq(), 1);
        // the truncation is durable: a re-open sees a clean log
        drop(wal2);
        let (_, scan) = Wal::open(Box::new(mem), 0).unwrap();
        assert_eq!(scan.truncated_bytes, 0);
    }

    #[test]
    fn interior_bit_flip_is_loud_corruption() {
        let mem = Memory::new();
        let (mut wal, _) = Wal::open(Box::new(mem.clone()), 0).unwrap();
        wal.append_events(&events(2));
        wal.append_commit(1);
        wal.append_events(&events(2));
        wal.append_commit(2);
        wal.sync().unwrap();
        mem.flip_bit(20, 3); // inside the first frame, later frames valid
        match Wal::open(Box::new(mem), 0) {
            Err(DurabilityError::Corrupt { .. }) => {}
            other => panic!("interior corruption must be loud, got {other:?}"),
        }
    }

    #[test]
    fn final_frame_bit_flip_truncates_and_reports() {
        let mem = Memory::new();
        let (mut wal, _) = Wal::open(Box::new(mem.clone()), 0).unwrap();
        wal.append_events(&events(2));
        wal.sync().unwrap();
        let tail = mem.len();
        wal.append_commit(1);
        wal.sync().unwrap();
        mem.flip_bit(tail + 10, 2); // inside the final frame
        let (_, scan) = Wal::open(Box::new(mem), 0).unwrap();
        assert_eq!(scan.frames.len(), 1, "damaged final frame dropped");
        assert!(scan.truncated_bytes > 0, "but the drop is REPORTED, never silent");
    }

    #[test]
    fn sequence_gap_is_corruption() {
        // splice two logs with non-contiguous seqs together
        let mem_a = Memory::new();
        let (mut wal, _) = Wal::open(Box::new(mem_a.clone()), 0).unwrap();
        wal.append_commit(1); // seq 0
        wal.sync().unwrap();
        let mem_b = Memory::new();
        let (mut wal_b, _) = Wal::open(Box::new(mem_b.clone()), 5).unwrap();
        wal_b.append_commit(2); // seq 5
        wal_b.sync().unwrap();
        {
            use super::super::backend::StorageBackend;
            let spliced = [
                mem_a.clone().read_all().unwrap(),
                mem_b.clone().read_all().unwrap(),
            ]
            .concat();
            let mut m = mem_a.clone();
            m.replace(&spliced).unwrap();
        }
        match Wal::open(Box::new(mem_a), 0) {
            Err(DurabilityError::Corrupt { detail, .. }) => {
                assert!(detail.contains("sequence gap"), "{detail}");
            }
            other => panic!("seq gap must be loud, got {other:?}"),
        }
    }

    #[test]
    fn truncate_through_drops_prefix_only() {
        let mem = Memory::new();
        let (mut wal, _) = Wal::open(Box::new(mem.clone()), 0).unwrap();
        wal.append_events(&events(1)); // seq 0
        wal.append_commit(1); // seq 1
        wal.append_events(&events(1)); // seq 2
        wal.append_commit(2); // seq 3
        wal.sync().unwrap();
        wal.truncate_through(1).unwrap();
        let (wal2, scan) = Wal::open(Box::new(mem), 10).unwrap();
        assert_eq!(scan.frames.len(), 2);
        assert_eq!(scan.frames[0].seq, 2);
        assert_eq!(wal2.next_seq(), 4, "seq continues after truncation");
    }

    #[test]
    fn empty_log_uses_fallback_seq() {
        let (wal, _) = Wal::open(Box::new(Memory::new()), 42).unwrap();
        assert_eq!(wal.next_seq(), 42);
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC32 of "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
