//! Checkpoints: a full serialized image of one tenant's tracking state
//! — committed adjacency, id map, published eigenpairs, tracker
//! internals ([`TrackerState`]) — written atomically through
//! [`StorageBackend::replace`] so a crash mid-checkpoint leaves the
//! previous checkpoint intact.
//!
//! Every f64 is serialized as its IEEE bit pattern (`to_bits`
//! little-endian), so a state that round-trips through a checkpoint is
//! *bitwise* identical — the property the crash tests assert.
//!
//! Format: magic `"GRCKPT01"`, then `crc32(payload): u32`, then the
//! payload.  `replace` is atomic, so a torn checkpoint cannot exist on
//! a well-behaved filesystem; any magic/CRC mismatch is therefore loud
//! corruption, never silently skipped.

use super::backend::StorageBackend;
use super::wal::crc32;
use super::DurabilityError;
use crate::linalg::mat::Mat;
use crate::sparse::csr::Csr;
use crate::tracking::traits::{EigenPairs, TrackerState};

const MAGIC: &[u8; 8] = b"GRCKPT01";

/// A tenant's full durable state at one flush boundary.
pub struct Checkpoint {
    /// First WAL sequence number NOT covered by this checkpoint —
    /// recovery replays frames with `seq >= next_seq`.
    pub next_seq: u64,
    /// Snapshot version at the checkpoint.
    pub version: u64,
    /// Wall-clock micros since the Unix epoch when the checkpointed
    /// snapshot was published (re-anchors `snapshot_age` after restore).
    pub wall_us: u64,
    /// Published eigenpairs.
    pub pairs: EigenPairs,
    /// External ids in internal-index order (rebuilds the `IdMap`).
    pub ids: Vec<u64>,
    /// Committed adjacency CSR.
    pub adjacency: Csr,
    /// Tracker internals from [`EigTracker::save_state`]
    /// (crate::tracking::traits::EigTracker::save_state).
    pub tracker: TrackerState,
}

struct Writer {
    out: Vec<u8>,
}

impl Writer {
    fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn u64s(&mut self, vs: &[u64]) {
        self.u64(vs.len() as u64);
        for &v in vs {
            self.u64(v);
        }
    }

    fn usizes(&mut self, vs: &[usize]) {
        self.u64(vs.len() as u64);
        for &v in vs {
            self.u64(v as u64);
        }
    }

    fn f64s(&mut self, vs: &[f64]) {
        self.u64(vs.len() as u64);
        for &v in vs {
            self.f64(v);
        }
    }

    fn mat(&mut self, m: &Mat) {
        self.u64(m.rows() as u64);
        self.u64(m.cols() as u64);
        self.f64s(m.as_slice());
    }

    fn pairs(&mut self, p: &EigenPairs) {
        self.f64s(&p.values);
        self.mat(&p.vectors);
    }

    fn csr(&mut self, c: &Csr) {
        self.u64(c.n_rows as u64);
        self.u64(c.n_cols as u64);
        self.usizes(&c.indptr);
        self.usizes(&c.indices);
        self.f64s(&c.data);
    }
}

struct Reader<'a> {
    data: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn fail(&self, detail: &str) -> DurabilityError {
        DurabilityError::Corrupt {
            context: "checkpoint",
            offset: self.at as u64,
            detail: detail.to_string(),
        }
    }

    fn u64(&mut self) -> Result<u64, DurabilityError> {
        let b: [u8; 8] = self
            .data
            .get(self.at..self.at + 8)
            .and_then(|s| s.try_into().ok())
            .ok_or_else(|| self.fail("truncated u64"))?;
        self.at += 8;
        Ok(u64::from_le_bytes(b))
    }

    fn f64(&mut self) -> Result<f64, DurabilityError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn len(&mut self) -> Result<usize, DurabilityError> {
        let n = self.u64()? as usize;
        // cheap sanity bound: a length field can never exceed the
        // remaining bytes / 8, so corrupted lengths fail fast instead
        // of attempting a huge allocation
        if n > (self.data.len() - self.at) / 8 {
            return Err(self.fail("implausible length"));
        }
        Ok(n)
    }

    fn u64s(&mut self) -> Result<Vec<u64>, DurabilityError> {
        let n = self.len()?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn usizes(&mut self) -> Result<Vec<usize>, DurabilityError> {
        let n = self.len()?;
        (0..n).map(|_| self.u64().map(|v| v as usize)).collect()
    }

    fn f64s(&mut self) -> Result<Vec<f64>, DurabilityError> {
        let n = self.len()?;
        (0..n).map(|_| self.f64()).collect()
    }

    fn mat(&mut self) -> Result<Mat, DurabilityError> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let data = self.f64s()?;
        if data.len() != rows * cols {
            return Err(self.fail("matrix shape/data mismatch"));
        }
        Ok(Mat::from_vec(rows, cols, data))
    }

    fn pairs(&mut self) -> Result<EigenPairs, DurabilityError> {
        let values = self.f64s()?;
        let vectors = self.mat()?;
        if vectors.cols() != values.len() {
            return Err(self.fail("eigenpair k mismatch"));
        }
        Ok(EigenPairs { values, vectors })
    }

    fn csr(&mut self) -> Result<Csr, DurabilityError> {
        let n_rows = self.u64()? as usize;
        let n_cols = self.u64()? as usize;
        let indptr = self.usizes()?;
        let indices = self.usizes()?;
        let data = self.f64s()?;
        let csr = Csr { n_rows, n_cols, indptr, indices, data };
        csr.check_invariants().map_err(|e| self.fail(&format!("invalid CSR: {e}")))?;
        Ok(csr)
    }
}

impl Checkpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer { out: Vec::new() };
        w.u64(self.next_seq);
        w.u64(self.version);
        w.u64(self.wall_us);
        w.pairs(&self.pairs);
        w.u64s(&self.ids);
        w.csr(&self.adjacency);
        w.pairs(&self.tracker.pairs);
        w.u64s(&self.tracker.aux_u);
        w.f64s(&self.tracker.aux_f);
        match &self.tracker.adjacency {
            None => w.u64(0),
            Some(c) => {
                w.u64(1);
                w.csr(c);
            }
        }
        let mut out = Vec::with_capacity(12 + w.out.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&crc32(&w.out).to_le_bytes());
        out.extend_from_slice(&w.out);
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<Checkpoint, DurabilityError> {
        let corrupt = |offset: usize, detail: &str| DurabilityError::Corrupt {
            context: "checkpoint",
            offset: offset as u64,
            detail: detail.to_string(),
        };
        if bytes.len() < 12 || &bytes[..8] != MAGIC {
            return Err(corrupt(0, "bad magic"));
        }
        let crc_bytes: [u8; 4] =
            bytes[8..12].try_into().map_err(|_| corrupt(8, "short crc"))?;
        let payload = &bytes[12..];
        if crc32(payload) != u32::from_le_bytes(crc_bytes) {
            return Err(corrupt(8, "checkpoint CRC mismatch"));
        }
        let mut r = Reader { data: payload, at: 0 };
        let next_seq = r.u64()?;
        let version = r.u64()?;
        let wall_us = r.u64()?;
        let pairs = r.pairs()?;
        let ids = r.u64s()?;
        let adjacency = r.csr()?;
        let t_pairs = r.pairs()?;
        let aux_u = r.u64s()?;
        let aux_f = r.f64s()?;
        let t_adj = match r.u64()? {
            0 => None,
            1 => Some(r.csr()?),
            _ => return Err(r.fail("bad option tag")),
        };
        if r.at != payload.len() {
            return Err(r.fail("trailing bytes"));
        }
        Ok(Checkpoint {
            next_seq,
            version,
            wall_us,
            pairs,
            ids,
            adjacency,
            tracker: TrackerState { pairs: t_pairs, aux_u, aux_f, adjacency: t_adj },
        })
    }

    /// Atomically persist through `replace`.
    pub fn store(&self, backend: &mut dyn StorageBackend) -> Result<(), DurabilityError> {
        backend.replace(&self.encode())?;
        Ok(())
    }

    /// Load the checkpoint, `None` if none was ever written.  Damage is
    /// loud: `replace` is atomic, so a bad image is corruption, not a
    /// torn write.
    pub fn load(backend: &mut dyn StorageBackend) -> Result<Option<Checkpoint>, DurabilityError> {
        let bytes = backend.read_all()?;
        if bytes.is_empty() {
            return Ok(None);
        }
        Checkpoint::decode(&bytes).map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::Memory;
    use super::*;

    fn sample() -> Checkpoint {
        let mut coo = crate::sparse::coo::Coo::new(3, 3);
        coo.push_sym(0, 1, 1.0);
        coo.push_sym(1, 2, 0.5);
        let adjacency = coo.to_csr();
        let pairs = EigenPairs {
            values: vec![1.25, -0.5],
            vectors: Mat::from_vec(3, 2, vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]),
        };
        Checkpoint {
            next_seq: 7,
            version: 3,
            wall_us: 1_700_000_000_000_000,
            pairs: pairs.clone(),
            ids: vec![0, 1, 900],
            adjacency: adjacency.clone(),
            tracker: TrackerState {
                pairs,
                aux_u: vec![1, 2, 3],
                aux_f: vec![0.25],
                adjacency: Some(adjacency),
            },
        }
    }

    #[test]
    fn encode_decode_bitwise_roundtrip() {
        let c = sample();
        let d = Checkpoint::decode(&c.encode()).unwrap();
        assert_eq!(d.next_seq, c.next_seq);
        assert_eq!(d.version, c.version);
        assert_eq!(d.wall_us, c.wall_us);
        assert_eq!(d.pairs.values, c.pairs.values);
        assert_eq!(d.pairs.vectors.as_slice(), c.pairs.vectors.as_slice());
        assert_eq!(d.ids, c.ids);
        assert_eq!(d.adjacency.indptr, c.adjacency.indptr);
        assert_eq!(d.adjacency.indices, c.adjacency.indices);
        assert_eq!(d.adjacency.data, c.adjacency.data);
        assert_eq!(d.tracker.aux_u, c.tracker.aux_u);
        assert_eq!(d.tracker.aux_f, c.tracker.aux_f);
        assert!(d.tracker.adjacency.is_some());
    }

    #[test]
    fn store_load_roundtrip_and_missing_is_none() {
        let mem = Memory::new();
        assert!(Checkpoint::load(&mut mem.clone()).unwrap().is_none());
        sample().store(&mut mem.clone()).unwrap();
        let loaded = Checkpoint::load(&mut mem.clone()).unwrap().unwrap();
        assert_eq!(loaded.version, 3);
    }

    #[test]
    fn corrupted_checkpoint_is_loud() {
        let mem = Memory::new();
        sample().store(&mut mem.clone()).unwrap();
        mem.flip_bit(40, 1);
        match Checkpoint::load(&mut mem.clone()) {
            Err(DurabilityError::Corrupt { context, .. }) => assert_eq!(context, "checkpoint"),
            other => panic!("corrupt checkpoint must be loud, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn nan_values_roundtrip_bitwise() {
        let mut c = sample();
        c.pairs.values[0] = f64::NAN;
        c.tracker.aux_f[0] = -0.0;
        let d = Checkpoint::decode(&c.encode()).unwrap();
        assert_eq!(d.pairs.values[0].to_bits(), f64::NAN.to_bits());
        assert_eq!(d.tracker.aux_f[0].to_bits(), (-0.0f64).to_bits());
    }
}
