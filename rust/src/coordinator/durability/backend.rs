//! Storage backends for the durability tier: one byte-log abstraction
//! ([`StorageBackend`]) with an in-memory implementation (tests, the
//! crash harness) and a file implementation (production), mirroring the
//! memory/file storage split of CRDT sync engines.
//!
//! The contract is deliberately tiny — an append-only byte log with an
//! explicit durability point (`sync`) and an atomic whole-log `replace`
//! — so the WAL and checkpoint layers above can be property-tested
//! against [`Memory`] (where "crash" = discard everything after the
//! last sync) and fault-injected through [`FaultyBackend`] without any
//! real I/O.

use crate::sync::{Arc, Mutex};
use std::path::{Path, PathBuf};

/// A storage-layer failure.  `Injected` marks faults planted by the
/// test harness ([`FaultyBackend`]) so assertions can tell a planned
/// crash from an unexpected I/O error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StorageError {
    /// A real I/O error from the OS.
    Io { op: &'static str, detail: String },
    /// A fault planted by a [`FaultPlan`] at syscall index `syscall`.
    Injected { op: &'static str, syscall: usize },
}

impl std::fmt::Display for StorageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageError::Io { op, detail } => write!(f, "storage {op} failed: {detail}"),
            StorageError::Injected { op, syscall } => {
                write!(f, "injected fault during {op} (syscall #{syscall})")
            }
        }
    }
}

impl std::error::Error for StorageError {}

/// An append-only byte log with an explicit durability point.
///
/// Semantics the layers above rely on:
/// - `append` buffers or writes bytes at the end of the log; bytes are
///   NOT durable until a subsequent `sync` returns `Ok`.
/// - `sync` makes every previously appended byte durable (group
///   commit: one fsync covers any number of appends).
/// - `replace` atomically swaps the entire log content (checkpoint
///   files, WAL truncation); on return the new content is durable and
///   a crash at any point yields either the old or the new content,
///   never a mix.
/// - `read_all` returns the current log content (durable prefix plus
///   any successfully appended-but-unsynced tail that survived — after
///   a real crash only the durable prefix remains).
pub trait StorageBackend: Send {
    fn read_all(&mut self) -> Result<Vec<u8>, StorageError>;
    fn append(&mut self, bytes: &[u8]) -> Result<(), StorageError>;
    fn sync(&mut self) -> Result<(), StorageError>;
    fn replace(&mut self, bytes: &[u8]) -> Result<(), StorageError>;
}

/// Shared state of a [`Memory`] backend: the full byte log plus the
/// durable high-water mark (`synced_len`).  `crash` rewinds to the
/// durable prefix, modeling a power cut after unsynced appends.
struct MemState {
    data: Vec<u8>,
    synced_len: usize,
}

/// In-memory backend.  Clones share the same underlying log, so a test
/// can keep one handle, hand another to a tenant, drop the tenant, call
/// [`Memory::crash`], and recover from exactly what a real file would
/// have held.
#[derive(Clone)]
pub struct Memory {
    inner: Arc<Mutex<MemState>>,
}

impl Default for Memory {
    fn default() -> Self {
        Self::new()
    }
}

impl Memory {
    pub fn new() -> Memory {
        Memory { inner: Arc::new(Mutex::new(MemState { data: Vec::new(), synced_len: 0 })) }
    }

    /// Simulate a crash: every byte appended after the last `sync` is
    /// lost (as it would be from the page cache).
    pub fn crash(&self) {
        let mut st = self.inner.lock();
        let keep = st.synced_len;
        st.data.truncate(keep);
    }

    /// Bytes currently held (durable or not) — for test assertions.
    pub fn len(&self) -> usize {
        self.inner.lock().data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Corrupt the log for tests: flip one bit at `byte` (no-op past
    /// the end).  Counts as durable damage, like media corruption.
    pub fn flip_bit(&self, byte: usize, bit: u8) {
        let mut st = self.inner.lock();
        if let Some(b) = st.data.get_mut(byte) {
            *b ^= 1 << (bit & 7);
        }
    }
}

impl StorageBackend for Memory {
    fn read_all(&mut self) -> Result<Vec<u8>, StorageError> {
        Ok(self.inner.lock().data.clone())
    }

    fn append(&mut self, bytes: &[u8]) -> Result<(), StorageError> {
        self.inner.lock().data.extend_from_slice(bytes);
        Ok(())
    }

    fn sync(&mut self) -> Result<(), StorageError> {
        let mut st = self.inner.lock();
        st.synced_len = st.data.len();
        Ok(())
    }

    fn replace(&mut self, bytes: &[u8]) -> Result<(), StorageError> {
        let mut st = self.inner.lock();
        st.data.clear();
        st.data.extend_from_slice(bytes);
        st.synced_len = st.data.len();
        Ok(())
    }
}

fn io_err(op: &'static str, e: std::io::Error) -> StorageError {
    StorageError::Io { op, detail: e.to_string() }
}

/// Probe that `dir` exists (creating it if needed) and is writable —
/// the spawn-time check behind `ConfigError::DirUnwritable`.  Lives
/// here rather than in the coordinator because this module is the
/// crate's only sanctioned `std::fs` user (detlint rule `raw-fs`).
pub fn probe_dir(dir: &Path) -> Result<(), String> {
    std::fs::create_dir_all(dir).map_err(|e| format!("create dir: {e}"))?;
    let probe = dir.join(".write-probe");
    std::fs::write(&probe, b"ok").map_err(|e| format!("write probe: {e}"))?;
    let _ = std::fs::remove_file(&probe);
    Ok(())
}

/// File-backed log.  `append` writes through an `O_APPEND` handle,
/// `sync` is `fdatasync`, and `replace` is the classic
/// write-temp + fsync + rename + fsync-parent-dir sequence, so a crash
/// mid-replace leaves the old content intact.
pub struct FileBackend {
    path: PathBuf,
    file: Option<std::fs::File>,
}

impl FileBackend {
    pub fn new(path: impl Into<PathBuf>) -> FileBackend {
        FileBackend { path: path.into(), file: None }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn handle(&mut self) -> Result<&mut std::fs::File, StorageError> {
        if self.file.is_none() {
            let f = std::fs::OpenOptions::new()
                .append(true)
                .create(true)
                .open(&self.path)
                .map_err(|e| io_err("open", e))?;
            self.file = Some(f);
        }
        match self.file.as_mut() {
            Some(f) => Ok(f),
            None => Err(StorageError::Io { op: "open", detail: "handle lost".into() }),
        }
    }
}

impl StorageBackend for FileBackend {
    fn read_all(&mut self) -> Result<Vec<u8>, StorageError> {
        match std::fs::read(&self.path) {
            Ok(bytes) => Ok(bytes),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Vec::new()),
            Err(e) => Err(io_err("read", e)),
        }
    }

    fn append(&mut self, bytes: &[u8]) -> Result<(), StorageError> {
        use std::io::Write;
        self.handle()?.write_all(bytes).map_err(|e| io_err("append", e))
    }

    fn sync(&mut self) -> Result<(), StorageError> {
        // nothing ever appended -> nothing to make durable
        if let Some(f) = self.file.as_mut() {
            f.sync_data().map_err(|e| io_err("fsync", e))?;
        }
        Ok(())
    }

    fn replace(&mut self, bytes: &[u8]) -> Result<(), StorageError> {
        use std::io::Write;
        let tmp = self.path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp).map_err(|e| io_err("create-tmp", e))?;
            f.write_all(bytes).map_err(|e| io_err("write-tmp", e))?;
            f.sync_all().map_err(|e| io_err("fsync-tmp", e))?;
        }
        std::fs::rename(&tmp, &self.path).map_err(|e| io_err("rename", e))?;
        // make the rename itself durable: fsync the containing directory
        if let Some(parent) = self.path.parent() {
            if !parent.as_os_str().is_empty() {
                let dir = std::fs::File::open(parent).map_err(|e| io_err("open-dir", e))?;
                dir.sync_all().map_err(|e| io_err("fsync-dir", e))?;
            }
        }
        // the old append handle now points at the unlinked inode
        self.file = None;
        Ok(())
    }
}

/// How an injected fault manifests at the chosen syscall.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// The process dies at this syscall: the op fails and every later
    /// op fails too (nothing after this point reaches storage).
    Kill,
    /// A torn write: only a prefix of the bytes lands, then the
    /// process dies.  On `replace` the rename never happens (the
    /// atomicity contract), so the old content survives unchanged.
    TornWrite,
    /// Silent media corruption: the write "succeeds" but one bit is
    /// flipped.  The process keeps running — recovery must *detect*
    /// this via CRC, never replay it.
    BitFlip,
}

/// One planned fault: fail the `fail_at`-th storage op (0-based, over
/// the backend's lifetime) in the given mode.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    pub fail_at: usize,
    pub mode: FaultMode,
}

struct FaultState {
    ops: usize,
    plan: Option<FaultPlan>,
    dead: bool,
}

/// Shared handle to a [`FaultyBackend`]'s fault state: the harness
/// keeps one clone to count ops on a clean reference run, then arms a
/// plan and asserts the "process" died where intended.
#[derive(Clone)]
pub struct FaultHandle {
    inner: Arc<Mutex<FaultState>>,
}

impl Default for FaultHandle {
    fn default() -> Self {
        Self::new()
    }
}

impl FaultHandle {
    pub fn new() -> FaultHandle {
        FaultHandle { inner: Arc::new(Mutex::new(FaultState { ops: 0, plan: None, dead: false })) }
    }

    /// Total storage ops issued so far (the fault-point space).
    pub fn ops(&self) -> usize {
        self.inner.lock().ops
    }

    /// Arm a fault at op index `fail_at`.
    pub fn arm(&self, fail_at: usize, mode: FaultMode) {
        self.inner.lock().plan = Some(FaultPlan { fail_at, mode });
    }

    /// Did an armed Kill/TornWrite fault fire (the "process" is dead)?
    pub fn is_dead(&self) -> bool {
        self.inner.lock().dead
    }

    /// Decide the fate of the op that was just issued.
    fn admit(&self, op: &'static str) -> Result<Option<FaultPlan>, StorageError> {
        let mut st = self.inner.lock();
        let idx = st.ops;
        st.ops += 1;
        if st.dead {
            return Err(StorageError::Injected { op, syscall: idx });
        }
        match st.plan {
            Some(plan) if plan.fail_at == idx => {
                if plan.mode != FaultMode::BitFlip {
                    st.dead = true;
                }
                Ok(Some(plan))
            }
            _ => Ok(None),
        }
    }
}

/// Wraps any backend and fails ops according to a [`FaultPlan`] — the
/// crash harness ISSUE 10 asks for: kill at every syscall boundary,
/// torn writes, silent bit flips.
pub struct FaultyBackend<B: StorageBackend> {
    inner: B,
    state: FaultHandle,
}

impl<B: StorageBackend> FaultyBackend<B> {
    pub fn new(inner: B, state: FaultHandle) -> FaultyBackend<B> {
        FaultyBackend { inner, state }
    }

    pub fn handle(&self) -> FaultHandle {
        self.state.clone()
    }
}

impl<B: StorageBackend> StorageBackend for FaultyBackend<B> {
    fn read_all(&mut self) -> Result<Vec<u8>, StorageError> {
        match self.state.admit("read")? {
            // a read can't tear or flip meaningfully mid-plan: treat
            // any fault at a read boundary as the process dying there
            Some(_) => {
                self.state.inner.lock().dead = true;
                Err(StorageError::Injected { op: "read", syscall: self.state.ops() - 1 })
            }
            None => self.inner.read_all(),
        }
    }

    fn append(&mut self, bytes: &[u8]) -> Result<(), StorageError> {
        match self.state.admit("append")? {
            Some(FaultPlan { mode: FaultMode::Kill, fail_at }) => {
                Err(StorageError::Injected { op: "append", syscall: fail_at })
            }
            Some(FaultPlan { mode: FaultMode::TornWrite, fail_at }) => {
                let half = &bytes[..bytes.len() / 2];
                let _ = self.inner.append(half);
                Err(StorageError::Injected { op: "append", syscall: fail_at })
            }
            Some(FaultPlan { mode: FaultMode::BitFlip, .. }) => {
                let mut flipped = bytes.to_vec();
                if let Some(b) = flipped.get_mut(bytes.len() / 2) {
                    *b ^= 0x10;
                }
                self.inner.append(&flipped)
            }
            None => self.inner.append(bytes),
        }
    }

    fn sync(&mut self) -> Result<(), StorageError> {
        match self.state.admit("sync")? {
            Some(plan) => {
                // a fault at the fsync boundary: the sync never
                // happened; Kill/Torn both mean the process is gone
                if plan.mode == FaultMode::BitFlip {
                    self.state.inner.lock().dead = true;
                }
                Err(StorageError::Injected { op: "sync", syscall: plan.fail_at })
            }
            None => self.inner.sync(),
        }
    }

    fn replace(&mut self, bytes: &[u8]) -> Result<(), StorageError> {
        match self.state.admit("replace")? {
            Some(FaultPlan { mode: FaultMode::Kill, fail_at })
            | Some(FaultPlan { mode: FaultMode::TornWrite, fail_at }) => {
                // atomic replace: a crash anywhere before the rename
                // leaves the old content; the rename simply never lands
                Err(StorageError::Injected { op: "replace", syscall: fail_at })
            }
            Some(FaultPlan { mode: FaultMode::BitFlip, .. }) => {
                let mut flipped = bytes.to_vec();
                if let Some(b) = flipped.get_mut(bytes.len() / 2) {
                    *b ^= 0x10;
                }
                self.inner.replace(&flipped)
            }
            None => self.inner.replace(bytes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_crash_discards_unsynced_tail() {
        let mem = Memory::new();
        let mut b = mem.clone();
        b.append(b"abc").unwrap();
        b.sync().unwrap();
        b.append(b"def").unwrap();
        mem.crash();
        assert_eq!(b.read_all().unwrap(), b"abc");
    }

    #[test]
    fn memory_replace_is_durable() {
        let mem = Memory::new();
        let mut b = mem.clone();
        b.append(b"old").unwrap();
        b.sync().unwrap();
        b.replace(b"new-content").unwrap();
        mem.crash();
        assert_eq!(b.read_all().unwrap(), b"new-content");
    }

    #[test]
    fn faulty_kill_fails_op_and_everything_after() {
        let h = FaultHandle::new();
        let mut b = FaultyBackend::new(Memory::new(), h.clone());
        b.append(b"one").unwrap(); // op 0
        h.arm(1, FaultMode::Kill);
        assert!(b.append(b"two").is_err()); // op 1: dies
        assert!(h.is_dead());
        assert!(b.sync().is_err()); // later ops all fail
        assert_eq!(h.ops(), 3);
    }

    #[test]
    fn faulty_torn_write_lands_half() {
        let h = FaultHandle::new();
        let mem = Memory::new();
        let mut b = FaultyBackend::new(mem.clone(), h.clone());
        h.arm(0, FaultMode::TornWrite);
        assert!(b.append(b"abcdef").is_err());
        assert_eq!(mem.len(), 3, "half the bytes landed");
    }

    #[test]
    fn faulty_bit_flip_succeeds_silently() {
        let h = FaultHandle::new();
        let mem = Memory::new();
        let mut b = FaultyBackend::new(mem.clone(), h.clone());
        h.arm(0, FaultMode::BitFlip);
        b.append(b"abcd").unwrap();
        assert!(!h.is_dead(), "bit flip is silent");
        assert_ne!(b.read_all().unwrap(), b"abcd");
    }

    #[test]
    fn faulty_replace_crash_preserves_old_content() {
        let h = FaultHandle::new();
        let mem = Memory::new();
        let mut b = FaultyBackend::new(mem.clone(), h.clone());
        b.replace(b"v1").unwrap();
        h.arm(1, FaultMode::TornWrite);
        assert!(b.replace(b"v2-much-longer").is_err());
        assert_eq!(mem.clone().read_all().unwrap(), b"v1", "old content intact");
    }

    #[test]
    fn file_backend_append_sync_replace_roundtrip() {
        let dir = std::env::temp_dir().join(format!("grest-backend-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.bin");
        let _ = std::fs::remove_file(&path);
        let mut b = FileBackend::new(&path);
        assert_eq!(b.read_all().unwrap(), b"", "missing file reads empty");
        b.append(b"hello ").unwrap();
        b.append(b"world").unwrap();
        b.sync().unwrap();
        assert_eq!(b.read_all().unwrap(), b"hello world");
        b.replace(b"fresh").unwrap();
        assert_eq!(b.read_all().unwrap(), b"fresh");
        // append after replace goes to the new inode
        b.append(b"+tail").unwrap();
        b.sync().unwrap();
        assert_eq!(b.read_all().unwrap(), b"fresh+tail");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
