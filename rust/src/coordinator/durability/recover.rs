//! Recovery: open a tenant's durable state (checkpoint + WAL), validate
//! that the two agree on sequence numbers, and package what the spawn
//! path needs to resume tracking.
//!
//! The replay itself runs through the normal `TenantState` ingest/flush
//! machinery (see `TenantState::replay`), so a recovered tenant
//! executes the *same* code path — and therefore the same floating-
//! point reduction orders — as the uninterrupted run.  This file only
//! loads and validates.

use super::backend::{FileBackend, StorageBackend};
use super::checkpoint::Checkpoint;
use super::wal::{Frame, Wal};
use super::{DurabilityConfig, DurabilityError};

/// The durable state of one tenant, loaded and cross-validated.
pub struct Recovered {
    /// Latest checkpoint, if one was ever written.
    pub checkpoint: Option<Checkpoint>,
    /// WAL frames to replay (already filtered to seqs the checkpoint
    /// does not cover, in order).
    pub tail: Vec<Frame>,
    /// Bytes dropped as a torn WAL tail (0 on a clean shutdown).
    pub truncated_bytes: u64,
    /// The opened WAL, positioned to continue appending.
    pub wal: Wal,
    /// The checkpoint backend, for the next checkpoint.
    pub ckpt_backend: Box<dyn StorageBackend>,
}

/// Open a tenant's durability directory (creating it on first run).
pub fn load_dir(config: &DurabilityConfig) -> Result<Recovered, DurabilityError> {
    std::fs::create_dir_all(&config.dir).map_err(|e| {
        DurabilityError::Storage(super::backend::StorageError::Io {
            op: "create-dir",
            detail: format!("{}: {e}", config.dir.display()),
        })
    })?;
    load(
        Box::new(FileBackend::new(config.wal_path())),
        Box::new(FileBackend::new(config.checkpoint_path())),
    )
}

/// Backend-agnostic load (the crash harness drives this with [`Memory`]
/// (super::backend::Memory) and [`FaultyBackend`]
/// (super::backend::FaultyBackend) pairs).
pub fn load(
    wal_backend: Box<dyn StorageBackend>,
    mut ckpt_backend: Box<dyn StorageBackend>,
) -> Result<Recovered, DurabilityError> {
    let checkpoint = Checkpoint::load(ckpt_backend.as_mut())?;
    let next_seq = checkpoint.as_ref().map_or(0, |c| c.next_seq);
    let (wal, scan) = Wal::open(wal_backend, next_seq)?;
    // Frames the checkpoint already covers are a stale prefix (left
    // behind when a crash hit between checkpoint store and WAL
    // truncation) — skipped, not replayed.  Whatever remains must start
    // exactly at the checkpoint's next_seq: a gap means frames that
    // were durably logged have gone missing, which is corruption.
    let tail: Vec<Frame> = scan.frames.into_iter().filter(|f| f.seq >= next_seq).collect();
    if let Some(first) = tail.first() {
        if first.seq != next_seq {
            return Err(DurabilityError::Corrupt {
                context: "recover",
                offset: 0,
                detail: format!(
                    "checkpoint covers seqs < {next_seq} but the wal resumes at {}: \
                     frames are missing",
                    first.seq
                ),
            });
        }
    }
    Ok(Recovered {
        checkpoint,
        tail,
        truncated_bytes: scan.truncated_bytes,
        wal,
        ckpt_backend,
    })
}

#[cfg(test)]
mod tests {
    use super::super::backend::Memory;
    use super::super::checkpoint::Checkpoint;
    use super::super::wal::{FramePayload, Wal};
    use super::*;
    use crate::graph::stream::GraphEvent;
    use crate::linalg::mat::Mat;
    use crate::sparse::csr::Csr;
    use crate::tracking::traits::{EigenPairs, TrackerState};

    fn tiny_ckpt(next_seq: u64) -> Checkpoint {
        let pairs =
            EigenPairs { values: vec![1.0], vectors: Mat::from_vec(1, 1, vec![1.0]) };
        Checkpoint {
            next_seq,
            version: 1,
            wall_us: 0,
            pairs: pairs.clone(),
            ids: vec![0],
            adjacency: Csr::empty(1, 1),
            tracker: TrackerState { pairs, aux_u: vec![], aux_f: vec![], adjacency: None },
        }
    }

    #[test]
    fn fresh_dir_recovers_empty() {
        let r = load(Box::new(Memory::new()), Box::new(Memory::new())).unwrap();
        assert!(r.checkpoint.is_none());
        assert!(r.tail.is_empty());
        assert_eq!(r.wal.next_seq(), 0);
    }

    #[test]
    fn stale_wal_prefix_is_skipped_not_replayed() {
        // crash between checkpoint store and wal truncation: the wal
        // still holds frames the checkpoint covers
        let wal_mem = Memory::new();
        let (mut wal, _) = Wal::open(Box::new(wal_mem.clone()), 0).unwrap();
        wal.append_events(&[GraphEvent::AddEdge(0, 1)]); // seq 0
        wal.append_commit(1); // seq 1
        wal.append_events(&[GraphEvent::AddEdge(1, 2)]); // seq 2
        wal.append_commit(2); // seq 3
        wal.sync().unwrap();
        let ckpt_mem = Memory::new();
        tiny_ckpt(2).store(&mut ckpt_mem.clone()).unwrap();
        let r = load(Box::new(wal_mem), Box::new(ckpt_mem)).unwrap();
        assert_eq!(r.tail.len(), 2, "only seqs 2..4 replay");
        assert_eq!(r.tail[0].seq, 2);
        assert!(matches!(r.tail[1].payload, FramePayload::Commit { version: 2 }));
    }

    #[test]
    fn missing_frames_after_checkpoint_are_loud() {
        // checkpoint says replay from seq 2, but the wal starts at 3
        let wal_mem = Memory::new();
        let (mut wal, _) = Wal::open(Box::new(wal_mem.clone()), 3).unwrap();
        wal.append_commit(2); // seq 3
        wal.sync().unwrap();
        let ckpt_mem = Memory::new();
        tiny_ckpt(2).store(&mut ckpt_mem.clone()).unwrap();
        match load(Box::new(wal_mem), Box::new(ckpt_mem)) {
            Err(DurabilityError::Corrupt { context, .. }) => assert_eq!(context, "recover"),
            _ => panic!("seq gap after checkpoint must be loud"),
        }
    }

    #[test]
    fn empty_wal_resumes_seq_from_checkpoint() {
        let ckpt_mem = Memory::new();
        tiny_ckpt(9).store(&mut ckpt_mem.clone()).unwrap();
        let r = load(Box::new(Memory::new()), Box::new(ckpt_mem)).unwrap();
        assert_eq!(r.wal.next_seq(), 9, "seq numbering continues monotone");
    }
}
