//! The fleet: many independent tenant graphs, each with its own
//! tracker, seed, and batch policy, multiplexed onto one shared
//! [`WorkerPool`].
//!
//! This is the ROADMAP's "serving system" layer: tenant count is
//! decoupled from OS thread count (16 tenants on 4 workers is the
//! tested configuration floor), scheduling is fair round-robin, and
//! per-tenant [`TenantBudget`]s surface flop/memory overruns through
//! each tenant's [`Metrics`].  `@xla` tenants transparently fall back
//! to a dedicated pinned thread (PJRT state is thread-bound) while
//! still being fleet-managed.
//!
//! Isolation contract: tenants share worker threads but nothing else —
//! a tenant whose tracker fails every batch only burns its own
//! scheduled steps and its own `update_failures` counter (soak-tested
//! in `tests/fleet.rs`).

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::service::{
    SendTrackerFactory, ServiceConfig, ServiceHandle, TrackingService,
};
use crate::coordinator::tenant::TenantBudget;
use crate::tracking::spec::Backend;
use anyhow::{bail, Result};
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::fmt;
use crate::sync::{Arc, Mutex};

/// Opaque tenant key (caller-assigned).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(pub u64);

impl fmt::Display for TenantId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "tenant-{}", self.0)
    }
}

/// Fleet-wide configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetConfig {
    /// Worker threads in the shared pool (`0` = auto, like
    /// [`Threads::AUTO`](crate::linalg::threads::Threads::AUTO)).
    pub workers: usize,
}

/// A multi-tenant coordinator: spawn/get/remove tenants by
/// [`TenantId`], roll their metrics up fleet-wide.
pub struct Fleet {
    pool: WorkerPool,
    tenants: Mutex<HashMap<TenantId, TrackingService>>,
}

impl Fleet {
    /// Start a fleet with its own worker pool.
    pub fn new(config: FleetConfig) -> Fleet {
        Fleet { pool: WorkerPool::new(config.workers), tenants: Mutex::new(HashMap::new()) }
    }

    /// Worker threads in the shared pool.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Spawn a tenant with the default (unbounded) budget.
    pub fn spawn(&self, id: TenantId, config: ServiceConfig) -> Result<ServiceHandle> {
        self.spawn_budgeted(id, config, TenantBudget::default())
    }

    /// Spawn a tenant with a resource budget.  Native-backend tenants
    /// join the shared pool; `@xla` tenants get a dedicated pinned
    /// thread (PJRT state is thread-bound) but stay fleet-managed.
    pub fn spawn_budgeted(
        &self,
        id: TenantId,
        mut config: ServiceConfig,
        budget: TenantBudget,
    ) -> Result<ServiceHandle> {
        self.check_free(id)?;
        scope_durability(&mut config, id);
        let svc = if config.tracker.backend == Backend::Xla {
            TrackingService::spawn_pinned_budgeted(config, budget)?
        } else {
            TrackingService::spawn_on(&self.pool, config, budget)?
        };
        self.insert(id, svc)
    }

    /// Spawn a pool-resident tenant from a hand-written `Send` tracker
    /// factory (`config.tracker` is ignored) — the escape hatch for
    /// trackers the registry can't build, e.g. fault-injection wrappers
    /// in the isolation tests.
    pub fn spawn_with_factory(
        &self,
        id: TenantId,
        mut config: ServiceConfig,
        budget: TenantBudget,
        factory: SendTrackerFactory,
    ) -> Result<ServiceHandle> {
        self.check_free(id)?;
        scope_durability(&mut config, id);
        let svc = TrackingService::spawn_on_with_factory(&self.pool, config, budget, factory)?;
        self.insert(id, svc)
    }

    /// Fast-path duplicate check before paying for tracker
    /// construction; [`insert`](Self::insert) re-checks authoritatively.
    fn check_free(&self, id: TenantId) -> Result<()> {
        if self.tenants.lock().contains_key(&id) {
            bail!("{id} already exists");
        }
        Ok(())
    }

    fn insert(&self, id: TenantId, svc: TrackingService) -> Result<ServiceHandle> {
        let handle = svc.handle.clone();
        match self.tenants.lock().entry(id) {
            // a concurrent spawn won the race: drop `svc` (its Drop
            // retires the just-registered tenant) and report the dup
            Entry::Occupied(_) => bail!("{id} already exists"),
            Entry::Vacant(slot) => {
                slot.insert(svc);
                Ok(handle)
            }
        }
    }

    /// Handle to a live tenant.
    pub fn get(&self, id: TenantId) -> Option<ServiceHandle> {
        self.tenants.lock().get(&id).map(|svc| svc.handle.clone())
    }

    /// A tenant's own metric set.
    pub fn metrics(&self, id: TenantId) -> Option<Arc<Metrics>> {
        self.get(id).map(|h| h.metrics())
    }

    /// Live tenant ids, sorted.
    pub fn ids(&self) -> Vec<TenantId> {
        let mut ids: Vec<TenantId> = self.tenants.lock().keys().copied().collect();
        ids.sort();
        ids
    }

    pub fn len(&self) -> usize {
        self.tenants.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.lock().is_empty()
    }

    /// Retire a tenant (waits until no worker will touch it again).
    /// Returns whether the id was live.
    pub fn remove(&self, id: TenantId) -> bool {
        // take the service out of the map first, so the join below
        // never holds the fleet lock while waiting on a worker
        let svc = self.tenants.lock().remove(&id);
        match svc {
            Some(svc) => {
                svc.join();
                true
            }
            None => false,
        }
    }

    /// Fleet-wide metrics roll-up: counters summed, latency histograms
    /// merged bucket-wise across every live tenant.
    pub fn metrics_rollup(&self) -> Metrics {
        let rollup = Metrics::default();
        for svc in self.tenants.lock().values() {
            rollup.merge_from(&svc.handle.metrics());
        }
        rollup
    }

    /// Retire every tenant and stop the pool (also what `Drop` does).
    pub fn join(self) {}
}

/// Fleet tenants share one configured durability root; each tenant's
/// WAL + checkpoint live in a `TenantId`-keyed subdirectory so two
/// tenants never write the same files.  The rewrite happens *before*
/// `ServiceConfig::validate`, which therefore probes the per-tenant dir.
fn scope_durability(config: &mut ServiceConfig, id: TenantId) {
    if let Some(d) = &mut config.durability {
        d.dir = d.dir.join(id.to_string());
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        // retire tenants while the pool still runs (each Shutdown needs
        // a worker to ack it), then stop the pool
        let tenants: Vec<TrackingService> =
            self.tenants.lock().drain().map(|(_, svc)| svc).collect();
        for svc in tenants {
            svc.join();
        }
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::graph::stream::GraphEvent;
    use crate::linalg::f32mat::ServePrecision;
    use crate::linalg::rng::Rng;
    use crate::linalg::threads::Threads;
    use crate::tracking::spec::TrackerSpec;

    fn config(seed: u64) -> ServiceConfig {
        let mut rng = Rng::new(seed);
        ServiceConfig {
            initial: crate::graph::generators::erdos_renyi(30, 0.1, &mut rng),
            k: 3,
            policy: BatchPolicy::ByCount(2),
            seed,
            tracker: TrackerSpec::default(),
            threads: Threads::SINGLE,
            serve_precision: ServePrecision::F64,
            durability: None,
        }
    }

    #[test]
    fn durability_dirs_are_scoped_per_tenant() {
        let mut cfg = config(1);
        cfg.durability =
            Some(crate::coordinator::durability::DurabilityConfig::new("/tmp/fleet-root"));
        scope_durability(&mut cfg, TenantId(42));
        let d = cfg.durability.unwrap();
        assert_eq!(d.dir, std::path::Path::new("/tmp/fleet-root/tenant-42"));
        assert!(d.wal_path().ends_with("tenant-42/wal.log"));
    }

    #[test]
    fn spawn_get_remove_lifecycle() {
        let fleet = Fleet::new(FleetConfig { workers: 2 });
        assert_eq!(fleet.workers(), 2);
        assert!(fleet.is_empty());
        let h = fleet.spawn(TenantId(1), config(1)).unwrap();
        fleet.spawn(TenantId(2), config(2)).unwrap();
        assert_eq!(fleet.len(), 2);
        assert_eq!(fleet.ids(), vec![TenantId(1), TenantId(2)]);
        h.ingest(vec![GraphEvent::AddEdge(0, 600), GraphEvent::AddEdge(1, 601)]).unwrap();
        let v = h.flush().unwrap();
        assert!(v >= 1);
        assert!(fleet.get(TenantId(1)).is_some());
        assert!(fleet.get(TenantId(9)).is_none());
        assert!(fleet.remove(TenantId(1)));
        assert!(!fleet.remove(TenantId(1)));
        assert_eq!(fleet.len(), 1);
        // the removed tenant's handle is dead, the survivor lives on
        assert!(h.ingest(vec![GraphEvent::AddEdge(0, 602)]).is_err());
        let h2 = fleet.get(TenantId(2)).unwrap();
        h2.ingest(vec![GraphEvent::AddEdge(0, 700), GraphEvent::AddEdge(1, 701)]).unwrap();
        assert!(h2.flush().unwrap() >= 1);
        fleet.join();
    }

    #[test]
    fn duplicate_tenant_id_is_rejected() {
        let fleet = Fleet::new(FleetConfig { workers: 1 });
        fleet.spawn(TenantId(7), config(3)).unwrap();
        let err = fleet.spawn(TenantId(7), config(4)).unwrap_err();
        assert!(err.to_string().contains("tenant-7"), "{err}");
        assert_eq!(fleet.len(), 1);
    }

    #[test]
    fn rollup_sums_tenant_metrics() {
        let fleet = Fleet::new(FleetConfig { workers: 2 });
        for id in 0..3u64 {
            let h = fleet.spawn(TenantId(id), config(10 + id)).unwrap();
            h.ingest(vec![
                GraphEvent::AddEdge(0, 500 + id),
                GraphEvent::AddEdge(1, 510 + id),
            ])
            .unwrap();
            h.flush().unwrap();
        }
        let rollup = fleet.metrics_rollup();
        assert_eq!(rollup.events_ingested.get(), 6);
        assert_eq!(rollup.batches_applied.get(), 3);
        assert_eq!(rollup.update_latency.count(), 3);
        assert!(rollup.resident_bytes.get() > 0);
        // per-tenant metrics stay scoped
        let m0 = fleet.metrics(TenantId(0)).unwrap();
        assert_eq!(m0.events_ingested.get(), 2);
    }
}
