//! The shared worker pool: a fixed set of parked threads driving any
//! number of [`TenantState`] machines.
//!
//! Replaces the thread-per-service model — tenant count is no longer
//! capped by OS thread count, and cross-tenant scheduling (fairness,
//! deadline wakeups) happens in one place.  Scheduling contract:
//!
//! * **Fair**: runnable tenants sit in a FIFO ready queue; a tenant
//!   that keeps receiving work re-enters at the back after each step
//!   (round-robin), so a bursty tenant cannot starve the rest.
//! * **Exclusive**: the per-tenant `queued` flag guarantees at most
//!   one worker runs a given tenant at a time, and a tenant is never
//!   in the ready queue twice.  Tenant state needs no further locking
//!   discipline from callers.
//! * **Deadline-aware**: a step that leaves a `max_age`-armed pending
//!   batch parks the tenant in a timer heap; the pool wakes it at the
//!   deadline with no new input required — and [`WorkerPool::shutdown`]
//!   flushes any still-armed deadline instead of stranding the batch.
//!
//! The scheduling protocol itself (ready queue, timer heap, `queued`
//! CAS exclusion, lost-wakeup re-check, retirement latch) lives in
//! [`pool_core`](crate::coordinator::pool_core), which the
//! `rust/loom-model` crate model-checks under exhaustive thread
//! interleaving; this module only adds OS threads, the global pool,
//! and `anyhow` error adaptation.  See `docs/CONCURRENCY.md`.
//!
//! `@xla` tenants must NOT run here — PJRT state is thread-bound — so
//! the service layer gives them a dedicated pinned thread driving the
//! same state machine (see `coordinator/service.rs`).

use crate::coordinator::pool_core::{PoolCore, PoolTenant};
use crate::coordinator::tenant::{TenantCmd, TenantState};
use crate::linalg::threads::Threads;
use crate::sync::{thread, Arc, Mutex, OnceLock};
use anyhow::Result;

/// A pool-resident tenant (inbox + scheduling flags + the state
/// machine).  Handles talk to it exclusively through
/// [`WorkerPool::submit`].
pub type Tenant = PoolTenant<TenantState>;

struct PoolInner {
    core: Arc<PoolCore<TenantState>>,
    workers: usize,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

/// Cloneable handle to a worker pool.
#[derive(Clone)]
pub struct WorkerPool {
    inner: Arc<PoolInner>,
}

impl WorkerPool {
    /// Spawn a pool of `workers` parked threads (`0` resolves like
    /// [`Threads::AUTO`]).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = if workers == 0 { Threads::AUTO.resolve() } else { workers };
        let core = Arc::new(PoolCore::new());
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let core = core.clone();
            handles.push(thread::spawn_named(&format!("grest-pool-{i}"), move || {
                core.worker_loop();
            }));
        }
        WorkerPool { inner: Arc::new(PoolInner { core, workers, handles: Mutex::new(handles) }) }
    }

    /// The process-wide default pool every native-backend
    /// `TrackingService` runs on (spawned lazily, never shut down).
    pub fn global() -> &'static WorkerPool {
        static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
        GLOBAL.get_or_init(|| WorkerPool::new(0))
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Adopt a tenant state machine.  The tenant is inert until its
    /// first [`submit`](Self::submit).
    pub fn register(&self, state: TenantState) -> Arc<Tenant> {
        self.inner.core.register(state)
    }

    /// Queue a command into the tenant's inbox and mark it runnable.
    pub fn submit(&self, tenant: &Arc<Tenant>, cmd: TenantCmd) -> Result<()> {
        Ok(self.inner.core.submit(tenant, cmd)?)
    }

    /// Stop accepting work, flush armed deadline batches, drain the
    /// ready queue, and join the worker threads.  Idempotent.  Tenants
    /// should be shut down (via a [`TenantCmd::Shutdown`]) *before* the
    /// pool, or their pending replies are dropped.
    pub fn shutdown(&self) {
        self.inner.core.begin_shutdown();
        let handles = std::mem::take(&mut *self.inner.handles.lock());
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::snapshot::{EmbeddingSnapshot, SnapshotStore};
    use crate::coordinator::tenant::TenantBudget;
    use crate::graph::stream::{DeltaBuilder, GraphEvent, IdMap};
    use crate::linalg::rng::Rng;
    use crate::sync::mpsc;
    use crate::tracking::spec::TrackerSpec;
    use std::time::{Duration, Instant};

    /// Shutdown a tenant and wait until no worker will touch it again.
    fn retire(pool: &WorkerPool, tenant: &Arc<Tenant>) {
        let (ack_tx, ack_rx) = mpsc::channel::<()>();
        if pool.submit(tenant, TenantCmd::Shutdown(ack_tx)).is_ok() {
            let _ = ack_rx.recv();
        }
    }

    fn register_tenant(
        pool: &WorkerPool,
        seed: u64,
        policy: BatchPolicy,
    ) -> (Arc<Tenant>, SnapshotStore) {
        let mut rng = Rng::new(seed);
        let g = crate::graph::generators::erdos_renyi(25, 0.12, &mut rng);
        let a0 = g.adjacency();
        let init = crate::tracking::traits::init_eigenpairs(&a0, 3, seed);
        let tracker = TrackerSpec::default().build_seeded_send(&a0, &init, seed).unwrap();
        let store = SnapshotStore::new(EmbeddingSnapshot {
            version: 0,
            n_nodes: a0.n_rows,
            pairs: init,
            ids: Arc::new(IdMap::identity(a0.n_rows)),
            published_at: Instant::now(),
        });
        let state = TenantState::new(
            tracker,
            DeltaBuilder::from_graph(g),
            a0,
            policy,
            store.clone(),
            Metrics::new(),
            TenantBudget::default(),
        );
        (pool.register(state), store)
    }

    #[test]
    fn more_tenants_than_workers_all_progress() {
        let pool = WorkerPool::new(2);
        let tenants: Vec<_> =
            (0..6).map(|i| register_tenant(&pool, 10 + i, BatchPolicy::ByCount(1))).collect();
        for (t, _) in &tenants {
            pool.submit(t, TenantCmd::Events(vec![GraphEvent::AddEdge(0, 800)])).unwrap();
        }
        for (t, store) in &tenants {
            let (rtx, rrx) = mpsc::channel();
            pool.submit(t, TenantCmd::Flush(rtx)).unwrap();
            let v = rrx.recv().unwrap();
            assert!(v >= 1, "every tenant must flush on a 2-worker pool");
            assert_eq!(store.latest().version, v);
        }
        for (t, _) in &tenants {
            retire(&pool, t);
        }
        pool.shutdown();
    }

    #[test]
    fn submit_to_retired_tenant_fails() {
        let pool = WorkerPool::new(1);
        let (tenant, _) = register_tenant(&pool, 3, BatchPolicy::ByCount(1));
        pool.submit(&tenant, TenantCmd::Events(vec![GraphEvent::AddEdge(0, 900)])).unwrap();
        retire(&pool, &tenant);
        assert!(tenant.is_stopped());
        let err = pool
            .submit(&tenant, TenantCmd::Events(vec![GraphEvent::AddEdge(1, 901)]))
            .unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        pool.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_rejects_new_work() {
        let pool = WorkerPool::new(1);
        let (tenant, _) = register_tenant(&pool, 4, BatchPolicy::ByCount(1));
        retire(&pool, &tenant);
        pool.shutdown();
        pool.shutdown();
        let (t2, _) = register_tenant(&pool, 5, BatchPolicy::ByCount(1));
        let err =
            pool.submit(&t2, TenantCmd::Events(vec![GraphEvent::AddEdge(0, 1)])).unwrap_err();
        assert!(err.to_string().contains("pool is shut down"), "{err}");
    }

    #[test]
    fn shutdown_flushes_max_age_pending_batches() {
        // regression: shutdown used to drop the timer heap on the
        // floor (add_timer no-oped once `shutdown` was set), so a
        // pending MaxAge batch was stranded unflushed forever
        let pool = WorkerPool::new(1);
        let far = BatchPolicy::MaxAge(Duration::from_secs(3600));
        let (tenant, store) = register_tenant(&pool, 6, far);
        pool.submit(&tenant, TenantCmd::Events(vec![GraphEvent::AddEdge(0, 900)])).unwrap();
        // barrier: once Adjacency replies, the Events command has been
        // applied, so a batch is pending under the far-future deadline
        let (rtx, rrx) = mpsc::channel();
        pool.submit(&tenant, TenantCmd::Adjacency(rtx)).unwrap();
        let _ = rrx.recv().unwrap();
        assert_eq!(store.latest().version, 0, "deadline is an hour out: nothing flushed yet");
        pool.shutdown();
        assert_eq!(
            store.latest().version,
            1,
            "shutdown must flush the armed MaxAge batch, not strand it"
        );
    }
}
