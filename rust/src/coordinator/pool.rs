//! The shared worker pool: a fixed set of parked threads driving any
//! number of [`TenantState`] machines.
//!
//! Replaces the thread-per-service model — tenant count is no longer
//! capped by OS thread count, and cross-tenant scheduling (fairness,
//! deadline wakeups) happens in one place.  Scheduling contract:
//!
//! * **Fair**: runnable tenants sit in a FIFO ready queue; a tenant
//!   that keeps receiving work re-enters at the back after each step
//!   (round-robin), so a bursty tenant cannot starve the rest.
//! * **Exclusive**: the per-tenant `queued` flag guarantees at most
//!   one worker runs a given tenant at a time, and a tenant is never
//!   in the ready queue twice.  Tenant state needs no further locking
//!   discipline from callers.
//! * **Deadline-aware**: a step that leaves a `max_age`-armed pending
//!   batch parks the tenant in a timer heap; the pool wakes it at the
//!   deadline with no new input required.
//!
//! `@xla` tenants must NOT run here — PJRT state is thread-bound — so
//! the service layer gives them a dedicated pinned thread driving the
//! same state machine (see `coordinator/service.rs`).

use crate::coordinator::tenant::{StepOutcome, TenantCmd, TenantState};
use crate::linalg::threads::Threads;
use anyhow::{bail, Result};
use std::collections::{BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// A pool-resident tenant: inbox + scheduling flags + the state
/// machine.  Handles talk to it exclusively through
/// [`WorkerPool::submit`].
pub struct Tenant {
    inbox: Mutex<VecDeque<TenantCmd>>,
    /// True while the tenant is in the ready queue or being stepped —
    /// the at-most-one-worker-per-tenant exclusion.
    queued: AtomicBool,
    /// Set once on shutdown; a stopped tenant is never scheduled again
    /// (`queued` stays latched true for the same reason).
    stopped: AtomicBool,
    state: Mutex<TenantState>,
}

impl Tenant {
    /// Has this tenant retired?  (Submissions now fail.)
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::Acquire)
    }
}

/// Timer-heap entry; `Ord` is reversed on `(at, seq)` so the std
/// max-heap pops the *earliest* deadline first (FIFO among ties).
struct TimerEntry {
    at: Instant,
    seq: u64,
    tenant: Arc<Tenant>,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &TimerEntry) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for TimerEntry {}

impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &TimerEntry) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TimerEntry {
    fn cmp(&self, other: &TimerEntry) -> std::cmp::Ordering {
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct Sched {
    ready: VecDeque<Arc<Tenant>>,
    timers: BinaryHeap<TimerEntry>,
    timer_seq: u64,
    shutdown: bool,
    handles: Vec<std::thread::JoinHandle<()>>,
}

struct PoolInner {
    sched: Mutex<Sched>,
    cv: Condvar,
    workers: usize,
}

/// Cloneable handle to a worker pool.
#[derive(Clone)]
pub struct WorkerPool {
    inner: Arc<PoolInner>,
}

impl WorkerPool {
    /// Spawn a pool of `workers` parked threads (`0` resolves like
    /// [`Threads::AUTO`]).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = if workers == 0 { Threads::AUTO.resolve() } else { workers };
        let inner = Arc::new(PoolInner {
            sched: Mutex::new(Sched {
                ready: VecDeque::new(),
                timers: BinaryHeap::new(),
                timer_seq: 0,
                shutdown: false,
                handles: Vec::new(),
            }),
            cv: Condvar::new(),
            workers,
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let inner = inner.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("grest-pool-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn pool worker thread"),
            );
        }
        inner.sched.lock().unwrap().handles = handles;
        WorkerPool { inner }
    }

    /// The process-wide default pool every native-backend
    /// `TrackingService` runs on (spawned lazily, never shut down).
    pub fn global() -> &'static WorkerPool {
        static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
        GLOBAL.get_or_init(|| WorkerPool::new(0))
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Adopt a tenant state machine.  The tenant is inert until its
    /// first [`submit`](Self::submit).
    pub fn register(&self, state: TenantState) -> Arc<Tenant> {
        Arc::new(Tenant {
            inbox: Mutex::new(VecDeque::new()),
            queued: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            state: Mutex::new(state),
        })
    }

    /// Queue a command into the tenant's inbox and mark it runnable.
    pub fn submit(&self, tenant: &Arc<Tenant>, cmd: TenantCmd) -> Result<()> {
        if tenant.is_stopped() {
            bail!("tracker worker is shut down");
        }
        if self.inner.sched.lock().unwrap().shutdown {
            bail!("worker pool is shut down");
        }
        tenant.inbox.lock().unwrap().push_back(cmd);
        if tenant.is_stopped() {
            // raced retirement: the worker that stopped the tenant has
            // already drained the inbox; drop our command too (any
            // reply sender in it unblocks its receiver with an Err)
            tenant.inbox.lock().unwrap().clear();
            bail!("tracker worker is shut down");
        }
        self.inner.schedule(tenant.clone());
        Ok(())
    }

    /// Stop accepting work, drain the ready queue, and join the worker
    /// threads.  Idempotent.  Tenants should be shut down (via a
    /// [`TenantCmd::Shutdown`]) *before* the pool, or their pending
    /// replies are dropped.
    pub fn shutdown(&self) {
        let handles = {
            let mut sched = self.inner.sched.lock().unwrap();
            sched.shutdown = true;
            std::mem::take(&mut sched.handles)
        };
        self.inner.cv.notify_all();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl PoolInner {
    /// Mark a tenant runnable if it isn't queued already.
    fn schedule(&self, tenant: Arc<Tenant>) {
        if tenant.is_stopped() {
            return;
        }
        if tenant
            .queued
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            // already queued or running; the lost-wakeup re-check in
            // run_turn guarantees the new command is seen
            return;
        }
        let mut sched = self.sched.lock().unwrap();
        sched.ready.push_back(tenant);
        self.cv.notify_one();
    }

    /// Park a tenant until `at` (deadline-armed pending batch).
    fn add_timer(&self, at: Instant, tenant: Arc<Tenant>) {
        let mut sched = self.sched.lock().unwrap();
        if sched.shutdown {
            return;
        }
        let seq = sched.timer_seq;
        sched.timer_seq += 1;
        sched.timers.push(TimerEntry { at, seq, tenant });
        // the new deadline may be earlier than what sleepers wait on
        self.cv.notify_one();
    }
}

fn worker_loop(inner: &Arc<PoolInner>) {
    let mut sched = inner.sched.lock().unwrap();
    loop {
        // promote due timers to the ready queue
        let now = Instant::now();
        while sched.timers.peek().is_some_and(|t| t.at <= now) {
            let entry = sched.timers.pop().unwrap();
            if !entry.tenant.is_stopped()
                && entry
                    .tenant
                    .queued
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                sched.ready.push_back(entry.tenant);
                inner.cv.notify_one();
            }
        }
        if let Some(tenant) = sched.ready.pop_front() {
            drop(sched);
            run_turn(inner, &tenant);
            sched = inner.sched.lock().unwrap();
            continue;
        }
        if sched.shutdown {
            return;
        }
        sched = match sched.timers.peek().map(|t| t.at) {
            None => inner.cv.wait(sched).unwrap(),
            Some(at) => {
                let now = Instant::now();
                if at <= now {
                    continue;
                }
                inner.cv.wait_timeout(sched, at - now).unwrap().0
            }
        };
    }
}

/// Run one scheduled step of a tenant.  Caller must hold the tenant's
/// `queued` flag (i.e. have popped it from the ready queue).
fn run_turn(inner: &Arc<PoolInner>, tenant: &Arc<Tenant>) {
    if tenant.is_stopped() {
        // stopped while waiting in the ready queue; `queued` stays
        // latched so it is never re-queued
        return;
    }
    let outcome = tenant.state.lock().unwrap().step(&tenant.inbox);
    match outcome {
        StepOutcome::Stopped(ack) => {
            tenant.stopped.store(true, Ordering::Release);
            // drop queued commands — their reply senders unblock any
            // waiting caller with a recv error
            tenant.inbox.lock().unwrap().clear();
            let _ = ack.send(());
        }
        outcome => {
            tenant.queued.store(false, Ordering::Release);
            // lost-wakeup re-check: a submit that raced the drain saw
            // `queued == true` and skipped scheduling
            if !tenant.inbox.lock().unwrap().is_empty() {
                inner.schedule(tenant.clone());
            } else if let StepOutcome::WaitUntil(at) = outcome {
                inner.add_timer(at, tenant.clone());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::snapshot::{EmbeddingSnapshot, SnapshotStore};
    use crate::coordinator::tenant::TenantBudget;
    use crate::graph::stream::{DeltaBuilder, GraphEvent, IdMap};
    use crate::linalg::rng::Rng;
    use crate::tracking::spec::TrackerSpec;

    /// Shutdown a tenant and wait until no worker will touch it again.
    fn retire(pool: &WorkerPool, tenant: &Arc<Tenant>) {
        let (ack_tx, ack_rx) = std::sync::mpsc::channel::<()>();
        if pool.submit(tenant, TenantCmd::Shutdown(ack_tx)).is_ok() {
            let _ = ack_rx.recv();
        }
    }

    fn register_tenant(pool: &WorkerPool, seed: u64) -> (Arc<Tenant>, SnapshotStore) {
        let mut rng = Rng::new(seed);
        let g = crate::graph::generators::erdos_renyi(25, 0.12, &mut rng);
        let a0 = g.adjacency();
        let init = crate::tracking::traits::init_eigenpairs(&a0, 3, seed);
        let tracker = TrackerSpec::default().build_seeded_send(&a0, &init, seed).unwrap();
        let store = SnapshotStore::new(EmbeddingSnapshot {
            version: 0,
            n_nodes: a0.n_rows,
            pairs: init,
            ids: Arc::new(IdMap::identity(a0.n_rows)),
            published_at: Instant::now(),
        });
        let state = TenantState::new(
            tracker,
            DeltaBuilder::from_graph(g),
            a0,
            BatchPolicy::ByCount(1),
            store.clone(),
            Metrics::new(),
            TenantBudget::default(),
        );
        (pool.register(state), store)
    }

    #[test]
    fn more_tenants_than_workers_all_progress() {
        let pool = WorkerPool::new(2);
        let tenants: Vec<_> = (0..6).map(|i| register_tenant(&pool, 10 + i)).collect();
        for (t, _) in &tenants {
            pool.submit(t, TenantCmd::Events(vec![GraphEvent::AddEdge(0, 800)])).unwrap();
        }
        for (t, store) in &tenants {
            let (rtx, rrx) = std::sync::mpsc::channel();
            pool.submit(t, TenantCmd::Flush(rtx)).unwrap();
            let v = rrx.recv().unwrap();
            assert!(v >= 1, "every tenant must flush on a 2-worker pool");
            assert_eq!(store.latest().version, v);
        }
        for (t, _) in &tenants {
            retire(&pool, t);
        }
        pool.shutdown();
    }

    #[test]
    fn submit_to_retired_tenant_fails() {
        let pool = WorkerPool::new(1);
        let (tenant, _) = register_tenant(&pool, 3);
        pool.submit(&tenant, TenantCmd::Events(vec![GraphEvent::AddEdge(0, 900)])).unwrap();
        retire(&pool, &tenant);
        assert!(tenant.is_stopped());
        let err = pool
            .submit(&tenant, TenantCmd::Events(vec![GraphEvent::AddEdge(1, 901)]))
            .unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        pool.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_rejects_new_work() {
        let pool = WorkerPool::new(1);
        let (tenant, _) = register_tenant(&pool, 4);
        retire(&pool, &tenant);
        pool.shutdown();
        pool.shutdown();
        let (t2, _) = register_tenant(&pool, 5);
        let err =
            pool.submit(&t2, TenantCmd::Events(vec![GraphEvent::AddEdge(0, 1)])).unwrap_err();
        assert!(err.to_string().contains("pool is shut down"), "{err}");
    }
}
