//! The scheduler protocol of the worker pool, extracted from any
//! tenant-specific state so it can be model-checked.
//!
//! This module is deliberately dependency-free: it imports only
//! [`crate::sync`] (the std/loom facade), std collections, and
//! `std::time`.  The `rust/loom-model` crate includes this exact source
//! file via `#[path]` and compiles it against a `loom`-backed facade,
//! so every lock/CAS/condvar line below is explored under exhaustive
//! interleaving by `cargo test` in that crate (`--cfg loom`).  Keep it
//! that way: no `anyhow`, no tracker types, no other crate modules.
//!
//! The protocol invariants (see `docs/CONCURRENCY.md` for the full
//! derivation, and `rust/loom-model/tests/loom_pool.rs` for the machine
//! checks):
//!
//! 1. **No lost wakeups**: a command pushed into an inbox is always
//!    followed by a turn that observes it — either the submitter wins
//!    the `queued` CAS and enqueues the tenant, or the worker that owns
//!    the flag re-checks the inbox after clearing it (`run_turn`).
//! 2. **At-most-one-worker-per-tenant**: the `queued` flag is acquired
//!    by exactly one party (submitter or timer promotion) before the
//!    tenant enters the ready queue, and the queue never holds the same
//!    tenant twice.
//! 3. **Retirement latch**: once a turn returns
//!    [`StepOutcome::Stopped`], `stopped` is set and `queued` stays
//!    latched `true` forever, so no post-stop command is ever executed
//!    and the inbox always ends empty (raced submitters clear it
//!    themselves behind the double-check in [`PoolCore::submit`]).

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{Arc, Condvar, Mutex};
use std::collections::{BinaryHeap, VecDeque};
use std::time::Instant;

/// Acknowledgement callback carried by [`StepOutcome::Stopped`]; the
/// scheduler invokes it once no worker will ever touch the tenant
/// again (the pinned path calls it from its dedicated thread).
pub type StopAck = Box<dyn FnOnce() + Send>;

/// What one [`Stepper::step`] turn left behind.
pub enum StepOutcome {
    /// Inbox drained, no deadline armed.
    Idle,
    /// Inbox drained (or the step yielded after a flush) and the state
    /// machine needs a wakeup by `at` even if no new input arrives.
    WaitUntil(Instant),
    /// The state machine retired; the scheduler latches the tenant
    /// stopped, clears its inbox, and fires the ack.
    Stopped(StopAck),
}

/// A resumable state machine the pool can drive.  The pool guarantees
/// `step` and `drain_deadline` are never run concurrently for one
/// tenant (they run under the tenant's state lock).
pub trait Stepper: Send + 'static {
    /// Commands this machine consumes from its inbox.
    type Cmd: Send;

    /// Run one schedulable unit of work: drain the inbox (bounded — a
    /// busy tenant must not monopolize a worker) and report how the
    /// scheduler should treat this tenant next.
    fn step(&mut self, inbox: &Mutex<VecDeque<Self::Cmd>>) -> StepOutcome;

    /// The pool is shutting down and any armed deadline will never
    /// fire: complete the deadline's work *now* (e.g. flush a pending
    /// `max_age` batch) rather than stranding it.
    fn drain_deadline(&mut self);
}

/// Why a submit was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The tenant retired (or retired while the command was in flight,
    /// in which case the command was discarded before execution).
    TenantStopped,
    /// The pool is shut down; no tenant runs again.
    PoolShutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::TenantStopped => write!(f, "tracker worker is shut down"),
            SubmitError::PoolShutdown => write!(f, "worker pool is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A pool-resident tenant: inbox + scheduling flags + the state
/// machine.  Callers talk to it exclusively through
/// [`PoolCore::submit`].
pub struct PoolTenant<S: Stepper> {
    inbox: Mutex<VecDeque<S::Cmd>>,
    /// True while the tenant is in the ready queue or being stepped —
    /// the at-most-one-worker-per-tenant exclusion.
    queued: AtomicBool,
    /// Set once on shutdown; a stopped tenant is never scheduled again
    /// (`queued` stays latched true for the same reason).
    stopped: AtomicBool,
    state: Mutex<S>,
}

impl<S: Stepper> PoolTenant<S> {
    fn new(state: S) -> PoolTenant<S> {
        PoolTenant {
            inbox: Mutex::new(VecDeque::new()),
            queued: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            state: Mutex::new(state),
        }
    }

    /// Has this tenant retired?  (Submissions now fail.)
    // ordering: Acquire pairs with the Release store in `run_turn`'s
    // Stopped arm — a caller that observes `stopped == true` also
    // observes every effect of the retiring turn (the inbox clear in
    // particular), so the double-check in `submit` cannot resurrect a
    // command the stopping worker already discarded.
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::Acquire)
    }

    /// Is the tenant currently in the ready queue or being stepped?
    /// (Diagnostics / model assertions; racy by nature for live pools.)
    // ordering: Acquire pairs with the Release half of the `queued`
    // CAS/store sites so a reader that sees `true` also sees the
    // enqueue (or latch) that published it.
    pub fn is_queued(&self) -> bool {
        self.queued.load(Ordering::Acquire)
    }

    /// Number of commands waiting in the inbox (model assertions).
    pub fn inbox_len(&self) -> usize {
        self.inbox.lock().len()
    }
}

/// Timer-heap entry; `Ord` is reversed on `(at, seq)` so the std
/// max-heap pops the *earliest* deadline first (FIFO among ties).
struct TimerEntry<S: Stepper> {
    at: Instant,
    seq: u64,
    tenant: Arc<PoolTenant<S>>,
}

impl<S: Stepper> PartialEq for TimerEntry<S> {
    fn eq(&self, other: &TimerEntry<S>) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<S: Stepper> Eq for TimerEntry<S> {}

impl<S: Stepper> PartialOrd for TimerEntry<S> {
    fn partial_cmp(&self, other: &TimerEntry<S>) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<S: Stepper> Ord for TimerEntry<S> {
    fn cmp(&self, other: &TimerEntry<S>) -> std::cmp::Ordering {
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct Sched<S: Stepper> {
    ready: VecDeque<Arc<PoolTenant<S>>>,
    timers: BinaryHeap<TimerEntry<S>>,
    timer_seq: u64,
    shutdown: bool,
}

/// The scheduler: a FIFO ready queue + deadline timer heap under one
/// mutex, a condvar for parked workers, and the per-tenant `queued`
/// exclusion protocol.  Thread management lives in the production
/// wrapper ([`crate::coordinator::pool::WorkerPool`]); the loom harness
/// drives [`PoolCore::worker_loop`] from model threads directly.
pub struct PoolCore<S: Stepper> {
    sched: Mutex<Sched<S>>,
    cv: Condvar,
}

impl<S: Stepper> Default for PoolCore<S> {
    fn default() -> PoolCore<S> {
        PoolCore::new()
    }
}

impl<S: Stepper> PoolCore<S> {
    pub fn new() -> PoolCore<S> {
        PoolCore {
            sched: Mutex::new(Sched {
                ready: VecDeque::new(),
                timers: BinaryHeap::new(),
                timer_seq: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Adopt a state machine.  The tenant is inert until its first
    /// [`submit`](Self::submit).
    pub fn register(&self, state: S) -> Arc<PoolTenant<S>> {
        Arc::new(PoolTenant::new(state))
    }

    /// Has [`begin_shutdown`](Self::begin_shutdown) run?
    pub fn is_shutdown(&self) -> bool {
        self.sched.lock().shutdown
    }

    /// Queue a command into the tenant's inbox and mark it runnable.
    ///
    /// `Ok` means the command was *enqueued* while the tenant was live;
    /// it executes unless the tenant retires first, in which case any
    /// reply channel inside it disconnects and unblocks its receiver
    /// with an error (no caller is ever stranded).
    pub fn submit(&self, tenant: &Arc<PoolTenant<S>>, cmd: S::Cmd) -> Result<(), SubmitError> {
        if tenant.is_stopped() {
            return Err(SubmitError::TenantStopped);
        }
        if self.sched.lock().shutdown {
            return Err(SubmitError::PoolShutdown);
        }
        tenant.inbox.lock().push_back(cmd);
        if tenant.is_stopped() {
            // raced retirement: the worker that stopped the tenant may
            // have drained the inbox before our push landed; discard
            // our command too (dropping it disconnects any reply
            // sender, so a blocked caller gets an error, and the
            // Acquire in is_stopped orders our clear after the
            // stopping worker's clear)
            tenant.inbox.lock().clear();
            return Err(SubmitError::TenantStopped);
        }
        self.schedule(tenant.clone());
        Ok(())
    }

    /// Mark a tenant runnable if it isn't queued already.
    pub(crate) fn schedule(&self, tenant: Arc<PoolTenant<S>>) {
        if tenant.is_stopped() {
            return;
        }
        // ordering: AcqRel on success — the Release half publishes the
        // inbox push that preceded this CAS to the worker that will
        // clear `queued` (its clearing store is Release, its CAS here
        // Acquire), and the Acquire half orders this enqueue after any
        // prior turn's effects.  Acquire on failure pairs with the
        // owner's eventual Release clear: seeing `true` means the
        // owning worker's re-check is still ahead of it and will
        // observe our push (lost-wakeup invariant).
        if tenant
            .queued
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            // already queued or running; the lost-wakeup re-check in
            // run_turn guarantees the new command is seen
            return;
        }
        let mut sched = self.sched.lock();
        debug_assert!(
            !sched.ready.iter().any(|t| Arc::ptr_eq(t, &tenant)),
            "a tenant must never be in the ready queue twice"
        );
        sched.ready.push_back(tenant);
        self.cv.notify_one();
    }

    /// Park a tenant until `at` (deadline-armed pending batch).  If the
    /// pool is already shutting down the timer would never fire, so the
    /// deadline's work is completed inline instead (see
    /// [`Stepper::drain_deadline`]).
    pub(crate) fn add_timer(&self, at: Instant, tenant: Arc<PoolTenant<S>>) {
        {
            let mut sched = self.sched.lock();
            if !sched.shutdown {
                let seq = sched.timer_seq;
                sched.timer_seq += 1;
                sched.timers.push(TimerEntry { at, seq, tenant });
                // the new deadline may be earlier than what sleepers
                // wait on
                self.cv.notify_one();
                return;
            }
        }
        // shutdown raced in between this turn's WaitUntil and arming
        // the timer: the heap was (or is being) drained, so flush the
        // pending work here rather than stranding it
        if !tenant.is_stopped() {
            tenant.state.lock().drain_deadline();
        }
    }

    /// Stop accepting work and wake every parked worker.  Armed
    /// deadline timers are drained — each parked tenant's pending work
    /// runs to completion here — instead of being silently dropped.
    /// Idempotent.  The caller joins its worker threads afterwards.
    pub fn begin_shutdown(&self) {
        let timers = {
            let mut sched = self.sched.lock();
            sched.shutdown = true;
            std::mem::take(&mut sched.timers)
        };
        self.cv.notify_all();
        // outside the sched lock: drain_deadline may run a full tracker
        // update, and workers need the lock to drain the ready queue.
        // Lock order here is state-only (never sched→state), matching
        // run_turn, so this cannot deadlock.
        for entry in timers {
            if !entry.tenant.is_stopped() {
                entry.tenant.state.lock().drain_deadline();
            }
        }
    }

    /// The worker body: promote due timers, run ready tenants, park on
    /// the condvar (deadline-bounded when timers are armed).  Returns
    /// when the pool is shut down and the ready queue is drained.
    pub fn worker_loop(&self) {
        let mut sched = self.sched.lock();
        loop {
            // promote due timers to the ready queue
            let now = Instant::now();
            while sched.timers.peek().is_some_and(|t| t.at <= now) {
                let Some(entry) = sched.timers.pop() else { break };
                // ordering: same pairing as `schedule` — winning this
                // CAS is the exclusive right to enqueue the tenant;
                // losing means a submitter queued it (or a worker runs
                // it) and that turn's deadline poll covers this wakeup.
                if !entry.tenant.is_stopped()
                    && entry
                        .tenant
                        .queued
                        .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                {
                    sched.ready.push_back(entry.tenant);
                    self.cv.notify_one();
                }
            }
            if let Some(tenant) = sched.ready.pop_front() {
                drop(sched);
                self.run_turn(&tenant);
                sched = self.sched.lock();
                continue;
            }
            if sched.shutdown {
                return;
            }
            sched = match sched.timers.peek().map(|t| t.at) {
                None => self.cv.wait(sched),
                Some(at) => {
                    let now = Instant::now();
                    if at <= now {
                        continue;
                    }
                    self.cv.wait_timeout(sched, at - now).0
                }
            };
        }
    }

    /// Run one scheduled step of a tenant.  Caller must hold the
    /// tenant's `queued` flag (i.e. have popped it from the ready
    /// queue).
    fn run_turn(&self, tenant: &Arc<PoolTenant<S>>) {
        if tenant.is_stopped() {
            // stopped while waiting in the ready queue; `queued` stays
            // latched so it is never re-queued
            return;
        }
        let outcome = tenant.state.lock().step(&tenant.inbox);
        match outcome {
            StepOutcome::Stopped(ack) => {
                // ordering: Release publishes this turn's effects —
                // crucially the inbox clear just below happens-after
                // any submitter's push that this store invalidates:
                // the submitter's double-check loads `stopped` with
                // Acquire and discards its own command.  `queued` is
                // deliberately NOT cleared: the latch guarantees no
                // future schedule() can ever re-enqueue the tenant.
                tenant.stopped.store(true, Ordering::Release);
                // drop queued commands — their reply senders unblock
                // any waiting caller with a recv error
                tenant.inbox.lock().clear();
                ack();
            }
            outcome => {
                // ordering: Release pairs with the Acquire CAS in
                // `schedule` — a submitter that wins the CAS after this
                // store observes everything this turn consumed, so it
                // never re-enqueues the tenant for work that was
                // already drained.
                tenant.queued.store(false, Ordering::Release);
                // lost-wakeup re-check: a submit that raced the drain
                // saw `queued == true` and skipped scheduling
                if !tenant.inbox.lock().is_empty() {
                    self.schedule(tenant.clone());
                } else if let StepOutcome::WaitUntil(at) = outcome {
                    self.add_timer(at, tenant.clone());
                }
            }
        }
    }
}
