//! Lightweight metrics: counters and log-bucketed latency histograms.
//!
//! This is the ONE module allowed to use `Ordering::Relaxed` (enforced
//! by `detlint` rule `relaxed-outside-metrics`): every atomic here is
//! an independent statistical counter — nothing reads one to make a
//! control-flow decision about another, so no cross-counter ordering
//! is ever required.  The [`Counter`] newtype keeps it that way: the
//! rest of the crate gets `add`/`incr`/`get`/`set` and can't spell an
//! ordering at all.

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::Arc;

/// A monotone (plus one gauge-style `set`) relaxed atomic counter.
///
/// Deliberately *not* a general atomic: no compare-exchange, no
/// ordering parameter.  Counters never synchronize other memory.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add `n` to the counter.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Add one.
    pub fn incr(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Current value (a statistical read, not a synchronization point).
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite the value — for gauge semantics (e.g. resident bytes),
    /// where the latest observation replaces the previous one.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the value to `v` if larger.
    pub fn max_with(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
}

/// Log₂-bucketed duration histogram (1µs … ~1000s).
#[derive(Debug, Default)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^{i+1}) microseconds
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn observe(&self, d: std::time::Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> std::time::Duration {
        let c = self.count();
        if c == 0 {
            return std::time::Duration::ZERO;
        }
        std::time::Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> std::time::Duration {
        std::time::Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket boundaries (upper bound),
    /// clamped to the observed maximum — a single 300µs sample must
    /// report p99 = 300µs, not the 512µs bucket edge.
    pub fn quantile(&self, q: f64) -> std::time::Duration {
        let total = self.count();
        if total == 0 {
            return std::time::Duration::ZERO;
        }
        let max_us = self.max_us.load(Ordering::Relaxed);
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                let upper = 1u64 << (i + 1);
                return std::time::Duration::from_micros(upper.min(max_us));
            }
        }
        self.max()
    }

    /// Fold another histogram into this one (bucket-wise sums, max of
    /// maxima) — the fleet-wide metrics roll-up.
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_us.fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// Shared metric set for the tracking service.
#[derive(Debug, Default)]
pub struct Metrics {
    pub events_ingested: Counter,
    pub batches_applied: Counter,
    /// Tracker updates that returned an error; the batch stays pending
    /// and is retried at the next flush (never silently dropped).
    pub update_failures: Counter,
    pub nodes_added: Counter,
    /// Queries answered from the version-keyed memo cache (including
    /// readers that waited on another reader's in-flight computation).
    pub queries_cached: Counter,
    /// Queries that actually computed their derived result.
    pub queries_computed: Counter,
    /// Tracker-reported FLOPs charged at each applied batch (the fleet's
    /// per-tenant compute-budget ledger).
    pub flops_applied: Counter,
    /// Applied batches whose FLOP cost exceeded the tenant's
    /// [`crate::coordinator::tenant::TenantBudget::max_flops_per_flush`].
    pub flop_budget_overruns: Counter,
    /// Estimated resident bytes (committed CSR + published eigenpairs +
    /// id map) as of the last flush; a gauge per tenant, a sum across a
    /// fleet roll-up.
    pub resident_bytes: Counter,
    /// Flushes that left the tenant above its
    /// [`crate::coordinator::tenant::TenantBudget::max_resident_bytes`].
    pub mem_budget_overruns: Counter,
    pub update_latency: Histogram,
    /// Latency of *pure* cache hits (should sit orders of magnitude
    /// below `query_latency_computed` — the read-storm contract).
    pub query_latency_cached: Histogram,
    /// Latency of queries that computed their result from the snapshot,
    /// plus readers that blocked on such an in-flight compute (their
    /// wait is compute-shaped even though they count as cached).
    pub query_latency_computed: Histogram,
    /// Events frames appended to the WAL (one per non-empty `Events`
    /// command, not per event).
    pub wal_appends: Counter,
    /// Framed bytes appended to the WAL (events + commit frames).
    pub wal_bytes: Counter,
    /// WAL append/fsync operations that failed.  Event-sync failures
    /// abort the flush (the batch retries); commit-frame failures are
    /// tolerated and the frame retries at the next group fsync.
    pub wal_failures: Counter,
    /// Checkpoints written (and the covered WAL prefix truncated).
    pub checkpoints_written: Counter,
    /// Checkpoint attempts that failed (tracker can't save, or the
    /// store/truncate I/O failed); the tenant keeps running off the WAL.
    pub checkpoint_failures: Counter,
    /// Successful crash recoveries (checkpoint load + WAL replay).
    pub recoveries: Counter,
    /// WAL frames replayed during recovery.
    pub replayed_frames: Counter,
    /// Events re-ingested from replayed frames during recovery.
    pub replayed_events: Counter,
    /// Torn-tail bytes discarded when opening the WAL (an interrupted
    /// final write; anything *interior* is corruption and fails loudly
    /// instead of counting here).
    pub wal_truncated_bytes: Counter,
    /// Group-fsync latency at flush boundaries (events + commit frames).
    pub fsync_latency: Histogram,
}

impl Metrics {
    pub fn new() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    /// Fraction of queries served from the memo cache (0 when no
    /// queries ran yet).
    pub fn query_cache_hit_rate(&self) -> f64 {
        let cached = self.queries_cached.get() as f64;
        let total = cached + self.queries_computed.get() as f64;
        if total == 0.0 {
            0.0
        } else {
            cached / total
        }
    }

    /// Fold another metric set into this one: counters sum, histograms
    /// merge bucket-wise.  `resident_bytes` gauges also sum — across a
    /// fleet that is the aggregate resident footprint.
    pub fn merge_from(&self, other: &Metrics) {
        let add = |dst: &Counter, src: &Counter| {
            let v = src.get();
            if v > 0 {
                dst.add(v);
            }
        };
        add(&self.events_ingested, &other.events_ingested);
        add(&self.batches_applied, &other.batches_applied);
        add(&self.update_failures, &other.update_failures);
        add(&self.nodes_added, &other.nodes_added);
        add(&self.queries_cached, &other.queries_cached);
        add(&self.queries_computed, &other.queries_computed);
        add(&self.flops_applied, &other.flops_applied);
        add(&self.flop_budget_overruns, &other.flop_budget_overruns);
        add(&self.resident_bytes, &other.resident_bytes);
        add(&self.mem_budget_overruns, &other.mem_budget_overruns);
        add(&self.wal_appends, &other.wal_appends);
        add(&self.wal_bytes, &other.wal_bytes);
        add(&self.wal_failures, &other.wal_failures);
        add(&self.checkpoints_written, &other.checkpoints_written);
        add(&self.checkpoint_failures, &other.checkpoint_failures);
        add(&self.recoveries, &other.recoveries);
        add(&self.replayed_frames, &other.replayed_frames);
        add(&self.replayed_events, &other.replayed_events);
        add(&self.wal_truncated_bytes, &other.wal_truncated_bytes);
        self.update_latency.merge(&other.update_latency);
        self.query_latency_cached.merge(&other.query_latency_cached);
        self.query_latency_computed.merge(&other.query_latency_computed);
        self.fsync_latency.merge(&other.fsync_latency);
    }

    pub fn report(&self) -> String {
        format!(
            "events={} batches={} update_failures={} nodes_added={} update_mean={:?} \
             update_p99={:?} update_max={:?} queries_computed={} queries_cached={} \
             hit_rate={:.1}% q_computed_mean={:?} q_cached_mean={:?} flops={} \
             resident_bytes={} budget_overruns={}/{} wal_bytes={} wal_failures={} \
             fsync_p99={:?} checkpoints={}/{} recoveries={} replayed_frames={}",
            self.events_ingested.get(),
            self.batches_applied.get(),
            self.update_failures.get(),
            self.nodes_added.get(),
            self.update_latency.mean(),
            self.update_latency.quantile(0.99),
            self.update_latency.max(),
            self.queries_computed.get(),
            self.queries_cached.get(),
            100.0 * self.query_cache_hit_rate(),
            self.query_latency_computed.mean(),
            self.query_latency_cached.mean(),
            self.flops_applied.get(),
            self.resident_bytes.get(),
            self.flop_budget_overruns.get(),
            self.mem_budget_overruns.get(),
            self.wal_bytes.get(),
            self.wal_failures.get(),
            self.fsync_latency.quantile(0.99),
            self.checkpoints_written.get(),
            self.checkpoint_failures.get(),
            self.recoveries.get(),
            self.replayed_frames.get(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn histogram_mean_and_count() {
        let h = Histogram::new();
        h.observe(Duration::from_micros(100));
        h.observe(Duration::from_micros(300));
        assert_eq!(h.count(), 2);
        let m = h.mean().as_micros();
        assert_eq!(m, 200);
        assert_eq!(h.max().as_micros(), 300);
    }

    #[test]
    fn counter_ops() {
        let c = Counter::new();
        assert_eq!(c.get(), 0);
        c.incr();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.set(2);
        assert_eq!(c.get(), 2);
        c.max_with(10);
        c.max_with(7);
        assert_eq!(c.get(), 10);
    }

    #[test]
    fn quantile_monotone() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.observe(Duration::from_micros(i));
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p99.as_micros() >= 512);
    }

    #[test]
    fn quantile_never_exceeds_observed_max() {
        // regression: quantile() returned the bucket's upper bound
        // unconditionally, reporting p99 > max() — a single 300µs sample
        // landed in bucket [256, 512) and reported 512µs
        let h = Histogram::new();
        h.observe(Duration::from_micros(300));
        assert_eq!(h.quantile(0.99), Duration::from_micros(300));
        assert_eq!(h.quantile(0.99), h.max());
        // and over an arbitrary sample set the invariant holds at every q
        let h = Histogram::new();
        for us in [3u64, 17, 100, 999, 5000, 77_777] {
            h.observe(Duration::from_micros(us));
        }
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            assert!(h.quantile(q) <= h.max(), "q={q}: {:?} > {:?}", h.quantile(q), h.max());
        }
    }

    #[test]
    fn histogram_merge_sums_counts_and_keeps_max() {
        let a = Histogram::new();
        let b = Histogram::new();
        for i in 1..=100u64 {
            a.observe(Duration::from_micros(i));
            b.observe(Duration::from_micros(10 * i));
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.max(), Duration::from_micros(1000));
        // mean of 1..=100 plus 10..=1000 step 10 = (5050 + 50500) / 200
        assert_eq!(a.mean(), Duration::from_micros(55550 / 200));
        assert!(a.quantile(0.99) <= a.max());
    }

    #[test]
    fn metrics_merge_from_sums_counters_and_histograms() {
        let a = Metrics::default();
        let b = Metrics::default();
        a.events_ingested.add(3);
        b.events_ingested.add(4);
        b.update_failures.add(2);
        b.flops_applied.add(1000);
        a.resident_bytes.set(10);
        b.resident_bytes.set(32);
        a.update_latency.observe(Duration::from_micros(50));
        b.update_latency.observe(Duration::from_micros(70));
        a.merge_from(&b);
        assert_eq!(a.events_ingested.get(), 7);
        assert_eq!(a.update_failures.get(), 2);
        assert_eq!(a.flops_applied.get(), 1000);
        assert_eq!(a.resident_bytes.get(), 42);
        assert_eq!(a.update_latency.count(), 2);
        assert_eq!(a.update_latency.max(), Duration::from_micros(70));
    }

    #[test]
    fn query_cache_hit_rate_counters() {
        let m = Metrics::default();
        assert_eq!(m.query_cache_hit_rate(), 0.0);
        m.queries_computed.incr();
        m.queries_cached.add(3);
        assert!((m.query_cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!(m.report().contains("hit_rate=75.0%"), "{}", m.report());
    }

    #[test]
    fn concurrent_observe() {
        let h = Arc::new(Histogram::new());
        let mut handles = vec![];
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.observe(Duration::from_micros((t * 1000 + i) as u64 + 1));
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
