//! Lightweight metrics: counters and log-bucketed latency histograms.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Log₂-bucketed duration histogram (1µs … ~1000s).
#[derive(Debug, Default)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^{i+1}) microseconds
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn observe(&self, d: std::time::Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> std::time::Duration {
        let c = self.count();
        if c == 0 {
            return std::time::Duration::ZERO;
        }
        std::time::Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> std::time::Duration {
        std::time::Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile(&self, q: f64) -> std::time::Duration {
        let total = self.count();
        if total == 0 {
            return std::time::Duration::ZERO;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return std::time::Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// Shared metric set for the tracking service.
#[derive(Debug, Default)]
pub struct Metrics {
    pub events_ingested: AtomicU64,
    pub batches_applied: AtomicU64,
    /// Tracker updates that returned an error; the batch stays pending
    /// and is retried at the next flush (never silently dropped).
    pub update_failures: AtomicU64,
    pub nodes_added: AtomicU64,
    /// Queries answered from the version-keyed memo cache (including
    /// readers that waited on another reader's in-flight computation).
    pub queries_cached: AtomicU64,
    /// Queries that actually computed their derived result.
    pub queries_computed: AtomicU64,
    pub update_latency: Histogram,
    /// Latency of *pure* cache hits (should sit orders of magnitude
    /// below `query_latency_computed` — the read-storm contract).
    pub query_latency_cached: Histogram,
    /// Latency of queries that computed their result from the snapshot,
    /// plus readers that blocked on such an in-flight compute (their
    /// wait is compute-shaped even though they count as cached).
    pub query_latency_computed: Histogram,
}

impl Metrics {
    pub fn new() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    /// Fraction of queries served from the memo cache (0 when no
    /// queries ran yet).
    pub fn query_cache_hit_rate(&self) -> f64 {
        let cached = self.queries_cached.load(Ordering::Relaxed) as f64;
        let total = cached + self.queries_computed.load(Ordering::Relaxed) as f64;
        if total == 0.0 {
            0.0
        } else {
            cached / total
        }
    }

    pub fn report(&self) -> String {
        format!(
            "events={} batches={} update_failures={} nodes_added={} update_mean={:?} update_p99={:?} update_max={:?} queries_computed={} queries_cached={} hit_rate={:.1}% q_computed_mean={:?} q_cached_mean={:?}",
            self.events_ingested.load(Ordering::Relaxed),
            self.batches_applied.load(Ordering::Relaxed),
            self.update_failures.load(Ordering::Relaxed),
            self.nodes_added.load(Ordering::Relaxed),
            self.update_latency.mean(),
            self.update_latency.quantile(0.99),
            self.update_latency.max(),
            self.queries_computed.load(Ordering::Relaxed),
            self.queries_cached.load(Ordering::Relaxed),
            100.0 * self.query_cache_hit_rate(),
            self.query_latency_computed.mean(),
            self.query_latency_cached.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn histogram_mean_and_count() {
        let h = Histogram::new();
        h.observe(Duration::from_micros(100));
        h.observe(Duration::from_micros(300));
        assert_eq!(h.count(), 2);
        let m = h.mean().as_micros();
        assert_eq!(m, 200);
        assert_eq!(h.max().as_micros(), 300);
    }

    #[test]
    fn quantile_monotone() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.observe(Duration::from_micros(i));
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p99.as_micros() >= 512);
    }

    #[test]
    fn query_cache_hit_rate_counters() {
        let m = Metrics::default();
        assert_eq!(m.query_cache_hit_rate(), 0.0);
        m.queries_computed.fetch_add(1, Ordering::Relaxed);
        m.queries_cached.fetch_add(3, Ordering::Relaxed);
        assert!((m.query_cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!(m.report().contains("hit_rate=75.0%"), "{}", m.report());
    }

    #[test]
    fn concurrent_observe() {
        let h = Arc::new(Histogram::new());
        let mut handles = vec![];
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.observe(Duration::from_micros((t * 1000 + i) as u64 + 1));
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
