//! L3 coordinator: a streaming embedding-tracking service.
//!
//! Edge events flow in; a batching policy groups them into time steps; a
//! dedicated worker thread applies each batch to the configured tracker
//! (native or PJRT-backed — the PJRT client is thread-bound, which is
//! exactly why the tracker lives on one worker thread); versioned
//! snapshots of the embedding — eigenpairs plus the frozen
//! internal↔external id map — are published for lock-cheap concurrent
//! reads; every derived query (centrality, clustering, embeddings,
//! similarity) is answered off-worker by the [`query::QueryEngine`]
//! with a version-keyed memo cache; metrics record ingest/update
//! latencies and cached/computed query counts.

pub mod batcher;
pub mod metrics;
pub mod query;
pub mod service;
pub mod snapshot;

pub use batcher::BatchPolicy;
pub use query::{ClusterAssignment, QueryEngine};
pub use service::{ServiceConfig, ServiceHandle, TrackingService};
pub use snapshot::EmbeddingSnapshot;
