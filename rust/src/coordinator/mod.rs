//! L3 coordinator: a streaming embedding-tracking service, multi-tenant
//! on a shared worker pool.
//!
//! Edge events flow in; a batching policy ([`batcher::BatchPolicy`] —
//! count pressure and/or a `max_age` staleness deadline) groups them
//! into time steps; each tenant is a resumable state machine
//! ([`tenant::TenantState`]) stepped by a fixed pool of workers
//! ([`pool::WorkerPool`]) — fair round-robin, at most one worker per
//! tenant, deadline wakeups for idle tenants.  `@xla` tenants are the
//! exception: the PJRT client is thread-bound, so they run pinned to a
//! dedicated thread driving the same state machine.
//!
//! Versioned snapshots of the embedding — eigenpairs plus the frozen
//! internal↔external id map — are published for lock-cheap concurrent
//! reads; every derived query (centrality, clustering, embeddings,
//! similarity) is answered off-worker by the [`query::QueryEngine`]
//! with a version-keyed memo cache; metrics record ingest/update
//! latencies, cached/computed query counts, and per-tenant flop/memory
//! budget accounting, with a fleet-wide roll-up.
//!
//! Single-tenant callers use the [`service::TrackingService`] facade;
//! multi-tenant callers manage [`fleet::TenantId`]-keyed tenants
//! through a [`fleet::Fleet`].

pub mod batcher;
pub mod durability;
pub mod fleet;
pub mod memo_core;
pub mod metrics;
pub mod pool;
pub mod pool_core;
pub mod query;
pub mod service;
pub mod snapshot;
pub mod tenant;

pub use batcher::BatchPolicy;
pub use durability::{DurabilityConfig, DurabilityError};
pub use fleet::{Fleet, FleetConfig, TenantId};
pub use pool::WorkerPool;
pub use pool_core::{Stepper, SubmitError};
pub use query::{ClusterAssignment, QueryEngine};
pub use service::{ConfigError, ServiceConfig, ServiceHandle, TrackingService};
pub use snapshot::{EmbeddingSnapshot, PublishStamp};
pub use tenant::TenantBudget;
