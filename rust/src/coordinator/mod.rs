//! L3 coordinator: a streaming embedding-tracking service.
//!
//! Edge events flow in; a batching policy groups them into time steps; a
//! dedicated worker thread applies each batch to the configured tracker
//! (native or PJRT-backed — the PJRT client is thread-bound, which is
//! exactly why the tracker lives on one worker thread); versioned
//! snapshots of the embedding are published for lock-cheap concurrent
//! reads; metrics record ingest/update latencies.

pub mod batcher;
pub mod metrics;
pub mod service;
pub mod snapshot;

pub use batcher::BatchPolicy;
pub use service::{ServiceConfig, ServiceHandle, TrackingService};
pub use snapshot::EmbeddingSnapshot;
