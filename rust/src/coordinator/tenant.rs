//! The per-tenant state machine: everything the old per-service worker
//! thread owned — graph, committed CSR, tracker, pending batch — packed
//! into a [`TenantState`] value with a resumable [`step`]
//! (TenantState::step).
//!
//! Extracting the state from the thread is what makes the fleet
//! possible: a worker-pool thread can pick up any runnable tenant, run
//! one `step` (drain queued commands, at most one flush), and put it
//! back.  The pinned-thread path for `@xla` tenants drives the *same*
//! state machine from a dedicated thread, so pooled and pinned runs are
//! bitwise identical given identical command sequences.
//!
//! `TenantState` is generic over the tracker's sizedness: the pool
//! stores `TenantState<dyn EigTracker + Send>` (trackers hop between
//! worker threads), the pinned path `TenantState<dyn EigTracker>`
//! (PJRT state never leaves its thread).

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::durability::checkpoint::Checkpoint;
use crate::coordinator::durability::wal::{Frame, FramePayload};
use crate::coordinator::durability::{DurabilityError, TenantDurability};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool_core::Stepper;
use crate::coordinator::snapshot::{EmbeddingSnapshot, PublishStamp, SnapshotStore};
use crate::graph::stream::{DeltaBuilder, GraphEvent};
use crate::sparse::csr::Csr;
use crate::sync::mpsc::Sender;
use crate::sync::{Arc, Mutex};
use crate::tracking::traits::EigTracker;
use std::collections::VecDeque;
use std::time::Instant;

// The step-outcome vocabulary lives in the model-checked scheduler
// core; re-exported here so tenant-facing code keeps one import path.
pub use crate::coordinator::pool_core::{StepOutcome, StopAck};

/// A command queued into a tenant's inbox.  Mirrors the old private
/// service `Command`, with `Shutdown` carrying an ack so joiners can
/// wait for the tenant to actually retire.
pub enum TenantCmd {
    /// Ingest events (the policy decides whether to flush).
    Events(Vec<GraphEvent>),
    /// Force a flush; replies with the published snapshot version.
    Flush(Sender<u64>),
    /// Reply with a clone of the committed adjacency.
    Adjacency(Sender<Csr>),
    /// Retire the tenant; the ack fires once no worker will touch it.
    Shutdown(Sender<()>),
}

/// What applying one command did.
pub enum Applied {
    /// Keep draining the inbox.
    Continue,
    /// A flush ran — yield so one step never runs two dense phases.
    Flushed,
    /// Shutdown was requested; the caller owns the ack.
    Stopped(Sender<()>),
}

/// Per-tenant resource budget.  Soft limits: overruns are *counted*
/// (surfaced through [`Metrics`]) rather than enforced, so a fleet
/// operator can find noisy tenants without the coordinator refusing
/// work mid-stream.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantBudget {
    /// Tracker-reported FLOPs one applied batch may cost before it
    /// counts as a [`Metrics::flop_budget_overruns`] overrun.
    pub max_flops_per_flush: Option<u64>,
    /// Estimated resident bytes (committed CSR + published pairs + id
    /// map) the tenant may hold before each flush counts as a
    /// [`Metrics::mem_budget_overruns`] overrun.
    pub max_resident_bytes: Option<u64>,
}

/// The state machine.  `T` is `dyn EigTracker + Send` on the pool and
/// `dyn EigTracker` on a pinned thread; the unsized field must be last.
pub struct TenantState<T: ?Sized + EigTracker = dyn EigTracker + Send> {
    builder: DeltaBuilder,
    adjacency: Csr,
    policy: BatchPolicy,
    store: SnapshotStore,
    metrics: Arc<Metrics>,
    budget: TenantBudget,
    version: u64,
    /// When the oldest event of the current pending batch arrived;
    /// `None` while the batch is empty.  A failed flush re-arms it to
    /// "now" so a broken tracker under a `max_age` policy retries at
    /// the deadline cadence instead of hot-spinning.
    pending_since: Option<Instant>,
    /// WAL + checkpoint sink; `None` runs the tenant purely in memory.
    /// Attached *after* recovery replay, so replayed flushes never
    /// re-log the frames they came from.
    durability: Option<TenantDurability>,
    tracker: Box<T>,
}

impl<T: ?Sized + EigTracker> TenantState<T> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        tracker: Box<T>,
        builder: DeltaBuilder,
        adjacency: Csr,
        policy: BatchPolicy,
        store: SnapshotStore,
        metrics: Arc<Metrics>,
        budget: TenantBudget,
    ) -> TenantState<T> {
        TenantState {
            builder,
            adjacency,
            policy,
            store,
            metrics,
            budget,
            version: 0,
            pending_since: None,
            durability: None,
            tracker,
        }
    }

    /// Last published snapshot version.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Attach the WAL + checkpoint sink.  Must happen *after*
    /// [`replay`](TenantState::replay) during recovery — a replayed
    /// flush with durability attached would append the frames it is
    /// replaying back onto the log.
    pub fn attach_durability(&mut self, d: TenantDurability) {
        self.durability = Some(d);
    }

    /// Overwrite the snapshot version counter.  Recovery uses this to
    /// resume numbering from the checkpointed version before replaying
    /// the WAL tail; published versions stay monotone across the crash.
    pub fn restore_version(&mut self, version: u64) {
        self.version = version;
    }

    /// Apply one command.
    pub fn apply(&mut self, cmd: TenantCmd) -> Applied {
        match cmd {
            TenantCmd::Events(events) => {
                // Log the batch as *received* (self-loops and all):
                // replay pushes the identical sequence through the same
                // builder path, so the pending counters — and therefore
                // the policy decisions — reproduce exactly.
                if let Some(d) = self.durability.as_mut() {
                    if !events.is_empty() {
                        let bytes = d.log_events(&events);
                        self.metrics.wal_appends.incr();
                        self.metrics.wal_bytes.add(bytes);
                    }
                }
                for ev in events {
                    self.builder.push(ev);
                }
                let (events, new_nodes) =
                    (self.builder.pending_events(), self.builder.pending_new_nodes());
                if (events > 0 || new_nodes > 0) && self.pending_since.is_none() {
                    self.pending_since = Some(Instant::now());
                }
                if self.policy.should_flush(events, new_nodes) {
                    self.flush();
                    Applied::Flushed
                } else {
                    Applied::Continue
                }
            }
            TenantCmd::Flush(reply) => {
                self.flush();
                let _ = reply.send(self.version);
                Applied::Flushed
            }
            TenantCmd::Adjacency(reply) => {
                let _ = reply.send(self.adjacency.clone());
                Applied::Continue
            }
            TenantCmd::Shutdown(ack) => Applied::Stopped(ack),
        }
    }

    /// Close the pending batch and run one tracker update.  On error
    /// the batch stays pending (retried at the next flush); on success
    /// the committed CSR advances by row-merge and a new snapshot
    /// publishes.
    pub fn flush(&mut self) {
        // Log-before-flush: every event frame of this batch must be
        // durable before the tracker consumes it.  A failed fsync
        // aborts the flush — the batch stays pending and retries at
        // the deadline cadence — so published state never runs ahead
        // of the log.
        if !self.sync_wal_events() {
            return;
        }
        match self.builder.prepare() {
            // batch netted out to no change: drop the pending events,
            // committed state is already consistent — but the commit
            // frame still goes down so replay reproduces the boundary
            None => {
                self.builder.commit();
                self.pending_since = None;
                self.log_commit_frame();
            }
            Some(delta) => {
                let t0 = Instant::now();
                match self.tracker.update(&delta) {
                    Ok(()) => {
                        // commit builder + adjacency only after the
                        // tracker accepted the batch, so a failure
                        // never leaves them diverged from the tracker
                        self.builder.commit();
                        self.pending_since = None;
                        let m = &self.metrics;
                        m.nodes_added.add(delta.s_new as u64);
                        m.update_latency.observe(t0.elapsed());
                        m.batches_applied.incr();
                        // incremental row-merge: only rows touched by
                        // Δ are rewritten, never a full rebuild
                        self.adjacency = self.adjacency.apply_delta(&delta);
                        self.charge_budget();
                        self.version += 1;
                        self.log_commit_frame();
                        let stamp = PublishStamp::now();
                        self.store.publish(EmbeddingSnapshot {
                            version: self.version,
                            n_nodes: self.adjacency.n_rows,
                            pairs: self.tracker.current().clone(),
                            // O(1): Arc clone, copy-on-write at commit
                            ids: self.builder.committed_ids(),
                            published_at: stamp,
                        });
                        self.maybe_checkpoint(stamp.wall_us());
                    }
                    Err(_) => {
                        // batch stays pending; the next flush retries
                        // the accumulated delta against the same
                        // committed state.  No commit frame: replay
                        // will fold this batch into the next
                        // successful flush, exactly as the live run
                        // did.
                        self.metrics.update_failures.incr();
                        if self.pending_since.is_some() {
                            self.pending_since = Some(Instant::now());
                        }
                    }
                }
            }
        }
    }

    /// Fsync any buffered WAL frames (this batch's events, plus a
    /// commit frame left over from an earlier failed sync).  Returns
    /// `false` — aborting the flush — when the log could not be made
    /// durable.
    fn sync_wal_events(&mut self) -> bool {
        let Some(d) = self.durability.as_mut() else { return true };
        if !d.has_buffered() {
            return true;
        }
        let t0 = Instant::now();
        match d.sync_events() {
            Ok(()) => {
                self.metrics.fsync_latency.observe(t0.elapsed());
                true
            }
            Err(_) => {
                self.metrics.wal_failures.incr();
                if self.pending_since.is_some() {
                    self.pending_since = Some(Instant::now());
                }
                false
            }
        }
    }

    /// Append + sync this flush's commit frame.  Failure is counted
    /// but does not block the publish: the published state is
    /// re-derivable from the already-durable event frames, and the
    /// buffered frame retries at the next flush's sync.
    fn log_commit_frame(&mut self) {
        let Some(d) = self.durability.as_mut() else { return };
        let t0 = Instant::now();
        match d.log_commit(self.version) {
            Ok(bytes) => {
                self.metrics.fsync_latency.observe(t0.elapsed());
                self.metrics.wal_bytes.add(bytes);
            }
            Err(_) => self.metrics.wal_failures.incr(),
        }
    }

    /// Write a checkpoint when the cadence says so.  Failures are
    /// counted and the tenant keeps running off the WAL alone.
    fn maybe_checkpoint(&mut self, wall_us: u64) {
        let due = match self.durability.as_mut() {
            Some(d) => d.due_for_checkpoint(),
            None => false,
        };
        if !due {
            return;
        }
        let tracker_state = match self.tracker.save_state() {
            Ok(st) => st,
            Err(_) => {
                // tracker can't checkpoint: count it and keep running
                // off the WAL alone
                self.metrics.checkpoint_failures.incr();
                return;
            }
        };
        let ckpt = Checkpoint {
            next_seq: match self.durability.as_ref() {
                Some(d) => d.wal_next_seq(),
                None => return,
            },
            version: self.version,
            wall_us,
            pairs: self.tracker.current().clone(),
            ids: self.builder.committed_ids().externals().to_vec(),
            adjacency: self.adjacency.clone(),
            tracker: tracker_state,
        };
        let Some(d) = self.durability.as_mut() else { return };
        match d.record_checkpoint(&ckpt) {
            Ok(()) => self.metrics.checkpoints_written.incr(),
            Err(_) => self.metrics.checkpoint_failures.incr(),
        }
    }

    /// Push events into the pending batch without logging or policy
    /// checks — recovery's replay path.
    fn ingest_replayed(&mut self, events: &[GraphEvent]) {
        for &ev in events {
            self.builder.push(ev);
        }
        let (n_ev, new_nodes) =
            (self.builder.pending_events(), self.builder.pending_new_nodes());
        if (n_ev > 0 || new_nodes > 0) && self.pending_since.is_none() {
            self.pending_since = Some(Instant::now());
        }
    }

    /// Re-drive the WAL tail through the normal flush path.  Events
    /// frames refill the pending batch; each commit frame closes it
    /// with a flush and cross-checks the resulting version against the
    /// one the frame recorded — any divergence is a loud
    /// [`DurabilityError::ReplayMismatch`], never a silent drift.
    ///
    /// Call *before* [`attach_durability`](TenantState::attach_durability):
    /// a replayed flush with durability attached would append the very
    /// frames it is replaying back onto the log.
    pub fn replay(&mut self, frames: &[Frame]) -> Result<(), DurabilityError> {
        for f in frames {
            match &f.payload {
                FramePayload::Events(events) => {
                    self.metrics.replayed_events.add(events.len() as u64);
                    self.ingest_replayed(events);
                }
                FramePayload::Commit { version } => {
                    self.flush();
                    if self.version != *version {
                        return Err(DurabilityError::ReplayMismatch {
                            seq: f.seq,
                            expected: *version,
                            got: self.version,
                        });
                    }
                }
            }
            self.metrics.replayed_frames.incr();
        }
        Ok(())
    }

    /// Charge the just-applied batch against the tenant's budget.
    fn charge_budget(&self) {
        let flops = self.tracker.last_step_flops();
        self.metrics.flops_applied.add(flops);
        if self.budget.max_flops_per_flush.is_some_and(|cap| flops > cap) {
            self.metrics.flop_budget_overruns.incr();
        }
        let resident = self.resident_bytes();
        self.metrics.resident_bytes.set(resident);
        if self.budget.max_resident_bytes.is_some_and(|cap| resident > cap) {
            self.metrics.mem_budget_overruns.incr();
        }
    }

    /// Estimated resident footprint: committed CSR arrays, tracked
    /// eigenpairs, and the id map (external array + intern table).
    pub fn resident_bytes(&self) -> u64 {
        let usz = std::mem::size_of::<usize>() as u64;
        let csr = (self.adjacency.indptr.len() as u64 + self.adjacency.indices.len() as u64) * usz
            + self.adjacency.data.len() as u64 * 8;
        let pairs = self.tracker.current();
        let eig = (pairs.n() as u64 * pairs.k() as u64 + pairs.k() as u64) * 8;
        let ids = self.builder.committed_ids().len() as u64 * 3 * usz;
        csr + eig + ids
    }

    /// Flush if the pending batch has outlived the policy's `max_age`
    /// deadline (the scheduler calls this on timer wakeups).
    pub fn poll_deadline(&mut self, now: Instant) {
        if let Some(since) = self.pending_since {
            let age = now.duration_since(since);
            let (events, new_nodes) =
                (self.builder.pending_events(), self.builder.pending_new_nodes());
            if self.policy.should_flush_aged(events, new_nodes, age) {
                self.flush();
            }
        }
    }

    /// When the scheduler must next wake this tenant with no new input:
    /// the pending batch's deadline, if the policy has a `max_age` arm
    /// and a batch is pending.
    pub fn next_deadline(&self) -> Option<Instant> {
        Some(self.pending_since? + self.policy.max_age()?)
    }

    /// One schedulable unit of work: drain the inbox (stopping after at
    /// most one flush so a busy tenant cannot monopolize a pool worker)
    /// and report how the scheduler should treat this tenant next.
    pub fn step(&mut self, inbox: &Mutex<VecDeque<TenantCmd>>) -> StepOutcome {
        let mut flushed = false;
        loop {
            let cmd = inbox.lock().pop_front();
            let Some(cmd) = cmd else { break };
            match self.apply(cmd) {
                Applied::Continue => {}
                Applied::Flushed => {
                    flushed = true;
                    break;
                }
                Applied::Stopped(ack) => {
                    return StepOutcome::Stopped(Box::new(move || {
                        let _ = ack.send(());
                    }));
                }
            }
        }
        if !flushed {
            self.poll_deadline(Instant::now());
        }
        match self.next_deadline() {
            Some(at) => StepOutcome::WaitUntil(at),
            None => StepOutcome::Idle,
        }
    }

    /// An armed `max_age` deadline will never fire (the pool is
    /// shutting down): close the pending batch now rather than strand
    /// it.  No-op when nothing is pending.
    pub fn drain_deadline(&mut self) {
        if self.pending_since.is_some() {
            self.flush();
        }
    }
}

impl Stepper for TenantState {
    type Cmd = TenantCmd;

    fn step(&mut self, inbox: &Mutex<VecDeque<TenantCmd>>) -> StepOutcome {
        TenantState::step(self, inbox)
    }

    fn drain_deadline(&mut self) {
        TenantState::drain_deadline(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stream::IdMap;
    use crate::linalg::rng::Rng;
    use crate::linalg::threads::Threads;
    use crate::tracking::spec::TrackerSpec;
    use std::time::Duration;

    fn make_state(policy: BatchPolicy) -> (TenantState, SnapshotStore, Arc<Metrics>) {
        let mut rng = Rng::new(5);
        let g = crate::graph::generators::erdos_renyi(30, 0.1, &mut rng);
        let a0 = g.adjacency();
        let init = crate::tracking::traits::init_eigenpairs(&a0, 3, 1);
        let tracker = TrackerSpec::default().build_seeded_send(&a0, &init, 1).unwrap();
        let store = SnapshotStore::new(EmbeddingSnapshot {
            version: 0,
            n_nodes: a0.n_rows,
            pairs: init,
            ids: Arc::new(IdMap::identity(a0.n_rows)),
            published_at: PublishStamp::now(),
        });
        let metrics = Metrics::new();
        let state = TenantState::new(
            tracker,
            DeltaBuilder::from_graph(g),
            a0,
            policy,
            store.clone(),
            metrics.clone(),
            TenantBudget::default(),
        );
        (state, store, metrics)
    }

    #[test]
    fn step_drains_inbox_and_flushes_on_count() {
        let (mut state, store, _) = make_state(BatchPolicy::ByCount(2));
        let inbox = Mutex::new(VecDeque::new());
        inbox.lock().push_back(TenantCmd::Events(vec![
            GraphEvent::AddEdge(0, 500),
            GraphEvent::AddEdge(1, 501),
        ]));
        match state.step(&inbox) {
            StepOutcome::Idle => {}
            _ => panic!("count policy leaves no deadline"),
        }
        assert_eq!(state.version(), 1);
        assert_eq!(store.latest().version, 1);
        assert!(store.latest().n_nodes > 30);
    }

    #[test]
    fn step_reports_deadline_for_aged_policy() {
        let (mut state, store, _) = make_state(BatchPolicy::MaxAge(Duration::from_secs(3600)));
        let inbox = Mutex::new(VecDeque::new());
        inbox.lock().push_back(TenantCmd::Events(vec![GraphEvent::AddEdge(0, 900)]));
        let armed_at = Instant::now();
        match state.step(&inbox) {
            StepOutcome::WaitUntil(at) => {
                let lead = at.duration_since(armed_at);
                assert!(lead <= Duration::from_secs(3600));
                assert!(lead > Duration::from_secs(3500));
            }
            _ => panic!("pending batch under MaxAge must arm a deadline"),
        }
        // nothing published yet: the deadline, not counts, closes it
        assert_eq!(store.latest().version, 0);
        // once past the deadline, poll_deadline flushes
        state.poll_deadline(armed_at + Duration::from_secs(3601));
        assert_eq!(state.version(), 1);
        assert!(state.next_deadline().is_none());
    }

    #[test]
    fn budget_overruns_are_counted_not_enforced() {
        let mut rng = Rng::new(5);
        let g = crate::graph::generators::erdos_renyi(30, 0.1, &mut rng);
        let a0 = g.adjacency();
        let init = crate::tracking::traits::init_eigenpairs(&a0, 3, 1);
        let spec = TrackerSpec::default().with_threads(Threads::SINGLE);
        let tracker = spec.build_seeded_send(&a0, &init, 1).unwrap();
        let store = SnapshotStore::new(EmbeddingSnapshot {
            version: 0,
            n_nodes: a0.n_rows,
            pairs: init,
            ids: Arc::new(IdMap::identity(a0.n_rows)),
            published_at: PublishStamp::now(),
        });
        let metrics = Metrics::new();
        let mut state = TenantState::new(
            tracker,
            DeltaBuilder::from_graph(g),
            a0,
            BatchPolicy::ByCount(1),
            store,
            metrics.clone(),
            // caps of 1 flop / 1 byte: every flush overruns both
            TenantBudget { max_flops_per_flush: Some(1), max_resident_bytes: Some(1) },
        );
        let inbox = Mutex::new(VecDeque::new());
        inbox.lock().push_back(TenantCmd::Events(vec![GraphEvent::AddEdge(0, 900)]));
        state.step(&inbox);
        assert_eq!(state.version(), 1, "soft budgets never block the flush");
        assert_eq!(metrics.flop_budget_overruns.get(), 1);
        assert_eq!(metrics.mem_budget_overruns.get(), 1);
        assert!(metrics.flops_applied.get() > 0);
        assert!(metrics.resident_bytes.get() > 0);
    }
}
