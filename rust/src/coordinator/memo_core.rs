//! The one-in-flight-compute memo cell behind the `QueryEngine` cache,
//! extracted so it can be model-checked.
//!
//! Like `pool_core`, this module imports only [`crate::sync`] and std
//! collections; the `rust/loom-model` crate `#[path]`-includes this
//! source and proves under exhaustive interleaving that two concurrent
//! [`Memo::get_or_compute`] calls for the same key run the compute
//! closure exactly once.  Keep it dependency-free.

use crate::sync::{Arc, Mutex, OnceSlot};
use std::collections::HashMap;
use std::hash::Hash;

/// How a [`Memo::get_or_compute`] call was answered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemoHow {
    /// The slot was already filled: a pure cache hit.
    Hit,
    /// This caller ran the compute closure.
    Computed,
    /// Another caller's in-flight compute was joined: nothing was
    /// recomputed, but the wait was compute-shaped.
    Waited,
}

/// A cache slot: concurrent first readers share one in-flight
/// computation through the [`OnceSlot`] instead of recomputing.
type Slot<V> = Arc<OnceSlot<V>>;

struct MemoMap<K, V> {
    map: HashMap<K, (u64, Slot<V>)>,
    tick: u64,
    cap: usize,
}

impl<K: Eq + Hash + Clone, V: Clone> MemoMap<K, V> {
    /// Fetch the slot for `key`, creating it if absent and evicting the
    /// least-recently-used slot beyond capacity.
    fn slot(&mut self, key: K) -> Slot<V> {
        self.tick += 1;
        let tick = self.tick;
        if let Some((t, slot)) = self.map.get_mut(&key) {
            *t = tick;
            return slot.clone();
        }
        if self.map.len() >= self.cap {
            // bind first: an if-let scrutinee would hold the iter
            // borrow across the remove
            let oldest = self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| k.clone());
            if let Some(oldest) = oldest {
                self.map.remove(&oldest);
            }
        }
        let slot: Slot<V> = Arc::new(OnceSlot::new());
        self.map.insert(key, (tick, slot.clone()));
        slot
    }
}

/// An LRU-bounded memo table whose values are computed at most once per
/// live slot.  The map lock is held only for slot bookkeeping, never
/// during a compute — racing readers block on the slot's [`OnceSlot`],
/// not on the table.
pub struct Memo<K, V> {
    inner: Mutex<MemoMap<K, V>>,
}

impl<K: Eq + Hash + Clone, V: Clone> Memo<K, V> {
    /// A memo table holding at most `cap` slots (minimum 1).
    pub fn new(cap: usize) -> Memo<K, V> {
        Memo { inner: Mutex::new(MemoMap { map: HashMap::new(), tick: 0, cap: cap.max(1) }) }
    }

    /// The memoized value for `key`, computing it if this is the first
    /// caller for a live slot.  Exactly one caller ever runs `compute`
    /// per slot; concurrent callers of the same key block on that one
    /// in-flight computation and clone its result.
    pub fn get_or_compute(&self, key: K, compute: impl FnOnce() -> V) -> (V, MemoHow) {
        let slot = self.inner.lock().slot(key);
        if let Some(v) = slot.try_get() {
            return (v, MemoHow::Hit);
        }
        let mut computed_here = false;
        let value = slot.get_or_init(|| {
            computed_here = true;
            compute()
        });
        (value, if computed_here { MemoHow::Computed } else { MemoHow::Waited })
    }

    /// Number of live slots.
    pub fn len(&self) -> usize {
        self.inner.lock().map.len()
    }

    /// True when no slot is live.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computes_once_then_hits() {
        let memo: Memo<u32, u32> = Memo::new(8);
        let (v, how) = memo.get_or_compute(1, || 10);
        assert_eq!((v, how), (10, MemoHow::Computed));
        let (v, how) = memo.get_or_compute(1, || panic!("must not recompute"));
        assert_eq!((v, how), (10, MemoHow::Hit));
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let memo: Memo<u32, u32> = Memo::new(2);
        memo.get_or_compute(1, || 1);
        memo.get_or_compute(2, || 2);
        memo.get_or_compute(1, || panic!("hit")); // touch: 1 most recent
        memo.get_or_compute(3, || 3); // evicts 2
        assert_eq!(memo.len(), 2);
        let (_, how) = memo.get_or_compute(1, || panic!("still cached"));
        assert_eq!(how, MemoHow::Hit);
        let (_, how) = memo.get_or_compute(2, || 22);
        assert_eq!(how, MemoHow::Computed, "evicted key recomputes");
    }

    #[test]
    fn concurrent_same_key_computes_once() {
        let memo: Arc<Memo<u32, u32>> = Arc::new(Memo::new(8));
        let computes = Arc::new(Mutex::new(0u64));
        let mut handles = vec![];
        for _ in 0..8 {
            let memo = memo.clone();
            let computes = computes.clone();
            handles.push(std::thread::spawn(move || {
                let (v, _) = memo.get_or_compute(7, || {
                    *computes.lock() += 1;
                    77
                });
                v
            }));
        }
        for h in handles {
            assert_eq!(h.join().expect("reader thread"), 77);
        }
        assert_eq!(*computes.lock(), 1);
    }
}
