//! Batching policies: when to close the pending event batch and run a
//! tracker update (the coordinator's "time step" boundary).
//!
//! Trade-off mirrors the paper's complexity analysis: more events per
//! batch amortize the O(N(K+L)²) dense phase, but enlarge ‖Δ‖ and hence
//! the subspace drift per step.
//!
//! Count triggers ([`BatchPolicy::ByCount`] / [`ByNewNodes`]
//! (BatchPolicy::ByNewNodes)) fire at ingest time.  The time trigger
//! ([`BatchPolicy::MaxAge`], or the `max_age` arm of
//! [`BatchPolicy::Either`]) bounds staleness for low-rate tenants: a
//! pending batch flushes once its oldest event reaches the deadline,
//! with no manual `flush()` — the worker pool's scheduler (and the
//! pinned-thread loop) wake deadline-armed idle tenants.

use std::time::Duration;

/// Policy deciding when a pending batch should be flushed.
#[derive(Clone, Copy, Debug)]
pub enum BatchPolicy {
    /// Flush after this many events.
    ByCount(usize),
    /// Flush when this many new nodes accumulated (bounds S, so the
    /// G-REST₃ panel and the artifact tier stay small).
    ByNewNodes(usize),
    /// Flush when the oldest pending event reaches this age (pure time
    /// trigger; count pressure never closes the batch early).
    MaxAge(Duration),
    /// Flush when either count bound trips, or — with `max_age` set —
    /// when the pending batch outlives the deadline.
    Either { events: usize, new_nodes: usize, max_age: Option<Duration> },
}

impl BatchPolicy {
    /// Should the batch (with `events` pending and `new_nodes` pending
    /// arrivals) be flushed now, on count pressure alone?  Time
    /// triggers report through [`BatchPolicy::should_flush_aged`] /
    /// [`BatchPolicy::max_age`] instead.
    pub fn should_flush(&self, events: usize, new_nodes: usize) -> bool {
        match *self {
            BatchPolicy::ByCount(c) => events >= c,
            BatchPolicy::ByNewNodes(s) => new_nodes >= s,
            BatchPolicy::MaxAge(_) => false,
            BatchPolicy::Either { events: c, new_nodes: s, .. } => events >= c || new_nodes >= s,
        }
    }

    /// [`should_flush`](Self::should_flush) extended with the age of the
    /// oldest pending event; an empty batch never flushes on age.
    pub fn should_flush_aged(&self, events: usize, new_nodes: usize, age: Duration) -> bool {
        self.should_flush(events, new_nodes)
            || ((events > 0 || new_nodes > 0) && self.max_age().is_some_and(|limit| age >= limit))
    }

    /// The deadline arm, when this policy has one: how long a non-empty
    /// pending batch may age before the scheduler must flush it.
    pub fn max_age(&self) -> Option<Duration> {
        match *self {
            BatchPolicy::MaxAge(d) => Some(d),
            BatchPolicy::Either { max_age, .. } => max_age,
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_count() {
        let p = BatchPolicy::ByCount(3);
        assert!(!p.should_flush(2, 100));
        assert!(p.should_flush(3, 0));
        assert_eq!(p.max_age(), None);
    }

    #[test]
    fn by_new_nodes() {
        let p = BatchPolicy::ByNewNodes(2);
        assert!(!p.should_flush(1000, 1));
        assert!(p.should_flush(0, 2));
    }

    #[test]
    fn either() {
        let p = BatchPolicy::Either { events: 5, new_nodes: 2, max_age: None };
        assert!(p.should_flush(5, 0));
        assert!(p.should_flush(0, 2));
        assert!(!p.should_flush(4, 1));
        assert_eq!(p.max_age(), None);
    }

    #[test]
    fn max_age_is_a_pure_time_trigger() {
        let p = BatchPolicy::MaxAge(Duration::from_millis(50));
        // count pressure alone never closes the batch
        assert!(!p.should_flush(1_000_000, 1_000_000));
        assert_eq!(p.max_age(), Some(Duration::from_millis(50)));
        // age closes it — but only when something is pending
        assert!(p.should_flush_aged(1, 0, Duration::from_millis(50)));
        assert!(p.should_flush_aged(1, 0, Duration::from_millis(200)));
        assert!(!p.should_flush_aged(1, 0, Duration::from_millis(49)));
        assert!(!p.should_flush_aged(0, 0, Duration::from_secs(60)));
    }

    #[test]
    fn either_with_deadline_arm() {
        let p = BatchPolicy::Either {
            events: 5,
            new_nodes: 2,
            max_age: Some(Duration::from_millis(100)),
        };
        assert_eq!(p.max_age(), Some(Duration::from_millis(100)));
        // counts fire immediately, age-independent
        assert!(p.should_flush_aged(5, 0, Duration::ZERO));
        // below the count bounds, the deadline decides
        assert!(!p.should_flush_aged(4, 1, Duration::from_millis(99)));
        assert!(p.should_flush_aged(4, 1, Duration::from_millis(100)));
        assert!(p.should_flush_aged(1, 0, Duration::from_millis(100)));
        // an empty batch has no age to exceed
        assert!(!p.should_flush_aged(0, 0, Duration::from_secs(5)));
    }
}
