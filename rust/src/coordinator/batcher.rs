//! Batching policies: when to close the pending event batch and run a
//! tracker update (the coordinator's "time step" boundary).
//!
//! Trade-off mirrors the paper's complexity analysis: more events per
//! batch amortize the O(N(K+L)²) dense phase, but enlarge ‖Δ‖ and hence
//! the subspace drift per step.

/// Policy deciding when a pending batch should be flushed.
#[derive(Clone, Copy, Debug)]
pub enum BatchPolicy {
    /// Flush after this many events.
    ByCount(usize),
    /// Flush when this many new nodes accumulated (bounds S, so the
    /// G-REST₃ panel and the artifact tier stay small).
    ByNewNodes(usize),
    /// Flush when either bound trips.
    Either { events: usize, new_nodes: usize },
}

impl BatchPolicy {
    /// Should the batch (with `events` pending and `new_nodes` pending
    /// arrivals) be flushed now?
    pub fn should_flush(&self, events: usize, new_nodes: usize) -> bool {
        match *self {
            BatchPolicy::ByCount(c) => events >= c,
            BatchPolicy::ByNewNodes(s) => new_nodes >= s,
            BatchPolicy::Either { events: c, new_nodes: s } => events >= c || new_nodes >= s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_count() {
        let p = BatchPolicy::ByCount(3);
        assert!(!p.should_flush(2, 100));
        assert!(p.should_flush(3, 0));
    }

    #[test]
    fn by_new_nodes() {
        let p = BatchPolicy::ByNewNodes(2);
        assert!(!p.should_flush(1000, 1));
        assert!(p.should_flush(0, 2));
    }

    #[test]
    fn either() {
        let p = BatchPolicy::Either { events: 5, new_nodes: 2 };
        assert!(p.should_flush(5, 0));
        assert!(p.should_flush(0, 2));
        assert!(!p.should_flush(4, 1));
    }
}
