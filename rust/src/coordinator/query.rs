//! Lock-free query engine: every downstream task (central nodes,
//! cluster assignments, per-node embedding lookup, embedding-cosine
//! similarity) is answered purely from an immutable
//! `Arc<EmbeddingSnapshot>` — queries never send a worker `Command`, so
//! a read storm cannot serialize behind pending batch updates.
//!
//! Derived results are memoized in a version-keyed cache: the first
//! reader at a given `(version, query)` computes, concurrent readers of
//! the same key block on that one in-flight computation (a shared
//! write-once slot, never a second compute), and every later reader
//! answers with a short mutex hold plus an `Arc` clone.  The cache
//! holds a small LRU-bounded set of slots, so stale versions age out as
//! the stream advances.  The one-in-flight-compute machinery itself is
//! [`Memo`](crate::coordinator::memo_core::Memo), whose guarantee is
//! loom-model-checked (see `rust/loom-model`).  All results are
//! reported in **external** node ids via the snapshot's
//! [`IdMap`](crate::graph::stream::IdMap).

use crate::coordinator::memo_core::{Memo, MemoHow};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::snapshot::EmbeddingSnapshot;
use crate::linalg::f32mat::{self, F32Mat, ServePrecision};
use crate::linalg::threads::Threads;
use crate::sync::Arc;
use crate::tasks::{centrality, clustering};
use std::time::Instant;

// The assignment type lives in the task layer (which stays free of
// coordinator dependencies); the coordinator re-exports it as part of
// the query API.
pub use crate::tasks::clustering::ClusterAssignment;

/// Identity of a derived query at one snapshot version.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
enum QueryKey {
    Central(usize),
    Clusters(usize),
    Similar(u64, usize),
}

/// One memoized result (clones are `Arc` clones).
#[derive(Clone)]
enum QueryValue {
    Central(Arc<Vec<u64>>),
    Clusters(Arc<ClusterAssignment>),
    Similar(Arc<Vec<(u64, f64)>>),
}

/// Default LRU bound: a handful of versions × a handful of distinct
/// queries per version.
const DEFAULT_CACHE_CAP: usize = 128;

/// LRU bound on demoted f32 panels (one per snapshot version).  Small:
/// readers overwhelmingly query the newest couple of versions, and a
/// panel is cheap to rebuild.
const PANEL_CACHE_CAP: usize = 4;

/// Snapshot-only query engine owned by the `ServiceHandle`.
pub struct QueryEngine {
    seed: u64,
    threads: Threads,
    metrics: Arc<Metrics>,
    /// Serving precision (`ServiceConfig::serve_precision`): `F64`
    /// answers from the snapshot bit-for-bit; `F32` serves cosine and
    /// k-means distance scans from a demoted row-major panel.
    precision: ServePrecision,
    cache: Memo<(u64, QueryKey), QueryValue>,
    /// Version-keyed f32 panels (`ServePrecision::F32` only).  A
    /// separate memo so panel builds never show up in the query
    /// hit/computed metrics or evict query results.
    panels: Memo<u64, Arc<F32Mat>>,
}

impl QueryEngine {
    pub fn new(seed: u64, threads: Threads, metrics: Arc<Metrics>) -> QueryEngine {
        QueryEngine::with_capacity(seed, threads, metrics, DEFAULT_CACHE_CAP)
    }

    /// [`QueryEngine::new`] with an explicit serving precision (the
    /// plain constructor serves `F64`).
    pub fn with_precision(
        seed: u64,
        threads: Threads,
        metrics: Arc<Metrics>,
        precision: ServePrecision,
    ) -> QueryEngine {
        let mut eng = QueryEngine::with_capacity(seed, threads, metrics, DEFAULT_CACHE_CAP);
        eng.precision = precision;
        eng
    }

    pub fn with_capacity(
        seed: u64,
        threads: Threads,
        metrics: Arc<Metrics>,
        cap: usize,
    ) -> QueryEngine {
        QueryEngine {
            seed,
            threads,
            metrics,
            precision: ServePrecision::F64,
            cache: Memo::new(cap),
            panels: Memo::new(PANEL_CACHE_CAP),
        }
    }

    /// The demoted f32 panel of `snap`, built once per version (shared
    /// across concurrent readers by the same write-once machinery as
    /// query results).
    fn f32_panel(&self, snap: &EmbeddingSnapshot) -> Arc<F32Mat> {
        let (panel, _) = self
            .panels
            .get_or_compute(snap.version, || Arc::new(F32Mat::from_mat(&snap.pairs.vectors)));
        panel
    }

    /// Memoize `compute` under `(snap.version, key)`: exactly one caller
    /// computes per live cache slot, everyone else gets the shared Arc.
    fn memoize(
        &self,
        version: u64,
        key: QueryKey,
        compute: impl FnOnce() -> QueryValue,
    ) -> QueryValue {
        let t0 = Instant::now();
        let (value, how) = self.cache.get_or_compute((version, key), compute);
        match how {
            MemoHow::Hit => {
                // pure hit: the only latencies the cached histogram
                // records
                self.metrics.queries_cached.incr();
                self.metrics.query_latency_cached.observe(t0.elapsed());
            }
            MemoHow::Computed => {
                self.metrics.queries_computed.incr();
                self.metrics.query_latency_computed.observe(t0.elapsed());
            }
            MemoHow::Waited => {
                // a reader that lost the race waited for the in-flight
                // compute: it counts as cached (nothing was recomputed)
                // but its latency is compute-shaped, so it must not
                // pollute the cached histogram
                self.metrics.queries_cached.incr();
                self.metrics.query_latency_computed.observe(t0.elapsed());
            }
        }
        value
    }

    /// Top-J central nodes of `snap` by subgraph centrality, as
    /// external ids.
    pub fn central_nodes(&self, snap: &EmbeddingSnapshot, j: usize) -> Arc<Vec<u64>> {
        match self.memoize(snap.version, QueryKey::Central(j), || {
            QueryValue::Central(Arc::new(centrality::central_nodes_external(
                &snap.pairs,
                &snap.ids,
                j,
            )))
        }) {
            QueryValue::Central(v) => v,
            _ => unreachable!("slot keyed Central holds Central"),
        }
    }

    /// Spectral k-clustering of `snap`, seeded from the service seed
    /// (deterministic per `(version, k)`), keyed by external ids.
    pub fn clusters(&self, snap: &EmbeddingSnapshot, k: usize) -> Arc<ClusterAssignment> {
        match self.memoize(snap.version, QueryKey::Clusters(k), || {
            QueryValue::Clusters(Arc::new(clustering::cluster_assignment_precision(
                &snap.pairs,
                &snap.ids,
                snap.version,
                k,
                self.seed,
                self.threads,
                self.precision,
            )))
        }) {
            QueryValue::Clusters(v) => v,
            _ => unreachable!("slot keyed Clusters holds Clusters"),
        }
    }

    /// K-dimensional embedding row of one external node id.  O(K) from
    /// the snapshot — cheap enough that it bypasses the memo cache.
    pub fn embedding(&self, snap: &EmbeddingSnapshot, external: u64) -> Option<Vec<f64>> {
        snap.embedding(external)
    }

    /// Top-`top` nodes most similar to `external` by embedding-row
    /// cosine, as `(external id, similarity)` descending; `None` when
    /// the id is not in the snapshot.  Excludes the query node itself.
    pub fn similar_to(
        &self,
        snap: &EmbeddingSnapshot,
        external: u64,
        top: usize,
    ) -> Option<Arc<Vec<(u64, f64)>>> {
        let q = snap.ids.internal(external)?;
        if q >= snap.pairs.n() {
            return None;
        }
        match self.memoize(snap.version, QueryKey::Similar(external, top), || {
            let scored = match self.precision {
                ServePrecision::F64 => cosine_similar(snap, q, top),
                ServePrecision::F32 => cosine_similar_f32(snap, &self.f32_panel(snap), q, top),
            };
            QueryValue::Similar(Arc::new(scored))
        }) {
            QueryValue::Similar(v) => Some(v),
            _ => unreachable!("slot keyed Similar holds Similar"),
        }
    }

    /// Number of live cache slots (tests/diagnostics).
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

/// Cosine similarity of every other row against row `q`, top-`top` by
/// similarity (ties by internal index); zero-norm rows score 0.
fn cosine_similar(snap: &EmbeddingSnapshot, q: usize, top: usize) -> Vec<(u64, f64)> {
    let x = &snap.pairs.vectors;
    let (n, k) = (snap.pairs.n(), snap.pairs.k());
    let qrow: Vec<f64> = (0..k).map(|j| x.get(q, j)).collect();
    let qn = qrow.iter().map(|v| v * v).sum::<f64>().sqrt();
    let mut scored: Vec<(usize, f64)> = (0..n)
        .filter(|&i| i != q)
        .map(|i| {
            let mut dot = 0.0;
            let mut nn = 0.0;
            for (j, &qj) in qrow.iter().enumerate() {
                let v = x.get(i, j);
                dot += qj * v;
                nn += v * v;
            }
            let denom = qn * nn.sqrt();
            (i, if denom > 0.0 { dot / denom } else { 0.0 })
        })
        .collect();
    scored.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    scored.truncate(top);
    // publish() asserts the id map covers every row, so the filter_map
    // drops nothing in practice; it exists so a (debug-asserted)
    // violation degrades to a shorter answer instead of a panic on the
    // read path
    scored.into_iter().filter_map(|(i, s)| Some((snap.ids.external(i)?, s))).collect()
}

/// [`cosine_similar`] against the demoted row-major f32 panel: f32
/// loads, f64 accumulation, identical sort and tie-break.  Scores drift
/// from the f64 path by the documented ~2⁻²⁴-relative storage rounding
/// (see `linalg::f32mat`), so top-k ranks are stable whenever adjacent
/// similarities are separated by more than that.
fn cosine_similar_f32(
    snap: &EmbeddingSnapshot,
    panel: &F32Mat,
    q: usize,
    top: usize,
) -> Vec<(u64, f64)> {
    let n = panel.rows();
    let qrow = panel.row(q);
    let (qq, _) = f32mat::dot_norm2_f32(qrow, qrow);
    let qn = qq.sqrt();
    let mut scored: Vec<(usize, f64)> = (0..n)
        .filter(|&i| i != q)
        .map(|i| {
            let (dot, nn) = f32mat::dot_norm2_f32(qrow, panel.row(i));
            let denom = qn * nn.sqrt();
            (i, if denom > 0.0 { dot / denom } else { 0.0 })
        })
        .collect();
    scored.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    scored.truncate(top);
    scored.into_iter().filter_map(|(i, s)| Some((snap.ids.external(i)?, s))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stream::IdMap;
    use crate::linalg::mat::Mat;
    use crate::tracking::traits::EigenPairs;

    fn snap_with_vectors(version: u64, vectors: Mat, externals: Vec<u64>) -> EmbeddingSnapshot {
        let k = vectors.cols();
        EmbeddingSnapshot {
            version,
            n_nodes: vectors.rows(),
            pairs: EigenPairs { values: (0..k).map(|j| (k - j) as f64).collect(), vectors },
            ids: Arc::new(IdMap::from_externals(externals)),
            published_at: Instant::now(),
        }
    }

    fn engine() -> (QueryEngine, Arc<Metrics>) {
        let m = Metrics::new();
        (QueryEngine::new(7, Threads::SINGLE, m.clone()), m)
    }

    #[test]
    fn memoizes_per_version_and_key() {
        let (eng, m) = engine();
        let mut rng = crate::linalg::rng::Rng::new(1);
        let s1 = snap_with_vectors(1, Mat::randn(20, 3, &mut rng), (0..20).collect());
        let a = eng.central_nodes(&s1, 5);
        let b = eng.central_nodes(&s1, 5);
        assert!(Arc::ptr_eq(&a, &b), "same version+key must share one result");
        assert_eq!(m.queries_computed.get(), 1);
        assert_eq!(m.queries_cached.get(), 1);
        // a different J, and a new version, each compute once
        let _ = eng.central_nodes(&s1, 3);
        let s2 = snap_with_vectors(2, Mat::randn(20, 3, &mut rng), (0..20).collect());
        let c = eng.central_nodes(&s2, 5);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(m.queries_computed.get(), 3);
    }

    #[test]
    fn lru_bound_evicts_oldest_key() {
        let m = Metrics::new();
        let eng = QueryEngine::with_capacity(7, Threads::SINGLE, m.clone(), 2);
        let mut rng = crate::linalg::rng::Rng::new(2);
        let s = snap_with_vectors(1, Mat::randn(10, 2, &mut rng), (0..10).collect());
        let _ = eng.central_nodes(&s, 1);
        let _ = eng.central_nodes(&s, 2);
        assert_eq!(eng.cache_len(), 2);
        let _ = eng.central_nodes(&s, 1); // touch: j=1 becomes most recent
        let _ = eng.central_nodes(&s, 3); // evicts j=2
        assert_eq!(eng.cache_len(), 2);
        let computed = m.queries_computed.get();
        let _ = eng.central_nodes(&s, 1); // still cached
        assert_eq!(m.queries_computed.get(), computed);
        let _ = eng.central_nodes(&s, 2); // was evicted: recomputes
        assert_eq!(m.queries_computed.get(), computed + 1);
    }

    #[test]
    fn similar_to_returns_external_ids_and_excludes_self() {
        let (eng, _) = engine();
        // three collinear rows + one orthogonal
        let mut v = Mat::zeros(4, 2);
        v.set(0, 0, 1.0);
        v.set(1, 0, 2.0); // same direction as row 0
        v.set(2, 1, 1.0); // orthogonal
        v.set(3, 0, -1.0); // opposite
        let s = snap_with_vectors(1, v, vec![100, 200, 300, 400]);
        let sim = eng.similar_to(&s, 100, 3).unwrap();
        assert_eq!(sim.len(), 3);
        assert_eq!(sim[0].0, 200);
        assert!((sim[0].1 - 1.0).abs() < 1e-12);
        assert_eq!(sim[2].0, 400, "anti-parallel row ranks last");
        assert!((sim[2].1 + 1.0).abs() < 1e-12);
        assert!(sim.iter().all(|&(e, _)| e != 100), "query node excluded");
        assert!(eng.similar_to(&s, 9999, 3).is_none(), "unknown id");
    }

    #[test]
    fn default_engine_serves_the_f64_oracle_bitwise() {
        let (eng, _) = engine();
        let mut rng = crate::linalg::rng::Rng::new(11);
        let s = snap_with_vectors(1, Mat::randn(50, 4, &mut rng), (0..50).collect());
        let got = eng.similar_to(&s, 7, 10).unwrap();
        let want = cosine_similar(&s, 7, 10);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.0, w.0);
            assert_eq!(g.1.to_bits(), w.1.to_bits(), "F64 tier must be bit-for-bit");
        }
    }

    #[test]
    fn f32_tier_is_rank_stable_on_conditioned_inputs() {
        // rows at distinct angles: adjacent cosine gaps are O(1e-2),
        // far above the documented ~2⁻²⁴ f32-storage drift, so the two
        // tiers must produce identical top-k orderings
        let n = 40;
        let mut v = Mat::zeros(n, 2);
        for i in 0..n {
            let theta = 0.07 * i as f64;
            v.set(i, 0, theta.cos());
            v.set(i, 1, theta.sin());
        }
        let ext: Vec<u64> = (0..n as u64).map(|i| 1000 + i).collect();
        let s = snap_with_vectors(1, v, ext);
        let m = Metrics::new();
        let f64eng = QueryEngine::new(7, Threads::SINGLE, m.clone());
        let f32eng =
            QueryEngine::with_precision(7, Threads::SINGLE, m.clone(), ServePrecision::F32);
        let want = f64eng.similar_to(&s, 1000, 10).unwrap();
        let got = f32eng.similar_to(&s, 1000, 10).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.0, w.0, "rank order must match the f64 oracle");
            assert!((g.1 - w.1).abs() < 1e-5, "{} vs {}", g.1, w.1);
        }
    }

    #[test]
    fn f32_tier_scores_track_f64_within_documented_tolerance() {
        let mut rng = crate::linalg::rng::Rng::new(12);
        let s = snap_with_vectors(1, Mat::randn(120, 6, &mut rng), (0..120).collect());
        let m = Metrics::new();
        let f64eng = QueryEngine::new(7, Threads::SINGLE, m.clone());
        let f32eng =
            QueryEngine::with_precision(7, Threads::SINGLE, m.clone(), ServePrecision::F32);
        // full ranking (top = n-1) so every score is comparable by id
        let want = f64eng.similar_to(&s, 3, 119).unwrap();
        let got = f32eng.similar_to(&s, 3, 119).unwrap();
        assert_eq!(got.len(), 119);
        assert_eq!(want.len(), 119);
        let oracle: std::collections::HashMap<u64, f64> = want.iter().copied().collect();
        for &(id, score) in got.iter() {
            assert_ne!(id, 3, "query node excluded");
            let w = oracle[&id];
            assert!((score - w).abs() < 1e-5, "id {id}: {score} vs {w}");
        }
    }

    #[test]
    fn f32_panel_is_cached_per_version_outside_query_metrics() {
        let m = Metrics::new();
        let eng = QueryEngine::with_precision(7, Threads::SINGLE, m.clone(), ServePrecision::F32);
        let mut rng = crate::linalg::rng::Rng::new(13);
        let s = snap_with_vectors(5, Mat::randn(30, 3, &mut rng), (0..30).collect());
        let _ = eng.similar_to(&s, 0, 5);
        let _ = eng.similar_to(&s, 1, 5);
        // two distinct query keys computed; the shared panel build does
        // not inflate the query counters and is reused across them
        assert_eq!(m.queries_computed.get(), 2);
        assert_eq!(m.queries_cached.get(), 0);
        assert_eq!(eng.panels.len(), 1);
        let s2 = snap_with_vectors(6, Mat::randn(30, 3, &mut rng), (0..30).collect());
        let _ = eng.similar_to(&s2, 0, 5);
        assert_eq!(eng.panels.len(), 2, "a new version demotes a new panel");
    }

    #[test]
    fn f32_engine_routes_clusters_through_the_precision_entry_point() {
        let mut rng = crate::linalg::rng::Rng::new(14);
        let mut v = Mat::zeros(40, 2);
        for i in 0..40 {
            let c = i / 20;
            v.set(i, 0, c as f64 * 10.0 + 0.1 * rng.normal());
            v.set(i, 1, 0.1 * rng.normal());
        }
        let s = snap_with_vectors(9, v, (0..40).collect());
        let m = Metrics::new();
        let eng = QueryEngine::with_precision(7, Threads::SINGLE, m, ServePrecision::F32);
        let got = eng.clusters(&s, 2);
        let want = clustering::cluster_assignment_precision(
            &s.pairs,
            &s.ids,
            s.version,
            2,
            7,
            Threads::SINGLE,
            ServePrecision::F32,
        );
        assert_eq!(*got, want);
    }

    #[test]
    fn clusters_deterministic_per_seed_and_uses_external_ids() {
        let mut rng = crate::linalg::rng::Rng::new(3);
        // two well-separated blobs in embedding space
        let mut v = Mat::zeros(40, 2);
        for i in 0..40 {
            let c = i / 20;
            v.set(i, 0, c as f64 * 10.0 + 0.1 * rng.normal());
            v.set(i, 1, 0.1 * rng.normal());
        }
        let ext: Vec<u64> = (0..40u64).map(|i| 5000 + i).collect();
        let s = snap_with_vectors(4, v.clone(), ext.clone());
        let (eng, _) = engine();
        let got = eng.clusters(&s, 2);
        assert_eq!(got.version, 4);
        assert_eq!(got.nodes, ext);
        // matches the pure task entry point with the engine's seed
        let want =
            clustering::cluster_assignment(&s.pairs, &s.ids, s.version, 2, 7, Threads::SINGLE);
        assert_eq!(*got, want);
        assert_eq!(got.label_of(5000), Some(got.labels[0]));
        assert_eq!(got.label_of(1), None);
        // blob membership is coherent
        assert!(got.labels[..20].iter().all(|&l| l == got.labels[0]));
        assert!(got.labels[20..].iter().all(|&l| l == got.labels[20]));
        assert_ne!(got.labels[0], got.labels[20]);
    }

    #[test]
    fn concurrent_readers_compute_once_and_agree() {
        let m = Metrics::new();
        let eng = Arc::new(QueryEngine::new(1, Threads::SINGLE, m.clone()));
        let mut rng = crate::linalg::rng::Rng::new(5);
        let s = Arc::new(snap_with_vectors(1, Mat::randn(300, 6, &mut rng), (0..300).collect()));
        let mut handles = vec![];
        for _ in 0..8 {
            let eng = eng.clone();
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut out = vec![];
                for _ in 0..50 {
                    out.push(eng.central_nodes(&s, 10));
                }
                out
            }));
        }
        let results: Vec<_> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        for r in &results {
            assert_eq!(**r, *results[0], "all readers at one version must agree");
        }
        assert_eq!(
            m.queries_computed.get(),
            1,
            "read storm at one version computes exactly once"
        );
        assert_eq!(m.queries_cached.get(), 8 * 50 - 1);
    }
}
