//! The structured update matrix Δ of paper Eq. (2):
//!
//! ```text
//!       Δ = [ K  G ]   K: N×N topological updates (±1),
//!           [ Gᵀ C ]   G: N×S old↔new edges, C: S×S new↔new edges.
//! ```
//!
//! Stored as one symmetric (N+S)×(N+S) CSR plus the block split, with the
//! products the trackers need: Δ·B, Δ₂·Ω, Δ₂ᵀ·M, dense Δ₂.

use crate::linalg::mat::{Mat, Padded};
use crate::linalg::threads::Threads;
use crate::linalg::workspace::StepWorkspace;
use crate::sparse::coo::Coo;
use crate::sparse::csr::{
    dense_row_major, dense_row_major_into, rowwise_spmm, rowwise_spmm_into, Csr,
};

/// Structured graph update (one time step).
#[derive(Clone, Debug)]
pub struct Delta {
    /// N — dimension before the update.
    pub n_old: usize,
    /// S — number of newly added nodes.
    pub s_new: usize,
    /// Full (N+S)×(N+S) symmetric update matrix.
    pub full: Csr,
}

impl Delta {
    /// Dimension after the update (N+S).
    pub fn n_new(&self) -> usize {
        self.n_old + self.s_new
    }

    pub fn nnz(&self) -> usize {
        self.full.nnz()
    }

    /// Assemble from the three blocks.
    ///
    /// * `k` — symmetric COO over old nodes (entries ±w; edge add/remove).
    /// * `g` — COO (old node, new-node-offset) connections.
    /// * `c` — symmetric COO among new nodes (offsets).
    pub fn from_blocks(n_old: usize, s_new: usize, k: &Coo, g: &Coo, c: &Coo) -> Delta {
        assert_eq!((k.rows, k.cols), (n_old, n_old));
        assert_eq!((g.rows, g.cols), (n_old, s_new));
        assert_eq!((c.rows, c.cols), (s_new, s_new));
        let n = n_old + s_new;
        let mut coo = Coo::new(n, n);
        for &(i, j, v) in &k.entries {
            coo.push(i, j, v);
        }
        for &(i, j, v) in &g.entries {
            coo.push(i, n_old + j, v);
            coo.push(n_old + j, i, v);
        }
        for &(i, j, v) in &c.entries {
            coo.push(n_old + i, n_old + j, v);
        }
        Delta { n_old, s_new, full: coo.to_csr() }
    }

    /// Δ = Â − Ā: difference between the updated matrix and the
    /// zero-padded old one (Eq. 2).  Works for adjacency or (shifted)
    /// Laplacian matrices alike.
    pub fn from_diff(a_old: &Csr, a_new: &Csr) -> Delta {
        assert!(a_new.n_rows >= a_old.n_rows);
        let n_old = a_old.n_rows;
        let s_new = a_new.n_rows - n_old;
        Delta { n_old, s_new, full: a_new.sub_padded(a_old) }
    }

    /// Δ · B for a dense (N+S)×m panel (auto thread budget).
    pub fn matmul_dense(&self, b: &Mat) -> Mat {
        self.full.matmul_dense(b)
    }

    /// [`Delta::matmul_dense`] with an explicit worker-thread budget.
    pub fn matmul_dense_with(&self, b: &Mat, threads: Threads) -> Mat {
        self.full.matmul_dense_with(b, threads)
    }

    /// [`Delta::matmul_dense_with`] into caller-owned storage (scratch
    /// from `ws`; allocation-free once warm on the sequential path).
    pub fn matmul_dense_into(
        &self,
        b: &Mat,
        out: &mut Mat,
        ws: &mut StepWorkspace,
        threads: Threads,
    ) {
        self.full.matmul_dense_into(b, out, ws, threads);
    }

    /// Δ · X̄ where X̄ is the zero-padded eigenvector panel: accepts the
    /// *unpadded* N×K matrix and returns (N+S)×K (uses that the padded
    /// rows of X̄ are zero, Prop. 4).  Auto thread budget.
    pub fn mul_padded(&self, x: &Mat) -> Mat {
        self.mul_padded_with(x, Threads::AUTO)
    }

    /// [`Delta::mul_padded`] with an explicit worker-thread budget:
    /// row-partitioned single pass with the same bitwise-stability
    /// contract as [`Csr::matmul_dense_with`].  Row indices are sorted,
    /// so each row stops at the first expansion column.
    pub fn mul_padded_with(&self, x: &Mat, threads: Threads) -> Mat {
        let mut ws = StepWorkspace::new();
        let mut out = Mat::zeros(0, 0);
        self.mul_padded_into(x, &mut out, &mut ws, threads);
        out
    }

    /// [`Delta::mul_padded_with`] into caller-owned storage: the output,
    /// the row-major X copy, and the per-row accumulator all come from
    /// `out`/`ws` — the ΔX̄ product of a warmed tracker step allocates
    /// nothing on the sequential path.
    pub fn mul_padded_into(&self, x: &Mat, out: &mut Mat, ws: &mut StepWorkspace, threads: Threads) {
        assert_eq!(x.rows(), self.n_old);
        let k = x.cols();
        let mut xt = ws.take_buf();
        dense_row_major_into(x, &mut xt);
        let mut acc = ws.take_buf();
        rowwise_spmm_into(
            out,
            &mut acc,
            self.n_new(),
            k,
            |i| self.full.indptr[i + 1] - self.full.indptr[i] + 1,
            2 * self.nnz() * k,
            threads,
            |i, acc| {
                let (cols, vals) = self.full.row(i);
                for (&c, &v) in cols.iter().zip(vals.iter()) {
                    if c >= self.n_old {
                        break;
                    }
                    crate::linalg::blas::axpy(v, &xt[c * k..(c + 1) * k], acc);
                }
            },
        );
        ws.give_buf(acc);
        ws.give_buf(xt);
    }

    /// Δ₂ · Ω  (Ω: S×j) — product with the trailing S columns of Δ.
    /// Auto thread budget.
    pub fn d2_mult(&self, omega: &Mat) -> Mat {
        self.d2_mult_with(omega, Threads::AUTO)
    }

    /// Number of entries in the Δ₂ panel (trailing S columns): by
    /// symmetry of Δ this equals the entry count of the bottom S rows.
    fn nnz_d2(&self) -> usize {
        self.full.indptr[self.n_new()] - self.full.indptr[self.n_old]
    }

    /// [`Delta::d2_mult`] with an explicit worker-thread budget.  Each
    /// row starts at its first expansion column (binary partition point
    /// in the sorted index run); the parallel threshold counts only the
    /// Δ₂ entries this kernel actually touches.
    pub fn d2_mult_with(&self, omega: &Mat, threads: Threads) -> Mat {
        assert_eq!(omega.rows(), self.s_new);
        let k = omega.cols();
        let wt = dense_row_major(omega);
        rowwise_spmm(
            self.n_new(),
            k,
            |i| self.full.indptr[i + 1] - self.full.indptr[i] + 1,
            2 * self.nnz_d2() * k,
            threads,
            |i, acc| {
                let (cols, vals) = self.full.row(i);
                let start = cols.partition_point(|&c| c < self.n_old);
                for (&c, &v) in cols[start..].iter().zip(vals[start..].iter()) {
                    let r = c - self.n_old;
                    crate::linalg::blas::axpy(v, &wt[r * k..(r + 1) * k], acc);
                }
            },
        )
    }

    /// Δ₂ᵀ · M (M: (N+S)×j, possibly a [`Padded`] view) — by symmetry of
    /// Δ this is the bottom S rows of Δ·M, so it costs one sparse pass
    /// over those rows only.  Auto thread budget.
    pub fn d2_t_mult<'a>(&self, m: impl Into<Padded<'a>>) -> Mat {
        self.d2_t_mult_with(m, Threads::AUTO)
    }

    /// [`Delta::d2_t_mult`] with an explicit worker-thread budget.
    /// Reads M in place (strided) rather than through a row-major copy:
    /// only O(nnz(Δ₂)·j) of M is touched, so materializing the whole
    /// (N+S)×j panel would reintroduce the very O(N) per-step cost this
    /// kernel exists to avoid.  The parallel threshold likewise counts
    /// only the Δ₂ entries.
    ///
    /// M accepts the [`Padded`] X̄ view: entries of Δ₂ᵀ hitting the
    /// structurally-zero rows contribute an exact ±0.0 and are skipped —
    /// bitwise identical to the materialized product, without the copy.
    pub fn d2_t_mult_with<'a>(&self, m: impl Into<Padded<'a>>, threads: Threads) -> Mat {
        let m = m.into();
        assert_eq!(m.rows(), self.n_new());
        let k = m.cols();
        let filled = m.filled();
        let ms = m.mat.as_slice();
        rowwise_spmm(
            self.s_new,
            k,
            |r| {
                let i = self.n_old + r;
                self.full.indptr[i + 1] - self.full.indptr[i] + 1
            },
            2 * self.nnz_d2() * k,
            threads,
            |r, acc| {
                let (cols, vals) = self.full.row(self.n_old + r);
                for (&c, &v) in cols.iter().zip(vals.iter()) {
                    if c >= filled {
                        continue;
                    }
                    for (j, a) in acc.iter_mut().enumerate() {
                        *a += v * ms[c + j * filled];
                    }
                }
            },
        )
    }

    /// Dense Δ₂ ((N+S)×S) — only for small S (G-REST₃'s exact panel).
    pub fn d2_dense(&self) -> Mat {
        let n = self.n_new();
        let mut out = Mat::zeros(n, self.s_new);
        for i in 0..n {
            let lo = self.full.indptr[i];
            let hi = self.full.indptr[i + 1];
            for p in lo..hi {
                let c = self.full.indices[p];
                if c >= self.n_old {
                    out.set(i, c - self.n_old, self.full.data[p]);
                }
            }
        }
        out
    }

    /// The K (topological) block as a dense matrix (tests only).
    pub fn k_block_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.n_old, self.n_old);
        for i in 0..self.n_old {
            let (cols, vals) = self.full.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                if j < self.n_old {
                    out.set(i, j, v);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    /// Build the Fig. 1 example: 4 old nodes, 2 new; edge (1,3) and (3,5)
    /// added among old+? — here a simpler structured example.
    fn example() -> Delta {
        let mut k = Coo::new(4, 4);
        k.push_sym(0, 2, 1.0); // edge added
        k.push_sym(1, 3, -1.0); // edge removed
        let mut g = Coo::new(4, 2);
        g.push(2, 0, 1.0); // old 2 — new 0
        g.push(3, 1, 1.0); // old 3 — new 1
        let mut c = Coo::new(2, 2);
        c.push_sym(0, 1, 1.0); // new 0 — new 1
        Delta::from_blocks(4, 2, &k, &g, &c)
    }

    #[test]
    fn blocks_land_in_right_places() {
        let d = example();
        assert_eq!(d.n_new(), 6);
        let f = &d.full;
        assert_eq!(f.get(0, 2), 1.0);
        assert_eq!(f.get(3, 1), -1.0);
        assert_eq!(f.get(2, 4), 1.0);
        assert_eq!(f.get(4, 2), 1.0);
        assert_eq!(f.get(4, 5), 1.0);
        assert!(f.is_symmetric(0.0));
    }

    #[test]
    fn mul_padded_matches_full_product() {
        let d = example();
        let mut rng = Rng::new(1);
        let x = Mat::randn(4, 3, &mut rng);
        let xbar = x.pad_rows(2);
        let want = d.matmul_dense(&xbar);
        let got = d.mul_padded(&x);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-12);
    }

    #[test]
    fn d2_products_match_dense() {
        let d = example();
        let mut rng = Rng::new(2);
        let d2 = d.d2_dense();
        let omega = Mat::randn(2, 5, &mut rng);
        let got = d.d2_mult(&omega);
        let want = d2.matmul(&omega);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-12);

        let m = Mat::randn(6, 4, &mut rng);
        let got_t = d.d2_t_mult(&m);
        let want_t = d2.t_matmul(&m);
        let mut diff_t = got_t.clone();
        diff_t.axpy(-1.0, &want_t);
        assert!(diff_t.max_abs() < 1e-12);
    }

    #[test]
    fn from_diff_round_trips() {
        // Â = Ā + Δ must hold entry-wise.
        let mut a_old = Coo::new(3, 3);
        a_old.push_sym(0, 1, 1.0);
        a_old.push_sym(1, 2, 1.0);
        let a_old = a_old.to_csr();
        let mut a_new = Coo::new(5, 5);
        a_new.push_sym(0, 1, 1.0);
        a_new.push_sym(0, 2, 1.0);
        a_new.push_sym(2, 3, 1.0);
        a_new.push_sym(3, 4, 1.0);
        let a_new = a_new.to_csr();
        let d = Delta::from_diff(&a_old, &a_new);
        assert_eq!(d.n_old, 3);
        assert_eq!(d.s_new, 2);
        // Ā + Δ == Â
        let dense_sum = {
            let mut m = a_old.to_dense().pad_rows(2);
            let mut full = Mat::zeros(5, 5);
            for i in 0..3 {
                for j in 0..3 {
                    full.set(i, j, m.get(i, j));
                }
            }
            let _ = &mut m;
            full.axpy(1.0, &d.full.to_dense());
            full
        };
        let mut diff = dense_sum;
        diff.axpy(-1.0, &a_new.to_dense());
        assert!(diff.max_abs() < 1e-12);
    }

    #[test]
    fn threaded_delta_products_bitwise_stable() {
        // sized past the parallel threshold so the row-partitioned
        // kernels actually fan out; the contract is bitwise equality
        use crate::linalg::threads::Threads;
        let mut rng = Rng::new(7);
        let n_old = 2000;
        let s = 64;
        let mut k = Coo::new(n_old, n_old);
        for _ in 0..20_000 {
            let (u, v) = (rng.below(n_old), rng.below(n_old));
            if u != v {
                k.push_sym(u, v, 1.0);
            }
        }
        let mut g = Coo::new(n_old, s);
        for j in 0..s {
            for _ in 0..40 {
                g.push(rng.below(n_old), j, 1.0);
            }
        }
        let mut c = Coo::new(s, s);
        c.push_sym(0, 1, 1.0);
        let d = Delta::from_blocks(n_old, s, &k, &g, &c);
        let x = Mat::randn(n_old, 64, &mut rng);
        let seq = d.mul_padded_with(&x, Threads::SINGLE);
        let par = d.mul_padded_with(&x, Threads(4));
        assert_eq!(seq.as_slice(), par.as_slice(), "mul_padded");
        let b = Mat::randn(d.n_new(), 64, &mut rng);
        let seq = d.matmul_dense_with(&b, Threads::SINGLE);
        let par = d.matmul_dense_with(&b, Threads(4));
        assert_eq!(seq.as_slice(), par.as_slice(), "matmul_dense");
        let om = Mat::randn(s, 64, &mut rng);
        let seq = d.d2_mult_with(&om, Threads::SINGLE);
        let par = d.d2_mult_with(&om, Threads(4));
        assert_eq!(seq.as_slice(), par.as_slice(), "d2_mult");
        let seq = d.d2_t_mult_with(&b, Threads::SINGLE);
        let par = d.d2_t_mult_with(&b, Threads(4));
        assert_eq!(seq.as_slice(), par.as_slice(), "d2_t_mult");
    }

    #[test]
    fn d2_t_mult_padded_view_bitwise_matches_materialized() {
        use crate::linalg::threads::Threads;
        let d = example();
        let mut rng = Rng::new(5);
        let x = Mat::randn(4, 3, &mut rng);
        let xbar = x.pad_rows(2);
        for &tc in &[Threads(1), Threads(4)] {
            let want = d.d2_t_mult_with(&xbar, tc);
            let got = d.d2_t_mult_with(Padded::new(&x, 2), tc);
            assert_eq!(got.as_slice(), want.as_slice());
        }
        // extra == 0 degenerates to the plain product
        let m = Mat::randn(6, 3, &mut rng);
        let plain = d.d2_t_mult(&m);
        let viewed = d.d2_t_mult(Padded::from(&m));
        assert_eq!(plain.as_slice(), viewed.as_slice());
    }

    #[test]
    fn into_variants_match_allocating_kernels() {
        use crate::linalg::threads::Threads;
        let d = example();
        let mut rng = Rng::new(6);
        let x = Mat::randn(4, 3, &mut rng);
        let mut ws = StepWorkspace::new();
        let mut out = Mat::zeros(0, 0);
        d.mul_padded_into(&x, &mut out, &mut ws, Threads(1));
        let want = d.mul_padded(&x);
        assert_eq!(out.as_slice(), want.as_slice());
        let b = Mat::randn(6, 4, &mut rng);
        d.matmul_dense_into(&b, &mut out, &mut ws, Threads(1));
        let want = d.matmul_dense(&b);
        assert_eq!(out.as_slice(), want.as_slice());
    }

    #[test]
    fn proposition1_xbar_delta_xbar_only_sees_k_block() {
        // x̄ᵢᵀ Δ x̄ⱼ = xᵢᵀ K xⱼ (Prop. 1)
        let d = example();
        let mut rng = Rng::new(3);
        let x = Mat::randn(4, 2, &mut rng);
        let xbar = x.pad_rows(2);
        let dx = d.matmul_dense(&xbar);
        let quad = xbar.t_matmul(&dx);
        let kx = d.k_block_dense().matmul(&x);
        let want = x.t_matmul(&kx);
        let mut diff = quad.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-12);
    }
}
