//! Compressed sparse row matrix with the operations the trackers need:
//! SpMV, SpMM against dense panels, transpose products, sparse
//! difference (for Laplacian deltas), and the incremental row-merge
//! `apply_delta` that the streaming ingestion path maintains committed
//! state with.

use crate::linalg::lanczos::LinOp;
use crate::linalg::mat::Mat;
use crate::linalg::threads::{balanced_col_chunks, kernel_pool, Threads};
use crate::sparse::delta::Delta;

/// CSR sparse matrix.
#[derive(Clone, Debug, Default)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Empty rows×cols matrix.
    pub fn empty(rows: usize, cols: usize) -> Csr {
        Csr { n_rows: rows, n_cols: cols, indptr: vec![0; rows + 1], indices: vec![], data: vec![] }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Structural invariants every `Csr` in the system relies on:
    /// `indptr` of length `n_rows + 1`, starting at 0, monotone, and
    /// covering `indices`/`data` exactly; column indices strictly
    /// increasing and in-bounds within each row.  `get`/`is_symmetric`
    /// (binary search) and the row-merge kernels silently misbehave on
    /// unsorted rows, so constructors and `apply_delta` assert this in
    /// debug builds via [`Csr::debug_validate`].
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.indptr.len() != self.n_rows + 1 {
            return Err(format!(
                "indptr len {} != n_rows + 1 = {}",
                self.indptr.len(),
                self.n_rows + 1
            ));
        }
        if self.indptr[0] != 0 {
            return Err(format!("indptr[0] = {} != 0", self.indptr[0]));
        }
        if self.indices.len() != self.data.len() {
            return Err(format!(
                "indices len {} != data len {}",
                self.indices.len(),
                self.data.len()
            ));
        }
        if self.indptr[self.n_rows] != self.indices.len() {
            return Err(format!(
                "indptr[n_rows] = {} != nnz = {}",
                self.indptr[self.n_rows],
                self.indices.len()
            ));
        }
        for i in 0..self.n_rows {
            if self.indptr[i] > self.indptr[i + 1] {
                return Err(format!(
                    "indptr not monotone at row {i}: {} > {}",
                    self.indptr[i],
                    self.indptr[i + 1]
                ));
            }
        }
        for i in 0..self.n_rows {
            let row = &self.indices[self.indptr[i]..self.indptr[i + 1]];
            for (p, &j) in row.iter().enumerate() {
                if j >= self.n_cols {
                    return Err(format!(
                        "row {i}: column {j} out of bounds ({} cols)",
                        self.n_cols
                    ));
                }
                if p > 0 && row[p - 1] >= j {
                    return Err(format!(
                        "row {i}: indices not strictly increasing ({} then {j})",
                        row[p - 1]
                    ));
                }
            }
        }
        Ok(())
    }

    /// Debug-build invariant check (free in release); consumes and
    /// returns `self` so constructors can validate in one expression.
    pub fn debug_validate(self) -> Csr {
        #[cfg(debug_assertions)]
        if let Err(e) = self.check_invariants() {
            panic!("Csr invariant violation: {e}");
        }
        self
    }

    /// Â = Ā + Δ by sorted row-merge: runs of rows untouched by Δ are
    /// copied wholesale (one memcpy per run), touched rows are merged
    /// entry-by-entry with exact-zero results dropped, and the S new
    /// rows are appended from Δ directly.  This is how committed state
    /// (coordinator adjacency, scenario adjacencies, shifted Laplacians)
    /// is maintained incrementally — cost O(nnz(Ā) memcpy + nnz(Δ))
    /// instead of the O(nnz(Â) log) rebuild+sort of the `from_diff`
    /// path, with no per-entry re-sorting.
    pub fn apply_delta(&self, delta: &Delta) -> Csr {
        assert_eq!(self.n_rows, delta.n_old, "apply_delta: Ā rows vs Δ n_old");
        assert_eq!(self.n_cols, delta.n_old, "apply_delta: Ā must be square");
        let n = delta.n_new();
        let cap = self.nnz() + delta.nnz();
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0usize);
        let mut indices: Vec<usize> = Vec::with_capacity(cap);
        let mut data: Vec<f64> = Vec::with_capacity(cap);
        let dptr = &delta.full.indptr;
        let mut i = 0;
        while i < self.n_rows {
            if dptr[i] == dptr[i + 1] {
                // bulk-copy the whole contiguous run of untouched rows
                let start = i;
                while i < self.n_rows && dptr[i] == dptr[i + 1] {
                    i += 1;
                }
                let (alo, ahi) = (self.indptr[start], self.indptr[i]);
                let base = indices.len();
                indices.extend_from_slice(&self.indices[alo..ahi]);
                data.extend_from_slice(&self.data[alo..ahi]);
                for r in start..i {
                    indptr.push(base + (self.indptr[r + 1] - alo));
                }
            } else {
                let (ac, av) = self.row(i);
                let (dc, dv) = delta.full.row(i);
                let (mut p, mut q) = (0usize, 0usize);
                while p < ac.len() && q < dc.len() {
                    match ac[p].cmp(&dc[q]) {
                        std::cmp::Ordering::Less => {
                            indices.push(ac[p]);
                            data.push(av[p]);
                            p += 1;
                        }
                        std::cmp::Ordering::Greater => {
                            if dv[q] != 0.0 {
                                indices.push(dc[q]);
                                data.push(dv[q]);
                            }
                            q += 1;
                        }
                        std::cmp::Ordering::Equal => {
                            let v = av[p] + dv[q];
                            if v != 0.0 {
                                indices.push(ac[p]);
                                data.push(v);
                            }
                            p += 1;
                            q += 1;
                        }
                    }
                }
                indices.extend_from_slice(&ac[p..]);
                data.extend_from_slice(&av[p..]);
                while q < dc.len() {
                    if dv[q] != 0.0 {
                        indices.push(dc[q]);
                        data.push(dv[q]);
                    }
                    q += 1;
                }
                indptr.push(indices.len());
                i += 1;
            }
        }
        for r in self.n_rows..n {
            let (dc, dv) = delta.full.row(r);
            for (&j, &v) in dc.iter().zip(dv.iter()) {
                if v != 0.0 {
                    indices.push(j);
                    data.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Csr { n_rows: n, n_cols: n, indptr, indices, data }.debug_validate()
    }

    /// Entry lookup (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        match self.indices[lo..hi].binary_search(&j) {
            Ok(pos) => self.data[lo + pos],
            Err(_) => 0.0,
        }
    }

    /// Row view: (column indices, values).
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// y += alpha * A x.
    pub fn matvec_acc(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n_cols);
        debug_assert_eq!(y.len(), self.n_rows);
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            let mut s = 0.0;
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                s += v * x[j];
            }
            y[i] += alpha * s;
        }
    }

    /// A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_acc(1.0, x, &mut y);
        y
    }

    /// A · B for a dense panel B (n_cols × m) → (n_rows × m), auto
    /// thread budget.
    pub fn matmul_dense(&self, b: &Mat) -> Mat {
        self.matmul_dense_with(b, Threads::AUTO)
    }

    /// [`Csr::matmul_dense_with`] writing into a caller-owned output,
    /// with the row-major B copy and the per-row accumulator drawn from
    /// `ws` — zero heap allocations on the sequential path once `ws` is
    /// warm.
    pub fn matmul_dense_into(
        &self,
        b: &Mat,
        out: &mut Mat,
        ws: &mut crate::linalg::workspace::StepWorkspace,
        threads: Threads,
    ) {
        assert_eq!(self.n_cols, b.rows());
        let k = b.cols();
        let mut bt = ws.take_buf();
        dense_row_major_into(b, &mut bt);
        let mut acc = ws.take_buf();
        rowwise_spmm_into(
            out,
            &mut acc,
            self.n_rows,
            k,
            |i| self.indptr[i + 1] - self.indptr[i] + 1,
            2 * self.nnz() * k,
            threads,
            |i, acc| {
                let (cols, vals) = self.row(i);
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    crate::linalg::blas::axpy(v, &bt[j * k..(j + 1) * k], acc);
                }
            },
        );
        ws.give_buf(acc);
        ws.give_buf(bt);
    }

    /// [`Csr::matmul_dense`] with an explicit worker-thread budget.
    ///
    /// Single pass over the sparse rows (rows outer, panel columns
    /// inner): each row walks its `indptr` range once and streams the
    /// matching rows of B from a row-major copy, instead of re-walking
    /// the whole matrix once per panel column.  Output rows are
    /// partitioned across workers weighted by row nnz; the per-element
    /// reduction order (ascending nonzero position) never changes, so
    /// results are bitwise identical across thread counts — the sparse
    /// analogue of the dense layer's column-partition contract.
    pub fn matmul_dense_with(&self, b: &Mat, threads: Threads) -> Mat {
        let mut ws = crate::linalg::workspace::StepWorkspace::new();
        let mut out = Mat::zeros(0, 0);
        self.matmul_dense_into(b, &mut out, &mut ws, threads);
        out
    }

    /// Aᵀ · B for a dense panel B (n_rows × m) → (n_cols × m),
    /// without materializing the transpose.
    pub fn t_matmul_dense(&self, b: &Mat) -> Mat {
        assert_eq!(self.n_rows, b.rows());
        let mut out = Mat::zeros(self.n_cols, b.cols());
        for j in 0..b.cols() {
            let bj = b.col(j);
            let oj = out.col_mut(j);
            for i in 0..self.n_rows {
                let lo = self.indptr[i];
                let hi = self.indptr[i + 1];
                let bij = bj[i];
                if bij == 0.0 {
                    continue;
                }
                for p in lo..hi {
                    oj[self.indices[p]] += self.data[p] * bij;
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Row sums (degrees for a 0/1 adjacency).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.n_rows)
            .map(|i| self.row(i).1.iter().sum())
            .collect()
    }

    /// self − other as a new sparse matrix (dimensions must match; `other`
    /// may be logically padded when smaller — see `sub_padded`).
    pub fn sub(&self, other: &Csr) -> Csr {
        assert_eq!((self.n_rows, self.n_cols), (other.n_rows, other.n_cols));
        self.sub_padded(other)
    }

    /// self − P(other) where P pads `other` with zero rows/cols up to
    /// self's shape.  This is exactly Δ = Â − Ā of paper Eq. (2).
    pub fn sub_padded(&self, other: &Csr) -> Csr {
        assert!(other.n_rows <= self.n_rows && other.n_cols <= self.n_cols);
        let mut coo = crate::sparse::coo::Coo::new(self.n_rows, self.n_cols);
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                coo.push(i, j, v);
            }
        }
        for i in 0..other.n_rows {
            let (cols, vals) = other.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                coo.push(i, j, -v);
            }
        }
        coo.to_csr()
    }

    /// Dense copy (tests / tiny matrices only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                m.set(i, j, v);
            }
        }
        m
    }

    /// Check structural symmetry (values too).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                if (self.get(j, i) - v).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

/// Row-major copy of a column-major dense panel (one pass); the sparse
/// kernels stream whole B rows contiguously from this buffer, one
/// `axpy` per nonzero.
pub(crate) fn dense_row_major(b: &Mat) -> Vec<f64> {
    let mut out = Vec::new();
    dense_row_major_into(b, &mut out);
    out
}

/// [`dense_row_major`] into a caller-owned (grow-only) buffer.
pub(crate) fn dense_row_major_into(b: &Mat, out: &mut Vec<f64>) {
    let (n, k) = (b.rows(), b.cols());
    out.clear();
    out.resize(n * k, 0.0);
    for c in 0..k {
        let col = b.col(c);
        for i in 0..n {
            out[i * k + c] = col[i];
        }
    }
}

/// Row-partitioned driver shared by the sparse panel products
/// ([`Csr::matmul_dense_with`] and the `Delta` kernels): `kernel`
/// accumulates output row `i` into a k-length buffer with a fixed
/// sequential order, rows are chunked across `threads` workers by
/// `weight` (typically row nnz), and each worker writes a private
/// column-major block that is copied into place afterwards.  Every
/// output element is produced by exactly one worker with the same
/// reduction order as the sequential path, so results are bitwise
/// identical for any worker count.
pub(crate) fn rowwise_spmm<F>(
    rows: usize,
    k: usize,
    weight: impl Fn(usize) -> usize,
    flops: usize,
    threads: Threads,
    kernel: F,
) -> Mat
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    let mut out = Mat::zeros(0, 0);
    let mut acc = Vec::new();
    rowwise_spmm_into(&mut out, &mut acc, rows, k, weight, flops, threads, kernel);
    out
}

/// Zero-filled block for the threaded path's per-chunk outputs.  Lives
/// outside the `_into` body on purpose: the steady-state
/// allocation-free contract is a `Threads(1)` property (see
/// [`rowwise_spmm_into`] docs), and keeping the one legitimate threaded
/// allocation here keeps the `_into` body itself token-clean for the
/// `into-alloc` lint.
fn zeros_block(len: usize) -> Vec<f64> {
    vec![0.0; len]
}

/// [`rowwise_spmm`] writing into a caller-owned output (reshaped in
/// place) with a caller-owned accumulator scratch: the sequential path
/// performs no heap allocation.  The threaded path (dispatched on the
/// persistent kernel pool — no per-call thread spawns) still allocates
/// its per-chunk private blocks; the allocation-free steady-state
/// contract is a `Threads(1)` property.
pub(crate) fn rowwise_spmm_into<F>(
    out: &mut Mat,
    acc_scratch: &mut Vec<f64>,
    rows: usize,
    k: usize,
    weight: impl Fn(usize) -> usize,
    flops: usize,
    threads: Threads,
    kernel: F,
) where
    F: Fn(usize, &mut [f64]) + Sync,
{
    out.reset(rows, k);
    if rows == 0 || k == 0 {
        return;
    }
    // one row loop shared by both paths; the accumulator comes in from
    // the caller (sequential) or is worker-local (threaded)
    let run = |lo: usize, hi: usize, buf: &mut [f64], acc: &mut Vec<f64>| {
        let chunk = hi - lo;
        acc.clear();
        acc.resize(k, 0.0);
        for i in lo..hi {
            acc.fill(0.0);
            kernel(i, acc);
            for (c, &v) in acc.iter().enumerate() {
                buf[(i - lo) + c * chunk] = v;
            }
        }
    };
    let workers = threads.for_flops(flops).min(rows);
    if workers <= 1 {
        run(0, rows, out.as_mut_slice(), acc_scratch);
        return;
    }
    let chunks = balanced_col_chunks(rows, workers, weight);
    // per-chunk private blocks, preallocated here so the pool chunks
    // only fill them (a chunk allocates nothing but its own `acc`)
    let mut locals: Vec<Vec<f64>> = Vec::with_capacity(chunks.len());
    for &(lo, hi) in &chunks {
        locals.push(zeros_block((hi - lo) * k));
    }
    {
        let runr = &run;
        let mut parts = Vec::with_capacity(chunks.len());
        for (&(lo, hi), buf) in chunks.iter().zip(locals.iter_mut()) {
            parts.push((lo, hi, buf));
        }
        kernel_pool().run(parts, move |(lo, hi, buf): (usize, usize, &mut Vec<f64>)| {
            let mut acc = Vec::with_capacity(k);
            runr(lo, hi, buf, &mut acc);
        });
    }
    for (&(lo, hi), local) in chunks.iter().zip(locals.iter()) {
        let rows_c = hi - lo;
        for c in 0..k {
            out.col_mut(c)[lo..hi].copy_from_slice(&local[c * rows_c..(c + 1) * rows_c]);
        }
    }
}

impl LinOp for Csr {
    fn dim(&self) -> usize {
        assert_eq!(self.n_rows, self.n_cols);
        self.n_rows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        y.fill(0.0);
        self.matvec_acc(1.0, x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::sparse::coo::Coo;

    fn random_csr(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Csr {
        let mut coo = Coo::new(rows, cols);
        for _ in 0..nnz {
            coo.push(rng.below(rows), rng.below(cols), rng.normal());
        }
        coo.to_csr()
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(1);
        let a = random_csr(20, 15, 60, &mut rng);
        let d = a.to_dense();
        let x: Vec<f64> = (0..15).map(|i| i as f64 * 0.3 - 1.0).collect();
        let y = a.matvec(&x);
        let want = crate::linalg::blas::gemv(&d, &x);
        for i in 0..20 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_dense_matches() {
        let mut rng = Rng::new(2);
        let a = random_csr(25, 18, 80, &mut rng);
        let b = Mat::randn(18, 7, &mut rng);
        let got = a.matmul_dense(&b);
        let want = a.to_dense().matmul(&b);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-12);
    }

    #[test]
    fn t_matmul_dense_matches() {
        let mut rng = Rng::new(3);
        let a = random_csr(25, 18, 80, &mut rng);
        let b = Mat::randn(25, 5, &mut rng);
        let got = a.t_matmul_dense(&b);
        let want = a.to_dense().t().matmul(&b);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-12);
    }

    #[test]
    fn sub_padded_reconstructs_delta() {
        // Â (4x4) minus padded A (3x3): exactly paper Eq. (2).
        let mut a = Coo::new(3, 3);
        a.push_sym(0, 1, 1.0);
        a.push_sym(1, 2, 1.0);
        let a = a.to_csr();
        let mut ahat = Coo::new(4, 4);
        ahat.push_sym(0, 1, 1.0); // kept
        ahat.push_sym(0, 2, 1.0); // added (K block)
        ahat.push_sym(2, 3, 1.0); // new node edge (G block)
        let ahat = ahat.to_csr();
        let delta = ahat.sub_padded(&a);
        assert_eq!(delta.get(1, 2), -1.0); // removed edge
        assert_eq!(delta.get(0, 2), 1.0);
        assert_eq!(delta.get(2, 3), 1.0);
        assert_eq!(delta.get(0, 1), 0.0);
        assert!(delta.is_symmetric(0.0));
    }

    #[test]
    fn row_sums_are_degrees() {
        let mut c = Coo::new(3, 3);
        c.push_sym(0, 1, 1.0);
        c.push_sym(0, 2, 1.0);
        let a = c.to_csr();
        assert_eq!(a.row_sums(), vec![2.0, 1.0, 1.0]);
    }

    #[test]
    fn apply_delta_matches_from_diff_oracle() {
        // random Ā/Â pairs: Ā.apply_delta(from_diff(Ā, Â)) reconstructs Â
        use crate::sparse::delta::Delta;
        let mut rng = Rng::new(9);
        for trial in 0..20u64 {
            let n_old = 5 + rng.below(20);
            let s_new = rng.below(4);
            let n = n_old + s_new;
            let a_old = random_csr(n_old, n_old, 3 * n_old, &mut rng);
            let a_new = random_csr(n, n, 3 * n, &mut rng);
            let delta = Delta::from_diff(&a_old, &a_new);
            let rebuilt = a_old.apply_delta(&delta);
            assert!(rebuilt.check_invariants().is_ok(), "trial {trial}");
            let mut diff = rebuilt.to_dense();
            diff.axpy(-1.0, &a_new.to_dense());
            assert!(diff.max_abs() < 1e-12, "trial {trial}: {}", diff.max_abs());
        }
    }

    #[test]
    fn apply_delta_bulk_copies_untouched_rows_exactly() {
        // integer-valued matrix + delta touching 2 of 50 rows: untouched
        // rows must be bit-identical and touched rows exactly merged
        let mut a = Coo::new(50, 50);
        for i in 0..49 {
            a.push_sym(i, i + 1, 1.0);
        }
        let a = a.to_csr();
        let mut k = Coo::new(50, 50);
        k.push_sym(10, 30, 1.0); // add
        k.push_sym(10, 11, -1.0); // remove existing
        let d = crate::sparse::delta::Delta::from_blocks(
            50,
            0,
            &k,
            &Coo::new(50, 0),
            &Coo::new(0, 0),
        );
        let got = a.apply_delta(&d);
        assert_eq!(got.get(10, 30), 1.0);
        assert_eq!(got.get(30, 10), 1.0);
        assert_eq!(got.get(10, 11), 0.0);
        assert_eq!(got.get(5, 6), 1.0);
        assert_eq!(got.nnz(), a.nnz() + 2 - 2);
        assert!(got.is_symmetric(0.0));
        assert!(got.check_invariants().is_ok());
    }

    #[test]
    fn apply_delta_appends_new_rows() {
        use crate::sparse::delta::Delta;
        let mut a = Coo::new(3, 3);
        a.push_sym(0, 1, 1.0);
        let a = a.to_csr();
        let mut g = Coo::new(3, 2);
        g.push(2, 0, 1.0);
        let mut c = Coo::new(2, 2);
        c.push_sym(0, 1, 1.0);
        let d = Delta::from_blocks(3, 2, &Coo::new(3, 3), &g, &c);
        let got = a.apply_delta(&d);
        assert_eq!(got.n_rows, 5);
        assert_eq!(got.get(2, 3), 1.0);
        assert_eq!(got.get(3, 2), 1.0);
        assert_eq!(got.get(3, 4), 1.0);
        assert_eq!(got.get(0, 1), 1.0);
        assert!(got.is_symmetric(0.0));
    }

    #[test]
    fn check_invariants_catches_corruption() {
        let mut rng = Rng::new(11);
        let good = random_csr(10, 10, 30, &mut rng);
        assert!(good.check_invariants().is_ok());

        let mut bad = good.clone();
        bad.indptr[0] = 1;
        assert!(bad.check_invariants().is_err(), "nonzero indptr[0]");

        let mut bad = good.clone();
        let last = bad.indptr.len() - 1;
        bad.indptr[last] += 1;
        assert!(bad.check_invariants().is_err(), "indptr/nnz mismatch");

        let mut bad = good.clone();
        if bad.nnz() >= 2 {
            bad.indices.swap(0, 1);
        }
        // swapping within a row breaks sortedness (rows with ≥ 2 entries)
        if bad.indptr[1] >= 2 {
            assert!(bad.check_invariants().is_err(), "unsorted row");
        }

        let mut bad = good.clone();
        if bad.nnz() > 0 {
            bad.indices[0] = bad.n_cols;
            assert!(bad.check_invariants().is_err(), "out-of-bounds column");
        }

        let mut bad = good;
        bad.data.pop();
        assert!(bad.check_invariants().is_err(), "data/indices length");
    }

    #[test]
    fn threaded_matmul_dense_bitwise_equals_sequential() {
        // sized past the parallel threshold (2·nnz·k > 2^22) so the
        // row-partitioned path actually fans out
        let mut rng = Rng::new(12);
        let a = random_csr(2000, 2000, 40_000, &mut rng);
        let b = Mat::randn(2000, 64, &mut rng);
        let seq = a.matmul_dense_with(&b, crate::linalg::threads::Threads::SINGLE);
        let par = a.matmul_dense_with(&b, crate::linalg::threads::Threads(4));
        assert_eq!(seq.as_slice(), par.as_slice(), "spmm not bitwise stable");
        // and both match the dense product
        let want = a.to_dense().matmul(&b);
        let mut diff = seq.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-10);
    }
}
