//! Compressed sparse row matrix with the operations the trackers need:
//! SpMV, SpMM against dense panels, transpose products, and sparse
//! difference (for Laplacian deltas).

use crate::linalg::lanczos::LinOp;
use crate::linalg::mat::Mat;

/// CSR sparse matrix.
#[derive(Clone, Debug, Default)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Empty rows×cols matrix.
    pub fn empty(rows: usize, cols: usize) -> Csr {
        Csr { n_rows: rows, n_cols: cols, indptr: vec![0; rows + 1], indices: vec![], data: vec![] }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Entry lookup (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        match self.indices[lo..hi].binary_search(&j) {
            Ok(pos) => self.data[lo + pos],
            Err(_) => 0.0,
        }
    }

    /// Row view: (column indices, values).
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// y += alpha * A x.
    pub fn matvec_acc(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n_cols);
        debug_assert_eq!(y.len(), self.n_rows);
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            let mut s = 0.0;
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                s += v * x[j];
            }
            y[i] += alpha * s;
        }
    }

    /// A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.matvec_acc(1.0, x, &mut y);
        y
    }

    /// A · B for a dense panel B (n_cols × m) → (n_rows × m).
    pub fn matmul_dense(&self, b: &Mat) -> Mat {
        assert_eq!(self.n_cols, b.rows());
        let mut out = Mat::zeros(self.n_rows, b.cols());
        for j in 0..b.cols() {
            let bj = b.col(j);
            let oj = out.col_mut(j);
            for i in 0..self.n_rows {
                let lo = self.indptr[i];
                let hi = self.indptr[i + 1];
                let mut s = 0.0;
                for p in lo..hi {
                    s += self.data[p] * bj[self.indices[p]];
                }
                oj[i] = s;
            }
        }
        out
    }

    /// Aᵀ · B for a dense panel B (n_rows × m) → (n_cols × m),
    /// without materializing the transpose.
    pub fn t_matmul_dense(&self, b: &Mat) -> Mat {
        assert_eq!(self.n_rows, b.rows());
        let mut out = Mat::zeros(self.n_cols, b.cols());
        for j in 0..b.cols() {
            let bj = b.col(j);
            let oj = out.col_mut(j);
            for i in 0..self.n_rows {
                let lo = self.indptr[i];
                let hi = self.indptr[i + 1];
                let bij = bj[i];
                if bij == 0.0 {
                    continue;
                }
                for p in lo..hi {
                    oj[self.indices[p]] += self.data[p] * bij;
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Row sums (degrees for a 0/1 adjacency).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.n_rows)
            .map(|i| self.row(i).1.iter().sum())
            .collect()
    }

    /// self − other as a new sparse matrix (dimensions must match; `other`
    /// may be logically padded when smaller — see `sub_padded`).
    pub fn sub(&self, other: &Csr) -> Csr {
        assert_eq!((self.n_rows, self.n_cols), (other.n_rows, other.n_cols));
        self.sub_padded(other)
    }

    /// self − P(other) where P pads `other` with zero rows/cols up to
    /// self's shape.  This is exactly Δ = Â − Ā of paper Eq. (2).
    pub fn sub_padded(&self, other: &Csr) -> Csr {
        assert!(other.n_rows <= self.n_rows && other.n_cols <= self.n_cols);
        let mut coo = crate::sparse::coo::Coo::new(self.n_rows, self.n_cols);
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                coo.push(i, j, v);
            }
        }
        for i in 0..other.n_rows {
            let (cols, vals) = other.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                coo.push(i, j, -v);
            }
        }
        coo.to_csr()
    }

    /// Dense copy (tests / tiny matrices only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                m.set(i, j, v);
            }
        }
        m
    }

    /// Check structural symmetry (values too).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                if (self.get(j, i) - v).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl LinOp for Csr {
    fn dim(&self) -> usize {
        assert_eq!(self.n_rows, self.n_cols);
        self.n_rows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        y.fill(0.0);
        self.matvec_acc(1.0, x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::sparse::coo::Coo;

    fn random_csr(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Csr {
        let mut coo = Coo::new(rows, cols);
        for _ in 0..nnz {
            coo.push(rng.below(rows), rng.below(cols), rng.normal());
        }
        coo.to_csr()
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::new(1);
        let a = random_csr(20, 15, 60, &mut rng);
        let d = a.to_dense();
        let x: Vec<f64> = (0..15).map(|i| i as f64 * 0.3 - 1.0).collect();
        let y = a.matvec(&x);
        let want = crate::linalg::blas::gemv(&d, &x);
        for i in 0..20 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_dense_matches() {
        let mut rng = Rng::new(2);
        let a = random_csr(25, 18, 80, &mut rng);
        let b = Mat::randn(18, 7, &mut rng);
        let got = a.matmul_dense(&b);
        let want = a.to_dense().matmul(&b);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-12);
    }

    #[test]
    fn t_matmul_dense_matches() {
        let mut rng = Rng::new(3);
        let a = random_csr(25, 18, 80, &mut rng);
        let b = Mat::randn(25, 5, &mut rng);
        let got = a.t_matmul_dense(&b);
        let want = a.to_dense().t().matmul(&b);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-12);
    }

    #[test]
    fn sub_padded_reconstructs_delta() {
        // Â (4x4) minus padded A (3x3): exactly paper Eq. (2).
        let mut a = Coo::new(3, 3);
        a.push_sym(0, 1, 1.0);
        a.push_sym(1, 2, 1.0);
        let a = a.to_csr();
        let mut ahat = Coo::new(4, 4);
        ahat.push_sym(0, 1, 1.0); // kept
        ahat.push_sym(0, 2, 1.0); // added (K block)
        ahat.push_sym(2, 3, 1.0); // new node edge (G block)
        let ahat = ahat.to_csr();
        let delta = ahat.sub_padded(&a);
        assert_eq!(delta.get(1, 2), -1.0); // removed edge
        assert_eq!(delta.get(0, 2), 1.0);
        assert_eq!(delta.get(2, 3), 1.0);
        assert_eq!(delta.get(0, 1), 0.0);
        assert!(delta.is_symmetric(0.0));
    }

    #[test]
    fn row_sums_are_degrees() {
        let mut c = Coo::new(3, 3);
        c.push_sym(0, 1, 1.0);
        c.push_sym(0, 2, 1.0);
        let a = c.to_csr();
        assert_eq!(a.row_sums(), vec![2.0, 1.0, 1.0]);
    }
}
