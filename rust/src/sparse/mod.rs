//! Sparse matrix substrate: COO assembly, symmetric CSR operations, and
//! the structured evolving-graph update matrix Δ of paper Eq. (2).

pub mod coo;
pub mod csr;
pub mod delta;
