//! Coordinate-format sparse matrix: the assembly format for graph deltas.

use crate::sparse::csr::Csr;

/// COO triplets (row, col, value).  Duplicates are summed on conversion.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(usize, usize, f64)>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Coo {
        Coo { rows, cols, entries: Vec::new() }
    }

    /// Add a single entry.
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols, "({i},{j}) out of {}x{}", self.rows, self.cols);
        if v != 0.0 {
            self.entries.push((i, j, v));
        }
    }

    /// Add both (i,j) and (j,i) — symmetric assembly (square only).
    pub fn push_sym(&mut self, i: usize, j: usize, v: f64) {
        assert_eq!(self.rows, self.cols);
        self.push(i, j, v);
        if i != j {
            self.push(j, i, v);
        }
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Convert to CSR, summing duplicates and dropping exact zeros.
    pub fn to_csr(&self) -> Csr {
        let mut entries = self.entries.clone();
        entries.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(entries.len());
        let mut data = Vec::with_capacity(entries.len());
        let mut it = entries.into_iter().peekable();
        while let Some((i, j, mut v)) = it.next() {
            while let Some(&(i2, j2, v2)) = it.peek() {
                if i2 == i && j2 == j {
                    v += v2;
                    it.next();
                } else {
                    break;
                }
            }
            if v != 0.0 {
                indices.push(j);
                data.push(v);
                indptr[i + 1] += 1;
            }
        }
        for r in 0..self.rows {
            indptr[r + 1] += indptr[r];
        }
        Csr { n_rows: self.rows, n_cols: self.cols, indptr, indices, data }.debug_validate()
    }

    /// y += alpha * (self · x) without converting to CSR.
    pub fn matvec_acc(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        for &(i, j, v) in &self.entries {
            y[i] += alpha * v * x[j];
        }
    }

    /// Frobenius norm (duplicates summed first).
    pub fn fro_norm(&self) -> f64 {
        self.to_csr().data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_csr_sums_duplicates_and_drops_zeros() {
        let mut c = Coo::new(3, 3);
        c.push(0, 1, 1.0);
        c.push(0, 1, 2.0);
        c.push(2, 2, 5.0);
        c.push(1, 0, 3.0);
        c.push(1, 0, -3.0); // cancels to zero -> dropped
        let m = c.to_csr();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.get(2, 2), 5.0);
        assert_eq!(m.get(1, 0), 0.0);
    }

    #[test]
    fn push_sym() {
        let mut c = Coo::new(4, 4);
        c.push_sym(1, 2, -1.0);
        c.push_sym(3, 3, 2.0);
        let m = c.to_csr();
        assert_eq!(m.get(1, 2), -1.0);
        assert_eq!(m.get(2, 1), -1.0);
        assert_eq!(m.get(3, 3), 2.0);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn matvec_acc_matches_csr() {
        let mut c = Coo::new(3, 4);
        c.push(0, 3, 2.0);
        c.push(2, 0, -1.0);
        c.push(0, 3, 1.0);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.0; 3];
        c.matvec_acc(1.0, &x, &mut y);
        let mut want = vec![0.0; 3];
        c.to_csr().matvec_acc(1.0, &x, &mut want);
        assert_eq!(y, want);
    }
}
