//! `grest` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   table2                         print the dataset registry (Table 2)
//!   experiment <id> [--quick]      regenerate a paper table/figure
//!                                  (ids: fig2 fig3 fig4 fig5 table3 fig6 all)
//!   track [--dataset D] [--k K] [--tracker SPEC] [--trackers A,B,C]
//!         [--t T] [--seed S] [--eval-every N] [--quick] [--xla]
//!                                  run one tracker over one dataset, or a
//!                                  side-by-side comparison of several
//!   serve-demo [--events N] [--tracker SPEC] [--serve-precision f64|f32]
//!              [--durability DIR] [--checkpoint-every N]
//!                                  run the streaming coordinator demo;
//!                                  with --durability, events WAL to DIR,
//!                                  state checkpoints every N flushes
//!                                  (default 16), and a re-run against the
//!                                  same DIR recovers and resumes
//!   fleet [--tenants N] [--workers W] [--events E] [--tracker SPEC]
//!                                  run N tenants on a W-worker shared pool
//!   generate --dataset D --out F   write a synthetic dataset edge list
//!
//! Global flags:
//!   --threads N                    dense-kernel worker budget for the
//!                                  G-REST family (0 = auto, 1 = serial)
//!
//! Trackers are addressed by the declarative spec grammar
//! `name[:key=value,...][@backend]` — e.g. `grest3`, `grest-rsvd:l=32,p=16`,
//! `timers:theta=0.01`, `grest3@xla`.  `--tracker list` prints the full
//! registry; every legacy tracker name keeps working as an alias.
//!
//! Argument parsing is hand-rolled (offline build: no clap); unknown
//! flags are errors, and each subcommand declares which flags it takes.

use grest::eval::experiments::{self, ExpConfig};
use grest::eval::harness::{reference_run, run_trackers};
use grest::eval::table::{fmt_secs, Table};
use grest::graph::datasets::{self, Kind};
use grest::graph::scenario::DynamicScenario;
use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::tracking::{self, Backend, EigTracker, TrackerSpec};
use std::collections::HashMap;

/// One CLI flag: its name and whether it consumes a value.
#[derive(Clone, Copy)]
struct Flag {
    name: &'static str,
    takes_value: bool,
}

const fn vflag(name: &'static str) -> Flag {
    Flag { name, takes_value: true }
}

const fn bflag(name: &'static str) -> Flag {
    Flag { name, takes_value: false }
}

/// Flags accepted by each subcommand (plus the global `--threads`).
fn known_flags(cmd: &str) -> Vec<Flag> {
    let mut flags = vec![vflag("threads")];
    match cmd {
        "experiment" | "table2" => flags.push(bflag("quick")),
        "track" => flags.extend([
            vflag("dataset"),
            vflag("k"),
            vflag("t"),
            vflag("tracker"),
            vflag("trackers"),
            vflag("seed"),
            vflag("eval-every"),
            bflag("quick"),
            bflag("xla"),
        ]),
        "serve-demo" => flags.extend([
            vflag("events"),
            vflag("tracker"),
            vflag("seed"),
            vflag("serve-precision"),
            vflag("durability"),
            vflag("checkpoint-every"),
        ]),
        "fleet" => flags.extend([
            vflag("tenants"),
            vflag("workers"),
            vflag("events"),
            vflag("tracker"),
            vflag("seed"),
        ]),
        "generate" => flags.extend([vflag("dataset"), vflag("out")]),
        _ => {}
    }
    flags
}

/// Split `args` into positionals and `--flag` values against a table of
/// known flags.  Value-taking flags always consume the next argument
/// (so negative numbers and other `-`-leading values are never
/// mis-parsed as booleans), boolean flags never do, and unknown flags
/// are an error rather than silently ignored.
fn parse_flags(
    args: &[String],
    known: &[Flag],
) -> anyhow::Result<(Vec<String>, HashMap<String, String>)> {
    let mut positional = vec![];
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        i += 1;
        let Some(name) = a.strip_prefix("--") else {
            positional.push(a.clone());
            continue;
        };
        let (key, inline) = match name.split_once('=') {
            Some((k, v)) => (k, Some(v.to_string())),
            None => (name, None),
        };
        let Some(flag) = known.iter().find(|f| f.name == key) else {
            let names: Vec<String> = known.iter().map(|f| format!("--{}", f.name)).collect();
            anyhow::bail!("unknown flag --{key}; expected one of: {}", names.join(", "));
        };
        let value = match (flag.takes_value, inline) {
            (true, Some(v)) => v,
            (false, Some(_)) => anyhow::bail!("flag --{key} does not take a value"),
            (true, None) => {
                let Some(v) = args.get(i) else {
                    anyhow::bail!("flag --{key} expects a value");
                };
                i += 1;
                v.clone()
            }
            (false, None) => "true".to_string(),
        };
        flags.insert(key.to_string(), value);
    }
    Ok((positional, flags))
}

fn flag_num<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> anyhow::Result<T> {
    match flags.get(key) {
        None => Ok(default),
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {s:?}")),
    }
}

const COMMANDS: &[&str] = &["table2", "experiment", "track", "serve-demo", "fleet", "generate"];

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        print_usage();
        return Ok(());
    }
    // the subcommand is located by name, so flags may precede it
    // (`grest --threads 8 track ...`, `grest --quick experiment fig2`)
    let Some(cmd_idx) = args.iter().position(|a| COMMANDS.contains(&a.as_str())) else {
        print_usage();
        return Ok(());
    };
    let cmd = args[cmd_idx].clone();
    let rest: Vec<String> = args
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != cmd_idx)
        .map(|(_, a)| a.clone())
        .collect();
    let (pos, flags) = parse_flags(&rest, &known_flags(&cmd))?;
    let threads = Threads(flag_num(&flags, "threads", 0usize)?);
    let mut cfg = if flags.contains_key("quick") { ExpConfig::quick() } else { ExpConfig::paper() };
    cfg.threads = threads;

    match cmd.as_str() {
        "table2" => {
            println!("{}", experiments::table2().render());
        }
        "experiment" => {
            let id = pos.first().map(|s| s.as_str()).unwrap_or("all");
            run_experiment(id, &cfg)?;
        }
        "track" => {
            cmd_track(&flags, threads)?;
        }
        "serve-demo" => {
            cmd_serve_demo(&flags, threads)?;
        }
        "fleet" => {
            cmd_fleet(&flags, threads)?;
        }
        "generate" => {
            cmd_generate(&flags)?;
        }
        _ => {
            print_usage();
        }
    }
    Ok(())
}

fn print_usage() {
    println!(
        "grest — Graph Rayleigh-Ritz Eigenspace Tracking\n\
         usage: grest <table2|experiment|track|serve-demo|fleet|generate> [flags]\n\
         trackers are declarative specs: name[:key=value,...][@backend]\n\
         (`grest track --tracker list` prints the registry)\n\
         see rust/src/main.rs header for details"
    );
}

fn run_experiment(id: &str, cfg: &ExpConfig) -> anyhow::Result<()> {
    let run_acc = |kind: Kind, label: &str| {
        let (_, ta, tb, tt) = experiments::timed(label, || {
            experiments::figure_accuracy_runtime(kind, cfg)
        });
        println!("== {label}(a): time-averaged psi for leading 3 eigenvectors ==");
        println!("{}", ta.render());
        println!("== {label}(b): mean psi over leading {} vs t ==", cfg.angles_k);
        println!("{}", tb.render());
        println!("== Fig4 slice: total runtimes ==");
        println!("{}", tt.render());
        let _ = ta.write_csv(&format!("{label}_a"));
        let _ = tb.write_csv(&format!("{label}_b"));
        let _ = tt.write_csv(&format!("{label}_runtime"));
    };
    match id {
        "table2" => println!("{}", experiments::table2().render()),
        "fig2" | "fig4a" => run_acc(Kind::Static, "fig2"),
        "fig3" | "fig4b" => run_acc(Kind::Dynamic, "fig3"),
        "fig4" => {
            run_acc(Kind::Static, "fig2");
            run_acc(Kind::Dynamic, "fig3");
        }
        "fig5" => {
            let grid = if cfg.mc <= 1 && cfg.t_override.is_some() {
                vec![8usize, 16]
            } else {
                vec![10usize, 20, 40, 80]
            };
            let t = experiments::timed("fig5", || experiments::fig5_rsvd_tradeoff(cfg, &grid));
            println!("== Fig5: RSVD L/P trade-off (CM-Collab) ==");
            println!("{}", t.render());
            let _ = t.write_csv("fig5");
        }
        "table3" => {
            let t = experiments::timed("table3", || {
                experiments::table3_centrality(cfg, &[100, 1000])
            });
            println!("== Table 3: central-node overlap ==");
            println!("{}", t.render());
            let _ = t.write_csv("table3");
        }
        "fig6" => {
            let n = if cfg.extra_scale > 1 { 500 } else { 2000 };
            let t = experiments::timed("fig6", || {
                experiments::fig6_clustering(
                    cfg,
                    n,
                    &[0.002, 0.005, 0.01, 0.02],
                    &[2, 4, 6, 8],
                )
            });
            println!("== Fig6: clustering ARI ratio ==");
            println!("{}", t.render());
            let _ = t.write_csv("fig6");
        }
        "all" => {
            for e in ["fig2", "fig3", "fig5", "table3", "fig6"] {
                run_experiment(e, cfg)?;
            }
        }
        other => anyhow::bail!("unknown experiment id {other}"),
    }
    Ok(())
}

/// Splice a `key=value` continuation into a spec string: before any
/// trailing `@backend` suffix, opening the `:` param section if the
/// spec has none yet.
fn append_spec_param(prev: &mut String, param: &str) {
    let (body_end, suffix) = match prev.rfind('@') {
        Some(at) => (at, prev[at..].to_string()),
        None => (prev.len(), String::new()),
    };
    let mut body = prev[..body_end].to_string();
    body.push(if body.contains(':') { ',' } else { ':' });
    body.push_str(param);
    body.push_str(&suffix);
    *prev = body;
}

/// Split a `--trackers` list on commas, except that a `key=value`
/// fragment continues the *previous* spec's parameter list (the spec
/// grammar itself uses commas between params, so
/// `grest-rsvd:l=16,p=8,trip` is two specs, not three).
fn split_tracker_list(list: &str) -> Vec<String> {
    let mut out: Vec<String> = vec![];
    for part in list.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let continues_params = match part.split_once('=') {
            // `l=16` continues params; `grest3:n=200` starts a new spec
            Some((key, _)) => !key.contains(':') && !key.contains('@'),
            None => false,
        };
        match out.last_mut() {
            Some(prev) if continues_params => append_spec_param(prev, part),
            _ => out.push(part.to_string()),
        }
    }
    out
}

/// Parse one tracker spec from the CLI, applying the `--threads`
/// fallback, the `--xla` backend override, and — for XLA specs — tier
/// capacities sized from the scenario.
fn cli_spec(
    text: &str,
    threads: Threads,
    use_xla: bool,
    sc: &DynamicScenario,
    k: usize,
) -> anyhow::Result<TrackerSpec> {
    // --xla is an alias for appending `@xla`; apply it before parsing so
    // backend-gated params (n=, m=) validate against the real backend.
    // An explicit `@backend` in the spec wins over the flag.
    let text = text.trim();
    let mut spec = if use_xla && !text.contains('@') {
        TrackerSpec::parse(&format!("{text}@xla"))?
    } else {
        TrackerSpec::parse(text)?
    };
    apply_cli_defaults(&mut spec, threads, sc.max_nodes());
    if spec.backend == Backend::Xla && spec.panel_cap == 0 {
        // panel width: K cols of ΔX̄ plus per-step expansion
        let max_s = sc.steps.iter().map(|s| s.delta.s_new).max().unwrap_or(0);
        spec.panel_cap = k + max_s.min(128);
    }
    Ok(spec)
}

/// Scenario-independent CLI defaulting, shared by `track` and
/// `serve-demo`: the `--threads` fallback for native G-REST specs and
/// the XLA tier row capacity when the spec leaves it unsized.
fn apply_cli_defaults(spec: &mut TrackerSpec, threads: Threads, xla_n_cap: usize) {
    // --threads drives the native dense kernels only
    if spec.algo.is_grest()
        && spec.backend == Backend::Native
        && spec.threads == Threads::AUTO
    {
        spec.threads = threads;
    }
    if spec.backend == Backend::Xla && spec.n_cap == 0 {
        spec.n_cap = xla_n_cap;
    }
}

fn cmd_track(flags: &HashMap<String, String>, threads: Threads) -> anyhow::Result<()> {
    let dataset = flags.get("dataset").map(|s| s.as_str()).unwrap_or("CM-Collab");
    let quick = flags.contains_key("quick");
    let k: usize = flag_num(flags, "k", if quick { 16 } else { 64 })?;
    let t_steps: Option<usize> = match flags.get("t") {
        None => {
            if quick {
                Some(4)
            } else {
                None
            }
        }
        Some(s) => Some(s.parse().map_err(|_| {
            anyhow::anyhow!("--t expects a number of time steps, got {s:?}")
        })?),
    };
    let seed: u64 = flag_num(flags, "seed", 1u64)?;
    let eval_every: usize = flag_num(flags, "eval-every", 1usize)?;
    let tracker_arg = flags.get("tracker").map(|s| s.as_str()).unwrap_or("grest3");
    let use_xla = flags.contains_key("xla");
    if flags.contains_key("tracker") && flags.contains_key("trackers") {
        anyhow::bail!(
            "pass either --tracker (single run) or --trackers (comparison), not both"
        );
    }

    if tracker_arg == "list" {
        println!("{}", tracking::spec::list_help());
        return Ok(());
    }

    let mut spec = datasets::by_name(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?;
    if quick {
        spec = experiments::scale_spec(&spec, 4);
    }
    let mut rng = Rng::new(seed);
    let sc = datasets::scenario_for(&spec, t_steps, &mut rng);
    println!(
        "dataset {dataset}: N0={} -> N={} over {} steps, total delta nnz {}",
        sc.initial.n_rows,
        sc.max_nodes(),
        sc.t_steps(),
        sc.total_delta_nnz()
    );

    if let Some(list) = flags.get("trackers") {
        if flags.contains_key("eval-every") {
            eprintln!(
                "warning: --eval-every only thins the single-tracker loop; \
                 comparison mode needs the per-step reference for psi and ignores it"
            );
        }
        let specs = split_tracker_list(list)
            .iter()
            .map(|s| cli_spec(s, threads, use_xla, &sc, k))
            .collect::<anyhow::Result<Vec<_>>>()?;
        if specs.is_empty() {
            anyhow::bail!("--trackers expects a comma-separated list of tracker specs");
        }
        return cmd_track_compare(&specs, &sc, k);
    }

    let tspec = cli_spec(tracker_arg, threads, use_xla, &sc, k)?;
    println!("tracker: {tspec} ({})", tspec.display_name());
    let init = tracking::init_eigenpairs(&sc.initial, k, 7);
    let mut tracker = tspec.build_seeded(&sc.initial, &init, 7)?;

    let t0 = std::time::Instant::now();
    let n_steps = sc.steps.len();
    for (i, step) in sc.steps.iter().enumerate() {
        let s0 = std::time::Instant::now();
        tracker.update(&step.delta)?;
        let update_t = s0.elapsed();
        // the per-step Lanczos reference dominates runtime on large
        // datasets; --eval-every N thins it (0 disables entirely)
        let do_eval = eval_every != 0 && ((i + 1) % eval_every == 0 || i + 1 == n_steps);
        let psi_col = if do_eval {
            let reference =
                tracking::traits::init_eigenpairs(&step.adjacency, k, 100 + i as u64);
            let psi = grest::eval::angle::mean_angle(tracker.current(), &reference, 3.min(k));
            format!(" mean_psi(top3)={psi:.4}")
        } else {
            String::new()
        };
        println!(
            "step {:>3}: N={:>6} S={:>4} nnz(d)={:>6} update={}{}",
            i + 1,
            step.adjacency.n_rows,
            step.delta.s_new,
            step.delta.nnz(),
            fmt_secs(update_t),
            psi_col
        );
    }
    println!("total tracking time {}", fmt_secs(t0.elapsed()));
    Ok(())
}

/// `--trackers a,b,c`: run the harness over an arbitrary spec list and
/// emit one side-by-side table/CSV keyed by spec-derived names.
fn cmd_track_compare(
    specs: &[TrackerSpec],
    sc: &DynamicScenario,
    k: usize,
) -> anyhow::Result<()> {
    for s in specs {
        s.validate_buildable()
            .map_err(|e| anyhow::anyhow!("cannot run `{s}`: {e}"))?;
    }
    println!(
        "comparing {} trackers: {}",
        specs.len(),
        specs.iter().map(|s| s.display_name()).collect::<Vec<_>>().join(", ")
    );
    let angles_k = 3.min(k);
    let reference = reference_run(sc, k, 100);
    let results = run_trackers(sc, &reference, k, angles_k, specs, 7)?;

    let mut table = Table::new(&[
        "Tracker",
        "Spec",
        "mean_psi_top3",
        "psi_1",
        "psi_2",
        "psi_3",
        "total_time",
        "Mflop_per_step",
    ]);
    for r in &results {
        table.row(vec![
            r.name.clone(),
            r.spec.clone(),
            format!("{:.4}", r.grand_mean_angle(angles_k)),
            format!("{:.4}", r.avg_angle_for_index(0)),
            format!("{:.4}", r.avg_angle_for_index(1)),
            format!("{:.4}", r.avg_angle_for_index(2)),
            fmt_secs(r.total_time),
            format!("{:.2}", r.mean_flops_per_step() / 1e6),
        ]);
    }
    table.row(vec![
        "eigs (reference)".into(),
        "eigs".into(),
        "0.0000".into(),
        "0.0000".into(),
        "0.0000".into(),
        "0.0000".into(),
        fmt_secs(reference.total_time),
        "-".into(),
    ]);
    println!("{}", table.render());
    match table.write_csv("track_compare") {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write CSV: {e}"),
    }
    Ok(())
}

fn cmd_serve_demo(flags: &HashMap<String, String>, threads: Threads) -> anyhow::Result<()> {
    use grest::coordinator::{BatchPolicy, ServiceConfig, TrackingService};
    use grest::graph::stream::GraphEvent;
    use grest::linalg::ServePrecision;
    let n_events: usize = flag_num(flags, "events", 2000usize)?;
    let seed: u64 = flag_num(flags, "seed", 5u64)?;
    let serve_precision = match flags.get("serve-precision").map(|s| s.as_str()) {
        None | Some("f64") => ServePrecision::F64,
        Some("f32") => ServePrecision::F32,
        Some(other) => anyhow::bail!("--serve-precision expects f64 or f32, got `{other}`"),
    };
    let durability = match flags.get("durability") {
        None => None,
        Some(dir) => {
            let mut d = grest::coordinator::DurabilityConfig::new(dir.as_str());
            d.checkpoint_every = flag_num(
                flags,
                "checkpoint-every",
                grest::coordinator::durability::DurabilityConfig::DEFAULT_CHECKPOINT_EVERY,
            )?;
            println!(
                "durability: wal + checkpoints under {dir} (checkpoint every {} flushes)",
                d.checkpoint_every
            );
            Some(d)
        }
    };
    let mut tspec = TrackerSpec::parse(
        flags.get("tracker").map(|s| s.as_str()).unwrap_or("grest3"),
    )?;
    // the event stream grows the graph past the 500-node seed (ids up
    // to 700); size any XLA tier with headroom so check_fits doesn't
    // trip mid-stream
    apply_cli_defaults(&mut tspec, threads, 1024);
    println!("serving tracker: {tspec} ({})", tspec.display_name());
    let mut rng = Rng::new(3);
    let g = grest::graph::generators::erdos_renyi(500, 0.02, &mut rng);
    let svc = TrackingService::spawn(ServiceConfig {
        initial: g,
        k: 16,
        policy: BatchPolicy::Either { events: 64, new_nodes: 16, max_age: None },
        seed,
        tracker: tspec,
        threads,
        serve_precision,
        durability,
    })?;
    {
        let m = svc.handle.metrics();
        if m.recoveries.get() > 0 {
            let snap = svc.handle.snapshot();
            println!(
                "recovered: v{} over {} nodes ({} wal frames replayed, {} events)",
                snap.version,
                snap.n_nodes,
                m.replayed_frames.get(),
                m.replayed_events.get()
            );
        }
    }
    let h = svc.handle.clone();
    let t0 = std::time::Instant::now();
    for i in 0..n_events as u64 {
        let ev = if rng.flip(0.85) {
            GraphEvent::AddEdge(rng.below(500) as u64, rng.below(700) as u64)
        } else {
            GraphEvent::RemoveEdge(rng.below(500) as u64, rng.below(500) as u64)
        };
        h.ingest(vec![ev])?;
        if i % 500 == 0 {
            let snap = h.snapshot();
            println!(
                "event {:>6}: snapshot v{} over {} nodes, lambda1={:.3}",
                i,
                snap.version,
                snap.n_nodes,
                snap.pairs.values.first().copied().unwrap_or(0.0)
            );
        }
    }
    h.flush()?;
    let snap = h.snapshot();
    println!(
        "final: v{} nodes={} | ingest+track {} for {n_events} events",
        snap.version,
        snap.n_nodes,
        fmt_secs(t0.elapsed())
    );
    // the read path: every query below is served from the snapshot by
    // the lock-free QueryEngine — the worker is never consulted
    let timed_query = |f: &dyn Fn()| {
        let t = std::time::Instant::now();
        f();
        t.elapsed()
    };
    let central = h.central_nodes(5);
    let t_uncached = timed_query(&|| {
        let _ = h.central_nodes(7);
    });
    let t_cached = timed_query(&|| {
        let _ = h.central_nodes(7);
    });
    println!("top-5 central (external ids): {central:?}");
    println!(
        "central-nodes latency: {} uncached, {} cached (version-keyed memo)",
        fmt_secs(t_uncached),
        fmt_secs(t_cached)
    );
    let assignment = h.clusters(4);
    let mut sizes = vec![0usize; 4];
    for &l in &assignment.labels {
        sizes[l.min(3)] += 1;
    }
    println!("clusters k=4 at v{}: sizes {:?}", assignment.version, sizes);
    if let Some(sim) = h.similar_to(central[0], 3) {
        println!("most similar to node {}: {:?}", central[0], sim);
    }
    let m = h.metrics();
    println!(
        "snapshot age {:?} | query cache: {} computed, {} cached (hit-rate {:.0}%)",
        h.snapshot_age(),
        m.queries_computed.get(),
        m.queries_cached.get(),
        100.0 * m.query_cache_hit_rate(),
    );
    println!("metrics: {}", m.report());
    svc.join();
    Ok(())
}

/// `grest fleet`: the multi-tenant coordinator demo — N independent
/// tenant graphs on a W-worker shared pool, round-robin ingest, then a
/// per-tenant report plus the fleet-wide metrics roll-up.
fn cmd_fleet(flags: &HashMap<String, String>, threads: Threads) -> anyhow::Result<()> {
    use grest::coordinator::{BatchPolicy, Fleet, FleetConfig, ServiceConfig, TenantId};
    use grest::graph::stream::GraphEvent;
    let tenants: usize = flag_num(flags, "tenants", 8usize)?;
    let workers: usize = flag_num(flags, "workers", 4usize)?;
    let n_events: usize = flag_num(flags, "events", 400usize)?;
    let seed: u64 = flag_num(flags, "seed", 5u64)?;
    let mut tspec = TrackerSpec::parse(
        flags.get("tracker").map(|s| s.as_str()).unwrap_or("grest3"),
    )?;
    apply_cli_defaults(&mut tspec, threads, 1024);
    let fleet = Fleet::new(FleetConfig { workers });
    println!(
        "fleet: {tenants} tenants of `{tspec}` on {} pool workers",
        fleet.workers()
    );
    for t in 0..tenants as u64 {
        let mut rng = Rng::new(seed + t);
        let g = grest::graph::generators::erdos_renyi(200, 0.03, &mut rng);
        fleet.spawn(
            TenantId(t),
            ServiceConfig {
                initial: g,
                k: 8,
                policy: BatchPolicy::Either {
                    events: 32,
                    new_nodes: 8,
                    // the deadline arm keeps low-rate tenants fresh
                    // with no manual flush
                    max_age: Some(std::time::Duration::from_millis(200)),
                },
                seed: seed + t,
                tracker: tspec.clone(),
                threads,
                serve_precision: grest::linalg::ServePrecision::F64,
                durability: None,
            },
        )?;
    }
    let t0 = std::time::Instant::now();
    let mut rngs: Vec<Rng> =
        (0..tenants as u64).map(|t| Rng::new(900 + seed + t)).collect();
    for _ in 0..n_events {
        // round-robin: one event per tenant per lap
        for (t, rng) in rngs.iter_mut().enumerate() {
            let h = fleet.get(TenantId(t as u64)).expect("tenant is live");
            let ev = if rng.flip(0.85) {
                GraphEvent::AddEdge(rng.below(200) as u64, rng.below(260) as u64)
            } else {
                GraphEvent::RemoveEdge(rng.below(200) as u64, rng.below(200) as u64)
            };
            h.ingest(vec![ev])?;
        }
    }
    let mut table =
        Table::new(&["Tenant", "version", "nodes", "batches", "p95_update", "Mflops"]);
    for id in fleet.ids() {
        let h = fleet.get(id).expect("tenant is live");
        let v = h.flush()?;
        let snap = h.snapshot();
        let m = h.metrics();
        table.row(vec![
            id.to_string(),
            v.to_string(),
            snap.n_nodes.to_string(),
            m.batches_applied.get().to_string(),
            format!("{:?}", m.update_latency.quantile(0.95)),
            format!("{:.2}", m.flops_applied.get() as f64 / 1e6),
        ]);
    }
    println!("{}", table.render());
    println!(
        "ingest+track {} for {n_events} events x {tenants} tenants",
        fmt_secs(t0.elapsed())
    );
    println!("fleet rollup: {}", fleet.metrics_rollup().report());
    fleet.join();
    Ok(())
}

fn cmd_generate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let dataset = flags
        .get("dataset")
        .ok_or_else(|| anyhow::anyhow!("--dataset required"))?;
    let out = flags.get("out").ok_or_else(|| anyhow::anyhow!("--out required"))?;
    let spec = datasets::by_name(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?;
    let mut rng = Rng::new(11);
    match spec.kind {
        Kind::Static => {
            let g = datasets::build_static(&spec, &mut rng);
            grest::graph::io::save_graph(&g, std::path::Path::new(out))?;
            println!("wrote {} ({} nodes, {} edges)", out, g.n_nodes(), g.n_edges());
        }
        Kind::Dynamic => {
            let stream = datasets::build_stream(&spec, &mut rng);
            let mut text = String::new();
            for (i, (u, v)) in stream.iter().enumerate() {
                text.push_str(&format!("{u} {v} {i}\n"));
            }
            std::fs::write(out, text)?;
            println!("wrote {} ({} timestamped edges)", out, stream.len());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn value_flags_consume_negative_numbers() {
        // regression: a value after a flag must be consumed even when it
        // starts with `-`, never downgraded to a boolean
        let (pos, flags) = parse_flags(&sv(&["--t", "-1"]), &known_flags("track")).unwrap();
        assert!(pos.is_empty());
        assert_eq!(flags.get("t").map(|s| s.as_str()), Some("-1"));
        let (_, flags) = parse_flags(&sv(&["--t", "0"]), &known_flags("track")).unwrap();
        assert_eq!(flags.get("t").map(|s| s.as_str()), Some("0"));
    }

    #[test]
    fn unknown_flags_are_errors_not_ignored() {
        let err = parse_flags(&sv(&["--bogus", "1"]), &known_flags("track")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--bogus"), "{msg}");
        assert!(msg.contains("--tracker"), "should list known flags: {msg}");
    }

    #[test]
    fn inline_and_separate_values_agree() {
        let (_, a) = parse_flags(&sv(&["--k=5"]), &known_flags("track")).unwrap();
        let (_, b) = parse_flags(&sv(&["--k", "5"]), &known_flags("track")).unwrap();
        assert_eq!(a.get("k"), b.get("k"));
    }

    #[test]
    fn boolean_flags_never_swallow_the_next_arg() {
        let (pos, flags) =
            parse_flags(&sv(&["--quick", "fig2", "--t", "3"]), &known_flags("track")).unwrap();
        assert_eq!(pos, vec!["fig2".to_string()]);
        assert_eq!(flags.get("quick").map(|s| s.as_str()), Some("true"));
        assert_eq!(flags.get("t").map(|s| s.as_str()), Some("3"));
    }

    #[test]
    fn boolean_flag_with_inline_value_errors() {
        let err = parse_flags(&sv(&["--quick=yes"]), &known_flags("track")).unwrap_err();
        assert!(err.to_string().contains("does not take a value"));
    }

    #[test]
    fn missing_value_errors() {
        let err = parse_flags(&sv(&["--t"]), &known_flags("track")).unwrap_err();
        assert!(err.to_string().contains("expects a value"));
    }

    #[test]
    fn tracker_list_split_respects_param_commas() {
        assert_eq!(
            split_tracker_list("grest-rsvd:l=16,p=8,trip"),
            vec!["grest-rsvd:l=16,p=8".to_string(), "trip".to_string()]
        );
        assert_eq!(
            split_tracker_list("grest3,trip,iasc"),
            vec!["grest3".to_string(), "trip".to_string(), "iasc".to_string()]
        );
        assert_eq!(
            split_tracker_list("timers:theta=0.02,gap=3,grest3:threads=2,seed=5"),
            vec![
                "timers:theta=0.02,gap=3".to_string(),
                "grest3:threads=2,seed=5".to_string()
            ]
        );
        assert_eq!(split_tracker_list(" ,grest3, "), vec!["grest3".to_string()]);
        // a continuation after a param-less spec opens the ':' section
        assert_eq!(
            split_tracker_list("grest3,threads=2,trip"),
            vec!["grest3:threads=2".to_string(), "trip".to_string()]
        );
        // and splices before an @backend suffix
        assert_eq!(
            split_tracker_list("grest3@xla,n=4096,trip"),
            vec!["grest3:n=4096@xla".to_string(), "trip".to_string()]
        );
        assert_eq!(
            split_tracker_list("grest3:n=200@xla,m=20"),
            vec!["grest3:n=200,m=20@xla".to_string()]
        );
    }

    #[test]
    fn value_flag_may_consume_dash_dash_token() {
        // `--tracker --weird` : the value slot belongs to --tracker; it
        // must be taken verbatim, not re-parsed as a flag
        let (_, flags) =
            parse_flags(&sv(&["--tracker", "--weird"]), &known_flags("track")).unwrap();
        assert_eq!(flags.get("tracker").map(|s| s.as_str()), Some("--weird"));
    }
}
