//! `grest` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   table2                         print the dataset registry (Table 2)
//!   experiment <id> [--quick]      regenerate a paper table/figure
//!                                  (ids: fig2 fig3 fig4 fig5 table3 fig6 all)
//!   track [--dataset D] [--k K] [--tracker T] [--xla] [--t T]
//!                                  run one tracker over one dataset
//!   serve-demo [--events N]        run the streaming coordinator demo
//!   generate --dataset D --out F   write a synthetic dataset edge list
//!
//! Global flags:
//!   --threads N                    dense-kernel worker budget for the
//!                                  G-REST family (0 = auto, 1 = serial)
//!
//! Argument parsing is hand-rolled (offline build: no clap).

use grest::eval::experiments::{self, ExpConfig};
use grest::eval::table::fmt_secs;
use grest::graph::datasets::{self, Kind};
use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::tracking::{self, EigTracker, GRest, SubspaceMode};
use std::collections::HashMap;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut positional = vec![];
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if let Some((key, value)) = name.split_once('=') {
                // --name=value form
                flags.insert(key.to_string(), value.to_string());
                i += 1;
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    (positional, flags)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(|s| s.as_str()).unwrap_or("help");
    let threads = match flags.get("threads") {
        None => Threads::AUTO,
        Some(s) => Threads(s.parse().map_err(|_| {
            anyhow::anyhow!("--threads expects a number (0 = auto, 1 = serial), got {s:?}")
        })?),
    };
    let mut cfg = if flags.contains_key("quick") { ExpConfig::quick() } else { ExpConfig::paper() };
    cfg.threads = threads;

    match cmd {
        "table2" => {
            println!("{}", experiments::table2().render());
        }
        "experiment" => {
            let id = pos.get(1).map(|s| s.as_str()).unwrap_or("all");
            run_experiment(id, &cfg)?;
        }
        "track" => {
            cmd_track(&flags, threads)?;
        }
        "serve-demo" => {
            cmd_serve_demo(&flags, threads)?;
        }
        "generate" => {
            cmd_generate(&flags)?;
        }
        _ => {
            println!(
                "grest — Graph Rayleigh-Ritz Eigenspace Tracking\n\
                 usage: grest <table2|experiment|track|serve-demo|generate> [flags]\n\
                 see rust/src/main.rs header for details"
            );
        }
    }
    Ok(())
}

fn run_experiment(id: &str, cfg: &ExpConfig) -> anyhow::Result<()> {
    let run_acc = |kind: Kind, label: &str| {
        let (_, ta, tb, tt) = experiments::timed(label, || {
            experiments::figure_accuracy_runtime(kind, cfg)
        });
        println!("== {label}(a): time-averaged psi for leading 3 eigenvectors ==");
        println!("{}", ta.render());
        println!("== {label}(b): mean psi over leading {} vs t ==", cfg.angles_k);
        println!("{}", tb.render());
        println!("== Fig4 slice: total runtimes ==");
        println!("{}", tt.render());
        let _ = ta.write_csv(&format!("{label}_a"));
        let _ = tb.write_csv(&format!("{label}_b"));
        let _ = tt.write_csv(&format!("{label}_runtime"));
    };
    match id {
        "table2" => println!("{}", experiments::table2().render()),
        "fig2" | "fig4a" => run_acc(Kind::Static, "fig2"),
        "fig3" | "fig4b" => run_acc(Kind::Dynamic, "fig3"),
        "fig4" => {
            run_acc(Kind::Static, "fig2");
            run_acc(Kind::Dynamic, "fig3");
        }
        "fig5" => {
            let grid = if cfg.mc <= 1 && cfg.t_override.is_some() {
                vec![8usize, 16]
            } else {
                vec![10usize, 20, 40, 80]
            };
            let t = experiments::timed("fig5", || experiments::fig5_rsvd_tradeoff(cfg, &grid));
            println!("== Fig5: RSVD L/P trade-off (CM-Collab) ==");
            println!("{}", t.render());
            let _ = t.write_csv("fig5");
        }
        "table3" => {
            let t = experiments::timed("table3", || {
                experiments::table3_centrality(cfg, &[100, 1000])
            });
            println!("== Table 3: central-node overlap ==");
            println!("{}", t.render());
            let _ = t.write_csv("table3");
        }
        "fig6" => {
            let n = if cfg.extra_scale > 1 { 500 } else { 2000 };
            let t = experiments::timed("fig6", || {
                experiments::fig6_clustering(
                    cfg,
                    n,
                    &[0.002, 0.005, 0.01, 0.02],
                    &[2, 4, 6, 8],
                )
            });
            println!("== Fig6: clustering ARI ratio ==");
            println!("{}", t.render());
            let _ = t.write_csv("fig6");
        }
        "all" => {
            for e in ["fig2", "fig3", "fig5", "table3", "fig6"] {
                run_experiment(e, cfg)?;
            }
        }
        other => anyhow::bail!("unknown experiment id {other}"),
    }
    Ok(())
}

fn cmd_track(flags: &HashMap<String, String>, threads: Threads) -> anyhow::Result<()> {
    let dataset = flags.get("dataset").map(|s| s.as_str()).unwrap_or("CM-Collab");
    let k: usize = flags.get("k").and_then(|s| s.parse().ok()).unwrap_or(64);
    let t_steps: Option<usize> = flags.get("t").and_then(|s| s.parse().ok());
    let tracker_name = flags.get("tracker").map(|s| s.as_str()).unwrap_or("grest3");
    let use_xla = flags.contains_key("xla");

    let spec = datasets::by_name(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?;
    let mut rng = Rng::new(1);
    let sc = datasets::scenario_for(&spec, t_steps, &mut rng);
    println!(
        "dataset {dataset}: N0={} -> N={} over {} steps, total delta nnz {}",
        sc.initial.n_rows,
        sc.max_nodes(),
        sc.t_steps(),
        sc.total_delta_nnz()
    );
    let init = tracking::init_eigenpairs(&sc.initial, k, 7);
    let mut tracker: Box<dyn EigTracker> = match tracker_name {
        "trip-basic" => Box::new(tracking::trip_basic::TripBasic::new(init)),
        "trip" => Box::new(tracking::trip::Trip::new(init)),
        "rm" => Box::new(tracking::residual_modes::ResidualModes::new(init)),
        "iasc" => Box::new(tracking::iasc::Iasc::new(init)),
        "timers" => Box::new(tracking::timers::Timers::new(&sc.initial, k, 7)),
        "grest2" => Box::new(GRest::with_threads(init, SubspaceMode::Rm, threads)),
        "grest3" if use_xla => {
            let manifest = grest::runtime::ArtifactManifest::load_default()?;
            // panel width: K cols of ΔX̄ plus per-step expansion
            let max_s = sc.steps.iter().map(|s| s.delta.s_new).max().unwrap_or(0);
            let phases = grest::runtime::XlaPhases::for_problem(
                manifest,
                sc.max_nodes(),
                k,
                k + max_s.min(128),
            )?;
            println!("XLA backend tier: {:?}", phases.tier());
            Box::new(GRest::with_phases(init, SubspaceMode::Full, phases, 7))
        }
        "grest3" => Box::new(GRest::with_threads(init, SubspaceMode::Full, threads)),
        "grest-rsvd" => {
            Box::new(GRest::with_threads(init, SubspaceMode::Rsvd { l: 32, p: 32 }, threads))
        }
        other => anyhow::bail!("unknown tracker {other}"),
    };

    let t0 = std::time::Instant::now();
    for (i, step) in sc.steps.iter().enumerate() {
        let s0 = std::time::Instant::now();
        tracker.update(&step.delta)?;
        let update_t = s0.elapsed();
        let reference =
            tracking::traits::init_eigenpairs(&step.adjacency, k, 100 + i as u64);
        let psi = grest::eval::angle::mean_angle(tracker.current(), &reference, 3.min(k));
        println!(
            "step {:>3}: N={:>6} S={:>4} nnz(d)={:>6} update={} mean_psi(top3)={:.4}",
            i + 1,
            step.adjacency.n_rows,
            step.delta.s_new,
            step.delta.nnz(),
            fmt_secs(update_t),
            psi
        );
    }
    println!("total tracking time {}", fmt_secs(t0.elapsed()));
    Ok(())
}

fn cmd_serve_demo(flags: &HashMap<String, String>, threads: Threads) -> anyhow::Result<()> {
    use grest::coordinator::{BatchPolicy, ServiceConfig, TrackingService};
    use grest::graph::stream::GraphEvent;
    let n_events: usize = flags.get("events").and_then(|s| s.parse().ok()).unwrap_or(2000);
    let mut rng = Rng::new(3);
    let g = grest::graph::generators::erdos_renyi(500, 0.02, &mut rng);
    let svc = TrackingService::spawn(
        ServiceConfig {
            initial: g,
            k: 16,
            policy: BatchPolicy::Either { events: 64, new_nodes: 16 },
            seed: 5,
        },
        Box::new(move |_a0, init| {
            Box::new(GRest::with_threads(init.clone(), SubspaceMode::Full, threads))
        }),
    )?;
    let h = svc.handle.clone();
    let t0 = std::time::Instant::now();
    for i in 0..n_events as u64 {
        let ev = if rng.flip(0.85) {
            GraphEvent::AddEdge(rng.below(500) as u64, rng.below(700) as u64)
        } else {
            GraphEvent::RemoveEdge(rng.below(500) as u64, rng.below(500) as u64)
        };
        h.ingest(vec![ev])?;
        if i % 500 == 0 {
            let snap = h.snapshot();
            println!(
                "event {:>6}: snapshot v{} over {} nodes, lambda1={:.3}",
                i,
                snap.version,
                snap.n_nodes,
                snap.pairs.values.first().copied().unwrap_or(0.0)
            );
        }
    }
    h.flush()?;
    let snap = h.snapshot();
    println!(
        "final: v{} nodes={} | ingest+track {} for {n_events} events",
        snap.version,
        snap.n_nodes,
        fmt_secs(t0.elapsed())
    );
    println!("top-5 central: {:?}", h.central_nodes(5)?);
    println!("metrics: {}", h.metrics().report());
    svc.join();
    Ok(())
}

fn cmd_generate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let dataset = flags
        .get("dataset")
        .ok_or_else(|| anyhow::anyhow!("--dataset required"))?;
    let out = flags.get("out").ok_or_else(|| anyhow::anyhow!("--out required"))?;
    let spec = datasets::by_name(dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset}"))?;
    let mut rng = Rng::new(11);
    match spec.kind {
        Kind::Static => {
            let g = datasets::build_static(&spec, &mut rng);
            grest::graph::io::save_graph(&g, std::path::Path::new(out))?;
            println!("wrote {} ({} nodes, {} edges)", out, g.n_nodes(), g.n_edges());
        }
        Kind::Dynamic => {
            let stream = datasets::build_stream(&spec, &mut rng);
            let mut text = String::new();
            for (i, (u, v)) in stream.iter().enumerate() {
                text.push_str(&format!("{u} {v} {i}\n"));
            }
            std::fs::write(out, text)?;
            println!("wrote {} ({} timestamped edges)", out, stream.len());
        }
    }
    Ok(())
}
