//! Dense numerical linear algebra substrate, written from scratch.
//!
//! The paper's reference implementation leans on MATLAB's `eigs`/`qr`/
//! `svd`; this module provides the equivalents: a column-major dense
//! matrix, blocked BLAS-like micro-kernels, Householder QR, a symmetric
//! eigensolver (tridiagonalization + implicit-shift QL), a one-sided
//! Jacobi SVD, Lanczos with full reorthogonalization (the `eigs` stand-in),
//! and the randomized range finder of paper Sec. 3.5.

pub mod blas;
pub mod chol;
pub mod eigh;
pub mod f32mat;
pub mod gemm_packed;
pub mod gemm_simd;
pub mod kernel_core;
pub mod lanczos;
pub mod lu;
pub mod mat;
pub mod qr;
pub mod rng;
pub mod rsvd;
pub mod svd;
pub mod threads;
pub mod workspace;

pub use f32mat::{F32Mat, ServePrecision};
pub use threads::Threads;
pub use workspace::StepWorkspace;
