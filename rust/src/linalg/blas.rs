//! The blocked BLAS-like kernel layer on [`Mat`].
//!
//! Hand-written (offline build: no external BLAS) and organized as a
//! two-level layer:
//!
//! * **Micro-kernels** (`*_cols`) compute a contiguous range of *output
//!   columns* with cache tiling: `BLOCK_J`-wide column tiles of C stay
//!   hot while `BLOCK_K`-deep panels of A stream through, and a 4-column
//!   register kernel amortizes each load of an A column across four
//!   outputs.
//! * **Drivers** (`gemm_with`, `gemm_tn_with`, `syrk_tn_with`,
//!   `proj_gram_with` and their `_into` variants) partition output
//!   columns into per-chunk work descriptors dispatched on the
//!   process-wide persistent [`KernelPool`](crate::linalg::threads::KernelPool)
//!   (workers stay parked between calls — no per-call thread spawns),
//!   sized by the [`Threads`] budget.
//!
//! `gemm_acc` additionally dispatches each chunk down a kernel ladder —
//! `naive → blocked → blocked+pool → packed → packed+simd → packed+fma`
//! — where the packed rungs are the BLIS-style micro-kernels of
//! [`gemm_packed`](crate::linalg::gemm_packed) and
//! [`gemm_simd`](crate::linalg::gemm_simd), taken when the chunk shape
//! amortizes panel packing ([`gemm_packed::profitable`]).  `Auto`
//! routes a profitable chunk to the AVX2 micro-kernel when
//! [`simd_level`] detected it (packed scalar otherwise); every
//! `Auto`-eligible rung is bitwise identical to the blocked one, so the
//! choice is invisible to results.  The FMA rung changes rounding (one
//! fused rounding per update) and is therefore **opt-in only** — `Auto`
//! never selects it.  [`GemmKernel`] pins a rung explicitly
//! (benches/tests).
//!
//! Because the partition is over *output* columns, every output element
//! is produced by exactly one worker with a fixed sequential reduction
//! order — results are bitwise identical across thread counts, which is
//! what keeps `GRest` deterministic under `--threads N`.
//!
//! Two refinements serve the G-REST hot loop:
//!
//! * every kernel whose left/projection operand is the padded panel
//!   X̄_K = [X_K; 0] accepts a borrowed [`Padded`] view (`&Mat` still
//!   works via `impl Into<Padded>`): the structurally-zero rows are
//!   never stored, never copied, and never multiplied — and because a
//!   0.0 contribution is exact in IEEE arithmetic with the reduction
//!   orders unchanged, the result is bitwise identical to running on the
//!   materialized `pad_rows` matrix (property-tested);
//! * `_into` variants (`gemm_into`, `gemm_tn_into`, `syrk_tn_into`,
//!   `proj_gram_into`) write into caller-owned buffers reshaped in
//!   place, so a steady-state G-REST step performs no heap allocation.
//!
//! Panels in this codebase are tall-skinny (N×K, K ≤ a few hundred), so
//! the kernels are tuned for that regime.

use crate::linalg::gemm_packed;
use crate::linalg::gemm_simd;
use crate::linalg::mat::{Mat, Padded};
pub use crate::linalg::threads::Threads;
use crate::linalg::threads::{balanced_col_chunks, kernel_pool, simd_level, SimdLevel};

/// Cache block along the shared (k) dimension.
const BLOCK_K: usize = 64;
/// Column tile of B/C per sweep (keeps the active C panel in cache).
const BLOCK_J: usize = 64;

/// C = A · B (auto thread budget).
pub fn gemm<'a>(a: impl Into<Padded<'a>>, b: &Mat) -> Mat {
    gemm_with(a, b, Threads::AUTO)
}

/// C = A · B with an explicit thread budget.
pub fn gemm_with<'a>(a: impl Into<Padded<'a>>, b: &Mat, threads: Threads) -> Mat {
    let mut c = Mat::zeros(0, 0);
    gemm_into(&mut c, a, b, threads);
    c
}

/// C = A · B written into a caller-owned buffer (reshaped in place; the
/// padded rows of a [`Padded`] A yield exact zero output rows).
pub fn gemm_into<'a>(c: &mut Mat, a: impl Into<Padded<'a>>, b: &Mat, threads: Threads) {
    let a = a.into();
    assert_eq!(
        a.cols(),
        b.rows(),
        "gemm dims: {}x{} · {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    c.reset(a.rows(), b.cols());
    gemm_acc_with(c, a, b, 1.0, threads);
}

/// C += alpha · A · B (auto thread budget).
pub fn gemm_acc<'a>(c: &mut Mat, a: impl Into<Padded<'a>>, b: &Mat, alpha: f64) {
    gemm_acc_with(c, a, b, alpha, Threads::AUTO);
}

/// C += alpha · A · B — thread-parallel over output columns, each chunk
/// dispatched down the kernel ladder (see module docs).  With a
/// [`Padded`] A, rows of C beyond the filled block are untouched (their
/// materialized-oracle contribution is an exact ±0.0 no-op).
pub fn gemm_acc_with<'a>(
    c: &mut Mat,
    a: impl Into<Padded<'a>>,
    b: &Mat,
    alpha: f64,
    threads: Threads,
) {
    gemm_acc_with_kernel(c, a, b, alpha, threads, GemmKernel::Auto);
}

/// Which rung of the `gemm_acc` kernel ladder to run.  Every rung
/// except [`GemmKernel::PackedFma`] is bitwise identical to the blocked
/// oracle; production code uses `Auto` (shape heuristic × detected
/// [`SimdLevel`]), benches and tests pin a rung to measure/compare it.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum GemmKernel {
    /// Per chunk: when [`gemm_packed::profitable`], the packed driver
    /// with the AVX2 micro-kernel where detected (packed scalar
    /// otherwise); else blocked.  Never FMA.
    #[default]
    Auto,
    /// The cache-blocked 4-column kernel (the bitwise oracle).
    Blocked,
    /// The packed scalar 8×4 micro-kernel, regardless of shape.
    Packed,
    /// The packed driver with the AVX2 micro-kernel (bitwise; degrades
    /// to packed scalar where AVX2 is undetected or force-disabled).
    PackedSimd,
    /// The packed driver with the FMA micro-kernel — **not bitwise**
    /// (fused rounding), opt-in only, never selected by `Auto`;
    /// degrades to the bitwise SIMD/scalar path without FMA hardware.
    PackedFma,
}

/// [`gemm_acc_with`] with an explicitly pinned ladder rung.
pub fn gemm_acc_with_kernel<'a>(
    c: &mut Mat,
    a: impl Into<Padded<'a>>,
    b: &Mat,
    alpha: f64,
    threads: Threads,
    kernel: GemmKernel,
) {
    let a = a.into();
    let (m, kk) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), kk);
    assert_eq!((c.rows(), c.cols()), (m, n));
    let workers = threads.for_flops(2 * a.filled() * kk * n).min(n.max(1));
    if workers <= 1 {
        run_gemm_chunk(kernel, c.as_mut_slice(), m, 0..n, a, b, alpha);
        return;
    }
    let chunks = balanced_col_chunks(n, workers, |_| 1);
    let mut parts = Vec::with_capacity(chunks.len());
    let mut buf = c.as_mut_slice();
    for &(lo, hi) in &chunks {
        let (head, rest) = buf.split_at_mut((hi - lo) * m);
        buf = rest;
        parts.push((lo, hi, head));
    }
    kernel_pool().run(parts, |(lo, hi, head)| run_gemm_chunk(kernel, head, m, lo..hi, a, b, alpha));
}

/// Route one column chunk to its ladder rung.
#[inline]
fn run_gemm_chunk(
    kernel: GemmKernel,
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: Padded<'_>,
    b: &Mat,
    alpha: f64,
) {
    match kernel {
        GemmKernel::Auto => {
            if gemm_packed::profitable(a.filled(), a.cols(), jr.len()) {
                if simd_level() >= SimdLevel::Avx2 {
                    // bitwise-identical AVX2 tile (never FMA from Auto)
                    gemm_simd::gemm_acc_cols_simd(c_cols, m, jr, a, b, alpha);
                } else {
                    gemm_packed::gemm_acc_cols_packed(c_cols, m, jr, a, b, alpha);
                }
            } else {
                gemm_acc_cols_blocked(c_cols, m, jr, a, b, alpha);
            }
        }
        GemmKernel::Blocked => gemm_acc_cols_blocked(c_cols, m, jr, a, b, alpha),
        GemmKernel::Packed => gemm_packed::gemm_acc_cols_packed(c_cols, m, jr, a, b, alpha),
        GemmKernel::PackedSimd => gemm_simd::gemm_acc_cols_simd(c_cols, m, jr, a, b, alpha),
        GemmKernel::PackedFma => gemm_simd::gemm_acc_cols_fma(c_cols, m, jr, a, b, alpha),
    }
}

/// Compute columns `jr` of C += alpha·A·B into `c_cols` (the contiguous
/// column-major storage of exactly those columns, stride `m` = the full
/// logical height); only the top `a.filled()` rows are written.
///
/// `pub` so benches can time this rung in isolation; production enters
/// through the drivers.
pub fn gemm_acc_cols_blocked(
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: Padded<'_>,
    b: &Mat,
    alpha: f64,
) {
    let kk = a.cols();
    let mt = a.filled();
    let j0 = jr.start;
    let n = jr.end;
    // Outer: BLOCK_J-wide tiles of C (stay hot across all k blocks).
    let mut jt = j0;
    while jt < n {
        let jt_end = (jt + BLOCK_J).min(n);
        for k0 in (0..kk).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(kk);
            let mut j = jt;
            // 4-column micro-kernel: each loaded A column feeds 4 outputs.
            while j + 4 <= jt_end {
                let (b0c, b1c, b2c, b3c) = (b.col(j), b.col(j + 1), b.col(j + 2), b.col(j + 3));
                let base = (j - j0) * m;
                let (c0, rest) = c_cols[base..].split_at_mut(m);
                let (c1, rest) = rest.split_at_mut(m);
                let (c2, c3s) = rest.split_at_mut(m);
                let c3 = &mut c3s[..m];
                for k in k0..k1 {
                    let ak = a.col_top(k);
                    let w0 = alpha * b0c[k];
                    let w1 = alpha * b1c[k];
                    let w2 = alpha * b2c[k];
                    let w3 = alpha * b3c[k];
                    if w0 == 0.0 && w1 == 0.0 && w2 == 0.0 && w3 == 0.0 {
                        continue;
                    }
                    for i in 0..mt {
                        let av = ak[i];
                        c0[i] += w0 * av;
                        c1[i] += w1 * av;
                        c2[i] += w2 * av;
                        c3[i] += w3 * av;
                    }
                }
                j += 4;
            }
            while j < jt_end {
                let bj = b.col(j);
                let cj = &mut c_cols[(j - j0) * m..(j - j0 + 1) * m];
                for k in k0..k1 {
                    let w = alpha * bj[k];
                    if w == 0.0 {
                        continue;
                    }
                    let ak = a.col_top(k);
                    for i in 0..mt {
                        cj[i] += w * ak[i];
                    }
                }
                j += 1;
            }
        }
        jt = jt_end;
    }
}

/// C = Aᵀ · B without materializing Aᵀ (auto thread budget).
pub fn gemm_tn<'a>(a: impl Into<Padded<'a>>, b: &Mat) -> Mat {
    gemm_tn_with(a, b, Threads::AUTO)
}

/// C = Aᵀ · B — the Gram kernel of the paper's projection step.  4×1
/// register blocking over A columns (each read of B feeds four dots),
/// thread-parallel over B columns.
pub fn gemm_tn_with<'a>(a: impl Into<Padded<'a>>, b: &Mat, threads: Threads) -> Mat {
    let mut c = Mat::zeros(0, 0);
    gemm_tn_into(&mut c, a, b, threads);
    c
}

/// [`gemm_tn_with`] writing into a caller-owned buffer.
pub fn gemm_tn_into<'a>(c: &mut Mat, a: impl Into<Padded<'a>>, b: &Mat, threads: Threads) {
    let a = a.into();
    assert_eq!(a.rows(), b.rows(), "gemm_tn dims");
    let (k, n) = (a.cols(), b.cols());
    c.reset(k, n);
    let workers = threads.for_flops(2 * a.filled() * k * n).min(n.max(1));
    if workers <= 1 {
        gemm_tn_cols(c.as_mut_slice(), 0..n, a, b);
        return;
    }
    let chunks = balanced_col_chunks(n, workers, |_| 1);
    let mut parts = Vec::with_capacity(chunks.len());
    let mut buf = c.as_mut_slice();
    for &(lo, hi) in &chunks {
        let (head, rest) = buf.split_at_mut((hi - lo) * k);
        buf = rest;
        parts.push((lo, hi, head));
    }
    kernel_pool().run(parts, |(lo, hi, head)| gemm_tn_cols(head, lo..hi, a, b));
}

fn gemm_tn_cols(c_cols: &mut [f64], jr: std::ops::Range<usize>, a: Padded<'_>, b: &Mat) {
    let k = a.cols();
    let mt = a.filled();
    let j0 = jr.start;
    for j in jr {
        let bj = b.col(j);
        let cj = &mut c_cols[(j - j0) * k..(j - j0 + 1) * k];
        let mut p = 0;
        while p + 4 <= k {
            let (a0, a1, a2, a3) = (
                a.col_top(p),
                a.col_top(p + 1),
                a.col_top(p + 2),
                a.col_top(p + 3),
            );
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for i in 0..mt {
                let bv = bj[i];
                s0 += a0[i] * bv;
                s1 += a1[i] * bv;
                s2 += a2[i] * bv;
                s3 += a3[i] * bv;
            }
            cj[p] = s0;
            cj[p + 1] = s1;
            cj[p + 2] = s2;
            cj[p + 3] = s3;
            p += 4;
        }
        while p < k {
            cj[p] = dot_padded(a.col_top(p), bj);
            p += 1;
        }
    }
}

/// Symmetric-result Gram product S = Aᵀ·B where AᵀB is *analytically*
/// symmetric (B = M·A with M = Mᵀ, or B = A): only the upper triangle is
/// computed (half the flops of `gemm_tn`) and mirrored.  This is the
/// `form_t` specialization of Eq. (13) — T₁₁ and T₂₂ are symmetric
/// because Δ is.
pub fn syrk_tn<'a>(a: impl Into<Padded<'a>>, b: &Mat) -> Mat {
    syrk_tn_with(a, b, Threads::AUTO)
}

/// [`syrk_tn`] with an explicit thread budget.  Work is triangular, so
/// column chunks are balanced by `j+1` weights.
pub fn syrk_tn_with<'a>(a: impl Into<Padded<'a>>, b: &Mat, threads: Threads) -> Mat {
    let mut c = Mat::zeros(0, 0);
    syrk_tn_into(&mut c, a, b, threads);
    c
}

/// [`syrk_tn_with`] writing into a caller-owned buffer.
pub fn syrk_tn_into<'a>(c: &mut Mat, a: impl Into<Padded<'a>>, b: &Mat, threads: Threads) {
    let a = a.into();
    assert_eq!(a.rows(), b.rows(), "syrk_tn dims (rows)");
    assert_eq!(a.cols(), b.cols(), "syrk_tn needs square output");
    let p = a.cols();
    c.reset(p, p);
    let workers = threads.for_flops(a.filled() * p * (p + 1)).min(p.max(1));
    if workers <= 1 {
        syrk_tn_cols(c.as_mut_slice(), 0..p, a, b);
    } else {
        let chunks = balanced_col_chunks(p, workers, |j| j + 1);
        let mut parts = Vec::with_capacity(chunks.len());
        let mut buf = c.as_mut_slice();
        for &(lo, hi) in &chunks {
            let (head, rest) = buf.split_at_mut((hi - lo) * p);
            buf = rest;
            parts.push((lo, hi, head));
        }
        kernel_pool().run(parts, |(lo, hi, head)| syrk_tn_cols(head, lo..hi, a, b));
    }
    mirror_upper(c);
}

fn syrk_tn_cols(c_cols: &mut [f64], jr: std::ops::Range<usize>, a: Padded<'_>, b: &Mat) {
    let p = a.cols();
    let j0 = jr.start;
    for j in jr {
        let bj = b.col(j);
        let cj = &mut c_cols[(j - j0) * p..(j - j0 + 1) * p];
        for (i, out) in cj.iter_mut().enumerate().take(j + 1) {
            *out = dot_padded(a.col_top(i), bj);
        }
    }
}

/// Copy the strict upper triangle onto the lower one in place.
fn mirror_upper(c: &mut Mat) {
    let p = c.rows();
    debug_assert_eq!(p, c.cols());
    for j in 0..p {
        for i in 0..j {
            let v = c.get(i, j);
            c.set(j, i, v);
        }
    }
}

/// Fused projection Gram: one sweep over the panel P computing both
/// C = XᵀP and the symmetric G = PᵀP (upper triangle + mirror).
///
/// This is the fusion behind `qr::orthonormalize_against`: with X
/// orthonormal, the Gram of the projected panel is
/// `(P−XC)ᵀ(P−XC) = G − CᵀC`, so the explicit project-out pass before
/// the Gram disappears — X̄ and P are each read once per CholeskyQR
/// round instead of twice.  X accepts the [`Padded`] X̄ view: only the
/// filled rows enter the C dots (P keeps its full height in G).
pub fn proj_gram_with<'a>(x: impl Into<Padded<'a>>, p: &Mat, threads: Threads) -> (Mat, Mat) {
    let mut c = Mat::zeros(0, 0);
    let mut g = Mat::zeros(0, 0);
    proj_gram_into(&mut c, &mut g, x, p, threads);
    (c, g)
}

/// [`proj_gram_with`] writing C and G into caller-owned buffers.
pub fn proj_gram_into<'a>(
    c: &mut Mat,
    g: &mut Mat,
    x: impl Into<Padded<'a>>,
    p: &Mat,
    threads: Threads,
) {
    let x = x.into();
    assert_eq!(x.rows(), p.rows(), "proj_gram dims");
    let n = p.rows();
    let k = x.cols();
    let m = p.cols();
    c.reset(k, m);
    g.reset(m, m);
    let workers = threads
        .for_flops(2 * x.filled() * k * m + n * m * (m + 1))
        .min(m.max(1));
    if workers <= 1 {
        proj_gram_cols(c.as_mut_slice(), g.as_mut_slice(), 0..m, x, p);
    } else {
        let chunks = balanced_col_chunks(m, workers, |j| k + j + 1);
        let mut parts = Vec::with_capacity(chunks.len());
        let mut cbuf = c.as_mut_slice();
        let mut gbuf = g.as_mut_slice();
        for &(lo, hi) in &chunks {
            let (chead, crest) = cbuf.split_at_mut((hi - lo) * k);
            let (ghead, grest) = gbuf.split_at_mut((hi - lo) * m);
            cbuf = crest;
            gbuf = grest;
            parts.push((lo, hi, chead, ghead));
        }
        kernel_pool()
            .run(parts, |(lo, hi, chead, ghead)| proj_gram_cols(chead, ghead, lo..hi, x, p));
    }
    mirror_upper(g);
}

fn proj_gram_cols(
    c_cols: &mut [f64],
    g_cols: &mut [f64],
    jr: std::ops::Range<usize>,
    x: Padded<'_>,
    p: &Mat,
) {
    let k = x.cols();
    let m = p.cols();
    let j0 = jr.start;
    for j in jr {
        let pj = p.col(j);
        let cj = &mut c_cols[(j - j0) * k..(j - j0 + 1) * k];
        for (i, out) in cj.iter_mut().enumerate() {
            *out = dot_padded(x.col_top(i), pj);
        }
        let gj = &mut g_cols[(j - j0) * m..(j - j0 + 1) * m];
        for (i, out) in gj.iter_mut().enumerate().take(j + 1) {
            *out = dot(p.col(i), pj);
        }
    }
}

/// Contiguous dot product (4-way unrolled).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

/// [`dot`] against a zero-padded vector whose stored part is `x_top`
/// and whose logical length is `y.len()`.
///
/// Replicates the lane structure of the full-length [`dot`] exactly:
/// fully-stored 4-chunks feed the same four lanes, the chunk straddling
/// the padding boundary adds only its stored entries to their lanes,
/// and the scalar tail adds stored entries after the lane reduction.
/// The skipped terms are exact ±0.0 contributions, and a lane that
/// starts at +0.0 can never become −0.0 under `+=`, so the result is
/// bitwise identical to `dot(&padded_x, y)` for finite inputs.  With
/// `x_top.len() == y.len()` this *is* [`dot`].
#[inline]
pub fn dot_padded(x_top: &[f64], y: &[f64]) -> f64 {
    let n = y.len();
    let nf = x_top.len();
    debug_assert!(nf <= n);
    let chunks = n / 4;
    // stored entries the full-length dot would process inside 4-chunks
    let in_chunks = (chunks * 4).min(nf);
    let full = in_chunks / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..full {
        let i = c * 4;
        s0 += x_top[i] * y[i];
        s1 += x_top[i + 1] * y[i + 1];
        s2 += x_top[i + 2] * y[i + 2];
        s3 += x_top[i + 3] * y[i + 3];
    }
    for i in full * 4..in_chunks {
        match i % 4 {
            0 => s0 += x_top[i] * y[i],
            1 => s1 += x_top[i] * y[i],
            2 => s2 += x_top[i] * y[i],
            _ => s3 += x_top[i] * y[i],
        }
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..nf {
        s += x_top[i] * y[i];
    }
    s
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// y = A · x (column-major gaxpy).
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    for (j, &xj) in x.iter().enumerate() {
        if xj != 0.0 {
            axpy(xj, a.col(j), &mut y);
        }
    }
    y
}

/// y = Aᵀ · x.
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    (0..a.cols()).map(|j| dot(a.col(j), x)).collect()
}

/// P = B − X · C, the "apply" half of project-out (mirrors the Pallas
/// kernel `apply_proj`).
pub fn sub_matmul<'a>(b: &Mat, x: impl Into<Padded<'a>>, c: &Mat) -> Mat {
    sub_matmul_with(b, x, c, Threads::AUTO)
}

/// [`sub_matmul`] with an explicit thread budget.
pub fn sub_matmul_with<'a>(b: &Mat, x: impl Into<Padded<'a>>, c: &Mat, threads: Threads) -> Mat {
    let mut p = b.clone();
    gemm_acc_with(&mut p, x, c, -1.0, threads);
    p
}

/// P = (I − X Xᵀ) B — project `b` against the orthonormal panel `x`
/// (mirrors the Pallas `project_out` composition).
pub fn project_out<'a>(x: impl Into<Padded<'a>>, b: &Mat) -> Mat {
    project_out_with(x, b, Threads::AUTO)
}

/// [`project_out`] with an explicit thread budget.
pub fn project_out_with<'a>(x: impl Into<Padded<'a>>, b: &Mat, threads: Threads) -> Mat {
    let x = x.into();
    let c = gemm_tn_with(x, b, threads);
    sub_matmul_with(b, x, &c, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    fn naive_mm(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a.get(i, k) * b.get(k, j)).sum()
        })
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (65, 130, 67), (100, 3, 100)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = gemm(&a, &b);
            let want = naive_mm(&a, &b);
            let mut diff = c.clone();
            diff.axpy(-1.0, &want);
            assert!(diff.max_abs() < 1e-10, "({m},{k},{n}): {}", diff.max_abs());
        }
    }

    #[test]
    fn gemm_tn_matches_transpose_mm() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(90, 13, &mut rng);
        let b = Mat::randn(90, 17, &mut rng);
        let c = gemm_tn(&a, &b);
        let want = naive_mm(&a.t(), &b);
        let mut diff = c.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-10);
    }

    #[test]
    fn threaded_gemm_is_bitwise_equal_to_sequential() {
        // the determinism contract behind --threads N
        let mut rng = Rng::new(7);
        let a = Mat::randn(300, 90, &mut rng);
        let b = Mat::randn(90, 150, &mut rng);
        let seq = gemm_with(&a, &b, Threads::SINGLE);
        let par = gemm_with(&a, &b, Threads(4));
        assert_eq!(seq.as_slice(), par.as_slice(), "gemm not bitwise stable");
        let seq_tn = gemm_tn_with(&a, &a, Threads::SINGLE);
        let par_tn = gemm_tn_with(&a, &a, Threads(3));
        assert_eq!(seq_tn.as_slice(), par_tn.as_slice(), "gemm_tn not bitwise stable");
    }

    #[test]
    fn every_ladder_rung_is_bitwise_identical() {
        // the packed rung's contract: pinning any rung, at any thread
        // count, changes nothing in the output bits
        let mut rng = Rng::new(21);
        let a = Mat::randn(200, 48, &mut rng);
        let b = Mat::randn(48, 60, &mut rng);
        let mut want = Mat::zeros(200, 60);
        gemm_acc_with_kernel(&mut want, &a, &b, 1.0, Threads::SINGLE, GemmKernel::Blocked);
        // every exact rung (FMA is the one deliberate exception — it has
        // its own tolerance test in gemm_simd)
        let exact = [
            GemmKernel::Auto,
            GemmKernel::Packed,
            GemmKernel::PackedSimd,
            GemmKernel::Blocked,
        ];
        for &kernel in &exact {
            for &tc in &[Threads(1), Threads(4)] {
                let mut c = Mat::zeros(200, 60);
                gemm_acc_with_kernel(&mut c, &a, &b, 1.0, tc, kernel);
                assert_eq!(c.as_slice(), want.as_slice(), "{kernel:?} t={}", tc.0);
            }
        }
        // sub-gate shapes fall back to blocked under Auto but must still
        // agree when the packed rungs are forced
        let a2 = Mat::randn(13, 9, &mut rng);
        let b2 = Mat::randn(9, 3, &mut rng);
        let mut w2 = Mat::zeros(13, 3);
        gemm_acc_with_kernel(&mut w2, &a2, &b2, -2.0, Threads::SINGLE, GemmKernel::Blocked);
        for &kernel in &[GemmKernel::Packed, GemmKernel::PackedSimd] {
            let mut p2 = Mat::zeros(13, 3);
            gemm_acc_with_kernel(&mut p2, &a2, &b2, -2.0, Threads::SINGLE, kernel);
            assert_eq!(w2.as_slice(), p2.as_slice(), "{kernel:?} sub-gate");
        }
    }

    #[test]
    fn syrk_matches_gemm_tn_for_symmetric_products() {
        let mut rng = Rng::new(3);
        // large enough that the triangular kernel actually fans out
        let a = Mat::randn(320, 120, &mut rng);
        // B = A gives the exactly-symmetric Gram.  gemm_tn accumulates in
        // a different lane order than the dot-based triangular kernel, so
        // compare with a tolerance, not bitwise.
        let s = syrk_tn_with(&a, &a, Threads::SINGLE);
        let full = gemm_tn(&a, &a);
        for i in 0..120 {
            for j in 0..120 {
                let want = if i <= j { full.get(i, j) } else { full.get(j, i) };
                assert!(
                    (s.get(i, j) - want).abs() < 1e-10 * (1.0 + want.abs()),
                    "({i},{j}): {} vs {}",
                    s.get(i, j),
                    want
                );
            }
        }
        // the mirrored halves are exactly equal by construction
        for i in 0..120 {
            for j in 0..120 {
                assert_eq!(s.get(i, j), s.get(j, i), "symmetry ({i},{j})");
            }
        }
        // threaded triangular kernel agrees bitwise
        let s4 = syrk_tn_with(&a, &a, Threads(4));
        assert_eq!(s.as_slice(), s4.as_slice());
    }

    #[test]
    fn proj_gram_matches_separate_kernels() {
        let mut rng = Rng::new(4);
        // sized past the parallel threshold so the fused kernel fans out
        let x = Mat::randn(320, 60, &mut rng);
        let p = Mat::randn(320, 100, &mut rng);
        let (c, g) = proj_gram_with(&x, &p, Threads::SINGLE);
        // C vs gemm_tn: different lane order, tolerance compare
        let c_want = gemm_tn(&x, &p);
        let mut cd = c.clone();
        cd.axpy(-1.0, &c_want);
        assert!(cd.max_abs() < 1e-10, "C mismatch {}", cd.max_abs());
        // G vs syrk_tn: both dot-based, exactly equal
        let g_want = syrk_tn(&p, &p);
        let mut gd = g.clone();
        gd.axpy(-1.0, &g_want);
        assert_eq!(gd.max_abs(), 0.0);
        // threaded path bitwise identical
        let (c4, g4) = proj_gram_with(&x, &p, Threads(4));
        assert_eq!(c.as_slice(), c4.as_slice());
        assert_eq!(g.as_slice(), g4.as_slice());
    }

    #[test]
    fn dot_padded_is_bitwise_dot_of_materialized() {
        let mut rng = Rng::new(12);
        // lengths straddling every 4-lane alignment case
        for &(nf, extra) in &[
            (0usize, 5usize),
            (1, 0),
            (1, 6),
            (3, 1),
            (4, 0),
            (4, 4),
            (5, 3),
            (6, 1),
            (6, 6),
            (31, 9),
            (32, 0),
            (33, 7),
            (1000, 24),
        ] {
            let x: Vec<f64> = (0..nf).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..nf + extra).map(|_| rng.normal()).collect();
            let mut xp = x.clone();
            xp.resize(nf + extra, 0.0);
            let want = dot(&xp, &y);
            let got = dot_padded(&x, &y);
            assert_eq!(got.to_bits(), want.to_bits(), "(nf={nf}, extra={extra})");
        }
    }

    #[test]
    fn padded_kernels_bitwise_match_materialized_oracle() {
        // the tentpole contract: every X̄-consuming kernel over a Padded
        // view equals the same kernel over the pad_rows matrix to the
        // last bit, across shapes (incl. extra == 0 and odd row counts
        // that straddle the dot lanes) and thread counts 1/4.
        let mut rng = Rng::new(5);
        for &(n_old, extra, k, m) in &[
            (30usize, 0usize, 5usize, 7usize),
            (31, 9, 6, 4),
            (57, 3, 3, 9),
            (257, 63, 16, 20),
            (2000, 48, 32, 40),
        ] {
            let n = n_old + extra;
            let x = Mat::randn(n_old, k, &mut rng);
            let xm = x.pad_rows(extra);
            let b = Mat::randn(n, m, &mut rng);
            let bk = Mat::randn(n, k, &mut rng);
            let f = Mat::randn(k, m, &mut rng);
            for &tc in &[Threads(1), Threads(4)] {
                let xp = Padded::new(&x, extra);
                let tag = format!("n_old={n_old} extra={extra} k={k} m={m} t={}", tc.0);
                // gemm_tn: X̄ᵀB
                let tn_p = gemm_tn_with(xp, &b, tc);
                let tn_m = gemm_tn_with(&xm, &b, tc);
                assert_eq!(tn_p.as_slice(), tn_m.as_slice(), "gemm_tn {tag}");
                // syrk_tn: sym(X̄ᵀB_k)
                let sy_p = syrk_tn_with(xp, &bk, tc);
                let sy_m = syrk_tn_with(&xm, &bk, tc);
                assert_eq!(sy_p.as_slice(), sy_m.as_slice(), "syrk_tn {tag}");
                // proj_gram: C = X̄ᵀP, G = PᵀP
                let (c_p, g_p) = proj_gram_with(xp, &b, tc);
                let (c_m, g_m) = proj_gram_with(&xm, &b, tc);
                assert_eq!(c_p.as_slice(), c_m.as_slice(), "proj_gram C {tag}");
                assert_eq!(g_p.as_slice(), g_m.as_slice(), "proj_gram G {tag}");
                // gemm: X̄·F (padded rows must come out exactly zero)
                let mm_p = gemm_with(xp, &f, tc);
                let mm_m = gemm_with(&xm, &f, tc);
                assert_eq!(mm_p.as_slice(), mm_m.as_slice(), "gemm {tag}");
                for i in n_old..n {
                    for j in 0..m {
                        assert_eq!(mm_p.get(i, j), 0.0, "gemm pad row {tag}");
                    }
                }
                // gemm_acc into a C with live data in the padded rows
                let mut acc_p = b.clone();
                let mut acc_m = b.clone();
                gemm_acc_with(&mut acc_p, xp, &f, -1.0, tc);
                gemm_acc_with(&mut acc_m, &xm, &f, -1.0, tc);
                assert_eq!(acc_p.as_slice(), acc_m.as_slice(), "gemm_acc {tag}");
                // project_out: the bottom rows of B pass through untouched
                let po_p = project_out_with(xp, &b, tc);
                let po_m = project_out_with(&xm, &b, tc);
                assert_eq!(po_p.as_slice(), po_m.as_slice(), "project_out {tag}");
            }
        }
    }

    #[test]
    fn into_variants_reuse_buffers_across_shapes() {
        let mut rng = Rng::new(6);
        let a = Mat::randn(40, 8, &mut rng);
        let b = Mat::randn(8, 12, &mut rng);
        let mut c = Mat::zeros(3, 3); // wrong shape on purpose
        gemm_into(&mut c, &a, &b, Threads::SINGLE);
        assert_eq!((c.rows(), c.cols()), (40, 12));
        let want = gemm(&a, &b);
        assert_eq!(c.as_slice(), want.as_slice());
        // shrink back: reuse the same output buffer for a Gram
        let p = Mat::randn(40, 6, &mut rng);
        gemm_tn_into(&mut c, &a, &p, Threads::SINGLE);
        assert_eq!((c.rows(), c.cols()), (8, 6));
        let want_tn = gemm_tn(&a, &p);
        assert_eq!(c.as_slice(), want_tn.as_slice());
        let mut s = Mat::zeros(0, 0);
        syrk_tn_into(&mut s, &p, &p, Threads::SINGLE);
        let want_s = syrk_tn(&p, &p);
        assert_eq!(s.as_slice(), want_s.as_slice());
        let (mut cc, mut gg) = (Mat::zeros(1, 1), Mat::zeros(1, 1));
        proj_gram_into(&mut cc, &mut gg, &a, &p, Threads::SINGLE);
        let (wc, wg) = proj_gram_with(&a, &p, Threads::SINGLE);
        assert_eq!(cc.as_slice(), wc.as_slice());
        assert_eq!(gg.as_slice(), wg.as_slice());
    }

    #[test]
    fn gemv_matches() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(11, 7, &mut rng);
        let x: Vec<f64> = (0..7).map(|i| i as f64 - 3.0).collect();
        let y = gemv(&a, &x);
        for i in 0..11 {
            let want: f64 = (0..7).map(|j| a.get(i, j) * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-12);
        }
        let z = gemv_t(&a, &gemv(&a, &x));
        assert_eq!(z.len(), 7);
    }

    #[test]
    fn project_out_annihilates_range() {
        let mut rng = Rng::new(4);
        let raw = Mat::randn(60, 6, &mut rng);
        let (q, _) = crate::linalg::qr::thin_qr(&raw);
        let coeff = Mat::randn(6, 4, &mut rng);
        let b = gemm(&q, &coeff);
        let p = project_out(&q, &b);
        assert!(p.max_abs() < 1e-10);
    }

    #[test]
    fn project_out_fixes_orthogonal_complement() {
        let mut rng = Rng::new(5);
        let raw = Mat::randn(50, 5, &mut rng);
        let (q, _) = crate::linalg::qr::thin_qr(&raw);
        let b = Mat::randn(50, 3, &mut rng);
        let p1 = project_out(&q, &b);
        let p2 = project_out(&q, &p1);
        let mut diff = p1.clone();
        diff.axpy(-1.0, &p2);
        assert!(diff.max_abs() < 1e-10);
    }
}
