//! BLAS-like micro-kernels on [`Mat`].
//!
//! Hand-written (offline build: no external BLAS).  `gemm` uses cache
//! blocking with a column-major-friendly loop order (j-k-i: the innermost
//! loop is a contiguous axpy over a column of A/C), which reaches a decent
//! fraction of scalar peak and vectorizes under `-O`.  Panels in this
//! codebase are tall-skinny (N×K, K ≤ 256), so the kernels are tuned for
//! that regime.

use crate::linalg::mat::Mat;

/// Cache block along the shared (k) dimension.
const BLOCK_K: usize = 64;
/// Cache block along columns of B/C.
const BLOCK_J: usize = 64;

/// C = A · B.
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "gemm dims: {}x{} · {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_acc(&mut c, a, b, 1.0);
    c
}

/// Row-count threshold above which the dense kernels fan out across
/// threads (column-partitioned; each thread owns disjoint output
/// columns, so no synchronization is needed).
const PAR_MIN_WORK: usize = 1 << 23;

fn n_threads_for(work: usize) -> usize {
    if work < PAR_MIN_WORK {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(8)
}

/// C += alpha · A · B  (blocked, 4-column register kernel, thread-
/// parallel over output column chunks for large problems).
pub fn gemm_acc(c: &mut Mat, a: &Mat, b: &Mat, alpha: f64) {
    let (m, kk) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), kk);
    assert_eq!((c.rows(), c.cols()), (m, n));
    let threads = n_threads_for(2 * m * kk * n).min(n.max(1));
    if threads <= 1 {
        gemm_acc_cols(c.as_mut_slice(), m, 0..n, a, b, alpha);
        return;
    }
    let chunk = n.div_ceil(threads);
    let cols: Vec<(usize, &mut [f64])> = {
        // split the column-major buffer into per-chunk slices
        let mut out = Vec::new();
        let mut buf = c.as_mut_slice();
        let mut j = 0;
        while j < n {
            let take = chunk.min(n - j);
            let (head, rest) = buf.split_at_mut(take * m);
            out.push((j, head));
            buf = rest;
            j += take;
        }
        out
    };
    std::thread::scope(|s| {
        for (j0, slice) in cols {
            let j1 = (j0 + slice.len() / m).min(n);
            s.spawn(move || gemm_acc_cols(slice, m, j0..j1, a, b, alpha));
        }
    });
}

/// Compute columns `jr` of C += alpha·A·B into `c_cols` (the contiguous
/// column-major storage of exactly those columns).
fn gemm_acc_cols(
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: &Mat,
    b: &Mat,
    alpha: f64,
) {
    let kk = a.cols();
    let j0 = jr.start;
    let n = jr.end;
    for k0 in (0..kk).step_by(BLOCK_K) {
        let k1 = (k0 + BLOCK_K).min(kk);
        let mut j = j0;
        // 4-column micro-kernel: each loaded a-column feeds 4 outputs.
        while j + 4 <= n {
            let (b0c, b1c, b2c, b3c) = (b.col(j), b.col(j + 1), b.col(j + 2), b.col(j + 3));
            let base = (j - j0) * m;
            let (lo, rest) = c_cols[base..].split_at_mut(m);
            let (c1, rest) = rest.split_at_mut(m);
            let (c2, c3s) = rest.split_at_mut(m);
            let c0 = lo;
            let c3 = &mut c3s[..m];
            for k in k0..k1 {
                let ak = a.col(k);
                let w0 = alpha * b0c[k];
                let w1 = alpha * b1c[k];
                let w2 = alpha * b2c[k];
                let w3 = alpha * b3c[k];
                if w0 == 0.0 && w1 == 0.0 && w2 == 0.0 && w3 == 0.0 {
                    continue;
                }
                for i in 0..m {
                    let av = ak[i];
                    c0[i] += w0 * av;
                    c1[i] += w1 * av;
                    c2[i] += w2 * av;
                    c3[i] += w3 * av;
                }
            }
            j += 4;
        }
        while j < n {
            let bj = b.col(j);
            let cj = &mut c_cols[(j - j0) * m..(j - j0 + 1) * m];
            for k in k0..k1 {
                let w = alpha * bj[k];
                if w == 0.0 {
                    continue;
                }
                let ak = a.col(k);
                for i in 0..m {
                    cj[i] += w * ak[i];
                }
            }
            j += 1;
        }
    }
}

/// C = Aᵀ · B without materializing Aᵀ (the Gram kernel of the paper's
/// projection step).  4×1 register blocking over A-columns (each read of
/// B feeds four dots), thread-parallel over B-columns for large inputs.
pub fn gemm_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "gemm_tn dims");
    let (k, n) = (a.cols(), b.cols());
    let m = a.rows();
    let mut c = Mat::zeros(k, n);
    let threads = n_threads_for(2 * m * k * n).min(n.max(1));
    if threads <= 1 {
        gemm_tn_cols(c.as_mut_slice(), 0..n, a, b);
        return c;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut buf = c.as_mut_slice();
        let mut j = 0;
        while j < n {
            let take = chunk.min(n - j);
            let (head, rest) = buf.split_at_mut(take * k);
            let jr = j..j + take;
            s.spawn(move || gemm_tn_cols(head, jr, a, b));
            buf = rest;
            j += take;
        }
    });
    c
}

fn gemm_tn_cols(c_cols: &mut [f64], jr: std::ops::Range<usize>, a: &Mat, b: &Mat) {
    let k = a.cols();
    let m = a.rows();
    let j0 = jr.start;
    for j in jr {
        let bj = b.col(j);
        let cj = &mut c_cols[(j - j0) * k..(j - j0 + 1) * k];
        let mut p = 0;
        while p + 4 <= k {
            let (a0, a1, a2, a3) = (a.col(p), a.col(p + 1), a.col(p + 2), a.col(p + 3));
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for i in 0..m {
                let bv = bj[i];
                s0 += a0[i] * bv;
                s1 += a1[i] * bv;
                s2 += a2[i] * bv;
                s3 += a3[i] * bv;
            }
            cj[p] = s0;
            cj[p + 1] = s1;
            cj[p + 2] = s2;
            cj[p + 3] = s3;
            p += 4;
        }
        while p < k {
            cj[p] = dot(a.col(p), bj);
            p += 1;
        }
    }
}

/// Contiguous dot product (4-way unrolled).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// y = A · x (column-major gaxpy).
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    for (j, &xj) in x.iter().enumerate() {
        if xj != 0.0 {
            axpy(xj, a.col(j), &mut y);
        }
    }
    y
}

/// y = Aᵀ · x.
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    (0..a.cols()).map(|j| dot(a.col(j), x)).collect()
}

/// P = B − X · C, the "apply" half of project-out (mirrors the Pallas
/// kernel `apply_proj`).
pub fn sub_matmul(b: &Mat, x: &Mat, c: &Mat) -> Mat {
    let mut p = b.clone();
    gemm_acc(&mut p, x, c, -1.0);
    p
}

/// P = (I − X Xᵀ) B — project `b` against the orthonormal panel `x`
/// (mirrors the Pallas `project_out` composition).
pub fn project_out(x: &Mat, b: &Mat) -> Mat {
    let c = gemm_tn(x, b);
    sub_matmul(b, x, &c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    fn naive_mm(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a.get(i, k) * b.get(k, j)).sum()
        })
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (65, 130, 67), (100, 3, 100)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = gemm(&a, &b);
            let want = naive_mm(&a, &b);
            let mut diff = c.clone();
            diff.axpy(-1.0, &want);
            assert!(diff.max_abs() < 1e-10, "({m},{k},{n}): {}", diff.max_abs());
        }
    }

    #[test]
    fn gemm_tn_matches_transpose_mm() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(90, 13, &mut rng);
        let b = Mat::randn(90, 17, &mut rng);
        let c = gemm_tn(&a, &b);
        let want = naive_mm(&a.t(), &b);
        let mut diff = c.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-10);
    }

    #[test]
    fn gemv_matches() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(11, 7, &mut rng);
        let x: Vec<f64> = (0..7).map(|i| i as f64 - 3.0).collect();
        let y = gemv(&a, &x);
        for i in 0..11 {
            let want: f64 = (0..7).map(|j| a.get(i, j) * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-12);
        }
        let z = gemv_t(&a, &gemv(&a, &x));
        assert_eq!(z.len(), 7);
    }

    #[test]
    fn project_out_annihilates_range() {
        let mut rng = Rng::new(4);
        let raw = Mat::randn(60, 6, &mut rng);
        let (q, _) = crate::linalg::qr::thin_qr(&raw);
        let coeff = Mat::randn(6, 4, &mut rng);
        let b = gemm(&q, &coeff);
        let p = project_out(&q, &b);
        assert!(p.max_abs() < 1e-10);
    }

    #[test]
    fn project_out_fixes_orthogonal_complement() {
        let mut rng = Rng::new(5);
        let raw = Mat::randn(50, 5, &mut rng);
        let (q, _) = crate::linalg::qr::thin_qr(&raw);
        let b = Mat::randn(50, 3, &mut rng);
        let p1 = project_out(&q, &b);
        let p2 = project_out(&q, &p1);
        let mut diff = p1.clone();
        diff.axpy(-1.0, &p2);
        assert!(diff.max_abs() < 1e-10);
    }
}
