//! The blocked BLAS-like kernel layer on [`Mat`].
//!
//! Hand-written (offline build: no external BLAS) and organized as a
//! two-level layer:
//!
//! * **Micro-kernels** (`*_cols`) compute a contiguous range of *output
//!   columns* with cache tiling: `BLOCK_J`-wide column tiles of C stay
//!   hot while `BLOCK_K`-deep panels of A stream through, and a 4-column
//!   register kernel amortizes each load of an A column across four
//!   outputs.
//! * **Drivers** (`gemm_with`, `gemm_tn_with`, `syrk_tn_with`,
//!   `proj_gram_with`) partition output columns across a
//!   `std::thread::scope` worker pool sized by the [`Threads`] budget.
//!
//! Because the partition is over *output* columns, every output element
//! is produced by exactly one worker with a fixed sequential reduction
//! order — results are bitwise identical across thread counts, which is
//! what keeps `GRest` deterministic under `--threads N`.
//!
//! Panels in this codebase are tall-skinny (N×K, K ≤ a few hundred), so
//! the kernels are tuned for that regime.

use crate::linalg::mat::Mat;
pub use crate::linalg::threads::Threads;
use crate::linalg::threads::balanced_col_chunks;

/// Cache block along the shared (k) dimension.
const BLOCK_K: usize = 64;
/// Column tile of B/C per sweep (keeps the active C panel in cache).
const BLOCK_J: usize = 64;

/// C = A · B (auto thread budget).
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    gemm_with(a, b, Threads::AUTO)
}

/// C = A · B with an explicit thread budget.
pub fn gemm_with(a: &Mat, b: &Mat, threads: Threads) -> Mat {
    assert_eq!(
        a.cols(),
        b.rows(),
        "gemm dims: {}x{} · {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_acc_with(&mut c, a, b, 1.0, threads);
    c
}

/// C += alpha · A · B (auto thread budget).
pub fn gemm_acc(c: &mut Mat, a: &Mat, b: &Mat, alpha: f64) {
    gemm_acc_with(c, a, b, alpha, Threads::AUTO);
}

/// C += alpha · A · B — blocked, thread-parallel over output columns.
pub fn gemm_acc_with(c: &mut Mat, a: &Mat, b: &Mat, alpha: f64, threads: Threads) {
    let (m, kk) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), kk);
    assert_eq!((c.rows(), c.cols()), (m, n));
    let workers = threads.for_flops(2 * m * kk * n).min(n.max(1));
    if workers <= 1 {
        gemm_acc_cols(c.as_mut_slice(), m, 0..n, a, b, alpha);
        return;
    }
    let chunks = balanced_col_chunks(n, workers, |_| 1);
    std::thread::scope(|s| {
        let mut buf = c.as_mut_slice();
        for &(lo, hi) in &chunks {
            let (head, rest) = buf.split_at_mut((hi - lo) * m);
            buf = rest;
            s.spawn(move || gemm_acc_cols(head, m, lo..hi, a, b, alpha));
        }
    });
}

/// Compute columns `jr` of C += alpha·A·B into `c_cols` (the contiguous
/// column-major storage of exactly those columns).
fn gemm_acc_cols(
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: &Mat,
    b: &Mat,
    alpha: f64,
) {
    let kk = a.cols();
    let j0 = jr.start;
    let n = jr.end;
    // Outer: BLOCK_J-wide tiles of C (stay hot across all k blocks).
    let mut jt = j0;
    while jt < n {
        let jt_end = (jt + BLOCK_J).min(n);
        for k0 in (0..kk).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(kk);
            let mut j = jt;
            // 4-column micro-kernel: each loaded A column feeds 4 outputs.
            while j + 4 <= jt_end {
                let (b0c, b1c, b2c, b3c) = (b.col(j), b.col(j + 1), b.col(j + 2), b.col(j + 3));
                let base = (j - j0) * m;
                let (c0, rest) = c_cols[base..].split_at_mut(m);
                let (c1, rest) = rest.split_at_mut(m);
                let (c2, c3s) = rest.split_at_mut(m);
                let c3 = &mut c3s[..m];
                for k in k0..k1 {
                    let ak = a.col(k);
                    let w0 = alpha * b0c[k];
                    let w1 = alpha * b1c[k];
                    let w2 = alpha * b2c[k];
                    let w3 = alpha * b3c[k];
                    if w0 == 0.0 && w1 == 0.0 && w2 == 0.0 && w3 == 0.0 {
                        continue;
                    }
                    for i in 0..m {
                        let av = ak[i];
                        c0[i] += w0 * av;
                        c1[i] += w1 * av;
                        c2[i] += w2 * av;
                        c3[i] += w3 * av;
                    }
                }
                j += 4;
            }
            while j < jt_end {
                let bj = b.col(j);
                let cj = &mut c_cols[(j - j0) * m..(j - j0 + 1) * m];
                for k in k0..k1 {
                    let w = alpha * bj[k];
                    if w == 0.0 {
                        continue;
                    }
                    let ak = a.col(k);
                    for i in 0..m {
                        cj[i] += w * ak[i];
                    }
                }
                j += 1;
            }
        }
        jt = jt_end;
    }
}

/// C = Aᵀ · B without materializing Aᵀ (auto thread budget).
pub fn gemm_tn(a: &Mat, b: &Mat) -> Mat {
    gemm_tn_with(a, b, Threads::AUTO)
}

/// C = Aᵀ · B — the Gram kernel of the paper's projection step.  4×1
/// register blocking over A columns (each read of B feeds four dots),
/// thread-parallel over B columns.
pub fn gemm_tn_with(a: &Mat, b: &Mat, threads: Threads) -> Mat {
    assert_eq!(a.rows(), b.rows(), "gemm_tn dims");
    let (k, n) = (a.cols(), b.cols());
    let m = a.rows();
    let mut c = Mat::zeros(k, n);
    let workers = threads.for_flops(2 * m * k * n).min(n.max(1));
    if workers <= 1 {
        gemm_tn_cols(c.as_mut_slice(), 0..n, a, b);
        return c;
    }
    let chunks = balanced_col_chunks(n, workers, |_| 1);
    std::thread::scope(|s| {
        let mut buf = c.as_mut_slice();
        for &(lo, hi) in &chunks {
            let (head, rest) = buf.split_at_mut((hi - lo) * k);
            buf = rest;
            s.spawn(move || gemm_tn_cols(head, lo..hi, a, b));
        }
    });
    c
}

fn gemm_tn_cols(c_cols: &mut [f64], jr: std::ops::Range<usize>, a: &Mat, b: &Mat) {
    let k = a.cols();
    let m = a.rows();
    let j0 = jr.start;
    for j in jr {
        let bj = b.col(j);
        let cj = &mut c_cols[(j - j0) * k..(j - j0 + 1) * k];
        let mut p = 0;
        while p + 4 <= k {
            let (a0, a1, a2, a3) = (a.col(p), a.col(p + 1), a.col(p + 2), a.col(p + 3));
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for i in 0..m {
                let bv = bj[i];
                s0 += a0[i] * bv;
                s1 += a1[i] * bv;
                s2 += a2[i] * bv;
                s3 += a3[i] * bv;
            }
            cj[p] = s0;
            cj[p + 1] = s1;
            cj[p + 2] = s2;
            cj[p + 3] = s3;
            p += 4;
        }
        while p < k {
            cj[p] = dot(a.col(p), bj);
            p += 1;
        }
    }
}

/// Symmetric-result Gram product S = Aᵀ·B where AᵀB is *analytically*
/// symmetric (B = M·A with M = Mᵀ, or B = A): only the upper triangle is
/// computed (half the flops of `gemm_tn`) and mirrored.  This is the
/// `form_t` specialization of Eq. (13) — T₁₁ and T₂₂ are symmetric
/// because Δ is.
pub fn syrk_tn(a: &Mat, b: &Mat) -> Mat {
    syrk_tn_with(a, b, Threads::AUTO)
}

/// [`syrk_tn`] with an explicit thread budget.  Work is triangular, so
/// column chunks are balanced by `j+1` weights.
pub fn syrk_tn_with(a: &Mat, b: &Mat, threads: Threads) -> Mat {
    assert_eq!(a.rows(), b.rows(), "syrk_tn dims (rows)");
    assert_eq!(a.cols(), b.cols(), "syrk_tn needs square output");
    let p = a.cols();
    let n = a.rows();
    let mut c = Mat::zeros(p, p);
    let workers = threads.for_flops(n * p * (p + 1)).min(p.max(1));
    if workers <= 1 {
        syrk_tn_cols(c.as_mut_slice(), 0..p, a, b);
    } else {
        let chunks = balanced_col_chunks(p, workers, |j| j + 1);
        std::thread::scope(|s| {
            let mut buf = c.as_mut_slice();
            for &(lo, hi) in &chunks {
                let (head, rest) = buf.split_at_mut((hi - lo) * p);
                buf = rest;
                s.spawn(move || syrk_tn_cols(head, lo..hi, a, b));
            }
        });
    }
    mirror_upper(&mut c);
    c
}

fn syrk_tn_cols(c_cols: &mut [f64], jr: std::ops::Range<usize>, a: &Mat, b: &Mat) {
    let p = a.cols();
    let j0 = jr.start;
    for j in jr {
        let bj = b.col(j);
        let cj = &mut c_cols[(j - j0) * p..(j - j0 + 1) * p];
        for (i, out) in cj.iter_mut().enumerate().take(j + 1) {
            *out = dot(a.col(i), bj);
        }
    }
}

/// Copy the strict upper triangle onto the lower one in place.
fn mirror_upper(c: &mut Mat) {
    let p = c.rows();
    debug_assert_eq!(p, c.cols());
    for j in 0..p {
        for i in 0..j {
            let v = c.get(i, j);
            c.set(j, i, v);
        }
    }
}

/// Fused projection Gram: one sweep over the panel P computing both
/// C = XᵀP and the symmetric G = PᵀP (upper triangle + mirror).
///
/// This is the fusion behind `qr::orthonormalize_against`: with X
/// orthonormal, the Gram of the projected panel is
/// `(P−XC)ᵀ(P−XC) = G − CᵀC`, so the explicit project-out pass before
/// the Gram disappears — X̄ and P are each read once per CholeskyQR
/// round instead of twice.
pub fn proj_gram_with(x: &Mat, p: &Mat, threads: Threads) -> (Mat, Mat) {
    assert_eq!(x.rows(), p.rows(), "proj_gram dims");
    let n = p.rows();
    let k = x.cols();
    let m = p.cols();
    let mut c = Mat::zeros(k, m);
    let mut g = Mat::zeros(m, m);
    let workers = threads.for_flops(n * m * (2 * k + m + 1)).min(m.max(1));
    if workers <= 1 {
        proj_gram_cols(c.as_mut_slice(), g.as_mut_slice(), 0..m, x, p);
    } else {
        let chunks = balanced_col_chunks(m, workers, |j| k + j + 1);
        std::thread::scope(|s| {
            let mut cbuf = c.as_mut_slice();
            let mut gbuf = g.as_mut_slice();
            for &(lo, hi) in &chunks {
                let (chead, crest) = cbuf.split_at_mut((hi - lo) * k);
                let (ghead, grest) = gbuf.split_at_mut((hi - lo) * m);
                cbuf = crest;
                gbuf = grest;
                s.spawn(move || proj_gram_cols(chead, ghead, lo..hi, x, p));
            }
        });
    }
    mirror_upper(&mut g);
    (c, g)
}

fn proj_gram_cols(
    c_cols: &mut [f64],
    g_cols: &mut [f64],
    jr: std::ops::Range<usize>,
    x: &Mat,
    p: &Mat,
) {
    let k = x.cols();
    let m = p.cols();
    let j0 = jr.start;
    for j in jr {
        let pj = p.col(j);
        let cj = &mut c_cols[(j - j0) * k..(j - j0 + 1) * k];
        for (i, out) in cj.iter_mut().enumerate() {
            *out = dot(x.col(i), pj);
        }
        let gj = &mut g_cols[(j - j0) * m..(j - j0 + 1) * m];
        for (i, out) in gj.iter_mut().enumerate().take(j + 1) {
            *out = dot(p.col(i), pj);
        }
    }
}

/// Contiguous dot product (4-way unrolled).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// y = A · x (column-major gaxpy).
pub fn gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    for (j, &xj) in x.iter().enumerate() {
        if xj != 0.0 {
            axpy(xj, a.col(j), &mut y);
        }
    }
    y
}

/// y = Aᵀ · x.
pub fn gemv_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    (0..a.cols()).map(|j| dot(a.col(j), x)).collect()
}

/// P = B − X · C, the "apply" half of project-out (mirrors the Pallas
/// kernel `apply_proj`).
pub fn sub_matmul(b: &Mat, x: &Mat, c: &Mat) -> Mat {
    sub_matmul_with(b, x, c, Threads::AUTO)
}

/// [`sub_matmul`] with an explicit thread budget.
pub fn sub_matmul_with(b: &Mat, x: &Mat, c: &Mat, threads: Threads) -> Mat {
    let mut p = b.clone();
    gemm_acc_with(&mut p, x, c, -1.0, threads);
    p
}

/// P = (I − X Xᵀ) B — project `b` against the orthonormal panel `x`
/// (mirrors the Pallas `project_out` composition).
pub fn project_out(x: &Mat, b: &Mat) -> Mat {
    project_out_with(x, b, Threads::AUTO)
}

/// [`project_out`] with an explicit thread budget.
pub fn project_out_with(x: &Mat, b: &Mat, threads: Threads) -> Mat {
    let c = gemm_tn_with(x, b, threads);
    sub_matmul_with(b, x, &c, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    fn naive_mm(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a.get(i, k) * b.get(k, j)).sum()
        })
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (65, 130, 67), (100, 3, 100)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = gemm(&a, &b);
            let want = naive_mm(&a, &b);
            let mut diff = c.clone();
            diff.axpy(-1.0, &want);
            assert!(diff.max_abs() < 1e-10, "({m},{k},{n}): {}", diff.max_abs());
        }
    }

    #[test]
    fn gemm_tn_matches_transpose_mm() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(90, 13, &mut rng);
        let b = Mat::randn(90, 17, &mut rng);
        let c = gemm_tn(&a, &b);
        let want = naive_mm(&a.t(), &b);
        let mut diff = c.clone();
        diff.axpy(-1.0, &want);
        assert!(diff.max_abs() < 1e-10);
    }

    #[test]
    fn threaded_gemm_is_bitwise_equal_to_sequential() {
        // the determinism contract behind --threads N
        let mut rng = Rng::new(7);
        let a = Mat::randn(300, 90, &mut rng);
        let b = Mat::randn(90, 150, &mut rng);
        let seq = gemm_with(&a, &b, Threads::SINGLE);
        let par = gemm_with(&a, &b, Threads(4));
        assert_eq!(seq.as_slice(), par.as_slice(), "gemm not bitwise stable");
        let seq_tn = gemm_tn_with(&a, &a, Threads::SINGLE);
        let par_tn = gemm_tn_with(&a, &a, Threads(3));
        assert_eq!(seq_tn.as_slice(), par_tn.as_slice(), "gemm_tn not bitwise stable");
    }

    #[test]
    fn syrk_matches_gemm_tn_for_symmetric_products() {
        let mut rng = Rng::new(3);
        // large enough that the triangular kernel actually fans out
        let a = Mat::randn(320, 120, &mut rng);
        // B = A gives the exactly-symmetric Gram.  gemm_tn accumulates in
        // a different lane order than the dot-based triangular kernel, so
        // compare with a tolerance, not bitwise.
        let s = syrk_tn_with(&a, &a, Threads::SINGLE);
        let full = gemm_tn(&a, &a);
        for i in 0..120 {
            for j in 0..120 {
                let want = if i <= j { full.get(i, j) } else { full.get(j, i) };
                assert!(
                    (s.get(i, j) - want).abs() < 1e-10 * (1.0 + want.abs()),
                    "({i},{j}): {} vs {}",
                    s.get(i, j),
                    want
                );
            }
        }
        // the mirrored halves are exactly equal by construction
        for i in 0..120 {
            for j in 0..120 {
                assert_eq!(s.get(i, j), s.get(j, i), "symmetry ({i},{j})");
            }
        }
        // threaded triangular kernel agrees bitwise
        let s4 = syrk_tn_with(&a, &a, Threads(4));
        assert_eq!(s.as_slice(), s4.as_slice());
    }

    #[test]
    fn proj_gram_matches_separate_kernels() {
        let mut rng = Rng::new(4);
        // sized past the parallel threshold so the fused kernel fans out
        let x = Mat::randn(320, 60, &mut rng);
        let p = Mat::randn(320, 100, &mut rng);
        let (c, g) = proj_gram_with(&x, &p, Threads::SINGLE);
        // C vs gemm_tn: different lane order, tolerance compare
        let c_want = gemm_tn(&x, &p);
        let mut cd = c.clone();
        cd.axpy(-1.0, &c_want);
        assert!(cd.max_abs() < 1e-10, "C mismatch {}", cd.max_abs());
        // G vs syrk_tn: both dot-based, exactly equal
        let g_want = syrk_tn(&p, &p);
        let mut gd = g.clone();
        gd.axpy(-1.0, &g_want);
        assert_eq!(gd.max_abs(), 0.0);
        // threaded path bitwise identical
        let (c4, g4) = proj_gram_with(&x, &p, Threads(4));
        assert_eq!(c.as_slice(), c4.as_slice());
        assert_eq!(g.as_slice(), g4.as_slice());
    }

    #[test]
    fn gemv_matches() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(11, 7, &mut rng);
        let x: Vec<f64> = (0..7).map(|i| i as f64 - 3.0).collect();
        let y = gemv(&a, &x);
        for i in 0..11 {
            let want: f64 = (0..7).map(|j| a.get(i, j) * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-12);
        }
        let z = gemv_t(&a, &gemv(&a, &x));
        assert_eq!(z.len(), 7);
    }

    #[test]
    fn project_out_annihilates_range() {
        let mut rng = Rng::new(4);
        let raw = Mat::randn(60, 6, &mut rng);
        let (q, _) = crate::linalg::qr::thin_qr(&raw);
        let coeff = Mat::randn(6, 4, &mut rng);
        let b = gemm(&q, &coeff);
        let p = project_out(&q, &b);
        assert!(p.max_abs() < 1e-10);
    }

    #[test]
    fn project_out_fixes_orthogonal_complement() {
        let mut rng = Rng::new(5);
        let raw = Mat::randn(50, 5, &mut rng);
        let (q, _) = crate::linalg::qr::thin_qr(&raw);
        let b = Mat::randn(50, 3, &mut rng);
        let p1 = project_out(&q, &b);
        let p2 = project_out(&q, &p1);
        let mut diff = p1.clone();
        diff.axpy(-1.0, &p2);
        assert!(diff.max_abs() < 1e-10);
    }
}
