//! The SIMD rungs of the GEMM dispatch ladder: the packed driver of
//! [`gemm_packed`] with AVX2 (bitwise) and FMA (opt-in, approximate)
//! register-tile micro-kernels.
//!
//! This is the **only** module in `rust/src` allowed to touch
//! `std::arch` (detlint rule `raw-intrinsics`); feature *detection*
//! lives beside `detected_parallelism()` in [`threads::simd_level`] and
//! uses only the `is_x86_feature_detected!` macro.  Stable intrinsics
//! only — no nightly, no portable-simd.
//!
//! ## Why the AVX2 rung stays bitwise
//!
//! The scalar micro-kernel's per-k update of one register tile is
//!
//! ```text
//! for t in 0..MR { r0[t] += w0·a[t]; r1[t] += w1·a[t]; r2[t] += w2·a[t]; r3[t] += w3·a[t]; }
//! ```
//!
//! — per output element `(column c, row t)` that is one individually
//! rounded multiply followed by one individually rounded add per k,
//! ascending k.  The AVX2 kernel vectorizes *across the `NR` = 4 output
//! columns*: accumulator `t` holds the lane quad `(c0[t], c1[t], c2[t],
//! c3[t])`, each k broadcasts `a[t]` and performs `_mm256_add_pd(acc,
//! _mm256_mul_pd(w, a))` — separate mul and add, each rounding per lane
//! exactly as the scalar ops do (Rust never contracts explicit `*`/`+`,
//! and these intrinsics *are* the explicit ops).  Every lane is a
//! distinct output element, so no cross-element reassociation happens
//! and the per-element update sequence is identical to the scalar
//! micro-kernel — hence identical to the blocked oracle.  The skip
//! predicate, packing, row remainder, and column tail are the shared
//! driver's ([`gemm_packed::gemm_acc_cols_with_micro`]), not duplicated
//! here.
//!
//! The FMA kernel replaces mul+add with `_mm256_fmadd_pd` — one rounding
//! per update instead of two.  That is usually *more* accurate but it is
//! **not** the oracle's rounding sequence, so the FMA rung is excluded
//! from `Auto` routing and only runs when a caller pins
//! `GemmKernel::PackedFma` (see the exactness matrix in the README).
//!
//! ## Soundness
//!
//! The `#[target_feature]` micro-kernels are reached only through
//! [`gemm_acc_cols_simd_level`], which clamps the requested level to the
//! runtime-detected [`simd_level()`] — the single point establishing the
//! "CPU really has AVX2/FMA" precondition every SAFETY comment below
//! cites.  On non-x86_64 targets detection is pinned to `Scalar` and
//! every entry point degrades to the packed scalar rung.

use crate::linalg::gemm_packed;
use crate::linalg::mat::{Mat, Padded};
use crate::linalg::threads::{simd_level, SimdLevel};

/// SIMD twin of [`gemm_packed::gemm_acc_cols_packed`] at the machine's
/// detected [`simd_level`]: AVX2 micro-kernel where detected (bitwise
/// identical to the packed scalar rung), packed scalar elsewhere.
/// Never selects FMA — `Auto` routing goes through here.
pub(crate) fn gemm_acc_cols_simd(
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: Padded<'_>,
    b: &Mat,
    alpha: f64,
) {
    // cap at Avx2: the detected level may be Avx2Fma, but FMA changes
    // rounding and must stay opt-in
    gemm_acc_cols_simd_level(SimdLevel::Avx2, c_cols, m, jr, a, b, alpha);
}

/// [`gemm_acc_cols_simd`] at an explicit level, clamped to the detected
/// one.  `Scalar` *is* the packed scalar rung (the forced-scalar path
/// tests assert bitwise equality through this entry point); a level the
/// machine lacks silently degrades — which is what makes handing the
/// `#[target_feature]` micro-kernels to the safe driver sound.
pub(crate) fn gemm_acc_cols_simd_level(
    level: SimdLevel,
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: Padded<'_>,
    b: &Mat,
    alpha: f64,
) {
    match level.min(simd_level()) {
        SimdLevel::Scalar => gemm_packed::gemm_acc_cols_packed(c_cols, m, jr, a, b, alpha),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            gemm_packed::gemm_acc_cols_with_micro(c_cols, m, jr, a, b, alpha, x86::micro_avx2)
        }
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2Fma => {
            gemm_packed::gemm_acc_cols_with_micro(c_cols, m, jr, a, b, alpha, x86::micro_fma)
        }
        // non-x86_64: simd_level() is pinned to Scalar, so the clamp
        // above already routed every call to the first arm
        #[cfg(not(target_arch = "x86_64"))]
        _ => gemm_packed::gemm_acc_cols_packed(c_cols, m, jr, a, b, alpha),
    }
}

/// The opt-in FMA rung (`GemmKernel::PackedFma`): fused multiply-add in
/// the register tile where the machine supports it, degrading to the
/// bitwise AVX2/scalar path elsewhere.  **Not bitwise** against the
/// oracle on FMA machines — callers opt into a different (typically
/// tighter) rounding.
pub(crate) fn gemm_acc_cols_fma(
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: Padded<'_>,
    b: &Mat,
    alpha: f64,
) {
    gemm_acc_cols_simd_level(SimdLevel::Avx2Fma, c_cols, m, jr, a, b, alpha);
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! The register-tile micro-kernels.  Both match the shared driver's
    //! [`MicroKernel`](super::gemm_packed::MicroKernel) contract: tile
    //! rows `ip..ip + MR` of the four output columns, packed A panel
    //! `ap` (k-major, `MR` rows per k), weight quads `wq` (`NR` weights
    //! per k — already contiguous, one unaligned vector load each), and
    //! the precomputed all-zero `skip` predicate.

    use crate::linalg::gemm_packed::{MR, NR};
    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_fmadd_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd,
        _mm256_set_pd, _mm256_setzero_pd, _mm256_storeu_pd,
    };

    /// Safe [`MicroKernel`](super::gemm_packed::MicroKernel) wrapper for
    /// the AVX2 tile.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn micro_avx2(
        c0: &mut [f64],
        c1: &mut [f64],
        c2: &mut [f64],
        c3: &mut [f64],
        ip: usize,
        ap: &[f64],
        wq: &[f64],
        skip: &[u8],
        kb: usize,
    ) {
        // SAFETY: this fn is handed to the packed driver only by
        // `gemm_acc_cols_simd_level` after clamping against the
        // runtime-detected `simd_level()`, so AVX2 is available on this
        // CPU.  Slice preconditions (`c*[ip..ip+MR]`, `ap`/`wq`/`skip`
        // sized for `kb`) are the driver's MicroKernel contract, same as
        // the scalar tile.
        unsafe { tile_avx2(c0, c1, c2, c3, ip, ap, wq, skip, kb) }
    }

    /// Safe wrapper for the FMA tile (reached only via
    /// `GemmKernel::PackedFma`).
    #[allow(clippy::too_many_arguments)]
    pub(super) fn micro_fma(
        c0: &mut [f64],
        c1: &mut [f64],
        c2: &mut [f64],
        c3: &mut [f64],
        ip: usize,
        ap: &[f64],
        wq: &[f64],
        skip: &[u8],
        kb: usize,
    ) {
        // SAFETY: as for `micro_avx2`, plus the clamp guarantees the
        // `fma` feature — `Avx2Fma` is only selected when detected.
        unsafe { tile_fma(c0, c1, c2, c3, ip, ap, wq, skip, kb) }
    }

    /// AVX2 8×4 register tile, one lane per output column: bitwise
    /// identical to the scalar tile (see module docs).
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn tile_avx2(
        c0: &mut [f64],
        c1: &mut [f64],
        c2: &mut [f64],
        c3: &mut [f64],
        ip: usize,
        ap: &[f64],
        wq: &[f64],
        skip: &[u8],
        kb: usize,
    ) {
        // SAFETY: AVX2 is enabled for this fn (checked at selection time
        // by the caller chain — see `micro_avx2`); the raw loads/stores
        // stay inside `wq` (`kb·NR` long, offset `kidx·NR + 4 ≤ kb·NR`)
        // and the stack quad `out`.
        unsafe {
            // transpose-load C: acc[t] = (c0[ip+t], c1[ip+t], c2[ip+t],
            // c3[ip+t]) — _mm256_set_pd takes lanes high-to-low
            let mut acc = [_mm256_setzero_pd(); MR];
            for (t, lane) in acc.iter_mut().enumerate() {
                *lane = _mm256_set_pd(c3[ip + t], c2[ip + t], c1[ip + t], c0[ip + t]);
            }
            for kidx in 0..kb {
                if skip[kidx] != 0 {
                    continue;
                }
                let wv = _mm256_loadu_pd(wq.as_ptr().add(kidx * NR));
                let a8 = &ap[kidx * MR..(kidx + 1) * MR];
                for (t, lane) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_pd(a8[t]);
                    // separate mul + add: two roundings per lane, the
                    // scalar tile's exact op sequence per element
                    *lane = _mm256_add_pd(*lane, _mm256_mul_pd(wv, av));
                }
            }
            store_tile(c0, c1, c2, c3, ip, &acc);
        }
    }

    /// FMA 8×4 register tile: same lane layout, fused multiply-add (one
    /// rounding per update — NOT the oracle's sequence).
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn tile_fma(
        c0: &mut [f64],
        c1: &mut [f64],
        c2: &mut [f64],
        c3: &mut [f64],
        ip: usize,
        ap: &[f64],
        wq: &[f64],
        skip: &[u8],
        kb: usize,
    ) {
        // SAFETY: AVX2+FMA enabled for this fn (selection-time runtime
        // detection, see `micro_fma`); bounds as in `tile_avx2`.
        unsafe {
            let mut acc = [_mm256_setzero_pd(); MR];
            for (t, lane) in acc.iter_mut().enumerate() {
                *lane = _mm256_set_pd(c3[ip + t], c2[ip + t], c1[ip + t], c0[ip + t]);
            }
            for kidx in 0..kb {
                if skip[kidx] != 0 {
                    continue;
                }
                let wv = _mm256_loadu_pd(wq.as_ptr().add(kidx * NR));
                let a8 = &ap[kidx * MR..(kidx + 1) * MR];
                for (t, lane) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_pd(a8[t]);
                    *lane = _mm256_fmadd_pd(wv, av, *lane);
                }
            }
            store_tile(c0, c1, c2, c3, ip, &acc);
        }
    }

    /// Scatter the accumulator quads back into the four C columns.
    #[target_feature(enable = "avx2")]
    unsafe fn store_tile(
        c0: &mut [f64],
        c1: &mut [f64],
        c2: &mut [f64],
        c3: &mut [f64],
        ip: usize,
        acc: &[__m256d; MR],
    ) {
        // SAFETY: AVX2 enabled (callers are the AVX2/FMA tiles); the
        // store target is a 4-wide stack array.
        unsafe {
            let mut out = [0.0f64; NR];
            for (t, lane) in acc.iter().enumerate() {
                _mm256_storeu_pd(out.as_mut_ptr(), *lane);
                c0[ip + t] = out[0];
                c1[ip + t] = out[1];
                c2[ip + t] = out[2];
                c3[ip + t] = out[3];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{gemm_acc_with_kernel, GemmKernel, Threads};
    use crate::linalg::rng::Rng;

    /// Random matrix with exact zeros sprinkled in (including whole
    /// all-zero columns) to exercise the shared skip predicate.
    fn randn_sparse(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::randn(rows, cols, rng);
        for j in 0..cols {
            for i in 0..rows {
                if rng.below(10) < 3 {
                    m.set(i, j, 0.0);
                }
            }
            if cols >= 4 && j % 7 == 3 {
                for i in 0..rows {
                    m.set(i, j, 0.0);
                }
            }
        }
        m
    }

    #[test]
    fn simd_is_bitwise_identical_to_packed_across_tile_straddles() {
        let mut rng = Rng::new(52);
        // the packed rung's shape battery: every MR/NR/BLOCK straddle,
        // k ∈ {0, 1}, sub-tile heights/widths, Padded extras
        let shapes: &[(usize, usize, usize, usize)] = &[
            // (filled_rows, extra_rows, k, ncols)
            (1, 0, 1, 1),
            (7, 0, 1, 3),
            (8, 0, 16, 4),
            (9, 5, 17, 5),
            (16, 0, 64, 8),
            (23, 9, 65, 13),
            (31, 1, 63, 64),
            (128, 0, 64, 65),
            (129, 7, 129, 67),
            (200, 48, 32, 32),
            (5, 0, 0, 6),
            (64, 0, 1, 130),
            (257, 3, 100, 20),
        ];
        for &(mt, extra, kk, ncols) in shapes {
            let x = Mat::randn(mt, kk, &mut rng);
            let bm = randn_sparse(kk, ncols, &mut rng);
            let a = Padded::new(&x, extra);
            let m = mt + extra;
            for &alpha in &[1.0, -1.0, 0.0, 0.37] {
                let seed = Mat::randn(m, ncols, &mut rng);
                let mut c_packed = seed.clone();
                let mut c_simd = seed.clone();
                let jr = 0..ncols;
                gemm_packed::gemm_acc_cols_packed(c_packed.as_mut_slice(), m, jr, a, &bm, alpha);
                gemm_acc_cols_simd(c_simd.as_mut_slice(), m, 0..ncols, a, &bm, alpha);
                assert_eq!(
                    c_packed.as_slice(),
                    c_simd.as_slice(),
                    "simd drifted from packed oracle at mt={mt} extra={extra} k={kk} n={ncols} alpha={alpha}"
                );
            }
        }
    }

    #[test]
    fn simd_matches_packed_on_nonzero_column_offsets() {
        // chunked invocation: the pool hands each chunk a j-range with
        // j0 > 0; tile bases are chunk-relative, exactly as packed
        let mut rng = Rng::new(53);
        let mt = 70;
        let kk = 40;
        let ncols = 90;
        let x = Mat::randn(mt, kk, &mut rng);
        let bm = randn_sparse(kk, ncols, &mut rng);
        let a = Padded::new(&x, 2);
        let m = mt + 2;
        for &(lo, hi) in &[(0usize, 37usize), (37, 70), (70, 90), (5, 9), (88, 90)] {
            let seed = Mat::randn(m, hi - lo, &mut rng);
            let mut cp = seed.clone();
            let mut cs = seed.clone();
            gemm_packed::gemm_acc_cols_packed(cp.as_mut_slice(), m, lo..hi, a, &bm, -0.5);
            gemm_acc_cols_simd(cs.as_mut_slice(), m, lo..hi, a, &bm, -0.5);
            assert_eq!(cp.as_slice(), cs.as_slice(), "chunk {lo}..{hi} drifted");
        }
    }

    #[test]
    fn forced_scalar_level_reproduces_the_packed_rung_bitwise() {
        // the satellite contract: pinning SimdLevel::Scalar through the
        // explicit-level entry point IS the packed scalar rung
        let mut rng = Rng::new(54);
        let x = Mat::randn(150, 40, &mut rng);
        let bm = randn_sparse(40, 48, &mut rng);
        let a = Padded::new(&x, 6);
        let m = 156;
        let seed = Mat::randn(m, 48, &mut rng);
        let mut cp = seed.clone();
        let mut cs = seed.clone();
        gemm_packed::gemm_acc_cols_packed(cp.as_mut_slice(), m, 0..48, a, &bm, 1.25);
        gemm_acc_cols_simd_level(SimdLevel::Scalar, cs.as_mut_slice(), m, 0..48, a, &bm, 1.25);
        assert_eq!(cp.as_slice(), cs.as_slice());
    }

    #[test]
    fn simd_rung_is_bitwise_across_thread_counts() {
        // shapes × threads through the public ladder: every chunk the
        // pool dispatches runs the same micro-kernel sequence
        let mut rng = Rng::new(55);
        for &(mt, extra, kk, ncols) in
            &[(64usize, 0usize, 32usize, 40usize), (150, 10, 48, 90), (257, 3, 100, 20)]
        {
            let x = Mat::randn(mt, kk, &mut rng);
            let bm = randn_sparse(kk, ncols, &mut rng);
            let a = Padded::new(&x, extra);
            let m = mt + extra;
            let seed = Mat::randn(m, ncols, &mut rng);
            let mut want = seed.clone();
            gemm_acc_with_kernel(&mut want, a, &bm, -0.75, Threads::SINGLE, GemmKernel::Packed);
            for &tc in &[Threads(1), Threads(4)] {
                let mut c = seed.clone();
                gemm_acc_with_kernel(&mut c, a, &bm, -0.75, tc, GemmKernel::PackedSimd);
                assert_eq!(
                    c.as_slice(),
                    want.as_slice(),
                    "mt={mt} extra={extra} k={kk} n={ncols} t={}",
                    tc.0
                );
            }
        }
    }

    #[test]
    fn fma_rung_is_close_but_opt_in() {
        // FMA is allowed to differ in the last bits (one rounding per
        // update instead of two) but must stay within a tight relative
        // tolerance of the oracle; on machines without FMA it degrades
        // to the bitwise path, which this bound also accepts
        let mut rng = Rng::new(56);
        let x = Mat::randn(200, 64, &mut rng);
        let bm = randn_sparse(64, 48, &mut rng);
        let a = Padded::new(&x, 0);
        let seed = Mat::randn(200, 48, &mut rng);
        let mut want = seed.clone();
        let mut got = seed.clone();
        gemm_packed::gemm_acc_cols_packed(want.as_mut_slice(), 200, 0..48, a, &bm, 1.0);
        gemm_acc_cols_fma(got.as_mut_slice(), 200, 0..48, a, &bm, 1.0);
        let scale = want.max_abs().max(1.0);
        let mut diff = got.clone();
        diff.axpy(-1.0, &want);
        assert!(
            diff.max_abs() <= 1e-12 * scale,
            "fma rung drifted beyond rounding noise: {}",
            diff.max_abs()
        );
    }
}
