//! [`StepWorkspace`] — the grow-only scratch arena behind the
//! allocation-free G-REST update step.
//!
//! Every per-step temporary of the dense pipeline (the assembled panel,
//! the BCGS2 round buffers, ΔQ, T, F₁/F₂, the small-eigh scratch, and
//! the double-buffered state vectors) is drawn from this pool and
//! returned after use.  The pool is a LIFO stack of `f64` buffers: a
//! step performs a fixed sequence of take/give calls, so after a warm-up
//! step at a given problem shape every `take` pops a buffer whose
//! capacity already fits and **no heap allocation happens** — the
//! property `benches/microbench_grest.rs` asserts with a counting global
//! allocator.
//!
//! Buffers hand out as [`Mat`]s via [`StepWorkspace::take_mat`]
//! (zero-filled, reshaped in place) or as raw scratch vectors via
//! [`StepWorkspace::take_buf`] (cleared, capacity kept).  Give every
//! buffer back when done; leaking one is harmless (the pool regrows) but
//! re-introduces steady-state allocations.

use crate::linalg::eigh::EighWork;
use crate::linalg::f32mat::F32Mat;
use crate::linalg::mat::Mat;

/// Upper bound on pooled buffers.  The native G-REST step keeps ~20 in
/// flight, comfortably under the cap, so it never drops (and stays
/// allocation-free).  Backends that return *fresh* matrices instead of
/// workspace-backed ones (the PJRT/XLA wrapper) give back more buffers
/// than they take; without a cap the LIFO pool would grow by a few
/// large buffers per step, a slow leak over long streams.  Excess
/// buffers are simply dropped.
const POOL_CAP: usize = 32;

/// Grow-only buffer pool plus the named scratch of one tracker step.
pub struct StepWorkspace {
    pool: Vec<Vec<f64>>,
    flag_pool: Vec<Vec<bool>>,
    /// f32 buffers of the serving tier (panel demotion scratch — see
    /// `linalg::f32mat`); same LIFO/[`POOL_CAP`] discipline as `pool`.
    f32_pool: Vec<Vec<f32>>,
    /// Surviving panel-column indices of the last `build_basis`.
    pub kept: Vec<usize>,
    /// Ritz-pair ordering scratch (`order_by_magnitude_into`).
    pub order: Vec<usize>,
    /// Small symmetric eigendecomposition scratch.
    pub eig: EighWork,
}

impl Default for StepWorkspace {
    fn default() -> StepWorkspace {
        StepWorkspace::new()
    }
}

impl StepWorkspace {
    pub fn new() -> StepWorkspace {
        StepWorkspace {
            pool: Vec::new(),
            flag_pool: Vec::new(),
            f32_pool: Vec::new(),
            kept: Vec::new(),
            order: Vec::new(),
            eig: EighWork::new(),
        }
    }

    /// An empty `Vec<f32>` with recycled capacity (length 0).
    pub fn take_f32_buf(&mut self) -> Vec<f32> {
        let mut buf = self.f32_pool.pop().unwrap_or_default();
        buf.clear();
        buf
    }

    /// Return an f32 scratch vector to the pool (dropped at
    /// [`POOL_CAP`]).
    pub fn give_f32_buf(&mut self, buf: Vec<f32>) {
        if self.f32_pool.len() < POOL_CAP {
            self.f32_pool.push(buf);
        }
    }

    /// Demote `m` into an [`F32Mat`] backed by a recycled buffer.
    pub fn take_f32_mat(&mut self, m: &Mat) -> F32Mat {
        let buf = self.take_f32_buf();
        F32Mat::from_mat_in(m, buf)
    }

    /// Return an [`F32Mat`]'s backing buffer to the pool.
    pub fn give_f32_mat(&mut self, m: F32Mat) {
        self.give_f32_buf(m.into_vec());
    }

    /// A zero-filled rows×cols matrix backed by a recycled buffer.
    pub fn take_mat(&mut self, rows: usize, cols: usize) -> Mat {
        let mut buf = self.pool.pop().unwrap_or_default();
        buf.clear();
        buf.resize(rows * cols, 0.0);
        Mat::from_vec(rows, cols, buf)
    }

    /// Return a matrix's backing buffer to the pool (dropped if the
    /// pool is at [`POOL_CAP`]).
    pub fn give_mat(&mut self, m: Mat) {
        self.give_buf(m.into_vec());
    }

    /// An empty `Vec<f64>` with recycled capacity (length 0).
    pub fn take_buf(&mut self) -> Vec<f64> {
        let mut buf = self.pool.pop().unwrap_or_default();
        buf.clear();
        buf
    }

    /// Return a scratch vector to the pool (dropped if the pool is at
    /// [`POOL_CAP`]).
    pub fn give_buf(&mut self, buf: Vec<f64>) {
        if self.pool.len() < POOL_CAP {
            self.pool.push(buf);
        }
    }

    /// A `Vec<bool>` of `len` copies of `init`, capacity recycled.
    pub fn take_flags(&mut self, len: usize, init: bool) -> Vec<bool> {
        let mut f = self.flag_pool.pop().unwrap_or_default();
        f.clear();
        f.resize(len, init);
        f
    }

    /// Return a flag vector to the pool (same [`POOL_CAP`] bound).
    pub fn give_flags(&mut self, f: Vec<bool>) {
        if self.flag_pool.len() < POOL_CAP {
            self.flag_pool.push(f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_mat_is_zero_filled_even_after_reuse() {
        let mut ws = StepWorkspace::new();
        let mut m = ws.take_mat(3, 2);
        m.set(2, 1, 7.0);
        ws.give_mat(m);
        let m2 = ws.take_mat(2, 2);
        assert!(m2.as_slice().iter().all(|&v| v == 0.0));
        assert_eq!((m2.rows(), m2.cols()), (2, 2));
    }

    #[test]
    fn pool_recycles_capacity() {
        let mut ws = StepWorkspace::new();
        let m = ws.take_mat(100, 4);
        let ptr = m.as_slice().as_ptr();
        ws.give_mat(m);
        // same-or-smaller request reuses the same backing buffer
        let m2 = ws.take_mat(50, 8);
        assert_eq!(m2.as_slice().as_ptr(), ptr);
        ws.give_mat(m2);
        let buf = ws.take_buf();
        assert!(buf.capacity() >= 400);
        assert_eq!(buf.len(), 0);
        ws.give_buf(buf);
    }

    #[test]
    fn pool_is_capped() {
        // a backend that gives more than it takes (the XLA wrapper)
        // must not grow the pool without bound
        let mut ws = StepWorkspace::new();
        for _ in 0..3 * POOL_CAP {
            ws.give_buf(vec![0.0; 8]);
            ws.give_flags(vec![true; 8]);
            ws.give_f32_buf(vec![0.0f32; 8]);
        }
        assert_eq!(ws.pool.len(), POOL_CAP);
        assert_eq!(ws.flag_pool.len(), POOL_CAP);
        assert_eq!(ws.f32_pool.len(), POOL_CAP);
    }

    #[test]
    fn f32_pool_recycles_through_f32mat() {
        let mut ws = StepWorkspace::new();
        let m = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let f = ws.take_f32_mat(&m);
        assert_eq!(f.row(1), &[3.0f32, 4.0]);
        let ptr = f.row(0).as_ptr();
        ws.give_f32_mat(f);
        // same-or-smaller demotion reuses the returned buffer
        let f2 = ws.take_f32_mat(&m);
        assert_eq!(f2.row(0).as_ptr(), ptr);
        assert_eq!(f2.row(0), &[1.0f32, 2.0]);
        ws.give_f32_mat(f2);
        let buf = ws.take_f32_buf();
        assert_eq!(buf.len(), 0);
        assert!(buf.capacity() >= 4);
        ws.give_f32_buf(buf);
    }

    #[test]
    fn flags_reset_on_take() {
        let mut ws = StepWorkspace::new();
        let mut f = ws.take_flags(4, true);
        f[2] = false;
        ws.give_flags(f);
        let f2 = ws.take_flags(6, true);
        assert_eq!(f2, vec![true; 6]);
    }
}
