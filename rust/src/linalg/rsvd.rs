//! Randomized SVD range finder (paper Sec. 3.5, steps S.1–S.4).
//!
//! Computes an approximate orthonormal basis `R` for the column space of
//! `(I − X Xᵀ) Δ₂` with target rank `L` and oversampling `P`, touching Δ₂
//! only through sparse products (supplied as closures), so the dense
//! N×S matrix is never materialized.

use crate::linalg::blas;
use crate::linalg::mat::{Mat, Padded};
use crate::linalg::rng::Rng;
use crate::linalg::svd::thin_svd;

/// Randomized basis of (I − XXᵀ)Δ₂.
///
/// * `s` — number of columns of Δ₂ (newly added nodes).
/// * `d2_mult(Ω)`   — Δ₂ · Ω for Ω (S×j), returns (N×j).
/// * `d2_t_mult(M, extra)` — Δ₂ᵀ · [M; 0] where `extra` zero rows pad M
///   to N rows, returns (S×j).  The split signature lets the caller pass
///   the X̄ view without materializing its zero rows (and plain panels
///   with `extra == 0`).
/// * `x` — orthonormal panel to project out, as a [`Padded`] view so
///   the G-REST caller never materializes X̄ (`None` to skip).
/// * `l`, `p` — rank and oversampling (paper's L and P).
///
/// Returns an N×L′ orthonormal matrix, L′ ≤ L (smaller if the sketch
/// reveals lower rank — Prop. 5 guarantees exact recovery when
/// rank(Δ₂) ≤ L+P).
pub fn rsvd_basis(
    s: usize,
    d2_mult: &dyn Fn(&Mat) -> Mat,
    d2_t_mult: &dyn Fn(&Mat, usize) -> Mat,
    x: Option<Padded<'_>>,
    l: usize,
    p: usize,
    rng: &mut Rng,
) -> Mat {
    let lp = (l + p).min(s).max(1);
    // S.1: Y = (I − XXᵀ) Δ₂ Ω
    let omega = Mat::randn(s, lp, rng);
    let mut y = d2_mult(&omega);
    if let Some(xm) = x {
        y = blas::project_out(xm, &y);
    }
    // Orthonormal M = Ran(Y); deflate numerically-zero directions.
    let empty = Mat::zeros(y.rows(), 0);
    let (m_basis, kept) = crate::linalg::qr::orthonormalize_against(&empty, &y, 1e-10);
    if kept.is_empty() {
        return Mat::zeros(y.rows(), 0);
    }
    // S.2: small SVD of B = Mᵀ (I − XXᵀ) Δ₂  ((L+P)×S), computed as
    //      (Δ₂ᵀ M)ᵀ − (Mᵀ X)(Xᵀ Δ₂) without densifying Δ₂.
    let d2t_m = d2_t_mult(&m_basis, 0); // S×(L+P)
    let mut b_t = d2t_m; // Bᵀ: S×(L+P)
    if let Some(xm) = x {
        // Bᵀ -= (Δ₂ᵀ X)(Xᵀ M)  — Xᵀ M is ~0 by construction of M, but we
        // keep the exact correction for robustness.
        let d2t_x = d2_t_mult(xm.mat, xm.extra_rows); // S×K
        let xt_m = blas::gemm_tn(xm, &m_basis); // K×(L+P)
        blas::gemm_acc(&mut b_t, &d2t_x, &xt_m, -1.0);
    }
    // thin_svd wants rows >= cols; Bᵀ is S×(L+P).  If S < L+P (clamped
    // above: lp <= s) this holds with equality allowed.
    let svd = thin_svd(&b_t);
    // Left singular vectors of B = right singular vectors of Bᵀ = svd.v.
    let rank = svd
        .s
        .iter()
        .take_while(|&&sv| sv > 1e-10 * svd.s.first().copied().unwrap_or(0.0).max(1e-300))
        .count()
        .min(l);
    // S.4: R = M Û(:, 1..rank)
    let u_hat = svd.v.top_left(svd.v.rows(), rank);
    m_basis.matmul(&u_hat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::thin_qr;

    fn dense_ops(d2: &Mat) -> (impl Fn(&Mat) -> Mat + '_, impl Fn(&Mat, usize) -> Mat + '_) {
        (
            move |om: &Mat| d2.matmul(om),
            move |m: &Mat, extra: usize| d2.t_matmul(&m.pad_rows(extra)),
        )
    }

    #[test]
    fn exact_recovery_of_low_rank() {
        // rank(Δ₂)=3 ≤ L+P ⇒ range recovered exactly (Prop. 5 / Sec. 3.5)
        let mut rng = Rng::new(1);
        let left = Mat::randn(80, 3, &mut rng);
        let right = Mat::randn(3, 20, &mut rng);
        let d2 = left.matmul(&right);
        let (mul, tmul) = dense_ops(&d2);
        let r = rsvd_basis(20, &mul, &tmul, None, 5, 3, &mut rng);
        assert!(r.cols() <= 5);
        assert!(r.cols() >= 3);
        // Ran(d2) ⊆ Ran(r): projecting d2 out of r leaves nothing
        let resid = blas::project_out(&r, &d2);
        assert!(resid.max_abs() < 1e-8, "resid {}", resid.max_abs());
    }

    #[test]
    fn output_is_orthonormal_and_orthogonal_to_x() {
        let mut rng = Rng::new(2);
        let (x, _) = thin_qr(&Mat::randn(60, 5, &mut rng));
        let d2 = Mat::randn(60, 30, &mut rng);
        let (mul, tmul) = dense_ops(&d2);
        let r = rsvd_basis(30, &mul, &tmul, Some(Padded::from(&x)), 8, 4, &mut rng);
        assert_eq!(r.cols(), 8);
        let g = r.t_matmul(&r);
        let mut eye = Mat::eye(8);
        eye.axpy(-1.0, &g);
        assert!(eye.max_abs() < 1e-8);
        assert!(x.t_matmul(&r).max_abs() < 1e-8);
    }

    #[test]
    fn captures_dominant_directions() {
        // Δ₂ with a strongly dominant rank-2 part: the L=2 basis must
        // capture most of its energy.
        let mut rng = Rng::new(3);
        let strong = Mat::randn(100, 2, &mut rng);
        let mut d2 = strong.matmul(&Mat::randn(2, 40, &mut rng));
        d2.scale(10.0);
        let noise = Mat::randn(100, 40, &mut rng);
        d2.axpy(0.01, &noise);
        let (mul, tmul) = dense_ops(&d2);
        let r = rsvd_basis(40, &mul, &tmul, None, 2, 6, &mut rng);
        let resid = blas::project_out(&r, &d2);
        assert!(resid.fro_norm() < 0.05 * d2.fro_norm());
    }

    #[test]
    fn zero_delta2_yields_empty_basis() {
        let mut rng = Rng::new(4);
        let d2 = Mat::zeros(50, 10);
        let (mul, tmul) = dense_ops(&d2);
        let r = rsvd_basis(10, &mul, &tmul, None, 4, 2, &mut rng);
        assert_eq!(r.cols(), 0);
    }
}
