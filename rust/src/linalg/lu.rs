//! Dense LU solve with partial pivoting — used by the TRIP baseline
//! (paper Eq. 7) for its K×K linear systems.

use crate::linalg::mat::Mat;

/// Solve A x = b for a dense square A (destroys a working copy).
/// Returns `None` if the matrix is numerically singular.
pub fn solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    assert_eq!(n, b.len());
    let mut lu = a.clone();
    let mut x = b.to_vec();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // partial pivot
        let mut pk = k;
        let mut pmax = lu.get(k, k).abs();
        for i in k + 1..n {
            let v = lu.get(i, k).abs();
            if v > pmax {
                pmax = v;
                pk = i;
            }
        }
        if pmax < 1e-300 {
            return None;
        }
        if pk != k {
            piv.swap(pk, k);
            for j in 0..n {
                let t = lu.get(k, j);
                lu.set(k, j, lu.get(pk, j));
                lu.set(pk, j, t);
            }
            x.swap(pk, k);
        }
        let dkk = lu.get(k, k);
        for i in k + 1..n {
            let f = lu.get(i, k) / dkk;
            lu.set(i, k, f);
            if f != 0.0 {
                for j in k + 1..n {
                    let cur = lu.get(i, j);
                    lu.set(i, j, cur - f * lu.get(k, j));
                }
                x[i] -= f * x[k];
            }
        }
    }
    // back substitution
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in i + 1..n {
            s -= lu.get(i, j) * x[j];
        }
        x[i] = s / lu.get(i, i);
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, rng::Rng};

    #[test]
    fn solves_random_systems() {
        let mut rng = Rng::new(1);
        for &n in &[1usize, 2, 5, 20, 64] {
            let a = Mat::randn(n, n, &mut rng);
            let xtrue: Vec<f64> = (0..n).map(|i| (i as f64) - 1.5).collect();
            let b = blas::gemv(&a, &xtrue);
            let x = solve(&a, &b).expect("nonsingular");
            for i in 0..n {
                assert!((x[i] - xtrue[i]).abs() < 1e-8, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn detects_singular() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(solve(&a, &[1.0, 1.0]).is_none());
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Mat::from_rows(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        let x = solve(&a, &[3.0, 7.0]).unwrap();
        assert!((x[0] - 7.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }
}
