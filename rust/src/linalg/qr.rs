//! Householder QR with thin-Q recovery, plus the CGS2 block
//! orthonormalizer used on the G-REST hot path.

use crate::linalg::blas;
use crate::linalg::mat::{Mat, Padded};
use crate::linalg::threads::Threads;
use crate::linalg::workspace::StepWorkspace;

/// Thin QR factorization A = Q R with Q (m×n, orthonormal columns) and R
/// (n×n upper-triangular), m >= n, via Householder reflectors.
pub fn thin_qr(a: &Mat) -> (Mat, Mat) {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "thin_qr requires rows >= cols");
    let mut work = a.clone();
    // tau[j] and the reflector stored below the diagonal of `work`.
    let mut tau = vec![0.0; n];
    for j in 0..n {
        // Householder vector for column j, rows j..m.
        let col = work.col(j);
        let alpha = col[j];
        let xnorm = blas::nrm2(&col[j + 1..]);
        if xnorm == 0.0 && alpha >= 0.0 {
            tau[j] = 0.0;
            continue;
        }
        let beta = -(alpha.signum()) * (alpha * alpha + xnorm * xnorm).sqrt();
        let t = (beta - alpha) / beta;
        tau[j] = t;
        let scale = 1.0 / (alpha - beta);
        {
            let colm = work.col_mut(j);
            for v in colm[j + 1..].iter_mut() {
                *v *= scale;
            }
            colm[j] = beta;
        }
        // Apply H = I - tau v vᵀ to the trailing columns, v = [1; work[j+1.., j]].
        for jj in j + 1..n {
            let mut w = work.get(j, jj);
            for i in j + 1..m {
                w += work.get(i, j) * work.get(i, jj);
            }
            w *= tau[j];
            let d = work.get(j, jj) - w;
            work.set(j, jj, d);
            for i in j + 1..m {
                let v = work.get(i, j);
                let cur = work.get(i, jj);
                work.set(i, jj, cur - w * v);
            }
        }
    }
    // Extract R.
    let mut r = Mat::zeros(n, n);
    for j in 0..n {
        for i in 0..=j {
            r.set(i, j, work.get(i, j));
        }
    }
    // Form thin Q by applying the reflectors to the first n identity columns,
    // from the last reflector to the first.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for j in (0..n).rev() {
        if tau[j] == 0.0 {
            continue;
        }
        for jj in 0..n {
            let mut w = q.get(j, jj);
            for i in j + 1..m {
                w += work.get(i, j) * q.get(i, jj);
            }
            w *= tau[j];
            let cur = q.get(j, jj);
            q.set(j, jj, cur - w);
            for i in j + 1..m {
                let v = work.get(i, j);
                let cur = q.get(i, jj);
                q.set(i, jj, cur - w * v);
            }
        }
    }
    (q, r)
}

/// Orthonormalize the columns of `panel` against the orthonormal block `x`
/// and against each other, deflating (numerically) dependent columns.
///
/// Implementation: BCGS2 + rank-guarded CholeskyQR2 — two rounds of
/// (project-out X, Gram, guarded Cholesky, triangular solve).  This is
/// entirely matmul-shaped (unlike column-by-column MGS), which is why the
/// native G-REST phase-1 runs at gemm speed; it also mirrors the lowered
/// jax `build_basis` exactly.  `tol` is the relative pivot threshold of
/// the Cholesky rank guard (norm² scale; 1e-8 ⇒ drop below ~1e-4·‖panel‖).
///
/// Returns (q, kept) where `q` has only the surviving columns and `kept`
/// maps them back to panel column indices.  This is the construction of
/// the paper's Eq. (11).
pub fn orthonormalize_against<'a>(
    x: impl Into<Padded<'a>>,
    panel: &Mat,
    tol: f64,
) -> (Mat, Vec<usize>) {
    orthonormalize_against_with(x, panel, tol, Threads::AUTO)
}

/// [`orthonormalize_against`] with an explicit thread budget.  Accepts
/// the padded X̄ as a borrowed [`Padded`] view (`&Mat` works too); the
/// structurally-zero rows never enter the Gram sweeps.
pub fn orthonormalize_against_with<'a>(
    x: impl Into<Padded<'a>>,
    panel: &Mat,
    tol: f64,
    threads: Threads,
) -> (Mat, Vec<usize>) {
    let mut ws = StepWorkspace::new();
    let mut p = panel.clone();
    let mut kept = Vec::new();
    orthonormalize_against_into(x.into(), &mut p, tol, threads, &mut ws, &mut kept);
    (p, kept)
}

/// The workspace-backed core of [`orthonormalize_against_with`]: the
/// panel is consumed *in place* (on return `p` holds the surviving
/// orthonormal columns, compacted left), every BCGS2 round buffer comes
/// from `ws`, and the surviving panel-column indices land in `kept` —
/// zero heap allocations once `ws` is warm.
///
/// The project-out pass is *fused* into the CholeskyQR round: one sweep
/// (`blas::proj_gram_into`) yields both C = X̄ᵀP and G = PᵀP, the
/// projected Gram is formed algebraically as G − CᵀC (exact for
/// orthonormal X̄), and the panel update applies projection and
/// triangular solve together as P·R⁻¹ − X̄·(C·R⁻¹).  Per round, X̄ and P
/// are each read once in the Gram sweep and once in the update — the
/// separate (I−X̄X̄ᵀ)P materialization of the unfused pipeline is gone.
pub fn orthonormalize_against_into(
    x: Padded<'_>,
    p: &mut Mat,
    tol: f64,
    threads: Threads,
    ws: &mut StepWorkspace,
    kept: &mut Vec<usize>,
) {
    assert_eq!(x.rows(), p.rows());
    kept.clear();
    let m = p.cols();
    if m == 0 {
        return;
    }
    let mut alive = ws.take_flags(m, true);
    let mut keep = ws.take_flags(0, true);
    let mut c = ws.take_mat(0, 0);
    let mut g = ws.take_mat(0, 0);
    let mut ctc = ws.take_mat(0, 0);
    let mut l = ws.take_mat(0, 0);
    let mut rinv = ws.take_mat(0, 0);
    let mut cr = ws.take_mat(0, 0);
    let mut pnew = ws.take_mat(0, 0);
    for _pass in 0..2 {
        blas::proj_gram_into(&mut c, &mut g, x, p, threads);
        // Gram of the projected panel: (P−XC)ᵀ(P−XC) = G − CᵀC
        blas::syrk_tn_into(&mut ctc, &c, &c, threads);
        g.axpy(-1.0, &ctc);
        crate::linalg::chol::cholesky_guarded_into(&g, tol.max(1e-14), &mut l, &mut keep);
        for (a, k) in alive.iter_mut().zip(keep.iter()) {
            *a &= k;
        }
        crate::linalg::chol::tri_inv_upper_from_lower_into(&l, &mut rinv);
        // P ← (P − X·C)·R⁻¹, applied as P·R⁻¹ − X·(C·R⁻¹)
        blas::gemm_into(&mut cr, &c, &rinv, threads);
        blas::gemm_into(&mut pnew, &*p, &rinv, threads);
        blas::gemm_acc_with(&mut pnew, x, &cr, -1.0, threads);
        std::mem::swap(p, &mut pnew);
    }
    ws.give_mat(pnew);
    ws.give_mat(cr);
    ws.give_mat(rinv);
    ws.give_mat(l);
    ws.give_mat(ctc);
    ws.give_mat(g);
    ws.give_mat(c);
    ws.give_flags(keep);
    // survivors have unit norm; dependent columns collapsed to ~0
    for (j, a) in alive.iter().enumerate() {
        let nrm = blas::nrm2(p.col(j));
        if *a && nrm > 0.5 {
            kept.push(j);
            let inv = 1.0 / nrm;
            for e in p.col_mut(j) {
                *e *= inv;
            }
        }
    }
    ws.give_flags(alive);
    p.keep_cols(kept);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    fn check_orthonormal(q: &Mat, tol: f64) {
        let g = q.t_matmul(q);
        for i in 0..g.rows() {
            for j in 0..g.cols() {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g.get(i, j) - want).abs() < tol,
                    "QtQ[{i},{j}]={}",
                    g.get(i, j)
                );
            }
        }
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(1);
        for &(m, n) in &[(1usize, 1usize), (5, 5), (40, 7), (123, 30)] {
            let a = Mat::randn(m, n, &mut rng);
            let (q, r) = thin_qr(&a);
            check_orthonormal(&q, 1e-10);
            let qr = q.matmul(&r);
            let mut diff = qr.clone();
            diff.axpy(-1.0, &a);
            assert!(diff.max_abs() < 1e-10, "({m},{n})");
            // R upper triangular
            for j in 0..n {
                for i in j + 1..n {
                    assert_eq!(r.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn qr_zero_rows_stay_zero() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(30, 5, &mut rng).pad_rows(20);
        let (q, _) = thin_qr(&a);
        for i in 30..50 {
            for j in 0..5 {
                assert!(q.get(i, j).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn orthonormalize_against_basics() {
        let mut rng = Rng::new(3);
        let (x, _) = thin_qr(&Mat::randn(80, 6, &mut rng));
        let panel = Mat::randn(80, 9, &mut rng);
        let (q, kept) = orthonormalize_against(&x, &panel, 1e-10);
        assert_eq!(kept.len(), 9);
        check_orthonormal(&q, 1e-9);
        let cross = x.t_matmul(&q);
        assert!(cross.max_abs() < 1e-9);
    }

    #[test]
    fn orthonormalize_deflates_dependent_columns() {
        let mut rng = Rng::new(4);
        let (x, _) = thin_qr(&Mat::randn(60, 4, &mut rng));
        let good = Mat::randn(60, 3, &mut rng);
        // panel: 3 good, 1 duplicate, 1 zero, 1 inside Ran(x)
        let mut panel = Mat::zeros(60, 6);
        for j in 0..3 {
            panel.set_col(j, good.col(j));
        }
        panel.set_col(3, good.col(0));
        // col 4 stays zero
        panel.set_col(5, x.col(1));
        let (q, kept) = orthonormalize_against(&x, &panel, 1e-8);
        assert_eq!(kept, vec![0, 1, 2]);
        check_orthonormal(&q, 1e-9);
    }

    #[test]
    fn orthonormalize_padded_bitwise_matches_materialized_oracle() {
        // tentpole contract at the BCGS2 level: running over the Padded
        // X̄ view equals running over the pad_rows matrix to the last
        // bit, across shapes (incl. extra == 0 and lane-straddling row
        // counts) and thread counts 1/4.
        let mut rng = Rng::new(8);
        for &(n_old, extra, k, m) in &[
            (50usize, 0usize, 4usize, 6usize),
            (61, 11, 5, 7),
            (2000, 64, 24, 40),
        ] {
            let (x, _) = thin_qr(&Mat::randn(n_old, k, &mut rng));
            let panel = Mat::randn(n_old + extra, m, &mut rng);
            let xm = x.pad_rows(extra);
            for &tc in &[Threads(1), Threads(4)] {
                let (qp, kp) = orthonormalize_against_with(Padded::new(&x, extra), &panel, 1e-8, tc);
                let (qm, km) = orthonormalize_against_with(&xm, &panel, 1e-8, tc);
                assert_eq!(kp, km, "kept mismatch n_old={n_old} extra={extra} t={}", tc.0);
                assert_eq!(
                    qp.as_slice(),
                    qm.as_slice(),
                    "q drifted n_old={n_old} extra={extra} t={}",
                    tc.0
                );
            }
        }
    }

    #[test]
    fn orthonormalize_into_is_reusable_and_matches_wrapper() {
        let mut rng = Rng::new(9);
        let mut ws = StepWorkspace::new();
        let mut kept = Vec::new();
        for trial in 0..3 {
            let (x, _) = thin_qr(&Mat::randn(40 + trial, 4, &mut rng));
            let panel = Mat::randn(40 + trial, 6, &mut rng);
            let (want_q, want_kept) = orthonormalize_against_with(&x, &panel, 1e-8, Threads(1));
            let mut p = panel.clone();
            orthonormalize_against_into(Padded::from(&x), &mut p, 1e-8, Threads(1), &mut ws, &mut kept);
            assert_eq!(kept, want_kept);
            assert_eq!(p.as_slice(), want_q.as_slice());
        }
    }

    #[test]
    fn span_is_preserved() {
        let mut rng = Rng::new(5);
        let (x, _) = thin_qr(&Mat::randn(50, 3, &mut rng));
        let panel = Mat::randn(50, 5, &mut rng);
        let (q, _) = orthonormalize_against(&x, &panel, 1e-10);
        // (I-XXᵀ)panel must lie in Ran(q): residual after projecting onto q is 0
        let p = blas::project_out(&x, &panel);
        let resid = blas::project_out(&q, &p);
        assert!(resid.max_abs() < 1e-9);
    }
}
