//! Lanczos with full reorthogonalization — the crate's stand-in for
//! MATLAB's `eigs` (reference eigenpairs, TIMERS restarts, tracker
//! initialization).

use crate::linalg::blas;
use crate::linalg::eigh::eigh;
use crate::linalg::mat::Mat;
use crate::linalg::rng::Rng;

/// A symmetric linear operator (adjacency, shifted Laplacian, ...).
pub trait LinOp {
    /// Dimension of the (square) operator.
    fn dim(&self) -> usize;
    /// y = A x.
    fn apply(&self, x: &[f64], y: &mut [f64]);
}

/// Top-K eigenpairs of a symmetric operator, ordered by |λ| descending
/// (the paper's convention for adjacency matrices).
///
/// Full reorthogonalization Lanczos: the basis grows until the top-K Ritz
/// residual estimates fall below `tol · |θ₁|` or `max_basis` is reached.
/// Invariant-subspace breakdowns restart with a fresh random direction, so
/// disconnected graphs are handled.
pub fn lanczos_topk(
    op: &dyn LinOp,
    k: usize,
    tol: f64,
    max_basis: usize,
    rng: &mut Rng,
) -> (Vec<f64>, Mat) {
    let n = op.dim();
    let k = k.min(n);
    let max_m = max_basis.min(n).max(k);
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(max_m);
    let mut alphas: Vec<f64> = Vec::with_capacity(max_m);
    let mut betas: Vec<f64> = Vec::with_capacity(max_m); // beta[j] links v_j -> v_{j+1}

    // random normalized start
    let mut v = random_unit(n, rng);
    let mut w = vec![0.0; n];
    let check_every = 8.max(k / 4);

    loop {
        let j = basis.len();
        basis.push(v.clone());
        op.apply(&v, &mut w);
        if j > 0 {
            let beta_prev = betas[j - 1];
            blas::axpy(-beta_prev, &basis[j - 1], &mut w);
        }
        let alpha = blas::dot(&w, &v);
        alphas.push(alpha);
        blas::axpy(-alpha, &v, &mut w);
        // full reorthogonalization (two passes)
        for _ in 0..2 {
            for b in basis.iter() {
                let c = blas::dot(b, &w);
                if c != 0.0 {
                    blas::axpy(-c, b, &mut w);
                }
            }
        }
        let beta = blas::nrm2(&w);
        let m = basis.len();

        let converged_or_full = m >= max_m
            || m >= n
            || ((m >= k + 2) && (m % check_every == 0) && {
                let (vals, _, resid) = ritz_from_tridiag(&alphas, &betas, beta, k);
                let top = vals.iter().fold(0.0f64, |a, &b| a.max(b.abs())).max(1e-300);
                resid.iter().all(|&r| r < tol * top)
            });
        if converged_or_full {
            break;
        }

        if beta < 1e-12 {
            // invariant subspace found: restart with a random direction
            // orthogonal to the current basis.
            let mut r = random_unit(n, rng);
            for _ in 0..2 {
                for b in basis.iter() {
                    let c = blas::dot(b, &r);
                    blas::axpy(-c, b, &mut r);
                }
            }
            let nr = blas::nrm2(&r);
            if nr < 1e-12 {
                break; // full space exhausted
            }
            for e in r.iter_mut() {
                *e /= nr;
            }
            betas.push(0.0);
            v = r;
        } else {
            betas.push(beta);
            v = w.iter().map(|x| x / beta).collect();
        }
    }

    // Final Rayleigh-Ritz on the tridiagonal matrix.
    let m = basis.len();
    let mut t = Mat::zeros(m, m);
    for i in 0..m {
        t.set(i, i, alphas[i]);
        if i + 1 < m {
            t.set(i, i + 1, betas[i]);
            t.set(i + 1, i, betas[i]);
        }
    }
    let e = eigh(&t);
    let order = e.leading_by_magnitude(k.min(m));
    let mut values = Vec::with_capacity(order.len());
    let mut vectors = Mat::zeros(n, order.len());
    for (c, &idx) in order.iter().enumerate() {
        values.push(e.values[idx]);
        let s = e.vectors.col(idx);
        let out = vectors.col_mut(c);
        for (b, &si) in basis.iter().zip(s.iter()) {
            blas::axpy(si, b, out);
        }
    }
    (values, vectors)
}

fn random_unit(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let nrm = blas::nrm2(&v).max(1e-300);
    for e in v.iter_mut() {
        *e /= nrm;
    }
    v
}

/// Ritz values of the current tridiagonal plus residual bounds
/// |β_m s_{m,i}| for the top-k pairs by |θ|.
fn ritz_from_tridiag(
    alphas: &[f64],
    betas: &[f64],
    beta_last: f64,
    k: usize,
) -> (Vec<f64>, Mat, Vec<f64>) {
    let m = alphas.len();
    let mut t = Mat::zeros(m, m);
    for i in 0..m {
        t.set(i, i, alphas[i]);
        if i + 1 < m {
            t.set(i, i + 1, betas[i]);
            t.set(i + 1, i, betas[i]);
        }
    }
    let e = eigh(&t);
    let order = e.leading_by_magnitude(k.min(m));
    let vals: Vec<f64> = order.iter().map(|&i| e.values[i]).collect();
    let resid: Vec<f64> = order
        .iter()
        .map(|&i| (beta_last * e.vectors.get(m - 1, i)).abs())
        .collect();
    (vals, e.vectors, resid)
}

/// Dense symmetric matrix viewed as a LinOp (tests/benches).
pub struct DenseOp<'a>(pub &'a Mat);

impl LinOp for DenseOp<'_> {
    fn dim(&self) -> usize {
        self.0.rows()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let out = blas::gemv(self.0, x);
        y.copy_from_slice(&out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_sym(n: usize, rng: &mut Rng) -> Mat {
        let a = Mat::randn(n, n, rng);
        let mut s = a.clone();
        s.axpy(1.0, &a.t());
        s.scale(0.5);
        s
    }

    #[test]
    fn matches_dense_eigh_topk() {
        let mut rng = Rng::new(42);
        let a = rand_sym(120, &mut rng);
        let (vals, vecs) = lanczos_topk(&DenseOp(&a), 6, 1e-10, 120, &mut rng);
        let dense = eigh(&a);
        let order = dense.leading_by_magnitude(6);
        for i in 0..6 {
            assert!(
                (vals[i] - dense.values[order[i]]).abs() < 1e-7,
                "λ{i}: {} vs {}",
                vals[i],
                dense.values[order[i]]
            );
            let dot = blas::dot(vecs.col(i), dense.vectors.col(order[i])).abs();
            assert!(dot > 1.0 - 1e-6, "vector {i} overlap {dot}");
        }
    }

    #[test]
    fn ordering_is_by_magnitude() {
        let mut rng = Rng::new(1);
        let a = Mat::diag(&[-9.0, 8.0, -7.0, 1.0, 0.5, -0.2, 3.0, 2.0]);
        let (vals, _) = lanczos_topk(&DenseOp(&a), 4, 1e-12, 8, &mut rng);
        let got: Vec<f64> = vals.clone();
        assert_eq!(got.len(), 4);
        assert!((got[0] - -9.0).abs() < 1e-9);
        assert!((got[1] - 8.0).abs() < 1e-9);
        assert!((got[2] - -7.0).abs() < 1e-9);
        assert!((got[3] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn handles_disconnected_blocks() {
        // block-diagonal with two strong blocks -> invariant subspace
        // breakdown path must still find both top eigenvalues.
        let mut a = Mat::zeros(40, 40);
        for i in 0..20 {
            for j in 0..20 {
                if i != j {
                    a.set(i, j, 1.0);
                    a.set(20 + i, 20 + j, 0.5);
                }
            }
        }
        let mut rng = Rng::new(2);
        let (vals, _) = lanczos_topk(&DenseOp(&a), 2, 1e-10, 40, &mut rng);
        assert!((vals[0] - 19.0).abs() < 1e-6);
        assert!((vals[1] - 9.5).abs() < 1e-6);
    }

    #[test]
    fn k_larger_than_matrix_is_clamped() {
        let a = Mat::diag(&[3.0, 1.0]);
        let mut rng = Rng::new(3);
        let (vals, vecs) = lanczos_topk(&DenseOp(&a), 10, 1e-10, 50, &mut rng);
        assert_eq!(vals.len(), 2);
        assert_eq!(vecs.cols(), 2);
    }

    #[test]
    fn orthonormal_output_vectors() {
        let mut rng = Rng::new(4);
        let a = rand_sym(60, &mut rng);
        let (_, vecs) = lanczos_topk(&DenseOp(&a), 8, 1e-10, 60, &mut rng);
        let g = vecs.t_matmul(&vecs);
        let mut eye = Mat::eye(8);
        eye.axpy(-1.0, &g);
        assert!(eye.max_abs() < 1e-7);
    }
}
