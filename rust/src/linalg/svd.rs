//! Thin SVD via one-sided Jacobi (Hestenes) rotations — accurate for the
//! tall-skinny panels this codebase produces (N×m, m ≤ a few hundred).

use crate::linalg::blas;
use crate::linalg::mat::Mat;

/// Thin singular value decomposition A = U Σ Vᵀ for A (m×n, m ≥ n).
pub struct SvdResult {
    /// Left singular vectors (m×n), orthonormal columns (zero columns for
    /// zero singular values).
    pub u: Mat,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// Right singular vectors (n×n).
    pub v: Mat,
}

/// One-sided Jacobi SVD.  Rotates column pairs of a working copy of `a`
/// until all pairs are numerically orthogonal; the column norms are the
/// singular values.
pub fn thin_svd(a: &Mat) -> SvdResult {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "thin_svd requires rows >= cols");
    let mut u = a.clone();
    let mut v = Mat::eye(n);
    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                let (alpha, beta, gamma);
                {
                    let up = u.col(p);
                    let uq = u.col(q);
                    alpha = blas::dot(up, up);
                    beta = blas::dot(uq, uq);
                    gamma = blas::dot(up, uq);
                }
                if gamma.abs() <= eps * (alpha * beta).sqrt() || gamma == 0.0 {
                    continue;
                }
                off = off.max(gamma.abs() / (alpha * beta).sqrt().max(1e-300));
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                {
                    let (up, uq) = u.two_cols_mut(p, q);
                    for i in 0..m {
                        let a0 = up[i];
                        let b0 = uq[i];
                        up[i] = c * a0 - s * b0;
                        uq[i] = s * a0 + c * b0;
                    }
                }
                {
                    let (vp, vq) = v.two_cols_mut(p, q);
                    for i in 0..n {
                        let a0 = vp[i];
                        let b0 = vq[i];
                        vp[i] = c * a0 - s * b0;
                        vq[i] = s * a0 + c * b0;
                    }
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }
    // Extract singular values = column norms; normalize U columns.
    let mut s: Vec<f64> = (0..n).map(|j| blas::nrm2(u.col(j))).collect();
    for j in 0..n {
        if s[j] > 1e-300 {
            let inv = 1.0 / s[j];
            for e in u.col_mut(j) {
                *e *= inv;
            }
        } else {
            s[j] = 0.0;
            for e in u.col_mut(j) {
                *e = 0.0;
            }
        }
    }
    // Sort descending.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&x, &y| s[y].partial_cmp(&s[x]).unwrap());
    let s_sorted: Vec<f64> = idx.iter().map(|&i| s[i]).collect();
    SvdResult {
        u: u.select_cols(&idx),
        s: s_sorted,
        v: v.select_cols(&idx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    fn check(a: &Mat, r: &SvdResult, tol: f64) {
        // A = U diag(s) Vᵀ
        let us = Mat::from_fn(r.u.rows(), r.s.len(), |i, j| r.u.get(i, j) * r.s[j]);
        let rec = us.matmul(&r.v.t());
        let mut diff = rec;
        diff.axpy(-1.0, a);
        assert!(diff.max_abs() < tol, "reconstruction {}", diff.max_abs());
        // descending
        for i in 1..r.s.len() {
            assert!(r.s[i] <= r.s[i - 1] + 1e-12);
        }
        // V orthonormal
        let g = r.v.t_matmul(&r.v);
        let mut eye = Mat::eye(g.rows());
        eye.axpy(-1.0, &g);
        assert!(eye.max_abs() < tol);
    }

    #[test]
    fn random_tall() {
        let mut rng = Rng::new(1);
        for &(m, n) in &[(1usize, 1usize), (8, 3), (50, 10), (120, 40)] {
            let a = Mat::randn(m, n, &mut rng);
            let r = thin_svd(&a);
            check(&a, &r, 1e-9);
        }
    }

    #[test]
    fn rank_deficient() {
        let mut rng = Rng::new(2);
        let b = Mat::randn(40, 3, &mut rng);
        let c = Mat::randn(3, 8, &mut rng);
        let a = b.matmul(&c); // rank 3 of 8 columns
        let r = thin_svd(&a);
        check(&a, &r, 1e-8);
        for i in 3..8 {
            assert!(r.s[i] < 1e-8, "s[{i}]={}", r.s[i]);
        }
        // surviving U columns orthonormal
        let u3 = r.u.top_left(40, 3);
        let g = u3.t_matmul(&u3);
        let mut eye = Mat::eye(3);
        eye.axpy(-1.0, &g);
        assert!(eye.max_abs() < 1e-8);
    }

    #[test]
    fn singular_values_match_eigh_of_gram() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(30, 6, &mut rng);
        let r = thin_svd(&a);
        let g = a.t_matmul(&a);
        let e = crate::linalg::eigh::eigh(&g);
        let mut lam: Vec<f64> = e.values.iter().map(|v| v.max(0.0).sqrt()).collect();
        lam.reverse();
        for (sv, ev) in r.s.iter().zip(lam.iter()) {
            assert!((sv - ev).abs() < 1e-8);
        }
    }
}
