//! Deterministic pseudo-random numbers: xoshiro256++ plus Gaussian
//! sampling.  Hand-rolled because the build is offline (no `rand` crate);
//! xoshiro256++ passes BigCrush and is the same generator family used by
//! `rand_xoshiro`.

/// xoshiro256++ PRNG with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller deviate
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiplication-shift method; bias negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal deviate via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn flip(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), unordered.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xDEADBEEFCAFEF00D)
    }

    /// Full generator state (xoshiro words + the cached Box–Muller
    /// spare), for checkpointing.  [`Rng::from_state`] round-trips it
    /// bitwise, so a restored generator emits the identical stream.
    pub fn state_words(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator from [`Rng::state_words`] output.
    pub fn from_state(s: [u64; 4], spare: Option<f64>) -> Rng {
        Rng { s, spare }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(10usize, 10usize), (100, 5), (50, 40)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
