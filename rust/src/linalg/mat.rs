//! Column-major dense matrix.
//!
//! Column-major is chosen so that eigenvector panels (N×K with K≈64–192)
//! expose each eigenvector as one contiguous slice — the access pattern of
//! every tracker and of the PJRT marshalling code.

use crate::linalg::rng::Rng;

/// Dense column-major matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Borrowed view of a matrix with `extra_rows` structurally-zero rows
/// appended — the padded eigenvector panel X̄_K = [X_K; 0] of paper
/// Eq. (3), without the n×k heap copy `pad_rows` pays.
///
/// The padded rows are never stored: kernels that consume a `Padded`
/// operand read only the top [`Padded::filled`] rows and treat the rest
/// as exact 0.0.  Because a 0.0 contribution is exact in IEEE arithmetic
/// and the kernels keep their reduction orders unchanged, results are
/// bitwise identical to running the same kernel on
/// `mat.pad_rows(extra_rows)` (the property-test oracle) — for finite
/// data; the views skip the `0·∞ = NaN` poisoning a materialized zero
/// row would propagate from non-finite inputs.
///
/// `Padded::from(&m)` (or passing `&Mat` to any kernel generic over
/// `impl Into<Padded>`) is the degenerate `extra_rows == 0` view.
#[derive(Clone, Copy)]
pub struct Padded<'a> {
    /// The stored top block (the filled rows).
    pub mat: &'a Mat,
    /// Number of structurally-zero rows appended below `mat`.
    pub extra_rows: usize,
}

impl<'a> From<&'a Mat> for Padded<'a> {
    fn from(mat: &'a Mat) -> Padded<'a> {
        Padded { mat, extra_rows: 0 }
    }
}

impl<'a> Padded<'a> {
    pub fn new(mat: &'a Mat, extra_rows: usize) -> Padded<'a> {
        Padded { mat, extra_rows }
    }

    /// Logical row count (stored + structural zeros).
    #[inline]
    pub fn rows(&self) -> usize {
        self.mat.rows() + self.extra_rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.mat.cols()
    }

    /// Number of rows actually stored (the top block).
    #[inline]
    pub fn filled(&self) -> usize {
        self.mat.rows()
    }

    /// Stored part of column `j` (length [`Padded::filled`]); the
    /// remaining [`Padded::rows`] − filled entries are exact zeros.
    #[inline]
    pub fn col_top(&self, j: usize) -> &[f64] {
        self.mat.col(j)
    }

    /// Entry (i, j) of the logical padded matrix.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        if i < self.mat.rows() {
            self.mat.get(i, j)
        } else {
            debug_assert!(i < self.rows());
            0.0
        }
    }

    /// Materialize the logical matrix (the `pad_rows` oracle).
    pub fn materialize(&self) -> Mat {
        self.mat.pad_rows(self.extra_rows)
    }
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(6);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>11.4e} ", self.get(i, j))?;
            }
            writeln!(f, "{}", if self.cols > cmax { "..." } else { "" })?;
        }
        if self.rows > rmax {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    /// Build from row-major data.
    pub fn from_rows(rows: usize, cols: usize, row_major: &[f64]) -> Mat {
        assert_eq!(row_major.len(), rows * cols);
        Mat::from_fn(rows, cols, |i, j| row_major[i * cols + j])
    }

    /// Column-major constructor taking ownership of the buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// i.i.d. standard normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Mat {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m.set(i, i, v);
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.rows + i] += v;
    }

    /// Column `j` as a contiguous slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Two distinct mutable columns (for Jacobi rotations).
    pub fn two_cols_mut(&mut self, a: usize, b: usize) -> (&mut [f64], &mut [f64]) {
        assert!(a != b);
        let r = self.rows;
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let (left, right) = self.data.split_at_mut(hi * r);
        let sa = &mut left[lo * r..(lo + 1) * r];
        let sb = &mut right[..r];
        if a < b {
            (sa, sb)
        } else {
            (sb, sa)
        }
    }

    /// Entire backing buffer (column-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Take the backing buffer (for workspace recycling).
    #[inline]
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Reshape to rows×cols with every entry zero, reusing the backing
    /// buffer — grow-only: allocates only when capacity is too small.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
        self.rows = rows;
        self.cols = cols;
    }

    /// Become a copy of `other` (shape and contents), reusing the
    /// backing buffer.
    pub fn copy_from(&mut self, other: &Mat) {
        self.data.clear();
        self.data.extend_from_slice(&other.data);
        self.rows = other.rows;
        self.cols = other.cols;
    }

    /// Swap columns `a` and `b` in place.
    pub fn swap_cols(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        let (ca, cb) = self.two_cols_mut(a, b);
        ca.swap_with_slice(cb);
    }

    /// Keep only columns `idx` (strictly ascending), compacting them to
    /// the left in place — the allocation-free [`Mat::select_cols`].
    pub fn keep_cols(&mut self, idx: &[usize]) {
        let r = self.rows;
        for (dst, &src) in idx.iter().enumerate() {
            debug_assert!(src >= dst && src < self.cols, "keep_cols needs ascending indices");
            if dst != src {
                self.data.copy_within(src * r..(src + 1) * r, dst * r);
            }
        }
        self.cols = idx.len();
        self.data.truncate(r * idx.len());
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy column `src` of `other` into column `dst` of `self`.
    pub fn set_col(&mut self, dst: usize, src: &[f64]) {
        assert_eq!(src.len(), self.rows);
        self.col_mut(dst).copy_from_slice(src);
    }

    /// Transposed copy.
    pub fn t(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// Horizontal concatenation [self, other].
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut m = Mat::zeros(self.rows, self.cols + other.cols);
        m.data[..self.data.len()].copy_from_slice(&self.data);
        m.data[self.data.len()..].copy_from_slice(&other.data);
        m
    }

    /// Sub-matrix of the first `r` rows and `c` columns.
    pub fn top_left(&self, r: usize, c: usize) -> Mat {
        assert!(r <= self.rows && c <= self.cols);
        Mat::from_fn(r, c, |i, j| self.get(i, j))
    }

    /// Copy with `extra` zero rows appended (the padding X̄ of Eq. 3).
    pub fn pad_rows(&self, extra: usize) -> Mat {
        let mut m = Mat::zeros(self.rows + extra, self.cols);
        for j in 0..self.cols {
            m.col_mut(j)[..self.rows].copy_from_slice(self.col(j));
        }
        m
    }

    /// Keep a subset of columns (in the given order).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut m = Mat::zeros(self.rows, idx.len());
        for (dst, &src) in idx.iter().enumerate() {
            m.set_col(dst, self.col(src));
        }
        m
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |a, &v| a.max(v.abs()))
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// Scale all entries.
    pub fn scale(&mut self, alpha: f64) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    /// Matrix product via the gemm kernel ladder (see `blas` docs).
    pub fn matmul(&self, other: &Mat) -> Mat {
        crate::linalg::blas::gemm(self, other)
    }

    /// [`Mat::matmul`] with an explicit thread budget.
    pub fn matmul_with(&self, other: &Mat, threads: crate::linalg::threads::Threads) -> Mat {
        crate::linalg::blas::gemm_with(self, other, threads)
    }

    /// selfᵀ · other without materializing the transpose.  (The former
    /// `t_matmul_with`/`sym_t_matmul{,_with}` conveniences are gone —
    /// the dense phases call `blas::{gemm_tn,syrk_tn}_into` directly.)
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        crate::linalg::blas::gemm_tn(self, other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_get_set() {
        let mut m = Mat::zeros(3, 2);
        m.set(2, 1, 5.0);
        assert_eq!(m.get(2, 1), 5.0);
        assert_eq!(m.col(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn from_rows_layout() {
        let m = Mat::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 2), 3.0);
        assert_eq!(m.get(1, 1), 5.0);
    }

    #[test]
    fn transpose() {
        let m = Mat::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let t = m.t();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.get(2, 1), 6.0);
    }

    #[test]
    fn pad_rows_appends_zeros() {
        let m = Mat::from_rows(2, 2, &[1., 2., 3., 4.]);
        let p = m.pad_rows(3);
        assert_eq!(p.rows(), 5);
        assert_eq!(p.get(1, 1), 4.0);
        assert_eq!(p.get(4, 0), 0.0);
    }

    #[test]
    fn hcat_and_select() {
        let a = Mat::from_rows(2, 1, &[1., 2.]);
        let b = Mat::from_rows(2, 2, &[3., 4., 5., 6.]);
        let c = a.hcat(&b);
        assert_eq!(c.cols(), 3);
        assert_eq!(c.get(1, 2), 6.0);
        let s = c.select_cols(&[2, 0]);
        assert_eq!(s.get(0, 0), 4.0);
        assert_eq!(s.get(0, 1), 1.0);
    }

    #[test]
    fn two_cols_mut_disjoint() {
        let mut m = Mat::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        {
            let (a, b) = m.two_cols_mut(2, 0);
            a[0] = 30.0;
            b[1] = 40.0;
        }
        assert_eq!(m.get(0, 2), 30.0);
        assert_eq!(m.get(1, 0), 40.0);
    }

    #[test]
    fn fro_norm() {
        let m = Mat::from_rows(2, 2, &[3., 0., 0., 4.]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn padded_view_matches_materialized() {
        let m = Mat::from_rows(2, 2, &[1., 2., 3., 4.]);
        let v = Padded::new(&m, 3);
        assert_eq!(v.rows(), 5);
        assert_eq!(v.cols(), 2);
        assert_eq!(v.filled(), 2);
        assert_eq!(v.col_top(1), &[2.0, 4.0]);
        let oracle = m.pad_rows(3);
        for i in 0..5 {
            for j in 0..2 {
                assert_eq!(v.get(i, j), oracle.get(i, j));
            }
        }
        assert_eq!(v.materialize().as_slice(), oracle.as_slice());
        let zero_extra = Padded::from(&m);
        assert_eq!(zero_extra.rows(), 2);
        assert_eq!(zero_extra.materialize().as_slice(), m.as_slice());
    }

    #[test]
    fn reset_and_copy_from_reuse_buffers() {
        let mut m = Mat::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        m.reset(3, 2);
        assert_eq!((m.rows(), m.cols()), (3, 2));
        assert!(m.as_slice().iter().all(|&v| v == 0.0));
        let src = Mat::from_rows(2, 2, &[7., 8., 9., 10.]);
        m.copy_from(&src);
        assert_eq!(m.as_slice(), src.as_slice());
        assert_eq!((m.rows(), m.cols()), (2, 2));
    }

    #[test]
    fn keep_and_swap_cols_in_place() {
        let mut m = Mat::from_rows(2, 4, &[1., 2., 3., 4., 5., 6., 7., 8.]);
        let want = m.select_cols(&[1, 3]);
        m.keep_cols(&[1, 3]);
        assert_eq!(m.as_slice(), want.as_slice());
        let mut s = Mat::from_rows(2, 2, &[1., 2., 3., 4.]);
        s.swap_cols(0, 1);
        assert_eq!(s.get(0, 0), 2.0);
        assert_eq!(s.get(1, 1), 3.0);
        s.swap_cols(1, 1); // no-op
        assert_eq!(s.get(0, 1), 1.0);
    }
}
