//! The packed GEMM micro-kernel — the fourth rung of the dispatch
//! ladder (`naive → blocked → blocked+pool → packed → packed+simd →
//! packed+fma`), and the shared driver the SIMD rungs plug into (see
//! [`MicroKernel`] and `linalg::gemm_simd`).
//!
//! BLIS-style structure: per (column-tile, k-block) the alpha-scaled B
//! weights are packed into k-major quads, per (k-block, row-block) the
//! A panel is packed into `MR`-row panels, and an `MR`×`NR` (8×4)
//! register tile accumulates over the whole k-block with C loaded into
//! registers once per block instead of streamed through memory on
//! every k — the blocked kernel's 2 C-accesses per multiply drop to
//! ~2/`BLOCK_K`.
//!
//! ## Bitwise contract (the blocked kernel is the oracle)
//!
//! For every output element, this kernel performs *exactly* the same
//! ordered sequence of individually-rounded `c = c + (w · a)` updates
//! as [`gemm_acc_cols`](crate::linalg::blas): k-blocks ascending, k
//! ascending within a block, the same quad grouping (`NR` columns from
//! the tile base), the same all-four-weights-zero skip per (quad, k),
//! and the same scalar tail for leftover columns.  Packing only copies
//! values; register accumulation only changes *where* the running sum
//! lives between updates, not the update sequence — so results are
//! bitwise identical (property-tested across tile-straddling shapes,
//! `k ∈ {0, 1}`, and [`Padded`] views).  No FMA: Rust never contracts
//! separate `*`/`+` float ops.
//!
//! Pack buffers live in a grow-only thread-local [`PackScratch`]
//! (taken/replaced around each call, so an unexpectedly nested kernel
//! falls back to a fresh scratch instead of aborting on a RefCell
//! double-borrow).  A per-`&mut StepWorkspace` home was considered and
//! rejected: chunks of one invocation run concurrently on pool workers
//! and cannot share the caller's workspace; thread-locals give each
//! executor its own reusable buffers with zero steady-state
//! allocations after warm-up (the counting-allocator bench holds).

use crate::linalg::mat::{Mat, Padded};
use std::cell::RefCell;

/// Micro-kernel register-tile height (rows of A/C per panel).
pub(crate) const MR: usize = 8;
/// Micro-kernel register-tile width — must equal the blocked kernel's
/// quad width, or the skip decisions would diverge.
pub(crate) const NR: usize = 4;
/// Row-block height: the packed A block is `MC`×`BLOCK_K` ≈ 64 KiB,
/// sized to sit in L2 while the register tile sweeps it `nq` times.
const MC: usize = 128;
/// Cache block along the shared (k) dimension — must match the blocked
/// kernel's `BLOCK_K` (the per-element k-grouping is part of the
/// bitwise contract).
const BLOCK_K: usize = 64;
/// Column tile — must match the blocked kernel's `BLOCK_J` (quad
/// boundaries are `NR`-strides from the tile base).
const BLOCK_J: usize = 64;

/// Grow-only pack buffers, one set per executor thread.
#[derive(Default)]
struct PackScratch {
    /// A panels: `MR`-row panels, k-major within a panel.
    apack: Vec<f64>,
    /// Alpha-scaled B quads: `NR` weights per k, k-major per quad.
    wpack: Vec<f64>,
    /// 1 where a (quad, k) has all `NR` weights exactly 0.0 — the
    /// blocked kernel's skip predicate, precomputed.
    skip: Vec<u8>,
}

thread_local! {
    static PACK: RefCell<PackScratch> = RefCell::new(PackScratch::default());
}

/// Should `gemm_acc` route a chunk of this shape through the packed
/// kernel?  Purely a performance heuristic — both kernels produce
/// bitwise-identical output — requiring enough rows to fill register
/// panels, enough k for the C-in-registers reuse to amortize packing,
/// and at least one full quad of columns.
pub(crate) fn profitable(mt: usize, kk: usize, ncols: usize) -> bool {
    mt >= 4 * MR && kk >= 16 && ncols >= NR
}

/// The register-tile contract shared by every packed rung: accumulate
/// one `MR`×`NR` tile (`c0..c3` at rows `ip..ip+MR`) over a packed A
/// panel `ap` and weight quad `wq` for `kb` k-steps, honoring `skip`.
///
/// The scalar implementation below is the reference; the AVX2/FMA
/// implementations live in `linalg::gemm_simd` and are injected into
/// [`gemm_acc_cols_with_micro`] as plain `fn` pointers — packing, tile
/// walk, row remainder, and column tail are shared verbatim, so the
/// bitwise-equality argument for a SIMD rung reduces to its micro-kernel
/// keeping the per-element `c += w·a` sequence.
#[allow(clippy::type_complexity)]
pub(crate) type MicroKernel =
    fn(&mut [f64], &mut [f64], &mut [f64], &mut [f64], usize, &[f64], &[f64], &[u8], usize);

/// Packed twin of [`gemm_acc_cols`](crate::linalg::blas): compute
/// columns `jr` of C += alpha·A·B into `c_cols` (contiguous
/// column-major storage of those columns, stride `m`), touching only
/// the top `a.filled()` rows.  Bitwise identical to the blocked kernel
/// (see module docs).
pub(crate) fn gemm_acc_cols_packed(
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: Padded<'_>,
    b: &Mat,
    alpha: f64,
) {
    gemm_acc_cols_with_micro(c_cols, m, jr, a, b, alpha, microkernel);
}

/// The packed driver with an injected register-tile micro-kernel (see
/// [`MicroKernel`]).  Everything outside the `MR`×`NR` tile — packing,
/// the blocked tile walk, the row remainder, and the scalar column tail
/// — is this one code path for every packed rung.
pub(crate) fn gemm_acc_cols_with_micro(
    c_cols: &mut [f64],
    m: usize,
    jr: std::ops::Range<usize>,
    a: Padded<'_>,
    b: &Mat,
    alpha: f64,
    micro: MicroKernel,
) {
    let kk = a.cols();
    let mt = a.filled();
    let j0 = jr.start;
    let n = jr.end;
    if j0 >= n || kk == 0 || mt == 0 {
        return;
    }
    // take/replace: a nested call on this thread sees a fresh default
    // (allocates once, still correct) instead of a RefCell panic
    let mut s = PACK.with(|p| p.take());
    let mut jt = j0;
    while jt < n {
        let jt_end = (jt + BLOCK_J).min(n);
        let nq = (jt_end - jt) / NR;
        for k0 in (0..kk).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(kk);
            let kb = k1 - k0;
            pack_weights(&mut s, b, alpha, jt, nq, k0, k1);
            for i0 in (0..mt).step_by(MC) {
                let i1 = (i0 + MC).min(mt);
                let n_panels = (i1 - i0) / MR;
                pack_a_panels(&mut s, a, i0, n_panels, k0, k1);
                let rem_lo = i0 + n_panels * MR;
                for q in 0..nq {
                    let j = jt + q * NR;
                    let base = (j - j0) * m;
                    let (c0, rest) = c_cols[base..].split_at_mut(m);
                    let (c1, rest) = rest.split_at_mut(m);
                    let (c2, c3s) = rest.split_at_mut(m);
                    let c3 = &mut c3s[..m];
                    let wq = &s.wpack[q * kb * NR..(q + 1) * kb * NR];
                    let sq = &s.skip[q * kb..(q + 1) * kb];
                    for p in 0..n_panels {
                        let ip = i0 + p * MR;
                        let ap = &s.apack[p * MR * kb..(p + 1) * MR * kb];
                        micro(c0, c1, c2, c3, ip, ap, wq, sq, kb);
                    }
                    // row remainder of this i-block: the blocked
                    // kernel's quad loop verbatim, restricted to the
                    // leftover rows (same per-element k order)
                    if rem_lo < i1 {
                        for kidx in 0..kb {
                            if sq[kidx] != 0 {
                                continue;
                            }
                            let w = &wq[kidx * NR..kidx * NR + NR];
                            let ak = a.col_top(k0 + kidx);
                            for i in rem_lo..i1 {
                                let av = ak[i];
                                c0[i] += w[0] * av;
                                c1[i] += w[1] * av;
                                c2[i] += w[2] * av;
                                c3[i] += w[3] * av;
                            }
                        }
                    }
                }
                // column tail (tile width % NR): identical to the
                // blocked kernel's scalar tail, restricted to this
                // i-block's rows
                for j in (jt + nq * NR)..jt_end {
                    let bj = b.col(j);
                    let cj = &mut c_cols[(j - j0) * m..(j - j0 + 1) * m];
                    for k in k0..k1 {
                        let w = alpha * bj[k];
                        if w == 0.0 {
                            continue;
                        }
                        let ak = a.col_top(k);
                        for i in i0..i1 {
                            cj[i] += w * ak[i];
                        }
                    }
                }
            }
        }
        jt = jt_end;
    }
    PACK.with(|p| p.replace(s));
}

/// Pack the alpha-scaled weights of the tile's full quads (k-major per
/// quad) and precompute the blocked kernel's all-zero skip predicate.
fn pack_weights(
    s: &mut PackScratch,
    b: &Mat,
    alpha: f64,
    jt: usize,
    nq: usize,
    k0: usize,
    k1: usize,
) {
    let kb = k1 - k0;
    s.wpack.clear();
    s.wpack.resize(nq * kb * NR, 0.0);
    s.skip.clear();
    s.skip.resize(nq * kb, 0);
    for q in 0..nq {
        let j = jt + q * NR;
        let (b0, b1, b2, b3) = (b.col(j), b.col(j + 1), b.col(j + 2), b.col(j + 3));
        for (kidx, k) in (k0..k1).enumerate() {
            // the same four products the blocked kernel forms per k
            let w0 = alpha * b0[k];
            let w1 = alpha * b1[k];
            let w2 = alpha * b2[k];
            let w3 = alpha * b3[k];
            let o = (q * kb + kidx) * NR;
            s.wpack[o] = w0;
            s.wpack[o + 1] = w1;
            s.wpack[o + 2] = w2;
            s.wpack[o + 3] = w3;
            s.skip[q * kb + kidx] = u8::from(w0 == 0.0 && w1 == 0.0 && w2 == 0.0 && w3 == 0.0);
        }
    }
}

/// Pack the full `MR`-row panels of A rows `i0..i0 + n_panels·MR` for
/// k-block `k0..k1`: panel-major, k-major within a panel, `MR`
/// contiguous rows per k.  Pure copies — values are exact.
fn pack_a_panels(
    s: &mut PackScratch,
    a: Padded<'_>,
    i0: usize,
    n_panels: usize,
    k0: usize,
    k1: usize,
) {
    let kb = k1 - k0;
    s.apack.clear();
    s.apack.resize(n_panels * MR * kb, 0.0);
    for (kidx, k) in (k0..k1).enumerate() {
        let ak = &a.col_top(k)[i0..i0 + n_panels * MR];
        for p in 0..n_panels {
            let dst = p * MR * kb + kidx * MR;
            s.apack[dst..dst + MR].copy_from_slice(&ak[p * MR..(p + 1) * MR]);
        }
    }
}

/// The 8×4 register tile: load C once, accumulate ascending k across
/// the whole k-block (one rounded multiply + one rounded add per
/// update, exactly the blocked kernel's per-element op sequence),
/// store once.
#[allow(clippy::too_many_arguments)]
#[inline]
fn microkernel(
    c0: &mut [f64],
    c1: &mut [f64],
    c2: &mut [f64],
    c3: &mut [f64],
    ip: usize,
    ap: &[f64],
    wq: &[f64],
    skip: &[u8],
    kb: usize,
) {
    let mut r0 = [0.0f64; MR];
    let mut r1 = [0.0f64; MR];
    let mut r2 = [0.0f64; MR];
    let mut r3 = [0.0f64; MR];
    r0.copy_from_slice(&c0[ip..ip + MR]);
    r1.copy_from_slice(&c1[ip..ip + MR]);
    r2.copy_from_slice(&c2[ip..ip + MR]);
    r3.copy_from_slice(&c3[ip..ip + MR]);
    for kidx in 0..kb {
        if skip[kidx] != 0 {
            continue;
        }
        let a8 = &ap[kidx * MR..(kidx + 1) * MR];
        let w = &wq[kidx * NR..kidx * NR + NR];
        let (w0, w1, w2, w3) = (w[0], w[1], w[2], w[3]);
        for t in 0..MR {
            let av = a8[t];
            r0[t] += w0 * av;
            r1[t] += w1 * av;
            r2[t] += w2 * av;
            r3[t] += w3 * av;
        }
    }
    c0[ip..ip + MR].copy_from_slice(&r0);
    c1[ip..ip + MR].copy_from_slice(&r1);
    c2[ip..ip + MR].copy_from_slice(&r2);
    c3[ip..ip + MR].copy_from_slice(&r3);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::gemm_acc_cols_blocked;
    use crate::linalg::rng::Rng;

    /// Random matrix with exact zeros sprinkled in, to exercise the
    /// skip predicate (including whole all-zero quads).
    fn randn_sparse(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::randn(rows, cols, rng);
        for j in 0..cols {
            for i in 0..rows {
                if rng.below(10) < 3 {
                    m.set(i, j, 0.0);
                }
            }
            if cols >= 4 && j % 7 == 3 {
                // zero a full column: quads with all-zero k rows appear
                for i in 0..rows {
                    m.set(i, j, 0.0);
                }
            }
        }
        m
    }

    #[test]
    fn packed_is_bitwise_identical_to_blocked_across_tile_straddles() {
        let mut rng = Rng::new(42);
        // shapes straddling every MR/NR/BLOCK boundary, plus k ∈ {0, 1}
        // and sub-tile heights/widths
        let shapes: &[(usize, usize, usize, usize)] = &[
            // (filled_rows, extra_rows, k, ncols)
            (1, 0, 1, 1),
            (7, 0, 1, 3),
            (8, 0, 16, 4),
            (9, 5, 17, 5),
            (16, 0, 64, 8),
            (23, 9, 65, 13),
            (31, 1, 63, 64),
            (128, 0, 64, 65),
            (129, 7, 129, 67),
            (200, 48, 32, 32),
            (5, 0, 0, 6),
            (64, 0, 1, 130),
            (257, 3, 100, 20),
        ];
        for &(mt, extra, kk, ncols) in shapes {
            let x = Mat::randn(mt, kk, &mut rng);
            let bm = randn_sparse(kk, ncols, &mut rng);
            let a = Padded::new(&x, extra);
            let m = mt + extra;
            for &alpha in &[1.0, -1.0, 0.0, 0.37] {
                let seed = Mat::randn(m, ncols, &mut rng);
                let mut c_blocked = seed.clone();
                let mut c_packed = seed.clone();
                gemm_acc_cols_blocked(c_blocked.as_mut_slice(), m, 0..ncols, a, &bm, alpha);
                gemm_acc_cols_packed(c_packed.as_mut_slice(), m, 0..ncols, a, &bm, alpha);
                assert_eq!(
                    c_blocked.as_slice(),
                    c_packed.as_slice(),
                    "packed drifted from blocked oracle at mt={mt} extra={extra} k={kk} n={ncols} alpha={alpha}"
                );
            }
        }
    }

    #[test]
    fn packed_matches_blocked_on_nonzero_column_offsets() {
        // chunked invocation: the pool hands each chunk a j-range with
        // j0 > 0; tile bases are chunk-relative, exactly as blocked
        let mut rng = Rng::new(43);
        let mt = 70;
        let kk = 40;
        let ncols = 90;
        let x = Mat::randn(mt, kk, &mut rng);
        let bm = randn_sparse(kk, ncols, &mut rng);
        let a = Padded::new(&x, 2);
        let m = mt + 2;
        for &(lo, hi) in &[(0usize, 37usize), (37, 70), (70, 90), (5, 9), (88, 90)] {
            let seed = Mat::randn(m, hi - lo, &mut rng);
            let mut cb = seed.clone();
            let mut cp = seed.clone();
            gemm_acc_cols_blocked(cb.as_mut_slice(), m, lo..hi, a, &bm, -0.5);
            gemm_acc_cols_packed(cp.as_mut_slice(), m, lo..hi, a, &bm, -0.5);
            assert_eq!(cb.as_slice(), cp.as_slice(), "chunk {lo}..{hi} drifted");
        }
    }

    #[test]
    fn profitability_gate_covers_the_paper_regime() {
        // the small-k G-REST shapes must take the packed rung...
        assert!(profitable(2000, 32, 32));
        assert!(profitable(8000, 96, 96));
        // ...while sub-panel shapes stay on the blocked kernel
        assert!(!profitable(16, 64, 64));
        assert!(!profitable(2000, 8, 32));
        assert!(!profitable(2000, 32, 3));
    }
}
