//! The `Threads` knob: one explicit worker-thread budget threaded through
//! the dense kernel layer, `DensePhases`, the experiment harness, and the
//! CLI (`--threads`).
//!
//! Every parallel kernel partitions *output columns* across workers, so
//! each output element is produced by exactly one thread with the same
//! sequential reduction order regardless of the worker count — results
//! are bitwise identical for `Threads(1)` and `Threads(n)`.

/// Worker-thread budget for the dense kernels.
///
/// * `Threads(0)` (= [`Threads::AUTO`]) resolves to the machine's
///   available parallelism, capped at [`MAX_AUTO_THREADS`].
/// * `Threads(1)` forces the sequential path.
/// * `Threads(n)` uses at most `n` workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Threads(pub usize);

/// Cap on auto-detected parallelism (the kernels are memory-bound well
/// before this point on typical hardware).
pub const MAX_AUTO_THREADS: usize = 16;

/// Minimum flop count of a kernel invocation before it fans out across
/// threads; below this the spawn overhead dominates.
pub const PAR_MIN_FLOPS: usize = 1 << 22;

impl Threads {
    /// Resolve the worker count from the machine.
    pub const AUTO: Threads = Threads(0);
    /// Always sequential.
    pub const SINGLE: Threads = Threads(1);

    /// Concrete worker count this budget resolves to.
    pub fn resolve(self) -> usize {
        if self.0 != 0 {
            return self.0;
        }
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(MAX_AUTO_THREADS)
    }

    /// Worker count for a kernel performing `flops` floating-point ops:
    /// 1 below the parallel threshold, the resolved budget above it.
    pub fn for_flops(self, flops: usize) -> usize {
        if flops < PAR_MIN_FLOPS {
            1
        } else {
            self.resolve()
        }
    }
}

impl Default for Threads {
    fn default() -> Threads {
        Threads::AUTO
    }
}

/// Split `cols` output columns into at most `workers` contiguous chunks
/// whose *work* (given by `weight(j)` per column) is roughly balanced.
/// Used by the triangular (syrk-style) kernels where column `j` costs
/// `O(j)`.
pub fn balanced_col_chunks(
    cols: usize,
    workers: usize,
    weight: impl Fn(usize) -> usize,
) -> Vec<(usize, usize)> {
    let workers = workers.max(1).min(cols.max(1));
    if cols == 0 {
        return vec![];
    }
    if workers == 1 {
        return vec![(0, cols)];
    }
    let total: usize = (0..cols).map(&weight).sum::<usize>().max(1);
    let per = total.div_ceil(workers);
    let mut chunks = Vec::with_capacity(workers);
    let mut start = 0;
    let mut acc = 0;
    for j in 0..cols {
        acc += weight(j);
        if acc >= per && j + 1 < cols {
            chunks.push((start, j + 1));
            start = j + 1;
            acc = 0;
        }
    }
    chunks.push((start, cols));
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_explicit_and_auto() {
        assert_eq!(Threads(3).resolve(), 3);
        assert!(Threads::AUTO.resolve() >= 1);
        assert!(Threads::AUTO.resolve() <= MAX_AUTO_THREADS);
        assert_eq!(Threads::SINGLE.resolve(), 1);
    }

    #[test]
    fn for_flops_thresholds() {
        assert_eq!(Threads(8).for_flops(16), 1);
        assert_eq!(Threads(8).for_flops(PAR_MIN_FLOPS), 8);
    }

    #[test]
    fn chunks_cover_everything_in_order() {
        for &(cols, workers) in &[(0usize, 4usize), (1, 4), (7, 3), (100, 8), (5, 9)] {
            let chunks = balanced_col_chunks(cols, workers, |j| j + 1);
            let mut expect = 0;
            for &(lo, hi) in &chunks {
                assert_eq!(lo, expect);
                assert!(hi > lo);
                expect = hi;
            }
            assert_eq!(expect, cols);
            assert!(chunks.len() <= workers.max(1));
        }
    }

    #[test]
    fn triangular_weights_balance() {
        // with weight j+1 the last chunk must not hold most columns
        let chunks = balanced_col_chunks(64, 4, |j| j + 1);
        assert!(chunks.len() >= 2);
        let (lo, hi) = chunks[chunks.len() - 1];
        assert!(hi - lo < 40, "last chunk too wide: {lo}..{hi}");
    }
}
